//! Bench target for Fig. 2 (needle score vs r*L, both tokenizer variants)
//! and the Fig. 3/4 depth x context grids.
//!
//! `cargo bench --bench fig2_needle`

use std::sync::Arc;
use std::time::Instant;

use lagkv::engine::Engine;
use lagkv::harness::{self, EvalOptions};

/// CPU reference backend by default; LAGKV_BACKEND=xla for the PJRT path.
fn load_engine(variant: &str) -> anyhow::Result<Engine> {
    lagkv::backend::EngineSpec::from_env()?.build(variant)
}

fn main() -> anyhow::Result<()> {
    let items: usize =
        std::env::var("LAGKV_BENCH_ITEMS").ok().and_then(|s| s.parse().ok()).unwrap_or(6);
    let opts = EvalOptions { n_items: items, ..Default::default() };
    let engines = vec![
        Arc::new(load_engine("llama_like")?),
        Arc::new(load_engine("qwen_like")?),
    ];
    std::fs::create_dir_all("target/paper")?;

    let t0 = Instant::now();
    let fig2 = harness::fig2(&engines, &opts)?;
    println!("{}", fig2.render());
    std::fs::write("target/paper/fig2.txt", fig2.render())?;
    std::fs::write("target/paper/fig2.csv", fig2.to_csv())?;

    for (engine, name) in engines.iter().zip(["fig3", "fig4"]) {
        for (ri, r) in [0.5, 0.25].into_iter().enumerate() {
            let grid = harness::fig34(engine, 64, r, &opts)?;
            println!("{}", grid.render());
            std::fs::write(format!("target/paper/{name}_r{ri}.txt"), grid.render())?;
        }
    }
    println!("fig2/3/4 bench wall {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

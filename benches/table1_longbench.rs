//! Bench target for Table 1 (DESIGN.md §4 row T1): regenerates the
//! LongBench-like category scores + needle column for both models over the
//! (L, r) grid, and times the end-to-end evaluation.
//!
//! `cargo bench --bench table1_longbench` (honours LAGKV_BENCH_ITEMS).
//!
//! Accuracy tables are the paper artifact; wall-clock is reported so this
//! doubles as an end-to-end throughput regression check.

use std::sync::Arc;
use std::time::Instant;

use lagkv::engine::Engine;
use lagkv::harness::{self, EvalOptions};

/// CPU reference backend by default; LAGKV_BACKEND=xla for the PJRT path.
fn load_engine(variant: &str) -> anyhow::Result<Engine> {
    lagkv::backend::EngineSpec::from_env()?.build(variant)
}

fn main() -> anyhow::Result<()> {
    let items: usize = std::env::var("LAGKV_BENCH_ITEMS").ok().and_then(|s| s.parse().ok()).unwrap_or(6);
    let opts = EvalOptions { n_items: items, ..Default::default() };
    let engines = vec![
        Arc::new(load_engine("llama_like")?),
        Arc::new(load_engine("qwen_like")?),
    ];
    let t0 = Instant::now();
    let table = harness::table1(&engines, &opts)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("{}", table.render());
    println!("table1 bench: {items} items/cell, wall {dt:.1}s");
    std::fs::create_dir_all("target/paper")?;
    std::fs::write("target/paper/table1.txt", table.render())?;
    std::fs::write("target/paper/table1.csv", table.to_csv())?;
    Ok(())
}

//! Performance benchmarks for the serving hot paths (§Perf deliverable):
//!
//!   * LagKV scoring kernel (pure-Rust) across partition sizes,
//!   * top-k selection,
//!   * KvCache append / compact / padded-export,
//!   * pooled block-remap compaction vs the old flat rebuild (with
//!     kvpool occupancy / high-water / fragmentation gauges),
//!   * 2-turn session resume via `prefill_onto` (pool-ledger evidence
//!     that a resume allocates only tail blocks),
//!   * the b=1-kill acceptance bench: n=2048 resume through the legacy
//!     copy-storm loop vs incremental b=1 vs the packed wide-bucket walk
//!     (>=5x asserted; results plus p50/p90/p99 segment-latency rows
//!     from the telemetry `HistogramRegistry` land in BENCH_prefill.json),
//!   * prefix-hit prefill on a shared-prefix workload (radix prefix
//!     cache: zero deep row copies asserted via the pool ledger, fewer
//!     backend prefill tokens than cold, hit/miss/reuse gauges),
//!   * the tiered-storage round trip: demote every frozen block to the
//!     disk store, fault the payload back with a full gather, re-demote
//!     (sticky store ids write nothing) — ledger exactness and
//!     bit-identity asserted; results land in BENCH_store.json,
//!   * the block codec (`--quant int8`): encode-at-freeze and
//!     decode-at-read throughput across block geometries plus the
//!     end-to-end resident-byte saving of an int8 freeze — error bound
//!     asserted; results land in BENCH_quant.json,
//!   * decode step (engine, literal path),
//!   * prefill per bucket,
//!   * end-to-end generation tokens/s,
//!   * streaming TTFT + inter-token latency off the live event stream,
//!   * XLA scorer vs Rust scorer (transfer overhead quantified).
//!
//! `cargo bench --bench perf_hotpath` — self-timed (no criterion offline).
//! Record results per backend in EXPERIMENTS.md (convention documented
//! there) so perf regressions stay attributable.

use std::time::Instant;

use lagkv::backend::ExecBackend;
use lagkv::compress::policy::make_policy;
use lagkv::compress::{maybe_compress, scores, topk};
use lagkv::config::{CompressionConfig, PolicyKind};
use lagkv::coordinator::{Event, GenerateParams, Router};
use lagkv::engine::{Engine, SlotState};
use lagkv::kvcache::KvCache;
use lagkv::kvpool::{BlockPool, PrefixConfig};
use lagkv::metrics::{Histogram, PoolGauges};
use lagkv::util::argmax;
use lagkv::util::rng::Rng;
use lagkv::util::time_it;
use lagkv::workloads::passkey::{gen_passkey, PasskeySpec};

/// Backend selection for engine-level benches: the hermetic CPU reference
/// backend by default, the PJRT artifact path with LAGKV_BACKEND=xla.
fn load_engine(variant: &str) -> anyhow::Result<Engine> {
    lagkv::backend::EngineSpec::from_env()?.build(variant)
}

fn row(name: &str, mean_ns: f64, note: &str) {
    let (val, unit) = if mean_ns >= 1e6 {
        (mean_ns / 1e6, "ms")
    } else if mean_ns >= 1e3 {
        (mean_ns / 1e3, "us")
    } else {
        (mean_ns, "ns")
    };
    println!("{name:<44} {val:>10.2} {unit:<2}  {note}");
}

fn bench_scores() {
    let mut rng = Rng::seed_from(1);
    for &(l, d) in &[(16usize, 32usize), (64, 32), (128, 32), (1024, 64)] {
        let mk = |rng: &mut Rng| -> Vec<f32> { (0..l * d).map(|_| rng.normal()).collect() };
        let kc = mk(&mut rng);
        let vc = mk(&mut rng);
        let kr = mk(&mut rng);
        let vr = mk(&mut rng);
        let (mean, _) = time_it(3, 30, || {
            std::hint::black_box(scores::lagkv_score(&kc, &vc, &kr, &vr, l, d));
        });
        let bytes = 4 * l * d * 4;
        row(
            &format!("lagkv_score L={l} D={d}"),
            mean,
            &format!("{:.2} GB/s", bytes as f64 / mean),
        );
    }
}

fn bench_topk() {
    let mut rng = Rng::seed_from(2);
    for &l in &[64usize, 128, 1024] {
        let s: Vec<f32> = (0..l).map(|_| rng.normal()).collect();
        let k = l / 4;
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        let (mean, _) = time_it(3, 100, || {
            topk::topk_indices_into(&s, k, &mut scratch, &mut out);
            std::hint::black_box(&out);
        });
        row(&format!("topk L={l} k={k}"), mean, "");
    }
}

fn bench_kvcache() {
    let (nl, nh, d) = (4usize, 2usize, 32usize);
    let w = nl * nh * d;
    let mut rng = Rng::seed_from(3);
    let k: Vec<f32> = (0..w).map(|_| rng.normal()).collect();

    let (mean, _) = time_it(3, 50, || {
        let mut c = KvCache::new(nl, nh, d);
        for t in 0..512 {
            c.append_token(&k, &k, t).unwrap();
        }
        std::hint::black_box(c.len(0));
    });
    row("kvcache append x512", mean, "");

    let cfg = CompressionConfig { policy: PolicyKind::LagKv, sink: 4, lag: 64, ratio: 0.25, ..Default::default() };
    let (mean, _) = time_it(3, 20, || {
        let mut c = KvCache::new(nl, nh, d);
        let mut scorer = make_policy(PolicyKind::LagKv, 0);
        for t in 0..512 {
            c.append_token(&k, &k, t).unwrap();
            maybe_compress(&mut c, &cfg, scorer.as_mut()).unwrap();
        }
        std::hint::black_box(c.len(0));
    });
    row("append+compress x512 (L=64, 4x)", mean, "");

    let mut c = KvCache::new(nl, nh, d);
    for t in 0..400 {
        c.append_token(&k, &k, t).unwrap();
    }
    let (mean, _) = time_it(3, 50, || {
        std::hint::black_box(c.all_padded(512));
    });
    row("all_padded export (400 rows -> 512)", mean, "");
}

/// The old flat per-head store (pre-kvpool): `compact_window` rebuilt the
/// whole `(layer, head)` allocation on every event.  Kept here verbatim as
/// the baseline the pooled block-remap must not regress against.  A
/// sibling copy in rust/tests/properties.rs is the *semantic* reference —
/// change neither without the other.
struct FlatHead {
    k: Vec<f32>,
    v: Vec<f32>,
    pos: Vec<i32>,
    attn: Vec<f32>,
}

impl FlatHead {
    fn compact_window(&mut self, d: usize, start: usize, l: usize, keep: &[usize]) {
        let mut k = Vec::with_capacity(self.k.len() - (l - keep.len()) * d);
        let mut v = Vec::with_capacity(k.capacity());
        let mut pos = Vec::with_capacity(self.pos.len() - (l - keep.len()));
        let mut attn = Vec::with_capacity(pos.capacity());
        k.extend_from_slice(&self.k[..start * d]);
        v.extend_from_slice(&self.v[..start * d]);
        pos.extend_from_slice(&self.pos[..start]);
        attn.extend_from_slice(&self.attn[..start]);
        for &i in keep {
            let r = start + i;
            k.extend_from_slice(&self.k[r * d..(r + 1) * d]);
            v.extend_from_slice(&self.v[r * d..(r + 1) * d]);
            pos.push(self.pos[r]);
            attn.push(self.attn[r]);
        }
        k.extend_from_slice(&self.k[(start + l) * d..]);
        v.extend_from_slice(&self.v[(start + l) * d..]);
        pos.extend_from_slice(&self.pos[start + l..]);
        attn.extend_from_slice(&self.attn[start + l..]);
        self.k = k;
        self.v = v;
        self.pos = pos;
        self.attn = attn;
    }
}

/// Decode-cadence compaction: the same chain of L=64, keep-16 windows
/// (start marching like the driver's boundary) applied to the pooled
/// cache (block-remap + freeze) and to the old flat rebuild.
fn bench_compact_remap() {
    let (nh, d) = (2usize, 32usize);
    for &n in &[512usize, 2048] {
        let mut rng = Rng::seed_from(6);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let keep: Vec<usize> = (0..16).map(|i| i * 4).collect();
        let mut windows = Vec::new();
        {
            let mut start = 4usize;
            let mut len = n;
            while start + 64 <= len {
                windows.push(start);
                len -= 48;
                start += 16;
            }
        }

        let pool = BlockPool::unbounded(16);
        let mut base = KvCache::new_in(pool.clone(), 1, nh, d);
        for t in 0..n {
            let mut rowbuf = Vec::with_capacity(nh * d);
            for _ in 0..nh {
                rowbuf.extend_from_slice(&rows[t * d..(t + 1) * d]);
            }
            base.append_token(&rowbuf, &rowbuf, t as i32).unwrap();
        }
        let keeps: Vec<Vec<usize>> = vec![keep.clone(); nh];
        let (mean_pooled, _) = time_it(3, 20, || {
            let mut c = base.clone();
            for &s in &windows {
                c.compact_layer(0, s, 64, &keeps).unwrap();
            }
            std::hint::black_box(c.len(0));
        });
        row(
            &format!("compact chain n={n} (pooled block-remap)"),
            mean_pooled,
            &format!("{} windows", windows.len()),
        );

        let base_flat: Vec<FlatHead> = (0..nh)
            .map(|_| FlatHead {
                k: rows.clone(),
                v: rows.clone(),
                pos: (0..n as i32).collect(),
                attn: vec![0.0; n],
            })
            .collect();
        let (mean_flat, _) = time_it(3, 20, || {
            let mut heads: Vec<FlatHead> = base_flat
                .iter()
                .map(|f| FlatHead {
                    k: f.k.clone(),
                    v: f.v.clone(),
                    pos: f.pos.clone(),
                    attn: f.attn.clone(),
                })
                .collect();
            for &s in &windows {
                for h in heads.iter_mut() {
                    h.compact_window(d, s, 64, &keep);
                }
            }
            std::hint::black_box(heads[0].pos.len());
        });
        row(
            &format!("compact chain n={n} (flat rebuild baseline)"),
            mean_flat,
            &format!("{:.2}x the pooled remap", mean_flat / mean_pooled),
        );
        println!("{}", PoolGauges::from(&pool.stats()).render());
    }
}

fn bench_engine(engine: &Engine) -> anyhow::Result<()> {
    let mut rng = Rng::seed_from(4);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 260, n_digits: 32, depth: None });
    let ids = engine.tokenizer.encode(&item.prompt, true);

    // prefill per bucket
    for short in [false, true] {
        let use_ids: Vec<i32> = if short { ids[..100].to_vec() } else { ids.clone() };
        let bucket = engine.pick_prefill_bucket(use_ids.len())?;
        let (mean, _) = time_it(1, 5, || {
            std::hint::black_box(engine.prefill(&use_ids).unwrap());
        });
        row(&format!("prefill bucket={bucket} ({} toks)", use_ids.len()), mean, "");
    }

    // single decode step via step_batch(b=1)
    let cfg = CompressionConfig { policy: PolicyKind::LagKv, sink: 4, lag: 64, ratio: 0.5, ..Default::default() };
    let (logits, cache) = engine.prefill(&ids)?;
    let first = argmax(&logits) as i32;
    let scorer = engine.make_scorer(&cfg, 0);
    let mut slots = vec![SlotState::occupied(cache, cfg.clone(), scorer, first, 10_000)];
    let (mean, _) = time_it(2, 20, || {
        engine.step_batch(&mut slots).unwrap();
    });
    row("decode step b=1 (literal path)", mean, "");

    // batched decode b=4 (amortization)
    if engine.decode_buckets().contains(&4) {
        let mut slots4 = Vec::new();
        for _ in 0..4 {
            let (lg, c) = engine.prefill(&ids)?;
            let f = argmax(&lg) as i32;
            slots4.push(SlotState::occupied(c, cfg.clone(), engine.make_scorer(&cfg, 0), f, 10_000));
        }
        let (mean4, _) = time_it(2, 20, || {
            engine.step_batch(&mut slots4).unwrap();
        });
        row("decode step b=4 (literal path)", mean4, &format!("{:.2}x per-seq speedup", 4.0 * mean / mean4));
    }

    // end-to-end generation throughput
    let t0 = Instant::now();
    let mut toks = 0usize;
    for i in 0..3 {
        let out = engine.generate(&item.prompt, &cfg, 48, i)?;
        toks += out.tokens.len();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{:<44} {:>10.2} tok/s  (3 gens, lagkv 2x)",
        "e2e generation throughput",
        toks as f64 / dt
    );
    Ok(())
}

/// A 2-turn session resume through `prefill_onto`: the resumed turn must
/// allocate only its own tail blocks (zero full-cache deep copies; the
/// pool ledger is the evidence — properties.rs asserts the same bound).
fn bench_session_resume(engine: &Engine) -> anyhow::Result<()> {
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        sink: 4,
        lag: 16,
        ratio: 0.25,
        ..Default::default()
    };
    let mut rng = Rng::seed_from(11);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 260, n_digits: 16, depth: None });
    let ids = engine.tokenizer.encode(&item.prompt, true);
    let (logits, mut cache) = engine.prefill(&ids)?;
    let mut scorer = engine.make_scorer(&cfg, 0);
    maybe_compress(&mut cache, &cfg, scorer.as_mut())?;
    let history_blocks = cache.frozen_blocks();
    let history_bytes = cache.exact_bytes();
    let before = engine.pool().stats();

    let first = argmax(&logits) as i32;
    let mut feed = vec![first];
    feed.extend(engine.tokenizer.encode("<q> the pass key <a>", false));
    let t0 = Instant::now();
    engine.prefill_onto(&mut cache, &cfg, scorer.as_mut(), &feed)?;
    let dt_ns = t0.elapsed().as_nanos() as f64;
    let after = engine.pool().stats();
    row(
        "session resume prefill_onto",
        dt_ns,
        &format!("{} new toks onto {} history rows", feed.len(), ids.len()),
    );
    println!(
        "  resume allocated {} new blocks (history: {history_blocks} blocks, {history_bytes} B); \
         high-water grew {} B",
        after.resident_blocks.saturating_sub(before.resident_blocks),
        after.high_water_bytes.saturating_sub(before.high_water_bytes),
    );
    println!("{}", PoolGauges::from(&after).render());
    Ok(())
}

/// Prefix-hit prefill on a shared-prefix workload (the radix prefix
/// cache's acceptance bound): the second request attaches the shared
/// prefix CoW — zero deep row copies, asserted via the pool ledger — and
/// runs materially fewer backend prefill tokens than a cold prefill.
fn bench_prefix_cache() -> anyhow::Result<()> {
    let mut engine = load_engine("llama_like")?;
    let prefix = engine.enable_prefix_cache(PrefixConfig { stride: 64, ..Default::default() });
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        sink: 4,
        lag: 16,
        ratio: 0.25,
        ..Default::default()
    };
    let mut rng = Rng::seed_from(13);
    let sys =
        gen_passkey(&mut rng, &PasskeySpec { n_filler: 260, n_digits: 16, depth: None }).prompt;
    let ids_sys = engine.tokenizer.encode(&sys, true);
    let tail1 = engine.tokenizer.encode("<q> the pass key <a>", false);
    let tail2 = engine.tokenizer.encode("<q> remember the words <a>", false);
    let ids1: Vec<i32> = ids_sys.iter().chain(tail1.iter()).copied().collect();
    let ids2: Vec<i32> = ids_sys.iter().chain(tail2.iter()).copied().collect();

    let mut scorer = engine.make_scorer(&cfg, 0);
    let t0 = Instant::now();
    let cold = engine.prefill_cached(&ids1, &cfg, scorer.as_mut(), 0)?;
    let cold_ns = t0.elapsed().as_nanos() as f64;
    assert_eq!(cold.reused_tokens, 0, "first request must be cold");
    row(
        "prefix-cache cold prefill (seeds tree)",
        cold_ns,
        &format!("{} backend tokens", ids1.len()),
    );

    let before = engine.pool().stats();
    let t1 = Instant::now();
    let warm = engine.prefill_cached(&ids2, &cfg, scorer.as_mut(), 0)?;
    let warm_ns = t1.elapsed().as_nanos() as f64;
    let after = engine.pool().stats();
    assert!(warm.reused_tokens > 0, "shared-prefix request must hit the cache");
    let backend_tokens = ids2.len() - warm.reused_tokens;
    assert!(
        backend_tokens * 2 < ids2.len(),
        "a prefix hit must run materially fewer backend prefill tokens \
         ({backend_tokens} of {})",
        ids2.len()
    );
    // Pool-ledger evidence of zero deep row copies: attaching the shared
    // prefix duplicates no blocks, so any block growth is bounded by the
    // warm request's own suffix + one freeze of slack per (layer, head).
    let grown = after.resident_blocks.saturating_sub(before.resident_blocks);
    let rpb = engine.pool().rows_per_block();
    let suffix_cap = backend_tokens + 2 * cfg.lag + rpb;
    assert!(
        grown * rpb <= warm.cache.n_layers * warm.cache.n_heads * suffix_cap,
        "{grown} new blocks is more than the suffix could need: a deep copy happened"
    );
    row(
        "prefix-cache warm prefill (shared prefix)",
        warm_ns,
        &format!(
            "{} of {} tokens reused, {backend_tokens} backend tokens, \
             {grown} new blocks, {:.2}x cold",
            warm.reused_tokens,
            ids2.len(),
            cold_ns / warm_ns,
        ),
    );
    println!(
        "{}",
        PoolGauges::from(&after).with_prefix(&prefix.stats()).render()
    );
    Ok(())
}

/// The pre-rewrite `prefill_onto` loop, replicated via public APIs as the
/// timing baseline: every token re-exports EVERY layer's padded K/V image
/// (`layer_padded` allocates and copies `heads * tmax * d_head` rows) —
/// the O(tokens x layers x tmax) copy storm the incremental rewrite and
/// the packed wide-bucket walk both kill.  Deliberately kept in the old
/// shape; do not "fix" it.
fn legacy_copy_storm_prefill_onto(
    engine: &Engine,
    cache: &mut KvCache,
    cfg: &CompressionConfig,
    scorer: &mut dyn lagkv::compress::Scorer,
    ids: &[i32],
) -> anyhow::Result<()> {
    use lagkv::backend::DecodeBatch;
    let (nl, hkv, dh) = (engine.dims.n_layers, engine.dims.n_kv_heads, engine.dims.d_head);
    let tmax = engine.tmax;
    let per_slot = hkv * tmax * dh;
    for &tok in ids {
        let mut kbuf = Vec::with_capacity(nl * per_slot);
        let mut vbuf = Vec::with_capacity(nl * per_slot);
        let mut lens = Vec::with_capacity(nl);
        for layer in 0..nl {
            let (k, v) = cache.layer_padded(layer, tmax);
            kbuf.extend_from_slice(&k);
            vbuf.extend_from_slice(&v);
            lens.push(cache.len(layer) as i32);
        }
        let pos = cache.appended as i32;
        let out = engine.backend().decode(&DecodeBatch {
            batch: 1,
            k: &kbuf,
            v: &vbuf,
            lens: &lens,
            pos: &[pos],
            tokens: &[tok],
        })?;
        cache.append_token(&out.k_new, &out.v_new, pos)?;
        maybe_compress(cache, cfg, scorer)?;
    }
    Ok(())
}

/// The b=1-kill acceptance bench: resume a session with n=2048 new tokens
/// on a 2560-capacity CPU-ref backend and compare
///   * the legacy copy-storm loop (before),
///   * the incremental b=1 `prefill_onto` (after),
///   * the packed wide-bucket `prefill_onto_batched` (after).
/// All three must land identical cache shapes (bit-parity is pinned in
/// rust/tests/properties.rs); the packed walk must clear the >=5x
/// acceptance bound.  Results are written to BENCH_prefill.json.
fn bench_prefill_kill_b1() -> anyhow::Result<()> {
    use lagkv::backend::cpu_ref::CpuRefBackend;
    use lagkv::telemetry::{HistogramRegistry, Metric};

    const N: usize = 2048;
    let (_, tokenizer) = CpuRefBackend::load("llama_like")?;
    let backend = CpuRefBackend::with_capacity(&tokenizer.vocab, 2560);
    let engine = Engine::new(Box::new(backend), tokenizer, "llama_like")?;
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        sink: 4,
        lag: 64,
        ratio: 0.25,
        ..Default::default()
    };

    // shared history every variant resumes from, compressed once
    let mut rng = Rng::seed_from(17);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 120, n_digits: 16, depth: None });
    let ids = engine.tokenizer.encode(&item.prompt, true);
    let (_, mut base) = engine.prefill(&ids)?;
    {
        let mut scorer = engine.make_scorer(&cfg, 0);
        maybe_compress(&mut base, &cfg, scorer.as_mut())?;
    }
    let history = base.appended;
    let feed: Vec<i32> = (0..N).map(|i| ids[i % ids.len()]).collect();

    let (legacy_ns, _) = time_it(1, 2, || {
        let mut c = base.clone();
        let mut sc = engine.make_scorer(&cfg, 0);
        legacy_copy_storm_prefill_onto(&engine, &mut c, &cfg, sc.as_mut(), &feed).unwrap();
        std::hint::black_box(c.len(0));
    });
    row(
        &format!("resume n={N} (legacy copy-storm b=1)"),
        legacy_ns,
        "re-exports every layer every token",
    );

    let (incr_ns, _) = time_it(1, 3, || {
        let mut c = base.clone();
        let mut sc = engine.make_scorer(&cfg, 0);
        engine.prefill_onto(&mut c, &cfg, sc.as_mut(), &feed).unwrap();
        std::hint::black_box(c.len(0));
    });
    row(
        &format!("resume n={N} (incremental b=1)"),
        incr_ns,
        &format!("{:.2}x the copy storm", legacy_ns / incr_ns),
    );

    let (packed_ns, _) = time_it(1, 3, || {
        let mut c = base.clone();
        let mut sc = engine.make_scorer(&cfg, 0);
        engine.prefill_onto_batched(&mut c, &cfg, sc.as_mut(), &feed).unwrap();
        std::hint::black_box(c.len(0));
    });
    row(
        &format!("resume n={N} (packed wide bucket)"),
        packed_ns,
        &format!("{:.2}x the copy storm", legacy_ns / packed_ns),
    );

    // shape equivalence across all three (bit-parity pinned in properties)
    let mut c_legacy = base.clone();
    let mut c_incr = base.clone();
    let mut c_packed = base.clone();
    let mut s1 = engine.make_scorer(&cfg, 0);
    let mut s2 = engine.make_scorer(&cfg, 0);
    let mut s3 = engine.make_scorer(&cfg, 0);
    legacy_copy_storm_prefill_onto(&engine, &mut c_legacy, &cfg, s1.as_mut(), &feed)?;
    engine.prefill_onto(&mut c_incr, &cfg, s2.as_mut(), &feed)?;
    engine.prefill_onto_batched(&mut c_packed, &cfg, s3.as_mut(), &feed)?;
    for layer in 0..c_legacy.n_layers {
        assert_eq!(c_legacy.len(layer), c_incr.len(layer), "incremental diverged");
        assert_eq!(c_legacy.len(layer), c_packed.len(layer), "packed diverged");
    }

    // Latency distribution through the telemetry registry: replay the
    // packed resume in batcher-sized segments, record each segment's wall
    // time as a `prefill_segment` sample, and fold the percentile rows the
    // server reports over `ops stats`/`ops trace` into the JSON below.
    let registry = HistogramRegistry::new();
    {
        let mut c = base.clone();
        let mut sc = engine.make_scorer(&cfg, 0);
        for seg in feed.chunks(128) {
            let t0 = Instant::now();
            engine.prefill_onto_batched(&mut c, &cfg, sc.as_mut(), seg)?;
            registry.record(Metric::PrefillSegment, t0.elapsed().as_micros() as u64);
        }
    }
    let seg = registry
        .summaries()
        .into_iter()
        .find(|h| h.metric == Metric::PrefillSegment)
        .expect("the segment replay recorded samples");
    row(
        "resume segment p50 (128-tok chunks)",
        seg.p50_us as f64 * 1e3,
        &format!("p90 {} us, p99 {} us over {} segments", seg.p90_us, seg.p99_us, seg.count),
    );

    let speedup_incr = legacy_ns / incr_ns;
    let speedup_packed = legacy_ns / packed_ns;
    assert!(
        speedup_packed >= 5.0,
        "acceptance bound: packed resume must be >=5x the legacy loop, got {speedup_packed:.2}x"
    );

    let json = format!(
        "{{\n  \"bench\": \"prefill_kill_b1\",\n  \"backend\": \"cpu_ref\",\n  \
         \"n_tokens\": {N},\n  \"tmax\": 2560,\n  \"history_tokens\": {history},\n  \
         \"legacy_b1_ns\": {legacy_ns:.0},\n  \"incremental_b1_ns\": {incr_ns:.0},\n  \
         \"packed_bucket_ns\": {packed_ns:.0},\n  \
         \"speedup_incremental_vs_legacy\": {speedup_incr:.2},\n  \
         \"speedup_packed_vs_legacy\": {speedup_packed:.2},\n  \
         \"segment_samples\": {},\n  \"segment_p50_us\": {},\n  \
         \"segment_p90_us\": {},\n  \"segment_p99_us\": {}\n}}\n",
        seg.count, seg.p50_us, seg.p90_us, seg.p99_us
    );
    std::fs::write("BENCH_prefill.json", json)?;
    println!("  wrote BENCH_prefill.json");
    Ok(())
}

/// Tiered-storage round trip (ISSUE 7's spill bench): build a pooled
/// cache, demote every frozen block to a disk store under a tempdir,
/// fault the whole payload back via a full gather, then re-demote (the
/// sticky store id means the second spill writes nothing).  Asserts the
/// per-tier ledger exact at every step and the faulted payload
/// bit-identical — the randomized version lives in
/// rust/tests/properties.rs — and records the timings in
/// BENCH_store.json.  Store files live only under the tempdir, removed
/// before returning.
fn bench_store_spill() -> anyhow::Result<()> {
    use lagkv::kvpool::block_bytes;
    use lagkv::kvstore::KvStore;
    use std::sync::Arc;

    let dir = std::env::temp_dir().join(format!("lagkv-bench-store-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let run = || -> anyhow::Result<()> {
        let store = Arc::new(KvStore::open(&dir)?);
        let (nh, d, rpb) = (2usize, 32usize, 16usize);
        let bpb = block_bytes(rpb, d);
        let pool = BlockPool::unbounded(rpb);
        pool.bind_store(Arc::clone(&store));
        let mut cache = KvCache::new_in(pool.clone(), 1, nh, d);
        let cfg = CompressionConfig {
            policy: PolicyKind::LagKv,
            sink: 4,
            lag: 64,
            ratio: 0.25,
            ..Default::default()
        };
        let mut scorer = make_policy(cfg.policy, 0);
        let mut rng = Rng::seed_from(19);
        let w = nh * d;
        for t in 0..2048i32 {
            let kv: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
            cache.append_token(&kv, &kv, t)?;
            maybe_compress(&mut cache, &cfg, scorer.as_mut())?;
        }
        let blocks = cache.frozen_blocks();
        anyhow::ensure!(blocks > 0, "nothing froze — nothing to spill");
        let snap: Vec<Vec<f32>> = (0..nh).map(|h| cache.head_k(0, h)).collect();

        // demote everything resident
        let t0 = Instant::now();
        let (nblocks, nbytes) = pool.spill(usize::MAX);
        let spill_ns = t0.elapsed().as_nanos() as f64;
        anyhow::ensure!(
            nblocks == blocks && nbytes == nblocks * bpb,
            "spill ledger not exact: {nblocks}/{blocks} blocks, {nbytes} bytes"
        );
        let s = pool.stats();
        anyhow::ensure!(
            s.resident_blocks == 0 && s.spilled_blocks == nblocks && s.spilled_bytes == nbytes,
            "tier gauges out of step after demote"
        );
        row(
            &format!("store spill {nblocks} blocks -> disk"),
            spill_ns,
            &format!(
                "{:.1} KiB, {:.2} MB/s",
                nbytes as f64 / 1024.0,
                nbytes as f64 * 1e3 / spill_ns
            ),
        );

        // fault everything back with one full gather per head
        let t1 = Instant::now();
        let back: Vec<Vec<f32>> = (0..nh).map(|h| cache.head_k(0, h)).collect();
        let fault_ns = t1.elapsed().as_nanos() as f64;
        anyhow::ensure!(back == snap, "fault-in is not bit-identical");
        let s = pool.stats();
        anyhow::ensure!(
            s.resident_blocks == nblocks && s.spilled_blocks == 0,
            "fault-in created or lost blocks (no-deep-copy bound)"
        );
        row(
            &format!("store fault {nblocks} blocks <- disk"),
            fault_ns,
            &format!("{:.2} MB/s, bit-identical", nbytes as f64 * 1e3 / fault_ns),
        );

        // re-demote: payloads already on disk, so nothing is re-serialized
        let t2 = Instant::now();
        let (nb2, _) = pool.spill(usize::MAX);
        let redemote_ns = t2.elapsed().as_nanos() as f64;
        anyhow::ensure!(nb2 == nblocks, "re-demote missed blocks");
        row(
            &format!("store re-demote {nblocks} blocks (sticky ids)"),
            redemote_ns,
            &format!("{:.2}x first spill", spill_ns / redemote_ns),
        );
        println!("{}", PoolGauges::from(&pool.stats()).render());

        let json = format!(
            "{{\n  \"bench\": \"store_spill_fault\",\n  \"rows_per_block\": {rpb},\n  \
             \"blocks\": {nblocks},\n  \"payload_bytes\": {nbytes},\n  \
             \"spill_ns\": {spill_ns:.0},\n  \"fault_ns\": {fault_ns:.0},\n  \
             \"redemote_ns\": {redemote_ns:.0},\n  \
             \"spill_mb_s\": {:.2},\n  \"fault_mb_s\": {:.2}\n}}\n",
            nbytes as f64 * 1e3 / spill_ns,
            nbytes as f64 * 1e3 / fault_ns,
        );
        std::fs::write("BENCH_store.json", json)?;
        println!("  wrote BENCH_store.json");
        Ok(())
    };
    let result = run();
    let _ = std::fs::remove_dir_all(&dir);
    result
}

/// Block-codec hot loop (the quantized-KV bench): encode-at-freeze and
/// decode-at-read throughput of the int8 codec across block geometries,
/// plus the end-to-end resident-byte saving of an int8 freeze against
/// the fp32 identity path (the ledger numbers the server budgets on).
/// Asserts every decoded row inside the per-row half-step error bound —
/// the randomized version lives in rust/tests/properties.rs — and
/// records results in BENCH_quant.json.
fn bench_quant_codec() -> anyhow::Result<()> {
    use lagkv::kvpool::block_bytes;
    use lagkv::quant::{CodecKind, QuantSpec};
    use std::sync::Arc;

    let codec = CodecKind::Int8Sym.codec();
    let mut geoms = Vec::new();
    for &(rows, d) in &[(16usize, 64usize), (16, 128), (64, 128)] {
        let mut rng = Rng::seed_from(23);
        let k: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        let v: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        let raw_bytes = 2 * rows * d * 4;

        let (enc_ns, _) = time_it(3, 200, || {
            std::hint::black_box(codec.encode(rows, d, &k, &v));
        });
        row(
            &format!("int8 encode {rows}x{d}"),
            enc_ns,
            &format!("{:.2} GB/s", raw_bytes as f64 / enc_ns),
        );

        let enc = codec.encode(rows, d, &k, &v);
        let mut ko = Vec::new();
        let mut vo = Vec::new();
        let (dec_ns, _) = time_it(3, 200, || {
            ko.clear();
            vo.clear();
            codec.decode(rows, d, &enc, &mut ko, &mut vo);
            std::hint::black_box(ko.len());
        });
        row(
            &format!("int8 decode {rows}x{d}"),
            dec_ns,
            &format!("{:.2} GB/s", raw_bytes as f64 / dec_ns),
        );

        // round-trip error bound: half a per-row quantization step
        for (orig_all, dec_all) in [(&k, &ko), (&v, &vo)] {
            for r in 0..rows {
                let orig = &orig_all[r * d..(r + 1) * d];
                let dec = &dec_all[r * d..(r + 1) * d];
                let max_abs = orig.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                let bound = max_abs / 127.0 * 0.501 + 1e-7;
                for (o, x) in orig.iter().zip(dec) {
                    anyhow::ensure!(
                        (o - x).abs() <= bound,
                        "row {r}: decode outside the half-step bound"
                    );
                }
            }
        }

        let enc_bytes = CodecKind::Int8Sym.encoded_block_bytes(rows, d);
        geoms.push(format!(
            "    {{\"rows\": {rows}, \"d\": {d}, \"raw_kv_bytes\": {raw_bytes}, \
             \"encoded_block_bytes\": {enc_bytes}, \"encode_ns\": {enc_ns:.0}, \
             \"decode_ns\": {dec_ns:.0}, \"encode_gb_s\": {:.2}, \"decode_gb_s\": {:.2}}}",
            raw_bytes as f64 / enc_ns,
            raw_bytes as f64 / dec_ns,
        ));
    }

    // end-to-end: freeze the same 512-row stream through each codec and
    // compare the exact resident footprint the admission budget sees
    let (nh, d, rpb) = (2usize, 64usize, 16usize);
    let mut fp = KvCache::new_in(BlockPool::unbounded(rpb), 1, nh, d);
    let mut q = KvCache::new_in(BlockPool::unbounded(rpb), 1, nh, d);
    q.set_quant(Arc::new(QuantSpec::all(CodecKind::Int8Sym)));
    let mut rng = Rng::seed_from(29);
    for t in 0..512i32 {
        let kv: Vec<f32> = (0..nh * d).map(|_| rng.normal()).collect();
        fp.append_token(&kv, &kv, t)?;
        q.append_token(&kv, &kv, t)?;
    }
    fp.freeze_layer_prefix(0, 512);
    q.freeze_layer_prefix(0, 512);
    let (fp_bytes, q_bytes) = (fp.exact_bytes(), q.exact_bytes());
    let saving = 1.0 - q_bytes as f64 / fp_bytes as f64;
    println!(
        "  int8 freeze of 512x{nh}x{d}: {q_bytes} B vs fp32 {fp_bytes} B \
         ({:.1}% resident saving, block {} -> {} B)",
        saving * 100.0,
        block_bytes(rpb, d),
        CodecKind::Int8Sym.encoded_block_bytes(rpb, d),
    );

    let json = format!(
        "{{\n  \"bench\": \"quant_codec\",\n  \"codec\": \"int8\",\n  \
         \"geometries\": [\n{}\n  ],\n  \
         \"freeze_rows\": 512,\n  \"freeze_heads\": {nh},\n  \"freeze_d\": {d},\n  \
         \"fp32_exact_bytes\": {fp_bytes},\n  \"int8_exact_bytes\": {q_bytes},\n  \
         \"resident_saving\": {saving:.4}\n}}\n",
        geoms.join(",\n"),
    );
    std::fs::write("BENCH_quant.json", json)?;
    println!("  wrote BENCH_quant.json");
    Ok(())
}

/// Streaming latencies only the event API can expose: time-to-first-token
/// (queue + prefill + first decode) and the inter-token gap, measured off
/// the live `Router::submit` stream.
fn bench_streaming() -> anyhow::Result<()> {
    let spec = lagkv::backend::EngineSpec::from_env()?;
    let router = Router::start(spec, &["llama_like".to_string()]);
    let mut rng = Rng::seed_from(7);
    let mut ttft = Histogram::new();
    let mut gaps = Histogram::new();
    for i in 0..6u64 {
        let item =
            gen_passkey(&mut rng, &PasskeySpec { n_filler: 200, n_digits: 16, depth: None });
        let req = GenerateParams::new(item.prompt)
            .lag(64)
            .ratio(0.5)
            .max_new(48)
            .seed(i)
            .into_request(i)?;
        let t0 = Instant::now();
        let handle = router.submit("llama_like", req)?;
        let mut last: Option<Instant> = None;
        for ev in handle.events.iter() {
            if matches!(ev, Event::Token { .. }) {
                let now = Instant::now();
                match last {
                    None => ttft.record(now - t0),
                    Some(prev) => gaps.record(now - prev),
                }
                last = Some(now);
            }
            if ev.is_terminal() {
                break;
            }
        }
    }
    row(
        "stream TTFT (submit -> first token)",
        ttft.mean_ms() * 1e6,
        &format!("p95 {:.2} ms over {} streams", ttft.p95_ms(), ttft.count()),
    );
    row(
        "stream inter-token latency",
        gaps.mean_ms() * 1e6,
        &format!("p95 {:.3} ms over {} gaps", gaps.p95_ms(), gaps.count()),
    );
    router.shutdown();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("== perf_hotpath ==");
    bench_scores();
    bench_topk();
    bench_kvcache();
    bench_compact_remap();
    match load_engine("llama_like") {
        Ok(engine) => {
            println!("-- engine benches ({}) --", engine.backend().platform());
            bench_engine(&engine)?;
            bench_session_resume(&engine)?;
        }
        Err(e) => eprintln!("SKIP engine benches: {e:#}"),
    }
    match bench_prefix_cache() {
        Ok(()) => {}
        Err(e) => eprintln!("SKIP prefix-cache bench: {e:#}"),
    }
    match bench_prefill_kill_b1() {
        Ok(()) => {}
        Err(e) => eprintln!("SKIP prefill b=1-kill bench: {e:#}"),
    }
    match bench_store_spill() {
        Ok(()) => {}
        Err(e) => eprintln!("SKIP tiered-storage bench: {e:#}"),
    }
    match bench_quant_codec() {
        Ok(()) => {}
        Err(e) => eprintln!("SKIP quant-codec bench: {e:#}"),
    }
    match bench_streaming() {
        Ok(()) => {}
        Err(e) => eprintln!("SKIP streaming benches: {e:#}"),
    }
    Ok(())
}

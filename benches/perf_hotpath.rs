//! Performance benchmarks for the serving hot paths (§Perf deliverable):
//!
//!   * LagKV scoring kernel (pure-Rust) across partition sizes,
//!   * top-k selection,
//!   * KvCache append / compact / padded-export,
//!   * decode step (engine, literal path),
//!   * prefill per bucket,
//!   * end-to-end generation tokens/s,
//!   * streaming TTFT + inter-token latency off the live event stream,
//!   * XLA scorer vs Rust scorer (transfer overhead quantified).
//!
//! `cargo bench --bench perf_hotpath` — self-timed (no criterion offline).
//! Record results per backend in EXPERIMENTS.md (convention documented
//! there) so perf regressions stay attributable.

use std::time::Instant;

use lagkv::compress::policy::make_policy;
use lagkv::compress::{maybe_compress, scores, topk};
use lagkv::config::{CompressionConfig, PolicyKind};
use lagkv::coordinator::{Event, GenerateParams, Router};
use lagkv::engine::{Engine, SlotState};
use lagkv::kvcache::KvCache;
use lagkv::metrics::Histogram;
use lagkv::util::argmax;
use lagkv::util::rng::Rng;
use lagkv::util::time_it;
use lagkv::workloads::passkey::{gen_passkey, PasskeySpec};

/// Backend selection for engine-level benches: the hermetic CPU reference
/// backend by default, the PJRT artifact path with LAGKV_BACKEND=xla.
fn load_engine(variant: &str) -> anyhow::Result<Engine> {
    lagkv::backend::EngineSpec::from_env()?.build(variant)
}

fn row(name: &str, mean_ns: f64, note: &str) {
    let (val, unit) = if mean_ns >= 1e6 {
        (mean_ns / 1e6, "ms")
    } else if mean_ns >= 1e3 {
        (mean_ns / 1e3, "us")
    } else {
        (mean_ns, "ns")
    };
    println!("{name:<44} {val:>10.2} {unit:<2}  {note}");
}

fn bench_scores() {
    let mut rng = Rng::seed_from(1);
    for &(l, d) in &[(16usize, 32usize), (64, 32), (128, 32), (1024, 64)] {
        let mk = |rng: &mut Rng| -> Vec<f32> { (0..l * d).map(|_| rng.normal()).collect() };
        let kc = mk(&mut rng);
        let vc = mk(&mut rng);
        let kr = mk(&mut rng);
        let vr = mk(&mut rng);
        let (mean, _) = time_it(3, 30, || {
            std::hint::black_box(scores::lagkv_score(&kc, &vc, &kr, &vr, l, d));
        });
        let bytes = 4 * l * d * 4;
        row(
            &format!("lagkv_score L={l} D={d}"),
            mean,
            &format!("{:.2} GB/s", bytes as f64 / mean),
        );
    }
}

fn bench_topk() {
    let mut rng = Rng::seed_from(2);
    for &l in &[64usize, 128, 1024] {
        let s: Vec<f32> = (0..l).map(|_| rng.normal()).collect();
        let k = l / 4;
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        let (mean, _) = time_it(3, 100, || {
            topk::topk_indices_into(&s, k, &mut scratch, &mut out);
            std::hint::black_box(&out);
        });
        row(&format!("topk L={l} k={k}"), mean, "");
    }
}

fn bench_kvcache() {
    let (nl, nh, d) = (4usize, 2usize, 32usize);
    let w = nl * nh * d;
    let mut rng = Rng::seed_from(3);
    let k: Vec<f32> = (0..w).map(|_| rng.normal()).collect();

    let (mean, _) = time_it(3, 50, || {
        let mut c = KvCache::new(nl, nh, d);
        for t in 0..512 {
            c.append_token(&k, &k, t).unwrap();
        }
        std::hint::black_box(c.len(0));
    });
    row("kvcache append x512", mean, "");

    let cfg = CompressionConfig { policy: PolicyKind::LagKv, sink: 4, lag: 64, ratio: 0.25, ..Default::default() };
    let (mean, _) = time_it(3, 20, || {
        let mut c = KvCache::new(nl, nh, d);
        let mut scorer = make_policy(PolicyKind::LagKv, 0);
        for t in 0..512 {
            c.append_token(&k, &k, t).unwrap();
            maybe_compress(&mut c, &cfg, scorer.as_mut()).unwrap();
        }
        std::hint::black_box(c.len(0));
    });
    row("append+compress x512 (L=64, 4x)", mean, "");

    let mut c = KvCache::new(nl, nh, d);
    for t in 0..400 {
        c.append_token(&k, &k, t).unwrap();
    }
    let (mean, _) = time_it(3, 50, || {
        std::hint::black_box(c.all_padded(512));
    });
    row("all_padded export (400 rows -> 512)", mean, "");
}

fn bench_engine(engine: &Engine) -> anyhow::Result<()> {
    let mut rng = Rng::seed_from(4);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 260, n_digits: 32, depth: None });
    let ids = engine.tokenizer.encode(&item.prompt, true);

    // prefill per bucket
    for short in [false, true] {
        let use_ids: Vec<i32> = if short { ids[..100].to_vec() } else { ids.clone() };
        let bucket = engine.pick_prefill_bucket(use_ids.len())?;
        let (mean, _) = time_it(1, 5, || {
            std::hint::black_box(engine.prefill(&use_ids).unwrap());
        });
        row(&format!("prefill bucket={bucket} ({} toks)", use_ids.len()), mean, "");
    }

    // single decode step via step_batch(b=1)
    let cfg = CompressionConfig { policy: PolicyKind::LagKv, sink: 4, lag: 64, ratio: 0.5, ..Default::default() };
    let (logits, cache) = engine.prefill(&ids)?;
    let first = argmax(&logits) as i32;
    let scorer = engine.make_scorer(&cfg, 0);
    let mut slots = vec![SlotState::occupied(cache, cfg.clone(), scorer, first, 10_000)];
    let (mean, _) = time_it(2, 20, || {
        engine.step_batch(&mut slots).unwrap();
    });
    row("decode step b=1 (literal path)", mean, "");

    // batched decode b=4 (amortization)
    if engine.decode_buckets().contains(&4) {
        let mut slots4 = Vec::new();
        for _ in 0..4 {
            let (lg, c) = engine.prefill(&ids)?;
            let f = argmax(&lg) as i32;
            slots4.push(SlotState::occupied(c, cfg.clone(), engine.make_scorer(&cfg, 0), f, 10_000));
        }
        let (mean4, _) = time_it(2, 20, || {
            engine.step_batch(&mut slots4).unwrap();
        });
        row("decode step b=4 (literal path)", mean4, &format!("{:.2}x per-seq speedup", 4.0 * mean / mean4));
    }

    // end-to-end generation throughput
    let t0 = Instant::now();
    let mut toks = 0usize;
    for i in 0..3 {
        let out = engine.generate(&item.prompt, &cfg, 48, i)?;
        toks += out.tokens.len();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{:<44} {:>10.2} tok/s  (3 gens, lagkv 2x)",
        "e2e generation throughput",
        toks as f64 / dt
    );
    Ok(())
}

/// Streaming latencies only the event API can expose: time-to-first-token
/// (queue + prefill + first decode) and the inter-token gap, measured off
/// the live `Router::submit` stream.
fn bench_streaming() -> anyhow::Result<()> {
    let spec = lagkv::backend::EngineSpec::from_env()?;
    let router = Router::start(spec, &["llama_like".to_string()]);
    let mut rng = Rng::seed_from(7);
    let mut ttft = Histogram::new();
    let mut gaps = Histogram::new();
    for i in 0..6u64 {
        let item =
            gen_passkey(&mut rng, &PasskeySpec { n_filler: 200, n_digits: 16, depth: None });
        let req = GenerateParams::new(item.prompt)
            .lag(64)
            .ratio(0.5)
            .max_new(48)
            .seed(i)
            .into_request(i)?;
        let t0 = Instant::now();
        let handle = router.submit("llama_like", req)?;
        let mut last: Option<Instant> = None;
        for ev in handle.events.iter() {
            if matches!(ev, Event::Token { .. }) {
                let now = Instant::now();
                match last {
                    None => ttft.record(now - t0),
                    Some(prev) => gaps.record(now - prev),
                }
                last = Some(now);
            }
            if ev.is_terminal() {
                break;
            }
        }
    }
    row(
        "stream TTFT (submit -> first token)",
        ttft.mean_ms() * 1e6,
        &format!("p95 {:.2} ms over {} streams", ttft.p95_ms(), ttft.count()),
    );
    row(
        "stream inter-token latency",
        gaps.mean_ms() * 1e6,
        &format!("p95 {:.3} ms over {} gaps", gaps.p95_ms(), gaps.count()),
    );
    router.shutdown();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("== perf_hotpath ==");
    bench_scores();
    bench_topk();
    bench_kvcache();
    match load_engine("llama_like") {
        Ok(engine) => {
            println!("-- engine benches ({}) --", engine.backend().platform());
            bench_engine(&engine)?;
        }
        Err(e) => eprintln!("SKIP engine benches: {e:#}"),
    }
    match bench_streaming() {
        Ok(()) => {}
        Err(e) => eprintln!("SKIP streaming benches: {e:#}"),
    }
    Ok(())
}

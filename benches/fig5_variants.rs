//! Bench target for Fig. 5 (LagKV vs LocalKV vs recursive-L2 variants) and
//! the §3.3 H2O comparison, plus the model-free simulator sweep and the
//! Eq. 10/11 ratio table.
//!
//! `cargo bench --bench fig5_variants`

use std::time::Instant;

use lagkv::engine::Engine;
use lagkv::harness::{self, EvalOptions};

/// CPU reference backend by default; LAGKV_BACKEND=xla for the PJRT path.
fn load_engine(variant: &str) -> anyhow::Result<Engine> {
    lagkv::backend::EngineSpec::from_env()?.build(variant)
}

fn main() -> anyhow::Result<()> {
    std::fs::create_dir_all("target/paper")?;

    // Model-free pieces always run.
    let ratio = harness::ratio_table();
    println!("{}", ratio.render());
    std::fs::write("target/paper/ratio.txt", ratio.render())?;

    let sim = harness::sim_fig5(16);
    println!("{}", sim.render());
    std::fs::write("target/paper/sim_fig5.txt", sim.render())?;

    let items: usize =
        std::env::var("LAGKV_BENCH_ITEMS").ok().and_then(|s| s.parse().ok()).unwrap_or(6);
    let opts = EvalOptions { n_items: items, ..Default::default() };
    let engine = load_engine("llama_like")?;
    let t0 = Instant::now();
    let fig5 = harness::fig5(&engine, 128, &opts)?;
    println!("{}", fig5.render());
    std::fs::write("target/paper/fig5.txt", fig5.render())?;

    let h2o = harness::h2o_table(&engine, 64, &opts)?;
    println!("{}", h2o.render());
    std::fs::write("target/paper/h2o.txt", h2o.render())?;
    println!("fig5/h2o bench wall {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

//! Checked-in baseline of grandfathered violations.
//!
//! Format: one entry per line, `<rule> <path> <count>`, with `#`
//! comments and blank lines ignored:
//!
//! ```text
//! # pre-existing panic sites, to be burned down
//! panic rust/src/kvpool/mod.rs 20
//! ```
//!
//! Applying the baseline suppresses up to `count` violations of `rule`
//! in `path` (lowest lines first).  The budget never goes negative and
//! unused budget is simply ignored — so deleting a grandfathered site
//! keeps the tree green, while adding a new one overflows the budget and
//! fails the lint.

use std::path::Path;

use crate::{Rule, Violation};

#[derive(Debug, Default)]
pub struct Baseline {
    entries: Vec<(Rule, String, usize)>,
}

impl Baseline {
    pub fn parse(text: &str) -> Result<Baseline, String> {
        let mut entries = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let (Some(rule), Some(path), Some(count), None) =
                (parts.next(), parts.next(), parts.next(), parts.next())
            else {
                return Err(format!(
                    "baseline line {}: expected `<rule> <path> <count>`, got {raw:?}",
                    lineno + 1
                ));
            };
            let rule = Rule::parse(rule)
                .ok_or_else(|| format!("baseline line {}: unknown rule {rule:?}", lineno + 1))?;
            let count: usize = count
                .parse()
                .map_err(|_| format!("baseline line {}: bad count {count:?}", lineno + 1))?;
            entries.push((rule, path.to_string(), count));
        }
        Ok(Baseline { entries })
    }

    /// Load a baseline file; a missing file is an empty baseline.
    pub fn load(path: &Path) -> Result<Baseline, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => Baseline::parse(&text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Baseline::default()),
            Err(e) => Err(format!("read {}: {e}", path.display())),
        }
    }

    pub fn entries(&self) -> &[(Rule, String, usize)] {
        &self.entries
    }

    /// Split `vios` into (still-failing, grandfathered-count).  `vios`
    /// must be sorted by (rule, file, line) — [`crate::check_tree`]'s
    /// output order — so the suppressed sites are the lowest lines.
    pub fn apply(&self, vios: Vec<Violation>) -> (Vec<Violation>, usize) {
        let mut budget: Vec<(Rule, &str, usize)> =
            self.entries.iter().map(|(r, p, c)| (*r, p.as_str(), *c)).collect();
        let mut remaining = Vec::new();
        let mut grandfathered = 0usize;
        'vio: for v in vios {
            for slot in budget.iter_mut() {
                if slot.0 == v.rule && slot.1 == v.file && slot.2 > 0 {
                    slot.2 -= 1;
                    grandfathered += 1;
                    continue 'vio;
                }
            }
            remaining.push(v);
        }
        (remaining, grandfathered)
    }
}

//! Minimal Rust lexer: just enough token structure for the rule engine.
//!
//! Comments never become tokens; instead each comment's text is recorded
//! against its starting line so the allow-comment grammar
//! (`// lint: allow(<rule>): <reason>`) can be resolved per line.  String
//! and char literals are consumed whole (their content can never trigger
//! a rule), lifetimes are distinguished from char literals, and numeric
//! literals fold a fractional part only when a digit follows the dot —
//! so `0..n` lexes as range punctuation, not a float.

use std::collections::{HashMap, HashSet};

use crate::Rule;

/// Token class.  Only identifiers and punctuation carry text; literal
/// payloads are irrelevant to every rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Id,
    Num,
    Str,
    CharLit,
    Lifetime,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// Lexed file: the token stream plus everything the allow-comment
/// machinery needs.
pub struct Lexed {
    pub toks: Vec<Tok>,
    /// Line -> rules suppressed by an allow comment on that line.
    pub allow: HashMap<u32, HashSet<Rule>>,
    /// Lines holding only comments (no tokens): candidates for the
    /// "contiguous comment block immediately above" allow placement.
    pub comment_only: HashSet<u32>,
}

impl Lexed {
    /// Is `rule` suppressed at `line`?  True when the allow comment sits
    /// on the line itself or anywhere in the contiguous comment-only
    /// block immediately above it.
    pub fn allowed(&self, rule: Rule, line: u32) -> bool {
        if self.allow.get(&line).is_some_and(|s| s.contains(&rule)) {
            return true;
        }
        let mut prev = line.wrapping_sub(1);
        while self.comment_only.contains(&prev) {
            if self.allow.get(&prev).is_some_and(|s| s.contains(&rule)) {
                return true;
            }
            prev = prev.wrapping_sub(1);
        }
        false
    }
}

fn is_id_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_id(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// `// lint: allow(<rule>): <reason>` — the reason is mandatory so every
/// escape hatch is justified in place.
fn parse_allow(comment: &str) -> Option<Rule> {
    let idx = comment.find("lint:")?;
    let rest = comment[idx + 5..].trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let close = rest.find(')')?;
    let rule = Rule::parse(&rest[..close])?;
    let rest = rest[close + 1..].strip_prefix(':')?;
    if rest.trim_start().is_empty() {
        return None;
    }
    Some(rule)
}

pub fn lex(text: &str) -> Lexed {
    let b = text.as_bytes();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<(u32, String)> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;

    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let j = text[i..].find('\n').map(|k| i + k).unwrap_or(n);
            comments.push((line, text[i..j].to_string()));
            i = j;
            continue;
        }
        // (nested) block comment, attributed to its starting line
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start = line;
            let mut depth = 1usize;
            let mut buf = String::from("/*");
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    buf.push_str("/*");
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    buf.push_str("*/");
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    buf.push(b[i] as char);
                    i += 1;
                }
            }
            comments.push((start, buf));
            continue;
        }
        // raw strings: r"..." r#"..."# br"..."
        if c == b'r' || (c == b'b' && i + 1 < n && b[i + 1] == b'r') {
            let p = if c == b'b' { i + 2 } else { i + 1 };
            let mut h = p;
            while h < n && b[h] == b'#' {
                h += 1;
            }
            if h < n && b[h] == b'"' {
                let hashes = h - p;
                let mut j = h + 1;
                'raw: while j < n {
                    if b[j] == b'"' {
                        let mut k = j + 1;
                        let mut seen = 0usize;
                        while k < n && b[k] == b'#' && seen < hashes {
                            k += 1;
                            seen += 1;
                        }
                        if seen == hashes {
                            j = k;
                            break 'raw;
                        }
                    }
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
                toks.push(Tok { kind: TokKind::Str, text: String::new(), line });
                i = j;
                continue;
            }
        }
        // plain strings: "..." b"..."
        if c == b'"' || (c == b'b' && i + 1 < n && b[i + 1] == b'"') {
            if c == b'b' {
                i += 1;
            }
            i += 1;
            while i < n {
                if b[i] == b'\\' {
                    i += 2;
                    continue;
                }
                if b[i] == b'\n' {
                    line += 1;
                }
                if b[i] == b'"' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Str, text: String::new(), line });
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            if i + 1 < n && b[i + 1] == b'\\' {
                let mut j = i + 2;
                if j < n {
                    j += 1;
                }
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                i = j + 1;
                toks.push(Tok { kind: TokKind::CharLit, text: String::new(), line });
                continue;
            }
            if i + 2 < n && b[i + 2] == b'\'' {
                i += 3;
                toks.push(Tok { kind: TokKind::CharLit, text: String::new(), line });
                continue;
            }
            let mut j = i + 1;
            while j < n && is_id(b[j]) {
                j += 1;
            }
            i = j;
            toks.push(Tok { kind: TokKind::Lifetime, text: String::new(), line });
            continue;
        }
        if is_id_start(c) {
            let mut j = i;
            while j < n && is_id(b[j]) {
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Id, text: text[i..j].to_string(), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && is_id(b[j]) {
                j += 1;
            }
            // fractional part only when a digit follows the dot, so
            // `0..n` stays range punctuation
            if j + 1 < n && b[j] == b'.' && b[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && is_id(b[j]) {
                    j += 1;
                }
            }
            toks.push(Tok { kind: TokKind::Num, text: String::new(), line });
            i = j;
            continue;
        }
        toks.push(Tok { kind: TokKind::Punct, text: (c as char).to_string(), line });
        i += 1;
    }

    let mut allow: HashMap<u32, HashSet<Rule>> = HashMap::new();
    for (ln, ctext) in &comments {
        if let Some(rule) = parse_allow(ctext) {
            allow.entry(*ln).or_default().insert(rule);
        }
    }
    let tok_lines: HashSet<u32> = toks.iter().map(|t| t.line).collect();
    let comment_only: HashSet<u32> =
        comments.iter().map(|(ln, _)| *ln).filter(|ln| !tok_lines.contains(ln)).collect();

    Lexed { toks, allow, comment_only }
}

//! CLI: `cargo run -p lagkv-lint -- check [--root <dir>] [--baseline
//! <file> | --no-baseline]`.
//!
//! Prints every non-grandfathered violation grouped by rule, then a
//! one-line summary `lagkv-lint: violations=N baseline=M`, and exits
//! non-zero when N > 0 (the CI contract).

use std::path::PathBuf;
use std::process::ExitCode;

use lagkv_lint::baseline::Baseline;
use lagkv_lint::{check_tree, Rule};

const USAGE: &str = "usage: lagkv-lint check [--root <dir>] [--baseline <file> | --no-baseline]";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("lagkv-lint: {e}");
            ExitCode::from(2)
        }
    }
}

fn run(args: &[String]) -> Result<ExitCode, String> {
    let mut it = args.iter();
    let Some(cmd) = it.next() else {
        return Err(USAGE.to_string());
    };
    if cmd != "check" {
        return Err(format!("unknown command {cmd:?}\n{USAGE}"));
    }
    let mut root = PathBuf::from(".");
    let mut baseline_path: Option<PathBuf> = None;
    let mut no_baseline = false;
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--root" => {
                root = PathBuf::from(it.next().ok_or_else(|| USAGE.to_string())?);
            }
            "--baseline" => {
                baseline_path =
                    Some(PathBuf::from(it.next().ok_or_else(|| USAGE.to_string())?));
            }
            "--no-baseline" => no_baseline = true,
            other => return Err(format!("unknown flag {other:?}\n{USAGE}")),
        }
    }

    let vios = check_tree(&root)?;
    let baseline = if no_baseline {
        Baseline::default()
    } else {
        let path = baseline_path
            .unwrap_or_else(|| root.join("tools").join("lagkv-lint").join("baseline.txt"));
        Baseline::load(&path)?
    };
    let (remaining, grandfathered) = baseline.apply(vios);

    for rule in Rule::ALL {
        let of_rule: Vec<_> = remaining.iter().filter(|v| v.rule == rule).collect();
        if of_rule.is_empty() {
            continue;
        }
        eprintln!("== {rule}: {}", of_rule.len());
        for v in of_rule {
            eprintln!("  {}:{}: {}", v.file, v.line, v.msg);
        }
    }
    println!("lagkv-lint: violations={} baseline={grandfathered}", remaining.len());
    Ok(if remaining.is_empty() { ExitCode::SUCCESS } else { ExitCode::FAILURE })
}

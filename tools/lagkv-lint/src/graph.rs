//! The two whole-program rules: sink reachability (rule 4) and the
//! held-while-acquiring lock graph (rule 5).
//!
//! Call resolution is name-level and deliberately approximate:
//!
//! * `self.f()` resolves against the enclosing impl's `Impl::f` first —
//!   the only case where the receiver type is knowable from tokens.
//! * Other method and path calls resolve by bare name, *except* names on
//!   the std stoplist ([`crate::is_std_name`]): without the stoplist,
//!   `Vec::push` or `Mutex::lock` would alias every crate function of
//!   the same name and flood both rules with fabricated paths.
//! * Calls whose name starts uppercase (tuple-struct constructors,
//!   `Some(..)`) are never calls into crate functions.
//!
//! Both rules operate on function *objects* (definition sites), not
//! names, so two same-named functions in different impls stay distinct
//! once resolved.

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};

use crate::scan::{CallKind, FnInfo};
use crate::{is_std_name, Rule, Violation, SINK_ROOTS};

/// Resolve one call to the function definitions it may target.
fn resolve(
    caller: &FnInfo,
    name: &str,
    kind: CallKind,
    fns: &[FnInfo],
    by_name: &HashMap<String, Vec<usize>>,
) -> Vec<usize> {
    if kind == CallKind::SelfRecv {
        if let Some(imp) = caller.qual.split("::").next().filter(|_| caller.qual.contains("::")) {
            let want = format!("{imp}::{name}");
            let same: Vec<usize> = by_name
                .get(name)
                .map(|ids| ids.iter().copied().filter(|&g| fns[g].qual == want).collect())
                .unwrap_or_default();
            if !same.is_empty() {
                return same;
            }
        }
    }
    if kind != CallKind::Free && is_std_name(name) {
        return Vec::new();
    }
    by_name.get(name).cloned().unwrap_or_default()
}

/// Rule 4: any blocking lock site in a function reachable from the
/// telemetry publish roots is a violation — those paths must use
/// `try_lock` and drop on contention.
pub fn sink_blocking_violations(
    fns: &[FnInfo],
    by_name: &HashMap<String, Vec<usize>>,
) -> Vec<Violation> {
    let mut seen: HashSet<usize> = HashSet::new();
    let mut work: Vec<usize> = Vec::new();
    for root in SINK_ROOTS {
        if let Some(ids) = by_name.get(root) {
            work.extend(ids.iter().copied());
        }
    }
    while let Some(fidx) = work.pop() {
        if !seen.insert(fidx) {
            continue;
        }
        for call in &fns[fidx].calls {
            for g in resolve(&fns[fidx], &call.name, call.kind, fns, by_name) {
                if !seen.contains(&g) {
                    work.push(g);
                }
            }
        }
    }
    let mut vios = Vec::new();
    for &fidx in &seen {
        for &line in &fns[fidx].blocking {
            vios.push(Violation {
                rule: Rule::SinkBlocking,
                file: fns[fidx].file.clone(),
                line,
                msg: format!(
                    "blocking lock in `{}`, reachable from the sink roots",
                    fns[fidx].qual
                ),
            });
        }
    }
    vios
}

/// Rule 5: build the held-while-acquiring edge set — direct edges from
/// each function, plus interprocedural edges from held labels at a call
/// site to every lock the callee may transitively take — and report each
/// strongly connected component as a potential deadlock cycle.
pub fn lock_order_violations(
    fns: &[FnInfo],
    by_name: &HashMap<String, Vec<usize>>,
) -> Vec<Violation> {
    // transitive lock sets, to fixpoint
    let mut trans: Vec<BTreeSet<String>> = fns
        .iter()
        .map(|f| f.locks.iter().map(|(lbl, _)| lbl.clone()).collect())
        .collect();
    let mut changed = true;
    while changed {
        changed = false;
        for fidx in 0..fns.len() {
            let mut additions: Vec<String> = Vec::new();
            for call in &fns[fidx].calls {
                for g in resolve(&fns[fidx], &call.name, call.kind, fns, by_name) {
                    for lbl in &trans[g] {
                        if !trans[fidx].contains(lbl) && !additions.contains(lbl) {
                            additions.push(lbl.clone());
                        }
                    }
                }
            }
            if !additions.is_empty() {
                trans[fidx].extend(additions);
                changed = true;
            }
        }
    }

    // edge map: (held, acquired) -> first witness (file, line, note)
    let mut edges: BTreeMap<(String, String), (String, u32, String)> = BTreeMap::new();
    for f in fns {
        for (a, b, line) in &f.edges {
            edges
                .entry((a.clone(), b.clone()))
                .or_insert_with(|| (f.file.clone(), *line, "direct".to_string()));
        }
    }
    for fidx in 0..fns.len() {
        for call in &fns[fidx].calls {
            if call.held.is_empty() {
                continue;
            }
            for g in resolve(&fns[fidx], &call.name, call.kind, fns, by_name) {
                for a in &call.held {
                    for b in &trans[g] {
                        if a != b {
                            edges.entry((a.clone(), b.clone())).or_insert_with(|| {
                                (fns[fidx].file.clone(), call.line, format!("via {}()", call.name))
                            });
                        }
                    }
                }
            }
        }
    }

    let mut graph: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for (a, b) in edges.keys() {
        graph.entry(a.as_str()).or_default().insert(b.as_str());
    }
    let mut vios = Vec::new();
    for cyc in find_cycles(&graph) {
        let members: BTreeSet<&str> = cyc.iter().copied().collect();
        let mut steps: Vec<String> = Vec::new();
        let mut first: Option<(String, u32)> = None;
        for ((a, b), (file, line, note)) in &edges {
            if members.contains(a.as_str()) && members.contains(b.as_str()) {
                if first.is_none() {
                    first = Some((file.clone(), *line));
                }
                steps.push(format!("{a}->{b} ({file}:{line} {note})"));
            }
        }
        let (file, line) = first.unwrap_or_else(|| ("?".to_string(), 0));
        let names: Vec<&str> = members.iter().copied().collect();
        vios.push(Violation {
            rule: Rule::LockOrder,
            file,
            line,
            msg: format!(
                "potential deadlock cycle among {{{}}}: {}",
                names.join(", "),
                steps.join("; ")
            ),
        });
    }
    vios
}

/// Tarjan SCCs over the label graph; only components that can actually
/// loop (size > 1, or a self-edge) are cycles.
fn find_cycles<'a>(graph: &BTreeMap<&'a str, BTreeSet<&'a str>>) -> Vec<Vec<&'a str>> {
    struct State<'a> {
        index: HashMap<&'a str, usize>,
        low: HashMap<&'a str, usize>,
        stack: Vec<&'a str>,
        on: HashSet<&'a str>,
        counter: usize,
        out: Vec<Vec<&'a str>>,
    }
    fn strong<'a>(v: &'a str, graph: &BTreeMap<&'a str, BTreeSet<&'a str>>, st: &mut State<'a>) {
        st.index.insert(v, st.counter);
        st.low.insert(v, st.counter);
        st.counter += 1;
        st.stack.push(v);
        st.on.insert(v);
        if let Some(succs) = graph.get(v) {
            for &w in succs {
                if !st.index.contains_key(w) {
                    strong(w, graph, st);
                    let lw = st.low[w];
                    let lv = st.low.get_mut(v).expect("v indexed above");
                    *lv = (*lv).min(lw);
                } else if st.on.contains(w) {
                    let iw = st.index[w];
                    let lv = st.low.get_mut(v).expect("v indexed above");
                    *lv = (*lv).min(iw);
                }
            }
        }
        if st.low[v] == st.index[v] {
            let mut comp: Vec<&str> = Vec::new();
            while let Some(w) = st.stack.pop() {
                st.on.remove(w);
                comp.push(w);
                if w == v {
                    break;
                }
            }
            let self_loop = graph.get(v).is_some_and(|s| s.contains(v));
            if comp.len() > 1 || self_loop {
                comp.sort_unstable();
                st.out.push(comp);
            }
        }
    }
    let mut st = State {
        index: HashMap::new(),
        low: HashMap::new(),
        stack: Vec::new(),
        on: HashSet::new(),
        counter: 0,
        out: Vec::new(),
    };
    for &v in graph.keys() {
        if !st.index.contains_key(v) {
            strong(v, graph, &mut st);
        }
    }
    st.out
}

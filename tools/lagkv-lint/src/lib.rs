//! lagkv-lint — project-specific static analysis for the lagkv serving
//! stack.  Pure std, zero external dependencies, hermetic by contract
//! (`CARGO_NET_OFFLINE=true` builds it from a cold cache).
//!
//! The tool lexes every file under `<root>/rust/src`, walks the token
//! stream with a lightweight structural scanner (impl blocks, functions,
//! brace depth, guard lifetimes), and enforces five rules:
//!
//! 1. **no-panic-in-serving** (`panic`) — `unwrap()` / `expect()` /
//!    `panic!` / `todo!` / `unimplemented!` are forbidden in the serving
//!    directories (`server/`, `coordinator/`, `kvpool/`, `kvstore/`,
//!    `telemetry/`, `api/`).
//! 2. **clock-discipline** (`clock`) — `Instant::now` / `SystemTime::now`
//!    only inside the telemetry `Clock` impls; everything else takes time
//!    from a `Clock` so tests can pin timelines.
//! 3. **ledger-discipline** (`ledger`) — raw `fetch_add` / `fetch_sub` /
//!    `store` / `fetch_update` on the byte-gauge atomics are forbidden
//!    outside `kvpool/stats.rs` and the RAII guard impls.
//! 4. **no-blocking-in-sink** (`sink-blocking`) — blocking `.lock()` is
//!    forbidden in any function reachable from the telemetry publish
//!    roots (`try_publish`, `finish_span`, `record`, ...).
//! 5. **lock-order** (`lock-order`) — per-function lock-acquisition
//!    sequences feed an approximate intra-crate call graph; cycles in
//!    the held-while-acquiring graph are reported as potential
//!    deadlocks.
//!
//! Inline escapes use `// lint: allow(<rule>): <reason>` on the
//! offending line or in the contiguous comment block immediately above
//! it; the reason is mandatory.  Grandfathered sites live in a
//! checked-in baseline (see [`baseline`]).
//!
//! The scanner is deliberately approximate — name-level call resolution
//! with a stoplist of ubiquitous std method names, lexical guard
//! lifetimes — and the approximations are documented in DESIGN.md §13.

pub mod baseline;
pub mod graph;
pub mod lexer;
pub mod scan;

use std::fmt;
use std::path::Path;

/// The five rules, in reporting order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Rule {
    Panic,
    Clock,
    Ledger,
    SinkBlocking,
    LockOrder,
}

impl Rule {
    pub const ALL: [Rule; 5] =
        [Rule::Panic, Rule::Clock, Rule::Ledger, Rule::SinkBlocking, Rule::LockOrder];

    /// The name used in allow comments and baseline entries.
    pub fn name(self) -> &'static str {
        match self {
            Rule::Panic => "panic",
            Rule::Clock => "clock",
            Rule::Ledger => "ledger",
            Rule::SinkBlocking => "sink-blocking",
            Rule::LockOrder => "lock-order",
        }
    }

    pub fn parse(s: &str) -> Option<Rule> {
        Rule::ALL.into_iter().find(|r| r.name() == s)
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One diagnostic: rule, repo-relative file, 1-based line, message.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: Rule,
    pub file: String,
    pub line: u32,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Directories (under `rust/src/`) where rule 1 applies: a panic here
/// takes down serving, not a bench or a test binary.
pub const SERVING_DIRS: [&str; 6] =
    ["server/", "coordinator/", "kvpool/", "kvstore/", "telemetry/", "api/"];

/// Byte-gauge atomics owned by the RAII accounting layer.
pub const GAUGES: [&str; 8] = [
    "sheddable",
    "prefix_sheddable",
    "queued",
    "reserved",
    "total",
    "quant_bytes",
    "quant_blocks",
    "dq_bytes",
];

/// Raw atomic ops that mutate a gauge.
pub const LEDGER_OPS: [&str; 4] = ["fetch_add", "fetch_sub", "store", "fetch_update"];

/// Files where raw gauge ops are the point (the accounting layer itself).
pub const LEDGER_FILES: [&str; 1] = ["kvpool/stats.rs"];

/// RAII guard impls whose mint/release halves own their gauge ops.
pub const GUARD_IMPLS: [&str; 3] = ["Reservation", "QueueToken", "LooseGauge"];

/// Sanctioned lock-wrapper functions: their bodies are exempt from the
/// lock rules because every *call site* is treated as the lock site.
pub const WRAPPER_FNS: [&str; 1] = ["locked"];

/// Impls allowed to read the real clock (rule 2).
pub const CLOCK_IMPLS: [&str; 1] = ["MonotonicClock"];

/// Telemetry publish roots: nothing reachable from these may block.
pub const SINK_ROOTS: [&str; 5] =
    ["try_publish", "finish_span", "record", "record_v", "begin_span"];

/// Method names that collide with ubiquitous std methods: calls through
/// a non-`self` receiver with these names are NOT resolved to crate
/// functions.  A documented under-approximation of the call graph —
/// without it, `Vec::push` or `HashMap::insert` would alias every crate
/// function of the same name and the graph would be all noise.
pub const STD_NAMES: [&str; 119] = [
    "new", "with_capacity", "default", "clone", "push", "pop", "insert", "remove", "get",
    "get_mut", "len", "is_empty", "iter", "iter_mut", "into_iter", "drain", "clear", "contains",
    "contains_key", "retain", "extend", "entry", "keys", "values", "take", "replace", "next",
    "collect", "map", "filter", "filter_map", "fold", "find", "position", "any", "all", "count",
    "last", "first", "rev", "zip", "chain", "enumerate", "flatten", "flat_map", "sum", "min",
    "max", "sort", "sort_by", "sort_by_key", "split_off", "append", "as_ref", "as_mut", "as_str",
    "as_slice", "as_bytes", "to_vec", "to_string", "into", "from", "try_from", "try_into",
    "parse", "fmt", "eq", "cmp", "hash", "drop", "send", "recv", "try_recv", "join", "spawn",
    "sleep", "load", "store", "swap", "fetch_add", "fetch_sub", "fetch_update",
    "compare_exchange", "lock", "try_lock", "read", "write", "unwrap", "expect", "unwrap_or",
    "unwrap_or_else", "unwrap_or_default", "ok", "err", "is_some", "is_none", "is_ok", "is_err",
    "flush", "write_all", "read_exact", "read_to_end", "read_to_string", "write_fmt",
    "starts_with", "ends_with", "trim", "split", "splitn", "lines", "bytes", "chars",
    "min_by_key", "max_by_key", "copy_from_slice", "extend_from_slice", "resize", "truncate",
    "reserve",
];

/// Extra stoplist entries that did not fit the first array cleanly.
pub const STD_NAMES_EXTRA: [&str; 22] = [
    "elapsed", "duration_since", "as_micros", "as_millis", "as_secs", "saturating_sub",
    "saturating_add", "checked_sub", "checked_add", "min_by", "max_by", "to_owned", "into_inner",
    "abs", "rem", "clamp", "windows", "chunks", "concat", "repeat", "get_or_insert_with", "drop",
];

pub fn is_std_name(name: &str) -> bool {
    STD_NAMES.contains(&name) || STD_NAMES_EXTRA.contains(&name)
}

/// Is this repo-relative path inside a serving directory?
pub fn in_serving(rel: &str) -> bool {
    SERVING_DIRS
        .iter()
        .any(|d| rel.contains(&format!("rust/src/{d}")) || rel.starts_with(d))
}

/// Lint the tree rooted at `root` (expects sources under
/// `<root>/rust/src`).  Returns every violation, sorted by
/// (rule, file, line) — baseline application is the caller's business.
pub fn check_tree(root: &Path) -> Result<Vec<Violation>, String> {
    let src = root.join("rust").join("src");
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    walk(&src, &mut files)?;
    files.sort();

    let mut ctx = scan::ScanCtx::default();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .map_err(|_| format!("{} escapes root", path.display()))?
            .to_string_lossy()
            .replace('\\', "/");
        scan::scan_file(&text, &rel, &mut ctx);
    }
    let mut vios = ctx.vios;
    vios.extend(graph::sink_blocking_violations(&ctx.fns, &ctx.by_name));
    vios.extend(graph::lock_order_violations(&ctx.fns, &ctx.by_name));
    vios.sort_by(|a, b| (a.rule, &a.file, a.line).cmp(&(b.rule, &b.file, b.line)));
    Ok(vios)
}

fn walk(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

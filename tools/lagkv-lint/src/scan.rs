//! Single-file structural scan: walks the token stream tracking impl
//! blocks, function bodies, brace depth, and lock-guard lifetimes, and
//! emits the per-site rules (panic, clock, ledger) plus the per-function
//! facts (calls, acquisitions, blocking sites, direct lock edges) the
//! graph rules consume.
//!
//! Guard lifetimes are lexical: a temporary guard (`x.lock()` used in an
//! expression) dies at the next `;`, a let-bound guard dies when its
//! block closes or at an explicit `drop(var)`.  That is an
//! approximation — a guard moved out of a `match` scrutinee lives
//! slightly longer in rustc's model — but it errs toward *longer* held
//! spans, which only adds candidate edges, never hides one.

use std::collections::HashMap;

use crate::lexer::{lex, Lexed, Tok, TokKind};
use crate::{in_serving, Rule, Violation, CLOCK_IMPLS, GAUGES, GUARD_IMPLS, LEDGER_FILES,
            LEDGER_OPS, WRAPPER_FNS};

const KEYWORDS: [&str; 35] = [
    "if", "else", "while", "for", "loop", "match", "return", "fn", "let", "mut", "pub", "impl",
    "struct", "enum", "trait", "mod", "use", "crate", "self", "Self", "super", "move", "ref",
    "in", "as", "where", "break", "continue", "const", "static", "type", "unsafe", "dyn", "true",
    "false",
];

fn is_keyword(s: &str) -> bool {
    KEYWORDS.contains(&s)
}

/// How a call names its target — drives resolution in [`crate::graph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallKind {
    /// `self.f()` — resolved against the enclosing impl first.
    SelfRecv,
    /// `recv.f()` / `Path::f()` — name-level, gated by the std stoplist.
    Method,
    /// `f()` — free function, always name-level.
    Free,
}

#[derive(Debug, Clone)]
pub struct Call {
    pub name: String,
    pub line: u32,
    /// Lock labels held at the call site (for interprocedural edges).
    pub held: Vec<String>,
    pub kind: CallKind,
}

/// Per-function facts accumulated by the scan.
#[derive(Debug, Clone)]
pub struct FnInfo {
    pub name: String,
    /// `Impl::name` inside an impl block, bare `name` otherwise.
    pub qual: String,
    pub file: String,
    pub calls: Vec<Call>,
    /// Lock acquisitions `(label, line)` in source order.
    pub locks: Vec<(String, u32)>,
    /// Blocking-lock sites (rule 4 candidates if this fn is reachable
    /// from a sink root).
    pub blocking: Vec<u32>,
    /// Direct held-while-acquiring edges `(held, acquired, line)`.
    pub edges: Vec<(String, String, u32)>,
}

#[derive(Default)]
pub struct ScanCtx {
    pub vios: Vec<Violation>,
    pub fns: Vec<FnInfo>,
    /// Function name -> indices into `fns` (every definition site).
    pub by_name: HashMap<String, Vec<usize>>,
}

struct Guard {
    label: String,
    /// The let binding holding the guard, when there is one (`_` and
    /// temporaries get `None`).
    var: Option<String>,
    /// Brace depth at acquisition: the guard dies when its block closes.
    depth: i32,
    /// Expression temporary: dies at the next `;`.
    temp: bool,
}

fn text_at(toks: &[Tok], i: usize) -> &str {
    toks.get(i).map(|t| t.text.as_str()).unwrap_or("")
}

fn kind_at(toks: &[Tok], i: usize) -> Option<TokKind> {
    toks.get(i).map(|t| t.kind)
}

/// `toks[i]` is `impl`; returns `(type name, index of the body open
/// brace or terminator)`.  Skips generics, takes the last path segment,
/// and prefers the segment after `for` (`impl Clock for MonotonicClock`
/// names `MonotonicClock`).
fn impl_name_from(toks: &[Tok], i: usize) -> (String, usize) {
    let n = toks.len();
    let mut j = i + 1;
    if text_at(toks, j) == "<" {
        let mut depth = 1;
        j += 1;
        while j < n && depth > 0 {
            match text_at(toks, j) {
                "<" => depth += 1,
                ">" => depth -= 1,
                _ => {}
            }
            j += 1;
        }
    }
    let mut segs: Vec<String> = Vec::new();
    let mut after_for: Option<Vec<String>> = None;
    while j < n {
        let t = text_at(toks, j);
        if t == "{" || t == ";" || t == "where" {
            break;
        }
        if kind_at(toks, j) == Some(TokKind::Id) && t == "for" {
            after_for = Some(Vec::new());
        } else if kind_at(toks, j) == Some(TokKind::Id) && !is_keyword(t) {
            match &mut after_for {
                Some(v) => v.push(t.to_string()),
                None => segs.push(t.to_string()),
            }
        } else if t == "<" {
            let mut depth = 1;
            j += 1;
            while j < n && depth > 0 {
                match text_at(toks, j) {
                    "<" => depth += 1,
                    ">" => depth -= 1,
                    _ => {}
                }
                j += 1;
            }
            continue;
        }
        j += 1;
    }
    let path = after_for.unwrap_or(segs);
    let name = path.last().cloned().unwrap_or_else(|| "?".to_string());
    (name, j)
}

/// Collect the dotted receiver path ending at token `end` (inclusive):
/// for `self.inner.lock()` with `end` at `inner`, yields
/// `Impl.inner`.  Returns `None` for pathless receivers.
fn path_label(toks: &[Tok], end: usize, cur_impl: Option<&str>) -> Option<String> {
    let mut segs: Vec<String> = Vec::new();
    let mut j = end as isize;
    let mut expecting_id = true;
    while j >= 0 {
        let idx = j as usize;
        if expecting_id && kind_at(toks, idx) == Some(TokKind::Id) {
            segs.push(toks[idx].text.clone());
            expecting_id = false;
            j -= 1;
        } else if !expecting_id && text_at(toks, idx) == "." {
            expecting_id = true;
            j -= 1;
        } else {
            break;
        }
    }
    segs.reverse();
    if segs.is_empty() {
        return None;
    }
    if segs[0] == "self" {
        let rest = &segs[1..];
        if rest.is_empty() {
            return None;
        }
        return Some(format!("{}.{}", cur_impl.unwrap_or("?"), rest.join(".")));
    }
    Some(segs.join("."))
}

/// Does the statement containing token `start_idx` begin with
/// `let [mut] <var>`?  Returns the bound variable name.
fn stmt_is_let(toks: &[Tok], start_idx: usize) -> (bool, Option<String>) {
    let mut j = start_idx as isize - 1;
    while j >= 0 {
        let t = text_at(toks, j as usize);
        if t == ";" || t == "{" || t == "}" {
            break;
        }
        j -= 1;
    }
    let j = (j + 1) as usize;
    if text_at(toks, j) != "let" {
        return (false, None);
    }
    let mut k = j + 1;
    if text_at(toks, k) == "mut" {
        k += 1;
    }
    if kind_at(toks, k) == Some(TokKind::Id) {
        return (true, Some(toks[k].text.clone()));
    }
    (true, None)
}

/// Skip a `#[test]` / `#[cfg(test)]`-guarded item: advance past the next
/// item's body (to its matching close brace) or terminator.
fn skip_item(toks: &[Tok], mut j: usize) -> usize {
    let n = toks.len();
    while j < n {
        let t = text_at(toks, j);
        if t == ";" {
            return j + 1;
        }
        if t == "{" {
            let mut depth = 1;
            j += 1;
            while j < n && depth > 0 {
                match text_at(toks, j) {
                    "{" => depth += 1,
                    "}" => depth -= 1,
                    _ => {}
                }
                j += 1;
            }
            return j;
        }
        j += 1;
    }
    j
}

pub fn scan_file(text: &str, rel: &str, ctx: &mut ScanCtx) {
    let lexed = lex(text);
    let toks = &lexed.toks;
    let n = toks.len();

    let mut i = 0usize;
    let mut depth: i32 = 0;
    let mut impl_stack: Vec<(String, i32)> = Vec::new();
    // (index into ctx.fns, depth at open, live guards)
    let mut fn_stack: Vec<(usize, i32, Vec<Guard>)> = Vec::new();
    let mut pending_skip = false;

    while i < n {
        let tok = &toks[i];
        let t = tok.text.as_str();
        let line = tok.line;
        let is_id = tok.kind == TokKind::Id;

        // attribute: detect test regions
        if t == "#" && text_at(toks, i + 1) == "[" {
            let mut j = i + 2;
            let mut bd = 1;
            let mut ids: Vec<&str> = Vec::new();
            while j < n && bd > 0 {
                match text_at(toks, j) {
                    "[" => bd += 1,
                    "]" => bd -= 1,
                    _ => {
                        if kind_at(toks, j) == Some(TokKind::Id) {
                            ids.push(&toks[j].text);
                        }
                    }
                }
                j += 1;
            }
            let test_only = ids == ["test"]
                || (ids.first() == Some(&"cfg")
                    && ids.contains(&"test")
                    && !ids.contains(&"not"));
            if test_only {
                pending_skip = true;
            }
            i = j;
            continue;
        }

        if pending_skip
            && is_id
            && matches!(
                t,
                "fn" | "mod" | "struct" | "enum" | "impl" | "trait" | "const" | "static" | "use"
                    | "pub"
            )
        {
            pending_skip = false;
            i = skip_item(toks, i);
            continue;
        }

        if is_id && t == "impl" {
            let (name, j) = impl_name_from(toks, i);
            if text_at(toks, j) == "{" {
                impl_stack.push((name, depth));
                depth += 1;
                i = j + 1;
            } else {
                i = j;
            }
            continue;
        }

        if is_id && t == "fn" && kind_at(toks, i + 1) == Some(TokKind::Id) {
            let fname = toks[i + 1].text.clone();
            let mut j = i + 2;
            while j < n && text_at(toks, j) != "{" && text_at(toks, j) != ";" {
                j += 1;
            }
            if text_at(toks, j) == "{" {
                let imp = impl_stack.last().map(|(name, _)| name.clone());
                let qual = match &imp {
                    Some(imp) => format!("{imp}::{fname}"),
                    None => fname.clone(),
                };
                let idx = ctx.fns.len();
                ctx.fns.push(FnInfo {
                    name: fname.clone(),
                    qual,
                    file: rel.to_string(),
                    calls: Vec::new(),
                    locks: Vec::new(),
                    blocking: Vec::new(),
                    edges: Vec::new(),
                });
                ctx.by_name.entry(fname).or_default().push(idx);
                fn_stack.push((idx, depth, Vec::new()));
                depth += 1;
                i = j + 1;
            } else {
                i = j;
            }
            continue;
        }

        if t == "{" {
            depth += 1;
            i += 1;
            continue;
        }
        if t == "}" {
            depth -= 1;
            let mut fn_closed = false;
            if let Some((_, fdepth, guards)) = fn_stack.last_mut() {
                guards.retain(|g| g.depth < depth);
                fn_closed = depth == *fdepth;
            }
            if fn_closed {
                fn_stack.pop();
            }
            if impl_stack.last().is_some_and(|(_, d)| *d == depth) {
                impl_stack.pop();
            }
            i += 1;
            continue;
        }
        if t == ";" {
            if let Some((_, _, guards)) = fn_stack.last_mut() {
                guards.retain(|g| !g.temp);
            }
            i += 1;
            continue;
        }

        if is_id {
            let nxt = text_at(toks, i + 1);
            let prv = if i > 0 { text_at(toks, i - 1) } else { "" };
            let cur_impl: Option<String> = impl_stack.last().map(|(name, _)| name.clone());

            // rule 1: no-panic-in-serving
            if in_serving(rel) {
                let hit = if matches!(t, "panic" | "todo" | "unimplemented") && nxt == "!" {
                    Some(format!("{t}!"))
                } else if matches!(t, "unwrap" | "expect") && prv == "." && nxt == "(" {
                    Some(format!(".{t}()"))
                } else {
                    None
                };
                if let Some(hit) = hit {
                    if !lexed.allowed(Rule::Panic, line) {
                        ctx.vios.push(Violation {
                            rule: Rule::Panic,
                            file: rel.to_string(),
                            line,
                            msg: format!("`{hit}` on a serving path"),
                        });
                    }
                }
            }

            // rule 2: clock-discipline
            if t == "now"
                && prv == ":"
                && i >= 3
                && text_at(toks, i - 2) == ":"
                && kind_at(toks, i - 3) == Some(TokKind::Id)
                && matches!(text_at(toks, i - 3), "Instant" | "SystemTime")
            {
                let ok = cur_impl.as_deref().is_some_and(|im| CLOCK_IMPLS.contains(&im));
                if !ok && !lexed.allowed(Rule::Clock, line) {
                    ctx.vios.push(Violation {
                        rule: Rule::Clock,
                        file: rel.to_string(),
                        line,
                        msg: format!(
                            "`{}::now` outside the telemetry Clock impls",
                            text_at(toks, i - 3)
                        ),
                    });
                }
            }

            // rule 3: ledger-discipline
            if LEDGER_OPS.contains(&t)
                && prv == "."
                && nxt == "("
                && i >= 2
                && kind_at(toks, i - 2) == Some(TokKind::Id)
                && GAUGES.contains(&text_at(toks, i - 2))
            {
                let ok = LEDGER_FILES.iter().any(|f| rel.ends_with(f))
                    || cur_impl.as_deref().is_some_and(|im| GUARD_IMPLS.contains(&im));
                if !ok && !lexed.allowed(Rule::Ledger, line) {
                    ctx.vios.push(Violation {
                        rule: Rule::Ledger,
                        file: rel.to_string(),
                        line,
                        msg: format!(
                            "raw `.{t}` on byte-gauge `{}` outside the RAII guards",
                            text_at(toks, i - 2)
                        ),
                    });
                }
            }

            // calls + rule 4 blocking sites + rule 5 acquisitions
            if nxt == "("
                && !is_keyword(t)
                && prv != "fn"
                && !t.chars().next().is_some_and(char::is_uppercase)
            {
                if let Some((fidx, _, guards)) = fn_stack.last() {
                    let mut held: Vec<String> =
                        guards.iter().map(|g| g.label.clone()).collect();
                    if lexed.allowed(Rule::LockOrder, line) {
                        held.clear();
                    }
                    let kind = if prv == "." && i >= 2 && text_at(toks, i - 2) == "self" {
                        CallKind::SelfRecv
                    } else if prv == "." || prv == ":" {
                        CallKind::Method
                    } else {
                        CallKind::Free
                    };
                    ctx.fns[*fidx].calls.push(Call {
                        name: t.to_string(),
                        line,
                        held,
                        kind,
                    });
                }
                let in_wrapper = fn_stack
                    .last()
                    .is_some_and(|(fidx, _, _)| WRAPPER_FNS.contains(&ctx.fns[*fidx].name.as_str()));

                if t == "lock" && prv == "." && !in_wrapper {
                    if let Some((fidx, _, _)) = fn_stack.last() {
                        if !lexed.allowed(Rule::SinkBlocking, line) {
                            ctx.fns[*fidx].blocking.push(line);
                        }
                    }
                    let lbl = path_label(toks, i.saturating_sub(2), cur_impl.as_deref());
                    let binding = stmt_is_let(toks, i.saturating_sub(2));
                    acquire(ctx, &lexed, &mut fn_stack, lbl, line, binding, depth);
                } else if matches!(t, "read" | "write")
                    && prv == "."
                    && text_at(toks, i + 2) == ")"
                    && !in_wrapper
                {
                    let lbl = path_label(toks, i.saturating_sub(2), cur_impl.as_deref());
                    let binding = stmt_is_let(toks, i.saturating_sub(2));
                    acquire(ctx, &lexed, &mut fn_stack, lbl, line, binding, depth);
                } else if t == "locked" {
                    if let Some((fidx, _, _)) = fn_stack.last() {
                        if !lexed.allowed(Rule::SinkBlocking, line) {
                            ctx.fns[*fidx].blocking.push(line);
                        }
                    }
                    // crate::util::locked(&self.inner) — label from the argument path
                    let mut j = i + 2;
                    if text_at(toks, j) == "&" {
                        j += 1;
                    }
                    let mut segs: Vec<String> = Vec::new();
                    while j < n
                        && (kind_at(toks, j) == Some(TokKind::Id) || text_at(toks, j) == ".")
                    {
                        if kind_at(toks, j) == Some(TokKind::Id) {
                            segs.push(toks[j].text.clone());
                        }
                        j += 1;
                    }
                    let lbl = if segs.is_empty() {
                        None
                    } else if segs[0] == "self" {
                        if segs.len() > 1 {
                            Some(format!(
                                "{}.{}",
                                cur_impl.as_deref().unwrap_or("?"),
                                segs[1..].join(".")
                            ))
                        } else {
                            None
                        }
                    } else {
                        Some(segs.join("."))
                    };
                    let binding = stmt_is_let(toks, i);
                    acquire(ctx, &lexed, &mut fn_stack, lbl, line, binding, depth);
                } else if t == "drop"
                    && kind_at(toks, i + 2) == Some(TokKind::Id)
                    && text_at(toks, i + 3) == ")"
                {
                    if let Some((_, _, guards)) = fn_stack.last_mut() {
                        let var = text_at(toks, i + 2).to_string();
                        guards.retain(|g| g.var.as_deref() != Some(var.as_str()));
                    }
                }
            }
        }
        i += 1;
    }
}

/// Register a lock acquisition in the innermost function: record it,
/// emit direct held-while-acquiring edges against every live guard, and
/// push a new guard whose lifetime depends on whether the statement
/// let-binds it.  An `allow(lock-order)` on the line removes the
/// acquisition from the graph entirely.
fn acquire(
    ctx: &mut ScanCtx,
    lexed: &Lexed,
    fn_stack: &mut [(usize, i32, Vec<Guard>)],
    label: Option<String>,
    line: u32,
    binding: (bool, Option<String>),
    depth: i32,
) {
    let label = label.unwrap_or_else(|| "?".to_string());
    if lexed.allowed(Rule::LockOrder, line) {
        return;
    }
    let Some((fidx, _, guards)) = fn_stack.last_mut() else {
        return;
    };
    ctx.fns[*fidx].locks.push((label.clone(), line));
    for g in guards.iter() {
        if g.label != label {
            ctx.fns[*fidx].edges.push((g.label.clone(), label.clone(), line));
        }
    }
    let (is_let, var) = binding;
    let held = is_let && var.as_deref() != Some("_");
    guards.push(Guard {
        label,
        var: if held { var } else { None },
        depth,
        temp: !held,
    });
}

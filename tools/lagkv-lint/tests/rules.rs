//! Rule-engine tests over the checked-in fixture tree
//! (`tests/fixtures/rust/src/**`), which mimics the real source layout
//! so the directory-scoped rules apply.  Each rule has a seeded
//! violation (asserted present at its exact line) and an
//! allow-comment-suppressed twin (asserted absent) — so these tests fail
//! both when a rule goes blind and when the escape hatch breaks.

use std::path::Path;

use lagkv_lint::baseline::Baseline;
use lagkv_lint::{check_tree, Rule, Violation};

fn fixture_vios() -> Vec<Violation> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures");
    check_tree(&root).expect("fixture tree scans")
}

fn at(vios: &[Violation], rule: Rule, file: &str, line: u32) -> bool {
    vios.iter().any(|v| v.rule == rule && v.file == file && v.line == line)
}

fn count(vios: &[Violation], rule: Rule) -> usize {
    vios.iter().filter(|v| v.rule == rule).count()
}

#[test]
fn panic_rule_flags_serving_sites_and_honors_allow() {
    let vios = fixture_vios();
    let f = "rust/src/server/panics.rs";
    assert!(at(&vios, Rule::Panic, f, 6), "unwrap() flagged");
    assert!(at(&vios, Rule::Panic, f, 7), "expect() flagged");
    assert!(at(&vios, Rule::Panic, f, 9), "panic! flagged");
    assert!(at(&vios, Rule::Panic, f, 17), "todo! flagged");
    assert!(!at(&vios, Rule::Panic, f, 12), "allow(panic) suppresses the line below it");
    // the #[cfg(test)] module's unwrap is not a violation
    assert_eq!(count(&vios, Rule::Panic), 4, "{vios:?}");
}

#[test]
fn clock_rule_flags_non_clock_impls_and_honors_allow() {
    let vios = fixture_vios();
    let f = "rust/src/engine/clock.rs";
    assert!(at(&vios, Rule::Clock, f, 10), "Instant::now outside a Clock impl flagged");
    assert!(!at(&vios, Rule::Clock, f, 16), "allow(clock) suppresses SystemTime::now");
    assert!(!at(&vios, Rule::Clock, f, 22), "MonotonicClock impl may read the real clock");
    assert_eq!(count(&vios, Rule::Clock), 1, "{vios:?}");
}

#[test]
fn ledger_rule_flags_raw_gauge_ops_and_honors_allow() {
    let vios = fixture_vios();
    let f = "rust/src/coordinator/ledger.rs";
    assert!(at(&vios, Rule::Ledger, f, 15), "raw fetch_add on `queued` flagged");
    assert!(at(&vios, Rule::Ledger, f, 19), "raw fetch_add on `quant_bytes` flagged");
    assert!(!at(&vios, Rule::Ledger, f, 24), "allow(ledger) suppresses the mint half");
    assert!(!at(&vios, Rule::Ledger, f, 32), "guard impls (QueueToken) own their gauge ops");
    assert_eq!(count(&vios, Rule::Ledger), 2, "{vios:?}");
}

#[test]
fn sink_rule_flags_blocking_locks_reachable_from_roots() {
    let vios = fixture_vios();
    let f = "rust/src/telemetry/sink.rs";
    assert!(at(&vios, Rule::SinkBlocking, f, 19), "blocking .lock() reachable from try_publish");
    assert!(!at(&vios, Rule::SinkBlocking, f, 26), "allow(sink-blocking) suppresses");
    assert!(!at(&vios, Rule::SinkBlocking, f, 32), "try_lock never blocks");
    assert_eq!(count(&vios, Rule::SinkBlocking), 1, "{vios:?}");
}

#[test]
fn lock_order_rule_reports_the_two_function_cycle_once() {
    let vios = fixture_vios();
    let cycles: Vec<&Violation> =
        vios.iter().filter(|v| v.rule == Rule::LockOrder).collect();
    assert_eq!(cycles.len(), 1, "exactly the FxOrder cycle: {cycles:?}");
    let c = cycles[0];
    assert_eq!(c.file, "rust/src/kvpool/order.rs");
    assert!(c.msg.contains("FxOrder.a") && c.msg.contains("FxOrder.b"), "{}", c.msg);
    assert!(
        !c.msg.contains("FxOrderOk"),
        "allow(lock-order) on the inverted acquisition kills the FxOrderOk cycle: {}",
        c.msg
    );
}

#[test]
fn baseline_grandfathers_exact_counts() {
    let vios = fixture_vios();
    let total = vios.len();
    let baseline = Baseline::parse(
        "# fixture baseline\n\
         panic rust/src/server/panics.rs 3\n\
         clock rust/src/engine/clock.rs 99\n",
    )
    .expect("baseline parses");
    let (remaining, grandfathered) = baseline.apply(vios);
    // 3 of 4 panics grandfathered (lowest lines first) + the 1 clock hit;
    // overcounted budget is ignored, never banked
    assert_eq!(grandfathered, 4);
    assert_eq!(remaining.len(), total - 4);
    assert!(at(&remaining, Rule::Panic, "rust/src/server/panics.rs", 17));
    assert!(!at(&remaining, Rule::Panic, "rust/src/server/panics.rs", 6));
    assert_eq!(count(&remaining, Rule::Clock), 0);
}

#[test]
fn baseline_rejects_malformed_lines() {
    assert!(Baseline::parse("panic onlytwo").is_err());
    assert!(Baseline::parse("nosuchrule a/b.rs 3").is_err());
    assert!(Baseline::parse("panic a/b.rs many").is_err());
    assert!(Baseline::parse("panic a/b.rs 3 extra").is_err());
    assert!(Baseline::parse("# just comments\n\n").expect("ok").entries().is_empty());
}

#[test]
fn allow_comment_requires_a_reason() {
    // a reasonless allow is not an allow: the violation must survive
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures");
    let text = std::fs::read_to_string(
        root.join("rust").join("src").join("server").join("panics.rs"),
    )
    .expect("fixture readable");
    assert!(text.contains("lint: allow(panic):"), "fixture carries a well-formed allow");

    let mut ctx = lagkv_lint::scan::ScanCtx::default();
    let bad = "pub fn f(v: Option<u32>) -> u32 {\n    // lint: allow(panic):\n    v.unwrap()\n}\n";
    lagkv_lint::scan::scan_file(bad, "rust/src/server/x.rs", &mut ctx);
    assert_eq!(ctx.vios.len(), 1, "reasonless allow must not suppress: {:?}", ctx.vios);
}

//! Fixture: rule 3 (ledger-discipline) seeds.  Raw atomic ops on the
//! byte-gauge names are only legal in the accounting module and the
//! RAII guard impls.

use std::sync::atomic::{AtomicU64, Ordering};

pub struct FxStats {
    pub queued: AtomicU64,
    pub reserved: AtomicU64,
    pub quant_bytes: AtomicU64,
}

impl FxStats {
    pub fn fx_bump(&self) {
        self.queued.fetch_add(1, Ordering::Relaxed);
    }

    pub fn fx_quant_bump(&self) {
        self.quant_bytes.fetch_add(416, Ordering::Relaxed);
    }

    pub fn fx_sanctioned(&self) {
        // lint: allow(ledger): fixture mint half of an RAII pair
        self.reserved.fetch_add(1, Ordering::Relaxed);
    }
}

pub struct QueueToken;

impl QueueToken {
    pub fn fx_release(stats: &FxStats) {
        stats.queued.fetch_sub(1, Ordering::Relaxed);
    }
}

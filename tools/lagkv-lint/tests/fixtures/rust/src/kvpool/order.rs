//! Fixture: rule 5 (lock-order) seeds — a two-function deadlock cycle
//! (`fx_ab` takes a then b, `fx_ba` takes b then a), plus an identical
//! shape whose inverted acquisition carries an allow comment and so
//! contributes no edge.

use std::sync::Mutex;

pub struct FxOrder {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

impl FxOrder {
    pub fn fx_ab(&self) -> u32 {
        let ga = self.a.lock();
        let gb = self.b.lock();
        match (ga, gb) {
            (Ok(x), Ok(y)) => *x + *y,
            _ => 0,
        }
    }

    pub fn fx_ba(&self) -> u32 {
        let gb = self.b.lock();
        let ga = self.a.lock();
        match (ga, gb) {
            (Ok(x), Ok(y)) => *x + *y,
            _ => 0,
        }
    }
}

pub struct FxOrderOk {
    c: Mutex<u32>,
    d: Mutex<u32>,
}

impl FxOrderOk {
    pub fn fx_cd(&self) -> u32 {
        let gc = self.c.lock();
        let gd = self.d.lock();
        match (gc, gd) {
            (Ok(x), Ok(y)) => *x + *y,
            _ => 0,
        }
    }

    pub fn fx_dc(&self) -> u32 {
        let gd = self.d.lock();
        // lint: allow(lock-order): fixture-sanctioned inverted order, the d->c path is startup-only
        let gc = self.c.lock();
        match (gc, gd) {
            (Ok(x), Ok(y)) => *x + *y,
            _ => 0,
        }
    }
}

//! Fixture: rule 4 (no-blocking-in-sink) seeds.  `try_publish` is a
//! sink root; everything it reaches must use `try_lock`.

use std::sync::Mutex;

pub struct FxSink {
    inner: Mutex<Vec<u32>>,
    bad: Mutex<Vec<u32>>,
}

impl FxSink {
    pub fn try_publish(&self, v: u32) {
        self.fx_blocking_push(v);
        self.fx_sanctioned_push(v);
        self.fx_nonblocking_push(v);
    }

    fn fx_blocking_push(&self, v: u32) {
        if let Ok(mut inner) = self.bad.lock() {
            inner.push(v);
        }
    }

    fn fx_sanctioned_push(&self, v: u32) {
        // lint: allow(sink-blocking): fixture exercises the escape hatch
        if let Ok(mut inner) = self.inner.lock() {
            inner.push(v);
        }
    }

    fn fx_nonblocking_push(&self, v: u32) {
        if let Ok(mut inner) = self.inner.try_lock() {
            inner.push(v);
        }
    }
}

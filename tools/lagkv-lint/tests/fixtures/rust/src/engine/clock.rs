//! Fixture: rule 2 (clock-discipline) seeds.  The clock rule applies
//! tree-wide: only the telemetry `Clock` impls may read the real clock.

use std::time::{Instant, SystemTime};

pub struct FxClock;

impl FxClock {
    pub fn fx_now(&self) -> Instant {
        Instant::now()
    }
}

pub fn fx_wall() -> SystemTime {
    // lint: allow(clock): fixture measures real wall time by design
    SystemTime::now()
}

pub struct MonotonicClock;

impl MonotonicClock {
    pub fn fx_origin() -> Instant {
        Instant::now()
    }
}

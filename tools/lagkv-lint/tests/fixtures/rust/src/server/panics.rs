//! Fixture: rule 1 (no-panic-in-serving) seeds.  `server/` is a serving
//! directory, so every panicking construct below must be flagged unless
//! an allow comment sanctions it.

pub fn fx_panics(v: Option<u32>) -> u32 {
    let a = v.unwrap();
    let b = v.expect("fixture");
    if a + b == 0 {
        panic!("fixture");
    }
    // lint: allow(panic): fixture-sanctioned invariant, the caller checked is_some
    let c = v.unwrap();
    a + b + c
}

pub fn fx_todo() {
    todo!()
}

#[cfg(test)]
mod tests {
    #[test]
    fn fx_test_panics_are_ignored() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}

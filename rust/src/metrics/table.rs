//! Fixed-width table printer used by the table/figure regeneration
//! harnesses (same rows the paper reports).

#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn fmt_f(x: f64) -> String {
        format!("{x:.2}")
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Also emit a machine-readable CSV next to the pretty table.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "score"]);
        t.row(vec!["lagkv".into(), "46.74".into()]);
        t.row(vec!["h2o".into(), "35.00".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("lagkv"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        // header and rows share the same width
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }
}

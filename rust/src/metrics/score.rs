//! Task scoring, matching the evaluation conventions of the paper's
//! benchmark facility (Yuan et al. 2024):
//!
//! * passkey retrieval  -> **partial match** over digits,
//! * QA / few-shot / code -> exact match on the answer tokens,
//! * summarization      -> coverage (recall of salient items, order-free),
//! * generic            -> token-level F1.

/// Partial-match score in [0, 100]: positionally aligned digit agreement
/// between prediction and reference (the 64-digit needle metric).  A
/// missing/short prediction scores only its aligned prefix.
pub fn partial_match_digits(pred: &str, truth: &str) -> f64 {
    if truth.is_empty() {
        return 0.0;
    }
    let hits = pred
        .bytes()
        .zip(truth.bytes())
        .filter(|(a, b)| a == b)
        .count();
    100.0 * hits as f64 / truth.len() as f64
}

/// Exact match on whitespace-normalized text, in {0, 100}.
pub fn exact_match(pred: &str, truth: &str) -> f64 {
    let norm = |s: &str| s.split_whitespace().collect::<Vec<_>>().join(" ");
    if norm(pred) == norm(truth) {
        100.0
    } else {
        0.0
    }
}

/// Coverage: fraction of reference symbols that appear in the prediction
/// (order-free, multiset-aware), in [0, 100].  Used for the summarization
/// family.
pub fn coverage_score(pred: &str, truth: &str) -> f64 {
    let want: Vec<&str> = truth.split_whitespace().collect();
    if want.is_empty() {
        return 0.0;
    }
    let mut have: Vec<&str> = pred.split_whitespace().collect();
    let mut hits = 0usize;
    for w in &want {
        if let Some(i) = have.iter().position(|h| h == w) {
            have.swap_remove(i);
            hits += 1;
        }
    }
    100.0 * hits as f64 / want.len() as f64
}

/// Token-level F1 (SQuAD-style), in [0, 100].
pub fn f1_token_score(pred: &str, truth: &str) -> f64 {
    let p: Vec<&str> = pred.split_whitespace().collect();
    let t: Vec<&str> = truth.split_whitespace().collect();
    if p.is_empty() || t.is_empty() {
        return if p.is_empty() && t.is_empty() { 100.0 } else { 0.0 };
    }
    let mut t_left = t.clone();
    let mut common = 0usize;
    for w in &p {
        if let Some(i) = t_left.iter().position(|x| x == w) {
            t_left.swap_remove(i);
            common += 1;
        }
    }
    if common == 0 {
        return 0.0;
    }
    let precision = common as f64 / p.len() as f64;
    let recall = common as f64 / t.len() as f64;
    100.0 * 2.0 * precision * recall / (precision + recall)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_match_basics() {
        assert_eq!(partial_match_digits("1234", "1234"), 100.0);
        assert_eq!(partial_match_digits("1234", "1235"), 75.0);
        assert_eq!(partial_match_digits("", "1234"), 0.0);
        assert_eq!(partial_match_digits("12", "1234"), 50.0);
        // extra digits beyond the reference length are ignored
        assert_eq!(partial_match_digits("123499", "1234"), 100.0);
    }

    #[test]
    fn exact_match_normalizes_whitespace() {
        assert_eq!(exact_match(" blue  ", "blue"), 100.0);
        assert_eq!(exact_match("blue red", "blue"), 0.0);
    }

    #[test]
    fn coverage_order_free() {
        assert_eq!(coverage_score("b a", "a b"), 100.0);
        assert_eq!(coverage_score("a", "a b"), 50.0);
        // multiset: a single "a" cannot cover two
        assert_eq!(coverage_score("a", "a a"), 50.0);
    }

    #[test]
    fn f1_partial_overlap() {
        let f1 = f1_token_score("red blue", "blue green");
        // precision 0.5, recall 0.5 -> F1 50
        assert!((f1 - 50.0).abs() < 1e-9);
        assert_eq!(f1_token_score("x", "y"), 0.0);
        assert_eq!(f1_token_score("same", "same"), 100.0);
    }
}

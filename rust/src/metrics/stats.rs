//! Latency histograms, throughput meters, and KV-pool occupancy gauges
//! for the serving path.

use std::time::{Duration, Instant};

use crate::kvpool::{PoolStats, PrefixStats};

/// Point-in-time KV block-pool gauges, shaped for dashboards and bench
/// output.  Built from the pool's exact ledger ([`PoolStats`]) so the
/// metrics layer never re-derives accounting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolGauges {
    /// Live data bytes (blocks + loose regions).
    pub resident_bytes: usize,
    /// Recycled block bytes parked in the free list.
    pub free_bytes: usize,
    /// Highest resident_bytes ever observed.
    pub high_water_bytes: usize,
    /// Live blocks (each counted once however many caches share it).
    pub resident_blocks: usize,
    /// Idle fraction of the pool's total allocation, in percent.
    pub fragmentation_pct: f64,
    /// Payload bytes demoted to the disk tier (not resident, not budget).
    pub spilled_bytes: usize,
    /// Live blocks currently on the disk tier.
    pub spilled_blocks: usize,
    /// Encoded bytes of quantized blocks resident in the pool.
    pub quant_bytes: usize,
    /// Live encoded-resident quantized blocks.
    pub quant_blocks: usize,
    /// Decoded-row cache bytes held for quantized block reads.
    pub dq_bytes: usize,
    /// Cumulative block fault-ins (disk → pool).
    pub faults: u64,
    /// Cumulative payload bytes faulted back in.
    pub fault_bytes: usize,
    /// The configured byte budget, when one is set.
    pub budget_bytes: Option<usize>,
    /// Prefix-cache gauges, when the deployment runs one ([`PrefixStats`]
    /// carried verbatim — the tree's ledger is already the gauge shape).
    pub prefix: Option<PrefixStats>,
}

impl From<&PoolStats> for PoolGauges {
    fn from(s: &PoolStats) -> PoolGauges {
        PoolGauges {
            resident_bytes: s.resident_bytes(),
            free_bytes: s.free_bytes,
            high_water_bytes: s.high_water_bytes,
            resident_blocks: s.resident_blocks,
            fragmentation_pct: s.fragmentation() * 100.0,
            spilled_bytes: s.spilled_bytes,
            spilled_blocks: s.spilled_blocks,
            quant_bytes: s.quant_bytes,
            quant_blocks: s.quant_blocks,
            dq_bytes: s.dq_bytes,
            faults: s.faults,
            fault_bytes: s.fault_bytes,
            budget_bytes: s.budget,
            prefix: None,
        }
    }
}

impl PoolGauges {
    /// Attach prefix-cache gauges (rendered as a second line).
    pub fn with_prefix(mut self, s: &PrefixStats) -> PoolGauges {
        self.prefix = Some(*s);
        self
    }

    /// One-line rendering for bench output and logs (two lines when
    /// prefix-cache gauges are attached).
    pub fn render(&self) -> String {
        let budget = match self.budget_bytes {
            Some(b) => format!("{:.1}", b as f64 / 1024.0),
            None => "inf".to_string(),
        };
        let mut out = format!(
            "pool: resident {:.1} KiB ({} blocks) / budget {} KiB, \
             high-water {:.1} KiB, free {:.1} KiB, fragmentation {:.1}%",
            self.resident_bytes as f64 / 1024.0,
            self.resident_blocks,
            budget,
            self.high_water_bytes as f64 / 1024.0,
            self.free_bytes as f64 / 1024.0,
            self.fragmentation_pct,
        );
        // Tier gauge only when the disk tier holds data, so memory-only
        // deployments keep their pinned one-line shape.
        if self.spilled_blocks > 0 {
            out.push_str(&format!(
                ", spilled {:.1} KiB ({} blocks)",
                self.spilled_bytes as f64 / 1024.0,
                self.spilled_blocks,
            ));
        }
        if self.faults > 0 {
            out.push_str(&format!(
                ", faulted {:.1} KiB ({} blocks)",
                self.fault_bytes as f64 / 1024.0,
                self.faults,
            ));
        }
        // Codec gauge only under --quant, same reasoning as the tier gauge.
        if self.quant_blocks > 0 {
            out.push_str(&format!(
                ", quantized {:.1} KiB ({} blocks, decode cache {:.1} KiB)",
                self.quant_bytes as f64 / 1024.0,
                self.quant_blocks,
                self.dq_bytes as f64 / 1024.0,
            ));
        }
        if let Some(p) = &self.prefix {
            out.push_str(&format!(
                "\nprefix: {} entries {:.1} KiB, hits {} / misses {}, \
                 reused {:.1} KiB ({} tokens), shed {}",
                p.entries,
                p.resident_bytes as f64 / 1024.0,
                p.hits,
                p.misses,
                p.reused_bytes as f64 / 1024.0,
                p.reused_tokens,
                p.shed,
            ));
        }
        out
    }
}

/// Streaming latency recorder with exact quantiles over a bounded sample
/// buffer (fine for benchmark-scale request counts).
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples_us: Vec<u64>,
    sorted: bool,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
        self.sorted = false;
    }

    pub fn record_us(&mut self, us: u64) {
        self.samples_us.push(us);
        self.sorted = false;
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64 / 1000.0
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples_us.sort_unstable();
            self.sorted = true;
        }
    }

    /// q in [0, 1]; nearest-rank.
    pub fn quantile_ms(&mut self, q: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = ((q * self.samples_us.len() as f64).ceil() as usize)
            .clamp(1, self.samples_us.len());
        self.samples_us[rank - 1] as f64 / 1000.0
    }

    /// q in [0, 1]; nearest-rank, integer microseconds — the wire form
    /// ([`HistogramSummary`]) stays integer-exact through JSON.
    ///
    /// [`HistogramSummary`]: crate::telemetry::HistogramSummary
    pub fn quantile_us(&mut self, q: f64) -> u64 {
        if self.samples_us.is_empty() {
            return 0;
        }
        self.ensure_sorted();
        let rank = ((q * self.samples_us.len() as f64).ceil() as usize)
            .clamp(1, self.samples_us.len());
        self.samples_us[rank - 1]
    }

    pub fn p50_ms(&mut self) -> f64 {
        self.quantile_ms(0.50)
    }

    pub fn p95_ms(&mut self) -> f64 {
        self.quantile_ms(0.95)
    }

    pub fn p99_ms(&mut self) -> f64 {
        self.quantile_ms(0.99)
    }

    pub fn merge(&mut self, other: &Histogram) {
        self.samples_us.extend_from_slice(&other.samples_us);
        self.sorted = false;
    }
}

/// Tokens/requests per second over a wall-clock window.
#[derive(Debug)]
pub struct ThroughputMeter {
    start: Instant,
    pub tokens: u64,
    pub requests: u64,
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        // lint: allow(clock): throughput is tokens per *wall-clock* second for bench reports; a virtual clock would be meaningless here
        ThroughputMeter { start: Instant::now(), tokens: 0, requests: 0 }
    }

    pub fn add_tokens(&mut self, n: u64) {
        self.tokens += n;
    }

    pub fn add_request(&mut self) {
        self.requests += 1;
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn requests_per_s(&self) -> f64 {
        self.requests as f64 / self.elapsed_s().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_nearest_rank() {
        let mut h = Histogram::new();
        for us in [1000u64, 2000, 3000, 4000, 5000, 6000, 7000, 8000, 9000, 10000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 10);
        assert!((h.mean_ms() - 5.5).abs() < 1e-9);
        assert_eq!(h.p50_ms(), 5.0);
        assert_eq!(h.quantile_ms(0.9), 9.0);
        assert_eq!(h.p99_ms(), 10.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let mut h = Histogram::new();
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.p95_ms(), 0.0);
    }

    #[test]
    fn pool_gauges_mirror_pool_stats() {
        let s = PoolStats {
            block_bytes: 3072,
            loose_bytes: 1024,
            free_bytes: 1024,
            high_water_bytes: 5120,
            resident_blocks: 3,
            free_blocks: 1,
            spilled_bytes: 0,
            spilled_blocks: 0,
            quant_bytes: 0,
            quant_blocks: 0,
            dq_bytes: 0,
            faults: 0,
            fault_bytes: 0,
            budget: Some(8192),
        };
        let g = PoolGauges::from(&s);
        assert_eq!(g.resident_bytes, 4096);
        assert_eq!(g.resident_blocks, 3);
        assert!((g.fragmentation_pct - 20.0).abs() < 1e-9);
        let line = g.render();
        assert!(line.contains("4.0 KiB"), "rendered: {line}");
        assert!(line.contains("3 blocks"), "rendered: {line}");
        assert!(line.contains("fragmentation 20.0%"), "rendered: {line}");
        assert!(!line.contains("spilled"), "no tier segment while the disk tier is empty");
        let unbudgeted = PoolGauges::from(&PoolStats { budget: None, ..s });
        assert!(unbudgeted.render().contains("budget inf"));
        assert!(!unbudgeted.render().contains("prefix:"), "no prefix line unless attached");
        let spilled =
            PoolGauges::from(&PoolStats { spilled_bytes: 2048, spilled_blocks: 2, ..s });
        let line = spilled.render();
        assert!(line.contains("spilled 2.0 KiB (2 blocks)"), "rendered: {line}");
        assert!(!line.contains("faulted"), "no fault segment before any fault-in");
        let faulted = PoolGauges::from(&PoolStats { faults: 3, fault_bytes: 3072, ..s });
        let line = faulted.render();
        assert!(line.contains("faulted 3.0 KiB (3 blocks)"), "rendered: {line}");
        assert!(!line.contains("quantized"), "no codec segment without --quant");
        let quantized = PoolGauges::from(&PoolStats {
            quant_bytes: 2048,
            quant_blocks: 4,
            dq_bytes: 1024,
            ..s
        });
        let line = quantized.render();
        assert!(
            line.contains("quantized 2.0 KiB (4 blocks, decode cache 1.0 KiB)"),
            "rendered: {line}"
        );
    }

    #[test]
    fn prefix_gauges_render_as_second_line() {
        let s = PoolStats {
            block_bytes: 2048,
            loose_bytes: 0,
            free_bytes: 0,
            high_water_bytes: 2048,
            resident_blocks: 2,
            free_blocks: 0,
            spilled_bytes: 0,
            spilled_blocks: 0,
            quant_bytes: 0,
            quant_blocks: 0,
            dq_bytes: 0,
            faults: 0,
            fault_bytes: 0,
            budget: None,
        };
        let p = PrefixStats {
            entries: 3,
            resident_bytes: 1024,
            hits: 5,
            misses: 2,
            inserts: 7,
            shed: 1,
            reused_bytes: 4096,
            reused_tokens: 96,
        };
        let g = PoolGauges::from(&s).with_prefix(&p);
        let line = g.render();
        assert!(line.contains("prefix: 3 entries 1.0 KiB"), "rendered: {line}");
        assert!(line.contains("hits 5 / misses 2"), "rendered: {line}");
        assert!(line.contains("reused 4.0 KiB (96 tokens), shed 1"), "rendered: {line}");
    }

    #[test]
    fn merge_combines_samples() {
        let mut a = Histogram::new();
        a.record_us(1000);
        let mut b = Histogram::new();
        b.record_us(3000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean_ms() - 2.0).abs() < 1e-9);
    }
}

//! Latency histograms and throughput meters for the serving path.

use std::time::{Duration, Instant};

/// Streaming latency recorder with exact quantiles over a bounded sample
/// buffer (fine for benchmark-scale request counts).
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples_us: Vec<u64>,
    sorted: bool,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
        self.sorted = false;
    }

    pub fn record_us(&mut self, us: u64) {
        self.samples_us.push(us);
        self.sorted = false;
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64 / 1000.0
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples_us.sort_unstable();
            self.sorted = true;
        }
    }

    /// q in [0, 1]; nearest-rank.
    pub fn quantile_ms(&mut self, q: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = ((q * self.samples_us.len() as f64).ceil() as usize)
            .clamp(1, self.samples_us.len());
        self.samples_us[rank - 1] as f64 / 1000.0
    }

    pub fn p50_ms(&mut self) -> f64 {
        self.quantile_ms(0.50)
    }

    pub fn p95_ms(&mut self) -> f64 {
        self.quantile_ms(0.95)
    }

    pub fn p99_ms(&mut self) -> f64 {
        self.quantile_ms(0.99)
    }

    pub fn merge(&mut self, other: &Histogram) {
        self.samples_us.extend_from_slice(&other.samples_us);
        self.sorted = false;
    }
}

/// Tokens/requests per second over a wall-clock window.
#[derive(Debug)]
pub struct ThroughputMeter {
    start: Instant,
    pub tokens: u64,
    pub requests: u64,
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        ThroughputMeter { start: Instant::now(), tokens: 0, requests: 0 }
    }

    pub fn add_tokens(&mut self, n: u64) {
        self.tokens += n;
    }

    pub fn add_request(&mut self) {
        self.requests += 1;
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn requests_per_s(&self) -> f64 {
        self.requests as f64 / self.elapsed_s().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_nearest_rank() {
        let mut h = Histogram::new();
        for us in [1000u64, 2000, 3000, 4000, 5000, 6000, 7000, 8000, 9000, 10000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 10);
        assert!((h.mean_ms() - 5.5).abs() < 1e-9);
        assert_eq!(h.p50_ms(), 5.0);
        assert_eq!(h.quantile_ms(0.9), 9.0);
        assert_eq!(h.p99_ms(), 10.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let mut h = Histogram::new();
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.p95_ms(), 0.0);
    }

    #[test]
    fn merge_combines_samples() {
        let mut a = Histogram::new();
        a.record_us(1000);
        let mut b = Histogram::new();
        b.record_us(3000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean_ms() - 2.0).abs() < 1e-9);
    }
}

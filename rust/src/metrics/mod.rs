//! Scoring functions for the evaluation harnesses plus serving-side
//! latency/throughput instrumentation and a fixed-width table printer.

pub mod score;
pub mod stats;
pub mod table;

pub use score::{coverage_score, exact_match, f1_token_score, partial_match_digits};
pub use stats::{Histogram, PoolGauges, ThroughputMeter};
pub use table::Table;

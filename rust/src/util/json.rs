//! Minimal JSON parser/writer.
//!
//! The offline image has no `serde`/`serde_json`, so artifact metadata
//! (vocab.json, manifest.json, golden vectors) is read through this small,
//! strict parser.  It supports the full JSON grammar except `\u` surrogate
//! pairs outside the BMP (sufficient for our ASCII artifacts, and rejected
//! loudly otherwise).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Array of numbers -> Vec<f32>.
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_str()?.to_string())).collect()
    }

    // -- writer ----------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building response payloads.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

pub fn n(v: f64) -> Json {
    Json::Num(v)
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            if (0xd800..0xe000).contains(&cp) {
                                bail!("surrogate \\u escapes unsupported");
                            }
                            out.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-sync to char boundary for multi-byte UTF-8
                    let len = utf8_len(c);
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let bytes = &self.b[self.i - 1..self.i - 1 + len];
                        out.push_str(std::str::from_utf8(bytes)?);
                        self.i += len - 1;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s\n",true,null],"z":{"w":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""aA\n\t\"q\"""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "aA\n\t\"q\"");
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ∞\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ∞");
    }
}

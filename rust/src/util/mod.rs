//! Self-contained substitutes for crates unavailable in the offline image
//! (see the note in Cargo.toml): JSON, CLI parsing, RNG, property testing,
//! and a tiny timing helper for the bench harnesses.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

use std::time::Instant;

/// Argmax over a flat f32 slice (greedy sampling).  Lives here (not in the
/// feature-gated runtime) because every backend's decode loop needs it.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// Measure wall-clock of `f` over `iters` runs after `warmup` runs;
/// returns (mean_ns, min_ns).
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut total = 0.0f64;
    let mut min = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_nanos() as f64;
        total += dt;
        min = min.min(dt);
    }
    (total / iters as f64, min)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0, -1.0]), 1);
        assert_eq!(argmax(&[7.0]), 0);
    }

    #[test]
    fn argmax_prefers_first_on_ties() {
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0);
    }
}

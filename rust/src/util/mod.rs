//! Self-contained substitutes for crates unavailable in the offline image
//! (see the note in Cargo.toml): JSON, CLI parsing, RNG, property testing,
//! and a tiny timing helper for the bench harnesses.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

/// Lock a mutex, recovering the data from a poisoned one instead of
/// propagating the poison panic.  Every guarded structure in the serving
/// stack keeps its invariants inside single statements (ledgers move under
/// RAII guards, maps are repaired on restore), so the state behind a
/// poisoned mutex is still coherent and serving on it beats taking the
/// whole process down.  `lagkv-lint` treats calls to this helper as lock
/// acquisitions for its sink-blocking and lock-order rules.
pub fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Argmax over a flat f32 slice (greedy sampling).  Lives here (not in the
/// feature-gated runtime) because every backend's decode loop needs it.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// Measure wall-clock of `f` over `iters` runs after `warmup` runs;
/// returns (mean_ns, min_ns).
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut total = 0.0f64;
    let mut min = f64::INFINITY;
    for _ in 0..iters {
        // lint: allow(clock): bench helper measures real wall time by design
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_nanos() as f64;
        total += dt;
        min = min.min(dt);
    }
    (total / iters as f64, min)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0, -1.0]), 1);
        assert_eq!(argmax(&[7.0]), 0);
    }

    #[test]
    fn argmax_prefers_first_on_ties() {
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0);
    }
}

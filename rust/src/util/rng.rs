//! Small, fast, seedable RNG (xoshiro256**) used by the workload generators,
//! the random-eviction baseline, and the property-testing harness.
//!
//! Deterministic across platforms: workload suites are reproducible from a
//! `--seed` flag alone.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        // splitmix64 expansion of the seed, as recommended by the xoshiro authors
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough variant (bias is
        // negligible for n << 2^64 and this is a workload generator).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi) (half-open).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Choose k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::seed_from(1);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::seed_from(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(3);
        let n = 20_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::seed_from(4);
        for _ in 0..50 {
            let mut v = r.choose_distinct(20, 8);
            v.sort_unstable();
            v.dedup();
            assert_eq!(v.len(), 8);
        }
    }
}

//! Seeded property-testing harness (no `proptest` in the offline image).
//!
//! Usage:
//! ```ignore
//! prop::check(256, |g| {
//!     let n = g.usize(1, 100);
//!     let xs = g.vec_f32(n, -10.0, 10.0);
//!     // ... assert invariant, or return Err(reason)
//!     Ok(())
//! });
//! ```
//!
//! On failure the harness reports the failing case number and the seed so a
//! `PROP_SEED=<seed> cargo test` rerun reproduces it exactly.

use crate::util::rng::Rng;

pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize(&mut self, lo: usize, hi_incl: usize) -> usize {
        self.rng.range(lo, hi_incl + 1)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, n: usize, scale: f32, offset: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal() * scale + offset).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `cases` random cases of `property`.  Panics with seed info on the
/// first failure.
pub fn check<F>(cases: usize, mut property: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed: u64 = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen { rng: Rng::seed_from(seed), case };
        if let Err(msg) = property(&mut g) {
            panic!(
                "property failed at case {case} (PROP_SEED={base_seed}, case seed {seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(64, |g| {
            let n = g.usize(1, 50);
            let v = g.vec_f32(n, 0.0, 1.0);
            if v.len() == n {
                Ok(())
            } else {
                Err("length mismatch".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        check(16, |g| {
            let x = g.usize(0, 10);
            if x < 10 {
                Ok(())
            } else {
                Err(format!("x = {x}"))
            }
        });
    }
}

//! Tiny argument parser (no `clap` in the offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

pub const FLAG_SET: &str = "true";

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|nxt| !nxt.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), FLAG_SET.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    /// Comma-separated list flag, e.g. `--lags 16,64,128`.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["tables", "--fig2", "--lag", "64", "--ratio=0.25", "out.txt"]);
        assert_eq!(a.positional, vec!["tables", "out.txt"]);
        assert!(a.has("fig2"));
        assert_eq!(a.get("lag"), Some("64"));
        assert_eq!(a.get("ratio"), Some("0.25"));
    }

    #[test]
    fn numeric_helpers() {
        let a = parse(&["--n", "12", "--r", "0.5"]);
        assert_eq!(a.usize_or("n", 1).unwrap(), 12);
        assert_eq!(a.f64_or("r", 1.0).unwrap(), 0.5);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn list_flag() {
        let a = parse(&["--lags", "16,64,128"]);
        assert_eq!(a.list_or("lags", &[]), vec!["16", "64", "128"]);
    }

    #[test]
    fn boolean_flag_before_positional_consumes_next() {
        // documented behaviour: `--flag value` binds value to flag
        let a = parse(&["--verbose", "serve"]);
        assert_eq!(a.get("verbose"), Some("serve"));
    }
}

//! Serve-time tokenizer, byte-identical with python/compile/tokenizer.py.
//!
//! The vocabulary is loaded from `artifacts/models/<variant>/vocab.json`
//! (written at train time); golden cross-checks live in
//! `artifacts/golden/tokenizer.json` and rust/tests/golden.rs.
//!
//! Digit runs are segmented by the variant's `digits_per_token`:
//! 1 ("qwen-like", one token per digit) or 3 ("llama-like", greedy 3-digit
//! packing) — the mechanism behind the paper's Fig. 2 divergence.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::config::read_json;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const Q: i32 = 4;
pub const A: i32 = 5;
pub const UNK: i32 = 6;

#[derive(Debug, Clone)]
pub struct Vocab {
    pub tokens: Vec<String>,
    pub token_to_id: HashMap<String, i32>,
    pub digit1_base: i32,
    pub digit2_base: i32,
    pub digit3_base: i32,
    pub word_base: i32,
    pub words: Vec<String>,
}

impl Vocab {
    pub fn load(path: &Path) -> Result<Vocab> {
        let v = read_json(path)?;
        let tokens = v.get("tokens")?.as_str_vec()?;
        let mut token_to_id = HashMap::with_capacity(tokens.len());
        for (i, t) in tokens.iter().enumerate() {
            // first occurrence wins (duplicate surfaces like "0" vs digit3 "000"
            // never collide, but keep python's setdefault semantics)
            token_to_id.entry(t.clone()).or_insert(i as i32);
        }
        Ok(Vocab {
            token_to_id,
            digit1_base: v.get("digit1_base")?.as_i64()? as i32,
            digit2_base: v.get("digit2_base")?.as_i64()? as i32,
            digit3_base: v.get("digit3_base")?.as_i64()? as i32,
            word_base: v.get("word_base")?.as_i64()? as i32,
            words: v.get("words")?.as_str_vec()?,
            tokens,
        })
    }

    /// The full synthetic vocabulary, built in-process — byte-identical to
    /// python/compile/common.py's `build_vocab()` (specials, digit slices,
    /// then filler + content + structural words, in that order; order is
    /// load-bearing because ids are positional).  This is what makes the
    /// CPU reference backend and the hermetic tokenizer tests independent
    /// of `make artifacts`.
    pub fn synthetic() -> Vocab {
        use crate::workloads::words::{CONTENT_WORDS, FILLER_WORDS, STRUCT_WORDS};
        let mut tokens: Vec<String> =
            ["<pad>", "<bos>", "<eos>", "<sep>", "<q>", "<a>", "<unk>"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        for d in 0..10 {
            tokens.push(format!("{d}"));
        }
        for d in 0..100 {
            tokens.push(format!("{d:02}"));
        }
        for d in 0..1000 {
            tokens.push(format!("{d:03}"));
        }
        let words: Vec<String> = FILLER_WORDS
            .iter()
            .chain(CONTENT_WORDS)
            .chain(STRUCT_WORDS)
            .map(|s| s.to_string())
            .collect();
        tokens.extend(words.iter().cloned());
        let mut token_to_id = HashMap::with_capacity(tokens.len());
        for (i, t) in tokens.iter().enumerate() {
            token_to_id.entry(t.clone()).or_insert(i as i32);
        }
        Vocab {
            token_to_id,
            digit1_base: 7,
            digit2_base: 17,
            digit3_base: 117,
            word_base: 1117,
            words,
            tokens,
        }
    }

    pub fn size(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_digit_token(&self, id: i32) -> bool {
        id >= self.digit1_base && id < self.word_base
    }

    pub fn surface(&self, id: i32) -> &str {
        self.tokens.get(id as usize).map(|s| s.as_str()).unwrap_or("<unk>")
    }
}

#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub vocab: Vocab,
    pub digits_per_token: usize,
}

impl Tokenizer {
    pub fn new(vocab: Vocab, digits_per_token: usize) -> Result<Tokenizer> {
        if digits_per_token != 1 && digits_per_token != 3 {
            bail!("digits_per_token must be 1 or 3");
        }
        Ok(Tokenizer { vocab, digits_per_token })
    }

    pub fn load(model_dir: &Path, digits_per_token: usize) -> Result<Tokenizer> {
        Tokenizer::new(Vocab::load(&model_dir.join("vocab.json"))?, digits_per_token)
    }

    pub fn encode_digit_run(&self, run: &str) -> Vec<i32> {
        debug_assert!(run.bytes().all(|b| b.is_ascii_digit()));
        let b = run.as_bytes();
        let mut out = Vec::with_capacity(run.len());
        if self.digits_per_token == 1 {
            for &c in b {
                out.push(self.vocab.digit1_base + (c - b'0') as i32);
            }
            return out;
        }
        let mut i = 0;
        while i < b.len() {
            let rem = b.len() - i;
            if rem >= 3 {
                let v = (b[i] - b'0') as i32 * 100 + (b[i + 1] - b'0') as i32 * 10
                    + (b[i + 2] - b'0') as i32;
                out.push(self.vocab.digit3_base + v);
                i += 3;
            } else if rem == 2 {
                let v = (b[i] - b'0') as i32 * 10 + (b[i + 1] - b'0') as i32;
                out.push(self.vocab.digit2_base + v);
                i += 2;
            } else {
                out.push(self.vocab.digit1_base + (b[i] - b'0') as i32);
                i += 1;
            }
        }
        out
    }

    pub fn encode_symbol(&self, sym: &str, out: &mut Vec<i32>) {
        if !sym.is_empty() && sym.bytes().all(|b| b.is_ascii_digit()) {
            out.extend(self.encode_digit_run(sym));
        } else {
            out.push(*self.vocab.token_to_id.get(sym).unwrap_or(&UNK));
        }
    }

    pub fn encode(&self, text: &str, bos: bool) -> Vec<i32> {
        let mut out = Vec::with_capacity(text.len() / 4 + 1);
        if bos {
            out.push(BOS);
        }
        for sym in text.split_whitespace() {
            self.encode_symbol(sym, &mut out);
        }
        out
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let mut parts: Vec<String> = Vec::new();
        let mut prev_digit = false;
        for &id in ids {
            let (surf, is_digit) = if id < 0 || id as usize >= self.vocab.size() {
                ("<unk>", false)
            } else {
                (self.vocab.surface(id), self.vocab.is_digit_token(id))
            };
            if is_digit && prev_digit {
                parts.last_mut().unwrap().push_str(surf);
            } else {
                parts.push(surf.to_string());
            }
            prev_digit = is_digit;
        }
        parts.join(" ")
    }

    /// Incremental decode: the suffix `id` appends to the decode of a
    /// preceding stream whose last token's digit-ness is `prev_digit`
    /// (`None` when nothing precedes).  Guarantees
    /// `decode(prefix) + delta == decode(prefix ++ [id])`, which is what
    /// makes streamed `text_delta`s concatenate to the one-shot text
    /// without re-decoding the whole prefix per token.
    pub fn decode_delta(&self, prev_digit: Option<bool>, id: i32) -> (String, bool) {
        let (surf, is_digit) = if id < 0 || id as usize >= self.vocab.size() {
            ("<unk>", false)
        } else {
            (self.vocab.surface(id), self.vocab.is_digit_token(id))
        };
        let mut out = String::with_capacity(surf.len() + 1);
        match prev_digit {
            // digit runs merge without a separator; the stream opener has
            // no separator either
            Some(true) if is_digit => {}
            None => {}
            _ => out.push(' '),
        }
        out.push_str(surf);
        (out, is_digit)
    }

    /// Concatenated digit content of a token stream (passkey scoring).
    pub fn decode_digits(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            if self.vocab.is_digit_token(id) {
                out.push_str(self.vocab.surface(id));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// In-memory vocab mirroring python/compile/common.py (subset of words
    /// is fine for unit tests; golden.rs validates against the artifact).
    pub fn test_vocab() -> Vocab {
        let mut tokens: Vec<String> =
            ["<pad>", "<bos>", "<eos>", "<sep>", "<q>", "<a>", "<unk>"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        for d in 0..10 {
            tokens.push(format!("{d}"));
        }
        for d in 0..100 {
            tokens.push(format!("{d:02}"));
        }
        for d in 0..1000 {
            tokens.push(format!("{d:03}"));
        }
        let words = ["the", "pass", "key", "is", "remember", "it", "fact", "falcon"];
        for w in words {
            tokens.push(w.to_string());
        }
        let mut token_to_id = HashMap::new();
        for (i, t) in tokens.iter().enumerate() {
            token_to_id.entry(t.clone()).or_insert(i as i32);
        }
        Vocab {
            token_to_id,
            digit1_base: 7,
            digit2_base: 17,
            digit3_base: 117,
            word_base: 1117,
            words: words.iter().map(|s| s.to_string()).collect(),
            tokens,
        }
    }

    #[test]
    fn synthetic_vocab_layout_matches_python() {
        let v = Vocab::synthetic();
        // 7 specials + 10 + 100 + 1000 digits + 64 filler + 98 content
        // + 22 struct words
        assert_eq!(v.size(), 7 + 10 + 100 + 1000 + 64 + 98 + 22);
        assert_eq!(v.word_base, 1117);
        assert_eq!(v.surface(0), "<pad>");
        assert_eq!(v.surface(7), "0");
        assert_eq!(v.surface(17), "00");
        assert_eq!(v.surface(117), "000");
        assert_eq!(v.surface(1117), "the");
        // duplicate surfaces ("0" vs padded digits) resolve to first id
        assert_eq!(v.token_to_id["0"], 7);
        assert!(v.is_digit_token(500));
        assert!(!v.is_digit_token(1200));
    }

    #[test]
    fn synthetic_vocab_encodes_task_templates_without_unk() {
        for dpt in [1usize, 3] {
            let t = Tokenizer::new(Vocab::synthetic(), dpt).unwrap();
            let text = "<sep> pass key is 9081726354 . remember it <sep> <q> pass key <a>";
            let ids = t.encode(text, false);
            assert!(!ids.contains(&UNK), "template words must all be in-vocab");
            assert_eq!(t.decode(&ids), text);
            assert_eq!(t.decode_digits(&ids), "9081726354");
        }
    }

    #[test]
    fn digit_run_lengths_match_fig2_mechanism() {
        let qwen = Tokenizer::new(test_vocab(), 1).unwrap();
        let llama = Tokenizer::new(test_vocab(), 3).unwrap();
        let run: String = "1234567890".repeat(6) + "1234"; // 64 digits
        assert_eq!(qwen.encode_digit_run(&run).len(), 64);
        assert_eq!(llama.encode_digit_run(&run).len(), 22);
    }

    #[test]
    fn packed_segmentation() {
        let t = Tokenizer::new(test_vocab(), 3).unwrap();
        // "1234567" -> "123" "456" "7"
        let ids = t.encode_digit_run("1234567");
        assert_eq!(ids, vec![117 + 123, 117 + 456, 7 + 7]);
        // "12" -> 2-digit slice
        assert_eq!(t.encode_digit_run("12"), vec![17 + 12]);
    }

    #[test]
    fn encode_decode_roundtrip() {
        for dpt in [1usize, 3] {
            let t = Tokenizer::new(test_vocab(), dpt).unwrap();
            let text = "the pass key is 9081726354 . remember it";
            let ids = t.encode(text, false);
            // "." is not in the test vocab -> <unk>; replace for comparison
            let decoded = t.decode(&ids);
            assert_eq!(decoded, text.replace(" . ", " <unk> "));
            assert_eq!(t.decode_digits(&ids), "9081726354");
        }
    }

    #[test]
    fn bos_and_specials() {
        let t = Tokenizer::new(test_vocab(), 1).unwrap();
        let ids = t.encode("<q> pass key <a>", true);
        assert_eq!(ids[0], BOS);
        assert_eq!(ids[1], Q);
        assert_eq!(*ids.last().unwrap(), A);
    }

    #[test]
    fn property_digit_roundtrip() {
        use crate::util::prop;
        let qwen = Tokenizer::new(test_vocab(), 1).unwrap();
        let llama = Tokenizer::new(test_vocab(), 3).unwrap();
        prop::check(200, |g| {
            let n = g.usize(1, 80);
            let run: String =
                (0..n).map(|_| char::from(b'0' + g.usize(0, 9) as u8)).collect();
            for t in [&qwen, &llama] {
                let ids = t.encode_digit_run(&run);
                if t.decode_digits(&ids) != run {
                    return Err(format!("roundtrip failed for {run}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_decode_delta_concatenates_to_decode() {
        // The streaming contract: per-token deltas concatenate to exactly
        // the batch decode, across digit runs, words, and out-of-range ids.
        use crate::util::prop;
        let t = Tokenizer::new(test_vocab(), 3).unwrap();
        let size = t.vocab.size() as i32;
        prop::check(120, |g| {
            let n = g.usize(1, 40);
            let ids: Vec<i32> =
                (0..n).map(|_| g.usize(0, size as usize + 3) as i32 - 2).collect();
            let mut prev = None;
            let mut text = String::new();
            for &id in &ids {
                let (delta, is_digit) = t.decode_delta(prev, id);
                text.push_str(&delta);
                prev = Some(is_digit);
            }
            if text != t.decode(&ids) {
                return Err(format!("delta concat {text:?} != decode of {ids:?}"));
            }
            Ok(())
        });
    }
}

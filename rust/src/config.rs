//! Layered configuration for the serving stack.
//!
//! Three pieces compose a run:
//! * [`ModelDims`]       — architecture, parsed from `artifacts/manifest.json`
//!                         (authored by python/compile/aot.py; never hand-edited).
//! * [`CompressionConfig`] — the paper's knobs: sink `S`, lag `L`, retained
//!                         ratio `r`, policy, scorer backend.
//! * [`ServingConfig`]   — coordinator knobs: batch buckets, queue depth,
//!                         decode limits.
//!
//! Everything has CLI overrides (`--lag 64 --ratio 0.25 --policy lagkv`).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::quant::QuantSpec;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Architecture of the AOT-compiled model (mirror of python ModelConfig).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
}

impl ModelDims {
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(ModelDims {
            vocab_size: v.get("vocab_size")?.as_usize()?,
            d_model: v.get("d_model")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            n_q_heads: v.get("n_q_heads")?.as_usize()?,
            n_kv_heads: v.get("n_kv_heads")?.as_usize()?,
            d_head: v.get("d_head")?.as_usize()?,
            d_ff: v.get("d_ff")?.as_usize()?,
            max_seq: v.get("max_seq")?.as_usize()?,
            rope_theta: v.get("rope_theta")?.as_f64()?,
            norm_eps: v.get("norm_eps")?.as_f64()?,
        })
    }

    pub fn group_size(&self) -> usize {
        self.n_q_heads / self.n_kv_heads
    }
}

/// Which eviction policy the KV-cache manager runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// The paper's method (Eqs. 5-9).
    LagKv,
    /// Appendix A.2 variant: min/max from the local chunk.
    LocalKv,
    /// Appendix A.2 variant: -||K||2, first two layers skipped.
    L2Norm,
    /// Heavy-hitter oracle: accumulated attention mass (needs instrumented
    /// executables — the FlashAttention-incompatible baseline).
    H2O,
    /// StreamingLLM-style recency: keep the newest rL of each partition.
    Streaming,
    /// StreamingLLM proper (sink + global recency): victims are the oldest
    /// evictable tokens anywhere in the cache, not per partition — what
    /// survives is exactly the attention sink plus the newest window.
    StreamingLlm,
    /// Uniform-random retention (sanity floor).
    Random,
    /// No compression (the paper's "Baseline" rows).
    None,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "lagkv" => PolicyKind::LagKv,
            "localkv" => PolicyKind::LocalKv,
            "l2norm" | "l2" => PolicyKind::L2Norm,
            "h2o" => PolicyKind::H2O,
            "streaming" | "window" => PolicyKind::Streaming,
            "streamingllm" | "sink-recency" => PolicyKind::StreamingLlm,
            "random" => PolicyKind::Random,
            "none" | "baseline" | "full" => PolicyKind::None,
            other => bail!("unknown policy {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::LagKv => "lagkv",
            PolicyKind::LocalKv => "localkv",
            PolicyKind::L2Norm => "l2norm",
            PolicyKind::H2O => "h2o",
            PolicyKind::Streaming => "streaming",
            PolicyKind::StreamingLlm => "streamingllm",
            PolicyKind::Random => "random",
            PolicyKind::None => "none",
        }
    }

    pub fn all() -> &'static [PolicyKind] {
        &[
            PolicyKind::LagKv,
            PolicyKind::LocalKv,
            PolicyKind::L2Norm,
            PolicyKind::H2O,
            PolicyKind::Streaming,
            PolicyKind::StreamingLlm,
            PolicyKind::Random,
            PolicyKind::None,
        ]
    }

    /// Does this policy need per-token attention statistics from the
    /// instrumented executables?
    pub fn needs_attention(&self) -> bool {
        matches!(self, PolicyKind::H2O)
    }
}

/// Scorer backend for the score-computing policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScorerBackend {
    /// Pure-Rust scorer (default; zero transfer overhead).
    Rust,
    /// AOT-compiled Pallas kernel via PJRT (proves L1 integration; used by
    /// tests to cross-validate the Rust scorer bit-for-bit-ish).
    Xla,
}

/// The paper's compression knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressionConfig {
    pub policy: PolicyKind,
    /// Attention-sink prefix size S (paper: 16 at 8B scale; 4 at ours).
    pub sink: usize,
    /// Lag / partition size L.
    pub lag: usize,
    /// Retained fraction r in each partition (0 < r <= 1); the paper's
    /// "2x/4x/6x/8x" map to r = 0.5 / 0.25 / 0.167 / 0.125.
    pub ratio: f64,
    pub scorer: ScorerBackend,
    /// Layers exempt from compression (the L2-norm variant skips 2).
    pub skip_layers: usize,
}

impl Default for CompressionConfig {
    fn default() -> Self {
        CompressionConfig {
            policy: PolicyKind::LagKv,
            sink: 4,
            lag: 64,
            ratio: 0.5,
            scorer: ScorerBackend::Rust,
            skip_layers: 0,
        }
    }
}

impl CompressionConfig {
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut c = CompressionConfig::default();
        if let Some(p) = args.get("policy") {
            c.policy = PolicyKind::parse(p)?;
        }
        c.sink = args.usize_or("sink", c.sink)?;
        c.lag = args.usize_or("lag", c.lag)?;
        c.ratio = args.f64_or("ratio", c.ratio)?;
        if let Some(s) = args.get("scorer") {
            c.scorer = match s {
                "rust" => ScorerBackend::Rust,
                "xla" => ScorerBackend::Xla,
                other => bail!("unknown scorer {other:?} (rust|xla)"),
            };
        }
        if c.policy == PolicyKind::L2Norm {
            c.skip_layers = args.usize_or("skip-layers", 2)?;
        } else {
            c.skip_layers = args.usize_or("skip-layers", 0)?;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0 < self.ratio && self.ratio <= 1.0) {
            bail!("ratio must be in (0, 1], got {}", self.ratio);
        }
        if self.lag == 0 {
            bail!("lag must be positive");
        }
        Ok(())
    }

    /// Tokens kept per compressed partition: floor(r * L), min 1.
    pub fn keep_per_partition(&self) -> usize {
        ((self.ratio * self.lag as f64).floor() as usize).max(1)
    }

    /// The paper's notation "Nx" (2x = r 0.5 ...).
    pub fn ratio_label(&self) -> String {
        format!("{:.0}x", 1.0 / self.ratio)
    }
}

/// Coordinator / serving parameters.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Decode batch buckets available as AOT executables (ascending).
    pub decode_buckets: Vec<usize>,
    /// Prefill length buckets available as AOT executables (ascending).
    pub prefill_buckets: Vec<usize>,
    pub max_new_tokens: usize,
    /// Bounded admission-queue depth per model (`queue-full` beyond it).
    pub max_queue: usize,
    /// Session-store capacity per model (0 disables cross-turn reuse).
    pub session_capacity: usize,
    /// Session idle time-to-live, seconds.
    pub session_ttl_s: u64,
    /// Byte budget for each model's KV block pool (`None` = unbudgeted).
    /// CLI: `--pool-mb N` (mebibytes; 0 means uncapped, matching
    /// `--session-mb`).
    pub pool_max_bytes: Option<usize>,
    /// Resident-byte cap for each model's session store (0 = uncapped).
    /// CLI: `--session-mb N` (mebibytes).
    pub session_max_bytes: usize,
    /// Enable the radix prefix cache (share identical prompt-prefix KV
    /// across sequences, CoW).  CLI: `--prefix-cache`.
    pub prefix_cache: bool,
    /// Root directory for the tiered KV store (`None` = memory-only).
    /// CLI: `--store-dir DIR`.  Enables disk spill of cold frozen blocks
    /// and WAL-journaled persistence of detached sessions and prefix
    /// snapshots across restarts.
    pub store_dir: Option<PathBuf>,
    /// Byte cap on the tiered store's page file (`None` = uncapped).
    /// CLI: `--store-max-mb N` (mebibytes; 0 = uncapped, matching
    /// `--pool-mb`).  Over the cap the coldest spilled inventory (prefix
    /// snapshots first, then detached sessions) is evicted LRU.
    pub store_max_bytes: Option<usize>,
    /// Block codec map for frozen KV blocks.  CLI: `--quant int8` (all
    /// layers) or `--quant int8:0,2-5` (those layers only); default fp32
    /// (no quantization).
    pub quant: QuantSpec,
    /// Directory for per-model NDJSON request traces (`None` = in-memory
    /// trace snapshots only).  CLI: `--trace-dir DIR`.
    pub trace_dir: Option<PathBuf>,
    /// Port for the TCP front-end.
    pub port: u16,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            decode_buckets: vec![1, 4],
            prefill_buckets: vec![128, 256, 512],
            max_new_tokens: 72,
            max_queue: 256,
            session_capacity: 64,
            session_ttl_s: 600,
            pool_max_bytes: None,
            session_max_bytes: 0,
            prefix_cache: false,
            store_dir: None,
            store_max_bytes: None,
            quant: QuantSpec::fp32(),
            trace_dir: None,
            port: 7199,
        }
    }
}

impl ServingConfig {
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut c = ServingConfig::default();
        c.max_new_tokens = args.usize_or("max-new", c.max_new_tokens)?;
        c.max_queue = args.usize_or("max-queue", c.max_queue)?;
        c.session_capacity = args.usize_or("sessions", c.session_capacity)?;
        c.session_ttl_s = args.u64_or("session-ttl", c.session_ttl_s)?;
        match args.usize_or("pool-mb", 0)? {
            0 => {} // absent or explicit 0: uncapped, like --session-mb 0
            mb => c.pool_max_bytes = Some(mb * 1024 * 1024),
        }
        c.session_max_bytes = args.usize_or("session-mb", 0)? * 1024 * 1024;
        c.prefix_cache = args.has("prefix-cache");
        c.store_dir = args.get("store-dir").map(PathBuf::from);
        match args.usize_or("store-max-mb", 0)? {
            0 => {} // absent or explicit 0: uncapped, like --pool-mb
            mb => c.store_max_bytes = Some(mb * 1024 * 1024),
        }
        if let Some(q) = args.get("quant") {
            c.quant = QuantSpec::parse(q)?;
        }
        c.trace_dir = args.get("trace-dir").map(PathBuf::from);
        c.port = args.usize_or("port", c.port as usize)? as u16;
        Ok(c)
    }
}

/// Locate the artifacts directory (env LAGKV_ARTIFACTS, --artifacts, or ./artifacts).
pub fn artifacts_dir(args: &Args) -> PathBuf {
    if let Some(p) = args.get("artifacts") {
        return PathBuf::from(p);
    }
    if let Ok(p) = std::env::var("LAGKV_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from("artifacts")
}

pub fn read_json(path: &Path) -> Result<Json> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_roundtrip() {
        for p in PolicyKind::all() {
            assert_eq!(PolicyKind::parse(p.name()).unwrap(), *p);
        }
        assert!(PolicyKind::parse("bogus").is_err());
    }

    #[test]
    fn ratio_labels() {
        let mk = |r| CompressionConfig { ratio: r, ..Default::default() };
        assert_eq!(mk(0.5).ratio_label(), "2x");
        assert_eq!(mk(0.25).ratio_label(), "4x");
        assert_eq!(mk(0.125).ratio_label(), "8x");
    }

    #[test]
    fn keep_per_partition_floor() {
        let c = CompressionConfig { lag: 64, ratio: 0.167, ..Default::default() };
        assert_eq!(c.keep_per_partition(), 10); // floor(10.688)
        let c = CompressionConfig { lag: 8, ratio: 0.01, ..Default::default() };
        assert_eq!(c.keep_per_partition(), 1); // never zero
    }

    #[test]
    fn validation() {
        let bad = CompressionConfig { ratio: 0.0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = CompressionConfig { lag: 0, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn from_args_overrides() {
        let args = Args::parse(
            ["--policy", "h2o", "--lag", "32", "--ratio", "0.25"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let c = CompressionConfig::from_args(&args).unwrap();
        assert_eq!(c.policy, PolicyKind::H2O);
        assert_eq!(c.lag, 32);
        assert_eq!(c.ratio, 0.25);
    }

    #[test]
    fn serving_memory_budget_flags() {
        let args = Args::parse(
            ["--pool-mb", "64", "--session-mb", "8"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let c = ServingConfig::from_args(&args).unwrap();
        assert_eq!(c.pool_max_bytes, Some(64 * 1024 * 1024));
        assert_eq!(c.session_max_bytes, 8 * 1024 * 1024);
        let empty = Args::parse(std::iter::empty::<String>()).unwrap();
        let d = ServingConfig::from_args(&empty).unwrap();
        assert_eq!(d.pool_max_bytes, None, "unbudgeted by default");
        assert_eq!(d.session_max_bytes, 0);
        // an explicit 0 means uncapped (like --session-mb), never a
        // zero-byte budget that would reject everything
        let zero =
            Args::parse(["--pool-mb", "0"].iter().map(|s| s.to_string())).unwrap();
        assert_eq!(ServingConfig::from_args(&zero).unwrap().pool_max_bytes, None);
    }

    #[test]
    fn store_dir_flag() {
        let empty = Args::parse(std::iter::empty::<String>()).unwrap();
        assert_eq!(
            ServingConfig::from_args(&empty).unwrap().store_dir,
            None,
            "memory-only by default"
        );
        let args = Args::parse(
            ["--store-dir", "/tmp/kvstore"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let c = ServingConfig::from_args(&args).unwrap();
        assert_eq!(c.store_dir, Some(PathBuf::from("/tmp/kvstore")));
    }

    #[test]
    fn trace_dir_flag() {
        let empty = Args::parse(std::iter::empty::<String>()).unwrap();
        assert_eq!(
            ServingConfig::from_args(&empty).unwrap().trace_dir,
            None,
            "in-memory tracing by default"
        );
        let args = Args::parse(
            ["--trace-dir", "/tmp/traces"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let c = ServingConfig::from_args(&args).unwrap();
        assert_eq!(c.trace_dir, Some(PathBuf::from("/tmp/traces")));
    }

    #[test]
    fn prefix_cache_flag() {
        let empty = Args::parse(std::iter::empty::<String>()).unwrap();
        assert!(!ServingConfig::from_args(&empty).unwrap().prefix_cache, "off by default");
        let on = Args::parse(["--prefix-cache"].iter().map(|s| s.to_string())).unwrap();
        assert!(ServingConfig::from_args(&on).unwrap().prefix_cache);
    }

    #[test]
    fn quant_flag() {
        let empty = Args::parse(std::iter::empty::<String>()).unwrap();
        assert!(ServingConfig::from_args(&empty).unwrap().quant.is_noop(), "fp32 by default");
        let args =
            Args::parse(["--quant", "int8:0-3"].iter().map(|s| s.to_string())).unwrap();
        let c = ServingConfig::from_args(&args).unwrap();
        assert_eq!(c.quant, QuantSpec::parse("int8:0-3").unwrap());
        let bad = Args::parse(["--quant", "fp16"].iter().map(|s| s.to_string())).unwrap();
        assert!(ServingConfig::from_args(&bad).is_err());
    }

    #[test]
    fn store_cap_flag() {
        let empty = Args::parse(std::iter::empty::<String>()).unwrap();
        assert_eq!(
            ServingConfig::from_args(&empty).unwrap().store_max_bytes,
            None,
            "uncapped by default"
        );
        let args =
            Args::parse(["--store-max-mb", "4"].iter().map(|s| s.to_string())).unwrap();
        assert_eq!(
            ServingConfig::from_args(&args).unwrap().store_max_bytes,
            Some(4 * 1024 * 1024)
        );
        let zero =
            Args::parse(["--store-max-mb", "0"].iter().map(|s| s.to_string())).unwrap();
        assert_eq!(ServingConfig::from_args(&zero).unwrap().store_max_bytes, None);
    }

    #[test]
    fn l2norm_default_skip_layers() {
        let args =
            Args::parse(["--policy", "l2norm"].iter().map(|s| s.to_string())).unwrap();
        let c = CompressionConfig::from_args(&args).unwrap();
        assert_eq!(c.skip_layers, 2);
    }
}

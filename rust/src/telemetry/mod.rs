//! Request-span tracing and latency histograms behind a non-blocking sink.
//!
//! Three pieces, all hermetic:
//!
//! * **Spans** — each request carries a [`SpanBuilder`] through the
//!   router/batcher lifecycle, stamping [`SpanEvent`]s (queued, admitted,
//!   prefill segments, per-token decode steps, compression firings, spill
//!   stalls, terminal state) from a [`Clock`].  Production uses
//!   [`MonotonicClock`]; tests pin exact timelines with [`FakeClock`].
//! * **Sink** — finished spans go through [`EventSink::try_publish`],
//!   which *never blocks the batcher*: a full ring or a contended lock
//!   drops the span and bumps an exact `dropped_events` counter.  A
//!   background flusher drains the ring in batches to an NDJSON trace
//!   file (one span per line) when `--trace-dir` is set; the most recent
//!   spans are always retained in memory for the `trace` op.
//! * **Histograms** — [`Telemetry::finish_span`] derives queue-wait,
//!   TTFT, and inter-token latencies from span deltas; the pool, engine,
//!   and router record spill/fault, compression, and checkpoint
//!   durations directly.  [`HistogramRegistry`] aggregates everything
//!   into integer-microsecond p50/p90/p99 summaries (exact on the wire —
//!   no float round-trip).
//!
//! One [`Telemetry`] hub exists per model; the router builds it and hands
//! `Arc`s to the coordinator, engine, and block pool.

use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write as IoWrite};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::metrics::Histogram;
use crate::util::json::{self, Json};
use crate::util::locked;

// -- clock ---------------------------------------------------------------------

/// Monotonic time source for span timestamps.  Abstracted so hermetic
/// tests can pin exact timelines with [`FakeClock`].
pub trait Clock: Send + Sync {
    /// Microseconds since this clock's origin.  Must be monotone
    /// non-decreasing across threads.
    fn now_us(&self) -> u64;
}

/// Production clock: microseconds since construction, via [`Instant`].
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { origin: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }
}

/// Test clock: time advances only when the test says so.
#[derive(Default)]
pub struct FakeClock {
    now: AtomicU64,
}

impl FakeClock {
    pub fn new() -> FakeClock {
        FakeClock::default()
    }

    pub fn advance_us(&self, us: u64) {
        self.now.fetch_add(us, Ordering::SeqCst);
    }

    pub fn set_us(&self, us: u64) {
        self.now.store(us, Ordering::SeqCst);
    }
}

impl Clock for FakeClock {
    fn now_us(&self) -> u64 {
        self.now.load(Ordering::SeqCst)
    }
}

// -- span model ----------------------------------------------------------------

/// What happened at one point in a request's lifetime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanEventKind {
    /// Accepted into the admission queue (span birth).
    Queued,
    /// Dequeued by the batcher into a slot.
    Admitted,
    /// Session cache restored (detached → live); value = resumed rows.
    SessionResume,
    /// One chunked-prefill segment ingested; value = tokens so far.
    PrefillSegment,
    /// First generated token emitted (TTFT boundary).
    FirstToken,
    /// One decode step appended a token; value = tokens sent so far.
    DecodeStep,
    /// Compression driver fired during this step; value = event count.
    Compression,
    /// Admission stalled on a pool spill; value = bytes demoted.
    SpillStall,
    /// Terminal: completed normally.
    Done,
    /// Terminal: cancelled by the client.
    Cancelled,
    /// Terminal: failed with an error.
    Failed,
}

impl SpanEventKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanEventKind::Queued => "queued",
            SpanEventKind::Admitted => "admitted",
            SpanEventKind::SessionResume => "session_resume",
            SpanEventKind::PrefillSegment => "prefill_segment",
            SpanEventKind::FirstToken => "first_token",
            SpanEventKind::DecodeStep => "decode_step",
            SpanEventKind::Compression => "compression",
            SpanEventKind::SpillStall => "spill_stall",
            SpanEventKind::Done => "done",
            SpanEventKind::Cancelled => "cancelled",
            SpanEventKind::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Result<SpanEventKind> {
        Ok(match s {
            "queued" => SpanEventKind::Queued,
            "admitted" => SpanEventKind::Admitted,
            "session_resume" => SpanEventKind::SessionResume,
            "prefill_segment" => SpanEventKind::PrefillSegment,
            "first_token" => SpanEventKind::FirstToken,
            "decode_step" => SpanEventKind::DecodeStep,
            "compression" => SpanEventKind::Compression,
            "spill_stall" => SpanEventKind::SpillStall,
            "done" => SpanEventKind::Done,
            "cancelled" => SpanEventKind::Cancelled,
            "failed" => SpanEventKind::Failed,
            other => bail!("unknown span event kind {other:?}"),
        })
    }
}

/// One timestamped point on a request's timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Clock microseconds (monotone within a span).
    pub t_us: u64,
    pub kind: SpanEventKind,
    /// Kind-specific payload (see [`SpanEventKind`] docs); 0 when unused.
    pub value: u64,
}

impl SpanEvent {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("t_us", json::n(self.t_us as f64)),
            ("kind", json::s(self.kind.name())),
            ("value", json::n(self.value as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SpanEvent> {
        let m = v.as_obj()?;
        for k in m.keys() {
            if !matches!(k.as_str(), "t_us" | "kind" | "value") {
                bail!("unknown field {k:?} in span event");
            }
        }
        Ok(SpanEvent {
            t_us: v.get("t_us")?.as_i64()? as u64,
            kind: SpanEventKind::parse(v.get("kind")?.as_str()?)?,
            value: v.get("value")?.as_i64()? as u64,
        })
    }
}

/// One request's full timeline: the sink's publish unit, the NDJSON trace
/// file's line unit, and the `trace` op's wire unit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    /// Request id (the coordinator's handle id).
    pub id: u64,
    pub events: Vec<SpanEvent>,
}

impl Span {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("id", json::n(self.id as f64)),
            ("events", json::arr(self.events.iter().map(|e| e.to_json()).collect())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Span> {
        let m = v.as_obj()?;
        for k in m.keys() {
            if !matches!(k.as_str(), "id" | "events") {
                bail!("unknown field {k:?} in span");
            }
        }
        let events = v
            .get("events")?
            .as_arr()?
            .iter()
            .map(SpanEvent::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Span { id: v.get("id")?.as_i64()? as u64, events })
    }

    /// Timestamp of the first event of `kind`.
    pub fn first(&self, kind: SpanEventKind) -> Option<&SpanEvent> {
        self.events.iter().find(|e| e.kind == kind)
    }
}

/// The per-request recorder the router creates and the batcher stamps.
/// Disabled builders (no clock) make every record a no-op, so code paths
/// without a telemetry hub pay nothing and need no `Option` plumbing.
pub struct SpanBuilder {
    clock: Option<Arc<dyn Clock>>,
    span: Span,
}

impl SpanBuilder {
    /// A recorder that ignores everything (direct-fed coordinators,
    /// tests that don't care about tracing).
    pub fn disabled() -> SpanBuilder {
        SpanBuilder { clock: None, span: Span { id: 0, events: Vec::new() } }
    }

    pub fn is_enabled(&self) -> bool {
        self.clock.is_some()
    }

    /// Current clock reading, for callers that time an operation and
    /// record its duration as the event value.  0 when disabled.
    pub fn now_us(&self) -> u64 {
        self.clock.as_ref().map(|c| c.now_us()).unwrap_or(0)
    }

    pub fn record(&mut self, kind: SpanEventKind) {
        self.record_v(kind, 0);
    }

    pub fn record_v(&mut self, kind: SpanEventKind, value: u64) {
        if let Some(clock) = &self.clock {
            self.span.events.push(SpanEvent { t_us: clock.now_us(), kind, value });
        }
    }

    pub fn events(&self) -> &[SpanEvent] {
        &self.span.events
    }
}

// -- event sink ----------------------------------------------------------------

/// In-memory depth of the publish ring: spans the flusher has not yet
/// drained.  Beyond it, publishes drop (and are counted) — the batcher is
/// never back-pressured by a slow trace consumer.
pub const DEFAULT_SINK_CAPACITY: usize = 256;

/// Finished spans retained in memory for `trace` snapshots.
pub const DEFAULT_RECENT_CAPACITY: usize = 64;

struct SinkInner {
    /// Published but not yet drained.
    ring: VecDeque<Span>,
    /// Most recently drained spans (the live snapshot).
    recent: VecDeque<Span>,
    /// NDJSON trace file, when tracing to disk is enabled.
    file: Option<BufWriter<File>>,
}

/// Bounded, non-blocking span sink.
///
/// Contract: [`EventSink::try_publish`] takes the inner lock with
/// `try_lock` and refuses (rather than waits) when the lock is contended
/// or the ring is full; every refusal increments `dropped_events`
/// exactly once.  Draining (flusher thread, or any snapshot request)
/// moves the ring into the bounded `recent` window and appends each
/// drained span as one NDJSON line to the trace file.
pub struct EventSink {
    inner: Mutex<SinkInner>,
    capacity: usize,
    recent_capacity: usize,
    published: AtomicU64,
    dropped: AtomicU64,
}

impl EventSink {
    pub fn new(capacity: usize, recent_capacity: usize, file: Option<File>) -> EventSink {
        EventSink {
            inner: Mutex::new(SinkInner {
                ring: VecDeque::with_capacity(capacity.min(1024)),
                recent: VecDeque::with_capacity(recent_capacity.min(1024)),
                file: file.map(BufWriter::new),
            }),
            capacity,
            recent_capacity,
            published: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Publish a finished span without ever blocking: a contended lock or
    /// a full ring drops the span and bumps the exact drop counter.
    pub fn try_publish(&self, span: Span) -> bool {
        if let Ok(mut inner) = self.inner.try_lock() {
            if inner.ring.len() < self.capacity {
                inner.ring.push_back(span);
                self.published.fetch_add(1, Ordering::Relaxed);
                return true;
            }
        }
        self.dropped.fetch_add(1, Ordering::Relaxed);
        false
    }

    /// Batch-drain the ring: retain drained spans in the `recent` window
    /// and append them to the NDJSON trace file.  Returns how many spans
    /// were drained.  Called from the flusher thread and forced before
    /// every snapshot so `trace` responses are deterministic.
    pub fn drain(&self) -> usize {
        let mut inner = locked(&self.inner);
        let inner = &mut *inner;
        let drained = inner.ring.len();
        if drained == 0 {
            return 0;
        }
        let mut write_err = false;
        while let Some(span) = inner.ring.pop_front() {
            if let Some(file) = inner.file.as_mut() {
                write_err |= writeln!(file, "{}", span.to_json().to_string()).is_err();
            }
            if inner.recent.len() == self.recent_capacity {
                inner.recent.pop_front();
            }
            inner.recent.push_back(span);
        }
        if let Some(file) = inner.file.as_mut() {
            write_err |= file.flush().is_err();
        }
        if write_err {
            // Tracing must never take down serving; drop the writer and
            // keep serving in-memory snapshots.
            eprintln!("telemetry: trace file write failed; disabling file tracing");
            inner.file = None;
        }
        drained
    }

    /// The most recently drained spans, oldest first (drains first so the
    /// snapshot includes everything published so far).
    pub fn recent(&self) -> Vec<Span> {
        self.drain();
        let inner = locked(&self.inner);
        inner.recent.iter().cloned().collect()
    }

    /// Spans accepted by `try_publish` so far.
    pub fn published(&self) -> u64 {
        self.published.load(Ordering::Relaxed)
    }

    /// Spans refused by `try_publish` so far — exact, never sampled.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

// -- histogram registry --------------------------------------------------------

/// The latency families the registry aggregates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Queued → first generated token.
    Ttft,
    /// Between successive generated tokens.
    InterToken,
    /// Queued → admitted into a slot.
    QueueWait,
    /// One chunked-prefill segment (ingest + driver pass).
    PrefillSegment,
    /// One compression-driver pass that fired at least one event.
    Compression,
    /// One `KvStore::checkpoint`.
    Checkpoint,
    /// One block demotion (pool → disk).
    Spill,
    /// One block fault-in (disk → pool).
    Fault,
    /// One block codec operation: encode-at-freeze or decode-at-read.
    Quant,
}

impl Metric {
    pub fn name(&self) -> &'static str {
        match self {
            Metric::Ttft => "ttft",
            Metric::InterToken => "inter_token",
            Metric::QueueWait => "queue_wait",
            Metric::PrefillSegment => "prefill_segment",
            Metric::Compression => "compression",
            Metric::Checkpoint => "checkpoint",
            Metric::Spill => "spill",
            Metric::Fault => "fault",
            Metric::Quant => "quantized",
        }
    }

    pub fn parse(s: &str) -> Result<Metric> {
        for m in Metric::all() {
            if m.name() == s {
                return Ok(*m);
            }
        }
        bail!("unknown metric {s:?}")
    }

    pub fn all() -> &'static [Metric] {
        &[
            Metric::Ttft,
            Metric::InterToken,
            Metric::QueueWait,
            Metric::PrefillSegment,
            Metric::Compression,
            Metric::Checkpoint,
            Metric::Spill,
            Metric::Fault,
            Metric::Quant,
        ]
    }
}

/// Wire/snapshot form of one metric's histogram: integer microseconds so
/// the v1 round-trip is exact (no f64 printing in the hot contract).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistogramSummary {
    pub metric: Metric,
    pub count: u64,
    pub p50_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
}

impl HistogramSummary {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("metric", json::s(self.metric.name())),
            ("count", json::n(self.count as f64)),
            ("p50_us", json::n(self.p50_us as f64)),
            ("p90_us", json::n(self.p90_us as f64)),
            ("p99_us", json::n(self.p99_us as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<HistogramSummary> {
        let m = v.as_obj()?;
        for k in m.keys() {
            if !matches!(k.as_str(), "metric" | "count" | "p50_us" | "p90_us" | "p99_us") {
                bail!("unknown field {k:?} in histogram summary");
            }
        }
        Ok(HistogramSummary {
            metric: Metric::parse(v.get("metric")?.as_str()?)?,
            count: v.get("count")?.as_i64()? as u64,
            p50_us: v.get("p50_us")?.as_i64()? as u64,
            p90_us: v.get("p90_us")?.as_i64()? as u64,
            p99_us: v.get("p99_us")?.as_i64()? as u64,
        })
    }
}

/// One [`Histogram`] per [`Metric`], summarized as p50/p90/p99.
pub struct HistogramRegistry {
    hists: Vec<Mutex<Histogram>>,
    dropped_samples: AtomicU64,
}

impl HistogramRegistry {
    pub fn new() -> HistogramRegistry {
        HistogramRegistry {
            hists: Metric::all().iter().map(|_| Mutex::new(Histogram::default())).collect(),
            dropped_samples: AtomicU64::new(0),
        }
    }

    /// Record one sample without ever blocking the caller: `record` sits
    /// on the span-finish path, so a contended histogram drops the sample
    /// and bumps the exact drop counter instead of waiting.
    pub fn record(&self, metric: Metric, us: u64) {
        // `Metric::all` lists variants in declaration order, so the enum
        // discriminant doubles as the registry index.
        let idx = metric as usize;
        if let Ok(mut hist) = self.hists[idx].try_lock() {
            hist.record_us(us);
        } else {
            self.dropped_samples.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Samples refused by `record` under lock contention — exact.
    pub fn dropped_samples(&self) -> u64 {
        self.dropped_samples.load(Ordering::Relaxed)
    }

    /// Summaries of every metric with at least one sample, in
    /// [`Metric::all`] order.
    pub fn summaries(&self) -> Vec<HistogramSummary> {
        Metric::all()
            .iter()
            .zip(&self.hists)
            .filter_map(|(metric, hist)| {
                let mut hist = locked(hist);
                if hist.is_empty() {
                    return None;
                }
                Some(HistogramSummary {
                    metric: *metric,
                    count: hist.count() as u64,
                    p50_us: hist.quantile_us(0.50),
                    p90_us: hist.quantile_us(0.90),
                    p99_us: hist.quantile_us(0.99),
                })
            })
            .collect()
    }
}

impl Default for HistogramRegistry {
    fn default() -> Self {
        HistogramRegistry::new()
    }
}

// -- hub -----------------------------------------------------------------------

/// How often the flusher thread drains the sink to the trace file.
const FLUSH_INTERVAL: std::time::Duration = std::time::Duration::from_millis(50);

#[derive(Debug, Clone, Default)]
pub struct TelemetryConfig {
    /// Write one NDJSON trace file per model under this directory
    /// (`<model>.trace.ndjson`).  `None` = in-memory snapshots only.
    pub trace_dir: Option<PathBuf>,
}

/// Per-model telemetry hub: clock + sink + histogram registry.  The
/// router builds one per model and shares it with the coordinator,
/// engine, and block pool.
pub struct Telemetry {
    clock: Arc<dyn Clock>,
    sink: Arc<EventSink>,
    hists: HistogramRegistry,
    next_id: AtomicU64,
}

impl Telemetry {
    /// Production hub.  When `trace_dir` is set, opens the model's trace
    /// file and spawns the batch flusher (which exits on its own once the
    /// sink is dropped).
    pub fn new(cfg: &TelemetryConfig, model: &str) -> Result<Telemetry> {
        let file = match &cfg.trace_dir {
            None => None,
            Some(dir) => {
                std::fs::create_dir_all(dir)?;
                Some(File::create(trace_path(dir, model))?)
            }
        };
        let sink =
            Arc::new(EventSink::new(DEFAULT_SINK_CAPACITY, DEFAULT_RECENT_CAPACITY, file));
        if cfg.trace_dir.is_some() {
            spawn_flusher(Arc::downgrade(&sink), model);
        }
        Ok(Telemetry {
            clock: Arc::new(MonotonicClock::new()),
            sink,
            hists: HistogramRegistry::new(),
            next_id: AtomicU64::new(1),
        })
    }

    /// Hermetic hub on a caller-controlled clock; no file, no flusher.
    pub fn with_clock(clock: Arc<dyn Clock>) -> Telemetry {
        Telemetry {
            clock,
            sink: Arc::new(EventSink::new(DEFAULT_SINK_CAPACITY, DEFAULT_RECENT_CAPACITY, None)),
            hists: HistogramRegistry::new(),
            next_id: AtomicU64::new(1),
        }
    }

    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    pub fn now_us(&self) -> u64 {
        self.clock.now_us()
    }

    pub fn sink(&self) -> &Arc<EventSink> {
        &self.sink
    }

    /// Begin a request span: allocates an id (overridden by the router
    /// with the request's handle id once known) and stamps `Queued`.
    pub fn begin_span(&self, id: u64) -> SpanBuilder {
        let id = if id != 0 { id } else { self.next_id.fetch_add(1, Ordering::Relaxed) };
        let mut b = SpanBuilder { clock: Some(Arc::clone(&self.clock)), span: Span { id, events: Vec::new() } };
        b.record(SpanEventKind::Queued);
        b
    }

    /// Stamp the terminal event, derive the span-delta histograms
    /// (queue wait, TTFT, inter-token), and publish — non-blocking.
    pub fn finish_span(&self, mut builder: SpanBuilder, terminal: SpanEventKind) {
        if !builder.is_enabled() {
            return;
        }
        builder.record(terminal);
        let span = builder.span;
        let queued = span.first(SpanEventKind::Queued).map(|e| e.t_us);
        if let (Some(q), Some(a)) = (queued, span.first(SpanEventKind::Admitted)) {
            self.record(Metric::QueueWait, a.t_us.saturating_sub(q));
        }
        if let (Some(q), Some(f)) = (queued, span.first(SpanEventKind::FirstToken)) {
            self.record(Metric::Ttft, f.t_us.saturating_sub(q));
        }
        let mut prev_token: Option<u64> = span.first(SpanEventKind::FirstToken).map(|e| e.t_us);
        for ev in &span.events {
            if ev.kind == SpanEventKind::DecodeStep {
                if let Some(prev) = prev_token {
                    self.record(Metric::InterToken, ev.t_us.saturating_sub(prev));
                }
                prev_token = Some(ev.t_us);
            }
        }
        self.sink.try_publish(span);
    }

    pub fn record(&self, metric: Metric, us: u64) {
        self.hists.record(metric, us);
    }

    pub fn summaries(&self) -> Vec<HistogramSummary> {
        self.hists.summaries()
    }

    /// Live snapshot: drains the sink first so every span finished before
    /// this call is visible.
    pub fn recent_spans(&self) -> Vec<Span> {
        self.sink.recent()
    }

    pub fn dropped_events(&self) -> u64 {
        self.sink.dropped()
    }
}

/// The model's NDJSON trace file path under a trace dir.
pub fn trace_path(dir: &Path, model: &str) -> PathBuf {
    dir.join(format!("{model}.trace.ndjson"))
}

fn spawn_flusher(sink: Weak<EventSink>, model: &str) {
    let name = format!("lagkv-trace-{model}");
    let spawn = std::thread::Builder::new().name(name).spawn(move || loop {
        std::thread::sleep(FLUSH_INTERVAL);
        match sink.upgrade() {
            Some(sink) => {
                sink.drain();
            }
            None => break, // hub dropped: exit quietly
        }
    });
    if let Err(e) = spawn {
        eprintln!("telemetry: failed to spawn trace flusher: {e}");
    }
}

impl Drop for Telemetry {
    fn drop(&mut self) {
        // Final batch flush so short-lived processes lose nothing.
        self.sink.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_hub() -> (Arc<FakeClock>, Telemetry) {
        let clock = Arc::new(FakeClock::new());
        let tel = Telemetry::with_clock(Arc::clone(&clock) as Arc<dyn Clock>);
        (clock, tel)
    }

    #[test]
    fn span_deltas_feed_the_registry() {
        let (clock, tel) = fake_hub();
        let mut b = tel.begin_span(7); // Queued at t=0
        clock.advance_us(100);
        b.record(SpanEventKind::Admitted);
        clock.advance_us(400);
        b.record(SpanEventKind::FirstToken);
        clock.advance_us(30);
        b.record_v(SpanEventKind::DecodeStep, 1);
        clock.advance_us(50);
        b.record_v(SpanEventKind::DecodeStep, 2);
        tel.finish_span(b, SpanEventKind::Done);

        let spans = tel.recent_spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].id, 7);
        let summaries = tel.summaries();
        let get = |m: Metric| summaries.iter().find(|s| s.metric == m).unwrap();
        assert_eq!(get(Metric::QueueWait).p50_us, 100);
        assert_eq!(get(Metric::Ttft).p50_us, 500);
        let it = get(Metric::InterToken);
        assert_eq!(it.count, 2, "first-token→step and step→step");
        assert_eq!(it.p50_us, 30);
        assert_eq!(it.p99_us, 50);
        assert_eq!(tel.dropped_events(), 0);
    }

    #[test]
    fn timestamps_are_monotone_under_a_fake_clock() {
        let (clock, tel) = fake_hub();
        let mut b = tel.begin_span(1);
        for i in 0..5 {
            clock.advance_us(10);
            b.record_v(SpanEventKind::PrefillSegment, i);
        }
        tel.finish_span(b, SpanEventKind::Done);
        let span = &tel.recent_spans()[0];
        for w in span.events.windows(2) {
            assert!(w[0].t_us <= w[1].t_us, "monotone timeline");
        }
        assert_eq!(span.events.first().unwrap().kind, SpanEventKind::Queued);
        assert_eq!(span.events.last().unwrap().kind, SpanEventKind::Done);
    }

    #[test]
    fn sink_full_drops_exactly_and_never_blocks() {
        let sink = EventSink::new(4, 4, None);
        for i in 0..10 {
            sink.try_publish(Span { id: i, events: Vec::new() });
        }
        assert_eq!(sink.published(), 4);
        assert_eq!(sink.dropped(), 6, "drops counted exactly");
        assert_eq!(sink.drain(), 4);
        // ring drained: publishes flow again, recent window is bounded
        for i in 10..16 {
            sink.try_publish(Span { id: i, events: Vec::new() });
        }
        let recent = sink.recent();
        assert_eq!(recent.len(), 4, "recent window bounded");
        assert_eq!(recent.last().unwrap().id, 13, "ring capacity bounds the second burst");
        assert_eq!(sink.dropped(), 8);
    }

    #[test]
    fn try_publish_refuses_under_contention() {
        let sink = EventSink::new(16, 16, None);
        let guard = sink.inner.lock().unwrap();
        assert!(!sink.try_publish(Span { id: 1, events: Vec::new() }), "contended lock refuses");
        assert_eq!(sink.dropped(), 1);
        drop(guard);
        assert!(sink.try_publish(Span { id: 1, events: Vec::new() }));
    }

    #[test]
    fn span_json_round_trips_exactly() {
        let span = Span {
            id: 42,
            events: vec![
                SpanEvent { t_us: 0, kind: SpanEventKind::Queued, value: 0 },
                SpanEvent { t_us: 10, kind: SpanEventKind::Admitted, value: 0 },
                SpanEvent { t_us: 25, kind: SpanEventKind::PrefillSegment, value: 64 },
                SpanEvent { t_us: 30, kind: SpanEventKind::Compression, value: 2 },
                SpanEvent { t_us: 44, kind: SpanEventKind::Done, value: 0 },
            ],
        };
        let text = span.to_json().to_string();
        let back = Span::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, span);
        assert_eq!(back.to_json().to_string(), text);
        // strictness: an unknown field is a hard error
        let spiked = text.replace("\"id\":", "\"bogus\":1,\"id\":");
        assert!(Span::from_json(&Json::parse(&spiked).unwrap()).is_err());
    }

    #[test]
    fn histogram_summary_json_round_trips() {
        let s = HistogramSummary {
            metric: Metric::Ttft,
            count: 12,
            p50_us: 1500,
            p90_us: 4000,
            p99_us: 9000,
        };
        let text = s.to_json().to_string();
        let back = HistogramSummary::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
        for m in Metric::all() {
            assert_eq!(Metric::parse(m.name()).unwrap(), *m);
        }
        assert!(Metric::parse("bogus").is_err());
    }

    #[test]
    fn trace_file_gets_ndjson_lines() {
        let dir = crate::kvstore::testutil::TempDir::new("trace");
        let cfg = TelemetryConfig { trace_dir: Some(dir.path().to_path_buf()) };
        let tel = Telemetry::new(&cfg, "toy").unwrap();
        let b = tel.begin_span(1);
        tel.finish_span(b, SpanEventKind::Done);
        let b = tel.begin_span(2);
        tel.finish_span(b, SpanEventKind::Cancelled);
        tel.sink().drain();
        let text = std::fs::read_to_string(trace_path(dir.path(), "toy")).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let s0 = Span::from_json(&Json::parse(lines[0]).unwrap()).unwrap();
        assert_eq!(s0.id, 1);
        assert_eq!(s0.events.last().unwrap().kind, SpanEventKind::Done);
        let s1 = Span::from_json(&Json::parse(lines[1]).unwrap()).unwrap();
        assert_eq!(s1.events.last().unwrap().kind, SpanEventKind::Cancelled);
    }

    #[test]
    fn disabled_builder_is_free_and_silent() {
        let mut b = SpanBuilder::disabled();
        b.record(SpanEventKind::Admitted);
        b.record_v(SpanEventKind::DecodeStep, 3);
        assert!(b.events().is_empty());
        assert!(!b.is_enabled());
        let (_, tel) = fake_hub();
        tel.finish_span(b, SpanEventKind::Done);
        assert!(tel.recent_spans().is_empty(), "disabled spans are never published");
    }
}

//! Multi-model router: one coordinator thread per model variant, a shared
//! handle for clients (in-proc or the TCP server).
//!
//! Engine handles may not be `Send` (the PJRT client wraps its state in
//! `Rc`), so each coordinator thread constructs its own [`Engine`] from a
//! plain-data [`EngineSpec`] and the router moves only [`WorkItem`]s across
//! threads.  The spec also carries the backend choice, so a router can
//! serve the hermetic CPU reference backend and the XLA artifact backend
//! with identical plumbing.
//!
//! [`Router::submit`] is the streaming entry point: it returns a
//! [`GenHandle`] whose receiver yields live [`Event`]s.  [`Router::generate`]
//! folds the stream back into a [`Response`] for one-shot callers.  The
//! admission queue is bounded ([`RouterConfig::queue_depth`]); a full queue
//! is a typed [`ApiError::QueueFull`] instead of unbounded memory growth.
//!
//! [`Engine`]: crate::engine::Engine

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::api::ModelInfo;
use crate::backend::EngineSpec;
use crate::kvcache::KvCache;
use crate::kvpool::{Block, BlockPool, PrefixCache, PrefixConfig};
use crate::kvstore::{CheckpointSummary, KvStore};
use crate::quant::QuantSpec;
use crate::telemetry::{Metric, SpanBuilder, Telemetry, TelemetryConfig};

use super::{
    ApiError, CoordStats, Coordinator, Event, Request, Response, SessionConfig, SessionStore,
    WorkItem,
};

/// A session store shared between one coordinator thread and the control
/// plane (`sessions` op).
pub type SharedSessionStore = Arc<Mutex<SessionStore>>;

/// Engine facts published by a coordinator thread once its engine load
/// settles: `None` = still loading, `Some(None)` = load failed,
/// `Some(Some(info))` = loaded.
type InfoSlot = Arc<Mutex<Option<Option<ModelInfo>>>>;

/// Per-coordinator serving knobs.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Bounded admission-queue depth per model; a full queue rejects with
    /// [`ApiError::QueueFull`].
    pub queue_depth: usize,
    pub sessions: SessionConfig,
    /// Byte budget for each model's KV block pool (`None` = unbudgeted).
    /// Under a budget the coordinator reclaims sheddable bytes before
    /// admitting work — prefix-cache snapshots first, then LRU sessions —
    /// and rejects with [`ApiError::PoolExhausted`] when even that leaves
    /// no room; the router additionally refuses to enqueue while the pool
    /// is under hard pressure.
    pub pool_max_bytes: Option<usize>,
    /// Radix prefix cache over each model's block pool (`None` = off;
    /// `--prefix-cache` enables the defaults): identical prompt prefixes
    /// are shared CoW across sequences, so a warm prefix costs zero deep
    /// copies and only the unmatched suffix runs on the backend.
    pub prefix_cache: Option<PrefixConfig>,
    /// Root directory for the tiered KV store (`--store-dir`; `None` =
    /// memory-only).  Each variant opens `<dir>/<variant>`: frozen blocks
    /// can then spill to disk under pool pressure, detached sessions and
    /// prefix snapshots are WAL-journaled, and boot replays the journal so
    /// both survive a restart without re-prefilling.
    pub store_dir: Option<PathBuf>,
    /// Byte cap on each variant's disk store (`--store-max-mb`; `None` =
    /// uncapped).  Over the cap the store evicts its coldest spilled
    /// inventory LRU — prefix snapshots first, then detached sessions —
    /// appending tombstones so replay never resurrects evicted payloads.
    pub store_max_bytes: Option<usize>,
    /// Block codec map installed on every engine (`--quant int8[:layers]`;
    /// default fp32 = no quantization).  Frozen blocks on selected layers
    /// encode through it; reads decode transparently.
    pub quant: QuantSpec,
    /// Write per-model NDJSON request traces under this directory
    /// (`--trace-dir`; `None` = in-memory trace snapshots only).  Spans
    /// publish through a bounded non-blocking sink either way; the
    /// directory only adds the background file flusher.
    pub trace_dir: Option<PathBuf>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            queue_depth: 256,
            sessions: SessionConfig::default(),
            pool_max_bytes: None,
            prefix_cache: None,
            store_dir: None,
            store_max_bytes: None,
            quant: QuantSpec::fp32(),
            trace_dir: None,
        }
    }
}

/// A live generation: the event receiver plus its cancel flag.  Dropping
/// the handle aborts the request (the coordinator notices the dead channel
/// at the next event it emits); [`GenHandle::cancel`] aborts it explicitly.
pub struct GenHandle {
    pub id: u64,
    pub events: mpsc::Receiver<Event>,
    cancel: Arc<AtomicBool>,
}

impl GenHandle {
    /// Ask the coordinator to abort this request at the next step boundary.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// The shared cancel flag (the server keeps one per live request so a
    /// `{"cancel": id}` line — possibly on another connection — can abort).
    pub fn cancel_flag(&self) -> Arc<AtomicBool> {
        self.cancel.clone()
    }

    /// Block until the stream terminates and fold it into a [`Response`].
    pub fn wait(self) -> Response {
        Response::from_events(self.events)
    }
}

pub struct Router {
    senders: HashMap<String, SyncSender<WorkItem>>,
    stats: HashMap<String, Arc<CoordStats>>,
    pools: HashMap<String, Arc<BlockPool>>,
    prefixes: HashMap<String, Arc<PrefixCache>>,
    /// Per-model disk stores, when the router was started with
    /// [`RouterConfig::store_dir`] (the `checkpoint` op flushes through
    /// these).
    stores: HashMap<String, Arc<KvStore>>,
    /// Per-model session stores, shared with the coordinator threads so
    /// the control plane (`sessions` op) can list/delete entries.
    sessions: HashMap<String, SharedSessionStore>,
    /// Engine facts published by each coordinator thread once its engine
    /// loads (`None` until then, or forever if the load failed) — the
    /// control plane's `info` op reads these.
    infos: HashMap<String, InfoSlot>,
    /// Per-model telemetry hubs: request spans, the non-blocking trace
    /// sink, and the latency histogram registry (the `trace` op reads
    /// these; `stats` folds in the histogram summaries).
    telemetry: HashMap<String, Arc<Telemetry>>,
    cfg: RouterConfig,
    /// Once set, admission is closed: every submit is a typed `draining`
    /// rejection while in-flight work runs to completion.
    draining: AtomicBool,
    threads: Vec<JoinHandle<()>>,
}

impl Router {
    /// Spin up one coordinator thread per model variant with default
    /// serving knobs.
    pub fn start(spec: EngineSpec, variants: &[String]) -> Router {
        Router::start_with(spec, variants, RouterConfig::default())
    }

    /// Spin up one coordinator thread per model variant.  Engine loading
    /// happens inside the thread; a variant that fails to load answers all
    /// of its requests with `engine-failure` instead of killing the router.
    pub fn start_with(spec: EngineSpec, variants: &[String], cfg: RouterConfig) -> Router {
        let mut senders = HashMap::new();
        let mut stats = HashMap::new();
        let mut pools = HashMap::new();
        let mut prefixes = HashMap::new();
        let mut sessions = HashMap::new();
        let mut stores = HashMap::new();
        let mut infos = HashMap::new();
        let mut telemetry = HashMap::new();
        let mut threads = Vec::new();
        let tel_cfg = TelemetryConfig { trace_dir: cfg.trace_dir.clone() };
        let quant = Arc::new(cfg.quant.clone());
        for variant in variants {
            let (tx, rx) = mpsc::sync_channel::<WorkItem>(cfg.queue_depth.max(1));
            senders.insert(variant.clone(), tx);
            let coord_stats = Arc::new(CoordStats::default());
            stats.insert(variant.clone(), coord_stats.clone());
            let pool = BlockPool::new(BlockPool::DEFAULT_ROWS_PER_BLOCK, cfg.pool_max_bytes);
            pools.insert(variant.clone(), pool.clone());
            // Telemetry hub: spans, the non-blocking sink, and the latency
            // registry.  An unwritable trace dir degrades to in-memory
            // tracing — observability must never take down serving.
            let tel = Telemetry::new(&tel_cfg, variant).unwrap_or_else(|e| {
                eprintln!("trace file for {variant} failed to open ({e:#}); tracing in-memory");
                // lint: allow(panic): the default config has no trace dir, so
                // this constructor performs no I/O and cannot fail
                Telemetry::new(&TelemetryConfig::default(), variant).expect("memory-only telemetry cannot fail")
            });
            let tel = Arc::new(tel);
            telemetry.insert(variant.clone(), Arc::clone(&tel));
            pool.set_telemetry(Arc::clone(&tel));
            // Constructed here (not inside the engine) so gauges stay
            // readable from outside the coordinator thread.
            let prefix = cfg
                .prefix_cache
                .clone()
                .map(|pc| PrefixCache::new(pc, pool.clone()));
            if let Some(pc) = &prefix {
                prefixes.insert(variant.clone(), Arc::clone(pc));
            }
            let store = Arc::new(Mutex::new(SessionStore::new(cfg.sessions.clone())));
            sessions.insert(variant.clone(), Arc::clone(&store));
            // Tiered storage opt-in: open this variant's disk store, bind
            // it to the pool (spill/fault) and both journaling layers,
            // then replay the journal so detached sessions and prefix
            // snapshots from the previous run serve without re-prefilling.
            if let Some(root) = &cfg.store_dir {
                match KvStore::open_with_cap(&root.join(variant), cfg.store_max_bytes) {
                    Ok(kv) => {
                        let kv = Arc::new(kv);
                        pool.bind_store(Arc::clone(&kv));
                        crate::util::locked(&store).bind_journal(Arc::clone(&kv));
                        if let Some(pc) = &prefix {
                            pc.bind_journal(Arc::clone(&kv));
                        }
                        restore_inventory(&kv, &pool, &store, prefix.as_deref());
                        stores.insert(variant.clone(), kv);
                    }
                    Err(e) => eprintln!(
                        "store for {variant} failed to open ({e:#}); serving memory-only"
                    ),
                }
            }
            let info_slot: InfoSlot = Arc::new(Mutex::new(None));
            infos.insert(variant.clone(), Arc::clone(&info_slot));
            let spec = spec.clone();
            let name = variant.clone();
            let quant = Arc::clone(&quant);
            threads.push(std::thread::spawn(move || match spec.build(&name) {
                Ok(mut engine) => {
                    engine.set_pool(pool);
                    if let Some(pc) = prefix {
                        engine.set_prefix_cache(pc);
                    }
                    engine.set_telemetry(Arc::clone(&tel));
                    engine.set_quant(quant);
                    // Publish the engine facts the `info` op self-configures
                    // clients from, before the first request is served.
                    *crate::util::locked(&info_slot) = Some(Some(ModelInfo {
                        model: name.clone(),
                        prefill_buckets: engine.backend().prefill_buckets().to_vec(),
                        decode_buckets: engine.decode_buckets().to_vec(),
                        max_prompt_tokens: engine.max_prompt_tokens(),
                        tmax: engine.tmax,
                        pool_budget_bytes: engine.pool().budget(),
                    }));
                    let mut coord = Coordinator::with_store(engine, store, coord_stats);
                    coord.set_telemetry(tel);
                    if let Err(e) = coord.run(rx) {
                        eprintln!("coordinator {name} died: {e:#}");
                    }
                }
                Err(e) => {
                    // Tombstone: the `info` op's settle-wait must be able
                    // to tell "load failed" from "still loading", or every
                    // info call would stall its full deadline.
                    *crate::util::locked(&info_slot) = Some(None);
                    let error = ApiError::EngineFailure {
                        message: format!("engine {name} failed to load: {e:#}"),
                    };
                    eprintln!("{error}");
                    // Each drained item's RAII queue token releases the
                    // `queued` gauge when the item drops at scope end.
                    while let Ok(item) = rx.recv() {
                        let _ = item.events.send(Event::Error {
                            id: item.request.id,
                            error: error.clone(),
                        });
                    }
                }
            }));
        }
        Router {
            senders,
            stats,
            pools,
            prefixes,
            stores,
            sessions,
            infos,
            telemetry,
            cfg,
            draining: AtomicBool::new(false),
            threads,
        }
    }

    pub fn models(&self) -> Vec<String> {
        self.senders.keys().cloned().collect()
    }

    /// This model's liveness counters (completed/cancelled/failed).
    pub fn stats(&self, model: &str) -> Option<Arc<CoordStats>> {
        self.stats.get(model).cloned()
    }

    /// This model's KV block pool (occupancy gauges, admission state).
    pub fn pool(&self, model: &str) -> Option<Arc<BlockPool>> {
        self.pools.get(model).cloned()
    }

    /// This model's radix prefix cache (hit/miss/shared-byte gauges), when
    /// the router was started with one.
    pub fn prefix_cache(&self, model: &str) -> Option<Arc<PrefixCache>> {
        self.prefixes.get(model).cloned()
    }

    /// This model's session store (the control plane's `sessions` op
    /// lists/deletes entries through it; the coordinator thread shares it).
    pub fn session_store(&self, model: &str) -> Option<SharedSessionStore> {
        self.sessions.get(model).cloned()
    }

    /// This model's disk store, when the router was started with a
    /// [`RouterConfig::store_dir`].
    pub fn store(&self, model: &str) -> Option<Arc<KvStore>> {
        self.stores.get(model).cloned()
    }

    /// This model's telemetry hub (recent request spans, drop counter,
    /// latency histogram summaries) — the `trace` op reads it.
    pub fn telemetry(&self, model: &str) -> Option<Arc<Telemetry>> {
        self.telemetry.get(model).cloned()
    }

    /// Checkpoint every variant's disk store: re-journal the live session
    /// and prefix inventory, fsync, and compact the WAL to it.  Variants
    /// without a store are skipped; results come back sorted by model
    /// name so the `checkpoint` op's output is deterministic.
    pub fn checkpoint(&self) -> Vec<(String, Result<CheckpointSummary>)> {
        let mut out: Vec<(String, Result<CheckpointSummary>)> = self
            .stores
            .iter()
            .map(|(name, kv)| {
                let res = kv.checkpoint();
                if let (Ok(summary), Some(tel)) = (&res, self.telemetry.get(name)) {
                    tel.record(Metric::Checkpoint, summary.elapsed_us);
                }
                (name.clone(), res)
            })
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Engine facts for this model, once its coordinator thread has loaded
    /// the engine (`None` while loading, or forever if the load failed).
    pub fn model_info(&self, model: &str) -> Option<ModelInfo> {
        self.infos.get(model).and_then(|slot| crate::util::locked(slot).clone().flatten())
    }

    /// Whether this model's engine load has settled (loaded *or* failed) —
    /// the `info` op waits on this, never on a failed load.
    pub fn model_settled(&self, model: &str) -> bool {
        self.infos.get(model).map(|slot| crate::util::locked(slot).is_some()).unwrap_or(true)
    }

    /// The serving knobs this router was started with.
    pub fn config(&self) -> &RouterConfig {
        &self.cfg
    }

    /// Close admission: every subsequent submit is a typed `draining`
    /// rejection while in-flight and already-queued work runs to
    /// completion.  Reversible — [`Router::undrain`] reopens admission, so
    /// a rolling restart that changes its mind keeps the warm process.
    pub fn drain(&self) {
        self.draining.store(true, Ordering::Relaxed);
    }

    /// Reopen admission after a [`Router::drain`].  A no-op when the
    /// router is not draining.
    pub fn undrain(&self) {
        self.draining.store(false, Ordering::Relaxed);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    /// Submit a request; returns the live event stream.
    pub fn submit(&self, model: &str, request: Request) -> Result<GenHandle, ApiError> {
        let tx = self.senders.get(model).ok_or_else(|| ApiError::UnknownModel {
            model: model.to_string(),
            have: self.models(),
        })?;
        if self.is_draining() {
            return Err(ApiError::Draining { model: model.to_string() });
        }
        // Memory-pressure admission, before the bounded queue accepts the
        // work: refuse while the pool would stay over budget even if every
        // sheddable byte — prefix-cache snapshots first, then detached
        // sessions — were reclaimed (the coordinator handles the precise
        // per-request estimate and the actual shedding).
        if let Some(pool) = self.pools.get(model) {
            if pool.hard_pressure() {
                if let Some(stats) = self.stats.get(model) {
                    stats.pool_rejected.fetch_add(1, Ordering::Relaxed);
                }
                return Err(ApiError::PoolExhausted {
                    model: model.to_string(),
                    detail: format!(
                        "{} bytes resident exceed the {}-byte budget even if every \
                         prefix snapshot and detached session were shed",
                        pool.resident_bytes(),
                        pool.budget().unwrap_or(0)
                    ),
                });
            }
        }
        let (etx, erx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let id = request.id;
        // Span birth (stamps `Queued`) and the RAII queue-depth claim.
        // The token travels inside the item: the batcher's dequeue drops
        // it, and a failed send below drops it with the returned item —
        // the gauge can neither leak nor underflow.
        let span = self
            .telemetry
            .get(model)
            .map(|tel| tel.begin_span(id))
            .unwrap_or_else(SpanBuilder::disabled);
        let queue_token = self.stats.get(model).map(|stats| stats.enqueue_token());
        // Stamp the enqueue instant on the same clock the coordinator will
        // read at admission (0 for hub-less coordinators: no hub, no spans,
        // and queue_us saturates to 0 rather than going negative).
        let enqueued_us = self.telemetry.get(model).map(|tel| tel.now_us()).unwrap_or(0);
        let item = WorkItem {
            request,
            events: etx,
            cancel: cancel.clone(),
            enqueued_us,
            span,
            queue_token,
        };
        match tx.try_send(item) {
            Ok(()) => Ok(GenHandle { id, events: erx, cancel }),
            Err(TrySendError::Full(_)) => Err(ApiError::QueueFull { model: model.to_string() }),
            Err(TrySendError::Disconnected(_)) => Err(ApiError::EngineFailure {
                message: format!("coordinator for {model} is gone"),
            }),
        }
    }

    /// Submit and fold the event stream (one-shot convenience; this is the
    /// pre-streaming API surface, kept for callers and tests).
    pub fn generate(&self, model: &str, request: Request) -> Result<Response> {
        let handle = self.submit(model, request)?;
        Ok(handle.wait())
    }

    /// Drop the senders and join the worker threads.
    pub fn shutdown(mut self) {
        self.senders.clear();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Replay a freshly opened store's inventory into the serving state.
/// Every descriptor restores through one shared handle map, so blocks
/// that were CoW-shared across sessions and snapshots in the previous
/// run come back as one `Block` each — same bytes resident once, shared
/// again.  A descriptor that fails validation is reported and dropped
/// (its records fall to the next checkpoint's GC); restore never takes
/// the process down.
fn restore_inventory(
    kv: &Arc<KvStore>,
    pool: &Arc<BlockPool>,
    sessions: &SharedSessionStore,
    prefix: Option<&PrefixCache>,
) {
    let mut handles: HashMap<u64, Arc<Block>> = HashMap::new();
    for (id, desc) in kv.boot_sessions() {
        match KvCache::restore(pool, kv, &desc, &mut handles) {
            Ok(cache) => {
                let pending = desc.get("pending").and_then(|j| j.as_i64()).unwrap_or(0) as i32;
                let turns = desc.get("turns").and_then(|j| j.as_i64()).unwrap_or(0) as u32;
                crate::util::locked(sessions).restore(&id, cache, pending, turns);
            }
            Err(e) => eprintln!("session {id} failed to restore ({e:#}); dropped"),
        }
    }
    let Some(pc) = prefix else { return };
    for (pid, desc) in kv.boot_prefixes() {
        let restored = KvCache::restore(pool, kv, &desc, &mut handles)
            .and_then(|cache| pc.restore(&desc, cache, pid));
        if let Err(e) = restored {
            eprintln!("prefix snapshot {pid} failed to restore ({e:#}); dropped");
        }
    }
}

//! Multi-model router: one coordinator thread per model variant, a shared
//! handle for clients (in-proc or the TCP server).
//!
//! Engine handles may not be `Send` (the PJRT client wraps its state in
//! `Rc`), so each coordinator thread constructs its own [`Engine`] from a
//! plain-data [`EngineSpec`] and the router moves only [`WorkItem`]s across
//! threads.  The spec also carries the backend choice, so a router can
//! serve the hermetic CPU reference backend and the XLA artifact backend
//! with identical plumbing.

use std::collections::HashMap;
use std::sync::mpsc::{self, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::backend::EngineSpec;

use super::{Coordinator, Request, Response, WorkItem};

pub struct Router {
    senders: HashMap<String, Sender<WorkItem>>,
    threads: Vec<JoinHandle<()>>,
}

impl Router {
    /// Spin up one coordinator thread per model variant.  Engine loading
    /// happens inside the thread; a variant that fails to load answers all
    /// of its requests with an error instead of killing the router.
    pub fn start(spec: EngineSpec, variants: &[String]) -> Router {
        let mut senders = HashMap::new();
        let mut threads = Vec::new();
        for variant in variants {
            let (tx, rx) = mpsc::channel::<WorkItem>();
            senders.insert(variant.clone(), tx);
            let spec = spec.clone();
            let name = variant.clone();
            threads.push(std::thread::spawn(move || match spec.build(&name) {
                Ok(engine) => {
                    let coord = Coordinator::new(engine);
                    if let Err(e) = coord.run(rx) {
                        eprintln!("coordinator {name} died: {e:#}");
                    }
                }
                Err(e) => {
                    let msg = format!("engine {name} failed to load: {e:#}");
                    eprintln!("{msg}");
                    while let Ok(item) = rx.recv() {
                        let _ = item.respond.send(Response::error(item.request.id, &msg));
                    }
                }
            }));
        }
        Router { senders, threads }
    }

    pub fn models(&self) -> Vec<String> {
        self.senders.keys().cloned().collect()
    }

    /// Submit a request; returns a receiver for its response.
    pub fn submit(&self, model: &str, request: Request) -> Result<mpsc::Receiver<Response>> {
        let tx = self
            .senders
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model:?} (have {:?})", self.models()))?;
        let (rtx, rrx) = mpsc::channel();
        tx.send(WorkItem { request, respond: rtx, enqueued: Instant::now() })
            .map_err(|_| anyhow!("coordinator for {model} is gone"))?;
        Ok(rrx)
    }

    /// Submit and wait (in-proc convenience).
    pub fn generate(&self, model: &str, request: Request) -> Result<Response> {
        let rx = self.submit(model, request)?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped the response"))
    }

    /// Drop the senders and join the worker threads.
    pub fn shutdown(mut self) {
        self.senders.clear();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

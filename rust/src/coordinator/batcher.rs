//! Continuous batcher: keeps a fixed-shape decode bucket full by admitting
//! queued requests into slots the moment they free up (prefill happens at
//! admission, decode proceeds in lockstep across occupied slots).
//!
//! Bucket policy: with one pending request the B=1 executable is used (no
//! padding waste); with more, the largest exported bucket.  A sequence
//! joining mid-flight simply occupies an idle slot at the next step
//! boundary — the defining property of continuous batching.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::compress::maybe_compress;
use crate::engine::{Engine, SlotState};
use crate::util::argmax;

use super::{Response, WorkItem};

pub struct Coordinator {
    pub engine: Engine,
    /// Max decode steps a batch runs before re-checking the queue (keeps
    /// admission latency bounded even under long generations).
    pub admission_interval: usize,
}

struct Pending {
    respond: std::sync::mpsc::Sender<Response>,
    id: u64,
    queue_us: u64,
    prefill_us: u64,
    prompt_tokens: usize,
    started: Instant,
}

impl Coordinator {
    pub fn new(engine: Engine) -> Self {
        Coordinator { engine, admission_interval: 8 }
    }

    /// Serve until the work channel closes; blocks the calling thread.
    pub fn run(&self, queue: Receiver<WorkItem>) -> Result<()> {
        let bucket = *self.engine.decode_buckets().iter().max().unwrap_or(&1);
        let mut slots: Vec<SlotState> = (0..bucket).map(|_| SlotState::idle()).collect();
        let mut meta: Vec<Option<Pending>> = (0..bucket).map(|_| None).collect();
        loop {
            let occupied = slots.iter().filter(|s| s.occupied_any()).count();
            // Admit while there is room.
            let mut admitted = false;
            while slots.iter().any(|s| !s.occupied_any()) {
                let item = if occupied == 0 && !admitted {
                    // Block for work when fully idle.
                    match queue.recv_timeout(Duration::from_millis(200)) {
                        Ok(i) => i,
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => return Ok(()),
                    }
                } else {
                    match queue.try_recv() {
                        Ok(i) => i,
                        Err(_) => break,
                    }
                };
                admitted = true;
                self.admit(item, &mut slots, &mut meta)?;
            }

            if !slots.iter().any(|s| s.occupied_any()) {
                // Nothing in flight; check for disconnect to terminate.
                match queue.recv_timeout(Duration::from_millis(50)) {
                    Ok(item) => {
                        self.admit(item, &mut slots, &mut meta)?;
                    }
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => return Ok(()),
                }
            }

            // Decode burst, then recheck admissions.
            for _ in 0..self.admission_interval {
                if !slots.iter().any(|s| s.active().is_some()) {
                    break;
                }
                self.engine.step_batch(&mut slots)?;
                self.reap(&mut slots, &mut meta);
            }
        }
    }

    fn admit(
        &self,
        item: WorkItem,
        slots: &mut [SlotState],
        meta: &mut [Option<Pending>],
    ) -> Result<()> {
        let idx = slots.iter().position(|s| !s.occupied_any()).expect("free slot");
        let queue_us = item.enqueued.elapsed().as_micros() as u64;
        let req = item.request;
        let t0 = Instant::now();
        let ids = self.engine.tokenizer.encode(&req.prompt, true);
        let prefill = self.engine.prefill(&ids);
        match prefill {
            Ok((logits, cache)) => {
                let first = argmax(&logits) as i32;
                let scorer = self.engine.make_scorer(&req.compression, req.seed);
                let mut slot = SlotState::occupied(
                    cache,
                    req.compression.clone(),
                    scorer,
                    first,
                    req.max_new,
                );
                if let Some(seq) = slot.active_mut() {
                    // prefill-stage recursive compression
                    let ev =
                        maybe_compress(&mut seq.cache, &req.compression, seq.scorer.as_mut())?;
                    seq.compression_events += ev.len();
                    seq.push_generated(first, self.engine.tmax);
                }
                slots[idx] = slot;
                meta[idx] = Some(Pending {
                    respond: item.respond,
                    id: req.id,
                    queue_us,
                    prefill_us: t0.elapsed().as_micros() as u64,
                    prompt_tokens: ids.len(),
                    started: Instant::now(),
                });
                // a freshly admitted sequence may already be done (max_new=1)
                self.reap_slot(idx, slots, meta);
            }
            Err(e) => {
                let _ = item.respond.send(Response {
                    id: req.id,
                    text: String::new(),
                    tokens: vec![],
                    prompt_tokens: ids.len(),
                    cache_lens: vec![],
                    compression_events: 0,
                    queue_us,
                    prefill_us: 0,
                    decode_us: 0,
                    error: Some(format!("{e:#}")),
                });
            }
        }
        Ok(())
    }

    fn reap(&self, slots: &mut [SlotState], meta: &mut [Option<Pending>]) {
        for idx in 0..slots.len() {
            self.reap_slot(idx, slots, meta);
        }
    }

    fn reap_slot(&self, idx: usize, slots: &mut [SlotState], meta: &mut [Option<Pending>]) {
        if !slots[idx].finished() {
            return;
        }
        let seq = slots[idx].take().unwrap();
        let pending = meta[idx].take().expect("finished slot has metadata");
        let text = self.engine.tokenizer.decode(&seq.generated_without_eos());
        let _ = pending.respond.send(Response {
            id: pending.id,
            text,
            tokens: seq.generated.clone(),
            prompt_tokens: pending.prompt_tokens,
            cache_lens: seq.cache.lens(),
            compression_events: seq.compression_events,
            queue_us: pending.queue_us,
            prefill_us: pending.prefill_us,
            decode_us: pending.started.elapsed().as_micros() as u64,
            error: None,
        });
    }
}

//! Continuous batcher: keeps a fixed-shape decode bucket full by admitting
//! queued requests into slots the moment they free up (prefill happens at
//! admission, decode proceeds in lockstep across occupied slots), and
//! emits the typed [`Event`] stream live — `Started` after prefill, one
//! `Token` per decode step, one `Compression` per partition event, and a
//! terminal `Done`/`Error`.
//!
//! Bucket policy: with one pending request the B=1 executable is used (no
//! padding waste); with more, the largest exported bucket.  A sequence
//! joining mid-flight simply occupies an idle slot at the next step
//! boundary — the defining property of continuous batching.
//!
//! Cancellation is cooperative: each burst boundary checks every slot's
//! cancel flag and its event channel.  A set flag *or* a dropped receiver
//! (the in-proc drop-abort path) frees the slot before the next decode
//! step and emits `Error(Cancelled)` if anyone is still listening.
//!
//! Sessions: a request carrying a session id re-attaches that session's
//! compressed cache (prefilling only the new text via the decode path) and
//! detaches its cache back into the [`SessionStore`] when it finishes or
//! is cancelled, so the next turn continues the Eq. 10 trajectory.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::compress::maybe_compress;
use crate::engine::{Engine, SeqState, SlotState};
use crate::tokenizer::EOS;
use crate::util::argmax;

use super::{ApiError, Event, SessionConfig, SessionStore, Timings, Usage, WorkItem};

/// Liveness counters shared with the router (and tests): how many requests
/// this coordinator finished, cancelled/aborted, or failed, plus the
/// memory-pressure admission counters (pool rejections, sessions shed).
#[derive(Default)]
pub struct CoordStats {
    pub completed: AtomicU64,
    pub cancelled: AtomicU64,
    pub failed: AtomicU64,
    pub sessions_resumed: AtomicU64,
    /// Requests rejected with the typed `pool-exhausted` error.
    pub pool_rejected: AtomicU64,
    /// Detached sessions evicted to make room under the pool budget.
    pub sessions_shed: AtomicU64,
}

pub struct Coordinator {
    pub engine: Engine,
    /// Max decode steps a batch runs before re-checking the queue (keeps
    /// admission latency bounded even under long generations).
    pub admission_interval: usize,
    sessions: SessionStore,
    stats: Arc<CoordStats>,
}

struct Pending {
    events: Sender<Event>,
    cancel: Arc<std::sync::atomic::AtomicBool>,
    /// False once a send failed (receiver dropped): drop-abort.
    alive: bool,
    id: u64,
    session: Option<String>,
    /// Turns completed before this one (from the session entry).
    turns: u32,
    queue_us: u64,
    prefill_us: u64,
    prompt_tokens: usize,
    reused_tokens: usize,
    started: Instant,
    /// Digit-ness of the last emitted visible token (`None` before the
    /// first), which is all `Tokenizer::decode_delta` needs to extend the
    /// running text in O(1) per token.
    prev_digit: Option<bool>,
    /// How many generated tokens have been emitted as `Token` events.
    sent_tokens: usize,
    /// Worst-case pool bytes this request may still occupy (its admission
    /// estimate, plus any reattached history).  Admission counts these
    /// reservations — not the slot's current resident bytes, which lag the
    /// estimate — so concurrent slots cannot jointly oversubscribe the
    /// budget.  Released implicitly when the slot's metadata is dropped.
    reserved_bytes: usize,
}

impl Pending {
    fn send(&mut self, ev: Event) {
        if self.alive && self.events.send(ev).is_err() {
            self.alive = false;
        }
    }

    fn flagged(&self) -> bool {
        !self.alive || self.cancel.load(Ordering::Relaxed)
    }
}

impl Coordinator {
    pub fn new(engine: Engine) -> Self {
        Coordinator::with_config(engine, SessionConfig::default(), Arc::default())
    }

    pub fn with_config(engine: Engine, sessions: SessionConfig, stats: Arc<CoordStats>) -> Self {
        Coordinator {
            engine,
            admission_interval: 8,
            sessions: SessionStore::new(sessions),
            stats,
        }
    }

    /// Serve until the work channel closes; blocks the calling thread.
    pub fn run(&mut self, queue: Receiver<WorkItem>) -> Result<()> {
        let bucket = *self.engine.decode_buckets().iter().max().unwrap_or(&1);
        let mut slots: Vec<SlotState> = (0..bucket).map(|_| SlotState::idle()).collect();
        let mut meta: Vec<Option<Pending>> = (0..bucket).map(|_| None).collect();
        loop {
            let occupied = slots.iter().filter(|s| s.occupied_any()).count();
            // Admit while there is room.
            let mut admitted = false;
            while slots.iter().any(|s| !s.occupied_any()) {
                let item = if occupied == 0 && !admitted {
                    // Block for work when fully idle.
                    match queue.recv_timeout(Duration::from_millis(200)) {
                        Ok(i) => i,
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => return Ok(()),
                    }
                } else {
                    match queue.try_recv() {
                        Ok(i) => i,
                        Err(_) => break,
                    }
                };
                admitted = true;
                self.admit(item, &mut slots, &mut meta);
            }

            if !slots.iter().any(|s| s.occupied_any()) {
                // Nothing in flight; check for disconnect to terminate.
                match queue.recv_timeout(Duration::from_millis(50)) {
                    Ok(item) => {
                        self.admit(item, &mut slots, &mut meta);
                    }
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => return Ok(()),
                }
            }

            // Decode burst, then recheck admissions.  Cancel flags are
            // honoured at every step boundary.
            for _ in 0..self.admission_interval {
                self.abort_flagged(&mut slots, &mut meta);
                if !slots.iter().any(|s| s.active().is_some()) {
                    break;
                }
                self.engine.step_batch(&mut slots)?;
                for idx in 0..slots.len() {
                    self.progress_slot(idx, &mut slots, &mut meta);
                    self.reap_slot(idx, &mut slots, &mut meta);
                }
            }
        }
    }

    fn admit(&mut self, item: WorkItem, slots: &mut [SlotState], meta: &mut [Option<Pending>]) {
        let idx = slots.iter().position(|s| !s.occupied_any()).expect("free slot");
        let req = item.request;
        let mut pending = Pending {
            events: item.events,
            cancel: item.cancel,
            alive: true,
            id: req.id,
            session: req.session.clone(),
            turns: 0,
            queue_us: item.enqueued.elapsed().as_micros() as u64,
            prefill_us: 0,
            prompt_tokens: 0,
            reused_tokens: 0,
            started: Instant::now(),
            prev_digit: None,
            sent_tokens: 0,
            reserved_bytes: 0,
        };
        if pending.flagged() {
            // Cancelled while queued: never prefill.
            pending.send(Event::Error { id: pending.id, error: ApiError::Cancelled });
            self.stats.cancelled.fetch_add(1, Ordering::Relaxed);
            return;
        }

        let t0 = Instant::now();
        let mut scorer = self.engine.make_scorer(&req.compression, req.seed);
        let resumed = req.session.as_deref().and_then(|sid| self.sessions.take(sid));
        // The taken entry's bytes are no longer sheddable while we hold it.
        self.publish_sheddable();
        // (logits, cache, prefill-stage compression events)
        let prefill = match resumed {
            Some(entry) => {
                // Session resume: prefill only the new turn (no BOS) onto
                // the reattached compressed history, via the decode path.
                let ids = self.engine.tokenizer.encode(&req.prompt, false);
                pending.prompt_tokens = ids.len();
                pending.reused_tokens = entry.cache.appended;
                pending.turns = entry.turns;
                let mut feed = vec![entry.pending];
                feed.extend_from_slice(&ids);
                if entry.cache.appended + feed.len() + 1 >= self.engine.tmax {
                    // Refuse before touching the cache so the stored
                    // conversation survives for a shorter retry.
                    let sid = req.session.as_deref().unwrap_or("");
                    let message = format!(
                        "session {sid:?}: history of {} + {} new tokens exceeds capacity {}",
                        entry.cache.appended,
                        feed.len(),
                        self.engine.tmax
                    );
                    self.sessions.put(sid, entry.cache, entry.pending, entry.turns);
                    self.publish_sheddable();
                    pending.send(Event::Error {
                        id: pending.id,
                        error: ApiError::EngineFailure { message },
                    });
                    self.stats.failed.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                // Memory-pressure admission: the reattached history is
                // already resident, so budget only the new turn's rows —
                // but reserve history + estimate so later admissions keep
                // counting the history once it moves into the slot.
                match self.ensure_pool_capacity(feed.len() + req.max_new, slots, meta) {
                    Ok(reserved) => {
                        pending.reserved_bytes = reserved + entry.cache.exact_bytes();
                    }
                    Err(detail) => {
                        let sid = req.session.as_deref().unwrap_or("");
                        self.sessions.put(sid, entry.cache, entry.pending, entry.turns);
                        self.publish_sheddable();
                        pending.send(Event::Error {
                            id: pending.id,
                            error: ApiError::PoolExhausted {
                                model: self.engine.variant.clone(),
                                detail,
                            },
                        });
                        self.stats.pool_rejected.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                }
                self.stats.sessions_resumed.fetch_add(1, Ordering::Relaxed);
                let mut cache = entry.cache;
                self.engine
                    .prefill_onto(&mut cache, &req.compression, scorer.as_mut(), &feed)
                    .map(|(logits, events)| (logits, cache, events))
            }
            None => {
                let ids = self.engine.tokenizer.encode(&req.prompt, true);
                pending.prompt_tokens = ids.len();
                match self.ensure_pool_capacity(ids.len() + req.max_new, slots, meta) {
                    Ok(reserved) => pending.reserved_bytes = reserved,
                    Err(detail) => {
                        pending.send(Event::Error {
                            id: pending.id,
                            error: ApiError::PoolExhausted {
                                model: self.engine.variant.clone(),
                                detail,
                            },
                        });
                        self.stats.pool_rejected.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                }
                self.engine.prefill(&ids).and_then(|(logits, mut cache)| {
                    // prefill-stage recursive compression
                    let events = maybe_compress(&mut cache, &req.compression, scorer.as_mut())?;
                    Ok((logits, cache, events))
                })
            }
        };

        match prefill {
            Ok((logits, cache, events)) => {
                pending.prefill_us = t0.elapsed().as_micros() as u64;
                pending.started = Instant::now();
                pending.send(Event::Started {
                    id: pending.id,
                    prompt_tokens: pending.prompt_tokens,
                    reused_tokens: pending.reused_tokens,
                });
                let first = argmax(&logits) as i32;
                let mut slot = SlotState::occupied(
                    cache,
                    req.compression.clone(),
                    scorer,
                    first,
                    req.max_new,
                );
                if let Some(seq) = slot.seq_mut() {
                    seq.compression_events += events.len();
                    seq.step_events = events;
                    seq.push_generated(first, self.engine.tmax);
                }
                slots[idx] = slot;
                meta[idx] = Some(pending);
                // emit the prefill-stage events and the first token; a
                // freshly admitted sequence may already be done (max_new=1)
                self.progress_slot(idx, slots, meta);
                self.reap_slot(idx, slots, meta);
            }
            Err(e) => {
                pending.send(Event::Error {
                    id: pending.id,
                    error: ApiError::EngineFailure { message: format!("{e:#}") },
                });
                self.stats.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Emit `Compression` and `Token` events for whatever the last step (or
    /// admission) produced on one slot.
    fn progress_slot(&self, idx: usize, slots: &mut [SlotState], meta: &mut [Option<Pending>]) {
        let Some(seq) = slots[idx].seq_mut() else { return };
        let Some(p) = meta[idx].as_mut() else { return };
        for ev in std::mem::take(&mut seq.step_events) {
            // Each event carries its own post-event length snapshot, so a
            // burst of events in one pass streams the true per-event
            // Eq. 10 trajectory (not N copies of the final lengths).
            p.send(Event::Compression {
                id: p.id,
                evicted: ev.l - ev.kept,
                layer_lens: ev.layer_lens,
            });
        }
        while p.sent_tokens < seq.generated.len() {
            let token = seq.generated[p.sent_tokens];
            // EOS is stripped from the folded text, so it streams an empty
            // delta; everything else extends the text in O(1).
            let text_delta = if token == EOS {
                String::new()
            } else {
                let (delta, is_digit) = self.engine.tokenizer.decode_delta(p.prev_digit, token);
                p.prev_digit = Some(is_digit);
                delta
            };
            p.send(Event::Token { id: p.id, token, text_delta });
            p.sent_tokens += 1;
        }
    }

    fn reap_slot(&mut self, idx: usize, slots: &mut [SlotState], meta: &mut [Option<Pending>]) {
        if !slots[idx].finished() {
            return;
        }
        let seq = slots[idx].take().unwrap();
        let mut p = meta[idx].take().expect("finished slot has metadata");
        let usage = Usage {
            prompt_tokens: p.prompt_tokens,
            new_tokens: seq.generated.len(),
            reused_tokens: p.reused_tokens,
            cache_lens: seq.cache.lens(),
            compression_events: seq.compression_events,
        };
        let timings = Timings {
            queue_us: p.queue_us,
            prefill_us: p.prefill_us,
            decode_us: p.started.elapsed().as_micros() as u64,
        };
        p.send(Event::Done { id: p.id, usage, timings });
        self.stats.completed.fetch_add(1, Ordering::Relaxed);
        self.stash_session(&p, seq);
    }

    /// Free every slot whose request was cancelled or whose event receiver
    /// is gone.  Runs at step boundaries, so an abort never wastes more
    /// than one decode step.
    fn abort_flagged(&mut self, slots: &mut [SlotState], meta: &mut [Option<Pending>]) {
        for idx in 0..slots.len() {
            let flagged = slots[idx].occupied_any()
                && meta[idx].as_ref().map(|p| p.flagged()).unwrap_or(false);
            if !flagged {
                continue;
            }
            let seq = slots[idx].take().unwrap();
            let mut p = meta[idx].take().expect("occupied slot has metadata");
            p.send(Event::Error { id: p.id, error: ApiError::Cancelled });
            self.stats.cancelled.fetch_add(1, Ordering::Relaxed);
            // A cancelled turn still advances its conversation: the cache
            // holds everything decoded so far.
            self.stash_session(&p, seq);
        }
    }

    fn stash_session(&mut self, p: &Pending, seq: SeqState) {
        if let Some(sid) = &p.session {
            self.sessions.put(sid, seq.cache, seq.next_token, p.turns + 1);
            self.publish_sheddable();
        }
    }

    /// Keep the pool's sheddable-bytes signal (read by the router's cheap
    /// pre-queue pressure check) in step with the session store.
    fn publish_sheddable(&self) {
        self.engine.pool().set_sheddable(self.sessions.total_bytes());
    }

    /// Memory-pressure admission for a byte-budgeted pool: estimate the
    /// request's worst-case new rows (prompt + generation budget, before
    /// compression), shed least-recently-used detached sessions until the
    /// estimate fits, and return the byte reservation the caller records
    /// on its [`Pending`].
    ///
    /// Occupancy is judged as `resident - in-flight materialized +
    /// in-flight reservations`: running slots are charged their full
    /// worst-case estimate rather than the rows they happen to hold right
    /// now, so concurrently admitted requests can never jointly grow past
    /// the budget.  A request that could not fit even after shedding
    /// every session is rejected *without* shedding anything — an
    /// impossible request must not destroy stored conversations.
    /// The typed rejection detail is reported when even an
    /// empty store leaves too little room.  Unbudgeted pools admit
    /// everything (the default — zero overhead on that path).
    fn ensure_pool_capacity(
        &mut self,
        new_rows: usize,
        slots: &[SlotState],
        meta: &[Option<Pending>],
    ) -> Result<usize, String> {
        let pool = self.engine.pool().clone();
        let Some(budget) = pool.budget() else { return Ok(0) };
        let (nl, nh, dh) = {
            let d = &self.engine.dims;
            (d.n_layers, d.n_kv_heads, d.d_head)
        };
        let needed = new_rows * crate::kvpool::row_bytes(nl, nh, dh);
        let reserved: usize = meta.iter().flatten().map(|p| p.reserved_bytes).sum();
        let materialized: usize =
            slots.iter().filter_map(|s| s.seq()).map(|q| q.cache.exact_bytes()).sum();
        loop {
            let resident = pool.resident_bytes();
            let effective = resident.saturating_sub(materialized) + reserved;
            if effective + needed <= budget {
                self.publish_sheddable();
                return Ok(needed);
            }
            let sheddable = self.sessions.total_bytes();
            if effective.saturating_sub(sheddable) + needed > budget {
                self.publish_sheddable();
                return Err(format!(
                    "{needed} bytes needed for {new_rows} rows, {effective} effectively \
                     occupied ({sheddable} sheddable) under a {budget}-byte budget"
                ));
            }
            match self.sessions.shed_lru() {
                Some(_) => {
                    self.stats.sessions_shed.fetch_add(1, Ordering::Relaxed);
                }
                // Unreachable while total_bytes() > 0, but never loop on a
                // store that cannot yield bytes.
                None => {
                    self.publish_sheddable();
                    return Err(format!(
                        "{needed} bytes needed for {new_rows} rows with nothing left \
                         to shed under a {budget}-byte budget"
                    ));
                }
            }
        }
    }
}

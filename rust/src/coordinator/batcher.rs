//! Continuous batcher: keeps a fixed-shape decode bucket full by admitting
//! queued requests into slots the moment they free up (prefill happens at
//! admission, decode proceeds in lockstep across occupied slots), and
//! emits the typed [`Event`] stream live — `Started` after prefill, one
//! `Token` per decode step, one `Compression` per partition event, and a
//! terminal `Done`/`Error`.
//!
//! Bucket policy: with one pending request the B=1 executable is used (no
//! padding waste); with more, the largest exported bucket.  A sequence
//! joining mid-flight simply occupies an idle slot at the next step
//! boundary — the defining property of continuous batching.
//!
//! Cancellation is cooperative: each burst boundary checks every slot's
//! cancel flag and its event channel.  A set flag *or* a dropped receiver
//! (the in-proc drop-abort path) frees the slot before the next decode
//! step and emits `Error(Cancelled)` if anyone is still listening.
//!
//! Sessions: a request carrying a session id re-attaches that session's
//! compressed cache (prefilling only the new text via the decode path) and
//! detaches its cache back into the [`SessionStore`] when it finishes or
//! is cancelled, so the next turn continues the Eq. 10 trajectory.
//!
//! Prefix reuse: fresh requests prefill through the engine's radix prefix
//! cache (`kvpool::radix`) when one is enabled — the longest stored prompt
//! prefix attaches CoW and only the suffix runs on the backend — and a
//! completed request's compression-final cache is keyed back into the tree.
//! Admission charges every in-flight request an RAII byte [`Reservation`]
//! and reclaims memory in three tiers under a pool budget: prefix-cache
//! snapshots first, detached sessions second, typed rejection last.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::config::CompressionConfig;
use crate::engine::{Engine, PrefillJob, PrefillTask, SeqState, SlotState};
use crate::telemetry::{Clock, Metric, MonotonicClock, SpanBuilder, SpanEventKind, Telemetry};
use crate::tokenizer::EOS;
use crate::util::{argmax, locked};

use super::{ApiError, Event, SessionConfig, SessionStore, Timings, Usage, WorkItem};

/// Liveness counters shared with the router (and tests): how many requests
/// this coordinator finished, cancelled/aborted, or failed, plus the
/// memory-pressure admission counters (pool rejections, sessions shed).
#[derive(Default)]
pub struct CoordStats {
    pub completed: AtomicU64,
    pub cancelled: AtomicU64,
    pub failed: AtomicU64,
    pub sessions_resumed: AtomicU64,
    /// Requests rejected with the typed `pool-exhausted` error.
    pub pool_rejected: AtomicU64,
    /// Detached sessions evicted to make room under the pool budget.
    pub sessions_shed: AtomicU64,
    /// Prefix-cache snapshots evicted to make room under the pool budget
    /// (the cheapest sheddable class — always drained before sessions).
    pub prefix_shed: AtomicU64,
    /// Frozen blocks demoted to the disk tier under pool pressure (the
    /// tier *before* any shedding: demotion loses no state, only
    /// residency).  Counts blocks, not bytes.
    pub blocks_spilled: AtomicU64,
    /// Requests sitting in the admission queue right now — the control
    /// plane's queue-depth gauge.  Maintained exclusively by RAII
    /// [`QueueToken`]s: enqueue mints one, and its drop (dequeue, queue
    /// drain, channel teardown) releases exactly one unit, so the gauge
    /// can never leak an increment or double-decrement across threads.
    pub queued: AtomicU64,
}

impl CoordStats {
    /// Claim one unit of the `queued` gauge; the returned token releases
    /// it exactly once on drop, whichever path dequeues (or drops) the
    /// work item.
    pub fn enqueue_token(self: &Arc<Self>) -> QueueToken {
        // lint: allow(ledger): the mint half of the QueueToken RAII pair —
        // the matching release lives in QueueToken::drop
        self.queued.fetch_add(1, Ordering::Relaxed);
        QueueToken { stats: Arc::clone(self) }
    }
}

/// RAII unit of [`CoordStats::queued`].  Travels inside the [`WorkItem`]
/// from the router's enqueue to the batcher's dequeue; dropping it on any
/// path — admission, drain-on-shutdown, an abandoned channel — releases
/// the gauge exactly once.
#[must_use = "dropping a QueueToken immediately releases its queued-gauge unit"]
pub struct QueueToken {
    stats: Arc<CoordStats>,
}

impl Drop for QueueToken {
    fn drop(&mut self) {
        // The token is the only decrementer, so underflow here means a
        // bookkeeping bug (a unit released twice), not a race: scream in
        // debug builds, keep the gauge pinned at zero in release.
        let _ = self.stats.queued.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |q| {
            match q.checked_sub(1) {
                Some(rest) => Some(rest),
                None => {
                    debug_assert!(false, "queued gauge underflow: a token released twice?");
                    Some(0)
                }
            }
        });
    }
}

/// RAII share of the coordinator's in-flight byte reservations.  Admission
/// charges every running request its worst-case pool footprint through one
/// shared counter; dropping the reservation — on *any* exit path: `Done`,
/// explicit cancel, handle-drop abort, engine error, even a pool rejection
/// mid-admission — returns the bytes, so a leaked reservation can never
/// permanently inflate the occupancy estimate and starve admission.
#[must_use = "dropping a Reservation immediately returns its reserved bytes"]
struct Reservation {
    bytes: usize,
    total: Arc<AtomicUsize>,
}

impl Reservation {
    /// Reserve additional bytes (a session resume adds its reattached
    /// history so later admissions keep counting it while it runs).
    fn add(&mut self, extra: usize) {
        self.bytes += extra;
        self.total.fetch_add(extra, Ordering::Relaxed);
    }
}

impl Drop for Reservation {
    fn drop(&mut self) {
        self.total.fetch_sub(self.bytes, Ordering::Relaxed);
    }
}

/// What `reap_slot` needs to key a finished request's compression-final
/// cache back into the radix prefix tree.
struct PrefixInsert {
    compression: CompressionConfig,
    seed: u64,
    prompt_ids: Vec<i32>,
}

pub struct Coordinator {
    pub engine: Engine,
    /// Max decode steps a batch runs before re-checking the queue (keeps
    /// admission latency bounded even under long generations).
    pub admission_interval: usize,
    /// Shared with the router so the control plane (`sessions` op) can
    /// list and delete entries from outside this coordinator's thread.
    /// Lock discipline: never held across an engine call — every access
    /// here is a short take/put/measure critical section.
    sessions: Arc<Mutex<SessionStore>>,
    stats: Arc<CoordStats>,
    /// Sum of live [`Reservation`]s (in-flight worst-case bytes).
    reserved: Arc<AtomicUsize>,
    /// Per-model telemetry hub (None for direct-fed coordinators): span
    /// publication on every terminal path plus the prefill-segment
    /// latency histogram.
    telemetry: Option<Arc<Telemetry>>,
    /// Time source for queue/prefill/decode timings.  Monotonic by
    /// default; `set_telemetry` swaps in the hub's clock so Timings and
    /// span stamps share one (fake-clock-testable) timeline.
    clock: Arc<dyn Clock>,
}

struct Pending {
    events: Sender<Event>,
    cancel: Arc<std::sync::atomic::AtomicBool>,
    /// False once a send failed (receiver dropped): drop-abort.
    alive: bool,
    id: u64,
    session: Option<String>,
    /// Turns completed before this one (from the session entry).
    turns: u32,
    queue_us: u64,
    prefill_us: u64,
    prompt_tokens: usize,
    reused_tokens: usize,
    /// Coordinator-clock reading (µs) when the current phase began.
    started_us: u64,
    /// Digit-ness of the last emitted visible token (`None` before the
    /// first), which is all `Tokenizer::decode_delta` needs to extend the
    /// running text in O(1) per token.
    prev_digit: Option<bool>,
    /// How many generated tokens have been emitted as `Token` events.
    sent_tokens: usize,
    /// Worst-case pool bytes this request may still occupy (its admission
    /// estimate, plus any reattached history).  Admission counts these
    /// reservations — not the slot's current resident bytes, which lag the
    /// estimate — so concurrent slots cannot jointly oversubscribe the
    /// budget.  RAII: dropping this metadata on any exit path releases it.
    reservation: Option<Reservation>,
    /// Set for fresh requests under a cacheable policy: reap keys the
    /// finished cache back into the radix prefix tree under prompt ids +
    /// appended generation.
    prefix_insert: Option<PrefixInsert>,
    /// Span recorder stamped through the slot lifecycle and published
    /// (non-blocking) on the terminal path.  Disabled builders make every
    /// stamp a no-op.
    span: SpanBuilder,
}

impl Pending {
    fn send(&mut self, ev: Event) {
        if self.alive && self.events.send(ev).is_err() {
            self.alive = false;
        }
    }

    fn flagged(&self) -> bool {
        !self.alive || self.cancel.load(Ordering::Relaxed)
    }
}

impl Coordinator {
    pub fn new(engine: Engine) -> Self {
        Coordinator::with_config(engine, SessionConfig::default(), Arc::default())
    }

    pub fn with_config(engine: Engine, sessions: SessionConfig, stats: Arc<CoordStats>) -> Self {
        let store = Arc::new(Mutex::new(SessionStore::new(sessions)));
        Coordinator::with_store(engine, store, stats)
    }

    /// Construct around a router-owned session store (shared so the
    /// control plane can list/delete sessions from outside this thread).
    pub fn with_store(
        engine: Engine,
        sessions: Arc<Mutex<SessionStore>>,
        stats: Arc<CoordStats>,
    ) -> Self {
        // The store republishes the pool's sheddable-bytes gauge on every
        // mutation from here on (take, put, byte-cap eviction, shedding).
        locked(&sessions).bind_pool(Arc::clone(engine.pool()));
        Coordinator {
            engine,
            admission_interval: 8,
            sessions,
            stats,
            reserved: Arc::new(AtomicUsize::new(0)),
            telemetry: None,
            clock: Arc::new(MonotonicClock::new()),
        }
    }

    /// Bind the model's telemetry hub: terminal spans publish through its
    /// non-blocking sink and prefill-segment latencies feed its registry.
    /// The coordinator adopts the hub's clock so request timings and span
    /// stamps are deltas on the same timeline.
    pub fn set_telemetry(&mut self, telemetry: Arc<Telemetry>) {
        self.clock = Arc::clone(telemetry.clock());
        self.telemetry = Some(telemetry);
    }

    /// Terminal span bookkeeping: stamp the terminal event, derive the
    /// span-delta histograms, and publish through the non-blocking sink.
    fn finish_span(&self, p: &mut Pending, terminal: SpanEventKind) {
        if let Some(tel) = &self.telemetry {
            let span = std::mem::replace(&mut p.span, SpanBuilder::disabled());
            tel.finish_span(span, terminal);
        }
    }

    /// Serve until the work channel closes; blocks the calling thread.
    pub fn run(&mut self, queue: Receiver<WorkItem>) -> Result<()> {
        let bucket = *self.engine.decode_buckets().iter().max().unwrap_or(&1);
        let mut slots: Vec<SlotState> = (0..bucket).map(|_| SlotState::idle()).collect();
        let mut meta: Vec<Option<Pending>> = (0..bucket).map(|_| None).collect();
        loop {
            let occupied = slots.iter().filter(|s| s.occupied_any()).count();
            // Admit while there is room.
            let mut admitted = false;
            while slots.iter().any(|s| !s.occupied_any()) {
                let item = if occupied == 0 && !admitted {
                    // Block for work when fully idle.
                    match queue.recv_timeout(Duration::from_millis(200)) {
                        Ok(i) => i,
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => return Ok(()),
                    }
                } else {
                    match queue.try_recv() {
                        Ok(i) => i,
                        Err(_) => break,
                    }
                };
                admitted = true;
                self.admit(item, &mut slots, &mut meta);
            }

            if !slots.iter().any(|s| s.occupied_any()) {
                // Nothing in flight; check for disconnect to terminate.
                match queue.recv_timeout(Duration::from_millis(50)) {
                    Ok(item) => {
                        self.admit(item, &mut slots, &mut meta);
                    }
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => return Ok(()),
                }
            }

            // Decode burst, then recheck admissions.  Cancel flags are
            // honoured at every step boundary, and every chunked cold
            // prefill advances one segment per step — interleaved with the
            // decode steps of in-flight sequences, so one long cold prompt
            // costs each streaming sequence at most one segment's latency
            // between tokens instead of a whole prefill.
            for _ in 0..self.admission_interval {
                self.abort_flagged(&mut slots, &mut meta);
                self.advance_prefills(&mut slots, &mut meta);
                if !slots.iter().any(|s| s.active().is_some()) {
                    if slots.iter().any(|s| s.is_prefilling()) {
                        // Nothing to decode yet, but prefill segments
                        // remain: keep burning burst iterations on them.
                        continue;
                    }
                    break;
                }
                self.engine.step_batch(&mut slots)?;
                for idx in 0..slots.len() {
                    self.progress_slot(idx, &mut slots, &mut meta);
                    self.reap_slot(idx, &mut slots, &mut meta);
                }
            }
        }
    }

    fn admit(&mut self, item: WorkItem, slots: &mut [SlotState], meta: &mut [Option<Pending>]) {
        // Dequeue: dropping the RAII token releases the `queued` gauge
        // exactly once (None for directly-fed coordinators, e.g. unit
        // tests, which never enqueued through the router's mint).
        drop(item.queue_token);
        // lint: allow(panic): both call sites run under the admission loop's
        // `any(!occupied)` guard, so a free slot provably exists
        let idx = slots.iter().position(|s| !s.occupied_any()).expect("free slot");
        let req = item.request;
        let now_us = self.clock.now_us();
        let mut pending = Pending {
            events: item.events,
            cancel: item.cancel,
            alive: true,
            id: req.id,
            session: req.session.clone(),
            turns: 0,
            queue_us: now_us.saturating_sub(item.enqueued_us),
            prefill_us: 0,
            prompt_tokens: 0,
            reused_tokens: 0,
            started_us: now_us,
            prev_digit: None,
            sent_tokens: 0,
            reservation: None,
            prefix_insert: None,
            span: item.span,
        };
        if pending.flagged() {
            // Cancelled while queued: never prefill (the span ends
            // Queued → Cancelled without ever being Admitted).
            pending.send(Event::Error { id: pending.id, error: ApiError::Cancelled });
            self.stats.cancelled.fetch_add(1, Ordering::Relaxed);
            self.finish_span(&mut pending, SpanEventKind::Cancelled);
            return;
        }
        pending.span.record(SpanEventKind::Admitted);

        let t0_us = self.clock.now_us();
        let mut scorer = self.engine.make_scorer(&req.compression, req.seed);
        // take() republishes the sheddable gauge: the entry's bytes stop
        // being sheddable the moment we hold it.
        let resumed = req.session.as_deref().and_then(|sid| locked(&self.sessions).take(sid));
        // (logits, cache, prefill-stage compression events)
        let prefill = match resumed {
            Some(entry) => {
                // Session resume: prefill only the new turn (no BOS) onto
                // the reattached compressed history, via the decode path.
                let ids = self.engine.tokenizer.encode(&req.prompt, false);
                pending.prompt_tokens = ids.len();
                pending.reused_tokens = entry.cache.appended;
                pending.turns = entry.turns;
                let feed = entry.resume_feed(&ids);
                if !self.engine.feed_fits(entry.cache.appended, feed.len()) {
                    // Refuse before touching the cache so the stored
                    // conversation survives for a shorter retry.  Same
                    // capacity rule and typed rejection as every other
                    // decode-path feed: a client-sized problem, so it
                    // reaches the wire as {"code": "bad-params"}.
                    let sid = req.session.as_deref().unwrap_or("");
                    let message = format!(
                        "session {sid:?}: history of {} + {} new tokens exceeds capacity {}",
                        entry.cache.appended,
                        feed.len(),
                        self.engine.tmax
                    );
                    locked(&self.sessions).put(sid, entry.cache, entry.pending, entry.turns);
                    pending.send(Event::Error {
                        id: pending.id,
                        error: ApiError::BadParams { message },
                    });
                    self.stats.failed.fetch_add(1, Ordering::Relaxed);
                    self.finish_span(&mut pending, SpanEventKind::Failed);
                    return;
                }
                // Memory-pressure admission: the reattached history is
                // already resident, so budget only the new turn's rows —
                // but reserve history + estimate so later admissions keep
                // counting the history once it moves into the slot.
                match self.ensure_pool_capacity(feed.len() + req.max_new, slots, &mut pending.span)
                {
                    Ok(mut reservation) => {
                        reservation.add(entry.cache.exact_bytes());
                        pending.reservation = Some(reservation);
                    }
                    Err(detail) => {
                        let sid = req.session.as_deref().unwrap_or("");
                        locked(&self.sessions).put(sid, entry.cache, entry.pending, entry.turns);
                        pending.send(Event::Error {
                            id: pending.id,
                            error: ApiError::PoolExhausted {
                                model: self.engine.variant.clone(),
                                detail,
                            },
                        });
                        self.stats.pool_rejected.fetch_add(1, Ordering::Relaxed);
                        self.finish_span(&mut pending, SpanEventKind::Failed);
                        return;
                    }
                }
                self.stats.sessions_resumed.fetch_add(1, Ordering::Relaxed);
                pending.span.record_v(SpanEventKind::SessionResume, pending.reused_tokens as u64);
                let mut cache = entry.cache;
                // Packed wide-bucket suffix prefill (bit-identical to the
                // b=1 trajectory; falls back to it on real-attention
                // backends) — fast enough to stay synchronous.
                self.engine
                    .prefill_onto_batched(&mut cache, &req.compression, scorer.as_mut(), &feed)
                    .map(|(logits, events)| (logits, cache, events))
            }
            None => {
                let ids = self.engine.tokenizer.encode(&req.prompt, true);
                pending.prompt_tokens = ids.len();
                let max_prompt = self.engine.max_prompt_tokens();
                if ids.len() > max_prompt {
                    // A client-sized problem, not an engine failure: the
                    // typed bad-params error reaches the wire as
                    // {"code": "bad-params"}.
                    pending.send(Event::Error {
                        id: pending.id,
                        error: ApiError::BadParams {
                            message: format!(
                                "prompt of {} tokens exceeds the largest prefill \
                                 bucket ({max_prompt})",
                                ids.len()
                            ),
                        },
                    });
                    self.stats.failed.fetch_add(1, Ordering::Relaxed);
                    self.finish_span(&mut pending, SpanEventKind::Failed);
                    return;
                }
                match self.ensure_pool_capacity(ids.len() + req.max_new, slots, &mut pending.span)
                {
                    Ok(reservation) => pending.reservation = Some(reservation),
                    Err(detail) => {
                        pending.send(Event::Error {
                            id: pending.id,
                            error: ApiError::PoolExhausted {
                                model: self.engine.variant.clone(),
                                detail,
                            },
                        });
                        self.stats.pool_rejected.fetch_add(1, Ordering::Relaxed);
                        self.finish_span(&mut pending, SpanEventKind::Failed);
                        return;
                    }
                }
                if self
                    .engine
                    .prefix_cache()
                    .map(|p| p.cacheable(&req.compression))
                    .unwrap_or(false)
                {
                    pending.prefix_insert = Some(PrefixInsert {
                        compression: req.compression.clone(),
                        seed: req.seed,
                        prompt_ids: ids.clone(),
                    });
                }
                // Start the prefill through the radix prefix cache: a warm
                // hit (longest stored prompt prefix attached CoW, packed
                // suffix decode) completes right here; a cold prompt comes
                // back as a chunked prefill that parks in the slot and is
                // advanced segment-by-segment by the decode loop, so it
                // never stalls in-flight decode for its whole length.
                match self.engine.begin_prefill(&ids, &req.compression, scorer.as_mut(), req.seed)
                {
                    Ok(PrefillTask::Done(outcome)) => {
                        pending.reused_tokens = outcome.reused_tokens;
                        Ok((outcome.logits, outcome.cache, outcome.events))
                    }
                    Ok(PrefillTask::Chunked(chunked)) => {
                        slots[idx] = SlotState::prefilling(PrefillJob {
                            chunked,
                            scorer,
                            compression: req.compression.clone(),
                            max_new: req.max_new,
                        });
                        meta[idx] = Some(pending);
                        return;
                    }
                    Err(e) => Err(e),
                }
            }
        };

        match prefill {
            Ok((logits, cache, events)) => {
                let now_us = self.clock.now_us();
                pending.prefill_us = now_us.saturating_sub(t0_us);
                pending.started_us = now_us;
                // A synchronous prefill (resume or warm hit) is one
                // segment on the timeline.
                pending.span.record_v(SpanEventKind::PrefillSegment, pending.prompt_tokens as u64);
                if let Some(tel) = &self.telemetry {
                    tel.record(Metric::PrefillSegment, pending.prefill_us);
                }
                pending.send(Event::Started {
                    id: pending.id,
                    prompt_tokens: pending.prompt_tokens,
                    reused_tokens: pending.reused_tokens,
                });
                let first = argmax(&logits) as i32;
                let mut slot = SlotState::occupied(
                    cache,
                    req.compression.clone(),
                    scorer,
                    first,
                    req.max_new,
                );
                if let Some(seq) = slot.seq_mut() {
                    seq.compression_events += events.len();
                    seq.step_events = events;
                    seq.push_generated(first, self.engine.tmax);
                }
                slots[idx] = slot;
                meta[idx] = Some(pending);
                // emit the prefill-stage events and the first token; a
                // freshly admitted sequence may already be done (max_new=1)
                self.progress_slot(idx, slots, meta);
                self.reap_slot(idx, slots, meta);
            }
            Err(e) => {
                pending.send(Event::Error {
                    id: pending.id,
                    error: ApiError::EngineFailure { message: format!("{e:#}") },
                });
                self.stats.failed.fetch_add(1, Ordering::Relaxed);
                self.finish_span(&mut pending, SpanEventKind::Failed);
            }
        }
    }

    /// Emit `Compression` and `Token` events for whatever the last step (or
    /// admission) produced on one slot.
    fn progress_slot(&self, idx: usize, slots: &mut [SlotState], meta: &mut [Option<Pending>]) {
        let Some(seq) = slots[idx].seq_mut() else { return };
        let Some(p) = meta[idx].as_mut() else { return };
        let fired = seq.step_events.len();
        for ev in std::mem::take(&mut seq.step_events) {
            // Each event carries its own post-event length snapshot, so a
            // burst of events in one pass streams the true per-event
            // Eq. 10 trajectory (not N copies of the final lengths).
            p.send(Event::Compression {
                id: p.id,
                evicted: ev.l - ev.kept,
                layer_lens: ev.layer_lens,
            });
        }
        if fired > 0 {
            p.span.record_v(SpanEventKind::Compression, fired as u64);
        }
        while p.sent_tokens < seq.generated.len() {
            let token = seq.generated[p.sent_tokens];
            // EOS is stripped from the folded text, so it streams an empty
            // delta; everything else extends the text in O(1).
            let text_delta = if token == EOS {
                String::new()
            } else {
                let (delta, is_digit) = self.engine.tokenizer.decode_delta(p.prev_digit, token);
                p.prev_digit = Some(is_digit);
                delta
            };
            p.send(Event::Token { id: p.id, token, text_delta });
            p.sent_tokens += 1;
            // The first emitted token is the TTFT boundary; every later
            // one is a decode step carrying the running sent count.
            if p.sent_tokens == 1 {
                p.span.record(SpanEventKind::FirstToken);
            } else {
                p.span.record_v(SpanEventKind::DecodeStep, p.sent_tokens as u64);
            }
        }
    }

    fn reap_slot(&mut self, idx: usize, slots: &mut [SlotState], meta: &mut [Option<Pending>]) {
        if !slots[idx].finished() {
            return;
        }
        // lint: allow(panic): `finished()` returned true, so the slot holds a
        // sequence and its paired metadata — violated only by a slot-accounting
        // bug, which should fail loudly
        let seq = slots[idx].take().expect("finished slot holds a sequence");
        // lint: allow(panic): same slot/metadata pairing invariant as above
        let mut p = meta[idx].take().expect("finished slot has metadata");
        let usage = Usage {
            prompt_tokens: p.prompt_tokens,
            new_tokens: seq.generated.len(),
            reused_tokens: p.reused_tokens,
            cache_lens: seq.cache.lens(),
            compression_events: seq.compression_events,
        };
        let timings = Timings {
            queue_us: p.queue_us,
            prefill_us: p.prefill_us,
            decode_us: self.clock.now_us().saturating_sub(p.started_us),
        };
        // A completed request's compression-final cache goes back into the
        // radix prefix tree keyed by its full appended token stream (the
        // prompt plus every generated token decode actually consumed), so
        // a later request extending this conversation-shaped prefix
        // attaches it CoW.  Inserted before the terminal event so a client
        // that saw `Done` can rely on the snapshot existing.
        if let (Some(pi), Some(prefix)) = (&p.prefix_insert, self.engine.prefix_cache()) {
            if !seq.generated.is_empty() {
                let mut key = pi.prompt_ids.clone();
                key.extend_from_slice(&seq.generated[..seq.generated.len() - 1]);
                prefix.insert(&pi.compression, pi.seed, &key, &seq.cache);
            }
        }
        p.send(Event::Done { id: p.id, usage, timings });
        self.stats.completed.fetch_add(1, Ordering::Relaxed);
        self.finish_span(&mut p, SpanEventKind::Done);
        self.stash_session(&p, seq);
    }

    /// Advance every in-progress chunked cold prefill by one segment.  A
    /// finished prefill is promoted into a decoding sequence: `Started`
    /// fires (TTFT semantics are unchanged — the client hears nothing
    /// until its prompt is fully prefilled), the first token is sampled
    /// from the prefill logits, and the slot joins the next decode step.
    fn advance_prefills(&mut self, slots: &mut [SlotState], meta: &mut [Option<Pending>]) {
        for idx in 0..slots.len() {
            let Some(job) = slots[idx].prefill_mut() else { continue };
            let t0_us = self.clock.now_us();
            let stepped = job.chunked.step(&self.engine, job.scorer.as_mut());
            let ingested = job.chunked.ingested();
            if let Some(tel) = &self.telemetry {
                tel.record(Metric::PrefillSegment, self.clock.now_us().saturating_sub(t0_us));
            }
            let done = match stepped {
                Ok(done) => done,
                Err(e) => {
                    slots[idx].take_prefill();
                    // lint: allow(panic): a prefilling slot always carries
                    // metadata — set together in admit()
                    let mut p = meta[idx].take().expect("prefilling slot has metadata");
                    p.send(Event::Error {
                        id: p.id,
                        error: ApiError::EngineFailure { message: format!("{e:#}") },
                    });
                    self.stats.failed.fetch_add(1, Ordering::Relaxed);
                    self.finish_span(&mut p, SpanEventKind::Failed);
                    continue;
                }
            };
            if let Some(p) = meta[idx].as_mut() {
                p.span.record_v(SpanEventKind::PrefillSegment, ingested as u64);
            }
            if !done {
                continue;
            }
            // lint: allow(panic): `prefill_mut()` returned Some above and
            // nothing freed the slot since
            let job = slots[idx].take_prefill().expect("prefill job present");
            let PrefillJob { chunked, scorer, compression, max_new } = *job;
            let outcome = chunked.finish(&self.engine);
            // lint: allow(panic): a prefilling slot always carries metadata
            let p = meta[idx].as_mut().expect("prefilling slot has metadata");
            let now_us = self.clock.now_us();
            p.prefill_us = now_us.saturating_sub(p.started_us);
            p.started_us = now_us;
            p.send(Event::Started {
                id: p.id,
                prompt_tokens: p.prompt_tokens,
                reused_tokens: outcome.reused_tokens,
            });
            let first = argmax(&outcome.logits) as i32;
            let mut slot = SlotState::occupied(outcome.cache, compression, scorer, first, max_new);
            if let Some(seq) = slot.seq_mut() {
                seq.compression_events += outcome.events.len();
                seq.step_events = outcome.events;
                seq.push_generated(first, self.engine.tmax);
            }
            slots[idx] = slot;
            // emit the prefill-stage events and the first token; a freshly
            // promoted sequence may already be done (max_new=1)
            self.progress_slot(idx, slots, meta);
            self.reap_slot(idx, slots, meta);
        }
    }

    /// Free every slot whose request was cancelled or whose event receiver
    /// is gone.  Runs at step boundaries, so an abort never wastes more
    /// than one decode step (or one prefill segment).
    fn abort_flagged(&mut self, slots: &mut [SlotState], meta: &mut [Option<Pending>]) {
        for idx in 0..slots.len() {
            let flagged = slots[idx].occupied_any()
                && meta[idx].as_ref().map(|p| p.flagged()).unwrap_or(false);
            if !flagged {
                continue;
            }
            if slots[idx].take_prefill().is_some() {
                // Cancelled mid-prefill: the turn never started, so there
                // is no conversation state to advance — same contract as a
                // cancel while queued.  The reservation releases on drop.
                // lint: allow(panic): a prefilling slot always carries metadata
                let mut p = meta[idx].take().expect("prefilling slot has metadata");
                p.send(Event::Error { id: p.id, error: ApiError::Cancelled });
                self.stats.cancelled.fetch_add(1, Ordering::Relaxed);
                self.finish_span(&mut p, SpanEventKind::Cancelled);
                continue;
            }
            // lint: allow(panic): the flagged check above required
            // `occupied_any()` plus present metadata, and take_prefill() just
            // returned None, so a decoding sequence is the only remaining state
            let seq = slots[idx].take().expect("occupied slot holds a sequence");
            // lint: allow(panic): same pairing invariant as above
            let mut p = meta[idx].take().expect("occupied slot has metadata");
            p.send(Event::Error { id: p.id, error: ApiError::Cancelled });
            self.stats.cancelled.fetch_add(1, Ordering::Relaxed);
            self.finish_span(&mut p, SpanEventKind::Cancelled);
            // A cancelled turn still advances its conversation: the cache
            // holds everything decoded so far.
            self.stash_session(&p, seq);
        }
    }

    fn stash_session(&mut self, p: &Pending, seq: SeqState) {
        if let Some(sid) = &p.session {
            // put() republishes the pool's sheddable gauge itself.
            locked(&self.sessions).put(sid, seq.cache, seq.next_token, p.turns + 1);
        }
    }

    /// Record `bytes` against the shared in-flight total and hand back the
    /// RAII share that returns them on drop.
    fn reserve(&self, bytes: usize) -> Reservation {
        // lint: allow(ledger): the mint half of the Reservation RAII pair —
        // the matching release lives in Reservation::drop
        self.reserved.fetch_add(bytes, Ordering::Relaxed);
        Reservation { bytes, total: Arc::clone(&self.reserved) }
    }

    /// Memory-pressure admission for a byte-budgeted pool: estimate the
    /// request's worst-case new rows (prompt + generation budget, before
    /// compression), reclaim sheddable bytes until the estimate fits, and
    /// return the RAII byte reservation the caller stores on its
    /// [`Pending`] (released on every exit path by drop).
    ///
    /// Reclaim is tiered, cheapest loss first.  **Tier 0** (only when a
    /// disk store is bound): demote cold frozen blocks to the disk tier —
    /// demotion loses no state at all, just residency, so it always runs
    /// before anything is shed.  Then **prefix-cache snapshots** (pure
    /// optimization — losing one costs a future prefill, never data), then
    /// **detached sessions** (losing one costs a stored conversation),
    /// then the typed rejection.
    ///
    /// Occupancy is judged as `resident - in-flight materialized +
    /// in-flight reservations`: running slots are charged their full
    /// worst-case estimate rather than the rows they happen to hold right
    /// now, so concurrently admitted requests can never jointly grow past
    /// the budget.  A request that could not fit even after shedding
    /// everything sheddable is rejected *without* shedding anything.
    /// That guard is best-effort, not exact: sheddable gauges count
    /// CoW-shared frozen blocks once per referencing cache (the session
    /// store's long-standing convention), so when snapshots overlap live
    /// slots or each other the guard can overestimate what shedding frees
    /// and a borderline request may still drain the tiers before its
    /// rejection — bounded waste, never an unsafe admission.  Unbudgeted
    /// pools admit everything (the default — zero overhead on that path).
    fn ensure_pool_capacity(
        &mut self,
        new_rows: usize,
        slots: &[SlotState],
        span: &mut SpanBuilder,
    ) -> Result<Reservation, String> {
        let pool = self.engine.pool().clone();
        let Some(budget) = pool.budget() else { return Ok(self.reserve(0)) };
        let (nl, nh, dh) = {
            let d = &self.engine.dims;
            (d.n_layers, d.n_kv_heads, d.d_head)
        };
        let needed = new_rows * crate::kvpool::row_bytes(nl, nh, dh);
        // Bytes already resident for in-flight work — decoding sequences
        // plus partially-ingested chunked prefills — all of it covered by
        // live reservations, so it is subtracted before adding `reserved`.
        let materialized: usize = slots
            .iter()
            .map(|s| {
                if let Some(q) = s.seq() {
                    q.cache.exact_bytes()
                } else if let Some(j) = s.prefill() {
                    j.chunked.cache_bytes()
                } else {
                    0
                }
            })
            .sum();
        loop {
            let resident = pool.resident_bytes();
            let reserved = self.reserved.load(Ordering::Relaxed);
            let effective = resident.saturating_sub(materialized) + reserved;
            if effective + needed <= budget {
                return Ok(self.reserve(needed));
            }
            // Tier 0: with a disk store bound, demote cold frozen blocks
            // before shedding anything — spill frees resident bytes at
            // zero information cost (blocks fault back in on read).
            if pool.has_store() {
                let overflow = (effective + needed).saturating_sub(budget);
                let (blocks, bytes) = pool.spill(overflow);
                if bytes > 0 {
                    self.stats.blocks_spilled.fetch_add(blocks as u64, Ordering::Relaxed);
                    // Admission stalled on this demotion; the span carries
                    // how many bytes had to move to the disk tier.
                    span.record_v(SpanEventKind::SpillStall, bytes as u64);
                    continue;
                }
            }
            let prefix_bytes =
                self.engine.prefix_cache().map(|p| p.total_bytes()).unwrap_or(0);
            let sheddable = prefix_bytes + locked(&self.sessions).total_bytes();
            if effective.saturating_sub(sheddable) + needed > budget {
                return Err(format!(
                    "{needed} bytes needed for {new_rows} rows, {effective} effectively \
                     occupied ({sheddable} sheddable) under a {budget}-byte budget"
                ));
            }
            // Tier 1: prefix-cache snapshots are the cheapest reclaim.
            if prefix_bytes > 0 {
                let shed = self.engine.prefix_cache().and_then(|p| p.shed_lru());
                if shed.is_some() {
                    self.stats.prefix_shed.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
            }
            // Tier 2: detached sessions.
            match locked(&self.sessions).shed_lru() {
                Some(_) => {
                    self.stats.sessions_shed.fetch_add(1, Ordering::Relaxed);
                }
                // Unreachable while total_bytes() > 0, but never loop on a
                // store that cannot yield bytes.
                None => {
                    return Err(format!(
                        "{needed} bytes needed for {new_rows} rows with nothing left \
                         to shed under a {budget}-byte budget"
                    ));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GenerateParams;
    use crate::telemetry::{Clock, FakeClock, Telemetry};
    use std::sync::atomic::AtomicBool;
    use std::sync::mpsc;

    /// Hermetic fake-clock pin of the span lifecycle for a chunked cold
    /// prefill: queued → admitted → prefill segments (strictly growing
    /// ingest counts, ending at the full prompt) → first token → decode
    /// steps interleaved with compression firings → done, on a monotone
    /// timeline — and the RAII queue token returns the gauge to zero.
    #[test]
    fn chunked_prefill_span_pins_the_lifecycle_order() {
        let engine = Engine::cpu_ref("llama_like").unwrap();
        let clock = Arc::new(FakeClock::new());
        let tel = Arc::new(Telemetry::with_clock(Arc::clone(&clock) as Arc<dyn Clock>));
        let stats = Arc::new(CoordStats::default());
        let mut coord =
            Coordinator::with_config(engine, SessionConfig::default(), Arc::clone(&stats));
        coord.set_telemetry(Arc::clone(&tel));

        let prompt = "the of and to in is it on as with ".repeat(16);
        let params = GenerateParams::new(prompt).lag(8).ratio(0.5).max_new(4);
        let req = params.into_request(77).unwrap();
        let prompt_tokens = coord.engine.tokenizer.encode(&req.prompt, true).len();
        assert!(
            prompt_tokens > crate::engine::DEFAULT_PREFILL_STRIDE,
            "prompt must exceed one stride to exercise chunked prefill"
        );

        let (tx, rx) = mpsc::channel::<WorkItem>();
        let (ev_tx, ev_rx) = mpsc::channel();
        tx.send(WorkItem {
            request: req,
            events: ev_tx,
            cancel: Arc::new(AtomicBool::new(false)),
            enqueued_us: tel.now_us(),
            span: tel.begin_span(77),
            queue_token: Some(stats.enqueue_token()),
        })
        .unwrap();
        assert_eq!(stats.queued.load(Ordering::Relaxed), 1, "token minted on enqueue");
        // The coordinator adopted the hub's fake clock in set_telemetry, so
        // advancing it here *is* the queue wait: admit() must measure exactly
        // this delta between the enqueue stamp and admission.
        clock.advance_us(1234);
        drop(tx);
        std::thread::spawn(move || coord.run(rx)).join().unwrap().unwrap();

        let mut new_tokens = 0;
        let mut done_timings = None;
        for ev in ev_rx.iter() {
            if let Event::Done { usage, timings } = &ev {
                new_tokens = usage.new_tokens;
                done_timings = Some(timings.clone());
            }
        }
        assert!(new_tokens >= 1, "request decoded");
        let timings = done_timings.expect("Done carries timings");
        assert_eq!(timings.queue_us, 1234, "queue wait measured on the shared fake clock");
        assert_eq!(timings.decode_us, 0, "frozen clock: no decode time can elapse");

        let spans = tel.recent_spans();
        assert_eq!(spans.len(), 1);
        let span = &spans[0];
        assert_eq!(span.id, 77);
        let kinds: Vec<SpanEventKind> = span.events.iter().map(|e| e.kind).collect();
        assert_eq!(kinds[0], SpanEventKind::Queued);
        assert_eq!(kinds[1], SpanEventKind::Admitted);
        assert_eq!(kinds.last(), Some(&SpanEventKind::Done));

        let segs: Vec<u64> = span
            .events
            .iter()
            .filter(|e| e.kind == SpanEventKind::PrefillSegment)
            .map(|e| e.value)
            .collect();
        assert!(segs.len() >= 2, "one stamp per chunked segment: {segs:?}");
        assert!(segs.windows(2).all(|w| w[0] < w[1]), "ingest counts grow: {segs:?}");
        assert_eq!(*segs.last().unwrap() as usize, prompt_tokens, "final segment = full prompt");

        let pos = |k: SpanEventKind| span.events.iter().position(|e| e.kind == k);
        let first_tok = pos(SpanEventKind::FirstToken).expect("first token stamped");
        let last_seg =
            span.events.iter().rposition(|e| e.kind == SpanEventKind::PrefillSegment).unwrap();
        assert!(last_seg < first_tok, "every prefill segment precedes the first token");
        assert!(
            pos(SpanEventKind::Compression).is_some(),
            "lag=8 over a {prompt_tokens}-token prompt must fire the driver"
        );
        let steps: Vec<u64> = span
            .events
            .iter()
            .filter(|e| e.kind == SpanEventKind::DecodeStep)
            .map(|e| e.value)
            .collect();
        assert_eq!(steps, (2..=new_tokens as u64).collect::<Vec<_>>(), "sent counts in order");
        for w in span.events.windows(2) {
            assert!(w[0].t_us <= w[1].t_us, "monotone timeline");
        }

        assert_eq!(stats.queued.load(Ordering::Relaxed), 0, "RAII token released on dequeue");
        assert_eq!(tel.dropped_events(), 0);
        let summaries = tel.summaries();
        for metric in [Metric::QueueWait, Metric::Ttft, Metric::PrefillSegment] {
            assert!(
                summaries.iter().any(|s| s.metric == metric),
                "span deltas populate {metric:?}"
            );
        }
    }

    /// The queued gauge is released exactly once per token even when items
    /// are dropped without ever reaching a coordinator (queue teardown).
    #[test]
    fn queue_tokens_release_exactly_once() {
        let stats = Arc::new(CoordStats::default());
        let tokens: Vec<QueueToken> = (0..3).map(|_| stats.enqueue_token()).collect();
        assert_eq!(stats.queued.load(Ordering::Relaxed), 3);
        drop(tokens);
        assert_eq!(stats.queued.load(Ordering::Relaxed), 0);
    }
}

//! L3 coordination: the typed serving API (events, errors, params), the
//! FCFS admission queue, the continuous batcher, the session store, and the
//! multi-model router.
//!
//! Data flow (vLLM-router-like, scaled to this testbed):
//!
//! ```text
//!   clients ──> server (TCP/ndjson or in-proc) ──> Router
//!                                                    │ per model variant
//!                                                    ▼
//!                                     Coordinator (one thread per model)
//!                                       admission queue (bounded, FCFS)
//!                                       continuous batcher over decode slots
//!                                       SessionStore (LRU+TTL, cross-turn
//!                                         reuse of the compressed KvCache)
//!                                       engine.step_batch / prefill
//! ```
//!
//! The public surface is **streaming- and session-first**:
//!
//! * [`Router::submit`] returns a [`GenHandle`] whose receiver yields typed
//!   [`Event`]s live from the continuous batcher — one `Token` per decode
//!   step, one `Compression` per partition-compression event, bracketed by
//!   `Started` and `Done`/`Error`.
//! * [`Router::generate`] folds the same events back into a [`Response`],
//!   so one-shot callers and the old tests keep working unchanged.
//! * Dropping a [`GenHandle`] mid-stream aborts the slot (the coordinator
//!   notices the dead channel at the next event); [`GenHandle::cancel`]
//!   aborts it explicitly, which is what the server's `{"cancel": id}`
//!   control line drives.
//! * A [`Request`] carrying a `session` id detaches its finished per-layer
//!   [`crate::kvcache::KvCache`] into the coordinator's [`SessionStore`];
//!   the next turn re-attaches it and prefills only the new text against
//!   the already-LagKV-compressed history (see [`session`]).
//!
//! Compression is a *per-request* property: each request carries its own
//! (policy, S, L, r), so a single deployment can serve baseline and
//! compressed traffic side by side — the integration story the paper's
//! "easy integration into the mainstream inference platform" claim implies.

pub mod batcher;
pub mod router;
pub mod session;

use std::sync::atomic::AtomicBool;
use std::sync::{mpsc, Arc};

use crate::config::{CompressionConfig, PolicyKind, ScorerBackend};
use crate::util::json::{arr, obj, s, Json};

/// Structured serving-API error.  Replaces the stringly `Response.error`;
/// every variant has a stable wire `code()` the server emits verbatim.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// The model's admission queue is at capacity; retry later.
    QueueFull { model: String },
    /// The model's KV block pool cannot fit the request even after
    /// shedding every detached session; retry later or shrink the request.
    PoolExhausted { model: String, detail: String },
    /// No coordinator serves this model variant.
    UnknownModel { model: String, have: Vec<String> },
    /// Request parameters failed validation (bad values, unknown fields).
    BadParams { message: String },
    /// The engine failed to load or a prefill/decode step errored.
    EngineFailure { message: String },
    /// The request was cancelled (explicitly, or by dropping its handle).
    Cancelled,
    /// The deployment is draining: admission is closed, in-flight work is
    /// finishing, and a shutdown follows.  Retry against another replica.
    Draining { model: String },
}

impl ApiError {
    /// Stable machine-readable code (the wire `"code"` field).
    pub fn code(&self) -> &'static str {
        match self {
            ApiError::QueueFull { .. } => "queue-full",
            ApiError::PoolExhausted { .. } => "pool-exhausted",
            ApiError::UnknownModel { .. } => "unknown-model",
            ApiError::BadParams { .. } => "bad-params",
            ApiError::EngineFailure { .. } => "engine-failure",
            ApiError::Cancelled => "cancelled",
            ApiError::Draining { .. } => "draining",
        }
    }

    /// Human-readable detail (the wire `"message"` field).
    pub fn message(&self) -> String {
        match self {
            ApiError::QueueFull { model } => {
                format!("admission queue for {model} is full")
            }
            ApiError::PoolExhausted { model, detail } => {
                format!("kv pool for {model} is exhausted: {detail}")
            }
            ApiError::UnknownModel { model, have } => {
                format!("unknown model {model:?} (have {have:?})")
            }
            ApiError::BadParams { message } => message.clone(),
            ApiError::EngineFailure { message } => message.clone(),
            ApiError::Cancelled => "request cancelled".to_string(),
            ApiError::Draining { model } => {
                format!("{model} is draining: admission closed, retry elsewhere")
            }
        }
    }

    /// Wire rendering: `{"code": ..., "message": ...}` plus the variant's
    /// structured payload fields (`model`, `detail`, `have`), so a typed
    /// client reconstructs the exact variant instead of scraping the
    /// human-readable message.  Legacy consumers keep reading only
    /// `code`/`message` — the extra fields are additive.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("code", s(self.code())), ("message", s(self.message()))];
        match self {
            ApiError::QueueFull { model } | ApiError::Draining { model } => {
                pairs.push(("model", s(model.clone())));
            }
            ApiError::PoolExhausted { model, detail } => {
                pairs.push(("model", s(model.clone())));
                pairs.push(("detail", s(detail.clone())));
            }
            ApiError::UnknownModel { model, have } => {
                pairs.push(("model", s(model.clone())));
                pairs.push(("have", arr(have.iter().map(|m| s(m.clone())).collect())));
            }
            ApiError::BadParams { .. } | ApiError::EngineFailure { .. } | ApiError::Cancelled => {}
        }
        obj(pairs)
    }

    /// Parse the wire form back into the exact variant (client SDK side).
    pub fn from_json(v: &Json) -> anyhow::Result<ApiError> {
        let code = v.get("code")?.as_str()?;
        let model = || -> anyhow::Result<String> { Ok(v.get("model")?.as_str()?.to_string()) };
        let message = || -> anyhow::Result<String> { Ok(v.get("message")?.as_str()?.to_string()) };
        Ok(match code {
            "queue-full" => ApiError::QueueFull { model: model()? },
            "pool-exhausted" => ApiError::PoolExhausted {
                model: model()?,
                detail: v.get("detail")?.as_str()?.to_string(),
            },
            "unknown-model" => ApiError::UnknownModel {
                model: model()?,
                have: v.get("have")?.as_str_vec()?,
            },
            "bad-params" => ApiError::BadParams { message: message()? },
            "engine-failure" => ApiError::EngineFailure { message: message()? },
            "cancelled" => ApiError::Cancelled,
            "draining" => ApiError::Draining { model: model()? },
            other => anyhow::bail!("unknown error code {other:?}"),
        })
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code(), self.message())
    }
}

// Makes `?` lift ApiError into anyhow::Error at call sites that want it.
impl std::error::Error for ApiError {}

/// Token accounting for one finished (or aborted) generation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Usage {
    /// Tokens prefilled from the request's *own* prompt text.  On a session
    /// resume this counts only the new turn — the reattached history is
    /// reported via `reused_tokens` instead.
    pub prompt_tokens: usize,
    pub new_tokens: usize,
    /// Tokens served from already-compressed KV instead of the backend:
    /// session history reattached from the session store, or (on a fresh
    /// request) a prompt prefix attached CoW from the radix prefix cache.
    /// 0 when nothing was reused.
    pub reused_tokens: usize,
    /// Final per-layer cache lengths (the Eq. 10 trajectory evidence).
    pub cache_lens: Vec<usize>,
    /// Partition-compression events fired over the request's lifetime.
    pub compression_events: usize,
}

/// Latency breakdown, microseconds.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Timings {
    pub queue_us: u64,
    pub prefill_us: u64,
    pub decode_us: u64,
}

/// One serving event, emitted live from the continuous batcher.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Prefill finished; decode is about to begin.
    Started { id: u64, prompt_tokens: usize, reused_tokens: usize },
    /// One decoded token.  `text_delta` is the suffix the token appended to
    /// the running text (empty for EOS); concatenating the deltas of a
    /// stream reproduces the folded `Response.text` exactly.
    Token { id: u64, token: i32, text_delta: String },
    /// One partition-compression event (Fig. 1) fired on this request's
    /// cache.  `layer_lens` is the per-layer length snapshot *after* the
    /// event; `evicted` is the number of rows it removed per head.
    Compression { id: u64, layer_lens: Vec<usize>, evicted: usize },
    /// Generation finished cleanly.
    Done { id: u64, usage: Usage, timings: Timings },
    /// Generation failed or was cancelled; terminal.
    Error { id: u64, error: ApiError },
}

impl Event {
    pub fn id(&self) -> u64 {
        match self {
            Event::Started { id, .. }
            | Event::Token { id, .. }
            | Event::Compression { id, .. }
            | Event::Done { id, .. }
            | Event::Error { id, .. } => *id,
        }
    }

    /// Does this event terminate its stream?
    pub fn is_terminal(&self) -> bool {
        matches!(self, Event::Done { .. } | Event::Error { .. })
    }
}

/// Everything a caller can set on a generation, with defaults matching
/// [`CompressionConfig::default`].  This is the one way the server parser,
/// the examples, the benches, and the harness construct requests — nothing
/// hand-mutates a `CompressionConfig` anymore.  Its wire form is
/// [`crate::api::GenerateRequest`].
#[derive(Debug, Clone, PartialEq)]
pub struct GenerateParams {
    pub model: String,
    pub prompt: String,
    pub policy: PolicyKind,
    pub sink: usize,
    pub lag: usize,
    pub ratio: f64,
    pub scorer: ScorerBackend,
    /// `None` -> the policy's default (2 for recursive-L2, else 0).
    pub skip_layers: Option<usize>,
    pub max_new: usize,
    pub seed: u64,
    /// Conversation key for cross-turn KV-cache reuse.
    pub session: Option<String>,
}

impl Default for GenerateParams {
    fn default() -> Self {
        let c = CompressionConfig::default();
        GenerateParams {
            model: "llama_like".to_string(),
            prompt: String::new(),
            policy: c.policy,
            sink: c.sink,
            lag: c.lag,
            ratio: c.ratio,
            scorer: c.scorer,
            skip_layers: None,
            max_new: 72,
            seed: 0,
            session: None,
        }
    }
}

impl GenerateParams {
    pub fn new(prompt: impl Into<String>) -> GenerateParams {
        GenerateParams { prompt: prompt.into(), ..Default::default() }
    }

    pub fn model(mut self, model: &str) -> Self {
        self.model = model.to_string();
        self
    }

    pub fn policy(mut self, policy: PolicyKind) -> Self {
        self.policy = policy;
        self
    }

    pub fn sink(mut self, sink: usize) -> Self {
        self.sink = sink;
        self
    }

    pub fn lag(mut self, lag: usize) -> Self {
        self.lag = lag;
        self
    }

    pub fn ratio(mut self, ratio: f64) -> Self {
        self.ratio = ratio;
        self
    }

    pub fn scorer(mut self, scorer: ScorerBackend) -> Self {
        self.scorer = scorer;
        self
    }

    pub fn skip_layers(mut self, n_layers: usize) -> Self {
        self.skip_layers = Some(n_layers);
        self
    }

    pub fn max_new(mut self, max_new: usize) -> Self {
        self.max_new = max_new;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn session(mut self, id: impl Into<String>) -> Self {
        self.session = Some(id.into());
        self
    }

    /// The compression knobs as the driver-level config.
    pub fn compression(&self) -> CompressionConfig {
        let skip = self.skip_layers.unwrap_or(match self.policy {
            PolicyKind::L2Norm => 2,
            _ => 0,
        });
        CompressionConfig {
            policy: self.policy,
            sink: self.sink,
            lag: self.lag,
            ratio: self.ratio,
            scorer: self.scorer,
            skip_layers: skip,
        }
    }

    pub fn validate(&self) -> Result<(), ApiError> {
        if self.prompt.is_empty() && self.session.is_none() {
            return Err(ApiError::BadParams {
                message: "prompt must be non-empty (or carry a session id)".to_string(),
            });
        }
        self.compression()
            .validate()
            .map_err(|e| ApiError::BadParams { message: format!("{e:#}") })
    }

    /// Validate and produce the queued request form.
    pub fn into_request(self, id: u64) -> Result<Request, ApiError> {
        self.validate()?;
        let compression = self.compression();
        Ok(Request {
            id,
            prompt: self.prompt,
            compression,
            max_new: self.max_new,
            seed: self.seed,
            session: self.session,
        })
    }

}

/// A generation request as queued at a coordinator.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub compression: CompressionConfig,
    pub max_new: usize,
    /// Random seed for seeded policies.
    pub seed: u64,
    /// Conversation key: reattach this session's compressed cache before
    /// prefill and detach it back into the store afterwards.
    pub session: Option<String>,
}

/// A finished generation, as folded from an event stream.  Its wire form
/// lives in [`crate::api`] (`response_to_json` / `response_from_json`).
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    pub reused_tokens: usize,
    pub cache_lens: Vec<usize>,
    pub compression_events: usize,
    /// Queue wait + prefill + decode, microseconds.
    pub queue_us: u64,
    pub prefill_us: u64,
    pub decode_us: u64,
    pub error: Option<ApiError>,
}

impl Response {
    fn empty(id: u64) -> Response {
        Response {
            id,
            text: String::new(),
            tokens: vec![],
            prompt_tokens: 0,
            reused_tokens: 0,
            cache_lens: vec![],
            compression_events: 0,
            queue_us: 0,
            prefill_us: 0,
            decode_us: 0,
            error: None,
        }
    }

    pub fn from_error(id: u64, error: ApiError) -> Response {
        Response { error: Some(error), ..Response::empty(id) }
    }

    /// Fold an event stream back into the one-shot response shape.  The
    /// stream may be partial (terminal event missing == engine failure).
    pub fn from_events<I: IntoIterator<Item = Event>>(events: I) -> Response {
        let mut r = Response::empty(0);
        let mut terminal = false;
        for ev in events {
            r.id = ev.id();
            match ev {
                Event::Started { prompt_tokens, reused_tokens, .. } => {
                    r.prompt_tokens = prompt_tokens;
                    r.reused_tokens = reused_tokens;
                }
                Event::Token { token, text_delta, .. } => {
                    r.tokens.push(token);
                    r.text.push_str(&text_delta);
                }
                Event::Compression { .. } => {
                    r.compression_events += 1;
                }
                Event::Done { usage, timings, .. } => {
                    r.prompt_tokens = usage.prompt_tokens;
                    r.reused_tokens = usage.reused_tokens;
                    r.cache_lens = usage.cache_lens;
                    r.compression_events = usage.compression_events;
                    r.queue_us = timings.queue_us;
                    r.prefill_us = timings.prefill_us;
                    r.decode_us = timings.decode_us;
                    terminal = true;
                }
                Event::Error { error, .. } => {
                    r.error = Some(error);
                    terminal = true;
                }
            }
            if terminal {
                break;
            }
        }
        if !terminal && r.error.is_none() {
            r.error = Some(ApiError::EngineFailure {
                message: "event stream ended without Done/Error".to_string(),
            });
        }
        r
    }
}

/// A queued unit: request, its live event channel, its cancel flag, the
/// enqueue timestamp, and the request's telemetry recorders.
pub struct WorkItem {
    pub request: Request,
    pub events: mpsc::Sender<Event>,
    pub cancel: Arc<AtomicBool>,
    /// Coordinator-clock reading (µs) when the item was enqueued, stamped
    /// from the model's telemetry clock (0 for hub-less coordinators);
    /// `admit()` subtracts it on the same clock to get the queue wait.
    pub enqueued_us: u64,
    /// Span recorder the batcher stamps through the slot lifecycle.
    /// [`SpanBuilder::disabled`] for direct-fed coordinators (tests).
    ///
    /// [`SpanBuilder::disabled`]: crate::telemetry::SpanBuilder::disabled
    pub span: crate::telemetry::SpanBuilder,
    /// RAII claim on the `queued` gauge (see [`CoordStats::enqueue_token`]);
    /// `None` when the item bypassed the router's accounting.
    pub queue_token: Option<batcher::QueueToken>,
}

pub use batcher::{CoordStats, Coordinator, QueueToken};
pub use router::{GenHandle, Router, RouterConfig};
pub use session::{SessionConfig, SessionStore, SessionSummary};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn api_error_codes_are_stable() {
        let errs = [
            ApiError::QueueFull { model: "m".into() },
            ApiError::PoolExhausted { model: "m".into(), detail: "z".into() },
            ApiError::UnknownModel { model: "m".into(), have: vec!["a".into()] },
            ApiError::BadParams { message: "x".into() },
            ApiError::EngineFailure { message: "y".into() },
            ApiError::Cancelled,
            ApiError::Draining { model: "m".into() },
        ];
        let codes: Vec<&str> = errs.iter().map(|e| e.code()).collect();
        assert_eq!(
            codes,
            vec![
                "queue-full",
                "pool-exhausted",
                "unknown-model",
                "bad-params",
                "engine-failure",
                "cancelled",
                "draining"
            ]
        );
        for e in &errs {
            let j = e.to_json();
            assert_eq!(j.get("code").unwrap().as_str().unwrap(), e.code());
            assert!(!e.message().is_empty());
            // the structured payload round-trips to the exact variant
            assert_eq!(&ApiError::from_json(&j).unwrap(), e);
        }
        assert!(ApiError::from_json(&Json::parse(r#"{"code":"nope"}"#).unwrap()).is_err());
    }

    #[test]
    fn params_builder_defaults_and_compression() {
        let p = GenerateParams::new("hi").lag(32).ratio(0.25).policy(PolicyKind::L2Norm);
        let c = p.compression();
        assert_eq!(c.lag, 32);
        assert_eq!(c.ratio, 0.25);
        assert_eq!(c.skip_layers, 2, "L2Norm defaults to skipping 2 layers");
        let c2 = p.clone().skip_layers(0).compression();
        assert_eq!(c2.skip_layers, 0, "explicit skip_layers wins");
        let req = p.into_request(7).unwrap();
        assert_eq!(req.id, 7);
        assert_eq!(req.prompt, "hi");
    }

    #[test]
    fn params_validation_rejects_bad_values() {
        let bad = GenerateParams::new("x").ratio(0.0);
        assert_eq!(bad.validate().unwrap_err().code(), "bad-params");
        let empty = GenerateParams::new("");
        assert_eq!(empty.validate().unwrap_err().code(), "bad-params");
        // empty prompt is fine on a session resume
        assert!(GenerateParams::new("").session("s1").validate().is_ok());
    }

    #[test]
    fn fold_reconstructs_response_from_events() {
        let events = vec![
            Event::Started { id: 9, prompt_tokens: 5, reused_tokens: 0 },
            Event::Token { id: 9, token: 1200, text_delta: "the".into() },
            Event::Compression { id: 9, layer_lens: vec![8, 8], evicted: 4 },
            Event::Token { id: 9, token: 1201, text_delta: " of".into() },
            Event::Done {
                id: 9,
                usage: Usage {
                    prompt_tokens: 5,
                    new_tokens: 2,
                    reused_tokens: 0,
                    cache_lens: vec![8, 8],
                    compression_events: 1,
                },
                timings: Timings { queue_us: 1, prefill_us: 2, decode_us: 3 },
            },
        ];
        let r = Response::from_events(events);
        assert_eq!(r.id, 9);
        assert_eq!(r.text, "the of");
        assert_eq!(r.tokens, vec![1200, 1201]);
        assert_eq!(r.compression_events, 1);
        assert_eq!(r.cache_lens, vec![8, 8]);
        assert_eq!(r.decode_us, 3);
        assert!(r.error.is_none());
    }

    #[test]
    fn fold_without_terminal_event_is_an_engine_failure() {
        let r = Response::from_events(vec![Event::Started {
            id: 2,
            prompt_tokens: 1,
            reused_tokens: 0,
        }]);
        assert_eq!(r.error.as_ref().unwrap().code(), "engine-failure");
    }

    #[test]
    fn fold_stops_at_terminal_error() {
        let r = Response::from_events(vec![
            Event::Started { id: 4, prompt_tokens: 1, reused_tokens: 0 },
            Event::Error { id: 4, error: ApiError::Cancelled },
            Event::Token { id: 4, token: 1, text_delta: "never".into() },
        ]);
        assert_eq!(r.error, Some(ApiError::Cancelled));
        assert!(r.tokens.is_empty(), "events after the terminal one are ignored");
    }
}

//! L3 coordination: request types, the FCFS admission queue, the
//! continuous batcher, and the multi-model router.
//!
//! Data flow (vLLM-router-like, scaled to this testbed):
//!
//! ```text
//!   clients ──> server (TCP/json or in-proc) ──> Router
//!                                                  │ per model variant
//!                                                  ▼
//!                                   Coordinator (one thread per model)
//!                                     admission queue (bounded, FCFS)
//!                                     continuous batcher over decode slots
//!                                     engine.step_batch / prefill
//! ```
//!
//! Compression is a *per-request* property: each request carries its own
//! (policy, S, L, r), so a single deployment can serve baseline and
//! compressed traffic side by side — the integration story the paper's
//! "easy integration into the mainstream inference platform" claim implies.

pub mod batcher;
pub mod router;

use std::sync::mpsc;

use crate::config::CompressionConfig;

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub compression: CompressionConfig,
    pub max_new: usize,
    /// Random seed for seeded policies.
    pub seed: u64,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    pub cache_lens: Vec<usize>,
    pub compression_events: usize,
    /// Queue wait + prefill + decode, microseconds.
    pub queue_us: u64,
    pub prefill_us: u64,
    pub decode_us: u64,
    pub error: Option<String>,
}

/// A queued unit: request plus its response channel and enqueue timestamp.
pub struct WorkItem {
    pub request: Request,
    pub respond: mpsc::Sender<Response>,
    pub enqueued: std::time::Instant,
}

impl Response {
    pub fn error(id: u64, msg: &str) -> Response {
        Response {
            id,
            text: String::new(),
            tokens: vec![],
            prompt_tokens: 0,
            cache_lens: vec![],
            compression_events: 0,
            queue_us: 0,
            prefill_us: 0,
            decode_us: 0,
            error: Some(msg.to_string()),
        }
    }
}

pub use batcher::Coordinator;
pub use router::Router;

//! Cross-turn session store: keeps a finished request's per-layer
//! [`KvCache`] — sink rows, compressed survivors, uncompressed tail,
//! per-head positions and accumulated attention mass, all intact — so the
//! next turn of the conversation prefills only its *new* text against an
//! already-LagKV-compressed history.
//!
//! This is where an attention-free eviction policy earns its keep in a
//! serving stack: the detached cache needs no attention statistics to stay
//! compressible, so a turn can resume under any policy and the Eq. 10
//! length trajectory simply continues from where turn N left off.
//!
//! A detached cache's frozen prefix lives in refcounted pool blocks
//! (see [`crate::kvpool`]): detach and re-attach move the cache without
//! copying, and any clone shares the blocks copy-on-write.  The store's
//! resident bytes are therefore exact, which makes them enforceable.
//!
//! The store is bounded three ways: a capacity cap (LRU eviction once
//! full), a TTL (entries expire `ttl` after their last use), and a
//! resident-byte budget (`max_bytes`; LRU eviction until under).  All
//! bounds are enforced on every mutation, and the coordinator can also
//! [`SessionStore::shed_lru`] explicitly under pool pressure.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use crate::kvcache::KvCache;
use crate::kvpool::BlockPool;
use crate::kvstore::KvStore;
use crate::telemetry::{Clock, MonotonicClock};
use crate::util::json::{self, Json};

/// Store bounds.  `capacity == 0` disables session persistence entirely
/// (requests still run; their caches are simply dropped at the end).
/// `max_bytes == 0` leaves the byte budget uncapped.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub capacity: usize,
    pub ttl: Duration,
    /// Total resident-byte cap across every stored cache (exact pool
    /// accounting).  Enforced on every `put` by LRU eviction; the entry
    /// `capacity` stays as a secondary limit.
    pub max_bytes: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { capacity: 64, ttl: Duration::from_secs(600), max_bytes: 0 }
    }
}

/// One detached conversation: the compressed cache plus the token the last
/// turn generated but never appended (decode always runs one token behind
/// generation), which the next turn must feed first so the cache matches
/// the equivalent concatenated prompt exactly.
pub struct SessionEntry {
    pub cache: KvCache,
    pub pending: i32,
    pub turns: u32,
    /// Store-clock reading (µs) at the last take/put — LRU order and TTL
    /// age are judged on the store's [`Clock`].
    last_used_us: u64,
}

impl SessionEntry {
    /// The token feed a resume must run through the decode path: the
    /// stored pending token first (so the cache trajectory matches the
    /// equivalent concatenated prompt exactly), then the new turn's ids.
    pub fn resume_feed(&self, ids: &[i32]) -> Vec<i32> {
        let mut feed = Vec::with_capacity(1 + ids.len());
        feed.push(self.pending);
        feed.extend_from_slice(ids);
        feed
    }
}

/// Accounting view of one stored session, as reported by the control
/// plane's `sessions` op (see [`crate::api`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionSummary {
    pub id: String,
    /// Conversation turns completed so far.
    pub turns: u32,
    /// Retained KV rows summed over layers.
    pub rows: usize,
    /// Exact resident bytes (frozen pool blocks + loose tails).
    pub bytes: usize,
}

pub struct SessionStore {
    cfg: SessionConfig,
    map: HashMap<String, SessionEntry>,
    /// When bound, the store publishes its resident bytes to this pool's
    /// sheddable-bytes gauge after *every* mutation — take, put (including
    /// its byte-cap and TTL evictions), and explicit shedding — so the
    /// router's `hard_pressure` pre-queue check never judges admission on
    /// stale sheddable bytes.
    pool: Option<Arc<BlockPool>>,
    /// When bound, completed-turn `put`s persist the session's cache to
    /// the store and every eviction path journals a remove (see
    /// [`SessionStore::bind_journal`]).
    journal: Option<Arc<KvStore>>,
    /// Time source for TTL expiry and LRU ordering; monotonic in
    /// production, swappable for fake-clock tests.
    clock: Arc<dyn Clock>,
}

impl SessionStore {
    pub fn new(cfg: SessionConfig) -> SessionStore {
        SessionStore {
            cfg,
            map: HashMap::new(),
            pool: None,
            journal: None,
            clock: Arc::new(MonotonicClock::new()),
        }
    }

    /// Bind the pool whose sheddable gauge mirrors this store.
    pub fn bind_pool(&mut self, pool: Arc<BlockPool>) {
        self.pool = Some(pool);
        self.publish();
    }

    /// Bind the durability journal: from now on every `put` persists the
    /// session to `store`, and every eviction — explicit removal, LRU
    /// shedding, byte-cap eviction, TTL expiry — journals a remove so a
    /// restart can never resurrect a session this store already let go
    /// of.  `take` deliberately journals nothing: a crash between a take
    /// and the turn's closing `put` resumes from the last *completed*
    /// turn (the put supersedes the old descriptor atomically).
    pub fn bind_journal(&mut self, store: Arc<KvStore>) {
        self.journal = Some(store);
    }

    fn journal_put(&self, id: &str) {
        let (Some(store), Some(entry)) = (&self.journal, self.map.get(id)) else { return };
        match entry.cache.persist(store) {
            Ok(mut desc) => {
                if let Json::Obj(map) = &mut desc {
                    map.insert("pending".to_string(), json::n(entry.pending as f64));
                    map.insert("turns".to_string(), json::n(entry.turns as f64));
                }
                if let Err(e) = store.journal_session_put(id, desc) {
                    eprintln!("sessions: failed to journal {id:?}: {e:#}");
                }
            }
            Err(e) => eprintln!("sessions: failed to persist {id:?}: {e:#}"),
        }
    }

    fn journal_remove(&self, id: &str) {
        if let Some(store) = &self.journal {
            if let Err(e) = store.journal_session_remove(id) {
                eprintln!("sessions: failed to journal removal of {id:?}: {e:#}");
            }
        }
    }

    fn publish(&self) {
        if let Some(pool) = &self.pool {
            pool.set_sheddable(self.total_bytes());
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Total KV rows currently held across all sessions (accounting).
    pub fn total_rows(&self) -> usize {
        self.map.values().map(|e| e.cache.total_rows()).sum()
    }

    /// Exact resident bytes held across all sessions (frozen pool blocks
    /// plus loose tails, including the pos/attn side arrays).
    pub fn total_bytes(&self) -> usize {
        self.map.values().map(|e| e.cache.exact_bytes()).sum()
    }

    /// Detach a session's cache for reattachment.  Removes the entry; the
    /// caller owns the cache until it `put`s an updated one back.
    pub fn take(&mut self, id: &str) -> Option<SessionEntry> {
        self.purge_expired();
        let entry = self.map.remove(id);
        self.publish();
        entry
    }

    /// Drop a stored session outright (the control plane's
    /// `sessions`+`delete` op).  Returns whether the id was resident.
    pub fn remove(&mut self, id: &str) -> bool {
        let removed = self.map.remove(id).is_some();
        if removed {
            self.journal_remove(id);
            self.publish();
        }
        removed
    }

    /// Accounting snapshot of every stored session, sorted by id (the
    /// control plane's `sessions` listing).
    pub fn summaries(&self) -> Vec<SessionSummary> {
        let mut out: Vec<SessionSummary> = self
            .map
            .iter()
            .map(|(id, e)| SessionSummary {
                id: id.clone(),
                turns: e.turns,
                rows: e.cache.total_rows(),
                bytes: e.cache.exact_bytes(),
            })
            .collect();
        out.sort_by(|a, b| a.id.cmp(&b.id));
        out
    }

    /// Evict the least-recently-used session (memory-pressure shedding).
    /// Returns the shed id and the bytes it freed.
    pub fn shed_lru(&mut self) -> Option<(String, usize)> {
        let key = self.lru_key()?;
        let entry = self.map.remove(&key)?;
        let bytes = entry.cache.exact_bytes();
        drop(entry);
        self.journal_remove(&key);
        self.publish();
        Some((key, bytes))
    }

    /// Attach (or re-attach) a finished turn's cache under `id`.  Enforces
    /// the TTL, the capacity cap, and the byte budget (evicting least-
    /// recently-used entries while over either limit; an entry that alone
    /// exceeds the byte budget is dropped outright).
    pub fn put(&mut self, id: &str, cache: KvCache, pending: i32, turns: u32) {
        if self.cfg.capacity == 0 {
            return;
        }
        // A cache that alone busts the byte budget is dropped outright —
        // never at the expense of the innocent sessions already stored.
        if self.cfg.max_bytes > 0 && cache.exact_bytes() > self.cfg.max_bytes {
            return;
        }
        self.purge_expired();
        while !self.map.contains_key(id) && self.map.len() >= self.cfg.capacity {
            if let Some(key) = self.lru_key() {
                self.map.remove(&key);
                self.journal_remove(&key);
            } else {
                break;
            }
        }
        let entry = SessionEntry { cache, pending, turns, last_used_us: self.clock.now_us() };
        self.map.insert(id.to_string(), entry);
        if self.cfg.max_bytes > 0 {
            while self.total_bytes() > self.cfg.max_bytes && !self.map.is_empty() {
                if let Some(key) = self.lru_key() {
                    self.map.remove(&key);
                    self.journal_remove(&key);
                } else {
                    break;
                }
            }
        }
        // Journal last: the byte-cap loop above may have evicted the very
        // entry being put (when it is itself the LRU), and eviction order
        // in the journal must match eviction order in memory.
        if self.map.contains_key(id) {
            self.journal_put(id);
        }
        self.publish();
    }

    /// Insert a session rebuilt from the journal at boot.  Does not
    /// re-journal (the bound store already holds this exact descriptor)
    /// and does not enforce caps — the inventory was legal when
    /// journaled, and TTL age restarts from boot.
    pub fn restore(&mut self, id: &str, cache: KvCache, pending: i32, turns: u32) {
        if self.cfg.capacity == 0 {
            return;
        }
        let entry = SessionEntry { cache, pending, turns, last_used_us: self.clock.now_us() };
        self.map.insert(id.to_string(), entry);
        self.publish();
    }

    fn lru_key(&self) -> Option<String> {
        self.map.iter().min_by_key(|(_, e)| e.last_used_us).map(|(k, _)| k.clone())
    }

    fn purge_expired(&mut self) {
        let ttl_us = self.cfg.ttl.as_micros() as u64;
        let now_us = self.clock.now_us();
        // Collect-then-remove (not `retain`) so every expired *journaled*
        // session gets its remove record too — a TTL eviction that only
        // dropped the in-memory entry would resurrect on replay.
        let expired: Vec<String> = self
            .map
            .iter()
            .filter(|(_, e)| now_us.saturating_sub(e.last_used_us) > ttl_us)
            .map(|(k, _)| k.clone())
            .collect();
        for id in expired {
            self.map.remove(&id);
            self.journal_remove(&id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache_with_rows(n: usize) -> KvCache {
        let mut c = KvCache::new(1, 1, 2);
        for t in 0..n {
            c.append_token(&[0.0, 0.0], &[0.0, 0.0], t as i32).unwrap();
        }
        c
    }

    /// Bytes `cache_with_rows(n)` occupies: one (layer, head), d = 2.
    fn row_cost() -> usize {
        crate::kvpool::row_bytes(1, 1, 2)
    }

    fn store(capacity: usize, ttl: Duration) -> SessionStore {
        SessionStore::new(SessionConfig { capacity, ttl, max_bytes: 0 })
    }

    fn byte_store(capacity: usize, max_bytes: usize) -> SessionStore {
        SessionStore::new(SessionConfig {
            capacity,
            ttl: Duration::from_secs(60),
            max_bytes,
        })
    }

    #[test]
    fn take_detaches_and_put_reattaches() {
        let mut st = store(4, Duration::from_secs(60));
        st.put("a", cache_with_rows(7), 42, 1);
        assert_eq!(st.len(), 1);
        assert_eq!(st.total_rows(), 7);
        assert_eq!(st.total_bytes(), 7 * row_cost());
        let e = st.take("a").unwrap();
        assert_eq!(e.pending, 42);
        assert_eq!(e.turns, 1);
        assert_eq!(e.cache.appended, 7);
        assert!(st.is_empty(), "take removes the entry");
        assert!(st.take("a").is_none());
        assert_eq!(st.total_bytes(), 0);
    }

    #[test]
    fn capacity_cap_evicts_lru() {
        let mut st = store(2, Duration::from_secs(60));
        st.put("a", cache_with_rows(1), 0, 1);
        std::thread::sleep(Duration::from_millis(2));
        st.put("b", cache_with_rows(1), 0, 1);
        std::thread::sleep(Duration::from_millis(2));
        // refresh "a" so "b" becomes the LRU victim
        let e = st.take("a").unwrap();
        st.put("a", e.cache, e.pending, e.turns + 1);
        std::thread::sleep(Duration::from_millis(2));
        st.put("c", cache_with_rows(1), 0, 1);
        assert_eq!(st.len(), 2);
        assert!(st.take("b").is_none(), "LRU entry evicted");
        assert!(st.take("a").is_some());
        assert!(st.take("c").is_some());
    }

    #[test]
    fn ttl_expires_entries() {
        let mut st = store(4, Duration::from_millis(1));
        st.put("a", cache_with_rows(1), 0, 1);
        std::thread::sleep(Duration::from_millis(5));
        assert!(st.take("a").is_none(), "expired entry is gone");
    }

    #[test]
    fn zero_capacity_disables_persistence() {
        let mut st = store(0, Duration::from_secs(60));
        st.put("a", cache_with_rows(1), 0, 1);
        assert!(st.is_empty());
        assert!(st.take("a").is_none());
    }

    #[test]
    fn updating_existing_key_never_evicts_others() {
        let mut st = store(2, Duration::from_secs(60));
        st.put("a", cache_with_rows(1), 0, 1);
        st.put("b", cache_with_rows(1), 0, 1);
        st.put("a", cache_with_rows(2), 1, 2);
        assert_eq!(st.len(), 2);
        assert!(st.take("b").is_some(), "re-putting a live key keeps the other");
        assert_eq!(st.take("a").unwrap().cache.appended, 2);
    }

    #[test]
    fn byte_budget_evicts_lru_until_under() {
        // budget = 10 rows worth; three 4-row sessions exceed it by one.
        let mut st = byte_store(16, 10 * row_cost());
        st.put("a", cache_with_rows(4), 0, 1);
        std::thread::sleep(Duration::from_millis(2));
        st.put("b", cache_with_rows(4), 0, 1);
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(st.len(), 2, "8 rows fit a 10-row budget");
        st.put("c", cache_with_rows(4), 0, 1);
        assert_eq!(st.len(), 2, "the LRU entry pays for the newcomer");
        assert!(st.take("a").is_none(), "oldest entry shed for bytes");
        assert!(st.take("b").is_some());
        assert!(st.take("c").is_some());
    }

    #[test]
    fn oversized_entry_is_dropped_outright() {
        let mut st = byte_store(16, 3 * row_cost());
        st.put("small", cache_with_rows(2), 0, 1);
        st.put("big", cache_with_rows(10), 0, 1);
        assert_eq!(st.len(), 1, "an entry that alone busts the budget is not kept");
        assert_eq!(st.total_bytes(), 2 * row_cost());
        assert!(
            st.take("small").is_some(),
            "stored sessions must survive an oversized put"
        );
        assert!(st.take("big").is_none());
    }

    #[test]
    fn entry_and_byte_caps_interact() {
        // capacity 2 (secondary limit) with a byte budget of 6 rows.
        let mut st = byte_store(2, 6 * row_cost());
        st.put("a", cache_with_rows(2), 0, 1);
        std::thread::sleep(Duration::from_millis(2));
        st.put("b", cache_with_rows(2), 0, 1);
        std::thread::sleep(Duration::from_millis(2));
        // entry cap evicts "a" even though 6 rows would fit the bytes
        st.put("c", cache_with_rows(2), 0, 1);
        assert_eq!(st.len(), 2);
        assert!(st.take("a").is_none(), "entry cap still enforced");
        // byte cap evicts even under the entry cap: a 5-row entry next to
        // a 2-row one busts 6 rows, so the LRU ("b") goes.
        std::thread::sleep(Duration::from_millis(2));
        st.put("d", cache_with_rows(5), 0, 1);
        assert_eq!(st.len(), 1, "byte budget evicted below the entry cap");
        assert!(st.take("b").is_none());
        assert!(st.take("c").is_none(), "both older entries shed to fit 5 rows");
        assert!(st.take("d").is_some());
    }

    #[test]
    fn bound_pool_gauge_tracks_every_mutation() {
        let pool = BlockPool::unbounded(4);
        // byte cap of 6 rows so put-time eviction fires too
        let mut st = byte_store(16, 6 * row_cost());
        st.bind_pool(pool.clone());
        assert_eq!(pool.sheddable_bytes(), 0);
        st.put("a", cache_with_rows(4), 0, 1);
        assert_eq!(pool.sheddable_bytes(), 4 * row_cost(), "put publishes");
        std::thread::sleep(Duration::from_millis(2));
        st.put("b", cache_with_rows(4), 0, 1);
        assert_eq!(
            pool.sheddable_bytes(),
            4 * row_cost(),
            "byte-cap eviction inside put republishes (a was evicted)"
        );
        let e = st.take("b").unwrap();
        assert_eq!(pool.sheddable_bytes(), 0, "take publishes the detached bytes");
        st.put("b", e.cache, e.pending, e.turns);
        st.shed_lru().unwrap();
        assert_eq!(pool.sheddable_bytes(), 0, "shed_lru republishes immediately");
    }

    #[test]
    fn summaries_and_remove_drive_the_sessions_op() {
        let pool = BlockPool::unbounded(4);
        let mut st = store(4, Duration::from_secs(60));
        st.bind_pool(pool.clone());
        st.put("b", cache_with_rows(3), 0, 2);
        st.put("a", cache_with_rows(5), 0, 1);
        let sums = st.summaries();
        assert_eq!(sums.len(), 2);
        assert_eq!(sums[0].id, "a", "summaries are sorted by id");
        assert_eq!(sums[0].turns, 1);
        assert_eq!(sums[0].rows, 5);
        assert_eq!(sums[0].bytes, 5 * row_cost());
        assert_eq!(sums[1].id, "b");
        assert!(st.remove("a"), "resident id removes");
        assert!(!st.remove("a"), "gone id reports false");
        assert_eq!(st.len(), 1);
        assert_eq!(
            pool.sheddable_bytes(),
            3 * row_cost(),
            "remove republishes the sheddable gauge"
        );
    }

    /// Every eviction path of a *journaled* session must append a remove
    /// record — otherwise replay resurrects sessions this store already
    /// let go of (TTL expiry was the original offender: it used `retain`
    /// and never told the journal).
    #[test]
    fn journaled_evictions_append_remove_records() {
        use crate::kvstore::{testutil::TempDir, KvStore};
        let dir = TempDir::new("sessions-journal");
        let kv = Arc::new(KvStore::open(dir.path()).unwrap());
        let mut st = store(2, Duration::from_millis(1));
        st.bind_journal(Arc::clone(&kv));
        st.put("a", cache_with_rows(2), 0, 1);
        assert_eq!(kv.inventory_counts().0, 1, "put journals the session");
        std::thread::sleep(Duration::from_millis(5));
        // the next put's TTL purge expires "a"
        st.put("b", cache_with_rows(2), 7, 1);
        assert!(st.take("a").is_none());
        assert_eq!(kv.inventory_counts().0, 1, "TTL eviction journaled its remove");
        // re-put the taken "b" (take journals nothing; put supersedes),
        // then shed it: the journal must drop to empty
        let e = st.take("b").unwrap();
        st.put("b", e.cache, e.pending, e.turns);
        st.shed_lru().unwrap();
        assert_eq!(kv.inventory_counts(), (0, 0, 0), "shed released every payload");
        st.put("c", cache_with_rows(2), 0, 1);
        assert!(st.remove("c"));
        drop(st);
        drop(kv);
        let reopened = KvStore::open(dir.path()).unwrap();
        assert_eq!(reopened.inventory_counts().0, 0, "replay resurrects nothing");
    }

    #[test]
    fn shed_lru_reports_freed_bytes() {
        let mut st = store(4, Duration::from_secs(60));
        assert!(st.shed_lru().is_none(), "empty store has nothing to shed");
        st.put("a", cache_with_rows(3), 0, 1);
        std::thread::sleep(Duration::from_millis(2));
        st.put("b", cache_with_rows(5), 0, 1);
        let (id, bytes) = st.shed_lru().unwrap();
        assert_eq!(id, "a");
        assert_eq!(bytes, 3 * row_cost());
        assert_eq!(st.len(), 1);
        assert_eq!(st.total_bytes(), 5 * row_cost());
    }
}

//! Cross-turn session store: keeps a finished request's per-layer
//! [`KvCache`] — sink rows, compressed survivors, uncompressed tail,
//! per-head positions and accumulated attention mass, all intact — so the
//! next turn of the conversation prefills only its *new* text against an
//! already-LagKV-compressed history.
//!
//! This is where an attention-free eviction policy earns its keep in a
//! serving stack: the detached cache needs no attention statistics to stay
//! compressible, so a turn can resume under any policy and the Eq. 10
//! length trajectory simply continues from where turn N left off.
//!
//! The store is bounded two ways: a capacity cap (LRU eviction once full)
//! and a TTL (entries expire `ttl` after their last use).  Both bounds are
//! enforced on every mutation, so the store can never grow past
//! `capacity` entries regardless of traffic shape.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::kvcache::KvCache;

/// Store bounds.  `capacity == 0` disables session persistence entirely
/// (requests still run; their caches are simply dropped at the end).
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub capacity: usize,
    pub ttl: Duration,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { capacity: 64, ttl: Duration::from_secs(600) }
    }
}

/// One detached conversation: the compressed cache plus the token the last
/// turn generated but never appended (decode always runs one token behind
/// generation), which the next turn must feed first so the cache matches
/// the equivalent concatenated prompt exactly.
pub struct SessionEntry {
    pub cache: KvCache,
    pub pending: i32,
    pub turns: u32,
    last_used: Instant,
}

pub struct SessionStore {
    cfg: SessionConfig,
    map: HashMap<String, SessionEntry>,
}

impl SessionStore {
    pub fn new(cfg: SessionConfig) -> SessionStore {
        SessionStore { cfg, map: HashMap::new() }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Total KV rows currently held across all sessions (accounting).
    pub fn total_rows(&self) -> usize {
        self.map.values().map(|e| e.cache.total_rows()).sum()
    }

    /// Detach a session's cache for reattachment.  Removes the entry; the
    /// caller owns the cache until it `put`s an updated one back.
    pub fn take(&mut self, id: &str) -> Option<SessionEntry> {
        self.purge_expired();
        self.map.remove(id)
    }

    /// Attach (or re-attach) a finished turn's cache under `id`.  Enforces
    /// the TTL and the capacity cap (evicting the least-recently-used
    /// entry when full).
    pub fn put(&mut self, id: &str, cache: KvCache, pending: i32, turns: u32) {
        if self.cfg.capacity == 0 {
            return;
        }
        self.purge_expired();
        while !self.map.contains_key(id) && self.map.len() >= self.cfg.capacity {
            if let Some(key) = self.lru_key() {
                self.map.remove(&key);
            } else {
                break;
            }
        }
        let entry = SessionEntry { cache, pending, turns, last_used: Instant::now() };
        self.map.insert(id.to_string(), entry);
    }

    fn lru_key(&self) -> Option<String> {
        self.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| k.clone())
    }

    fn purge_expired(&mut self) {
        let ttl = self.cfg.ttl;
        let now = Instant::now();
        self.map.retain(|_, e| now.duration_since(e.last_used) <= ttl);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache_with_rows(n: usize) -> KvCache {
        let mut c = KvCache::new(1, 1, 2);
        for t in 0..n {
            c.append_token(&[0.0, 0.0], &[0.0, 0.0], t as i32).unwrap();
        }
        c
    }

    fn store(capacity: usize, ttl: Duration) -> SessionStore {
        SessionStore::new(SessionConfig { capacity, ttl })
    }

    #[test]
    fn take_detaches_and_put_reattaches() {
        let mut st = store(4, Duration::from_secs(60));
        st.put("a", cache_with_rows(7), 42, 1);
        assert_eq!(st.len(), 1);
        assert_eq!(st.total_rows(), 7);
        let e = st.take("a").unwrap();
        assert_eq!(e.pending, 42);
        assert_eq!(e.turns, 1);
        assert_eq!(e.cache.appended, 7);
        assert!(st.is_empty(), "take removes the entry");
        assert!(st.take("a").is_none());
    }

    #[test]
    fn capacity_cap_evicts_lru() {
        let mut st = store(2, Duration::from_secs(60));
        st.put("a", cache_with_rows(1), 0, 1);
        std::thread::sleep(Duration::from_millis(2));
        st.put("b", cache_with_rows(1), 0, 1);
        std::thread::sleep(Duration::from_millis(2));
        // refresh "a" so "b" becomes the LRU victim
        let e = st.take("a").unwrap();
        st.put("a", e.cache, e.pending, e.turns + 1);
        std::thread::sleep(Duration::from_millis(2));
        st.put("c", cache_with_rows(1), 0, 1);
        assert_eq!(st.len(), 2);
        assert!(st.take("b").is_none(), "LRU entry evicted");
        assert!(st.take("a").is_some());
        assert!(st.take("c").is_some());
    }

    #[test]
    fn ttl_expires_entries() {
        let mut st = store(4, Duration::from_millis(1));
        st.put("a", cache_with_rows(1), 0, 1);
        std::thread::sleep(Duration::from_millis(5));
        assert!(st.take("a").is_none(), "expired entry is gone");
    }

    #[test]
    fn zero_capacity_disables_persistence() {
        let mut st = store(0, Duration::from_secs(60));
        st.put("a", cache_with_rows(1), 0, 1);
        assert!(st.is_empty());
        assert!(st.take("a").is_none());
    }

    #[test]
    fn updating_existing_key_never_evicts_others() {
        let mut st = store(2, Duration::from_secs(60));
        st.put("a", cache_with_rows(1), 0, 1);
        st.put("b", cache_with_rows(1), 0, 1);
        st.put("a", cache_with_rows(2), 1, 2);
        assert_eq!(st.len(), 2);
        assert!(st.take("b").is_some(), "re-putting a live key keeps the other");
        assert_eq!(st.take("a").unwrap().cache.appended, 2);
    }
}

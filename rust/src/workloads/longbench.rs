//! The six LongBench-like task families of Table 1 — Rust mirrors of
//! python/compile/data.py generators (identical templates; the trained
//! models saw exactly these formats).

use crate::util::rng::Rng;

use super::passkey::{digits, filler, splice};
use super::words::{fewshot_map, nouns, values};
use super::TaskItem;

pub const FAMILIES: &[&str] =
    &["single_qa", "multi_qa", "summarization", "fewshot", "synthetic", "code"];

/// Table-1 column grouping.
pub fn family_label(family: &str) -> &'static str {
    match family {
        "single_qa" => "Single. QA",
        "multi_qa" => "Multi. QA",
        "summarization" => "Summ.",
        "fewshot" => "Few-shot",
        "synthetic" => "Synthetic",
        "code" => "Code",
        _ => "Other",
    }
}

pub fn gen_single_qa(rng: &mut Rng, n_filler: usize) -> TaskItem {
    let n_facts = rng.range(3, 7);
    let ns = rng.choose_distinct(nouns().len(), n_facts);
    let vs: Vec<usize> = (0..n_facts).map(|_| rng.below(values().len())).collect();
    let mut hay = filler(rng, n_filler);
    for j in 0..n_facts {
        let fact: Vec<String> = ["fact", "the", nouns()[ns[j]], "is", values()[vs[j]], "."]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let depth = 0.05 + rng.f64() * 0.90;
        splice(&mut hay, fact, depth);
    }
    let pick = rng.below(n_facts);
    hay.extend(["<q>", "the", nouns()[ns[pick]], "<a>"].iter().map(|s| s.to_string()));
    TaskItem {
        family: "single_qa",
        prompt: hay.join(" "),
        answer: values()[vs[pick]].to_string(),
    }
}

pub fn gen_multi_qa(rng: &mut Rng, n_filler: usize) -> TaskItem {
    let ns = rng.choose_distinct(nouns().len(), 2);
    let vs: Vec<usize> = (0..2).map(|_| rng.below(values().len())).collect();
    let mut docs: Vec<String> = Vec::new();
    let per_doc = n_filler / 2;
    for j in 0..2 {
        let mut hay = filler(rng, per_doc);
        let fact: Vec<String> = ["fact", "the", nouns()[ns[j]], "is", values()[vs[j]], "."]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let depth = 0.1 + rng.f64() * 0.8;
        splice(&mut hay, fact, depth);
        docs.push("<sep>".to_string());
        docs.push("doc".to_string());
        docs.extend(hay);
    }
    docs.extend(
        ["<q>", "the", nouns()[ns[0]], "and", "the", nouns()[ns[1]], "<a>"]
            .iter()
            .map(|s| s.to_string()),
    );
    TaskItem {
        family: "multi_qa",
        prompt: docs.join(" "),
        answer: format!("{} {}", values()[vs[0]], values()[vs[1]]),
    }
}

pub fn gen_summarization(rng: &mut Rng, n_filler: usize) -> TaskItem {
    let k = rng.range(2, 5);
    let vs = rng.choose_distinct(values().len(), k);
    let mut hay = filler(rng, n_filler);
    let mut depths: Vec<f64> = (0..k).map(|_| 0.05 + rng.f64() * 0.90).collect();
    depths.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for j in (0..k).rev() {
        let item: Vec<String> =
            ["item", values()[vs[j]], "."].iter().map(|s| s.to_string()).collect();
        splice(&mut hay, item, depths[j]);
    }
    hay.extend(["<q>", "summary", "<a>"].iter().map(|s| s.to_string()));
    let answer = vs.iter().map(|&v| values()[v]).collect::<Vec<_>>().join(" ");
    TaskItem { family: "summarization", prompt: hay.join(" "), answer }
}

pub fn gen_fewshot(rng: &mut Rng, n_filler: usize) -> TaskItem {
    let n_shots = rng.range(3, 6);
    let idxs = rng.choose_distinct(values().len(), n_shots + 1);
    let mut shots: Vec<String> = Vec::new();
    for &w in idxs.iter().take(n_shots) {
        shots.extend(
            ["in:", values()[w], "out:", values()[fewshot_map(w)], "."]
                .iter()
                .map(|s| s.to_string()),
        );
    }
    let mut hay = filler(rng, n_filler);
    let depth = rng.f64() * 0.6;
    splice(&mut hay, shots, depth);
    let q = idxs[n_shots];
    hay.extend(["<q>", "in:", values()[q], "out:", "<a>"].iter().map(|s| s.to_string()));
    TaskItem {
        family: "fewshot",
        prompt: hay.join(" "),
        answer: values()[fewshot_map(q)].to_string(),
    }
}

pub fn gen_synthetic(rng: &mut Rng, n_filler: usize) -> TaskItem {
    let n_codes = rng.range(3, 7);
    let ids: Vec<usize> = rng.choose_distinct(90, n_codes).iter().map(|i| i + 10).collect();
    let codes: Vec<String> = (0..n_codes).map(|_| digits(rng, 8)).collect();
    let mut hay = filler(rng, n_filler);
    for j in 0..n_codes {
        let entry: Vec<String> =
            ["code", &ids[j].to_string(), "is", codes[j].as_str(), "."]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let depth = 0.05 + rng.f64() * 0.90;
        splice(&mut hay, entry, depth);
    }
    let pick = rng.below(n_codes);
    hay.extend(["<q>", "code", &ids[pick].to_string(), "<a>"].iter().map(|s| s.to_string()));
    TaskItem { family: "synthetic", prompt: hay.join(" "), answer: codes[pick].clone() }
}

pub fn gen_code(rng: &mut Rng, n_filler: usize) -> TaskItem {
    let n_defs = rng.range(3, 7);
    let ns = rng.choose_distinct(nouns().len(), n_defs);
    let rets: Vec<usize> = (0..n_defs).map(|_| rng.below(values().len())).collect();
    let mut hay = filler(rng, n_filler);
    for j in 0..n_defs {
        let d: Vec<String> =
            ["def", nouns()[ns[j]], "(", ")", ":", "return", values()[rets[j]]]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let depth = 0.05 + rng.f64() * 0.90;
        splice(&mut hay, d, depth);
    }
    let pick = rng.below(n_defs);
    hay.extend(["<q>", "call", nouns()[ns[pick]], "<a>"].iter().map(|s| s.to_string()));
    TaskItem {
        family: "code",
        prompt: hay.join(" "),
        answer: values()[rets[pick]].to_string(),
    }
}

pub fn generate(family: &str, rng: &mut Rng, n_filler: usize) -> TaskItem {
    match family {
        "single_qa" => gen_single_qa(rng, n_filler),
        "multi_qa" => gen_multi_qa(rng, n_filler),
        "summarization" => gen_summarization(rng, n_filler),
        "fewshot" => gen_fewshot(rng, n_filler),
        "synthetic" => gen_synthetic(rng, n_filler),
        "code" => gen_code(rng, n_filler),
        other => panic!("unknown family {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_families_answerable() {
        let mut rng = Rng::seed_from(9);
        for fam in FAMILIES {
            for _ in 0..5 {
                let item = generate(fam, &mut rng, 80);
                assert!(item.prompt.ends_with("<a>"), "{fam}");
                assert!(!item.answer.is_empty(), "{fam}");
                // answers are drawn from the context (fewshot's answer is
                // derived through the mapping, not copied verbatim)
                if *fam != "fewshot" {
                    for sym in item.answer.split_whitespace() {
                        assert!(
                            item.prompt.split_whitespace().any(|w| w == sym),
                            "{fam}: {sym} missing from prompt"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fewshot_answer_consistent_with_map() {
        let mut rng = Rng::seed_from(10);
        let item = gen_fewshot(&mut rng, 40);
        let toks: Vec<&str> = item.prompt.split_whitespace().collect();
        let qpos = toks.iter().rposition(|&w| w == "<q>").unwrap();
        let w = toks[qpos + 2];
        let wi = values().iter().position(|&v| v == w).unwrap();
        assert_eq!(item.answer, values()[fewshot_map(wi)]);
    }

    #[test]
    fn summarization_items_in_order() {
        let mut rng = Rng::seed_from(11);
        let item = gen_summarization(&mut rng, 100);
        let toks: Vec<&str> = item.prompt.split_whitespace().collect();
        let mut positions = Vec::new();
        for v in item.answer.split_whitespace() {
            let p = toks
                .windows(2)
                .position(|w| w[0] == "item" && w[1] == v)
                .expect("salient item present");
            positions.push(p);
        }
        let mut sorted = positions.clone();
        sorted.sort_unstable();
        assert_eq!(positions, sorted);
    }

    #[test]
    fn synthetic_codes_are_8_digits() {
        let mut rng = Rng::seed_from(12);
        let item = gen_synthetic(&mut rng, 60);
        assert_eq!(item.answer.len(), 8);
        assert!(item.answer.bytes().all(|b| b.is_ascii_digit()));
    }
}

//! Serve-time workload generators — Rust mirrors of
//! python/compile/data.py (same templates, same word lists), so the
//! build-time-trained models are in-distribution at evaluation time.

pub mod longbench;
pub mod passkey;
pub mod words;

/// One evaluation item: prompt text (ends with "<a>"), reference answer,
/// and the scoring rule of its family.
#[derive(Debug, Clone)]
pub struct TaskItem {
    pub family: &'static str,
    pub prompt: String,
    pub answer: String,
}

/// Scoring rule per family (see metrics::score).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreKind {
    PartialDigits,
    Exact,
    Coverage,
    F1,
}

pub fn score_kind(family: &str) -> ScoreKind {
    match family {
        "passkey" | "synthetic" => ScoreKind::PartialDigits,
        "summarization" => ScoreKind::Coverage,
        "single_qa" | "multi_qa" | "fewshot" | "code" => ScoreKind::Exact,
        _ => ScoreKind::F1,
    }
}

pub fn score_item(item: &TaskItem, pred: &str) -> f64 {
    use crate::metrics::score::*;
    match score_kind(item.family) {
        ScoreKind::PartialDigits => {
            let digits: String = pred.chars().filter(|c| c.is_ascii_digit()).collect();
            partial_match_digits(&digits, &item.answer)
        }
        ScoreKind::Exact => exact_match(pred, &item.answer),
        ScoreKind::Coverage => coverage_score(pred, &item.answer),
        ScoreKind::F1 => f1_token_score(pred, &item.answer),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoring_dispatch() {
        let item = TaskItem {
            family: "passkey",
            prompt: "x <a>".into(),
            answer: "1234".into(),
        };
        assert_eq!(score_item(&item, "12 99"), 50.0);
        let item = TaskItem {
            family: "single_qa",
            prompt: "x <a>".into(),
            answer: "blue".into(),
        };
        assert_eq!(score_item(&item, " blue "), 100.0);
    }
}

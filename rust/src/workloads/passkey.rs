//! Needle-in-a-Haystack / passkey-retrieval generator (the paper's §3.3
//! benchmark): a run of digits hidden at a controlled depth inside filler
//! text, queried at the end.  Mirror of data.gen_passkey.

use crate::util::rng::Rng;

use super::words::FILLER_WORDS;
use super::TaskItem;

/// Sentence-ish filler: `n_words` words with a period every 8..14 words.
pub fn filler(rng: &mut Rng, n_words: usize) -> Vec<String> {
    let mut out = Vec::with_capacity(n_words + n_words / 8 + 1);
    let mut gap = rng.range(8, 15);
    for _ in 0..n_words {
        out.push(FILLER_WORDS[rng.below(FILLER_WORDS.len())].to_string());
        gap -= 1;
        if gap == 0 {
            out.push(".".to_string());
            gap = rng.range(8, 15);
        }
    }
    out
}

pub fn digits(rng: &mut Rng, n: usize) -> String {
    (0..n).map(|_| char::from(b'0' + rng.below(10) as u8)).collect()
}

/// Insert `needle` at fractional `depth` of `hay`.
pub fn splice(hay: &mut Vec<String>, needle: Vec<String>, depth: f64) {
    let pos = ((depth * hay.len() as f64).round() as usize).min(hay.len());
    hay.splice(pos..pos, needle);
}

#[derive(Debug, Clone)]
pub struct PasskeySpec {
    pub n_filler: usize,
    pub n_digits: usize,
    /// None -> uniform random depth.
    pub depth: Option<f64>,
}

impl Default for PasskeySpec {
    fn default() -> Self {
        PasskeySpec { n_filler: 300, n_digits: 64, depth: None }
    }
}

pub fn gen_passkey(rng: &mut Rng, spec: &PasskeySpec) -> TaskItem {
    let depth = spec.depth.unwrap_or_else(|| rng.f64());
    let key = digits(rng, spec.n_digits);
    let needle: Vec<String> =
        ["<sep>", "pass", "key", "is", key.as_str(), ".", "remember", "it", "<sep>"]
            .iter()
            .map(|s| s.to_string())
            .collect();
    let mut hay = filler(rng, spec.n_filler);
    splice(&mut hay, needle, depth);
    hay.extend(["<q>", "pass", "key", "<a>"].iter().map(|s| s.to_string()));
    TaskItem { family: "passkey", prompt: hay.join(" "), answer: key }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn answer_embedded_in_prompt() {
        let mut rng = Rng::seed_from(1);
        let item = gen_passkey(&mut rng, &PasskeySpec::default());
        assert_eq!(item.answer.len(), 64);
        assert!(item.prompt.contains(&item.answer));
        assert!(item.prompt.ends_with("<a>"));
    }

    #[test]
    fn depth_controls_position() {
        let spec0 = PasskeySpec { depth: Some(0.0), ..Default::default() };
        let spec1 = PasskeySpec { depth: Some(1.0), ..Default::default() };
        let mut r0 = Rng::seed_from(2);
        let mut r1 = Rng::seed_from(2);
        let a = gen_passkey(&mut r0, &spec0);
        let b = gen_passkey(&mut r1, &spec1);
        let posa = a.prompt.find("pass key is").unwrap();
        let posb = b.prompt.find("pass key is").unwrap();
        assert!(posa < posb);
    }

    #[test]
    fn deterministic_under_seed() {
        let spec = PasskeySpec::default();
        let a = gen_passkey(&mut Rng::seed_from(3), &spec);
        let b = gen_passkey(&mut Rng::seed_from(3), &spec);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.answer, b.answer);
    }
}

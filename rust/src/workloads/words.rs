//! Word tables — byte-identical mirror of python/compile/common.py.
//! Order is load-bearing: generators index into these lists.

pub const FILLER_WORDS: &[&str] = &[
    "the", "a", "of", "and", "to", "in", "is", "it", "on", "as", "with",
    "was", "for", "at", "by", "be", "this", "that", "from", "or", "an",
    "are", "not", "we", "his", "but", "they", "she", "her", "you", "all",
    "will", "one", "there", "so", "out", "up", "if", "about", "who", "get",
    "which", "when", "make", "can", "like", "time", "just", "him", "know",
    "take", "people", "into", "year", "your", "good", "some", "could",
    "them", "see", "other", "than", "then", "now",
];

pub const CONTENT_WORDS: &[&str] = &[
    "apple", "river", "stone", "cloud", "tiger", "maple", "ocean", "candle",
    "silver", "meadow", "falcon", "ember", "harbor", "lantern", "orchid",
    "pebble", "quartz", "raven", "saddle", "thistle", "umbra", "velvet",
    "willow", "zephyr", "anchor", "basil", "cedar", "dahlia", "elm",
    "fern", "ginger", "hazel", "iris", "jasper", "kelp", "lotus",
    "mango", "nutmeg", "olive", "pine", "quince", "rose", "sage",
    "tulip", "violet", "walnut", "yarrow", "zinnia", "blue", "red",
    "green", "gold", "black", "white", "amber", "coral", "crimson",
    "indigo", "ivory", "jade", "onyx", "pearl", "ruby", "teal",
    "alpha", "beta", "gamma", "delta", "omega", "sigma", "kappa", "theta",
    "north", "south", "east", "west", "spring", "summer", "autumn",
    "winter", "copper", "iron", "zinc", "nickel", "cobalt", "helium",
    "neon", "argon", "xenon", "radon", "quark", "boson", "lepton",
    "hadron", "photon", "proton", "magnet", "prism",
];

pub const STRUCT_WORDS: &[&str] = &[
    // structural words used by task templates (kept separate so templates
    // never collide with haystack filler) — mirror of common.STRUCT_WORDS
    "pass", "key", "remember", "what", "summary", "value", "color",
    "code", "call", "def", "return", "(", ")", ":", ".", ",",
    "in:", "out:", "doc", "fact", "item", "is",
];

/// Nouns = first 48 content words; values = the rest (mirror of data.py).
pub fn nouns() -> &'static [&'static str] {
    &CONTENT_WORDS[..48]
}

pub fn values() -> &'static [&'static str] {
    &CONTENT_WORDS[48..]
}

/// The deterministic few-shot pairing on the value table (data._fewshot_map).
pub fn fewshot_map(w_idx: usize) -> usize {
    (w_idx * 7 + 3) % values().len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_sizes_match_python() {
        assert_eq!(FILLER_WORDS.len(), 64);
        assert_eq!(CONTENT_WORDS.len(), 98);
        assert_eq!(STRUCT_WORDS.len(), 22);
        assert_eq!(nouns().len(), 48);
        assert_eq!(values().len(), 50);
    }

    #[test]
    fn fewshot_map_is_permutation_free_but_total() {
        // every index maps inside the table and the map is deterministic
        for i in 0..values().len() {
            assert!(fewshot_map(i) < values().len());
            assert_eq!(fewshot_map(i), fewshot_map(i));
        }
    }
}

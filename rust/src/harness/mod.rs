//! Evaluation harnesses that regenerate the paper's tables and figures
//! (DESIGN.md §4).  Shared by the `lagkv tables` subcommand, the examples,
//! and the bench targets.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{CompressionConfig, PolicyKind};
use crate::coordinator::GenerateParams;
use crate::engine::Engine;
use crate::metrics::Table;
use crate::sim::{self, SimSpec};
use crate::util::rng::Rng;
use crate::workloads::passkey::{gen_passkey, PasskeySpec};
use crate::workloads::{longbench, score_item, TaskItem};

/// Shared evaluation options.
#[derive(Debug, Clone)]
pub struct EvalOptions {
    /// Items per (family, config) cell.
    pub n_items: usize,
    /// Filler words per prompt (scaled to the 512-token context window).
    pub n_filler: usize,
    pub seed: u64,
    pub max_new: usize,
    /// Needle length in digits.  16 is the 1/8-scale mapping of the
    /// paper's 64 (DESIGN.md §6); pass --digits 64 for the unscaled task.
    pub n_digits: usize,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions { n_items: 12, n_filler: 260, seed: 17, max_new: 24, n_digits: 16 }
    }
}

/// The paper's parameter grid, scaled 1/8 (DESIGN.md §6):
/// L {128,512,1024} -> {16,64,128}; S 16 -> 4; r unchanged.
pub fn paper_lags() -> Vec<usize> {
    vec![16, 64, 128]
}

pub fn paper_ratios() -> Vec<f64> {
    vec![0.5, 0.25, 0.167, 0.125]
}

pub fn cfg(policy: PolicyKind, lag: usize, ratio: f64) -> CompressionConfig {
    // One construction path for the whole stack: the params builder picks
    // the policy-appropriate skip_layers (2 for recursive-L2).
    GenerateParams::default().policy(policy).sink(4).lag(lag).ratio(ratio).compression()
}

/// Evaluate one family at one config; returns the mean score (0-100).
pub fn eval_family(
    engine: &Engine,
    family: &str,
    comp: &CompressionConfig,
    opts: &EvalOptions,
) -> Result<f64> {
    let mut rng = Rng::seed_from(opts.seed ^ fxhash(family));
    let mut total = 0.0;
    for i in 0..opts.n_items {
        let item = make_item(family, &mut rng, opts, engine.tokenizer.digits_per_token);
        let out = engine.generate(&item.prompt, comp, opts.max_new, opts.seed + i as u64)?;
        total += score_item(&item, &out.text);
    }
    Ok(total / opts.n_items as f64)
}

fn make_item(family: &str, rng: &mut Rng, opts: &EvalOptions, dpt: usize) -> TaskItem {
    match family {
        "passkey" => {
            // keep qwen-like (1 digit/token) prompts inside the context
            let n_filler = if dpt == 1 { opts.n_filler.saturating_sub(50) } else { opts.n_filler };
            gen_passkey(rng, &PasskeySpec { n_filler, n_digits: opts.n_digits, depth: None })
        }
        fam => longbench::generate(fam, rng, opts.n_filler),
    }
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

/// One Table-1 row: six LongBench families + average + needle.
pub fn table1_row(
    engine: &Engine,
    comp: &CompressionConfig,
    opts: &EvalOptions,
) -> Result<(Vec<f64>, f64, f64)> {
    let mut scores = Vec::new();
    for fam in longbench::FAMILIES {
        scores.push(eval_family(engine, fam, comp, opts)?);
    }
    let avg = scores.iter().sum::<f64>() / scores.len() as f64;
    let needle = eval_family(engine, "passkey", comp, opts)?;
    Ok((scores, avg, needle))
}

/// Table 1: per-model grid over (L, r) plus the uncompressed baseline.
pub fn table1(engines: &[Arc<Engine>], opts: &EvalOptions) -> Result<Table> {
    let mut t = Table::new(
        "Table 1: LongBench-like suite + 64-digit needle (paper Table 1, 1/8 scale)",
        &[
            "model", "method", "Single.QA", "Multi.QA", "Summ.", "Few-shot", "Synthetic",
            "Code", "LB Avg.", "Needle",
        ],
    );
    for engine in engines {
        let base = cfg(PolicyKind::None, 64, 1.0);
        let (s, avg, needle) = table1_row(engine, &base, opts)?;
        push_t1_row(&mut t, &engine.variant, "Baseline".into(), &s, avg, needle);
        for &lag in &paper_lags() {
            for &r in &paper_ratios() {
                let comp = cfg(PolicyKind::LagKv, lag, r);
                let (s, avg, needle) = table1_row(engine, &comp, opts)?;
                let label = format!("L={lag},r={}", comp.ratio_label());
                push_t1_row(&mut t, &engine.variant, label, &s, avg, needle);
            }
        }
    }
    Ok(t)
}

fn push_t1_row(t: &mut Table, model: &str, method: String, s: &[f64], avg: f64, needle: f64) {
    let mut row = vec![model.to_string(), method];
    row.extend(s.iter().map(|&x| Table::fmt_f(x)));
    row.push(Table::fmt_f(avg));
    row.push(Table::fmt_f(needle));
    t.row(row);
}

/// Fig. 2: needle score vs r*L for both models (log-x in the paper).
pub fn fig2(engines: &[Arc<Engine>], opts: &EvalOptions) -> Result<Table> {
    let mut t = Table::new(
        "Fig. 2: needle score vs r*L (paper knees at the needle's token count)",
        &["model", "L", "r", "r*L", "needle"],
    );
    for engine in engines {
        for &lag in &paper_lags() {
            for &r in &paper_ratios() {
                let comp = cfg(PolicyKind::LagKv, lag, r);
                let needle = eval_family(engine, "passkey", &comp, opts)?;
                t.row(vec![
                    engine.variant.clone(),
                    lag.to_string(),
                    comp.ratio_label(),
                    format!("{:.0}", r * lag as f64),
                    Table::fmt_f(needle),
                ]);
            }
        }
    }
    Ok(t)
}

/// Figs. 3/4: needle score per (depth, context length) grid for one model.
pub fn fig34(engine: &Engine, lag: usize, ratio: f64, opts: &EvalOptions) -> Result<Table> {
    let comp = cfg(PolicyKind::LagKv, lag, ratio);
    let mut t = Table::new(
        &format!(
            "Fig. 3/4 grid: {} L={lag} r={} (needle score by depth x context)",
            engine.variant,
            comp.ratio_label()
        ),
        &["depth", "ctx~160", "ctx~260", "ctx~360", "ctx~440"],
    );
    for depth in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let mut row = vec![format!("{depth:.2}")];
        for n_filler in [130usize, 230, 330, 410] {
            let mut rng = Rng::seed_from(opts.seed ^ (n_filler as u64) << 8 ^ (depth * 100.0) as u64);
            let mut total = 0.0;
            let n = opts.n_items.max(4) / 2;
            for i in 0..n {
                let nf = if engine.tokenizer.digits_per_token == 1 {
                    n_filler.saturating_sub(50)
                } else {
                    n_filler
                };
                let item = gen_passkey(
                    &mut rng,
                    &PasskeySpec { n_filler: nf, n_digits: opts.n_digits, depth: Some(depth) },
                );
                let out = engine.generate(&item.prompt, &comp, opts.max_new, i as u64)?;
                total += score_item(&item, &out.text);
            }
            row.push(Table::fmt_f(total / n as f64));
        }
        t.row(row);
    }
    Ok(t)
}

/// Fig. 5: variant comparison (LagKV vs LocalKV vs recursive-L2) on the
/// needle task across compression ratios.
pub fn fig5(engine: &Engine, lag: usize, opts: &EvalOptions) -> Result<Table> {
    let mut t = Table::new(
        &format!("Fig. 5: scoring variants, {} (S=4, L={lag})", engine.variant),
        &["variant", "2x", "4x", "6x", "8x"],
    );
    for policy in [PolicyKind::LagKv, PolicyKind::LocalKv, PolicyKind::L2Norm] {
        let mut row = vec![policy.name().to_string()];
        for &r in &paper_ratios() {
            let comp = cfg(policy, lag, r);
            row.push(Table::fmt_f(eval_family(engine, "passkey", &comp, opts)?));
        }
        t.row(row);
    }
    Ok(t)
}

/// §3.3 H2O comparison on the 64-digit needle.
pub fn h2o_table(engine: &Engine, lag: usize, opts: &EvalOptions) -> Result<Table> {
    let mut t = Table::new(
        &format!("§3.3: LagKV vs H2O vs streaming/random, {} (L={lag})", engine.variant),
        &["method", "2x", "4x", "8x"],
    );
    for policy in [PolicyKind::LagKv, PolicyKind::H2O, PolicyKind::Streaming, PolicyKind::Random]
    {
        let mut row = vec![policy.name().to_string()];
        for r in [0.5, 0.25, 0.125] {
            let comp = cfg(policy, lag, r);
            row.push(Table::fmt_f(eval_family(engine, "passkey", &comp, opts)?));
        }
        t.row(row);
    }
    Ok(t)
}

/// Eq. 10/11 compression-ratio table (analytic, no model needed).
pub fn ratio_table() -> Table {
    let mut t = Table::new(
        "Eqs. 10-11: retained length / compression ratio (S=4)",
        &["Ls", "L", "r", "retained", "ratio"],
    );
    for &lag in &paper_lags() {
        for &r in &paper_ratios() {
            for ls in [128usize, 256, 384, 512] {
                let keep = ((r * lag as f64).floor() as usize).max(1);
                let kept = crate::kvcache::ratio::retained_len(ls, 4, lag, keep);
                let c = crate::kvcache::ratio::compression_ratio(ls, 4, lag, keep);
                t.row(vec![
                    ls.to_string(),
                    lag.to_string(),
                    format!("{r:.3}"),
                    kept.to_string(),
                    format!("{c:.3}"),
                ]);
            }
        }
    }
    t
}

/// Fig. 5 analogue on the model-free simulator (wide sweep; seconds).
pub fn sim_fig5(seeds: u64) -> Table {
    let mut t = Table::new(
        "Simulator: needle retention by policy (model-free KV statistics)",
        &["policy", "2x", "4x", "6x", "8x"],
    );
    let spec = SimSpec::default();
    let mut rows: std::collections::BTreeMap<&'static str, Vec<f64>> = Default::default();
    for &r in &paper_ratios() {
        let mut acc: std::collections::BTreeMap<&'static str, f64> = Default::default();
        for seed in 0..seeds {
            for rep in sim::compare_policies(&spec, 4, 32, r, seed) {
                *acc.entry(rep.policy).or_default() += rep.needle_recall * 100.0;
            }
        }
        for (p, v) in acc {
            rows.entry(p).or_default().push(v / seeds as f64);
        }
    }
    for (p, vals) in rows {
        let mut row = vec![p.to_string()];
        row.extend(vals.iter().map(|&v| Table::fmt_f(v)));
        t.row(row);
    }
    t
}

//! Per-block KV codecs: encode-at-freeze / decode-at-read compression of
//! frozen pool blocks.
//!
//! LagKV's eviction shrinks the cache by dropping tokens; this module is
//! multiplicative on what survives.  The design leans on the pool's block
//! immutability contract: a frozen block is written exactly once (at
//! freeze time, in `HeadStore::freeze_prefix`) and never mutated, so a
//! lossy codec has a single well-defined encode point and decode is a
//! pure function of the encoded payload — re-reading (or spilling and
//! faulting) a quantized block is bit-identical *in the encoded domain*
//! by construction.
//!
//! Two codecs:
//!
//! * [`Fp32`] — the identity codec.  Encoded form is the raw
//!   little-endian f32 payload; `encoded_block_bytes` equals
//!   [`block_bytes`], so an "fp32-quantized" block costs exactly what a
//!   plain block costs (the pool routes it to the plain path).
//! * [`Int8Sym`] — per-row symmetric int8.  Each K row and each V row
//!   quantizes independently: `scale = max_abs(row) / 127`,
//!   `q = clamp(round(x / scale), -127, 127)`, `x' = q * scale`.  The
//!   per-row f32 scales live in a *sidecar* so the quantized tensor data
//!   stays densely packed.  Max-abs reconstruction error is bounded by
//!   `scale / 2` per row (no value clips: the row max maps to ±127
//!   exactly), which the property suite pins.
//!
//! Byte accounting is exact and closed-form: for a block of `rows` rows
//! at head width `d`,
//!
//! ```text
//!   fp32: rows * (8d + 8)              (== kvpool::block_bytes)
//!   int8: rows * (2d + 16)             (qk + qv + 2 scales + pos + attn)
//! ```
//!
//! (`+8`/`+16` cover the uncompressed per-row `pos: i32` / `attn: f32`
//! side entries, and for int8 the two f32 scales.)  The pool's
//! `quant_bytes` gauge moves in exactly these units, so the ledger
//! reconciliation property `quant_bytes == Σ encoded_block_bytes` holds
//! with equality, not approximately.
//!
//! [`block_bytes`]: crate::kvpool::block_bytes

use anyhow::{bail, Result};

/// Identity of a block codec: stable tags are persisted in the kvstore
/// block metadata and WAL, so the enum is append-only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CodecKind {
    /// Identity: raw f32, no sidecar.
    Fp32,
    /// Per-row symmetric int8 with f32 scales in the sidecar.
    Int8Sym,
}

impl CodecKind {
    /// Stable on-disk tag (WAL `"q"` field, block record header).
    pub fn tag(self) -> u8 {
        match self {
            CodecKind::Fp32 => 0,
            CodecKind::Int8Sym => 1,
        }
    }

    pub fn from_tag(tag: u8) -> Option<CodecKind> {
        match tag {
            0 => Some(CodecKind::Fp32),
            1 => Some(CodecKind::Int8Sym),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CodecKind::Fp32 => "fp32",
            CodecKind::Int8Sym => "int8",
        }
    }

    pub fn parse(s: &str) -> Result<CodecKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fp32" | "none" => CodecKind::Fp32,
            "int8" | "int8sym" | "int8-sym" => CodecKind::Int8Sym,
            other => bail!("unknown codec {other:?} (fp32|int8)"),
        })
    }

    /// The codec implementation behind this kind.
    pub fn codec(self) -> &'static dyn BlockCodec {
        match self {
            CodecKind::Fp32 => &Fp32,
            CodecKind::Int8Sym => &Int8Sym,
        }
    }

    /// Exact resident bytes of one encoded block of `rows` rows at head
    /// width `d`: the encoded K/V payload + sidecar, plus the (never
    /// compressed) per-row `pos: i32` and `attn: f32` side arrays.  This
    /// is the unit the pool's `quant_bytes` ledger moves in, and — for
    /// [`CodecKind::Fp32`] — equals [`crate::kvpool::block_bytes`].
    pub fn encoded_block_bytes(self, rows: usize, d: usize) -> usize {
        self.codec().encoded_kv_bytes(rows, d)
            + rows * (std::mem::size_of::<i32>() + std::mem::size_of::<f32>())
    }
}

/// The encoded form of one block's K/V payload: densely packed tensor
/// `data` plus a codec-specific `sidecar` (per-row scales for int8;
/// empty for fp32).  Spill serializes exactly these bytes — never a
/// decode-then-respill — so a spilled quantized block faults back
/// bit-identical to its encoded form.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EncodedKv {
    pub data: Vec<u8>,
    pub sidecar: Vec<u8>,
}

impl EncodedKv {
    /// Total encoded K/V bytes (data + sidecar).
    pub fn byte_len(&self) -> usize {
        self.data.len() + self.sidecar.len()
    }
}

/// A block codec: a pure, deterministic mapping between a block's f32
/// K/V payload and its encoded form.  `decode(encode(x))` need not equal
/// `x` (lossy is the point), but `encode` is called exactly once per
/// block (freeze time) and `decode` must be total on anything `encode`
/// produced — decode failures on the read path are unrepresentable.
pub trait BlockCodec: Send + Sync {
    fn kind(&self) -> CodecKind;

    /// Exact encoded size (data + sidecar) of `rows` rows at width `d`.
    fn encoded_kv_bytes(&self, rows: usize, d: usize) -> usize;

    /// Encode a block's K and V (each `rows * d`, row-major).
    fn encode(&self, rows: usize, d: usize, k: &[f32], v: &[f32]) -> EncodedKv;

    /// Append the decoded K and V rows onto `k_out` / `v_out`.
    fn decode(
        &self,
        rows: usize,
        d: usize,
        enc: &EncodedKv,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    );
}

/// The identity codec: encoded form is the little-endian f32 payload.
pub struct Fp32;

impl BlockCodec for Fp32 {
    fn kind(&self) -> CodecKind {
        CodecKind::Fp32
    }

    fn encoded_kv_bytes(&self, rows: usize, d: usize) -> usize {
        2 * rows * d * std::mem::size_of::<f32>()
    }

    fn encode(&self, rows: usize, d: usize, k: &[f32], v: &[f32]) -> EncodedKv {
        assert_eq!(k.len(), rows * d, "Fp32::encode: k shape");
        assert_eq!(v.len(), rows * d, "Fp32::encode: v shape");
        let mut data = Vec::with_capacity(2 * rows * d * 4);
        for x in k.iter().chain(v.iter()) {
            data.extend_from_slice(&x.to_le_bytes());
        }
        EncodedKv { data, sidecar: Vec::new() }
    }

    fn decode(
        &self,
        rows: usize,
        d: usize,
        enc: &EncodedKv,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) {
        let n = rows * d;
        assert_eq!(enc.data.len(), 2 * n * 4, "Fp32::decode: payload shape");
        assert!(enc.sidecar.is_empty(), "Fp32::decode: unexpected sidecar");
        k_out.reserve(n);
        v_out.reserve(n);
        for (i, c) in enc.data.chunks_exact(4).enumerate() {
            let x = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            if i < n {
                k_out.push(x);
            } else {
                v_out.push(x);
            }
        }
    }
}

/// Per-row symmetric int8: `data = [qk i8×rows·d | qv i8×rows·d]`,
/// `sidecar = [k_scales f32×rows | v_scales f32×rows]` (little-endian).
pub struct Int8Sym;

fn quantize_rows(rows: usize, d: usize, src: &[f32], data: &mut Vec<u8>, scales: &mut Vec<u8>) {
    for r in 0..rows {
        let row = &src[r * d..(r + 1) * d];
        let max_abs = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = max_abs / 127.0;
        scales.extend_from_slice(&scale.to_le_bytes());
        if scale == 0.0 {
            data.extend(std::iter::repeat(0u8).take(d));
        } else {
            for &x in row {
                let q = (x / scale).round().clamp(-127.0, 127.0) as i8;
                data.push(q as u8);
            }
        }
    }
}

fn dequantize_rows(rows: usize, d: usize, data: &[u8], scales: &[u8], out: &mut Vec<f32>) {
    debug_assert_eq!(data.len(), rows * d);
    debug_assert_eq!(scales.len(), rows * 4);
    out.reserve(rows * d);
    for r in 0..rows {
        let s = &scales[r * 4..(r + 1) * 4];
        let scale = f32::from_le_bytes([s[0], s[1], s[2], s[3]]);
        for &b in &data[r * d..(r + 1) * d] {
            out.push((b as i8) as f32 * scale);
        }
    }
}

impl BlockCodec for Int8Sym {
    fn kind(&self) -> CodecKind {
        CodecKind::Int8Sym
    }

    fn encoded_kv_bytes(&self, rows: usize, d: usize) -> usize {
        // qk + qv (one byte per element) + one f32 scale per K row and per
        // V row
        2 * rows * d + 2 * rows * std::mem::size_of::<f32>()
    }

    fn encode(&self, rows: usize, d: usize, k: &[f32], v: &[f32]) -> EncodedKv {
        assert_eq!(k.len(), rows * d, "Int8Sym::encode: k shape");
        assert_eq!(v.len(), rows * d, "Int8Sym::encode: v shape");
        let mut data = Vec::with_capacity(2 * rows * d);
        let mut sidecar = Vec::with_capacity(2 * rows * 4);
        quantize_rows(rows, d, k, &mut data, &mut sidecar);
        quantize_rows(rows, d, v, &mut data, &mut sidecar);
        EncodedKv { data, sidecar }
    }

    fn decode(
        &self,
        rows: usize,
        d: usize,
        enc: &EncodedKv,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) {
        let n = rows * d;
        assert_eq!(enc.data.len(), 2 * n, "Int8Sym::decode: payload shape");
        assert_eq!(enc.sidecar.len(), 2 * rows * 4, "Int8Sym::decode: sidecar shape");
        dequantize_rows(rows, d, &enc.data[..n], &enc.sidecar[..rows * 4], k_out);
        dequantize_rows(rows, d, &enc.data[n..], &enc.sidecar[rows * 4..], v_out);
    }
}

/// The engine's quantization configuration: one codec kind plus an
/// optional layer selector — the CLI's `--quant int8` (all layers) or
/// `--quant int8:0,2-5` (those layers only; the rest stay fp32).  The
/// per-layer map is how heterogeneous budgets (KVCompose-style) slot in
/// without touching the pool: the cache asks `codec_for(layer)` at each
/// freeze point.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantSpec {
    kind: CodecKind,
    /// Inclusive `(lo, hi)` layer ranges; `None` = every layer.
    sel: Option<Vec<(usize, usize)>>,
}

impl Default for QuantSpec {
    fn default() -> Self {
        QuantSpec::fp32()
    }
}

impl QuantSpec {
    /// The no-op spec: every layer stays fp32 (plain blocks).
    pub fn fp32() -> QuantSpec {
        QuantSpec { kind: CodecKind::Fp32, sel: None }
    }

    /// Apply `kind` to every layer.
    pub fn all(kind: CodecKind) -> QuantSpec {
        QuantSpec { kind, sel: None }
    }

    /// Parse the CLI form: `"int8"`, `"int8:0,2-5"`, `"fp32"`.
    pub fn parse(s: &str) -> Result<QuantSpec> {
        let (kind_str, sel_str) = match s.split_once(':') {
            Some((k, rest)) => (k, Some(rest)),
            None => (s, None),
        };
        let kind = CodecKind::parse(kind_str)?;
        let sel = match sel_str {
            None => None,
            Some(rest) => {
                let mut ranges = Vec::new();
                for part in rest.split(',') {
                    let part = part.trim();
                    if part.is_empty() {
                        bail!("empty layer range in quant spec {s:?}");
                    }
                    let (lo, hi) = match part.split_once('-') {
                        Some((a, b)) => {
                            let lo: usize = a
                                .trim()
                                .parse()
                                .map_err(|_| anyhow::anyhow!("bad layer {a:?} in {s:?}"))?;
                            let hi: usize = b
                                .trim()
                                .parse()
                                .map_err(|_| anyhow::anyhow!("bad layer {b:?} in {s:?}"))?;
                            (lo, hi)
                        }
                        None => {
                            let l: usize = part
                                .parse()
                                .map_err(|_| anyhow::anyhow!("bad layer {part:?} in {s:?}"))?;
                            (l, l)
                        }
                    };
                    if lo > hi {
                        bail!("descending layer range {lo}-{hi} in quant spec {s:?}");
                    }
                    ranges.push((lo, hi));
                }
                if ranges.is_empty() {
                    bail!("empty layer selector in quant spec {s:?}");
                }
                Some(ranges)
            }
        };
        Ok(QuantSpec { kind, sel })
    }

    pub fn kind(&self) -> CodecKind {
        self.kind
    }

    /// The codec this spec assigns to `layer`.
    pub fn codec_for(&self, layer: usize) -> CodecKind {
        if self.kind == CodecKind::Fp32 {
            return CodecKind::Fp32;
        }
        match &self.sel {
            None => self.kind,
            Some(ranges) => {
                if ranges.iter().any(|&(lo, hi)| lo <= layer && layer <= hi) {
                    self.kind
                } else {
                    CodecKind::Fp32
                }
            }
        }
    }

    /// True when no layer would ever encode (the default configuration).
    pub fn is_noop(&self) -> bool {
        self.kind == CodecKind::Fp32
    }

    /// Round-trippable display form (`"int8"`, `"int8:0,2-5"`, `"fp32"`).
    pub fn label(&self) -> String {
        match &self.sel {
            None => self.kind.name().to_string(),
            Some(ranges) => {
                let parts: Vec<String> = ranges
                    .iter()
                    .map(|&(lo, hi)| {
                        if lo == hi {
                            format!("{lo}")
                        } else {
                            format!("{lo}-{hi}")
                        }
                    })
                    .collect();
                format!("{}:{}", self.kind.name(), parts.join(","))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_rows(rows: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed_from(seed);
        let k: Vec<f32> = (0..rows * d).map(|_| rng.normal() * 3.0).collect();
        let v: Vec<f32> = (0..rows * d).map(|_| rng.normal() * 0.1).collect();
        (k, v)
    }

    #[test]
    fn fp32_round_trips_bit_exact() {
        let (rows, d) = (4, 5);
        let (k, v) = random_rows(rows, d, 1);
        let enc = Fp32.encode(rows, d, &k, &v);
        assert_eq!(enc.byte_len(), Fp32.encoded_kv_bytes(rows, d));
        assert!(enc.sidecar.is_empty());
        let (mut k2, mut v2) = (Vec::new(), Vec::new());
        Fp32.decode(rows, d, &enc, &mut k2, &mut v2);
        assert_eq!(k2, k);
        assert_eq!(v2, v);
    }

    #[test]
    fn int8_error_bounded_by_half_scale_per_row() {
        let (rows, d) = (16, 8);
        let (k, v) = random_rows(rows, d, 7);
        let enc = Int8Sym.encode(rows, d, &k, &v);
        assert_eq!(enc.byte_len(), Int8Sym.encoded_kv_bytes(rows, d));
        let (mut k2, mut v2) = (Vec::new(), Vec::new());
        Int8Sym.decode(rows, d, &enc, &mut k2, &mut v2);
        for (src, dec, scales) in
            [(&k, &k2, &enc.sidecar[..rows * 4]), (&v, &v2, &enc.sidecar[rows * 4..])]
        {
            for r in 0..rows {
                let s = &scales[r * 4..(r + 1) * 4];
                let scale = f32::from_le_bytes([s[0], s[1], s[2], s[3]]);
                let max_abs =
                    src[r * d..(r + 1) * d].iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                assert!((scale - max_abs / 127.0).abs() <= f32::EPSILON * max_abs.max(1.0));
                for i in r * d..(r + 1) * d {
                    let err = (src[i] - dec[i]).abs();
                    assert!(
                        err <= scale * 0.5 + 1e-6,
                        "row {r} err {err} > scale/2 = {}",
                        scale * 0.5
                    );
                }
            }
        }
    }

    #[test]
    fn int8_zero_rows_and_deterministic_re_encode() {
        let (rows, d) = (3, 4);
        let k = vec![0.0f32; rows * d];
        let mut v = vec![0.0f32; rows * d];
        v[5] = 2.5; // one non-zero row in v
        let enc = Int8Sym.encode(rows, d, &k, &v);
        let (mut k2, mut v2) = (Vec::new(), Vec::new());
        Int8Sym.decode(rows, d, &enc, &mut k2, &mut v2);
        assert!(k2.iter().all(|&x| x == 0.0), "all-zero rows decode to zero");
        assert_eq!(v2[5], 2.5, "row max reconstructs exactly (q = ±127)");
        // encode is a pure function: same input, same bytes
        assert_eq!(Int8Sym.encode(rows, d, &k, &v), enc);
    }

    #[test]
    fn encoded_byte_arithmetic_is_closed_form() {
        for (rows, d) in [(16, 8), (4, 3), (1, 1), (16, 64)] {
            assert_eq!(
                CodecKind::Fp32.encoded_block_bytes(rows, d),
                crate::kvpool::block_bytes(rows, d),
                "fp32 encoded bytes equal plain block bytes"
            );
            assert_eq!(CodecKind::Int8Sym.encoded_block_bytes(rows, d), rows * (2 * d + 16));
        }
    }

    #[test]
    fn codec_kind_tags_round_trip() {
        for kind in [CodecKind::Fp32, CodecKind::Int8Sym] {
            assert_eq!(CodecKind::from_tag(kind.tag()), Some(kind));
            assert_eq!(CodecKind::parse(kind.name()).unwrap(), kind);
            assert_eq!(kind.codec().kind(), kind);
        }
        assert_eq!(CodecKind::from_tag(7), None);
        assert!(CodecKind::parse("fp16").is_err());
    }

    #[test]
    fn quant_spec_parse_and_layer_map() {
        let all = QuantSpec::parse("int8").unwrap();
        assert_eq!(all.kind(), CodecKind::Int8Sym);
        assert!(!all.is_noop());
        for l in 0..32 {
            assert_eq!(all.codec_for(l), CodecKind::Int8Sym);
        }
        assert_eq!(all.label(), "int8");

        let some = QuantSpec::parse("int8:0,2-5,9").unwrap();
        for (l, want) in [
            (0, CodecKind::Int8Sym),
            (1, CodecKind::Fp32),
            (2, CodecKind::Int8Sym),
            (5, CodecKind::Int8Sym),
            (6, CodecKind::Fp32),
            (9, CodecKind::Int8Sym),
            (10, CodecKind::Fp32),
        ] {
            assert_eq!(some.codec_for(l), want, "layer {l}");
        }
        assert_eq!(some.label(), "int8:0,2-5,9");
        assert_eq!(QuantSpec::parse(&some.label()).unwrap(), some);

        let noop = QuantSpec::parse("fp32").unwrap();
        assert!(noop.is_noop());
        assert_eq!(noop, QuantSpec::default());

        assert!(QuantSpec::parse("int8:").is_err());
        assert!(QuantSpec::parse("int8:5-2").is_err());
        assert!(QuantSpec::parse("int8:a").is_err());
        assert!(QuantSpec::parse("fp16").is_err());
    }
}

//! Model-free KV-statistics simulator.
//!
//! The paper's mechanism rests on two statistical facts about KV caches
//! (§1, citing Liu et al. 2024): *token-wise locality* (nearby tokens have
//! similar K/V) and *channel-wise structure* (consistent per-channel
//! ranges).  This module generates synthetic K/V streams with exactly those
//! properties — an AR(1) process per channel with a drifting channel mean —
//! and plants a known set of **salient tokens** (retrieval-critical rows,
//! e.g. a needle's digits) as locality-breaking excursions.
//!
//! Running the real compression driver over the synthetic stream measures,
//! for every policy, how much of the ground-truth-salient set survives —
//! the model-free analogue of the passkey experiments, used for wide sweeps
//! (thousands of configurations in seconds) and for property tests.

use std::sync::Arc;

use crate::compress::{maybe_compress, policy::make_policy};
use crate::config::{CompressionConfig, PolicyKind};
use crate::kvcache::KvCache;
use crate::quant::QuantSpec;
use crate::util::rng::Rng;

/// Statistical shape of the synthetic stream.
#[derive(Debug, Clone)]
pub struct SimSpec {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub n_tokens: usize,
    /// AR(1) coefficient: token-wise locality strength (paper: high).
    pub locality: f32,
    /// Per-channel mean offsets scale (channel-wise structure).
    pub channel_scale: f32,
    /// Salient-token excursion magnitude (σ units).
    pub salience_boost: f32,
    /// Contiguous salient span (a "needle"): (start, len).  Keep the span
    /// shorter than keep-per-partition (r*L) or retention is capped by r
    /// itself regardless of policy — the Fig. 2 "r*L vs needle length"
    /// mechanism, which sim tests exercise explicitly.
    pub needle: (usize, usize),
    /// Block codec the simulated cache freezes through (`--quant`'s map).
    /// Defaults to fp32 (identity).  With int8 the driver scores over
    /// *decoded* rows, so runs measure whether the policy ordering
    /// survives quantization noise — the sim-tier twin of the paper's
    /// "quantization-friendly" claim.
    pub quant: QuantSpec,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            n_layers: 2,
            n_heads: 2,
            d_head: 16,
            n_tokens: 512,
            locality: 0.9,
            channel_scale: 2.0,
            salience_boost: 3.0,
            needle: (200, 8),
            quant: QuantSpec::fp32(),
        }
    }
}

/// Outcome of one simulated compression run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub policy: &'static str,
    /// Fraction of needle tokens retained, averaged over layers and heads.
    pub needle_recall: f64,
    /// Fraction of all tokens retained (the realized compression ratio's
    /// complement; sanity anchor for comparing policies fairly).
    pub retained_frac: f64,
    /// Final cache length (uniform across layers unless layers skipped).
    pub cache_len: usize,
}

/// Generate the stream and run the driver; measure needle retention.
pub fn run(spec: &SimSpec, cfg: &CompressionConfig, seed: u64) -> SimReport {
    let mut cache = KvCache::new(spec.n_layers, spec.n_heads, spec.d_head);
    cache.set_quant(Arc::new(spec.quant.clone()));
    let mut scorer = make_policy(cfg.policy, seed);
    let mut rng = Rng::seed_from(seed);

    let w = spec.n_layers * spec.n_heads * spec.d_head;
    // AR(1) state and fixed per-channel means
    let mut state_k = vec![0.0f32; w];
    let mut state_v = vec![0.0f32; w];
    let mean: Vec<f32> = (0..w).map(|_| rng.normal() * spec.channel_scale).collect();
    let rho = spec.locality;
    let innov = (1.0 - rho * rho).sqrt();

    let (n0, nl) = spec.needle;
    let mut k_row = vec![0.0f32; w];
    let mut v_row = vec![0.0f32; w];
    for t in 0..spec.n_tokens {
        let salient = t >= n0 && t < n0 + nl;
        // Salient rows are *locality breakers*: per-token random excursions
        // (a passkey's digit tokens look nothing like the filler prose
        // around them).  This is exactly the incoherence signal the paper
        // says LagKV picks up ("finds the tokens that are not coherent to
        // the next chunk").
        let boost = if salient { spec.salience_boost } else { 0.0 };
        for c in 0..w {
            state_k[c] = rho * state_k[c] + innov * rng.normal();
            state_v[c] = rho * state_v[c] + innov * rng.normal();
            k_row[c] = mean[c] + state_k[c] + boost * rng.normal();
            v_row[c] = -mean[c] * 0.5 + state_v[c] + boost * rng.normal();
        }
        cache.append_token(&k_row, &v_row, t as i32).unwrap();
        // crude attention surrogate for H2O: salient rows + sink collect
        // extra mass; recency gets a boost.  (Real runs use model attention.)
        if cfg.policy.needs_attention() {
            synth_attention(&mut cache, t, n0, nl);
        }
        maybe_compress(&mut cache, cfg, scorer.as_mut()).unwrap();
    }

    // measure needle retention over compressed layers only
    let mut recall = 0.0f64;
    let mut n_meas = 0usize;
    for layer in cfg.skip_layers.min(spec.n_layers)..spec.n_layers {
        for head in 0..spec.n_heads {
            let kept = cache
                .positions(layer, head)
                .iter()
                .filter(|&&p| (p as usize) >= n0 && (p as usize) < n0 + nl)
                .count();
            recall += kept as f64 / nl as f64;
            n_meas += 1;
        }
    }
    SimReport {
        policy: cfg.policy.name(),
        needle_recall: if n_meas > 0 { recall / n_meas as f64 } else { 1.0 },
        retained_frac: cache.len(spec.n_layers - 1) as f64 / spec.n_tokens as f64,
        cache_len: cache.len(spec.n_layers - 1),
    }
}

/// Synthetic attention-mass surrogate (H2O's food in the simulator): mass
/// concentrates on the sink and on recency, with only a *weak* signal on
/// the needle before the query arrives — modeling the paper's observation
/// that pre-query attention under-weights a passkey whose relevance only
/// materializes at the end ("first token leakage" failure of H2O, §3.3).
fn synth_attention(cache: &mut KvCache, t: usize, n0: usize, nl: usize) {
    let t_max = t + 1;
    let nlh = cache.n_layers * cache.n_heads;
    let mut row = vec![0.0f32; nlh * t_max];
    // Before the query arrives, attention has no way of knowing the digits
    // will matter — the premise behind H2O's 64-digit collapse (§3.3).
    // Digit tokens in prose actually receive *below*-average attention from
    // subsequent filler (they are syntactically inert), modeled by the 0.4
    // multiplier; sink and recency dominate, as observed everywhere.
    for lh in 0..nlh {
        let base = lh * t_max;
        let mut total = 0.0f32;
        for r in 0..t_max {
            let sink = if r < 4 { 3.0 } else { 0.0 };
            let recency = (-((t - r) as f32) / 24.0).exp();
            let mut m = sink + recency + 0.02;
            if r >= n0 && r < n0 + nl {
                m *= 0.4;
            }
            row[base + r] = m;
            total += m;
        }
        for r in 0..t_max {
            row[base + r] /= total;
        }
    }
    // align to current (compacted) row order via positions (scratch
    // reused across heads: this runs once per simulated token)
    let mut aligned = vec![0.0f32; nlh * cache.max_len().max(1)];
    let t_cache = cache.max_len();
    let mut pos = Vec::new();
    for layer in 0..cache.n_layers {
        for head in 0..cache.n_heads {
            let lh = layer * cache.n_heads + head;
            cache.positions_into(layer, head, &mut pos);
            for (r, &p) in pos.iter().enumerate() {
                aligned[lh * t_cache + r] = row[lh * t_max + (p as usize).min(t_max - 1)];
            }
        }
    }
    cache.accumulate_attention(&aligned, t_cache).unwrap();
}

/// Compare every policy at the same (S, L, r); convenience for Fig.5-style
/// sweeps and tests.
pub fn compare_policies(
    spec: &SimSpec,
    sink: usize,
    lag: usize,
    ratio: f64,
    seed: u64,
) -> Vec<SimReport> {
    PolicyKind::all()
        .iter()
        .filter(|k| **k != PolicyKind::None)
        .map(|&k| {
            let cfg = CompressionConfig {
                policy: k,
                sink,
                lag,
                ratio,
                skip_layers: if k == PolicyKind::L2Norm { 1 } else { 0 },
                ..Default::default()
            };
            run(spec, &cfg, seed)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_recall(policy: PolicyKind, ratio: f64, seeds: std::ops::Range<u64>) -> f64 {
        let spec = SimSpec::default();
        let cfg = CompressionConfig {
            policy,
            sink: 4,
            lag: 32,
            ratio,
            ..Default::default()
        };
        let n = (seeds.end - seeds.start) as f64;
        seeds.map(|s| run(&spec, &cfg, s).needle_recall).sum::<f64>() / n
    }

    #[test]
    fn lagkv_beats_random_on_needle_retention() {
        let lag = mean_recall(PolicyKind::LagKv, 0.25, 0..5);
        let rnd = mean_recall(PolicyKind::Random, 0.25, 0..5);
        assert!(
            lag > rnd + 0.15,
            "lagkv {lag:.3} should clearly beat random {rnd:.3}"
        );
    }

    #[test]
    fn lagkv_beats_streaming_on_mid_context_needle() {
        let lag = mean_recall(PolicyKind::LagKv, 0.25, 5..10);
        let st = mean_recall(PolicyKind::Streaming, 0.25, 5..10);
        assert!(lag > st, "lagkv {lag:.3} vs streaming {st:.3}");
    }

    #[test]
    fn recall_degrades_with_compression() {
        let r2 = mean_recall(PolicyKind::LagKv, 0.5, 0..5);
        let r8 = mean_recall(PolicyKind::LagKv, 0.125, 0..5);
        assert!(r2 >= r8 - 1e-9, "2x {r2:.3} should be >= 8x {r8:.3}");
    }

    #[test]
    fn retained_fraction_matches_ratio_math() {
        let spec = SimSpec::default();
        let cfg = CompressionConfig {
            policy: PolicyKind::LagKv,
            sink: 4,
            lag: 32,
            ratio: 0.25,
            ..Default::default()
        };
        let rep = run(&spec, &cfg, 1);
        let want = crate::kvcache::ratio::retained_len(
            spec.n_tokens,
            cfg.sink,
            cfg.lag,
            cfg.keep_per_partition(),
        );
        assert_eq!(rep.cache_len, want);
    }

    #[test]
    fn int8_blocks_preserve_the_length_law() {
        // Same run, frozen through the int8 codec: values are lossy but
        // the retention arithmetic (Eq. 10) is codec-independent.
        let spec = SimSpec {
            quant: QuantSpec::all(crate::quant::CodecKind::Int8Sym),
            ..Default::default()
        };
        let cfg = CompressionConfig {
            policy: PolicyKind::LagKv,
            sink: 4,
            lag: 32,
            ratio: 0.25,
            ..Default::default()
        };
        let rep = run(&spec, &cfg, 1);
        let want = crate::kvcache::ratio::retained_len(
            spec.n_tokens,
            cfg.sink,
            cfg.lag,
            cfg.keep_per_partition(),
        );
        assert_eq!(rep.cache_len, want);
    }

    #[test]
    fn h2o_collapses_on_long_needle_lagkv_hits_the_cap() {
        // The §3.3 story at 64 digits: partitions inside the needle can keep
        // at most r*L rows, and LagKV keeps ~that cap, while H2O's
        // accumulated-attention score (which cannot foresee the query)
        // spends its budget on sink/recency rows instead.
        let spec = SimSpec { needle: (200, 64), ..Default::default() };
        let run_mean = |policy: PolicyKind| -> f64 {
            let cfg = CompressionConfig {
                policy,
                sink: 4,
                lag: 32,
                ratio: 0.25,
                ..Default::default()
            };
            (10..14).map(|s| run(&spec, &cfg, s).needle_recall).sum::<f64>() / 4.0
        };
        let lag = run_mean(PolicyKind::LagKv);
        let h2o = run_mean(PolicyKind::H2O);
        assert!(
            lag > 2.0 * h2o + 0.05,
            "lagkv {lag:.3} should dominate h2o {h2o:.3} on a 64-token needle"
        );
    }
}

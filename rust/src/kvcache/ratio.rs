//! Compression-ratio arithmetic, Eqs. (10)-(11) of the paper.
//!
//! For sequence length `Ls >= S + 2L` the retained cache length is
//!
//! ```text
//!   L_R = S + rL * (floor((Ls - S)/L) - 1) + L + mod(Ls - S, L)
//!   C   = 1 - L_R / Ls
//! ```
//!
//! For `Ls < S + 2L` the compression ratio is zero (nothing is evicted).
//! (The paper states the zero case as `Ls <= S + 2L` but its own Eq. 10 is
//! defined for `Ls` "not less than" `S + 2L`; at exact equality the first
//! partition has its lag reference available and compression fires, so the
//! strict inequality is the consistent reading — the recursive driver and
//! this closed form agree at every length, which the tests assert.)
//! These closed forms are cross-checked against the actual cache manager in
//! rust/tests/ (the measured retained length must match exactly).

/// Retained cache length after recursive compression (Eq. 10).
pub fn retained_len(ls: usize, sink: usize, lag: usize, keep_per_partition: usize) -> usize {
    if ls < sink + 2 * lag {
        return ls;
    }
    let rest = ls - sink;
    let partitions = rest / lag; // floor
    let rem = rest % lag;
    sink + keep_per_partition * (partitions - 1) + lag + rem
}

/// Compression ratio C (Eq. 11): fraction of the cache evicted.
pub fn compression_ratio(ls: usize, sink: usize, lag: usize, keep_per_partition: usize) -> f64 {
    if ls == 0 {
        return 0.0;
    }
    1.0 - retained_len(ls, sink, lag, keep_per_partition) as f64 / ls as f64
}

/// Asymptotic ratio as Ls -> inf: 1 - r (all mass ends up in compressed
/// partitions).
pub fn asymptotic_ratio(r: f64) -> f64 {
    1.0 - r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn below_threshold_is_identity() {
        for ls in 0..(4 + 2 * 16) {
            assert_eq!(retained_len(ls, 4, 16, 8), ls);
            assert_eq!(compression_ratio(ls, 4, 16, 8), 0.0);
        }
        // at exactly S+2L the first compression fires
        assert_eq!(retained_len(36, 4, 16, 8), 28);
    }

    #[test]
    fn paper_formula_exact() {
        // S=4, L=16, r=0.5 (keep 8), Ls = 4 + 16*5 + 7 = 91
        // partitions = floor(87/16) = 5, rem = 7
        // L_R = 4 + 8*4 + 16 + 7 = 59
        assert_eq!(retained_len(91, 4, 16, 8), 59);
        let c = compression_ratio(91, 4, 16, 8);
        assert!((c - (1.0 - 59.0 / 91.0)).abs() < 1e-12);
    }

    #[test]
    fn exact_multiple_boundary() {
        // Ls - S an exact multiple of L: rem = 0, last partition stays whole
        // S=4, L=16, Ls = 4 + 48: partitions=3, L_R = 4 + 8*2 + 16 + 0 = 36
        assert_eq!(retained_len(52, 4, 16, 8), 36);
    }

    #[test]
    fn ratio_sawtooth_monotone_at_partition_boundaries() {
        // The ratio is a sawtooth in Ls (the uncompressed window refills
        // between partition boundaries); sampled AT the boundaries it is
        // monotone non-decreasing.
        let mut prev = 0.0;
        for k in 2..30 {
            let ls = 4 + 64 * k;
            let c = compression_ratio(ls, 4, 64, 16);
            assert!(c >= prev - 1e-12, "boundary ratio dropped at k={k}");
            prev = c;
        }
        // and everywhere it is bounded by the asymptote
        for ls in 40..4000 {
            assert!(compression_ratio(ls, 4, 64, 16) < asymptotic_ratio(0.25));
        }
    }

    #[test]
    fn approaches_asymptote() {
        let c = compression_ratio(1_000_000, 4, 64, 16);
        assert!((c - asymptotic_ratio(0.25)).abs() < 0.001);
    }
}

//! KV-cache manager: per-sequence, per-layer, per-head compacted storage
//! with the paper's sink / compressed / tail layout.
//!
//! Row order within each (layer, head):
//!
//! ```text
//!   [ sink S | compressed survivors ... | tail (uncompressed) ]
//!             ^ boundary                                      ^ len
//! ```
//!
//! * Rows `< boundary` are final: sink plus the winners of past partition
//!   compressions.
//! * The *tail* accumulates appended tokens.  When it reaches `2L`, the
//!   compression driver (compress/driver.rs) scores the oldest `L` against
//!   the next `L` (the lag reference) and keeps the top `floor(r*L)` per
//!   head — the paper's recursive scheme (Fig. 1), identical in prefill and
//!   decode.
//! * Head token *identities* diverge after eviction (per-head top-k) but
//!   head *counts* stay equal, so a single length per layer suffices — the
//!   shape contract of the decode executable.  Lengths may differ across
//!   layers (the recursive-L2 variant skips layers).
//!
//! The cache also carries per-row original positions (debug/analysis) and
//! per-row accumulated attention mass (the H2O baseline's statistic).

pub mod ratio;

use anyhow::{bail, Result};

/// Storage for one (layer, head).
#[derive(Debug, Clone, Default)]
pub struct HeadStore {
    /// Row-major keys, `len * d_head`.
    pub k: Vec<f32>,
    /// Row-major values, `len * d_head`.
    pub v: Vec<f32>,
    /// Original absolute position of each row.
    pub pos: Vec<i32>,
    /// Accumulated attention mass per row (H2O).
    pub attn: Vec<f32>,
}

impl HeadStore {
    fn len(&self, d: usize) -> usize {
        debug_assert_eq!(self.k.len() % d, 0);
        self.k.len() / d
    }

    /// Keep only `keep` (ascending row indices) within `[start, start+l)`,
    /// leaving rows outside the window untouched.
    fn compact_window(&mut self, d: usize, start: usize, l: usize, keep: &[usize]) {
        debug_assert!(keep.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(keep.iter().all(|&i| i < l));
        let mut k = Vec::with_capacity(self.k.len() - (l - keep.len()) * d);
        let mut v = Vec::with_capacity(k.capacity());
        let mut pos = Vec::with_capacity(self.pos.len() - (l - keep.len()));
        let mut attn = Vec::with_capacity(pos.capacity());
        k.extend_from_slice(&self.k[..start * d]);
        v.extend_from_slice(&self.v[..start * d]);
        pos.extend_from_slice(&self.pos[..start]);
        attn.extend_from_slice(&self.attn[..start]);
        for &i in keep {
            let r = start + i;
            k.extend_from_slice(&self.k[r * d..(r + 1) * d]);
            v.extend_from_slice(&self.v[r * d..(r + 1) * d]);
            pos.push(self.pos[r]);
            attn.push(self.attn[r]);
        }
        k.extend_from_slice(&self.k[(start + l) * d..]);
        v.extend_from_slice(&self.v[(start + l) * d..]);
        pos.extend_from_slice(&self.pos[start + l..]);
        attn.extend_from_slice(&self.attn[start + l..]);
        self.k = k;
        self.v = v;
        self.pos = pos;
        self.attn = attn;
    }
}

/// Per-layer state.
#[derive(Debug, Clone)]
pub struct LayerCache {
    pub heads: Vec<HeadStore>,
    /// Rows `< boundary` are sink + already-compressed survivors.
    pub boundary: usize,
}

/// The full per-sequence cache.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub layers: Vec<LayerCache>,
    /// Total tokens ever appended (= next absolute position).
    pub appended: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, n_heads: usize, d_head: usize) -> Self {
        KvCache {
            n_layers,
            n_heads,
            d_head,
            layers: (0..n_layers)
                .map(|_| LayerCache {
                    heads: vec![HeadStore::default(); n_heads],
                    boundary: 0,
                })
                .collect(),
            appended: 0,
        }
    }

    /// Current row count of `layer` (uniform across its heads).
    pub fn len(&self, layer: usize) -> usize {
        self.layers[layer].heads[0].len(self.d_head)
    }

    pub fn lens(&self) -> Vec<usize> {
        (0..self.n_layers).map(|l| self.len(l)).collect()
    }

    pub fn max_len(&self) -> usize {
        self.lens().into_iter().max().unwrap_or(0)
    }

    /// Total retained rows summed over layers (session-store accounting;
    /// head counts are uniform within a layer, so one length per layer).
    pub fn total_rows(&self) -> usize {
        self.lens().into_iter().sum()
    }

    /// Approximate resident bytes of the K/V payload (positions and
    /// attention mass excluded): rows * heads * d_head * 2 tensors * f32.
    pub fn approx_bytes(&self) -> usize {
        self.total_rows() * self.n_heads * self.d_head * 2 * std::mem::size_of::<f32>()
    }

    pub fn is_empty(&self) -> bool {
        self.appended == 0
    }

    /// Uncompressed tail length of `layer`.
    pub fn tail_len(&self, layer: usize) -> usize {
        self.len(layer) - self.layers[layer].boundary
    }

    /// Append one token's K/V for every layer/head.
    ///
    /// `k_new`/`v_new` layout: `[n_layers, n_heads, d_head]` row-major —
    /// exactly the decode executable's `k_new` output.
    pub fn append_token(&mut self, k_new: &[f32], v_new: &[f32], position: i32) -> Result<()> {
        let d = self.d_head;
        let expect = self.n_layers * self.n_heads * d;
        if k_new.len() != expect || v_new.len() != expect {
            bail!("append_token: expected {expect} floats, got {}", k_new.len());
        }
        for (li, layer) in self.layers.iter_mut().enumerate() {
            for (hi, head) in layer.heads.iter_mut().enumerate() {
                let off = (li * self.n_heads + hi) * d;
                head.k.extend_from_slice(&k_new[off..off + d]);
                head.v.extend_from_slice(&v_new[off..off + d]);
                head.pos.push(position);
                head.attn.push(0.0);
            }
        }
        self.appended += 1;
        Ok(())
    }

    /// Ingest a prefill output: `k`/`v` are `[n_layers, n_heads, t_bucket,
    /// d_head]` and `attn_sums` is `[n_layers, n_heads, t_bucket]`; only the
    /// first `true_len` rows are real.
    pub fn ingest_prefill(
        &mut self,
        k: &[f32],
        v: &[f32],
        attn_sums: &[f32],
        t_bucket: usize,
        true_len: usize,
    ) -> Result<()> {
        let d = self.d_head;
        if k.len() != self.n_layers * self.n_heads * t_bucket * d {
            bail!(
                "ingest_prefill: bad k len {} for bucket {t_bucket}",
                k.len()
            );
        }
        if true_len > t_bucket {
            bail!("true_len {true_len} > bucket {t_bucket}");
        }
        for (li, layer) in self.layers.iter_mut().enumerate() {
            for (hi, head) in layer.heads.iter_mut().enumerate() {
                let base = (li * self.n_heads + hi) * t_bucket;
                let row0 = base * d;
                head.k.extend_from_slice(&k[row0..row0 + true_len * d]);
                head.v.extend_from_slice(&v[row0..row0 + true_len * d]);
                head.pos.extend((0..true_len as i32).map(|p| self.appended as i32 + p));
                head.attn.extend_from_slice(&attn_sums[base..base + true_len]);
            }
        }
        self.appended += true_len;
        Ok(())
    }

    /// Add one decode step's attention row (`[n_layers, n_heads, t_max]`,
    /// aligned with current row order) to the accumulated H2O statistic.
    pub fn accumulate_attention(&mut self, attn_row: &[f32], t_max: usize) -> Result<()> {
        if attn_row.len() != self.n_layers * self.n_heads * t_max {
            bail!("accumulate_attention: bad len {}", attn_row.len());
        }
        for (li, layer) in self.layers.iter_mut().enumerate() {
            for (hi, head) in layer.heads.iter_mut().enumerate() {
                let base = (li * self.n_heads + hi) * t_max;
                let n = head.attn.len().min(t_max);
                for r in 0..n {
                    head.attn[r] += attn_row[base + r];
                }
            }
        }
        Ok(())
    }

    /// Apply a per-head keep-set to the window `[start, start+l)` of
    /// `layer`.  `keeps[h]` must be ascending indices into the window and
    /// all heads must keep the same count (shape contract).
    pub fn compact_layer(
        &mut self,
        layer: usize,
        start: usize,
        l: usize,
        keeps: &[Vec<usize>],
    ) -> Result<()> {
        let d = self.d_head;
        if keeps.len() != self.n_heads {
            bail!("compact_layer: {} keep sets for {} heads", keeps.len(), self.n_heads);
        }
        let kept = keeps[0].len();
        if keeps.iter().any(|ks| ks.len() != kept) {
            bail!("compact_layer: unequal keep counts across heads");
        }
        let len = self.len(layer);
        if start + l > len {
            bail!("compact_layer: window [{start}, {}) out of bounds {len}", start + l);
        }
        for (hi, head) in self.layers[layer].heads.iter_mut().enumerate() {
            head.compact_window(d, start, l, &keeps[hi]);
        }
        self.layers[layer].boundary = start + kept;
        Ok(())
    }

    /// Flat padded export of one layer for upload: `([n_heads, t_max, d],
    /// same for v)`; rows `>= len` are zero.
    pub fn layer_padded(&self, layer: usize, t_max: usize) -> (Vec<f32>, Vec<f32>) {
        let d = self.d_head;
        let len = self.len(layer).min(t_max);
        let mut k = vec![0.0f32; self.n_heads * t_max * d];
        let mut v = vec![0.0f32; self.n_heads * t_max * d];
        for (hi, head) in self.layers[layer].heads.iter().enumerate() {
            let dst = hi * t_max * d;
            k[dst..dst + len * d].copy_from_slice(&head.k[..len * d]);
            v[dst..dst + len * d].copy_from_slice(&head.v[..len * d]);
        }
        (k, v)
    }

    /// Flat padded export of the whole cache: `[n_layers, n_heads, t_max, d]`.
    pub fn all_padded(&self, t_max: usize) -> (Vec<f32>, Vec<f32>) {
        let per = self.n_heads * t_max * self.d_head;
        let mut k = Vec::with_capacity(self.n_layers * per);
        let mut v = Vec::with_capacity(self.n_layers * per);
        for l in 0..self.n_layers {
            let (lk, lv) = self.layer_padded(l, t_max);
            k.extend_from_slice(&lk);
            v.extend_from_slice(&lv);
        }
        (k, v)
    }

    /// Borrow the row range `[start, start+l)` of one head as K/V slices.
    pub fn window(&self, layer: usize, head: usize, start: usize, l: usize) -> Window<'_> {
        let d = self.d_head;
        let h = &self.layers[layer].heads[head];
        Window {
            k: &h.k[start * d..(start + l) * d],
            v: &h.v[start * d..(start + l) * d],
            attn: &h.attn[start..start + l],
            pos: &h.pos[start..start + l],
        }
    }

    /// Retained original positions of one head (analysis / tests).
    pub fn positions(&self, layer: usize, head: usize) -> &[i32] {
        &self.layers[layer].heads[head].pos
    }
}

/// A borrowed view of `l` consecutive rows of one head.
pub struct Window<'a> {
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub attn: &'a [f32],
    pub pos: &'a [i32],
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn filled(nl: usize, nh: usize, d: usize, n: usize) -> KvCache {
        let mut c = KvCache::new(nl, nh, d);
        let mut rng = Rng::seed_from(1);
        for t in 0..n {
            let k: Vec<f32> = (0..nl * nh * d).map(|_| rng.normal()).collect();
            let v: Vec<f32> = (0..nl * nh * d).map(|_| rng.normal()).collect();
            c.append_token(&k, &v, t as i32).unwrap();
        }
        c
    }

    #[test]
    fn append_grows_uniformly() {
        let c = filled(3, 2, 4, 10);
        assert_eq!(c.lens(), vec![10, 10, 10]);
        assert_eq!(c.appended, 10);
    }

    #[test]
    fn compact_keeps_selected_rows() {
        let mut c = filled(1, 2, 4, 8);
        let before_h0: Vec<f32> = c.layers[0].heads[0].k.clone();
        // window rows 2..6, head0 keeps {1,3} (abs 3,5), head1 keeps {0,2} (abs 2,4)
        c.compact_layer(0, 2, 4, &[vec![1, 3], vec![0, 2]]).unwrap();
        assert_eq!(c.len(0), 6);
        assert_eq!(c.layers[0].boundary, 4);
        let d = 4;
        // head0 row2 should be old row 3
        assert_eq!(&c.layers[0].heads[0].k[2 * d..3 * d], &before_h0[3 * d..4 * d]);
        assert_eq!(&c.layers[0].heads[0].k[3 * d..4 * d], &before_h0[5 * d..6 * d]);
        // trailing rows shift down
        assert_eq!(&c.layers[0].heads[0].k[4 * d..5 * d], &before_h0[6 * d..7 * d]);
        assert_eq!(c.positions(0, 0), &[0, 1, 3, 5, 6, 7]);
        assert_eq!(c.positions(0, 1), &[0, 1, 2, 4, 6, 7]);
    }

    #[test]
    fn compact_rejects_unequal_counts() {
        let mut c = filled(1, 2, 4, 8);
        assert!(c.compact_layer(0, 2, 4, &[vec![1], vec![0, 2]]).is_err());
    }

    #[test]
    fn padded_export_zero_fills() {
        let c = filled(2, 2, 4, 5);
        let (k, _v) = c.layer_padded(0, 8);
        assert_eq!(k.len(), 2 * 8 * 4);
        // row 5.. are zero
        for h in 0..2 {
            for r in 5..8 {
                let off = (h * 8 + r) * 4;
                assert!(k[off..off + 4].iter().all(|&x| x == 0.0));
            }
        }
    }

    #[test]
    fn ingest_prefill_respects_true_len() {
        let nl = 2;
        let nh = 2;
        let d = 3;
        let t_bucket = 6;
        let true_len = 4;
        let mut c = KvCache::new(nl, nh, d);
        let k: Vec<f32> = (0..nl * nh * t_bucket * d).map(|i| i as f32).collect();
        let v = k.clone();
        let attn: Vec<f32> = (0..nl * nh * t_bucket).map(|i| i as f32).collect();
        c.ingest_prefill(&k, &v, &attn, t_bucket, true_len).unwrap();
        assert_eq!(c.lens(), vec![4, 4]);
        assert_eq!(c.appended, 4);
        // layer1/head1 row0 == k[(1*2+1)*6*3 ..]
        let off = (1 * nh + 1) * t_bucket * d;
        assert_eq!(&c.layers[1].heads[1].k[..d], &k[off..off + d]);
        assert_eq!(c.layers[1].heads[1].attn, attn[(1 * nh + 1) * t_bucket..][..4]);
    }

    #[test]
    fn attention_accumulates_in_row_order() {
        let mut c = filled(1, 1, 2, 3);
        let t_max = 8;
        let mut row = vec![0.0f32; t_max];
        row[0] = 0.5;
        row[2] = 0.25;
        c.accumulate_attention(&row, t_max).unwrap();
        c.accumulate_attention(&row, t_max).unwrap();
        assert_eq!(c.layers[0].heads[0].attn, vec![1.0, 0.0, 0.5]);
    }

    #[test]
    fn prop_compact_preserves_untouched_regions() {
        prop::check(60, |g| {
            let d = g.usize(1, 6);
            let n = g.usize(6, 40);
            let start = g.usize(0, n.saturating_sub(6));
            let l = g.usize(2, (n - start).min(8)).max(2);
            let kept = g.usize(1, l - 1);
            let mut c = KvCache::new(1, 1, d);
            let mut rng = Rng::seed_from(g.case as u64);
            for t in 0..n {
                let k: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                c.append_token(&k, &k, t as i32).unwrap();
            }
            let before = c.layers[0].heads[0].k.clone();
            let mut keep: Vec<usize> = (0..l).collect();
            let mut r2 = Rng::seed_from(g.case as u64 + 999);
            r2.shuffle(&mut keep);
            keep.truncate(kept);
            keep.sort_unstable();
            c.compact_layer(0, start, l, &[keep.clone()]).unwrap();
            // prefix untouched
            if c.layers[0].heads[0].k[..start * d] != before[..start * d] {
                return Err("prefix changed".into());
            }
            // suffix shifted but identical content
            let suffix_rows = n - start - l;
            let got = &c.layers[0].heads[0].k[(start + kept) * d..];
            let want = &before[(start + l) * d..];
            if got != want || got.len() != suffix_rows * d {
                return Err("suffix mismatch".into());
            }
            // positions of kept rows ascend
            let pos = c.positions(0, 0);
            if pos.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("positions not ascending: {pos:?}"));
            }
            Ok(())
        });
    }
}

//! KV-cache manager: per-sequence, per-layer, per-head compacted storage
//! with the paper's sink / compressed / tail layout, backed by the paged
//! block pool ([`crate::kvpool`]).
//!
//! Row order within each (layer, head):
//!
//! ```text
//!   [ sink S | compressed survivors ... | tail (uncompressed) ]
//!             ^ boundary                                      ^ len
//! ```
//!
//! * Rows `< boundary` are final: sink plus the winners of past partition
//!   compressions.
//! * The *tail* accumulates appended tokens.  When it reaches `2L`, the
//!   compression driver (compress/driver.rs) scores the oldest `L` against
//!   the next `L` (the lag reference) and keeps the top `floor(r*L)` per
//!   head — the paper's recursive scheme (Fig. 1), identical in prefill and
//!   decode.
//! * Head token *identities* diverge after eviction (per-head top-k) but
//!   head *counts* stay equal, so a single length per layer suffices — the
//!   shape contract of the decode executable.  Lengths may differ across
//!   layers (the recursive-L2 variant skips layers).
//!
//! ## Physical layout: frozen blocks + loose tail
//!
//! Each head splits its rows at `frozen_rows` into two regions:
//!
//! * rows `[0, frozen_rows)` live in immutable, refcounted, pool-owned
//!   blocks (`Arc<Block>`) — they were below a past compaction's window
//!   start, so the driver will never score or move them again.  Cloning a
//!   cache (session detach, CoW re-attachment) shares these blocks by
//!   refcount instead of copying;
//! * rows `[frozen_rows, len)` stay in contiguous `Vec`s so the scorer's
//!   [`KvCache::window`] can hand out plain slices.
//!
//! `compact_layer` first freezes whole blocks below the window start
//! (each row is copied into a block at most once, ever), then rebuilds only
//! the loose region — O(tail) instead of the old full-store O(len) rebuild.
//! The driver's window start is monotone per layer for partition-scope
//! policies, which is what keeps every scoring window inside the loose
//! region; global-scope policies (original H2O) call
//! [`KvCache::thaw_layer`] first (see compress/driver.rs).
//!
//! The cache also carries per-row original positions (debug/analysis) and
//! per-row accumulated attention mass (the H2O baseline's statistic).
//! Attention mass is only accumulated onto loose rows: frozen rows are
//! final and no scorer reads their mass again.

pub mod ratio;

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::kvpool::{row_bytes, Block, BlockPool, LooseGauge};
use crate::kvstore::KvStore;
use crate::quant::{CodecKind, QuantSpec};
use crate::util::json::{self, Json};

/// Storage for one (layer, head): frozen pool blocks plus the loose tail.
#[derive(Debug, Clone, Default)]
pub struct HeadStore {
    /// Immutable full blocks covering rows `[0, frozen_rows)`.
    frozen: Vec<Arc<Block>>,
    frozen_rows: usize,
    /// Live accumulated attention mass for the frozen rows, parallel to
    /// the block order.  Kept *outside* the immutable (possibly shared)
    /// blocks so H2O mass keeps accumulating after a freeze and a later
    /// thaw — e.g. a session turn that switches to a global-scope policy —
    /// scores on current statistics, not a freeze-time snapshot.  Owned
    /// per cache (a clone accumulates independently), so CoW stays sound.
    frozen_attn: Vec<f32>,
    /// Loose region, rows `[frozen_rows, len)`: row-major keys `n * d`.
    k: Vec<f32>,
    /// Loose row-major values, `n * d`.
    v: Vec<f32>,
    /// Loose original absolute positions.
    pos: Vec<i32>,
    /// Loose accumulated attention mass per row (H2O).
    attn: Vec<f32>,
}

impl HeadStore {
    fn len(&self, d: usize) -> usize {
        debug_assert_eq!(self.k.len() % d, 0);
        self.frozen_rows + self.k.len() / d
    }

    /// Bytes resident outside pool blocks: the loose region plus the live
    /// frozen-row attention mass.
    fn loose_bytes(&self) -> usize {
        (self.k.len() + self.v.len() + self.attn.len() + self.frozen_attn.len())
            * std::mem::size_of::<f32>()
            + self.pos.len() * std::mem::size_of::<i32>()
    }

    /// Freeze whole blocks out of the loose prefix until `frozen_rows`
    /// would pass `upto` (absolute rows, block-aligned by the caller).
    /// Best-effort: freezing is an optimization (paging + CoW sharing),
    /// never a correctness requirement, so budget exhaustion just leaves
    /// the remaining rows loose for admission control to deal with.
    fn freeze_prefix(&mut self, d: usize, pool: &Arc<BlockPool>, upto: usize, kind: CodecKind) {
        let rows = pool.rows_per_block();
        // Loose bytes each freeze drains (K, V, positions; the attention
        // mass migrates to `frozen_attn` and stays loose).  The pool's
        // loose gauge is only re-synced after the caller finishes, so each
        // successive block's budget check must also credit everything this
        // call already drained — otherwise drained-but-still-gauged bytes
        // double-count and freezing stalls exactly under budget pressure.
        // The credit is the drained *fp32* loose bytes regardless of codec:
        // it reverses the loose gauge, not the (smaller) encoded charge.
        let replaced =
            rows * (2 * d * std::mem::size_of::<f32>() + std::mem::size_of::<i32>());
        let mut drained = 0usize;
        while self.frozen_rows + rows <= upto {
            let w = rows * d;
            match BlockPool::alloc_quant_block(
                pool,
                d,
                kind,
                &self.k[..w],
                &self.v[..w],
                &self.pos[..rows],
                &self.attn[..rows],
                drained + replaced,
            ) {
                Ok(block) => {
                    self.frozen.push(block);
                    self.frozen_attn.extend_from_slice(&self.attn[..rows]);
                    self.k.drain(..w);
                    self.v.drain(..w);
                    self.pos.drain(..rows);
                    self.attn.drain(..rows);
                    self.frozen_rows += rows;
                    drained += replaced;
                }
                Err(_) => break,
            }
        }
    }

    /// Move every frozen block back into the loose region (global-scope
    /// scoring, or a compaction window reaching behind the frozen line).
    fn thaw(&mut self, d: usize) {
        if self.frozen.is_empty() {
            return;
        }
        let mut k = Vec::with_capacity(self.frozen_rows * d + self.k.len());
        let mut v = Vec::with_capacity(k.capacity());
        let mut pos = Vec::with_capacity(self.frozen_rows + self.pos.len());
        let mut attn = Vec::with_capacity(pos.capacity());
        for b in &self.frozen {
            let data = b.read();
            k.extend_from_slice(data.k());
            v.extend_from_slice(data.v());
            pos.extend_from_slice(data.pos());
        }
        // Live mass, not the blocks' freeze-time snapshot.
        attn.extend_from_slice(&self.frozen_attn);
        k.extend_from_slice(&self.k);
        v.extend_from_slice(&self.v);
        pos.extend_from_slice(&self.pos);
        attn.extend_from_slice(&self.attn);
        self.k = k;
        self.v = v;
        self.pos = pos;
        self.attn = attn;
        self.frozen.clear();
        self.frozen_attn.clear();
        self.frozen_rows = 0;
    }

    /// Keep only `keep` (ascending indices into the window) within the
    /// absolute row window `[start, start+l)`, leaving rows outside it
    /// untouched.  The window must lie in the loose region; only the loose
    /// region is rebuilt (the frozen prefix is below `start` and is not
    /// touched at all — the block-remap property).
    fn compact_window(&mut self, d: usize, start: usize, l: usize, keep: &[usize]) {
        debug_assert!(start >= self.frozen_rows);
        debug_assert!(keep.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(keep.iter().all(|&i| i < l));
        let s = start - self.frozen_rows;
        let mut k = Vec::with_capacity(self.k.len() - (l - keep.len()) * d);
        let mut v = Vec::with_capacity(k.capacity());
        let mut pos = Vec::with_capacity(self.pos.len() - (l - keep.len()));
        let mut attn = Vec::with_capacity(pos.capacity());
        k.extend_from_slice(&self.k[..s * d]);
        v.extend_from_slice(&self.v[..s * d]);
        pos.extend_from_slice(&self.pos[..s]);
        attn.extend_from_slice(&self.attn[..s]);
        for &i in keep {
            let r = s + i;
            k.extend_from_slice(&self.k[r * d..(r + 1) * d]);
            v.extend_from_slice(&self.v[r * d..(r + 1) * d]);
            pos.push(self.pos[r]);
            attn.push(self.attn[r]);
        }
        k.extend_from_slice(&self.k[(s + l) * d..]);
        v.extend_from_slice(&self.v[(s + l) * d..]);
        pos.extend_from_slice(&self.pos[s + l..]);
        attn.extend_from_slice(&self.attn[s + l..]);
        self.k = k;
        self.v = v;
        self.pos = pos;
        self.attn = attn;
    }

    /// Copy the first `n_rows` rows of K and V into row-major `dst`
    /// buffers (padded-export gather across frozen blocks + loose tail).
    fn copy_rows(&self, d: usize, n_rows: usize, dst_k: &mut [f32], dst_v: &mut [f32]) {
        let mut row = 0usize;
        for b in &self.frozen {
            if row == n_rows {
                return;
            }
            let take = b.rows().min(n_rows - row);
            let data = b.read();
            dst_k[row * d..(row + take) * d].copy_from_slice(&data.k()[..take * d]);
            dst_v[row * d..(row + take) * d].copy_from_slice(&data.v()[..take * d]);
            row += take;
        }
        if row < n_rows {
            let take = n_rows - row;
            dst_k[row * d..(row + take) * d].copy_from_slice(&self.k[..take * d]);
            dst_v[row * d..(row + take) * d].copy_from_slice(&self.v[..take * d]);
        }
    }

    fn gather_k(&self, d: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.frozen_rows * d + self.k.len());
        for b in &self.frozen {
            out.extend_from_slice(b.read().k());
        }
        out.extend_from_slice(&self.k);
        out
    }

    fn gather_v(&self, d: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.frozen_rows * d + self.v.len());
        for b in &self.frozen {
            out.extend_from_slice(b.read().v());
        }
        out.extend_from_slice(&self.v);
        out
    }

    fn gather_attn(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.frozen_rows + self.attn.len());
        out.extend_from_slice(&self.frozen_attn);
        out.extend_from_slice(&self.attn);
        out
    }
}

/// Per-layer state.
#[derive(Debug, Clone)]
pub struct LayerCache {
    pub heads: Vec<HeadStore>,
    /// Rows `< boundary` are sink + already-compressed survivors.
    pub boundary: usize,
}

/// The full per-sequence cache.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub layers: Vec<LayerCache>,
    /// Total tokens ever appended (= next absolute position).
    pub appended: usize,
    /// Registers the loose-region bytes with the owning pool (cloning a
    /// cache registers the clone's own copy; dropping deregisters).
    gauge: LooseGauge,
    /// Per-layer block codec map: every freeze on this cache encodes
    /// through `quant.codec_for(layer)`.  Defaults to fp32 (identity);
    /// the engine installs the serving configuration's spec on every
    /// cache it creates.  Shared, immutable — clones keep encoding the
    /// same way.
    quant: Arc<QuantSpec>,
}

impl KvCache {
    /// A cache on a private, unbudgeted pool (tests, standalone tools).
    pub fn new(n_layers: usize, n_heads: usize, d_head: usize) -> Self {
        KvCache::new_in(
            BlockPool::unbounded(BlockPool::DEFAULT_ROWS_PER_BLOCK),
            n_layers,
            n_heads,
            d_head,
        )
    }

    /// A cache drawing its blocks from `pool` (the engine's shared pool on
    /// the serving path — one pool per engine, slots draw from it).
    pub fn new_in(pool: Arc<BlockPool>, n_layers: usize, n_heads: usize, d_head: usize) -> Self {
        KvCache {
            n_layers,
            n_heads,
            d_head,
            layers: (0..n_layers)
                .map(|_| LayerCache {
                    heads: vec![HeadStore::default(); n_heads],
                    boundary: 0,
                })
                .collect(),
            appended: 0,
            gauge: LooseGauge::new(pool),
            quant: Arc::new(QuantSpec::fp32()),
        }
    }

    /// The pool this cache allocates from.
    pub fn pool(&self) -> &Arc<BlockPool> {
        self.gauge.pool()
    }

    /// Install the block codec map.  Applies to *future* freezes only —
    /// already-frozen blocks keep the codec they were encoded with (each
    /// block carries its own tag), so flipping the spec mid-life is safe.
    pub fn set_quant(&mut self, quant: Arc<QuantSpec>) {
        self.quant = quant;
    }

    /// The codec map freezes on this cache encode through.
    pub fn quant(&self) -> &Arc<QuantSpec> {
        &self.quant
    }

    /// Current row count of `layer` (uniform across its heads).
    pub fn len(&self, layer: usize) -> usize {
        self.layers[layer].heads[0].len(self.d_head)
    }

    pub fn lens(&self) -> Vec<usize> {
        (0..self.n_layers).map(|l| self.len(l)).collect()
    }

    pub fn max_len(&self) -> usize {
        self.lens().into_iter().max().unwrap_or(0)
    }

    /// Total retained rows summed over layers (session-store accounting;
    /// head counts are uniform within a layer, so one length per layer).
    pub fn total_rows(&self) -> usize {
        self.lens().into_iter().sum()
    }

    /// Exact resident bytes of this cache: frozen pool blocks plus the
    /// loose regions, counting K, V, *and* the position/attention side
    /// arrays (which the old estimate ignored).
    pub fn exact_bytes(&self) -> usize {
        let mut blocks = 0usize;
        let mut loose = 0usize;
        for layer in &self.layers {
            for head in &layer.heads {
                blocks += head.frozen.iter().map(|b| b.payload_bytes()).sum::<usize>();
                loose += head.loose_bytes();
            }
        }
        debug_assert_eq!(loose, self.gauge.bytes(), "loose-byte gauge out of sync");
        blocks + loose
    }

    /// Checked alias of [`KvCache::exact_bytes`].  (Historically a K/V-only
    /// estimate that undercounted the `pos`/`attn` side arrays; kept under
    /// the old name so accounting call sites read the exact number.)
    pub fn approx_bytes(&self) -> usize {
        self.exact_bytes()
    }

    /// Pool blocks this cache references, summed over heads.  A block
    /// shared with a clone counts once *per referencing cache* here; the
    /// pool's `resident_blocks` counts it once globally.
    pub fn frozen_blocks(&self) -> usize {
        self.layers.iter().flat_map(|l| l.heads.iter()).map(|h| h.frozen.len()).sum()
    }

    /// Rows of `layer` frozen into pool blocks (uniform across heads on
    /// every path the driver takes).
    pub fn frozen_rows(&self, layer: usize) -> usize {
        self.layers[layer].heads[0].frozen_rows
    }

    pub fn is_empty(&self) -> bool {
        self.appended == 0
    }

    /// Uncompressed tail length of `layer`.
    pub fn tail_len(&self, layer: usize) -> usize {
        self.len(layer) - self.layers[layer].boundary
    }

    /// Full re-scan of the loose regions (compaction / thaw paths, which
    /// change sizes irregularly).  Appends use the O(1) delta instead.
    fn sync_gauge(&mut self) {
        let loose: usize = self
            .layers
            .iter()
            .flat_map(|l| l.heads.iter())
            .map(|h| h.loose_bytes())
            .sum();
        self.gauge.set(loose);
    }

    /// O(1) gauge update for the per-token hot path: `n_rows` loose rows
    /// were just appended to every head of every layer.
    fn grow_gauge(&mut self, n_rows: usize) {
        let delta = n_rows * row_bytes(self.n_layers, self.n_heads, self.d_head);
        let bytes = self.gauge.bytes() + delta;
        self.gauge.set(bytes);
    }

    /// Append one token's K/V for every layer/head.
    ///
    /// `k_new`/`v_new` layout: `[n_layers, n_heads, d_head]` row-major —
    /// exactly the decode executable's `k_new` output.
    pub fn append_token(&mut self, k_new: &[f32], v_new: &[f32], position: i32) -> Result<()> {
        let d = self.d_head;
        let nh = self.n_heads;
        let expect = self.n_layers * nh * d;
        if k_new.len() != expect || v_new.len() != expect {
            bail!("append_token: expected {expect} floats, got {}", k_new.len());
        }
        for (li, layer) in self.layers.iter_mut().enumerate() {
            for (hi, head) in layer.heads.iter_mut().enumerate() {
                let off = (li * nh + hi) * d;
                head.k.extend_from_slice(&k_new[off..off + d]);
                head.v.extend_from_slice(&v_new[off..off + d]);
                head.pos.push(position);
                head.attn.push(0.0);
            }
        }
        self.appended += 1;
        self.grow_gauge(1);
        Ok(())
    }

    /// Ingest a prefill output: `k`/`v` are `[n_layers, n_heads, t_bucket,
    /// d_head]` and `attn_sums` is `[n_layers, n_heads, t_bucket]`; only the
    /// first `true_len` rows are real.
    pub fn ingest_prefill(
        &mut self,
        k: &[f32],
        v: &[f32],
        attn_sums: &[f32],
        t_bucket: usize,
        true_len: usize,
    ) -> Result<()> {
        self.ingest_prefill_segment(k, v, attn_sums, t_bucket, 0, true_len)
    }

    /// Ingest rows `[from, to)` of a prefill output (same layouts as
    /// [`KvCache::ingest_prefill`]).  `from` must equal the rows already
    /// ingested from this output, so a segmented ingest — interleaving
    /// compression (and prefix-cache snapshots) between segments — appends
    /// each row at the same absolute position a whole-output ingest would
    /// have; the driver's order-insensitivity makes the final states equal.
    pub fn ingest_prefill_segment(
        &mut self,
        k: &[f32],
        v: &[f32],
        attn_sums: &[f32],
        t_bucket: usize,
        from: usize,
        to: usize,
    ) -> Result<()> {
        let d = self.d_head;
        let nh = self.n_heads;
        if k.len() != self.n_layers * nh * t_bucket * d {
            bail!(
                "ingest_prefill: bad k len {} for bucket {t_bucket}",
                k.len()
            );
        }
        if from > to || to > t_bucket {
            bail!("ingest_prefill: bad row segment [{from}, {to}) for bucket {t_bucket}");
        }
        let rows = to - from;
        let base_pos = self.appended as i32 - from as i32;
        for (li, layer) in self.layers.iter_mut().enumerate() {
            for (hi, head) in layer.heads.iter_mut().enumerate() {
                let base = (li * nh + hi) * t_bucket;
                let row0 = (base + from) * d;
                head.k.extend_from_slice(&k[row0..row0 + rows * d]);
                head.v.extend_from_slice(&v[row0..row0 + rows * d]);
                head.pos.extend((from as i32..to as i32).map(|p| base_pos + p));
                head.attn.extend_from_slice(&attn_sums[base + from..base + to]);
            }
        }
        self.appended += rows;
        self.grow_gauge(rows);
        Ok(())
    }

    /// Add one decode step's attention row (`[n_layers, n_heads, t_max]`,
    /// aligned with current row order) to the accumulated H2O statistic.
    /// Frozen rows accumulate into the per-cache `frozen_attn` side array
    /// (the blocks themselves are immutable and possibly shared), so a
    /// later thaw — e.g. a turn that switches to a global-scope policy —
    /// scores on up-to-date mass.
    pub fn accumulate_attention(&mut self, attn_row: &[f32], t_max: usize) -> Result<()> {
        if attn_row.len() != self.n_layers * self.n_heads * t_max {
            bail!("accumulate_attention: bad len {}", attn_row.len());
        }
        let d = self.d_head;
        let nh = self.n_heads;
        for (li, layer) in self.layers.iter_mut().enumerate() {
            for (hi, head) in layer.heads.iter_mut().enumerate() {
                let base = (li * nh + hi) * t_max;
                let n = head.len(d).min(t_max);
                let frozen = head.frozen_rows;
                for r in 0..frozen.min(n) {
                    head.frozen_attn[r] += attn_row[base + r];
                }
                for r in frozen..n {
                    head.attn[r - frozen] += attn_row[base + r];
                }
            }
        }
        Ok(())
    }

    /// Apply a per-head keep-set to the window `[start, start+l)` of
    /// `layer`.  `keeps[h]` must be ascending indices into the window and
    /// all heads must keep the same count (shape contract).
    ///
    /// Rows below the window start that fill whole blocks are frozen into
    /// the pool first (they are final — the driver's start is monotone per
    /// layer), so the rebuild only touches the loose tail.  A caller whose
    /// window reaches behind the frozen line (arbitrary direct use; the
    /// driver never does this) gets the layer thawed transparently.
    pub fn compact_layer(
        &mut self,
        layer: usize,
        start: usize,
        l: usize,
        keeps: &[Vec<usize>],
    ) -> Result<()> {
        let d = self.d_head;
        if keeps.len() != self.n_heads {
            bail!("compact_layer: {} keep sets for {} heads", keeps.len(), self.n_heads);
        }
        let kept = keeps[0].len();
        if keeps.iter().any(|ks| ks.len() != kept) {
            bail!("compact_layer: unequal keep counts across heads");
        }
        let len = self.len(layer);
        if start + l > len {
            bail!("compact_layer: window [{start}, {}) out of bounds {len}", start + l);
        }
        if self.layers[layer].heads.iter().any(|h| start < h.frozen_rows) {
            self.thaw_layer(layer);
        }
        let pool = Arc::clone(self.gauge.pool());
        let rpb = pool.rows_per_block();
        let freeze_upto = (start / rpb) * rpb;
        let kind = self.quant.codec_for(layer);
        for hi in 0..self.n_heads {
            let head = &mut self.layers[layer].heads[hi];
            head.freeze_prefix(d, &pool, freeze_upto, kind);
            head.compact_window(d, start, l, &keeps[hi]);
            // Re-sync after every head so the next head's freeze budget
            // checks never double-count bytes this head just drained or
            // evicted (compaction is off the per-token hot path).
            self.sync_gauge();
        }
        self.layers[layer].boundary = start + kept;
        Ok(())
    }

    /// Freeze the loose prefix of `layer` into pool blocks up to
    /// `upto_rows` (aligned *down* to whole blocks; rows already frozen
    /// are skipped).  Best-effort under a byte budget, like compaction's
    /// freezing — rows simply stay loose on exhaustion.
    ///
    /// Safety contract (the caller's, not checked here): no future
    /// scoring window may start below `upto_rows` on this cache or any
    /// clone of it.  The radix prefix cache uses this at insert time to
    /// freeze snapshot tails that compression will never touch — rows
    /// below the layer's boundary (partition window starts are monotone
    /// from `boundary.max(sink)`), or the whole layer for configurations
    /// the driver never compacts (`PolicyKind::None`, skipped layers) —
    /// so even never-compacted snapshots share CoW instead of deep-copying
    /// their loose region into every clone.
    pub fn freeze_layer_prefix(&mut self, layer: usize, upto_rows: usize) {
        let d = self.d_head;
        let pool = Arc::clone(self.gauge.pool());
        let rpb = pool.rows_per_block();
        let upto = (upto_rows.min(self.len(layer)) / rpb) * rpb;
        let kind = self.quant.codec_for(layer);
        for hi in 0..self.n_heads {
            self.layers[layer].heads[hi].freeze_prefix(d, &pool, upto, kind);
            // Re-sync per head (as compaction does) so the next head's
            // freeze budget checks never double-count drained bytes.
            self.sync_gauge();
        }
    }

    /// Move every frozen block of `layer` back into contiguous loose
    /// storage.  Needed by global-scope policies (original H2O), whose
    /// scoring window spans the whole evictable region; a no-op for caches
    /// that never froze (every pure-H2O cache, since their compaction
    /// start stays at the sink).
    pub fn thaw_layer(&mut self, layer: usize) {
        let d = self.d_head;
        for head in self.layers[layer].heads.iter_mut() {
            head.thaw(d);
        }
        self.sync_gauge();
    }

    /// Flat padded export of one layer for upload: `([n_heads, t_max, d],
    /// same for v)`; rows `>= len` are zero.
    pub fn layer_padded(&self, layer: usize, t_max: usize) -> (Vec<f32>, Vec<f32>) {
        let d = self.d_head;
        let mut k = vec![0.0f32; self.n_heads * t_max * d];
        let mut v = vec![0.0f32; self.n_heads * t_max * d];
        self.layer_padded_into(layer, t_max, &mut k, &mut v);
        (k, v)
    }

    /// Allocation-free variant of [`KvCache::layer_padded`]: writes the
    /// padded layer into caller-owned `[n_heads, t_max, d]` slices, zeroing
    /// rows `>= len` so a reused buffer never leaks a longer previous
    /// state.  The incremental decode paths call this once per layer per
    /// *compression event* instead of once per token.
    pub fn layer_padded_into(&self, layer: usize, t_max: usize, k: &mut [f32], v: &mut [f32]) {
        let d = self.d_head;
        let per_head = t_max * d;
        assert_eq!(k.len(), self.n_heads * per_head, "layer_padded_into: k shape");
        assert_eq!(v.len(), self.n_heads * per_head, "layer_padded_into: v shape");
        let len = self.len(layer).min(t_max);
        for (hi, head) in self.layers[layer].heads.iter().enumerate() {
            let dst = hi * per_head;
            head.copy_rows(d, len, &mut k[dst..dst + len * d], &mut v[dst..dst + len * d]);
            k[dst + len * d..dst + per_head].fill(0.0);
            v[dst + len * d..dst + per_head].fill(0.0);
        }
    }

    /// Flat padded export of the whole cache: `[n_layers, n_heads, t_max, d]`.
    pub fn all_padded(&self, t_max: usize) -> (Vec<f32>, Vec<f32>) {
        let per = self.n_heads * t_max * self.d_head;
        let mut k = Vec::with_capacity(self.n_layers * per);
        let mut v = Vec::with_capacity(self.n_layers * per);
        for l in 0..self.n_layers {
            let (lk, lv) = self.layer_padded(l, t_max);
            k.extend_from_slice(&lk);
            v.extend_from_slice(&lv);
        }
        (k, v)
    }

    /// Borrow the row range `[start, start+l)` of one head as K/V slices.
    ///
    /// The range must lie in the loose region (`start >= frozen_rows`).
    /// The compression driver guarantees this: partition-scope window
    /// starts are monotone per layer and freezing never passes the last
    /// start; global-scope scoring thaws the layer first.
    pub fn window(&self, layer: usize, head: usize, start: usize, l: usize) -> Window<'_> {
        let d = self.d_head;
        let h = &self.layers[layer].heads[head];
        assert!(
            start >= h.frozen_rows,
            "window [{start}, {}) reaches behind the frozen boundary ({} rows): \
             thaw_layer first or keep window starts monotone",
            start + l,
            h.frozen_rows
        );
        let s = start - h.frozen_rows;
        Window {
            k: &h.k[s * d..(s + l) * d],
            v: &h.v[s * d..(s + l) * d],
            attn: &h.attn[s..s + l],
            pos: &h.pos[s..s + l],
        }
    }

    /// Retained original positions of one head (analysis / tests),
    /// gathered across frozen blocks and the loose tail.
    pub fn positions(&self, layer: usize, head: usize) -> Vec<i32> {
        let mut out = Vec::new();
        self.positions_into(layer, head, &mut out);
        out
    }

    /// Gather retained positions into `out` (cleared first) — the
    /// allocation-free variant of [`KvCache::positions`] for per-step hot
    /// loops that can reuse a scratch buffer.
    pub fn positions_into(&self, layer: usize, head: usize, out: &mut Vec<i32>) {
        let h = &self.layers[layer].heads[head];
        out.clear();
        out.reserve(h.frozen_rows + h.pos.len());
        for b in &h.frozen {
            out.extend_from_slice(b.read().pos());
        }
        out.extend_from_slice(&h.pos);
    }

    /// All keys of one head, gathered contiguously (tests / analysis).
    pub fn head_k(&self, layer: usize, head: usize) -> Vec<f32> {
        self.layers[layer].heads[head].gather_k(self.d_head)
    }

    /// All values of one head, gathered contiguously (tests / analysis).
    pub fn head_v(&self, layer: usize, head: usize) -> Vec<f32> {
        self.layers[layer].heads[head].gather_v(self.d_head)
    }

    /// Accumulated attention mass of one head, gathered contiguously.
    pub fn head_attn(&self, layer: usize, head: usize) -> Vec<f32> {
        self.layers[layer].heads[head].gather_attn()
    }

    // -- persistence (kvstore descriptors) -------------------------------------

    /// Serialize this cache into a store descriptor: every frozen block
    /// is persisted (or its existing record re-claimed — a block spilled
    /// by the pool is never re-serialized) and each head's loose region
    /// plus its live frozen-row attention mass becomes a binary sidecar
    /// record.  The returned descriptor owns one store claim per block
    /// reference; journaling it (`journal_session_put` /
    /// `journal_prefix_put`) hands ownership to the store, which releases
    /// the claims when the descriptor is superseded or removed.  On
    /// failure every claim and sidecar written so far is rolled back.
    pub fn persist(&self, store: &KvStore) -> Result<Json> {
        let mut claimed: Vec<u64> = Vec::new();
        let mut blobs: Vec<u64> = Vec::new();
        match self.persist_desc(store, &mut claimed, &mut blobs) {
            Ok(desc) => Ok(desc),
            Err(e) => {
                store.abort_blobs(&blobs);
                for id in claimed {
                    store.release_block(id);
                }
                Err(e)
            }
        }
    }

    fn persist_desc(
        &self,
        store: &KvStore,
        claimed: &mut Vec<u64>,
        blobs: &mut Vec<u64>,
    ) -> Result<Json> {
        let mut layers = Vec::with_capacity(self.n_layers);
        for layer in &self.layers {
            let mut heads = Vec::with_capacity(self.n_heads);
            for head in &layer.heads {
                let mut fb = Vec::with_capacity(head.frozen.len());
                for b in &head.frozen {
                    let id = b.persist_into(store)?;
                    claimed.push(id);
                    fb.push(json::n(id as f64));
                }
                let sc = store.put_blob(&encode_sidecar(head))?;
                blobs.push(sc);
                heads.push(json::obj(vec![
                    ("fr", json::n(head.frozen_rows as f64)),
                    ("fb", json::arr(fb)),
                    ("sc", json::n(sc as f64)),
                ]));
            }
            layers.push(json::obj(vec![
                ("b", json::n(layer.boundary as f64)),
                ("heads", json::arr(heads)),
            ]));
        }
        Ok(json::obj(vec![
            ("nl", json::n(self.n_layers as f64)),
            ("nh", json::n(self.n_heads as f64)),
            ("d", json::n(self.d_head as f64)),
            ("app", json::n(self.appended as f64)),
            ("cache", json::obj(vec![("layers", json::arr(layers))])),
        ]))
    }

    /// Rebuild a cache from a descriptor produced by [`KvCache::persist`]
    /// (the boot restore path).  Blocks adopt lazily — they start spilled
    /// and fault in on first read, so restoring a large inventory costs
    /// no resident bytes up front.  `handles` must be shared across every
    /// restore of one boot so a block referenced by several descriptors
    /// (a detached session and a prefix snapshot sharing a CoW prefix)
    /// materializes as one `Arc<Block>`, exactly as before the restart.
    pub fn restore(
        pool: &Arc<BlockPool>,
        store: &KvStore,
        desc: &Json,
        handles: &mut HashMap<u64, Arc<Block>>,
    ) -> Result<KvCache> {
        let nl = desc.get("nl")?.as_usize()?;
        let nh = desc.get("nh")?.as_usize()?;
        let d = desc.get("d")?.as_usize()?;
        let appended = desc.get("app")?.as_usize()?;
        let layers_json = desc.get("cache")?.get("layers")?.as_arr()?;
        if layers_json.len() != nl {
            bail!("restore: descriptor has {} layers, dims say {nl}", layers_json.len());
        }
        let mut cache = KvCache::new_in(Arc::clone(pool), nl, nh, d);
        cache.appended = appended;
        for (li, layer_json) in layers_json.iter().enumerate() {
            let heads_json = layer_json.get("heads")?.as_arr()?;
            if heads_json.len() != nh {
                bail!("restore: layer {li} has {} heads, dims say {nh}", heads_json.len());
            }
            cache.layers[li].boundary = layer_json.get("b")?.as_usize()?;
            for (hi, head_json) in heads_json.iter().enumerate() {
                let fr = head_json.get("fr")?.as_usize()?;
                let mut blocks = Vec::new();
                let mut rows = 0usize;
                for id_json in head_json.get("fb")?.as_arr()? {
                    let id = id_json.as_i64()? as u64;
                    let block = match handles.get(&id) {
                        Some(b) => Arc::clone(b),
                        None => {
                            let (b_rows, b_d, _) = store
                                .block_dims(id)
                                .ok_or_else(|| anyhow!("restore: unknown block {id}"))?;
                            if b_d != d {
                                bail!("restore: block {id} width {b_d} != cache width {d}");
                            }
                            let tag = store
                                .block_codec(id)
                                .ok_or_else(|| anyhow!("restore: unknown block {id}"))?;
                            let codec = CodecKind::from_tag(tag)
                                .ok_or_else(|| anyhow!("restore: block {id} has unknown codec tag {tag}"))?;
                            let b = BlockPool::adopt_spilled(pool, id, b_rows, b_d, codec);
                            handles.insert(id, Arc::clone(&b));
                            b
                        }
                    };
                    rows += block.rows();
                    blocks.push(block);
                }
                if rows != fr {
                    bail!("restore: head ({li},{hi}) blocks cover {rows} rows, descriptor says {fr}");
                }
                let sc = head_json.get("sc")?.as_i64()? as u64;
                let blob = store.read_blob(sc)?;
                let head = &mut cache.layers[li].heads[hi];
                head.frozen = blocks;
                head.frozen_rows = fr;
                decode_sidecar(&blob, d, fr, head)?;
            }
        }
        cache.sync_gauge();
        Ok(cache)
    }
}

/// A borrowed view of `l` consecutive rows of one head.
pub struct Window<'a> {
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub attn: &'a [f32],
    pub pos: &'a [i32],
}

// -- sidecar serialization (little-endian, mirrors kvstore's block codec) ------

/// Encode a head's non-block state — the live frozen-row attention mass
/// plus the whole loose region.  Binary because JSON cannot round-trip
/// non-finite f32 bits:
/// `[fr u32][frozen_attn f32×fr][n u32][k f32×n·d][v f32×n·d][pos i32×n][attn f32×n]`.
fn encode_sidecar(head: &HeadStore) -> Vec<u8> {
    let n = head.pos.len();
    let mut out = Vec::with_capacity(
        8 + (head.frozen_attn.len() + head.k.len() + head.v.len() + 2 * n) * 4,
    );
    out.extend_from_slice(&(head.frozen_attn.len() as u32).to_le_bytes());
    for x in &head.frozen_attn {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out.extend_from_slice(&(n as u32).to_le_bytes());
    for x in &head.k {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for x in &head.v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for p in &head.pos {
        out.extend_from_slice(&p.to_le_bytes());
    }
    for x in &head.attn {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn take_u32(buf: &[u8], off: &mut usize) -> Result<usize> {
    let b = buf.get(*off..*off + 4).ok_or_else(|| anyhow!("short sidecar record"))?;
    *off += 4;
    Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize)
}

fn take_f32s(buf: &[u8], off: &mut usize, n: usize) -> Result<Vec<f32>> {
    let end = *off + n * 4;
    let s = buf.get(*off..end).ok_or_else(|| anyhow!("short sidecar record"))?;
    *off = end;
    Ok(s.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Decode a sidecar into `head`'s loose region + frozen attention mass.
/// `fr` is the descriptor's frozen-row count — the blob must agree.
fn decode_sidecar(buf: &[u8], d: usize, fr: usize, head: &mut HeadStore) -> Result<()> {
    let mut off = 0usize;
    let n_frozen = take_u32(buf, &mut off)?;
    if n_frozen != fr {
        bail!("sidecar frozen-mass length {n_frozen} != descriptor frozen rows {fr}");
    }
    head.frozen_attn = take_f32s(buf, &mut off, n_frozen)?;
    let n = take_u32(buf, &mut off)?;
    head.k = take_f32s(buf, &mut off, n * d)?;
    head.v = take_f32s(buf, &mut off, n * d)?;
    let pos_bytes =
        buf.get(off..off + n * 4).ok_or_else(|| anyhow!("short sidecar record"))?;
    head.pos =
        pos_bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
    off += n * 4;
    head.attn = take_f32s(buf, &mut off, n)?;
    if off != buf.len() {
        bail!("sidecar record has {} trailing bytes", buf.len() - off);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn filled(nl: usize, nh: usize, d: usize, n: usize) -> KvCache {
        let mut c = KvCache::new(nl, nh, d);
        let mut rng = Rng::seed_from(1);
        for t in 0..n {
            let k: Vec<f32> = (0..nl * nh * d).map(|_| rng.normal()).collect();
            let v: Vec<f32> = (0..nl * nh * d).map(|_| rng.normal()).collect();
            c.append_token(&k, &v, t as i32).unwrap();
        }
        c
    }

    #[test]
    fn append_grows_uniformly() {
        let c = filled(3, 2, 4, 10);
        assert_eq!(c.lens(), vec![10, 10, 10]);
        assert_eq!(c.appended, 10);
    }

    #[test]
    fn compact_keeps_selected_rows() {
        let mut c = filled(1, 2, 4, 8);
        let before_h0 = c.head_k(0, 0);
        // window rows 2..6, head0 keeps {1,3} (abs 3,5), head1 keeps {0,2} (abs 2,4)
        c.compact_layer(0, 2, 4, &[vec![1, 3], vec![0, 2]]).unwrap();
        assert_eq!(c.len(0), 6);
        assert_eq!(c.layers[0].boundary, 4);
        let d = 4;
        let after_h0 = c.head_k(0, 0);
        // head0 row2 should be old row 3
        assert_eq!(&after_h0[2 * d..3 * d], &before_h0[3 * d..4 * d]);
        assert_eq!(&after_h0[3 * d..4 * d], &before_h0[5 * d..6 * d]);
        // trailing rows shift down
        assert_eq!(&after_h0[4 * d..5 * d], &before_h0[6 * d..7 * d]);
        assert_eq!(c.positions(0, 0), vec![0, 1, 3, 5, 6, 7]);
        assert_eq!(c.positions(0, 1), vec![0, 1, 2, 4, 6, 7]);
    }

    #[test]
    fn quantized_freeze_shrinks_bytes_and_reads_decode_transparently() {
        let (nh, d) = (2, 4);
        let mut fp = filled(1, nh, d, 40);
        let mut q = fp.clone();
        q.set_quant(Arc::new(QuantSpec::all(CodecKind::Int8Sym)));
        fp.freeze_layer_prefix(0, 32);
        q.freeze_layer_prefix(0, 32);
        assert_eq!(fp.frozen_rows(0), q.frozen_rows(0), "same rows froze either way");
        assert!(q.frozen_blocks() > 0);
        assert!(
            q.exact_bytes() < fp.exact_bytes(),
            "int8 blocks are exact-accounted smaller: {} vs {}",
            q.exact_bytes(),
            fp.exact_bytes()
        );
        let s = q.pool().stats();
        assert_eq!(s.quant_blocks, q.frozen_blocks(), "every frozen block encoded");
        assert_eq!(
            s.quant_bytes,
            s.quant_blocks * CodecKind::Int8Sym.encoded_block_bytes(q.pool().rows_per_block(), d)
        );
        // reads decode transparently: positions exact, rows error-bounded
        assert_eq!(q.positions(0, 0), fp.positions(0, 0));
        let (kf, kq) = (fp.head_k(0, 0), q.head_k(0, 0));
        assert_eq!(kf.len(), kq.len());
        let max_abs = kf.iter().fold(0f32, |m, x| m.max(x.abs()));
        for (a, b) in kf.iter().zip(&kq) {
            assert!((a - b).abs() <= max_abs / 127.0 + 1e-6, "dequantized row within bound");
        }
        // thaw dequantizes: lossy but the cache stays structurally sound
        q.thaw_layer(0);
        assert_eq!(q.frozen_rows(0), 0);
        assert_eq!(q.len(0), 40);
        assert_eq!(q.positions(0, 1), fp.positions(0, 1));
    }

    #[test]
    fn compact_rejects_unequal_counts() {
        let mut c = filled(1, 2, 4, 8);
        assert!(c.compact_layer(0, 2, 4, &[vec![1], vec![0, 2]]).is_err());
    }

    #[test]
    fn padded_export_zero_fills() {
        let c = filled(2, 2, 4, 5);
        let (k, _v) = c.layer_padded(0, 8);
        assert_eq!(k.len(), 2 * 8 * 4);
        // row 5.. are zero
        for h in 0..2 {
            for r in 5..8 {
                let off = (h * 8 + r) * 4;
                assert!(k[off..off + 4].iter().all(|&x| x == 0.0));
            }
        }
    }

    #[test]
    fn ingest_prefill_respects_true_len() {
        let nl = 2;
        let nh = 2;
        let d = 3;
        let t_bucket = 6;
        let true_len = 4;
        let mut c = KvCache::new(nl, nh, d);
        let k: Vec<f32> = (0..nl * nh * t_bucket * d).map(|i| i as f32).collect();
        let v = k.clone();
        let attn: Vec<f32> = (0..nl * nh * t_bucket).map(|i| i as f32).collect();
        c.ingest_prefill(&k, &v, &attn, t_bucket, true_len).unwrap();
        assert_eq!(c.lens(), vec![4, 4]);
        assert_eq!(c.appended, 4);
        // layer1/head1 row0 == k[(1*2+1)*6*3 ..]
        let off = (1 * nh + 1) * t_bucket * d;
        assert_eq!(&c.head_k(1, 1)[..d], &k[off..off + d]);
        assert_eq!(c.head_attn(1, 1), attn[(1 * nh + 1) * t_bucket..][..4]);
    }

    #[test]
    fn segmented_ingest_matches_whole_ingest() {
        let (nl, nh, d) = (2, 2, 3);
        let t_bucket = 10;
        let true_len = 9;
        let mut rng = Rng::seed_from(17);
        let k: Vec<f32> = (0..nl * nh * t_bucket * d).map(|_| rng.normal()).collect();
        let v: Vec<f32> = (0..nl * nh * t_bucket * d).map(|_| rng.normal()).collect();
        let attn: Vec<f32> = (0..nl * nh * t_bucket).map(|_| rng.normal()).collect();
        let mut whole = KvCache::new(nl, nh, d);
        whole.ingest_prefill(&k, &v, &attn, t_bucket, true_len).unwrap();
        let mut seg = KvCache::new(nl, nh, d);
        for w in [(0usize, 4usize), (4, 7), (7, 9)] {
            seg.ingest_prefill_segment(&k, &v, &attn, t_bucket, w.0, w.1).unwrap();
        }
        assert_eq!(seg.appended, whole.appended);
        for l in 0..nl {
            for h in 0..nh {
                assert_eq!(seg.head_k(l, h), whole.head_k(l, h), "layer {l} head {h}");
                assert_eq!(seg.head_v(l, h), whole.head_v(l, h));
                assert_eq!(seg.positions(l, h), whole.positions(l, h));
                assert_eq!(seg.head_attn(l, h), whole.head_attn(l, h));
            }
        }
        assert_eq!(seg.exact_bytes(), whole.exact_bytes());
        // bad segments are typed errors
        assert!(seg.ingest_prefill_segment(&k, &v, &attn, t_bucket, 5, 3).is_err());
        assert!(seg.ingest_prefill_segment(&k, &v, &attn, t_bucket, 9, 11).is_err());
    }

    #[test]
    fn attention_accumulates_in_row_order() {
        let mut c = filled(1, 1, 2, 3);
        let t_max = 8;
        let mut row = vec![0.0f32; t_max];
        row[0] = 0.5;
        row[2] = 0.25;
        c.accumulate_attention(&row, t_max).unwrap();
        c.accumulate_attention(&row, t_max).unwrap();
        assert_eq!(c.head_attn(0, 0), vec![1.0, 0.0, 0.5]);
    }

    /// Compacting with a window start past whole blocks freezes them into
    /// the pool; reads (padded export, gathers, windows) are unchanged.
    #[test]
    fn compact_freezes_prefix_blocks() {
        let pool = BlockPool::unbounded(4);
        let mut c = KvCache::new_in(pool.clone(), 1, 1, 2);
        let mut rng = Rng::seed_from(9);
        for t in 0..20 {
            let k: Vec<f32> = (0..2).map(|_| rng.normal()).collect();
            c.append_token(&k, &k, t).unwrap();
        }
        let before_k = c.head_k(0, 0);
        let before_pos = c.positions(0, 0);
        // window [10, 14), keep 2 -> start 10 freezes rows [0, 8) as 2 blocks
        c.compact_layer(0, 10, 4, &[vec![0, 2]]).unwrap();
        assert_eq!(c.frozen_rows(0), 8);
        assert_eq!(c.frozen_blocks(), 2);
        assert_eq!(pool.stats().resident_blocks, 2);
        assert_eq!(c.len(0), 18);
        // prefix [0, 10) survived the remap bit-for-bit
        assert_eq!(&c.head_k(0, 0)[..10 * 2], &before_k[..10 * 2]);
        assert_eq!(&c.positions(0, 0)[..10], &before_pos[..10]);
        // a window at the new boundary still reads loose slices
        let w = c.window(0, 0, 12, 4);
        assert_eq!(w.pos.len(), 4);
        // exact bytes = 2 blocks + loose remainder + the live frozen-row
        // attention mass kept outside the blocks
        let rpb_bytes = crate::kvpool::block_bytes(4, 2);
        assert_eq!(
            c.exact_bytes(),
            2 * rpb_bytes
                + (18 - 8) * crate::kvpool::block_bytes(1, 2)
                + 8 * std::mem::size_of::<f32>()
        );
        // thaw restores one contiguous region and frees the blocks
        c.thaw_layer(0);
        assert_eq!(c.frozen_blocks(), 0);
        assert_eq!(pool.stats().resident_blocks, 0);
        assert!(pool.stats().free_blocks >= 2, "thawed blocks recycle to the free list");
        assert_eq!(c.len(0), 18);
    }

    /// Explicit prefix freezing (the radix-insert path): rows move into
    /// pool blocks block-aligned, reads are unchanged, and clones share
    /// the new blocks instead of copying the loose region.
    #[test]
    fn freeze_layer_prefix_is_block_aligned_and_read_transparent() {
        let pool = BlockPool::unbounded(4);
        let mut c = KvCache::new_in(pool.clone(), 1, 1, 2);
        let mut rng = Rng::seed_from(23);
        for t in 0..14 {
            let k: Vec<f32> = (0..2).map(|_| rng.normal()).collect();
            c.append_token(&k, &k, t).unwrap();
        }
        let before_k = c.head_k(0, 0);
        let before_pos = c.positions(0, 0);
        c.freeze_layer_prefix(0, 11); // aligns down to 8 = 2 blocks
        assert_eq!(c.frozen_rows(0), 8);
        assert_eq!(c.frozen_blocks(), 2);
        assert_eq!(c.len(0), 14, "freezing never changes logical content");
        assert_eq!(c.head_k(0, 0), before_k);
        assert_eq!(c.positions(0, 0), before_pos);
        // idempotent: a second call with a smaller target is a no-op
        c.freeze_layer_prefix(0, 4);
        assert_eq!(c.frozen_rows(0), 8);
        // a clone shares the blocks (refcount), never copies them
        let blocks_before = pool.stats().resident_blocks;
        let clone = c.clone();
        assert_eq!(pool.stats().resident_blocks, blocks_before);
        assert_eq!(clone.head_k(0, 0), before_k);
        // a target past the length clamps to the full (aligned) store
        c.freeze_layer_prefix(0, usize::MAX);
        assert_eq!(c.frozen_rows(0), 12);
    }

    /// H2O mass keeps accumulating on frozen rows (via the per-cache side
    /// array), and a thaw restores the live values — not the freeze-time
    /// snapshot stored in the immutable blocks.
    #[test]
    fn frozen_rows_keep_accumulating_attention() {
        let pool = BlockPool::unbounded(4);
        let mut c = KvCache::new_in(pool, 1, 1, 2);
        for t in 0..12 {
            c.append_token(&[1.0, 1.0], &[1.0, 1.0], t).unwrap();
        }
        c.compact_layer(0, 8, 2, &[vec![0]]).unwrap(); // freezes rows [0, 8)
        assert_eq!(c.frozen_rows(0), 8);
        let t_max = 16;
        let mut row = vec![0.0f32; t_max];
        row[2] = 1.0; // a frozen row
        row[9] = 0.5; // a loose row
        c.accumulate_attention(&row, t_max).unwrap();
        c.accumulate_attention(&row, t_max).unwrap();
        let attn = c.head_attn(0, 0);
        assert_eq!(attn[2], 2.0, "frozen rows keep accumulating mass");
        assert_eq!(attn[9], 1.0);
        c.thaw_layer(0);
        assert_eq!(c.head_attn(0, 0)[2], 2.0, "thaw restores live mass, not the snapshot");
        assert_eq!(c.head_attn(0, 0)[9], 1.0);
    }

    /// Cloning shares frozen blocks (refcount, not copy) and mutating the
    /// original never changes what the clone reads.
    #[test]
    fn clone_shares_frozen_blocks_cow() {
        let pool = BlockPool::unbounded(4);
        let mut c = KvCache::new_in(pool.clone(), 1, 1, 2);
        let mut rng = Rng::seed_from(10);
        for t in 0..16 {
            let k: Vec<f32> = (0..2).map(|_| rng.normal()).collect();
            c.append_token(&k, &k, t).unwrap();
        }
        c.compact_layer(0, 8, 4, &[vec![1, 2]]).unwrap(); // freezes rows [0, 8)
        assert_eq!(c.frozen_blocks(), 2);
        let snap_k = c.head_k(0, 0);
        let snap_pos = c.positions(0, 0);
        let clone = c.clone();
        assert_eq!(pool.stats().resident_blocks, 2, "clone shares, never copies, blocks");
        // mutate the original past another compaction
        for t in 16..32 {
            let k: Vec<f32> = (0..2).map(|_| rng.normal()).collect();
            c.append_token(&k, &k, t).unwrap();
        }
        c.compact_layer(0, 14, 8, &[vec![0, 5]]).unwrap();
        assert_eq!(clone.head_k(0, 0), snap_k, "shared blocks must never be mutated");
        assert_eq!(clone.positions(0, 0), snap_pos);
        drop(c);
        assert_eq!(clone.head_k(0, 0), snap_k, "clone owns its share of the blocks");
        drop(clone);
        assert_eq!(pool.stats().resident_blocks, 0, "all blocks recycled");
    }

    #[test]
    fn exact_bytes_counts_side_arrays() {
        let c = filled(2, 3, 4, 10);
        // 10 rows x 2 layers x 3 heads x (2*4 floats + pos + attn)
        let want = 10 * crate::kvpool::row_bytes(2, 3, 4);
        assert_eq!(c.exact_bytes(), want);
        assert_eq!(c.approx_bytes(), want, "approx_bytes is the checked exact alias");
        assert_eq!(c.pool().stats().loose_bytes, want);
    }

    #[test]
    fn prop_compact_preserves_untouched_regions() {
        prop::check(60, |g| {
            let d = g.usize(1, 6);
            let n = g.usize(6, 40);
            let start = g.usize(0, n.saturating_sub(6));
            let l = g.usize(2, (n - start).min(8)).max(2);
            let kept = g.usize(1, l - 1);
            let mut c = KvCache::new(1, 1, d);
            let mut rng = Rng::seed_from(g.case as u64);
            for t in 0..n {
                let k: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                c.append_token(&k, &k, t as i32).unwrap();
            }
            let before = c.head_k(0, 0);
            let mut keep: Vec<usize> = (0..l).collect();
            let mut r2 = Rng::seed_from(g.case as u64 + 999);
            r2.shuffle(&mut keep);
            keep.truncate(kept);
            keep.sort_unstable();
            c.compact_layer(0, start, l, &[keep.clone()]).unwrap();
            let after = c.head_k(0, 0);
            // prefix untouched
            if after[..start * d] != before[..start * d] {
                return Err("prefix changed".into());
            }
            // suffix shifted but identical content
            let suffix_rows = n - start - l;
            let got = &after[(start + kept) * d..];
            let want = &before[(start + l) * d..];
            if got != want || got.len() != suffix_rows * d {
                return Err("suffix mismatch".into());
            }
            // positions of kept rows ascend
            let pos = c.positions(0, 0);
            if pos.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("positions not ascending: {pos:?}"));
            }
            Ok(())
        });
    }

    /// A persisted cache restores bit-identically across a store reopen:
    /// frozen blocks adopt lazily (starting spilled, faulting in on first
    /// read), the loose tail and the *live* frozen-row attention mass come
    /// back from the sidecar, and reads drain the spilled tier to zero.
    #[test]
    fn persist_restore_round_trips_across_reopen() {
        use crate::kvstore::{testutil::TempDir, KvStore};
        let dir = TempDir::new("kvcache-persist");
        let pool = BlockPool::unbounded(4);
        let mut c = KvCache::new_in(pool.clone(), 2, 2, 3);
        let mut rng = Rng::seed_from(77);
        for t in 0..20 {
            let k: Vec<f32> = (0..2 * 2 * 3).map(|_| rng.normal()).collect();
            let v: Vec<f32> = (0..2 * 2 * 3).map(|_| rng.normal()).collect();
            c.append_token(&k, &v, t).unwrap();
        }
        // freezes rows [0, 8) of layer 0; layer 1 stays fully loose
        c.compact_layer(0, 10, 4, &[vec![0, 2], vec![1, 3]]).unwrap();
        assert_eq!(c.frozen_rows(0), 8);
        // accumulate onto a frozen row *after* the freeze: restore must
        // return this live value, not the block's freeze-time snapshot
        let mut row = vec![0.0f32; 2 * 2 * 32];
        row[2] = 1.5;
        c.accumulate_attention(&row, 32).unwrap();
        let pairs: Vec<(usize, usize)> =
            (0..2).flat_map(|l| (0..2).map(move |h| (l, h))).collect();
        let snapshot: Vec<_> = pairs
            .iter()
            .map(|&(l, h)| (c.head_k(l, h), c.head_v(l, h), c.positions(l, h), c.head_attn(l, h)))
            .collect();
        let lens = c.lens();
        let boundary = c.layers[0].boundary;
        let appended = c.appended;
        {
            let store = KvStore::open(dir.path()).unwrap();
            let desc = c.persist(&store).unwrap();
            store.journal_session_put("s", desc).unwrap();
            store.checkpoint().unwrap();
        }
        drop(c);
        let store = Arc::new(KvStore::open(dir.path()).unwrap());
        let pool2 = BlockPool::unbounded(4);
        pool2.bind_store(Arc::clone(&store));
        let desc = store.boot_sessions().pop().unwrap().1;
        let mut handles = HashMap::new();
        let r = KvCache::restore(&pool2, &store, &desc, &mut handles).unwrap();
        assert_eq!(r.lens(), lens);
        assert_eq!(r.appended, appended);
        assert_eq!(r.layers[0].boundary, boundary);
        assert_eq!(r.frozen_rows(0), 8);
        assert_eq!(handles.len(), 2, "one shared handle per distinct block");
        let spilled = pool2.stats();
        assert_eq!(spilled.spilled_blocks, 2, "blocks adopt lazily, starting spilled");
        assert_eq!(spilled.resident_blocks, 0);
        for (i, &(l, h)) in pairs.iter().enumerate() {
            let (k, v, pos, attn) = &snapshot[i];
            assert_eq!(&r.head_k(l, h), k, "layer {l} head {h} keys");
            assert_eq!(&r.head_v(l, h), v);
            assert_eq!(&r.positions(l, h), pos);
            assert_eq!(&r.head_attn(l, h), attn, "live frozen mass restored");
        }
        // the reads above faulted every block back in
        let after = pool2.stats();
        assert_eq!(after.spilled_blocks, 0);
        assert_eq!(after.resident_blocks, 2);
    }
}

//! `lagkv` — CLI for the LagKV serving stack.
//!
//! Subcommands:
//!   info                         backend + model inventory
//!   generate --prompt "..."      one-shot generation with any policy
//!   serve [--port 7199]          TCP server (v1 wire protocol, NDJSON)
//!   ops stats|info|sessions|drain|undrain|checkpoint|trace [--port 7199]
//!                                control plane of a running server
//!   tables --table1|--fig2|--fig3|--fig4|--fig5|--h2o|--ratio|--sim
//!                                regenerate the paper's tables/figures
//!
//! Common flags: --backend cpu|xla, --artifacts DIR,
//! --model llama_like|qwen_like, --policy P --sink S --lag L --ratio R
//! --scorer rust|xla, --items N.
//!
//! The default `cpu` backend is hermetic (no artifacts needed); `--backend
//! xla` drives the AOT PJRT path and requires `--features xla` plus
//! `make artifacts`.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use lagkv::backend::EngineSpec;
use lagkv::client::Client;
use lagkv::config::ServingConfig;
use lagkv::coordinator::{GenerateParams, Router, RouterConfig, SessionConfig};
use lagkv::engine::Engine;
use lagkv::harness;
use lagkv::metrics::PoolGauges;
use lagkv::server::Server;
use lagkv::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => info(&args),
        "generate" => generate(&args),
        "serve" => serve(&args),
        "ops" => ops(&args),
        "tables" => tables(&args),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = r#"lagkv — LagKV KV-cache compression serving stack

USAGE:
  lagkv info [--backend cpu|xla] [--artifacts DIR]
  lagkv generate --prompt "..." [--model M] [--policy P --lag L --ratio R]
                 [--stream] [--session ID]
  lagkv serve [--port 7199] [--models llama_like,qwen_like]
              [--max-queue 256] [--sessions 64] [--session-ttl 600]
              [--pool-mb N] [--session-mb N] [--prefix-cache]
              [--store-dir DIR] [--store-max-mb N] [--trace-dir DIR]
              [--quant int8[:LAYERS]]
  lagkv ops stats|info|sessions|drain|undrain|checkpoint|trace [--port 7199]
            [--model M] [--delete SESSION_ID]
  lagkv tables --table1|--fig2|--fig3|--fig4|--fig5|--h2o|--ratio|--sim
               [--items N] [--lag L] [--out FILE]

BACKENDS: cpu (default, hermetic) | xla (--features xla + make artifacts)
POLICIES: lagkv localkv l2norm h2o streaming streamingllm random none
WIRE PROTOCOL v1: see DESIGN.md §9 ({"v":1,"op":...} envelopes, NDJSON
  event streams, typed {"code","message"} errors, ops control plane:
  stats/sessions/info/drain/undrain/checkpoint; legacy bare request lines
  accepted via the compat shim).  Talk to it from Rust through
  lagkv::client::Client.
TIERED STORAGE: --store-dir DIR spills cold frozen KV blocks to disk under
  pool pressure and WAL-journals detached sessions + prefix snapshots, so
  both survive a restart (see DESIGN.md §11).  --store-max-mb N caps the
  page file; over the cap the coldest spilled inventory is evicted LRU.
QUANTIZED KV: --quant int8 encodes frozen blocks as per-row symmetric int8
  (4x smaller resident/spilled KV); --quant int8:0,2-5 quantizes only those
  layers.  Reads decode transparently (see DESIGN.md §14).
OBSERVABILITY: every request records a span (queued -> prefill segments ->
  decode -> compression -> done); `lagkv ops trace` shows recent spans and
  p50/p90/p99 latency summaries, --trace-dir DIR streams spans as NDJSON
  (see DESIGN.md §12).
"#;

fn load_engine(args: &Args, variant: &str) -> Result<Arc<Engine>> {
    Ok(Arc::new(EngineSpec::from_args(args)?.build(variant)?))
}

fn info(args: &Args) -> Result<()> {
    let spec = EngineSpec::from_args(args)?;
    println!("backend: {}", spec.backend.name());
    println!("artifacts: {}", spec.art_dir.display());
    for variant in ["llama_like", "qwen_like"] {
        match spec.build(variant) {
            Ok(e) => {
                println!(
                    "model {variant}: vocab={} d={} layers={} heads={}q/{}kv tmax={} (platform {})",
                    e.dims.vocab_size,
                    e.dims.d_model,
                    e.dims.n_layers,
                    e.dims.n_q_heads,
                    e.dims.n_kv_heads,
                    e.tmax,
                    e.backend().platform(),
                );
                let entries = e.backend().entries();
                if !entries.is_empty() {
                    println!("  entries: {}", entries.join(", "));
                }
            }
            Err(e) => println!("model {variant}: unavailable ({e:#})"),
        }
    }
    Ok(())
}

/// The one knob bundle every front end constructs (see coordinator docs).
fn params_from_args(args: &Args) -> Result<GenerateParams> {
    let prompt = match args.get("prompt") {
        Some(p) => p.to_string(),
        None => bail!("--prompt required"),
    };
    let mut p = GenerateParams::new(prompt)
        .model(args.get_or("model", "llama_like"))
        .sink(args.usize_or("sink", 4)?)
        .lag(args.usize_or("lag", 64)?)
        .ratio(args.f64_or("ratio", 0.5)?)
        .max_new(args.usize_or("max-new", 72)?)
        .seed(args.u64_or("seed", 0)?);
    if let Some(name) = args.get("policy") {
        p = p.policy(lagkv::config::PolicyKind::parse(name)?);
    }
    if let Some(skip) = args.get("skip-layers") {
        p = p.skip_layers(skip.parse()?);
    }
    if let Some(sid) = args.get("session") {
        p = p.session(sid);
    }
    Ok(p)
}

fn generate(args: &Args) -> Result<()> {
    let params = params_from_args(args)?;
    if args.has("stream") {
        // Stream through the full serving path: router -> coordinator ->
        // live events, printed as the same NDJSON lines the TCP server
        // emits.
        let model = params.model.clone();
        let router = Router::start(EngineSpec::from_args(args)?, &[model.clone()]);
        let handle = router.submit(&model, params.into_request(1)?)?;
        for ev in handle.events.iter() {
            println!("{}", lagkv::api::event_line(&ev));
            if ev.is_terminal() {
                break;
            }
        }
        drop(handle);
        router.shutdown();
        return Ok(());
    }
    let engine = load_engine(args, &params.model)?;
    let out = engine.run(&params)?;
    println!("text: {}", out.text);
    println!(
        "prompt_tokens={} new_tokens={} cache_lens={:?} compression_events={} prefill={}us decode={}us",
        out.prompt_tokens,
        out.tokens.len(),
        out.cache_lens,
        out.compression_events,
        out.prefill_us,
        out.decode_us
    );
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let serving = ServingConfig::from_args(args)?;
    let models = args.list_or("models", &["llama_like", "qwen_like"]);
    let router_cfg = RouterConfig {
        queue_depth: serving.max_queue,
        sessions: SessionConfig {
            capacity: serving.session_capacity,
            ttl: Duration::from_secs(serving.session_ttl_s),
            max_bytes: serving.session_max_bytes,
        },
        pool_max_bytes: serving.pool_max_bytes,
        prefix_cache: serving.prefix_cache.then(lagkv::kvpool::PrefixConfig::default),
        store_dir: serving.store_dir.clone(),
        store_max_bytes: serving.store_max_bytes,
        quant: serving.quant.clone(),
        trace_dir: serving.trace_dir.clone(),
    };
    let router = Arc::new(Router::start_with(EngineSpec::from_args(args)?, &models, router_cfg));
    let server = Arc::new(Server::new(router));
    let stop = Arc::new(AtomicBool::new(false));
    server.serve(serving.port, stop)
}

/// Control plane of a running server, through the typed client SDK.
fn ops(args: &Args) -> Result<()> {
    let port = args.usize_or("port", 7199)? as u16;
    let mut client = Client::connect(port)?;
    let action = args.positional.get(1).map(|s| s.as_str()).unwrap_or("stats");
    match action {
        "stats" => {
            let stats = client.stats()?;
            println!("draining: {}", stats.draining);
            for m in &stats.models {
                let c = &m.coord;
                println!("{}:", m.model);
                let mut gauges = PoolGauges::from(&m.pool);
                if let Some(p) = &m.prefix {
                    gauges = gauges.with_prefix(p);
                }
                for line in gauges.render().lines() {
                    println!("  {line}");
                }
                println!(
                    "  coord: completed {} cancelled {} failed {} queued {}/{} \
                     resumed {} shed {}+{} spilled {} pool-rejected {}",
                    c.completed,
                    c.cancelled,
                    c.failed,
                    c.queued,
                    m.queue_capacity,
                    c.sessions_resumed,
                    c.prefix_shed,
                    c.sessions_shed,
                    c.blocks_spilled,
                    c.pool_rejected,
                );
                println!(
                    "  sessions: {} entries, {:.1} KiB",
                    m.sessions.entries,
                    m.sessions.bytes as f64 / 1024.0
                );
                for h in &m.histograms {
                    println!(
                        "  {}: n={} p50={}us p90={}us p99={}us",
                        h.metric.name(),
                        h.count,
                        h.p50_us,
                        h.p90_us,
                        h.p99_us
                    );
                }
            }
        }
        "info" => {
            let info = client.info()?;
            println!("protocol: v{}", info.version);
            println!("policies: {}", info.policies.join(" "));
            println!(
                "queue depth {} | session capacity {} | prefix cache {}",
                info.queue_depth, info.session_capacity, info.prefix_cache
            );
            for m in &info.models {
                println!(
                    "{}: prefill {:?} decode {:?} max_prompt {} tmax {} pool budget {:?}",
                    m.model,
                    m.prefill_buckets,
                    m.decode_buckets,
                    m.max_prompt_tokens,
                    m.tmax,
                    m.pool_budget_bytes,
                );
            }
        }
        "sessions" => {
            if let Some(sid) = args.get("delete") {
                let deleted = client.delete_session(args.get("model"), sid)?;
                println!("deleted {deleted} session(s) named {sid:?}");
                return Ok(());
            }
            let resp = client.sessions(args.get("model"))?;
            for m in &resp.models {
                println!("{}: {} session(s)", m.model, m.sessions.len());
                for ss in &m.sessions {
                    println!(
                        "  {} turns={} rows={} bytes={}",
                        ss.id, ss.turns, ss.rows, ss.bytes
                    );
                }
            }
        }
        "drain" => {
            let resp = client.drain()?;
            println!(
                "draining: {} ({} request(s) still in flight)",
                resp.draining, resp.in_flight
            );
        }
        "undrain" => {
            let resp = client.undrain()?;
            println!(
                "draining: {} ({} request(s) still in flight)",
                resp.draining, resp.in_flight
            );
        }
        "checkpoint" => {
            let resp = client.checkpoint()?;
            if resp.models.is_empty() {
                println!("no disk stores (server runs without --store-dir)");
            }
            for m in &resp.models {
                match &m.result {
                    Ok(cp) => println!(
                        "{}: checkpointed {} session(s), {} prefix(es), {} block(s) \
                         across {} page(s) in {}us",
                        m.model, cp.sessions, cp.prefixes, cp.blocks, cp.pages, cp.elapsed_us
                    ),
                    Err(e) => println!("{}: checkpoint failed: {e}", m.model),
                }
            }
        }
        "trace" => {
            let resp = client.trace()?;
            for m in &resp.models {
                println!(
                    "{}: {} recent span(s), {} dropped event(s)",
                    m.model,
                    m.spans.len(),
                    m.dropped_events
                );
                for sp in &m.spans {
                    let t0 = sp.events.first().map(|e| e.t_us).unwrap_or(0);
                    let steps: Vec<String> = sp
                        .events
                        .iter()
                        .map(|e| format!("{}@{}us", e.kind.name(), e.t_us.saturating_sub(t0)))
                        .collect();
                    println!("  span {}: {}", sp.id, steps.join(" "));
                }
                for h in &m.histograms {
                    println!(
                        "  {}: n={} p50={}us p90={}us p99={}us",
                        h.metric.name(),
                        h.count,
                        h.p50_us,
                        h.p90_us,
                        h.p99_us
                    );
                }
            }
        }
        other => bail!(
            "unknown ops action {other:?} (stats|info|sessions|drain|undrain|checkpoint|trace)"
        ),
    }
    Ok(())
}

fn tables(args: &Args) -> Result<()> {
    let mut opts = harness::EvalOptions::default();
    opts.n_items = args.usize_or("items", opts.n_items)?;
    opts.seed = args.u64_or("seed", opts.seed)?;
    opts.n_digits = args.usize_or("digits", opts.n_digits)?;
    opts.max_new = args.usize_or("max-new", opts.max_new)?;
    let lag = args.usize_or("lag", 128)?;
    let mut outputs: Vec<String> = Vec::new();

    let need_engines = args.has("table1") || args.has("fig2");
    let engines: Vec<Arc<Engine>> = if need_engines {
        vec![load_engine(args, "llama_like")?, load_engine(args, "qwen_like")?]
    } else {
        vec![]
    };

    if args.has("table1") {
        outputs.push(harness::table1(&engines, &opts)?.render());
    }
    if args.has("fig2") {
        outputs.push(harness::fig2(&engines, &opts)?.render());
    }
    if args.has("fig3") {
        let e = load_engine(args, "llama_like")?;
        for r in [0.5, 0.25] {
            outputs.push(harness::fig34(&e, lag, r, &opts)?.render());
        }
    }
    if args.has("fig4") {
        let e = load_engine(args, "qwen_like")?;
        for r in [0.5, 0.25] {
            outputs.push(harness::fig34(&e, lag, r, &opts)?.render());
        }
    }
    if args.has("fig5") {
        let e = load_engine(args, args.get_or("model", "llama_like"))?;
        outputs.push(harness::fig5(&e, lag, &opts)?.render());
    }
    if args.has("h2o") {
        let e = load_engine(args, args.get_or("model", "llama_like"))?;
        outputs.push(harness::h2o_table(&e, lag, &opts)?.render());
    }
    if args.has("ratio") {
        outputs.push(harness::ratio_table().render());
    }
    if args.has("sim") {
        outputs.push(harness::sim_fig5(args.u64_or("sim-seeds", 8)?).render());
    }
    if outputs.is_empty() {
        bail!("pick at least one of --table1 --fig2 --fig3 --fig4 --fig5 --h2o --ratio --sim");
    }
    let text = outputs.join("\n");
    println!("{text}");
    if let Some(path) = args.get("out") {
        std::fs::write(path, &text)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

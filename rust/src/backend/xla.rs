//! PJRT execution backend (`--features xla`): drives the AOT-compiled
//! prefill/decode HLO executables produced by `make artifacts`.
//!
//! All `xla::` types live behind this module (and [`super::xla_scorer`]);
//! the engine and everything above it see only [`ExecBackend`].
//!
//! Implementation notes carried over from the original engine:
//! * Arguments travel as host literals — the device-resident buffer path
//!   (`execute_b`) segfaults nondeterministically inside the prebuilt
//!   `xla_extension` (see EXPERIMENTS.md §Perf).
//! * Executables are Arc-cached inside the runtime; the XLA scorer holds
//!   its own handles and does not borrow the backend.

use std::path::Path;

use anyhow::{bail, Result};

use crate::compress::Scorer;
use crate::config::{CompressionConfig, ModelDims, ScorerBackend};
use crate::runtime::{lit_f32, lit_i32, lit_i32_scalar, to_vec_f32, Runtime};

use super::{DecodeBatch, DecodeOutput, ExecBackend, PrefillOutput};

pub struct XlaBackend {
    pub rt: Runtime,
    dims: ModelDims,
    weights: Vec<xla::Literal>,
    prefill_buckets: Vec<usize>,
    decode_buckets: Vec<usize>,
    score_lags: Vec<usize>,
    tmax: usize,
}

impl XlaBackend {
    /// `art_dir` = artifacts/, `variant` = "llama_like" | "qwen_like".
    pub fn load(art_dir: &Path, variant: &str) -> Result<XlaBackend> {
        let rt = Runtime::open(art_dir)?;
        let dims = ModelDims::from_json(rt.manifest.get("model_config")?)?;
        let model_dir = art_dir.join("models").join(variant);
        let weights = rt.load_weights(&model_dir)?;
        let prefill_buckets = rt.manifest.get("prefill_buckets")?.as_usize_vec()?;
        let decode_buckets = rt.manifest.get("decode_buckets")?.as_usize_vec()?;
        let score_lags = rt.manifest.get("score_lags")?.as_usize_vec()?;
        let tmax = rt.manifest.get("tmax")?.as_usize()?;
        Ok(XlaBackend {
            rt,
            dims,
            weights,
            prefill_buckets,
            decode_buckets,
            score_lags,
            tmax,
        })
    }

    fn score_exe_handles(&self) -> super::xla_scorer::ScoreExes {
        let mut map = std::collections::HashMap::new();
        for &l in &self.score_lags {
            if let Ok(exe) = self.rt.executable(&format!("lagkv_score_l{l}")) {
                map.insert(l, exe);
            }
        }
        super::xla_scorer::ScoreExes { by_lag: map }
    }
}

impl ExecBackend for XlaBackend {
    fn kind(&self) -> &'static str {
        "xla"
    }

    fn platform(&self) -> String {
        self.rt.platform()
    }

    fn entries(&self) -> Vec<String> {
        self.rt.entries()
    }

    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn tmax(&self) -> usize {
        self.tmax
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.prefill_buckets
    }

    fn decode_buckets(&self) -> &[usize] {
        &self.decode_buckets
    }

    fn prefill(&self, tokens: &[i32], true_len: usize) -> Result<PrefillOutput> {
        let bucket = tokens.len();
        let mut args = self.weights.clone();
        args.push(lit_i32(tokens, &[bucket])?);
        args.push(lit_i32_scalar(true_len as i32));
        let out = self.rt.execute(&format!("prefill_t{bucket}"), &args)?;
        if out.len() != 4 {
            bail!("prefill returned {} outputs, expected 4", out.len());
        }
        Ok(PrefillOutput {
            logits: to_vec_f32(&out[0])?,
            k: to_vec_f32(&out[1])?,
            v: to_vec_f32(&out[2])?,
            attn_sums: to_vec_f32(&out[3])?,
        })
    }

    fn decode(&self, batch: &DecodeBatch<'_>) -> Result<DecodeOutput> {
        let b = batch.batch;
        let (nl, hkv, dh) = (self.dims.n_layers, self.dims.n_kv_heads, self.dims.d_head);
        let tmax = self.tmax;
        let args: Vec<xla::Literal> = self
            .weights
            .iter()
            .cloned()
            .chain([
                lit_f32(batch.k, &[nl, b, hkv, tmax, dh])?,
                lit_f32(batch.v, &[nl, b, hkv, tmax, dh])?,
                lit_i32(batch.lens, &[nl, b])?,
                lit_i32(batch.pos, &[b])?,
                lit_i32(batch.tokens, &[b])?,
            ])
            .collect();
        let out = self.rt.execute(&format!("decode_b{b}"), &args)?;
        if out.len() != 6 {
            bail!("decode returned {} outputs, expected 6", out.len());
        }
        Ok(DecodeOutput {
            logits: to_vec_f32(&out[0])?,
            k_new: to_vec_f32(&out[1])?,
            v_new: to_vec_f32(&out[2])?,
            attn_rows: to_vec_f32(&out[5])?,
        })
    }

    /// The lowered decode HLO runs real attention over the packed K/V
    /// buffers, so a slot's logits depend on every cached row being
    /// up to date — sequential in-call packing would read stale state.
    /// Explicitly not KV-oblivious (suffix/resume prefill falls back to
    /// the incremental b=1 path on this backend).
    fn decode_is_kv_oblivious(&self) -> bool {
        false
    }

    fn scorer(&self, cfg: &CompressionConfig, seed: u64) -> Option<Box<dyn Scorer>> {
        if cfg.scorer != ScorerBackend::Xla {
            return None;
        }
        Some(Box::new(super::xla_scorer::XlaScorer::new(
            self.score_exe_handles(),
            cfg.policy,
            seed,
            self.dims.n_kv_heads,
        )))
    }
}

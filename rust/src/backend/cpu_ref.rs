//! Pure-Rust reference backend: a synthetic model that exercises the whole
//! serving stack with zero artifacts and zero native libraries.
//!
//! KV rows are a *pure function* of `(token id, absolute position)` with the
//! two statistical properties the paper's mechanism rests on (§1):
//!
//! * **channel-wise structure** — fixed per-channel means plus a slow
//!   positional drift, so a lag-reference chunk's min/max band is a stable
//!   normalizer for its neighbor chunk;
//! * **locality breakers** — digit tokens (passkey material) get large
//!   random excursions, the incoherence signal LagKV scores highly.
//!
//! Purity matters: prefill and decode produce byte-identical rows for the
//! same `(token, position)`, so streamed and batched execution agree and
//! the "batched decode == solo decode" and "prefill+compress == stream+
//! compress" invariants hold exactly, like the real AOT model.
//!
//! The language-model head is a deterministic toy: the next token is a
//! hash of `(token, position)` over the word table, with a rare EOS.  It
//! is *not* meant to solve retrieval tasks — task-quality orderings are
//! measured model-free in [`crate::sim`] — it exists so generation,
//! continuous batching, compression cadence, and the server all run
//! end-to-end under `cargo test` on a clean machine.

use anyhow::{bail, Result};

use crate::config::ModelDims;
use crate::tokenizer::{Tokenizer, Vocab, EOS};
use crate::util::rng::Rng;

use super::{digits_per_token, DecodeBatch, DecodeOutput, ExecBackend, PrefillOutput};

/// splitmix64-style mixer: decorrelates `(token, position)` seeds.
fn mix2(a: u64, b: u64) -> u64 {
    let mut z = a
        .wrapping_mul(0x9e3779b97f4a7c15)
        .wrapping_add(b)
        .wrapping_add(0x632be59bd9b4e019);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

pub struct CpuRefBackend {
    dims: ModelDims,
    tmax: usize,
    prefill_buckets: Vec<usize>,
    decode_buckets: Vec<usize>,
    /// Fixed per-channel means, `[n_layers * n_kv_heads * d_head]`.
    k_mean: Vec<f32>,
    v_mean: Vec<f32>,
    /// Token-id range of digit tokens (the salient/locality-breaking ids).
    digit_lo: i32,
    digit_hi: i32,
    word_base: usize,
    n_words: usize,
}

impl CpuRefBackend {
    /// Build the backend plus the matching tokenizer for a model variant
    /// ("llama_like" packs 3 digits per token, "qwen_like" packs 1).
    pub fn load(variant: &str) -> Result<(CpuRefBackend, Tokenizer)> {
        let tokenizer = Tokenizer::new(Vocab::synthetic(), digits_per_token(variant)?)?;
        let backend = CpuRefBackend::new(&tokenizer.vocab);
        Ok((backend, tokenizer))
    }

    pub fn new(vocab: &Vocab) -> CpuRefBackend {
        CpuRefBackend::with_capacity(vocab, 640)
    }

    /// Same synthetic model with a caller-chosen cache capacity.  KV rows
    /// are a pure function of `(token, pos)` — independent of `max_seq` —
    /// so two backends of different capacity emit byte-identical rows;
    /// only the padded-buffer shapes and bucket menus change.  Benches use
    /// this to run prompt lengths past the default 640-row ceiling.
    pub fn with_capacity(vocab: &Vocab, max_seq: usize) -> CpuRefBackend {
        let dims = ModelDims {
            vocab_size: vocab.size(),
            d_model: 32,
            n_layers: 2,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            max_seq,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
        };
        // Doubling prefill buckets up to the capacity; the default 640
        // capacity reproduces the historical menu [128, 256, 512, 640].
        let mut prefill_buckets = Vec::new();
        let mut b = 128usize;
        while b < max_seq {
            prefill_buckets.push(b);
            b *= 2;
        }
        prefill_buckets.push(max_seq);
        let w = dims.n_layers * dims.n_kv_heads * dims.d_head;
        let mut rng = Rng::seed_from(0xC0DE);
        let k_mean: Vec<f32> = (0..w).map(|_| rng.normal() * 1.5).collect();
        let v_mean: Vec<f32> = (0..w).map(|_| rng.normal() * 1.5).collect();
        CpuRefBackend {
            tmax: dims.max_seq,
            prefill_buckets,
            decode_buckets: vec![1, 4],
            k_mean,
            v_mean,
            digit_lo: vocab.digit1_base,
            digit_hi: vocab.word_base,
            word_base: vocab.word_base as usize,
            n_words: vocab.words.len(),
            dims,
        }
    }

    fn row_width(&self) -> usize {
        self.dims.n_layers * self.dims.n_kv_heads * self.dims.d_head
    }

    fn is_salient(&self, token: i32) -> bool {
        token >= self.digit_lo && token < self.digit_hi
    }

    /// One token's K/V rows for every (layer, head): `[n_layers,
    /// n_kv_heads, d_head]` row-major, a pure function of `(token, pos)`.
    fn kv_row(&self, token: i32, pos: i32) -> (Vec<f32>, Vec<f32>) {
        let w = self.row_width();
        let boost = if self.is_salient(token) { 3.0 } else { 0.0 };
        let drift = ((pos as f32) * 0.05).sin() * 0.4;
        let mut rng = Rng::seed_from(mix2(token as u32 as u64, pos as u32 as u64));
        let mut k = Vec::with_capacity(w);
        let mut v = Vec::with_capacity(w);
        for c in 0..w {
            let nk = rng.normal();
            let nv = rng.normal();
            let sk = rng.normal();
            let sv = rng.normal();
            k.push(self.k_mean[c] + drift + 0.35 * nk + boost * sk);
            v.push(self.v_mean[c] - 0.5 * drift + 0.35 * nv + boost * sv);
        }
        (k, v)
    }

    /// Deterministic toy LM head: `[vocab]` logits with a unique argmax.
    fn next_logits(&self, token: i32, pos: i32) -> Vec<f32> {
        let vocab = self.dims.vocab_size;
        let mut logits = vec![-4.0f32; vocab];
        let h = mix2(token as u32 as u64, (pos as u32 as u64) ^ 0xABCD_1234);
        let next = if h % 37 == 0 {
            EOS as usize
        } else {
            self.word_base + (h >> 8) as usize % self.n_words
        };
        logits[next] = 6.0;
        // mild secondary structure so the distribution is not one-hot
        logits[(h >> 32) as usize % vocab] += 0.5;
        logits
    }

    /// Synthetic attention column masses over `len` valid rows: sink +
    /// recency dominate; digit rows (when known) are down-weighted, the
    /// §3.3 "pre-query attention cannot foresee the passkey" premise.
    fn attn_masses(&self, len: usize, salient: impl Fn(usize) -> bool) -> Vec<f32> {
        let mut row = vec![0.0f32; len];
        let mut total = 0.0f32;
        for (r, slot) in row.iter_mut().enumerate() {
            let sink = if r < 4 { 3.0 } else { 0.0 };
            let recency = (-((len - 1 - r) as f32) / 24.0).exp();
            let mut m = sink + recency + 0.02;
            if salient(r) {
                m *= 0.4;
            }
            *slot = m;
            total += m;
        }
        if total > 0.0 {
            for slot in row.iter_mut() {
                *slot /= total;
            }
        }
        row
    }
}

impl ExecBackend for CpuRefBackend {
    fn kind(&self) -> &'static str {
        "cpu-ref"
    }

    fn platform(&self) -> String {
        "cpu-ref (synthetic, hermetic)".to_string()
    }

    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn tmax(&self) -> usize {
        self.tmax
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.prefill_buckets
    }

    fn decode_buckets(&self) -> &[usize] {
        &self.decode_buckets
    }

    fn prefill(&self, tokens: &[i32], true_len: usize) -> Result<PrefillOutput> {
        let bucket = tokens.len();
        if true_len == 0 || true_len > bucket {
            bail!("prefill: true_len {true_len} outside bucket {bucket}");
        }
        let (nl, hkv, dh) = (self.dims.n_layers, self.dims.n_kv_heads, self.dims.d_head);
        let mut k = vec![0.0f32; nl * hkv * bucket * dh];
        let mut v = vec![0.0f32; nl * hkv * bucket * dh];
        for (t, &tok) in tokens.iter().enumerate().take(true_len) {
            let (kr, vr) = self.kv_row(tok, t as i32);
            for lh in 0..nl * hkv {
                let src = lh * dh;
                let dst = (lh * bucket + t) * dh;
                k[dst..dst + dh].copy_from_slice(&kr[src..src + dh]);
                v[dst..dst + dh].copy_from_slice(&vr[src..src + dh]);
            }
        }
        let masses = self.attn_masses(true_len, |r| self.is_salient(tokens[r]));
        let mut attn_sums = vec![0.0f32; nl * hkv * bucket];
        for lh in 0..nl * hkv {
            attn_sums[lh * bucket..lh * bucket + true_len].copy_from_slice(&masses);
        }
        let logits = self.next_logits(tokens[true_len - 1], (true_len - 1) as i32);
        Ok(PrefillOutput { logits, k, v, attn_sums })
    }

    fn decode(&self, batch: &DecodeBatch<'_>) -> Result<DecodeOutput> {
        let (nl, hkv, dh) = (self.dims.n_layers, self.dims.n_kv_heads, self.dims.d_head);
        let (b, tmax) = (batch.batch, self.tmax);
        if batch.k.len() != nl * b * hkv * tmax * dh
            || batch.lens.len() != nl * b
            || batch.tokens.len() != b
            || batch.pos.len() != b
        {
            bail!("decode: malformed batch shapes (b={b})");
        }
        let vocab = self.dims.vocab_size;
        let mut logits = vec![0.0f32; b * vocab];
        let mut k_new = vec![0.0f32; nl * b * hkv * dh];
        let mut v_new = vec![0.0f32; nl * b * hkv * dh];
        let mut attn_rows = vec![0.0f32; nl * b * hkv * tmax];
        for s in 0..b {
            let (kr, vr) = self.kv_row(batch.tokens[s], batch.pos[s]);
            for layer in 0..nl {
                for h in 0..hkv {
                    let src = (layer * hkv + h) * dh;
                    let dst = (((layer * b) + s) * hkv + h) * dh;
                    k_new[dst..dst + dh].copy_from_slice(&kr[src..src + dh]);
                    v_new[dst..dst + dh].copy_from_slice(&vr[src..src + dh]);
                }
                let len = (batch.lens[layer * b + s].max(0) as usize).min(tmax);
                if len > 0 {
                    // Cached-row token identity is gone after compaction;
                    // the surrogate down-weights nothing here.
                    let masses = self.attn_masses(len, |_| false);
                    for h in 0..hkv {
                        let dst = (((layer * b) + s) * hkv + h) * tmax;
                        attn_rows[dst..dst + len].copy_from_slice(&masses);
                    }
                }
            }
            logits[s * vocab..(s + 1) * vocab]
                .copy_from_slice(&self.next_logits(batch.tokens[s], batch.pos[s]));
        }
        Ok(DecodeOutput { logits, k_new, v_new, attn_rows })
    }

    /// `decode` above derives `k_new`/`v_new`/`logits` from `(token, pos)`
    /// alone and never dereferences row *contents* of `batch.k`/`batch.v`
    /// (only `lens` feeds the attention surrogate), so sequential tokens
    /// of one sequence may be packed across slots of a single call.
    fn decode_is_kv_oblivious(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::argmax;

    fn backend() -> CpuRefBackend {
        CpuRefBackend::load("llama_like").unwrap().0
    }

    #[test]
    fn kv_rows_are_pure_functions() {
        let b = backend();
        let (k1, v1) = b.kv_row(42, 7);
        let (k2, v2) = b.kv_row(42, 7);
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
        let (k3, _) = b.kv_row(42, 8);
        assert_ne!(k1, k3, "different positions must differ");
    }

    #[test]
    fn digit_tokens_are_locality_breakers() {
        let b = backend();
        let spread = |xs: &[f32]| -> f32 {
            let m = xs.iter().sum::<f32>() / xs.len() as f32;
            xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32
        };
        // average over several tokens: digit rows carry far more energy
        let mut digit = 0.0;
        let mut word = 0.0;
        for i in 0..8 {
            let (kd, _) = b.kv_row(b.digit_lo + i, 100 + i);
            let (kw, _) = b.kv_row(b.word_base as i32 + i, 100 + i);
            digit += spread(&kd);
            word += spread(&kw);
        }
        assert!(digit > 4.0 * word, "digit spread {digit} vs word {word}");
    }

    #[test]
    fn prefill_and_decode_rows_agree() {
        // The purity contract: the row a token gets at prefill equals the
        // row it would get decoded at the same absolute position.
        let b = backend();
        let dims = b.dims().clone();
        let (nl, hkv, dh) = (dims.n_layers, dims.n_kv_heads, dims.d_head);
        let tokens = vec![1, 9, 12, 1200, 7];
        let mut padded = tokens.clone();
        padded.resize(128, 0);
        let pre = b.prefill(&padded, tokens.len()).unwrap();

        let tmax = b.tmax();
        let k = vec![0.0f32; nl * hkv * tmax * dh];
        let lens = vec![0i32; nl];
        let batch = DecodeBatch {
            batch: 1,
            k: &k,
            v: &k,
            lens: &lens,
            pos: &[3],
            tokens: &[tokens[3]],
        };
        let dec = b.decode(&batch).unwrap();
        for layer in 0..nl {
            for h in 0..hkv {
                let lh = layer * hkv + h;
                let pre_row = &pre.k[(lh * 128 + 3) * dh..(lh * 128 + 4) * dh];
                let dec_row = &dec.k_new[lh * dh..(lh + 1) * dh];
                assert_eq!(pre_row, dec_row, "layer {layer} head {h}");
            }
        }
    }

    #[test]
    fn with_capacity_extends_buckets_and_preserves_rows() {
        let vocab = Vocab::synthetic();
        let small = CpuRefBackend::new(&vocab);
        let big = CpuRefBackend::with_capacity(&vocab, 2560);
        assert_eq!(small.prefill_buckets(), &[128, 256, 512, 640]);
        assert_eq!(big.prefill_buckets(), &[128, 256, 512, 1024, 2048, 2560]);
        assert_eq!(big.tmax(), 2560);
        assert!(big.decode_is_kv_oblivious());
        // capacity never changes row content: purity is over (token, pos)
        let (k1, v1) = small.kv_row(42, 600);
        let (k2, v2) = big.kv_row(42, 600);
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn logits_have_unique_argmax_in_vocab() {
        let b = backend();
        for (tok, pos) in [(1, 0), (2000, 55), (9, 600)] {
            let l = b.next_logits(tok, pos);
            assert_eq!(l.len(), b.dims().vocab_size);
            let best = argmax(&l);
            let second = l
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != best)
                .map(|(_, &x)| x)
                .fold(f32::NEG_INFINITY, f32::max);
            assert!(l[best] > second, "argmax must be strict");
        }
    }

    #[test]
    fn attention_surrogate_is_normalized_distribution() {
        let b = backend();
        let m = b.attn_masses(40, |r| r >= 10 && r < 18);
        assert_eq!(m.len(), 40);
        let sum: f32 = m.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "sum {sum}");
        // sink rows outweigh mid rows; down-weighted rows lose mass
        assert!(m[0] > m[20]);
        assert!(m[12] < m[20] || m[20] == m[12], "salient rows are damped");
    }
}

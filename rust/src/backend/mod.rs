//! Execution-backend seam between the serving layers and the model.
//!
//! Everything above this line — [`crate::engine`], [`crate::coordinator`],
//! [`crate::server`], the CLI — speaks only [`ExecBackend`]: *"run prefill
//! over these padded tokens"*, *"run one decode step over this batch"*.
//! What executes underneath is a backend choice:
//!
//! * [`cpu_ref::CpuRefBackend`] (default, hermetic) — a pure-Rust synthetic
//!   model that emits KV streams with the paper's two statistical
//!   properties (token-wise locality, channel-wise structure; same recipe
//!   as [`crate::sim`]) and a deterministic toy language model head.  It
//!   exercises generation, continuous batching, and the recursive
//!   compression driver end-to-end with zero artifacts and zero native
//!   libraries — this is what makes `cargo test` a first-class gate.
//! * [`xla::XlaBackend`] (`--features xla`) — the PJRT path: AOT-lowered
//!   HLO executables produced by `make artifacts`, plus the L1 Pallas
//!   scoring kernel behind [`crate::compress::Scorer`].
//!
//! LagKV itself never needs attention weights, so the entire compression
//! stack (scores → topk → policy → driver → kvcache) is backend-agnostic;
//! the seam is exactly the paper's "easy integration to the mainstream
//! inference platform" claim expressed as a trait.

pub mod cpu_ref;
#[cfg(feature = "xla")]
pub mod xla;
#[cfg(feature = "xla")]
pub mod xla_scorer;

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::compress::Scorer;
use crate::config::{artifacts_dir, CompressionConfig, ModelDims};
use crate::engine::Engine;
use crate::util::cli::Args;

/// Output of one prefill execution over a padded token bucket.
#[derive(Debug, Clone)]
pub struct PrefillOutput {
    /// Last real token's next-token logits, `[vocab]`.
    pub logits: Vec<f32>,
    /// Keys, `[n_layers, n_kv_heads, bucket, d_head]` row-major.
    pub k: Vec<f32>,
    /// Values, same layout as `k`.
    pub v: Vec<f32>,
    /// Accumulated attention column sums, `[n_layers, n_kv_heads, bucket]`
    /// (the H2O statistic; zeros are fine for attention-free backends).
    pub attn_sums: Vec<f32>,
}

/// Input of one batched decode step.  All slices use the fixed-shape
/// layouts the engine assembles from the per-sequence caches.
pub struct DecodeBatch<'a> {
    pub batch: usize,
    /// Padded keys, `[n_layers, batch, n_kv_heads, tmax, d_head]`.
    pub k: &'a [f32],
    /// Padded values, same layout as `k`.
    pub v: &'a [f32],
    /// Valid row counts, `[n_layers, batch]`.
    pub lens: &'a [i32],
    /// Absolute position of the token being decoded, `[batch]`.
    pub pos: &'a [i32],
    /// Token ids being decoded, `[batch]`.
    pub tokens: &'a [i32],
}

/// Output of one batched decode step.
#[derive(Debug, Clone)]
pub struct DecodeOutput {
    /// Next-token logits, `[batch, vocab]`.
    pub logits: Vec<f32>,
    /// New key rows, `[n_layers, batch, n_kv_heads, d_head]`.
    pub k_new: Vec<f32>,
    /// New value rows, same layout as `k_new`.
    pub v_new: Vec<f32>,
    /// This step's attention rows, `[n_layers, batch, n_kv_heads, tmax]`,
    /// aligned with current cache row order (H2O accumulation).
    pub attn_rows: Vec<f32>,
}

/// A model execution backend: prefill/decode/score, nothing else.
///
/// NOT necessarily `Send` (the PJRT client is thread-pinned); backends are
/// constructed on the thread that drives them, exactly like the engines
/// they power.
pub trait ExecBackend {
    /// Short machine name ("cpu-ref", "xla").
    fn kind(&self) -> &'static str;

    /// Human-readable platform string (e.g. PJRT platform name).
    fn platform(&self) -> String {
        self.kind().to_string()
    }

    /// Loadable executable entry names (artifact inventory; may be empty).
    fn entries(&self) -> Vec<String> {
        Vec::new()
    }

    fn dims(&self) -> &ModelDims;

    /// Maximum cache rows per (layer, head) the decode path supports.
    fn tmax(&self) -> usize;

    /// Ascending prefill token buckets.
    fn prefill_buckets(&self) -> &[usize];

    /// Ascending decode batch buckets.
    fn decode_buckets(&self) -> &[usize];

    /// Run prefill.  `tokens` is padded to a bucket length; only the first
    /// `true_len` entries are real.
    fn prefill(&self, tokens: &[i32], true_len: usize) -> Result<PrefillOutput>;

    /// Run one decode step over a fixed-shape batch.
    fn decode(&self, batch: &DecodeBatch<'_>) -> Result<DecodeOutput>;

    /// True when [`ExecBackend::decode`]'s per-slot outputs (`k_new`,
    /// `v_new`, `logits`) are pure functions of `(token, pos)` that never
    /// read the packed `k`/`v` buffers or other slots.  Such a backend can
    /// serve *sequential* tokens of one sequence packed across the slots of
    /// a single wide decode call ([`Engine::prefill_onto_batched`]): slot
    /// `s+1` does not need slot `s`'s KV row to be visible in the buffers.
    ///
    /// A real-attention backend must return `false` (the default): its
    /// logits at position `p` attend over every cached row `< p`, so
    /// in-call packing would read stale state.
    ///
    /// [`Engine::prefill_onto_batched`]: crate::engine::Engine::prefill_onto_batched
    fn decode_is_kv_oblivious(&self) -> bool {
        false
    }

    /// Backend-accelerated scorer for this compression config, if the
    /// backend provides one (`None` -> the engine falls back to the
    /// pure-Rust policy scorer).
    fn scorer(&self, cfg: &CompressionConfig, seed: u64) -> Option<Box<dyn Scorer>> {
        let _ = (cfg, seed);
        None
    }
}

/// Which backend family to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Hermetic pure-Rust synthetic backend (default).
    CpuRef,
    /// PJRT/HLO artifact backend (`--features xla` + `make artifacts`).
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "cpu" | "cpu-ref" | "cpuref" | "ref" => BackendKind::CpuRef,
            "xla" | "pjrt" => BackendKind::Xla,
            other => bail!("unknown backend {other:?} (cpu|xla)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::CpuRef => "cpu",
            BackendKind::Xla => "xla",
        }
    }
}

/// Digit-run segmentation width for a model variant (the paper's Fig. 2
/// llama-vs-qwen tokenizer mechanism).
pub fn digits_per_token(variant: &str) -> Result<usize> {
    match variant {
        "llama_like" => Ok(3),
        "qwen_like" => Ok(1),
        other => bail!("unknown model variant {other:?}"),
    }
}

/// Everything needed to construct an [`Engine`] on any thread: plain data,
/// `Clone + Send`.  The coordinator router moves one of these into each
/// per-model thread and builds the engine there (PJRT handles are not
/// `Send`, so engines never cross threads).
#[derive(Debug, Clone)]
pub struct EngineSpec {
    pub backend: BackendKind,
    pub art_dir: PathBuf,
}

impl EngineSpec {
    /// Hermetic default: CPU reference backend, conventional artifact dir.
    pub fn cpu() -> EngineSpec {
        EngineSpec { backend: BackendKind::CpuRef, art_dir: PathBuf::from("artifacts") }
    }

    /// From CLI flags: `--backend cpu|xla` (default cpu), `--artifacts DIR`.
    pub fn from_args(args: &Args) -> Result<EngineSpec> {
        let backend = match args.get("backend") {
            Some(s) => BackendKind::parse(s)?,
            None => BackendKind::CpuRef,
        };
        Ok(EngineSpec { backend, art_dir: artifacts_dir(args) })
    }

    /// From the environment (bench targets, which take no CLI flags):
    /// `LAGKV_BACKEND=cpu|xla` (default cpu), `LAGKV_ARTIFACTS=DIR`.
    pub fn from_env() -> Result<EngineSpec> {
        let backend = match std::env::var("LAGKV_BACKEND") {
            Ok(v) => BackendKind::parse(&v)?,
            Err(_) => BackendKind::CpuRef,
        };
        let art_dir = std::env::var("LAGKV_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"));
        Ok(EngineSpec { backend, art_dir })
    }

    /// Construct the engine for one model variant.
    pub fn build(&self, variant: &str) -> Result<Engine> {
        match self.backend {
            BackendKind::CpuRef => Engine::cpu_ref(variant),
            BackendKind::Xla => Engine::load(&self.art_dir, variant),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("cpu").unwrap(), BackendKind::CpuRef);
        assert_eq!(BackendKind::parse("XLA").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("tpu").is_err());
    }

    #[test]
    fn digits_per_token_by_variant() {
        assert_eq!(digits_per_token("llama_like").unwrap(), 3);
        assert_eq!(digits_per_token("qwen_like").unwrap(), 1);
        assert!(digits_per_token("gpt_like").is_err());
    }

    #[test]
    fn spec_builds_cpu_engines() {
        let spec = EngineSpec::cpu();
        let e = spec.build("llama_like").unwrap();
        assert_eq!(e.backend().kind(), "cpu-ref");
        assert_eq!(e.tokenizer.digits_per_token, 3);
        assert!(spec.build("nope").is_err());
    }
}

//! XLA-backed scorer: runs the AOT-compiled L1 Pallas kernel
//! (`lagkv_score_l{L}.hlo.txt`) through PJRT instead of the pure-Rust
//! mirror.
//!
//! This exists for two reasons:
//! 1. it proves the L1 kernel is a first-class runtime citizen (the paper's
//!    "easy integration" claim exercised end-to-end), and
//! 2. the integration tests cross-validate Rust scores against the Pallas
//!    kernel's scores on identical inputs, pinning all three
//!    implementations (jnp ref / Pallas / Rust) together.
//!
//! The exported kernel scores `[H, L, D]` (all KV heads at once) while the
//! driver calls per head; the head's tile is replicated across the H rows
//! (the kernel is per-head independent, so row 0 is exactly this head's
//! score).  The small redundancy is irrelevant at H=2 and keeps one
//! artifact shape per lag.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::compress::policy::{PartitionInput, RandomScorer, Scorer};
use crate::compress::scores as rust_scores;
use crate::config::PolicyKind;
use crate::runtime::{lit_f32, to_vec_f32};

/// Compiled score executables keyed by lag size, plus the exported head
/// count.
pub struct ScoreExes {
    pub by_lag: HashMap<usize, Arc<xla::PjRtLoadedExecutable>>,
}

pub struct XlaScorer {
    exes: ScoreExes,
    policy: PolicyKind,
    seed: u64,
    /// Head count of the exported kernels (model n_kv_heads).
    n_heads: usize,
}

impl XlaScorer {
    pub fn new(exes: ScoreExes, policy: PolicyKind, seed: u64, n_heads: usize) -> Self {
        XlaScorer { exes, policy, seed, n_heads }
    }

    fn exec_tiled(&self, inp: &PartitionInput<'_>) -> Result<Vec<f32>> {
        let exe = self
            .exes
            .by_lag
            .get(&inp.l)
            .ok_or_else(|| anyhow!("no lagkv_score executable for L={}", inp.l))?;
        let h = self.n_heads;
        let tile = |x: &[f32]| -> Vec<f32> {
            let mut out = Vec::with_capacity(h * x.len());
            for _ in 0..h {
                out.extend_from_slice(x);
            }
            out
        };
        let dims = [h, inp.l, inp.d];
        let args = [
            lit_f32(&tile(inp.k_cur), &dims)?,
            lit_f32(&tile(inp.v_cur), &dims)?,
            lit_f32(&tile(inp.k_ref), &dims)?,
            lit_f32(&tile(inp.v_ref), &dims)?,
        ];
        let out = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("xla scorer: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("xla scorer fetch: {e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("xla scorer tuple: {e:?}"))?;
        let flat = to_vec_f32(&out[0])?; // [H, L]
        Ok(flat[..inp.l].to_vec())
    }
}

impl Scorer for XlaScorer {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn score(&mut self, inp: &PartitionInput<'_>) -> Result<Vec<f32>> {
        match self.policy {
            PolicyKind::LagKv => self.exec_tiled(inp),
            // Only the LagKV kernel is exported; the remaining policies
            // fall back to their Rust scorers even under --scorer=xla.
            PolicyKind::LocalKv => {
                Ok(rust_scores::localkv_score(inp.k_cur, inp.v_cur, inp.l, inp.d))
            }
            PolicyKind::L2Norm => Ok(rust_scores::l2norm_score(inp.k_cur, inp.l, inp.d)),
            PolicyKind::H2O => Ok(inp.attn_acc.to_vec()),
            PolicyKind::Streaming | PolicyKind::None => {
                Ok((0..inp.l).map(|i| i as f32).collect())
            }
            PolicyKind::StreamingLlm => {
                Ok(inp.positions.iter().map(|&p| p as f32).collect())
            }
            PolicyKind::Random => RandomScorer { seed: self.seed }.score(inp),
        }
    }
}

//! Fixed-size KV blocks: the unit the pool hands out and recycles.

use std::fmt;
use std::sync::Arc;

use super::BlockPool;

/// The raw buffers behind one block: `rows × d_head` keys and values plus
/// the per-row position and attention-mass side arrays.  Lives either
/// inside a live [`Block`] or parked in the pool's free list.
#[derive(Default)]
pub struct BlockBufs {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub pos: Vec<i32>,
    pub attn: Vec<f32>,
}

impl BlockBufs {
    pub(super) fn with_capacity(rows: usize, d: usize) -> BlockBufs {
        BlockBufs {
            k: Vec::with_capacity(rows * d),
            v: Vec::with_capacity(rows * d),
            pos: Vec::with_capacity(rows),
            attn: Vec::with_capacity(rows),
        }
    }

    pub(super) fn clear(&mut self) {
        self.k.clear();
        self.v.clear();
        self.pos.clear();
        self.attn.clear();
    }
}

/// Payload bytes of one full block of `rows` rows at head width `d`:
/// K + V (`f32`) plus the position (`i32`) and attention (`f32`) arrays.
pub fn block_bytes(rows: usize, d: usize) -> usize {
    rows * (2 * d * std::mem::size_of::<f32>())
        + rows * (std::mem::size_of::<i32>() + std::mem::size_of::<f32>())
}

/// One immutable, refcounted block of KV rows.
///
/// Blocks are always created *full* (exactly `rows_per_block` rows) and
/// never mutated afterwards — that immutability is what makes sharing a
/// frozen prefix between a live cache and a detached session copy-on-write
/// safe by construction.  Dropping the last reference returns the buffers
/// to the owning pool's free list.
pub struct Block {
    /// `Some` until drop hands the buffers back to the pool.
    bufs: Option<BlockBufs>,
    rows: usize,
    d: usize,
    pool: Arc<BlockPool>,
}

impl Block {
    pub(super) fn new(bufs: BlockBufs, rows: usize, d: usize, pool: Arc<BlockPool>) -> Block {
        debug_assert_eq!(bufs.k.len(), rows * d);
        debug_assert_eq!(bufs.v.len(), rows * d);
        debug_assert_eq!(bufs.pos.len(), rows);
        debug_assert_eq!(bufs.attn.len(), rows);
        Block { bufs: Some(bufs), rows, d, pool }
    }

    fn bufs(&self) -> &BlockBufs {
        self.bufs.as_ref().expect("block buffers live until drop")
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Row-major keys, `rows * d`.
    pub fn k(&self) -> &[f32] {
        &self.bufs().k
    }

    /// Row-major values, `rows * d`.
    pub fn v(&self) -> &[f32] {
        &self.bufs().v
    }

    /// Original absolute position of each row.
    pub fn pos(&self) -> &[i32] {
        &self.bufs().pos
    }

    /// Attention mass per row as it stood at freeze time.  A snapshot
    /// only: the cache keeps the *live* mass for frozen rows in its own
    /// side array (`HeadStore::frozen_attn`), since blocks are immutable
    /// and possibly shared.
    pub fn attn(&self) -> &[f32] {
        &self.bufs().attn
    }

    pub fn payload_bytes(&self) -> usize {
        block_bytes(self.rows, self.d)
    }
}

impl Drop for Block {
    fn drop(&mut self) {
        if let Some(bufs) = self.bufs.take() {
            self.pool.release(self.rows, self.d, bufs);
        }
    }
}

impl fmt::Debug for Block {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Block")
            .field("rows", &self.rows)
            .field("d", &self.d)
            .field("bytes", &self.payload_bytes())
            .finish()
    }
}

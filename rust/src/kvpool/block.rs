//! Fixed-size KV blocks: the unit the pool hands out, recycles — and,
//! when a [`kvstore::KvStore`] is bound, demotes to disk and faults back.
//!
//! Residency state machine (per block, under its own `RwLock`):
//!
//! ```text
//!            try_demote (pool.spill)
//!   Resident ────────────────────────▶ Spilled
//!   bufs: Some                         bufs: None
//!   store_id: 0 or id ◀──────────────  store_id: id
//!            fault-in (Block::read)
//! ```
//!
//! Blocks are immutable from birth, so demotion never loses writes: the
//! payload on disk is bit-identical to the buffers it replaced, and a
//! block that was persisted once is never re-serialized (fault-in leaves
//! `store_id` set; a later demote just drops the buffers again).
//!
//! ## Quantized blocks
//!
//! A block frozen through a lossy codec ([`crate::quant`]) holds its
//! payload *encoded*: `quant` carries the packed int8 data + scale
//! sidecar plus the uncompressed `pos`/`attn` side arrays.  `bufs` then
//! doubles as the **decoded-row cache** — filled lazily on first
//! [`Block::read`] (so `window`/`layer_padded`/`prefill_onto` stay
//! decode-transparent) and dropped under decode-cache pressure or on
//! demote.  The residency machine gains one axis:
//!
//! ```text
//!   encoded-resident:  quant: Some            (bufs: None or Some)
//!   spilled:           quant: None, bufs: None, store_id: id
//! ```
//!
//! Spill serializes the *encoded* payload + sidecar — never a
//! decode-then-respill — so disk pages shrink by the codec's factor and
//! a faulted block is bit-identical to its encoded form.
//!
//! [`kvstore::KvStore`]: crate::kvstore::KvStore

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock, RwLockReadGuard};

use crate::kvstore::KvStore;
use crate::quant::{CodecKind, EncodedKv};

use super::BlockPool;

/// The raw buffers behind one block: `rows × d_head` keys and values plus
/// the per-row position and attention-mass side arrays.  Lives inside a
/// resident [`Block`], parked in the pool's free list, or — for a spilled
/// block — nowhere: the payload is a page-store record.
#[derive(Default)]
pub struct BlockBufs {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub pos: Vec<i32>,
    pub attn: Vec<f32>,
}

impl BlockBufs {
    pub(super) fn with_capacity(rows: usize, d: usize) -> BlockBufs {
        BlockBufs {
            k: Vec::with_capacity(rows * d),
            v: Vec::with_capacity(rows * d),
            pos: Vec::with_capacity(rows),
            attn: Vec::with_capacity(rows),
        }
    }

    pub(super) fn clear(&mut self) {
        self.k.clear();
        self.v.clear();
        self.pos.clear();
        self.attn.clear();
    }
}

/// Payload bytes of one full block of `rows` rows at head width `d`:
/// K + V (`f32`) plus the position (`i32`) and attention (`f32`) arrays.
pub fn block_bytes(rows: usize, d: usize) -> usize {
    rows * (2 * d * std::mem::size_of::<f32>())
        + rows * (std::mem::size_of::<i32>() + std::mem::size_of::<f32>())
}

/// The encoded payload of a quantized block: packed codec output plus
/// the uncompressed per-row side arrays (positions and freeze-time
/// attention mass are never quantized — they are exact metadata).
pub(super) struct QuantPayload {
    pub(super) enc: EncodedKv,
    pub(super) pos: Vec<i32>,
    pub(super) attn: Vec<f32>,
}

struct BlockState {
    /// `Some` while resident; `None` while the payload lives on disk.
    /// For a quantized block this is the *decoded-row cache*: droppable
    /// at any time while `quant` is resident, rebuilt on the next read.
    bufs: Option<BlockBufs>,
    /// `Some` while a quantized block's encoded payload is resident;
    /// always `None` for plain (fp32) blocks.
    quant: Option<QuantPayload>,
    /// Store id once persisted (0 = never persisted).  Sticky: survives
    /// fault-in so a re-demote writes nothing.
    store_id: u64,
}

/// One immutable, refcounted block of KV rows.
///
/// Blocks are always created *full* (exactly `rows_per_block` rows) and
/// never mutated afterwards — that immutability is what makes sharing a
/// frozen prefix between a live cache and a detached session copy-on-write
/// safe by construction, and what makes disk demotion safe: re-reading a
/// spilled payload is guaranteed bit-identical.  Dropping the last
/// reference returns resident buffers to the owning pool's free list and
/// releases the store's live claim on a persisted payload.
pub struct Block {
    state: RwLock<BlockState>,
    rows: usize,
    d: usize,
    /// The codec this block was frozen through.  Immutable, like the
    /// payload: [`CodecKind::Fp32`] means a plain block (`quant` stays
    /// `None` forever).
    codec: CodecKind,
    /// Pool-clock value of the last `read()`: the spill LRU signal.
    tick: AtomicU64,
    pool: Arc<BlockPool>,
}

/// Read guard over a block's payload.  Holding it pins the block
/// resident: demotion uses `try_write` and skips blocks under read.
#[must_use = "dropping a BlockData releases the read pin, making the block demotable again"]
pub struct BlockData<'a> {
    guard: RwLockReadGuard<'a, BlockState>,
}

impl BlockData<'_> {
    fn bufs(&self) -> &BlockBufs {
        self.guard.bufs.as_ref().expect("guard only issued over resident state")
    }

    /// Row-major keys, `rows * d`.
    pub fn k(&self) -> &[f32] {
        &self.bufs().k
    }

    /// Row-major values, `rows * d`.
    pub fn v(&self) -> &[f32] {
        &self.bufs().v
    }

    /// Original absolute position of each row.
    pub fn pos(&self) -> &[i32] {
        &self.bufs().pos
    }

    /// Attention mass per row as it stood at freeze time.  A snapshot
    /// only: the cache keeps the *live* mass for frozen rows in its own
    /// side array (`HeadStore::frozen_attn`), since blocks are immutable
    /// and possibly shared.
    pub fn attn(&self) -> &[f32] {
        &self.bufs().attn
    }
}

impl Block {
    pub(super) fn new(bufs: BlockBufs, rows: usize, d: usize, pool: Arc<BlockPool>) -> Block {
        debug_assert_eq!(bufs.k.len(), rows * d);
        debug_assert_eq!(bufs.v.len(), rows * d);
        debug_assert_eq!(bufs.pos.len(), rows);
        debug_assert_eq!(bufs.attn.len(), rows);
        Block {
            state: RwLock::new(BlockState { bufs: Some(bufs), quant: None, store_id: 0 }),
            rows,
            d,
            codec: CodecKind::Fp32,
            tick: AtomicU64::new(0),
            pool,
        }
    }

    /// A quantized block, born encoded-resident with a cold decode cache.
    pub(super) fn new_quant(
        kind: CodecKind,
        enc: EncodedKv,
        pos: Vec<i32>,
        attn: Vec<f32>,
        rows: usize,
        d: usize,
        pool: Arc<BlockPool>,
    ) -> Block {
        debug_assert!(kind != CodecKind::Fp32, "fp32 freezes take the plain-block path");
        debug_assert_eq!(enc.byte_len(), kind.codec().encoded_kv_bytes(rows, d));
        debug_assert_eq!(pos.len(), rows);
        debug_assert_eq!(attn.len(), rows);
        Block {
            state: RwLock::new(BlockState {
                bufs: None,
                quant: Some(QuantPayload { enc, pos, attn }),
                store_id: 0,
            }),
            rows,
            d,
            codec: kind,
            tick: AtomicU64::new(0),
            pool,
        }
    }

    /// A handle over an already-persisted payload, starting spilled
    /// (restart restore path: the payload stays on disk until read).
    pub(super) fn restored(
        rows: usize,
        d: usize,
        codec: CodecKind,
        store_id: u64,
        pool: Arc<BlockPool>,
    ) -> Block {
        debug_assert!(store_id != 0);
        Block {
            state: RwLock::new(BlockState { bufs: None, quant: None, store_id }),
            rows,
            d,
            codec,
            tick: AtomicU64::new(0),
            pool,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// The codec this block's payload is stored under.
    pub fn codec(&self) -> CodecKind {
        self.codec
    }

    /// Resident bytes of this block's payload in its stored form: plain
    /// [`block_bytes`] for fp32, the exact encoded size for a quantized
    /// block.  The decode cache is accounted separately (pool `dq_bytes`).
    pub fn payload_bytes(&self) -> usize {
        self.codec.encoded_block_bytes(self.rows, self.d)
    }

    pub fn is_resident(&self) -> bool {
        let st = self.state.read().unwrap();
        st.bufs.is_some() || st.quant.is_some()
    }

    /// Does this quantized block currently hold a decoded-row cache?
    /// (Always false for plain blocks: their `bufs` *is* the payload.)
    pub(super) fn has_decoded(&self) -> bool {
        if self.codec == CodecKind::Fp32 {
            return false;
        }
        // lint: allow(panic): lock poisoning is unrecoverable by design across the pool
        self.state.read().unwrap().bufs.is_some()
    }

    /// A clone of the encoded payload, when resident (tests / analysis:
    /// the spill→fault bit-identity property compares these).
    pub fn encoded(&self) -> Option<EncodedKv> {
        // lint: allow(panic): lock poisoning is unrecoverable by design across the pool
        self.state.read().unwrap().quant.as_ref().map(|q| q.enc.clone())
    }

    pub(super) fn last_tick(&self) -> u64 {
        self.tick.load(Ordering::Relaxed)
    }

    /// Access the payload, faulting it in from the store when spilled and
    /// decoding it when quantized.  Infallible by design — decode never
    /// fails mid-request on tiering — so an unreadable store record (torn
    /// file, dead disk) panics.
    pub fn read(&self) -> BlockData<'_> {
        self.tick.store(self.pool.next_tick(), Ordering::Relaxed);
        if self.codec != CodecKind::Fp32 {
            // Keep the decode cache inside its budget before (possibly)
            // growing it; this block was just stamped hottest, so it is
            // the last trim candidate.
            self.pool.maybe_trim_decoded();
        }
        loop {
            {
                let guard = self.state.read().unwrap();
                if guard.bufs.is_some() {
                    return BlockData { guard };
                }
            }
            self.fault_in();
        }
    }

    /// Make `bufs` present: fault the payload in from the store when
    /// spilled, then (for a quantized block) decode it into the cache.
    fn fault_in(&self) {
        let mut guard = self.state.write().unwrap();
        let st = &mut *guard;
        if st.bufs.is_some() {
            return; // raced with another reader's fault-in
        }
        if self.codec == CodecKind::Fp32 {
            st.bufs = Some(self.pool.fault_block(st.store_id, self.rows, self.d));
            return;
        }
        if st.quant.is_none() {
            let (enc, pos, attn) =
                self.pool.fault_quant_block(st.store_id, self.codec, self.rows, self.d);
            st.quant = Some(QuantPayload { enc, pos, attn });
        }
        if let Some(q) = st.quant.as_ref() {
            st.bufs =
                Some(self.pool.decode_block(self.codec, self.rows, self.d, &q.enc, &q.pos, &q.attn));
        }
    }

    /// Persist the payload (if not already on disk) and take one claim
    /// for a descriptor that will reference it.  Quantized blocks persist
    /// their *encoded* form.
    pub fn persist_into(&self, store: &KvStore) -> anyhow::Result<u64> {
        let mut st = self.state.write().unwrap();
        if st.store_id == 0 {
            if self.codec == CodecKind::Fp32 {
                let bufs = st.bufs.as_ref().expect("an unpersisted block is resident");
                st.store_id = store.persist_block(
                    self.rows,
                    self.d,
                    &bufs.k,
                    &bufs.v,
                    &bufs.pos,
                    &bufs.attn,
                )?;
            } else {
                // lint: allow(panic): the state machine keeps an unpersisted quant block encoded-resident
                let q = st.quant.as_ref().expect("an unpersisted quant block is encoded-resident");
                st.store_id = store.persist_quant_block(
                    self.rows,
                    self.d,
                    self.codec,
                    &q.enc,
                    &q.pos,
                    &q.attn,
                )?;
            }
        }
        store.retain_block(st.store_id);
        Ok(st.store_id)
    }

    /// Demote to disk: persist (first time only), drop the buffers, move
    /// the ledger bytes resident → spilled.  Skips — returning `None` —
    /// when the block is already spilled, under an active read guard, or
    /// the store write fails.  Returns the resident bytes freed (for a
    /// quantized block: the encoded payload plus any decode cache).
    pub(super) fn try_demote(&self, store: &KvStore) -> Option<usize> {
        let mut guard = self.state.try_write().ok()?;
        let st = &mut *guard;
        if self.codec == CodecKind::Fp32 {
            st.bufs.as_ref()?;
            if st.store_id == 0 {
                let bufs = st.bufs.as_ref().expect("checked above");
                match store.persist_block(self.rows, self.d, &bufs.k, &bufs.v, &bufs.pos, &bufs.attn)
                {
                    Ok(id) => st.store_id = id,
                    Err(e) => {
                        eprintln!("kvpool: spill write failed, keeping block resident: {e:#}");
                        return None;
                    }
                }
            }
            let bufs = st.bufs.take().expect("checked above");
            // ledger moves under the state lock so a racing fault-in observes
            // state + ledger atomically
            self.pool.on_demoted(self.rows, self.d, bufs);
            return Some(self.payload_bytes());
        }
        let q = st.quant.take()?;
        if st.store_id == 0 {
            match store.persist_quant_block(self.rows, self.d, self.codec, &q.enc, &q.pos, &q.attn)
            {
                Ok(id) => st.store_id = id,
                Err(e) => {
                    eprintln!("kvpool: quant spill write failed, keeping block resident: {e:#}");
                    st.quant = Some(q);
                    return None;
                }
            }
        }
        let decoded = st.bufs.take();
        let freed = self.payload_bytes()
            + decoded.as_ref().map_or(0, |_| block_bytes(self.rows, self.d));
        self.pool.on_demoted_quant(self.rows, self.d, self.codec, decoded);
        Some(freed)
    }

    /// Drop a quantized block's decoded-row cache (decode-cache budget
    /// trim).  The encoded payload stays resident, so the next read just
    /// re-decodes — no disk involved.  Skips blocks under an active read
    /// guard or currently spilled.  Returns the cache bytes freed.
    pub(super) fn try_drop_decoded(&self) -> Option<usize> {
        if self.codec == CodecKind::Fp32 {
            return None;
        }
        let mut st = self.state.try_write().ok()?;
        if st.quant.is_none() {
            return None; // spilled: the cache is already gone
        }
        let bufs = st.bufs.take()?;
        self.pool.on_decoded_dropped(self.rows, self.d, bufs);
        Some(block_bytes(self.rows, self.d))
    }
}

impl Drop for Block {
    fn drop(&mut self) {
        let st = self.state.get_mut().unwrap();
        let store_id = st.store_id;
        if self.codec == CodecKind::Fp32 {
            match st.bufs.take() {
                Some(bufs) => self.pool.release(self.rows, self.d, bufs),
                None => self.pool.release_spilled(self.payload_bytes()),
            }
        } else {
            let decoded = st.bufs.take();
            match st.quant.take() {
                Some(_) => self.pool.release_quant(self.rows, self.d, self.codec, decoded),
                None => self.pool.release_spilled(self.payload_bytes()),
            }
        }
        if store_id != 0 {
            self.pool.release_store_claim(store_id);
        }
    }
}

impl fmt::Debug for Block {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Block")
            .field("rows", &self.rows)
            .field("d", &self.d)
            .field("codec", &self.codec)
            .field("bytes", &self.payload_bytes())
            .field("resident", &self.is_resident())
            .finish()
    }
}

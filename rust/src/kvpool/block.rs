//! Fixed-size KV blocks: the unit the pool hands out, recycles — and,
//! when a [`kvstore::KvStore`] is bound, demotes to disk and faults back.
//!
//! Residency state machine (per block, under its own `RwLock`):
//!
//! ```text
//!            try_demote (pool.spill)
//!   Resident ────────────────────────▶ Spilled
//!   bufs: Some                         bufs: None
//!   store_id: 0 or id ◀──────────────  store_id: id
//!            fault-in (Block::read)
//! ```
//!
//! Blocks are immutable from birth, so demotion never loses writes: the
//! payload on disk is bit-identical to the buffers it replaced, and a
//! block that was persisted once is never re-serialized (fault-in leaves
//! `store_id` set; a later demote just drops the buffers again).
//!
//! [`kvstore::KvStore`]: crate::kvstore::KvStore

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock, RwLockReadGuard};

use crate::kvstore::KvStore;

use super::BlockPool;

/// The raw buffers behind one block: `rows × d_head` keys and values plus
/// the per-row position and attention-mass side arrays.  Lives inside a
/// resident [`Block`], parked in the pool's free list, or — for a spilled
/// block — nowhere: the payload is a page-store record.
#[derive(Default)]
pub struct BlockBufs {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub pos: Vec<i32>,
    pub attn: Vec<f32>,
}

impl BlockBufs {
    pub(super) fn with_capacity(rows: usize, d: usize) -> BlockBufs {
        BlockBufs {
            k: Vec::with_capacity(rows * d),
            v: Vec::with_capacity(rows * d),
            pos: Vec::with_capacity(rows),
            attn: Vec::with_capacity(rows),
        }
    }

    pub(super) fn clear(&mut self) {
        self.k.clear();
        self.v.clear();
        self.pos.clear();
        self.attn.clear();
    }
}

/// Payload bytes of one full block of `rows` rows at head width `d`:
/// K + V (`f32`) plus the position (`i32`) and attention (`f32`) arrays.
pub fn block_bytes(rows: usize, d: usize) -> usize {
    rows * (2 * d * std::mem::size_of::<f32>())
        + rows * (std::mem::size_of::<i32>() + std::mem::size_of::<f32>())
}

struct BlockState {
    /// `Some` while resident; `None` while the payload lives on disk.
    bufs: Option<BlockBufs>,
    /// Store id once persisted (0 = never persisted).  Sticky: survives
    /// fault-in so a re-demote writes nothing.
    store_id: u64,
}

/// One immutable, refcounted block of KV rows.
///
/// Blocks are always created *full* (exactly `rows_per_block` rows) and
/// never mutated afterwards — that immutability is what makes sharing a
/// frozen prefix between a live cache and a detached session copy-on-write
/// safe by construction, and what makes disk demotion safe: re-reading a
/// spilled payload is guaranteed bit-identical.  Dropping the last
/// reference returns resident buffers to the owning pool's free list and
/// releases the store's live claim on a persisted payload.
pub struct Block {
    state: RwLock<BlockState>,
    rows: usize,
    d: usize,
    /// Pool-clock value of the last `read()`: the spill LRU signal.
    tick: AtomicU64,
    pool: Arc<BlockPool>,
}

/// Read guard over a block's payload.  Holding it pins the block
/// resident: demotion uses `try_write` and skips blocks under read.
#[must_use = "dropping a BlockData releases the read pin, making the block demotable again"]
pub struct BlockData<'a> {
    guard: RwLockReadGuard<'a, BlockState>,
}

impl BlockData<'_> {
    fn bufs(&self) -> &BlockBufs {
        self.guard.bufs.as_ref().expect("guard only issued over resident state")
    }

    /// Row-major keys, `rows * d`.
    pub fn k(&self) -> &[f32] {
        &self.bufs().k
    }

    /// Row-major values, `rows * d`.
    pub fn v(&self) -> &[f32] {
        &self.bufs().v
    }

    /// Original absolute position of each row.
    pub fn pos(&self) -> &[i32] {
        &self.bufs().pos
    }

    /// Attention mass per row as it stood at freeze time.  A snapshot
    /// only: the cache keeps the *live* mass for frozen rows in its own
    /// side array (`HeadStore::frozen_attn`), since blocks are immutable
    /// and possibly shared.
    pub fn attn(&self) -> &[f32] {
        &self.bufs().attn
    }
}

impl Block {
    pub(super) fn new(bufs: BlockBufs, rows: usize, d: usize, pool: Arc<BlockPool>) -> Block {
        debug_assert_eq!(bufs.k.len(), rows * d);
        debug_assert_eq!(bufs.v.len(), rows * d);
        debug_assert_eq!(bufs.pos.len(), rows);
        debug_assert_eq!(bufs.attn.len(), rows);
        Block {
            state: RwLock::new(BlockState { bufs: Some(bufs), store_id: 0 }),
            rows,
            d,
            tick: AtomicU64::new(0),
            pool,
        }
    }

    /// A handle over an already-persisted payload, starting spilled
    /// (restart restore path: the payload stays on disk until read).
    pub(super) fn restored(rows: usize, d: usize, store_id: u64, pool: Arc<BlockPool>) -> Block {
        debug_assert!(store_id != 0);
        Block {
            state: RwLock::new(BlockState { bufs: None, store_id }),
            rows,
            d,
            tick: AtomicU64::new(0),
            pool,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn payload_bytes(&self) -> usize {
        block_bytes(self.rows, self.d)
    }

    pub fn is_resident(&self) -> bool {
        self.state.read().unwrap().bufs.is_some()
    }

    pub(super) fn last_tick(&self) -> u64 {
        self.tick.load(Ordering::Relaxed)
    }

    /// Access the payload, faulting it in from the store when spilled.
    /// Infallible by design — decode never fails mid-request on tiering —
    /// so an unreadable store record (torn file, dead disk) panics.
    pub fn read(&self) -> BlockData<'_> {
        self.tick.store(self.pool.next_tick(), Ordering::Relaxed);
        loop {
            {
                let guard = self.state.read().unwrap();
                if guard.bufs.is_some() {
                    return BlockData { guard };
                }
            }
            self.fault_in();
        }
    }

    fn fault_in(&self) {
        let mut st = self.state.write().unwrap();
        if st.bufs.is_some() {
            return; // raced with another reader's fault-in
        }
        let bufs = self.pool.fault_block(st.store_id, self.rows, self.d);
        st.bufs = Some(bufs);
    }

    /// Persist the payload (if not already on disk) and take one claim
    /// for a descriptor that will reference it.
    pub fn persist_into(&self, store: &KvStore) -> anyhow::Result<u64> {
        let mut st = self.state.write().unwrap();
        if st.store_id == 0 {
            let bufs = st.bufs.as_ref().expect("an unpersisted block is resident");
            st.store_id =
                store.persist_block(self.rows, self.d, &bufs.k, &bufs.v, &bufs.pos, &bufs.attn)?;
        }
        store.retain_block(st.store_id);
        Ok(st.store_id)
    }

    /// Demote to disk: persist (first time only), drop the buffers, move
    /// the ledger bytes resident → spilled.  Skips — returning `None` —
    /// when the block is already spilled, under an active read guard, or
    /// the store write fails.
    pub(super) fn try_demote(&self, store: &KvStore) -> Option<usize> {
        let mut st = self.state.try_write().ok()?;
        st.bufs.as_ref()?;
        if st.store_id == 0 {
            let bufs = st.bufs.as_ref().expect("checked above");
            match store.persist_block(self.rows, self.d, &bufs.k, &bufs.v, &bufs.pos, &bufs.attn) {
                Ok(id) => st.store_id = id,
                Err(e) => {
                    eprintln!("kvpool: spill write failed, keeping block resident: {e:#}");
                    return None;
                }
            }
        }
        let bufs = st.bufs.take().expect("checked above");
        // ledger moves under the state lock so a racing fault-in observes
        // state + ledger atomically
        self.pool.on_demoted(self.rows, self.d, bufs);
        Some(self.payload_bytes())
    }
}

impl Drop for Block {
    fn drop(&mut self) {
        let st = self.state.get_mut().unwrap();
        let store_id = st.store_id;
        match st.bufs.take() {
            Some(bufs) => self.pool.release(self.rows, self.d, bufs),
            None => self.pool.release_spilled(self.rows, self.d),
        }
        if store_id != 0 {
            self.pool.release_store_claim(store_id);
        }
    }
}

impl fmt::Debug for Block {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Block")
            .field("rows", &self.rows)
            .field("d", &self.d)
            .field("bytes", &self.payload_bytes())
            .field("resident", &self.is_resident())
            .finish()
    }
}

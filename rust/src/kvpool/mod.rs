//! Paged KV memory pool: a slab-backed block allocator with exact byte
//! accounting and memory-pressure signals for the serving stack.
//!
//! The paper's recursive lag-compression exists to *bound* KV memory; this
//! module is where that bound becomes operational.  LagKV's fixed-size
//! partition windows (score the oldest `L` tail rows, keep `floor(r*L)`)
//! are unusually friendly to fixed-size block allocation, so the cache
//! manager splits every `(layer, head)` store into two regions:
//!
//! * a **frozen prefix** of immutable, refcounted, pool-owned [`Block`]s —
//!   sink rows and past compression survivors, final by the driver's
//!   contract.  Freezing happens at compaction time, one full block at a
//!   time, so each row is copied at most once ever (the old flat `Vec`
//!   rebuild re-copied the whole prefix on every compaction);
//! * a **loose tail** of contiguous `Vec`s — the uncompressed rows the
//!   scorer still reads as slices.  Its bytes are registered with the pool
//!   through a [`LooseGauge`] so `PoolStats::resident_bytes()` is exact.
//!
//! Sharing a frozen block is a refcount bump, which is what makes a
//! detached session's cache copy-on-write: a resumed turn re-attaches the
//! history blocks and allocates only its own tail.  Blocks are immutable
//! from birth, so shared data can never be written through either owner.
//!
//! Budgeted pools (`BlockPool::new(rows, Some(bytes))`) enforce the budget
//! at block allocation and expose [`BlockPool::resident_bytes`] /
//! [`BlockPool::hard_pressure`] for the coordinator's admission path, which
//! sheds least-recently-used sessions under pressure and rejects with the
//! typed `pool-exhausted` error when even an empty store leaves no room.
//! Freezing itself degrades gracefully under a full budget (rows simply
//! stay loose): decode never fails mid-request on a pool limit.

pub mod block;
pub mod radix;
pub mod stats;

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::kvstore::KvStore;
use crate::quant::{CodecKind, EncodedKv};
use crate::telemetry::{Metric, Telemetry};

pub use block::{block_bytes, Block, BlockBufs, BlockData};
pub use radix::{PrefixCache, PrefixConfig, PrefixStats};
pub use stats::{PoolExhausted, PoolStats};

/// Payload bytes of one cache row across every `(layer, head)`: K + V at
/// `d_head` floats each, plus the position and attention side entries.
/// The admission path multiplies this by a row estimate to budget work.
pub fn row_bytes(n_layers: usize, n_heads: usize, d_head: usize) -> usize {
    n_layers * n_heads * block_bytes(1, d_head)
}

#[derive(Default)]
struct PoolInner {
    /// Recycled buffers keyed by head width `d` (one pool may serve test
    /// caches of several widths; a serving engine uses exactly one).
    free: HashMap<usize, Vec<BlockBufs>>,
    block_bytes: usize,
    loose_bytes: usize,
    free_bytes: usize,
    high_water: usize,
    resident_blocks: usize,
    free_blocks: usize,
    /// Payload bytes of blocks demoted to the disk tier (their buffers
    /// recycled).  Not resident: spilled bytes never count against the
    /// budget — that is the whole point of demotion.
    spilled_bytes: usize,
    spilled_blocks: usize,
    /// Cumulative fault-ins (disk → pool); monotone, unlike the spill
    /// gauges, which move both ways as blocks demote and return.
    faults: u64,
    fault_bytes: usize,
    /// Exact encoded bytes of resident quantized blocks (payload +
    /// sidecar + side arrays, in [`CodecKind::encoded_block_bytes`]
    /// units).  Invariant under freeze/thaw/spill/fault churn:
    /// `quant_bytes == Σ_blocks encoded_block_bytes` — the property suite
    /// pins this with randomized churn.
    quant_bytes: usize,
    quant_blocks: usize,
    /// Bytes in decoded-row caches of quantized blocks (fp32 copies kept
    /// for read paths; droppable at any time, bounded by the pool's
    /// decode-cache budget).
    dq_bytes: usize,
}

impl PoolInner {
    /// Live data bytes: plain blocks, loose regions, encoded quantized
    /// blocks, and their decoded-row caches.
    fn resident(&self) -> usize {
        self.block_bytes + self.loose_bytes + self.quant_bytes + self.dq_bytes
    }

    fn bump_high_water(&mut self) {
        let resident = self.resident();
        if resident > self.high_water {
            self.high_water = resident;
        }
    }
}

/// The allocator.  Shared (`Arc`) between an engine, its caches, and the
/// router's admission check; internally a mutex-guarded ledger plus free
/// list — allocation is off the per-token hot path (one block per
/// `rows_per_block` frozen rows).
pub struct BlockPool {
    rows_per_block: usize,
    max_bytes: Option<usize>,
    /// Bytes the coordinator could reclaim by shedding every detached
    /// session (published by the session store on every mutation; used by
    /// the router's cheap pre-queue pressure check).
    sheddable: AtomicUsize,
    /// Bytes reclaimable by shedding every prefix-cache snapshot (the
    /// cheapest sheddable class; published by [`radix::PrefixCache`]).
    prefix_sheddable: AtomicUsize,
    /// Logical clock stamped onto blocks on every read: the spill LRU.
    clock: AtomicU64,
    /// Bound disk tier, when `--store-dir` is in play: spill target and
    /// fault-in source.
    store: Mutex<Option<Arc<KvStore>>>,
    /// Every live block (weak), so `spill` can find demotion candidates.
    /// Compacted amortized-O(1) as dead entries accumulate.
    registry: Mutex<Registry>,
    /// Bound telemetry hub, when the router runs one: spill and fault-in
    /// durations land in its histogram registry.
    telemetry: Mutex<Option<Arc<Telemetry>>>,
    /// Byte budget for the decoded-row caches of quantized blocks
    /// (`dq_bytes`); reads trim coldest-first above it.
    dq_budget: AtomicUsize,
    inner: Mutex<PoolInner>,
}

#[derive(Default)]
struct Registry {
    items: Vec<Weak<Block>>,
    compact_at: usize,
}

impl Registry {
    fn push(&mut self, block: &Arc<Block>) {
        if self.items.len() >= self.compact_at.max(64) {
            self.items.retain(|w| w.strong_count() > 0);
            self.compact_at = self.items.len() * 2;
        }
        self.items.push(Arc::downgrade(block));
    }
}

impl BlockPool {
    /// Default block height: 16 rows, so the default lag window `L = 64`
    /// freezes as exactly four blocks.
    pub const DEFAULT_ROWS_PER_BLOCK: usize = 16;

    /// Default byte budget for decoded-row caches of quantized blocks:
    /// 32 MiB — enough to keep every hot block's fp32 copy around at the
    /// scales this stack serves, small enough that quantization's resident
    /// saving survives heavy read traffic.
    pub const DEFAULT_DECODE_CACHE_BYTES: usize = 32 << 20;

    pub fn new(rows_per_block: usize, max_bytes: Option<usize>) -> Arc<BlockPool> {
        assert!(rows_per_block > 0, "rows_per_block must be positive");
        Arc::new(BlockPool {
            rows_per_block,
            max_bytes,
            sheddable: AtomicUsize::new(0),
            prefix_sheddable: AtomicUsize::new(0),
            clock: AtomicU64::new(0),
            store: Mutex::new(None),
            registry: Mutex::new(Registry::default()),
            telemetry: Mutex::new(None),
            dq_budget: AtomicUsize::new(BlockPool::DEFAULT_DECODE_CACHE_BYTES),
            inner: Mutex::new(PoolInner::default()),
        })
    }

    /// A pool with no byte budget (the default for standalone caches and
    /// unconfigured engines: accounting without enforcement).
    pub fn unbounded(rows_per_block: usize) -> Arc<BlockPool> {
        BlockPool::new(rows_per_block, None)
    }

    pub fn rows_per_block(&self) -> usize {
        self.rows_per_block
    }

    pub fn budget(&self) -> Option<usize> {
        self.max_bytes
    }

    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.lock().unwrap();
        PoolStats {
            block_bytes: inner.block_bytes,
            loose_bytes: inner.loose_bytes,
            free_bytes: inner.free_bytes,
            high_water_bytes: inner.high_water,
            resident_blocks: inner.resident_blocks,
            free_blocks: inner.free_blocks,
            spilled_bytes: inner.spilled_bytes,
            spilled_blocks: inner.spilled_blocks,
            faults: inner.faults,
            fault_bytes: inner.fault_bytes,
            quant_bytes: inner.quant_bytes,
            quant_blocks: inner.quant_blocks,
            dq_bytes: inner.dq_bytes,
            budget: self.max_bytes,
        }
    }

    /// Live data bytes right now: plain blocks, registered loose regions,
    /// encoded quantized blocks, and their decoded-row caches.
    pub fn resident_bytes(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.resident()
    }

    /// Set the byte budget for decoded-row caches of quantized blocks.
    /// Reads trim coldest caches first once `dq_bytes` passes it.
    pub fn set_decode_cache_budget(&self, bytes: usize) {
        self.dq_budget.store(bytes, Ordering::Relaxed);
    }

    pub fn decode_cache_budget(&self) -> usize {
        self.dq_budget.load(Ordering::Relaxed)
    }

    /// Allocate one full block holding exactly `rows_per_block` rows,
    /// copied from the given contiguous sources.  Reuses a free-list
    /// buffer when one of the right width exists; enforces the byte
    /// budget; returns the typed [`PoolExhausted`] on overflow.
    ///
    /// `loose_credit` is the count of already-resident loose bytes this
    /// block is about to replace: freezing converts loose rows into block
    /// rows (the caller drains them right after), so the budget check
    /// discounts the credit to keep a net-zero operation admissible even
    /// at a full budget.  Pass 0 for a plain allocation.
    ///
    /// An associated function (not a method) because the block must hold
    /// an owning handle back to its pool for free-list recycling on drop.
    pub fn alloc_block(
        pool: &Arc<BlockPool>,
        d: usize,
        k: &[f32],
        v: &[f32],
        pos: &[i32],
        attn: &[f32],
        loose_credit: usize,
    ) -> Result<Arc<Block>, PoolExhausted> {
        let this: &BlockPool = pool;
        let rows = this.rows_per_block;
        assert_eq!(k.len(), rows * d, "alloc_block: k must hold {rows} rows of width {d}");
        assert_eq!(v.len(), rows * d, "alloc_block: v must hold {rows} rows of width {d}");
        assert_eq!(pos.len(), rows, "alloc_block: pos must hold {rows} rows");
        assert_eq!(attn.len(), rows, "alloc_block: attn must hold {rows} rows");
        let bytes = block_bytes(rows, d);
        let mut bufs = {
            let mut inner = this.inner.lock().unwrap();
            if let Some(budget) = this.max_bytes {
                let resident = inner.resident();
                if resident + bytes > budget.saturating_add(loose_credit) {
                    return Err(PoolExhausted { needed: bytes, resident, budget });
                }
            }
            let bufs = match inner.free.get_mut(&d).and_then(|fl| fl.pop()) {
                Some(b) => {
                    inner.free_blocks -= 1;
                    inner.free_bytes -= bytes;
                    b
                }
                None => BlockBufs::with_capacity(rows, d),
            };
            inner.block_bytes += bytes;
            inner.resident_blocks += 1;
            inner.bump_high_water();
            bufs
        };
        bufs.clear();
        bufs.k.extend_from_slice(k);
        bufs.v.extend_from_slice(v);
        bufs.pos.extend_from_slice(pos);
        bufs.attn.extend_from_slice(attn);
        let block = Arc::new(Block::new(bufs, rows, d, Arc::clone(pool)));
        this.registry.lock().unwrap().push(&block);
        Ok(block)
    }

    /// Allocate one full block through a codec: [`CodecKind::Fp32`]
    /// routes to the plain [`BlockPool::alloc_block`] path (identical
    /// blocks, identical ledger); any lossy codec encodes here — the
    /// single encode point of the whole stack — and the block is born
    /// encoded-resident, accounted under `quant_bytes`/`quant_blocks` in
    /// exact [`CodecKind::encoded_block_bytes`] units.  Budget and
    /// `loose_credit` semantics match `alloc_block`, but the budget check
    /// uses the *encoded* size, so freezing through a shrinking codec is
    /// strictly net-negative and always admissible at a full budget.
    #[allow(clippy::too_many_arguments)]
    pub fn alloc_quant_block(
        pool: &Arc<BlockPool>,
        d: usize,
        kind: CodecKind,
        k: &[f32],
        v: &[f32],
        pos: &[i32],
        attn: &[f32],
        loose_credit: usize,
    ) -> Result<Arc<Block>, PoolExhausted> {
        if kind == CodecKind::Fp32 {
            return BlockPool::alloc_block(pool, d, k, v, pos, attn, loose_credit);
        }
        let this: &BlockPool = pool;
        let rows = this.rows_per_block;
        assert_eq!(k.len(), rows * d, "alloc_quant_block: k must hold {rows} rows of width {d}");
        assert_eq!(v.len(), rows * d, "alloc_quant_block: v must hold {rows} rows of width {d}");
        assert_eq!(pos.len(), rows, "alloc_quant_block: pos must hold {rows} rows");
        assert_eq!(attn.len(), rows, "alloc_quant_block: attn must hold {rows} rows");
        let bytes = kind.encoded_block_bytes(rows, d);
        {
            // lint: allow(panic): lock poisoning is unrecoverable by design across the pool
            let mut inner = this.inner.lock().unwrap();
            if let Some(budget) = this.max_bytes {
                let resident = inner.resident();
                if resident + bytes > budget.saturating_add(loose_credit) {
                    return Err(PoolExhausted { needed: bytes, resident, budget });
                }
            }
            inner.quant_bytes += bytes;
            inner.quant_blocks += 1;
            inner.bump_high_water();
        }
        let timer = this.quant_timer();
        let enc = kind.codec().encode(rows, d, k, v);
        this.finish_quant_timer(timer);
        debug_assert_eq!(enc.byte_len(), kind.codec().encoded_kv_bytes(rows, d));
        let block = Arc::new(Block::new_quant(
            kind,
            enc,
            pos.to_vec(),
            attn.to_vec(),
            rows,
            d,
            Arc::clone(pool),
        ));
        // lint: allow(panic): lock poisoning is unrecoverable by design across the pool
        this.registry.lock().unwrap().push(&block);
        Ok(block)
    }

    /// Adopt a block whose payload already lives in the bound store (the
    /// restart restore path).  Starts spilled — zero resident bytes — and
    /// faults in lazily on first read; takes the live handle's claim on
    /// the store record.  `codec` must match the persisted record's codec
    /// (the store metadata carries it), so the spilled gauge moves in the
    /// encoded units the eventual fault-in will reverse.
    pub fn adopt_spilled(
        pool: &Arc<BlockPool>,
        store_id: u64,
        rows: usize,
        d: usize,
        codec: CodecKind,
    ) -> Arc<Block> {
        let bytes = codec.encoded_block_bytes(rows, d);
        {
            let mut inner = pool.inner.lock().unwrap();
            inner.spilled_bytes += bytes;
            inner.spilled_blocks += 1;
        }
        if let Some(store) = pool.store() {
            store.retain_block(store_id);
        }
        let block = Arc::new(Block::restored(rows, d, codec, store_id, Arc::clone(pool)));
        pool.registry.lock().unwrap().push(&block);
        block
    }

    /// Return a dropped block's buffers to the free list (called from
    /// `Block::drop`).
    pub(crate) fn release(&self, rows: usize, d: usize, bufs: BlockBufs) {
        let bytes = block_bytes(rows, d);
        let mut inner = self.inner.lock().unwrap();
        inner.block_bytes -= bytes;
        inner.resident_blocks -= 1;
        inner.free_bytes += bytes;
        inner.free_blocks += 1;
        inner.free.entry(d).or_default().push(bufs);
    }

    // -- disk tier (spill / fault) ---------------------------------------------

    /// Bind the disk tier.  Done once at router start; from then on
    /// `spill` can demote cold blocks and spilled blocks fault back in
    /// transparently on read.
    pub fn bind_store(&self, store: Arc<KvStore>) {
        *self.store.lock().unwrap() = Some(store);
    }

    pub fn store(&self) -> Option<Arc<KvStore>> {
        self.store.lock().unwrap().clone()
    }

    pub fn has_store(&self) -> bool {
        self.store.lock().unwrap().is_some()
    }

    /// Bind the model's telemetry hub (router start).  Spill and fault-in
    /// durations are recorded into its histogram registry from then on.
    pub fn set_telemetry(&self, telemetry: Arc<Telemetry>) {
        *self.telemetry.lock().unwrap() = Some(telemetry);
    }

    fn telemetry(&self) -> Option<Arc<Telemetry>> {
        self.telemetry.lock().unwrap().clone()
    }

    /// Start timing one codec pass (encode or decode).  Returns `None`
    /// when no telemetry hub is bound, so the hot path pays one mutex
    /// clone and nothing else.
    fn quant_timer(&self) -> Option<(Arc<Telemetry>, u64)> {
        self.telemetry().map(|tel| {
            let t0_us = tel.now_us();
            (tel, t0_us)
        })
    }

    /// Close a [`BlockPool::quant_timer`] span into the `quantized`
    /// histogram.
    fn finish_quant_timer(&self, timer: Option<(Arc<Telemetry>, u64)>) {
        if let Some((tel, t0_us)) = timer {
            tel.record(Metric::Quant, tel.now_us().saturating_sub(t0_us));
        }
    }

    /// Next value of the block-read clock (the spill LRU ordering).
    pub(crate) fn next_tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Demote cold blocks to the disk tier until at least `target` bytes
    /// have left residency or no candidate remains.  Returns
    /// `(blocks_demoted, bytes_demoted)`.  Candidates are every live
    /// resident block, coldest first (least-recently-read); blocks under
    /// an active read guard are skipped, not waited on.  A no-op without
    /// a bound store.
    pub fn spill(&self, target: usize) -> (usize, usize) {
        let Some(store) = self.store() else {
            return (0, 0);
        };
        if target == 0 {
            return (0, 0);
        }
        let mut candidates: Vec<(u64, Arc<Block>)> = Vec::new();
        {
            let mut reg = self.registry.lock().unwrap();
            reg.items.retain(|w| w.strong_count() > 0);
            for w in reg.items.iter() {
                if let Some(b) = w.upgrade() {
                    if b.is_resident() {
                        candidates.push((b.last_tick(), b));
                    }
                }
            }
        }
        candidates.sort_by_key(|(tick, _)| *tick);
        let telemetry = self.telemetry();
        let mut blocks = 0usize;
        let mut bytes = 0usize;
        for (_, b) in candidates {
            if bytes >= target {
                break;
            }
            let t0_us = telemetry.as_ref().map(|tel| tel.now_us());
            if let Some(n) = b.try_demote(&store) {
                blocks += 1;
                bytes += n;
                if let (Some(tel), Some(t0_us)) = (&telemetry, t0_us) {
                    tel.record(Metric::Spill, tel.now_us().saturating_sub(t0_us));
                }
            }
        }
        (blocks, bytes)
    }

    /// Keep the decoded-row cache under its budget by dropping the
    /// coldest decoded copies.  Quantized blocks stay encoded-resident;
    /// only their fp32 decode caches are shed, so this never touches the
    /// store and never loses data.  Called from `Block::read` *before*
    /// any block lock is taken (the reading block has just stamped the
    /// freshest tick, making it the last candidate — a reader never
    /// thrashes its own cache).  Skips blocks under an active read guard
    /// via `try_drop_decoded`'s non-blocking write attempt.
    pub(crate) fn maybe_trim_decoded(&self) {
        let budget = self.dq_budget.load(Ordering::Relaxed);
        {
            // lint: allow(panic): lock poisoning is unrecoverable by design across the pool
            let inner = self.inner.lock().unwrap();
            if inner.dq_bytes <= budget {
                return;
            }
        }
        let mut candidates: Vec<(u64, Arc<Block>)> = Vec::new();
        {
            // lint: allow(panic): lock poisoning is unrecoverable by design across the pool
            let mut reg = self.registry.lock().unwrap();
            reg.items.retain(|w| w.strong_count() > 0);
            for w in reg.items.iter() {
                if let Some(b) = w.upgrade() {
                    if b.has_decoded() {
                        candidates.push((b.last_tick(), b));
                    }
                }
            }
        }
        candidates.sort_by_key(|(tick, _)| *tick);
        for (_, b) in candidates {
            {
                // lint: allow(panic): lock poisoning is unrecoverable by design across the pool
                let inner = self.inner.lock().unwrap();
                if inner.dq_bytes <= budget {
                    return;
                }
            }
            b.try_drop_decoded();
        }
    }

    /// Ledger half of a demotion (called by `Block::try_demote` with the
    /// block's state lock held, so residency and accounting move
    /// together): bytes leave the resident tier for the spilled tier and
    /// the buffers are recycled.
    pub(crate) fn on_demoted(&self, rows: usize, d: usize, bufs: BlockBufs) {
        let bytes = block_bytes(rows, d);
        let mut inner = self.inner.lock().unwrap();
        inner.block_bytes -= bytes;
        inner.resident_blocks -= 1;
        inner.spilled_bytes += bytes;
        inner.spilled_blocks += 1;
        inner.free_bytes += bytes;
        inner.free_blocks += 1;
        inner.free.entry(d).or_default().push(bufs);
    }

    /// Ledger half of a *quantized* demotion: the encoded payload's bytes
    /// move quant → spilled (same exact encoded units the fault-in will
    /// reverse), and any decoded fp32 cache the block was carrying is
    /// dropped alongside — its buffers recycle to the free list.  The
    /// encoded `Vec<u8>`s travel with the store write and are not pooled.
    pub(crate) fn on_demoted_quant(
        &self,
        rows: usize,
        d: usize,
        kind: CodecKind,
        decoded: Option<BlockBufs>,
    ) {
        let enc_bytes = kind.encoded_block_bytes(rows, d);
        // lint: allow(panic): lock poisoning is unrecoverable by design across the pool
        let mut inner = self.inner.lock().unwrap();
        inner.quant_bytes -= enc_bytes;
        inner.quant_blocks -= 1;
        inner.spilled_bytes += enc_bytes;
        inner.spilled_blocks += 1;
        if let Some(bufs) = decoded {
            let bytes = block_bytes(rows, d);
            inner.dq_bytes -= bytes;
            inner.free_bytes += bytes;
            inner.free_blocks += 1;
            inner.free.entry(d).or_default().push(bufs);
        }
    }

    /// Ledger half of a decode-cache trim (called by
    /// `Block::try_drop_decoded` with the block's state lock held): the
    /// fp32 copy leaves the `dq_bytes` gauge and its buffers recycle.
    /// The block itself stays encoded-resident.
    pub(crate) fn on_decoded_dropped(&self, rows: usize, d: usize, bufs: BlockBufs) {
        let bytes = block_bytes(rows, d);
        // lint: allow(panic): lock poisoning is unrecoverable by design across the pool
        let mut inner = self.inner.lock().unwrap();
        inner.dq_bytes -= bytes;
        inner.free_bytes += bytes;
        inner.free_blocks += 1;
        inner.free.entry(d).or_default().push(bufs);
    }

    /// Fault a spilled payload back in: read the store record, move the
    /// ledger bytes spilled → resident, and fill (recycled) buffers.
    ///
    /// Deliberately *not* budget-checked: fault-in happens on the decode
    /// path (`window()` walking a re-attached cache), which must never
    /// fail on a pool limit; the next admission sees the grown residency
    /// and sheds or spills accordingly.  Panics when the bound store
    /// cannot produce the payload — that is a torn store file, not a
    /// recoverable serving condition.
    pub(crate) fn fault_block(&self, store_id: u64, rows: usize, d: usize) -> BlockBufs {
        let telemetry = self.telemetry();
        let t0_us = telemetry.as_ref().map(|tel| tel.now_us());
        let store = self.store().expect("faulting a spilled block requires its bound store");
        let payload = store
            .read_block(store_id)
            .unwrap_or_else(|e| panic!("kvpool: fault-in of block {store_id} failed: {e:#}"));
        assert_eq!((payload.rows, payload.d), (rows, d), "store payload dims drifted");
        let bytes = block_bytes(rows, d);
        let mut bufs = {
            let mut inner = self.inner.lock().unwrap();
            let bufs = match inner.free.get_mut(&d).and_then(|fl| fl.pop()) {
                Some(b) => {
                    inner.free_blocks -= 1;
                    inner.free_bytes -= bytes;
                    b
                }
                None => BlockBufs::with_capacity(rows, d),
            };
            inner.spilled_bytes -= bytes;
            inner.spilled_blocks -= 1;
            inner.block_bytes += bytes;
            inner.resident_blocks += 1;
            inner.faults += 1;
            inner.fault_bytes += bytes;
            inner.bump_high_water();
            bufs
        };
        bufs.clear();
        bufs.k.extend_from_slice(&payload.k);
        bufs.v.extend_from_slice(&payload.v);
        bufs.pos.extend_from_slice(&payload.pos);
        bufs.attn.extend_from_slice(&payload.attn);
        if let (Some(tel), Some(t0_us)) = (&telemetry, t0_us) {
            tel.record(Metric::Fault, tel.now_us().saturating_sub(t0_us));
        }
        bufs
    }

    /// Fault a spilled *encoded* payload back in: read the quant store
    /// record (encoded data + sidecar + side arrays, exactly the bytes
    /// the demotion wrote — never a decode round-trip) and move the
    /// ledger bytes spilled → quant.  Like [`BlockPool::fault_block`],
    /// deliberately not budget-checked, and a torn store record panics.
    pub(crate) fn fault_quant_block(
        &self,
        store_id: u64,
        kind: CodecKind,
        rows: usize,
        d: usize,
    ) -> (EncodedKv, Vec<i32>, Vec<f32>) {
        let telemetry = self.telemetry();
        let t0_us = telemetry.as_ref().map(|tel| tel.now_us());
        // lint: allow(panic): a missing store on the fault path is a wiring bug, not a serving condition
        let store = self.store().expect("faulting a spilled block requires its bound store");
        let payload = store
            .read_quant_block(store_id)
            // lint: allow(panic): a torn store record is unrecoverable by design (mirrors fault_block)
            .unwrap_or_else(|e| panic!("kvpool: fault-in of quant block {store_id} failed: {e:#}"));
        assert_eq!((payload.rows, payload.d), (rows, d), "store payload dims drifted");
        assert_eq!(payload.codec, kind.tag(), "store payload codec drifted");
        let bytes = kind.encoded_block_bytes(rows, d);
        {
            // lint: allow(panic): lock poisoning is unrecoverable by design across the pool
            let mut inner = self.inner.lock().unwrap();
            inner.spilled_bytes -= bytes;
            inner.spilled_blocks -= 1;
            inner.quant_bytes += bytes;
            inner.quant_blocks += 1;
            inner.faults += 1;
            inner.fault_bytes += bytes;
            inner.bump_high_water();
        }
        if let (Some(tel), Some(t0_us)) = (&telemetry, t0_us) {
            tel.record(Metric::Fault, tel.now_us().saturating_sub(t0_us));
        }
        (EncodedKv { data: payload.data, sidecar: payload.sidecar }, payload.pos, payload.attn)
    }

    /// Decode an encoded block into fp32 row buffers (the decoded-row
    /// cache).  Buffers come off the free list when one of the right
    /// width is available; the decoded copy is accounted under
    /// `dq_bytes` in full fp32 `block_bytes` units.
    pub(crate) fn decode_block(
        &self,
        kind: CodecKind,
        rows: usize,
        d: usize,
        enc: &EncodedKv,
        pos: &[i32],
        attn: &[f32],
    ) -> BlockBufs {
        let timer = self.quant_timer();
        let bytes = block_bytes(rows, d);
        let mut bufs = {
            // lint: allow(panic): lock poisoning is unrecoverable by design across the pool
            let mut inner = self.inner.lock().unwrap();
            let bufs = match inner.free.get_mut(&d).and_then(|fl| fl.pop()) {
                Some(b) => {
                    inner.free_blocks -= 1;
                    inner.free_bytes -= bytes;
                    b
                }
                None => BlockBufs::with_capacity(rows, d),
            };
            inner.dq_bytes += bytes;
            inner.bump_high_water();
            bufs
        };
        bufs.clear();
        kind.codec().decode(rows, d, enc, &mut bufs.k, &mut bufs.v);
        bufs.pos.extend_from_slice(pos);
        bufs.attn.extend_from_slice(attn);
        self.finish_quant_timer(timer);
        bufs
    }

    /// A spilled block's last handle dropped: its payload bytes (fp32 or
    /// encoded — the caller passes its own `payload_bytes()`) leave the
    /// spilled tier.  The store claim is released separately.
    pub(crate) fn release_spilled(&self, bytes: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.spilled_bytes -= bytes;
        inner.spilled_blocks -= 1;
    }

    /// An encoded-resident block's last handle dropped: encoded bytes
    /// leave the quant gauges (the encoded buffers are plain `Vec`s, not
    /// pooled) and any decoded cache recycles to the free list.
    pub(crate) fn release_quant(
        &self,
        rows: usize,
        d: usize,
        kind: CodecKind,
        decoded: Option<BlockBufs>,
    ) {
        let enc_bytes = kind.encoded_block_bytes(rows, d);
        // lint: allow(panic): lock poisoning is unrecoverable by design across the pool
        let mut inner = self.inner.lock().unwrap();
        inner.quant_bytes -= enc_bytes;
        inner.quant_blocks -= 1;
        if let Some(bufs) = decoded {
            let bytes = block_bytes(rows, d);
            inner.dq_bytes -= bytes;
            inner.free_bytes += bytes;
            inner.free_blocks += 1;
            inner.free.entry(d).or_default().push(bufs);
        }
    }

    /// Drop the live handle's claim on a persisted payload.
    pub(crate) fn release_store_claim(&self, store_id: u64) {
        if let Some(store) = self.store() {
            store.release_block(store_id);
        }
    }

    /// Swap one gauge's registered loose bytes (`old` out, `new` in).
    ///
    /// Deregistering more bytes than the ledger holds is accounting drift —
    /// a gauge double-dropped, or a byte count mutated behind the pool's
    /// back.  The old `saturating_sub` silently absorbed that drift (and
    /// with it, any bug that caused it); now it is a `debug_assert!` in
    /// test builds, and release builds re-base the ledger on the surviving
    /// registrations (`new` alone) instead of under-counting forever.
    pub(crate) fn adjust_loose(&self, old: usize, new: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.loose_bytes = match inner.loose_bytes.checked_sub(old) {
            Some(rest) => rest + new,
            None => {
                debug_assert!(
                    false,
                    "pool ledger underflow: deregistering {old} loose bytes with only {} \
                     registered",
                    inner.loose_bytes
                );
                new
            }
        };
        inner.bump_high_water();
    }

    /// Publish how many resident bytes belong to detached sessions (the
    /// session store owns that number; the router only reads it).
    pub fn set_sheddable(&self, bytes: usize) {
        // lint: allow(ledger): this setter IS the gauge's single publish point — the session store owns the value and republishes it whole after every mutation
        self.sheddable.store(bytes, Ordering::Relaxed);
    }

    /// Publish how many resident bytes belong to prefix-cache snapshots
    /// (owned by [`radix::PrefixCache`]; shed before sessions).
    pub fn set_prefix_sheddable(&self, bytes: usize) {
        // lint: allow(ledger): this setter IS the gauge's single publish point — the prefix cache owns the value and republishes it whole after every mutation
        self.prefix_sheddable.store(bytes, Ordering::Relaxed);
    }

    /// Total reclaimable bytes across both sheddable classes: prefix-cache
    /// snapshots (shed first) plus detached sessions.
    pub fn sheddable_bytes(&self) -> usize {
        self.sheddable.load(Ordering::Relaxed) + self.prefix_sheddable.load(Ordering::Relaxed)
    }

    /// True when a budget is set and the pool would stay at or over it
    /// even if every reclaimable byte were taken back: the router's cheap
    /// reject-before-enqueue signal.  Reclaimable covers the sheddable
    /// classes (prefix-cache snapshots, then detached sessions) and —
    /// with a disk tier bound — every frozen block byte, since spilling
    /// demotes those without destroying state.  The two sets overlap
    /// (sheddable caches hold blocks), so their *maximum* is used: a
    /// valid lower bound on the union that never double-counts.
    /// Unbudgeted pools are never under pressure.
    pub fn hard_pressure(&self) -> bool {
        match self.max_bytes {
            None => false,
            Some(budget) => {
                let mut reclaimable = self.sheddable_bytes();
                if self.has_store() {
                    // Every frozen block byte is demotable: fp32 blocks,
                    // encoded-resident quant blocks, and their decoded
                    // caches (which vanish when their block demotes).
                    let inner = self.inner.lock().unwrap();
                    reclaimable =
                        reclaimable.max(inner.block_bytes + inner.quant_bytes + inner.dq_bytes);
                }
                self.resident_bytes().saturating_sub(reclaimable) >= budget
            }
        }
    }
}

impl fmt::Debug for BlockPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.stats();
        f.debug_struct("BlockPool")
            .field("rows_per_block", &self.rows_per_block)
            .field("budget", &self.max_bytes)
            .field("resident_bytes", &s.resident_bytes())
            .field("resident_blocks", &s.resident_blocks)
            .field("free_blocks", &s.free_blocks)
            .finish()
    }
}

/// RAII registration of a cache's loose (non-block) bytes with its pool.
/// Cloning registers the same byte count again (the clone owns its own
/// copy of the loose region); dropping deregisters.  This is what keeps
/// `PoolStats::loose_bytes` exact without the pool knowing about caches.
#[must_use = "dropping a LooseGauge immediately deregisters its loose bytes from the pool"]
pub struct LooseGauge {
    pool: Arc<BlockPool>,
    bytes: usize,
}

impl LooseGauge {
    pub fn new(pool: Arc<BlockPool>) -> LooseGauge {
        LooseGauge { pool, bytes: 0 }
    }

    pub fn pool(&self) -> &Arc<BlockPool> {
        &self.pool
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn set(&mut self, bytes: usize) {
        if bytes != self.bytes {
            self.pool.adjust_loose(self.bytes, bytes);
            self.bytes = bytes;
        }
    }
}

impl Clone for LooseGauge {
    fn clone(&self) -> LooseGauge {
        self.pool.adjust_loose(0, self.bytes);
        LooseGauge { pool: Arc::clone(&self.pool), bytes: self.bytes }
    }
}

impl Drop for LooseGauge {
    fn drop(&mut self) {
        self.pool.adjust_loose(self.bytes, 0);
    }
}

impl fmt::Debug for LooseGauge {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("LooseGauge").field("bytes", &self.bytes).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(rows: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<i32>, Vec<f32>) {
        let k: Vec<f32> = (0..rows * d).map(|i| i as f32).collect();
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        let pos: Vec<i32> = (0..rows as i32).collect();
        let attn = vec![0.5f32; rows];
        (k, v, pos, attn)
    }

    #[test]
    fn alloc_accounts_and_drop_recycles() {
        let pool = BlockPool::unbounded(4);
        let d = 3;
        let (k, v, pos, attn) = filled(4, d);
        let bytes = block_bytes(4, d);
        let b1 = BlockPool::alloc_block(&pool, d, &k, &v, &pos, &attn, 0).unwrap();
        let b2 = BlockPool::alloc_block(&pool, d, &k, &v, &pos, &attn, 0).unwrap();
        let s = pool.stats();
        assert_eq!(s.resident_blocks, 2);
        assert_eq!(s.block_bytes, 2 * bytes);
        assert_eq!(s.high_water_bytes, 2 * bytes);
        assert_eq!(b1.read().k(), &k[..]);
        assert_eq!(b1.read().pos(), &pos[..]);
        drop(b1);
        drop(b2);
        let s = pool.stats();
        assert_eq!(s.resident_blocks, 0);
        assert_eq!(s.block_bytes, 0);
        assert_eq!(s.free_blocks, 2, "buffers return to the free list");
        assert_eq!(s.free_bytes, 2 * bytes);
        assert!(s.fragmentation() > 0.99);
        assert_eq!(s.high_water_bytes, 2 * bytes, "high water is sticky");
        // the next alloc reuses a recycled buffer
        let _b3 = BlockPool::alloc_block(&pool, d, &k, &v, &pos, &attn, 0).unwrap();
        assert_eq!(pool.stats().free_blocks, 1);
    }

    #[test]
    fn shared_block_counts_once_and_frees_last() {
        let pool = BlockPool::unbounded(2);
        let (k, v, pos, attn) = filled(2, 2);
        let a = BlockPool::alloc_block(&pool, 2, &k, &v, &pos, &attn, 0).unwrap();
        let b = Arc::clone(&a); // copy-on-write share
        assert_eq!(pool.stats().resident_blocks, 1, "sharing is a refcount bump");
        drop(a);
        assert_eq!(pool.stats().resident_blocks, 1);
        assert_eq!(b.read().k(), &k[..]);
        drop(b);
        assert_eq!(pool.stats().resident_blocks, 0);
    }

    #[test]
    fn budget_rejects_with_typed_error() {
        let d = 2;
        let bytes = block_bytes(2, d);
        let pool = BlockPool::new(2, Some(bytes + bytes / 2));
        let (k, v, pos, attn) = filled(2, d);
        let held = BlockPool::alloc_block(&pool, d, &k, &v, &pos, &attn, 0).unwrap();
        let err = BlockPool::alloc_block(&pool, d, &k, &v, &pos, &attn, 0).unwrap_err();
        assert_eq!(
            err,
            PoolExhausted { needed: bytes, resident: bytes, budget: bytes + bytes / 2 }
        );
        drop(held);
        assert!(
            BlockPool::alloc_block(&pool, d, &k, &v, &pos, &attn, 0).is_ok(),
            "frees make room again"
        );
    }

    #[test]
    fn freeze_credit_keeps_net_zero_alloc_admissible_at_full_budget() {
        let d = 2;
        let bytes = block_bytes(2, d);
        let pool = BlockPool::new(2, Some(bytes));
        // a cache's loose rows fill the whole budget...
        pool.adjust_loose(0, bytes);
        let (k, v, pos, attn) = filled(2, d);
        // ...freezing them is net-zero, so the credited alloc is admitted
        let b = BlockPool::alloc_block(&pool, d, &k, &v, &pos, &attn, bytes).unwrap();
        pool.adjust_loose(bytes, 0); // the cache drains the frozen loose rows
        assert_eq!(pool.resident_bytes(), bytes);
        // an uncredited alloc at the full budget is still rejected
        assert!(BlockPool::alloc_block(&pool, d, &k, &v, &pos, &attn, 0).is_err());
        drop(b);
    }

    #[test]
    fn loose_gauge_registers_clones_and_drops() {
        let pool = BlockPool::unbounded(4);
        let mut g = LooseGauge::new(pool.clone());
        g.set(100);
        assert_eq!(pool.stats().loose_bytes, 100);
        let g2 = g.clone();
        assert_eq!(pool.stats().loose_bytes, 200, "a clone owns its own loose copy");
        g.set(40);
        assert_eq!(pool.stats().loose_bytes, 140);
        drop(g2);
        assert_eq!(pool.stats().loose_bytes, 40);
        drop(g);
        assert_eq!(pool.stats().loose_bytes, 0);
        assert_eq!(pool.stats().high_water_bytes, 200);
    }

    #[test]
    fn pressure_signals() {
        let pool = BlockPool::new(2, Some(1000));
        assert!(!pool.hard_pressure());
        pool.adjust_loose(0, 1000);
        assert!(pool.hard_pressure(), "at budget with nothing sheddable");
        pool.set_sheddable(600);
        assert!(!pool.hard_pressure(), "shedding could relieve the pressure");
        // grow well past the budget: one class alone no longer covers the
        // overrun, but the two sheddable classes together do
        pool.adjust_loose(1000, 1800);
        pool.set_sheddable(300);
        assert!(pool.hard_pressure(), "sessions alone no longer cover the overrun");
        pool.set_prefix_sheddable(600);
        assert_eq!(pool.sheddable_bytes(), 900);
        assert!(!pool.hard_pressure(), "prefix snapshots + sessions relieve the pressure");
        pool.set_prefix_sheddable(0);
        pool.set_sheddable(0);
        let unbounded = BlockPool::unbounded(2);
        unbounded.adjust_loose(0, 1 << 30);
        assert!(!unbounded.hard_pressure(), "no budget, no pressure");
    }

    #[test]
    fn row_bytes_counts_side_arrays() {
        // 2 layers x 2 heads x (2*8 floats + pos + attn) = 4 * (64 + 8)
        assert_eq!(row_bytes(2, 2, 8), 4 * (64 + 8));
    }

    #[test]
    fn spill_and_fault_round_trip_is_ledger_exact_and_bit_identical() {
        let dir = crate::kvstore::testutil::TempDir::new("pool-spill");
        let store = Arc::new(KvStore::open(dir.path()).unwrap());
        let pool = BlockPool::unbounded(4);
        pool.bind_store(Arc::clone(&store));
        let d = 3;
        let (k, v, pos, attn) = filled(4, d);
        let bytes = block_bytes(4, d);
        let b1 = BlockPool::alloc_block(&pool, d, &k, &v, &pos, &attn, 0).unwrap();
        let b2 = BlockPool::alloc_block(&pool, d, &k, &v, &pos, &attn, 0).unwrap();
        let _ = b2.read(); // stamp b2 hotter than b1
        let (nblocks, nbytes) = pool.spill(1);
        assert_eq!((nblocks, nbytes), (1, bytes), "coldest block demotes first");
        assert!(!b1.is_resident());
        assert!(b2.is_resident());
        let s = pool.stats();
        assert_eq!(s.block_bytes, bytes);
        assert_eq!((s.spilled_bytes, s.spilled_blocks), (bytes, 1));
        assert_eq!(s.resident_blocks, 1);
        assert_eq!(s.free_blocks, 1, "demoted buffers recycle to the free list");
        assert_eq!((s.faults, s.fault_bytes), (0, 0), "nothing faulted yet");
        // fault back in on read: bit-identical payload, ledger moves back
        assert_eq!(b1.read().k(), &k[..]);
        assert_eq!(b1.read().v(), &v[..]);
        assert_eq!(b1.read().pos(), &pos[..]);
        assert!(b1.is_resident());
        let s = pool.stats();
        assert_eq!((s.spilled_bytes, s.spilled_blocks), (0, 0));
        assert_eq!(s.block_bytes, 2 * bytes);
        assert_eq!((s.faults, s.fault_bytes), (1, bytes), "one fault-in, counted once");
        drop(b1);
        drop(b2);
        let s = pool.stats();
        assert_eq!(s.block_bytes, 0);
        assert_eq!(s.spilled_bytes, 0);
        assert_eq!((s.resident_blocks, s.spilled_blocks), (0, 0));
        let (_, _, blocks) = store.inventory_counts();
        assert_eq!(blocks, 0, "the last handle released the store record");
    }

    #[test]
    fn active_read_guard_pins_block_resident() {
        let dir = crate::kvstore::testutil::TempDir::new("pool-pin");
        let store = Arc::new(KvStore::open(dir.path()).unwrap());
        let pool = BlockPool::unbounded(2);
        pool.bind_store(store);
        let (k, v, pos, attn) = filled(2, 2);
        let b = BlockPool::alloc_block(&pool, 2, &k, &v, &pos, &attn, 0).unwrap();
        let guard = b.read();
        assert_eq!(pool.spill(usize::MAX), (0, 0), "a read guard pins the block");
        assert_eq!(guard.k(), &k[..]);
        drop(guard);
        let (nblocks, _) = pool.spill(usize::MAX);
        assert_eq!(nblocks, 1);
        // a re-demote after fault-in writes nothing new: same store record
        assert_eq!(b.read().k(), &k[..]);
        assert_eq!(pool.spill(usize::MAX).0, 1);
        assert!(!b.is_resident());
    }

    #[test]
    fn adopt_spilled_restores_a_persisted_block() {
        let dir = crate::kvstore::testutil::TempDir::new("pool-adopt");
        let store = Arc::new(KvStore::open(dir.path()).unwrap());
        let (k, v, pos, attn) = filled(4, 3);
        let id = {
            let pool = BlockPool::unbounded(4);
            pool.bind_store(Arc::clone(&store));
            let b = BlockPool::alloc_block(&pool, 3, &k, &v, &pos, &attn, 0).unwrap();
            // a descriptor-style claim keeps the payload after the handle dies
            b.persist_into(&store).unwrap()
        };
        let pool = BlockPool::unbounded(4);
        pool.bind_store(Arc::clone(&store));
        let b = BlockPool::adopt_spilled(&pool, id, 4, 3, CodecKind::Fp32);
        assert!(!b.is_resident(), "restored blocks start on the disk tier");
        let s = pool.stats();
        assert_eq!((s.spilled_blocks, s.block_bytes), (1, 0));
        assert_eq!(b.read().k(), &k[..], "lazy fault-in yields the original payload");
        store.release_block(id); // the descriptor claim goes away
        drop(b);
        let (_, _, blocks) = store.inventory_counts();
        assert_eq!(blocks, 0);
    }

    #[test]
    fn quant_alloc_is_ledger_exact_and_reads_decode() {
        let pool = BlockPool::unbounded(4);
        let d = 3;
        let (k, v, pos, attn) = filled(4, d);
        let enc_bytes = CodecKind::Int8Sym.encoded_block_bytes(4, d);
        assert!(enc_bytes < block_bytes(4, d), "int8 must shrink the block");
        let b =
            BlockPool::alloc_quant_block(&pool, d, CodecKind::Int8Sym, &k, &v, &pos, &attn, 0)
                .unwrap();
        assert_eq!(b.codec(), CodecKind::Int8Sym);
        let s = pool.stats();
        assert_eq!((s.quant_bytes, s.quant_blocks), (enc_bytes, 1));
        assert_eq!((s.block_bytes, s.resident_blocks), (0, 0), "no fp32 residency");
        assert_eq!(s.dq_bytes, 0, "nothing decoded until first read");
        assert_eq!(pool.resident_bytes(), enc_bytes);
        // first read decodes into the cache; side arrays are exact
        {
            let g = b.read();
            assert_eq!(g.pos(), &pos[..]);
            assert_eq!(g.attn(), &attn[..]);
            let scale = k.iter().fold(0f32, |m, x| m.max(x.abs())) / 127.0;
            for (orig, deq) in k.iter().zip(g.k()) {
                assert!((orig - deq).abs() <= scale, "row error bounded by its scale");
            }
        }
        let s = pool.stats();
        assert_eq!(s.dq_bytes, block_bytes(4, d), "decoded cache accounted in fp32 units");
        assert_eq!(pool.resident_bytes(), enc_bytes + block_bytes(4, d));
        drop(b);
        let s = pool.stats();
        assert_eq!((s.quant_bytes, s.quant_blocks, s.dq_bytes), (0, 0, 0));
        assert_eq!(s.free_blocks, 1, "decoded buffers recycle; encoded ones don't pool");
    }

    #[test]
    fn quant_fp32_routes_to_plain_alloc() {
        let pool = BlockPool::unbounded(2);
        let (k, v, pos, attn) = filled(2, 2);
        let b = BlockPool::alloc_quant_block(&pool, 2, CodecKind::Fp32, &k, &v, &pos, &attn, 0)
            .unwrap();
        assert_eq!(b.codec(), CodecKind::Fp32);
        let s = pool.stats();
        assert_eq!((s.quant_blocks, s.resident_blocks), (0, 1));
        assert_eq!(b.read().k(), &k[..], "identity codec is bit-exact");
    }

    #[test]
    fn quant_spill_and_fault_keeps_encoded_payload_bit_identical() {
        let dir = crate::kvstore::testutil::TempDir::new("pool-quant-spill");
        let store = Arc::new(KvStore::open(dir.path()).unwrap());
        let pool = BlockPool::unbounded(4);
        pool.bind_store(Arc::clone(&store));
        let d = 3;
        let (k, v, pos, attn) = filled(4, d);
        let enc_bytes = CodecKind::Int8Sym.encoded_block_bytes(4, d);
        let b =
            BlockPool::alloc_quant_block(&pool, d, CodecKind::Int8Sym, &k, &v, &pos, &attn, 0)
                .unwrap();
        let before = b.encoded().expect("encoded-resident");
        let deq_before: Vec<f32> = b.read().k().to_vec();
        let (nblocks, nbytes) = pool.spill(usize::MAX);
        assert_eq!((nblocks, nbytes), (1, enc_bytes + block_bytes(4, d)));
        assert!(!b.is_resident());
        let s = pool.stats();
        assert_eq!((s.spilled_bytes, s.spilled_blocks), (enc_bytes, 1));
        assert_eq!((s.quant_bytes, s.quant_blocks, s.dq_bytes), (0, 0, 0));
        assert_eq!(s.free_blocks, 1, "the decoded cache recycled on demote");
        // fault back: the *encoded* payload round-trips bit-identically
        assert_eq!(b.read().pos(), &pos[..]);
        let after = b.encoded().expect("encoded-resident after fault");
        assert_eq!(before.data, after.data, "encoded rows are bit-identical across spill");
        assert_eq!(before.sidecar, after.sidecar, "sidecar scales are bit-identical");
        assert_eq!(b.read().k(), &deq_before[..], "so dequantized rows are too");
        let s = pool.stats();
        assert_eq!((s.quant_bytes, s.quant_blocks), (enc_bytes, 1));
        assert_eq!((s.faults, s.fault_bytes), (1, enc_bytes));
        drop(b);
        let (_, _, blocks) = store.inventory_counts();
        assert_eq!(blocks, 0, "the last handle released the store record");
    }

    #[test]
    fn decode_cache_trims_coldest_over_budget() {
        let pool = BlockPool::unbounded(2);
        let d = 2;
        let (k, v, pos, attn) = filled(2, d);
        let bytes = block_bytes(2, d);
        let b1 =
            BlockPool::alloc_quant_block(&pool, d, CodecKind::Int8Sym, &k, &v, &pos, &attn, 0)
                .unwrap();
        let b2 =
            BlockPool::alloc_quant_block(&pool, d, CodecKind::Int8Sym, &k, &v, &pos, &attn, 0)
                .unwrap();
        // budget admits exactly one decoded copy
        pool.set_decode_cache_budget(bytes);
        assert_eq!(pool.decode_cache_budget(), bytes);
        let _ = b1.read(); // decode b1 (within budget: nothing trims)
        assert_eq!(pool.stats().dq_bytes, bytes);
        let _ = b2.read(); // decode b2 (trim runs *before* the decode, so both live)
        assert_eq!(pool.stats().dq_bytes, 2 * bytes);
        let _ = b2.read(); // the next read sees the overrun and trims the coldest (b1)
        let s = pool.stats();
        assert_eq!(s.dq_bytes, bytes, "trim keeps the cache at one decoded copy");
        assert_eq!(s.quant_blocks, 2, "both blocks stay encoded-resident");
        assert!(b1.is_resident(), "trimming a decode cache never evicts the block");
        assert!(b2.is_resident());
        // b1 re-decodes transparently on its next read
        assert_eq!(b1.read().pos(), &pos[..]);
    }
}

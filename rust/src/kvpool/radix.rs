//! Radix-tree prefix cache: share identical prompt-prefix KV across
//! *sequences*, not just across turns of one session.
//!
//! LagKV's compression is attention-free and deterministic in the token
//! prefix (PAPER.md Eqs. 8–10): two requests that share a prompt prefix
//! produce bit-identical compressed KV for it, so the frozen pool blocks a
//! finished (or mid-prefill) cache holds are shareable by refcount.  The
//! tree is keyed on token ids; every stored node carries a *snapshot* — a
//! [`KvCache`] clone whose frozen prefix is shared CoW with whoever
//! produced it — that is exactly the compression state after its key's
//! tokens.  A lookup walks the tree and returns a clone of the deepest
//! snapshot whose key is a **proper** prefix of the query (at least one
//! suffix token must remain: the engine still needs last-token logits),
//! so the engine runs the backend only over the unmatched suffix.
//!
//! Three invariants make this sound:
//!
//! * **determinism** — every cacheable scorer is a pure function of the
//!   window contents (the Random policy is re-seeded per `(layer, head,
//!   start position)`), so replaying a suffix on an attached snapshot
//!   lands in the same state a cold prefill would;
//! * **monotone freezing** — `compact_layer`'s window start only advances,
//!   so a shared frozen prefix is only ever *extended*, never rewritten;
//!   blocks are immutable from birth (see [`crate::kvpool`]);
//! * **attention-freeness** — H2O's accumulated-attention statistic is
//!   path-dependent (prefill column sums vs per-step decode rows), so
//!   `needs_attention` policies bypass the tree entirely.  This is the
//!   paper's integration argument made concrete: attention-free scoring
//!   is what lets compression compose with prefix caching at all.
//!
//! Entries are the *cheapest* sheddable class: the coordinator evicts tree
//! leaves before detached sessions under pool pressure (reclaim order:
//! disk spill when a store is bound, then prefix entries, then sessions,
//! then typed rejection), and the tree
//! publishes its resident bytes to the pool's prefix-sheddable gauge so
//! the router's `hard_pressure` pre-queue check never rejects on bytes a
//! shed could reclaim.
//!
//! Byte accounting note: an entry's `bytes` is its cache's
//! [`KvCache::exact_bytes`], which counts shared frozen blocks once *per
//! referencing cache* — the same convention the session store uses.  The
//! pool's `resident_blocks` stays the deduplicated truth.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::config::{CompressionConfig, PolicyKind, ScorerBackend};
use crate::kvcache::KvCache;
use crate::kvstore::KvStore;
use crate::util::json::{self, Json};

use super::BlockPool;

/// Prefix-cache knobs (`--prefix-cache` enables the defaults).
#[derive(Debug, Clone)]
pub struct PrefixConfig {
    /// Max stored snapshots (LRU eviction beyond; 0 disables the cache).
    pub max_entries: usize,
    /// Resident-byte cap across entries (0 = uncapped; pool pressure still
    /// sheds entries LRU-first regardless).
    pub max_bytes: usize,
    /// Snapshot cadence during cold prefill, in tokens: a snapshot is
    /// inserted every `stride` prompt tokens so later requests can attach
    /// at *shared-prefix* depths, not only at whole stored prompts.
    pub stride: usize,
}

impl Default for PrefixConfig {
    fn default() -> Self {
        PrefixConfig { max_entries: 128, max_bytes: 0, stride: 64 }
    }
}

/// Point-in-time prefix-cache gauges (see `metrics::PoolGauges`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrefixStats {
    /// Stored snapshots right now.
    pub entries: usize,
    /// Sum of entry byte costs (per-cache accounting; see module docs).
    pub resident_bytes: usize,
    /// Lookups that attached a snapshot.
    pub hits: u64,
    /// Cacheable lookups that found no usable prefix.
    pub misses: u64,
    /// Snapshots ever inserted (including refreshed keys).
    pub inserts: u64,
    /// Entries evicted (caps or memory-pressure shedding).
    pub shed: u64,
    /// Cumulative bytes served from attached snapshots.
    pub reused_bytes: u64,
    /// Cumulative prompt tokens served from attached snapshots.
    pub reused_tokens: u64,
}

/// Compression knobs that must agree for two caches to be bit-compatible.
/// Seed participates only for the seeded policy (Random); deterministic
/// policies share one tree across seeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct Fingerprint {
    policy: PolicyKind,
    sink: usize,
    lag: usize,
    ratio_bits: u64,
    skip_layers: usize,
    scorer: ScorerBackend,
    seed: u64,
}

struct Entry {
    cache: KvCache,
    bytes: usize,
    last_used: u64,
    /// Journal id of this snapshot's descriptor in the bound store
    /// (0 = not journaled).  Eviction must remove the record, or replay
    /// would resurrect an entry the tree already let go of.
    pid: u64,
}

struct Edge {
    label: Vec<i32>,
    node: Node,
}

#[derive(Default)]
struct Node {
    entry: Option<Entry>,
    children: Vec<Edge>,
}

#[derive(Default)]
struct Counters {
    hits: u64,
    misses: u64,
    inserts: u64,
    shed: u64,
    reused_bytes: u64,
    reused_tokens: u64,
}

#[derive(Default)]
struct Inner {
    trees: HashMap<Fingerprint, Node>,
    /// Logical clock for LRU ordering (monotone, no wall time).
    tick: u64,
    entries: usize,
    bytes: usize,
    c: Counters,
    /// When bound, inserts persist their snapshot and evictions journal
    /// its removal (see [`PrefixCache::bind_journal`]).
    journal: Option<Arc<KvStore>>,
}

/// The per-engine prefix cache.  Interior mutex: one engine lives on one
/// coordinator thread, so contention is nil; the router only reads stats.
pub struct PrefixCache {
    cfg: PrefixConfig,
    pool: Arc<BlockPool>,
    inner: Mutex<Inner>,
}

fn common_len(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

/// Fingerprint → descriptor JSON.  `ratio` travels as its f64 value (the
/// shortest-round-trip `Display` is bit-exact through parse); `seed` as a
/// decimal string, since f64 cannot carry every u64 exactly.
fn fp_to_json(fp: &Fingerprint) -> Json {
    json::obj(vec![
        ("policy", json::s(fp.policy.name())),
        ("sink", json::n(fp.sink as f64)),
        ("lag", json::n(fp.lag as f64)),
        ("ratio", json::n(f64::from_bits(fp.ratio_bits))),
        ("skip", json::n(fp.skip_layers as f64)),
        (
            "scorer",
            json::s(match fp.scorer {
                ScorerBackend::Rust => "rust",
                ScorerBackend::Xla => "xla",
            }),
        ),
        ("seed", json::s(fp.seed.to_string())),
    ])
}

fn fp_from_json(j: &Json) -> Result<Fingerprint> {
    Ok(Fingerprint {
        policy: PolicyKind::parse(j.get("policy")?.as_str()?)?,
        sink: j.get("sink")?.as_usize()?,
        lag: j.get("lag")?.as_usize()?,
        ratio_bits: j.get("ratio")?.as_f64()?.to_bits(),
        skip_layers: j.get("skip")?.as_usize()?,
        scorer: match j.get("scorer")?.as_str()? {
            "xla" => ScorerBackend::Xla,
            _ => ScorerBackend::Rust,
        },
        seed: j.get("seed")?.as_str()?.parse()?,
    })
}

/// Returns the entry previously stored at exactly this key, if any.
fn insert_rec(node: &mut Node, rest: &[i32], entry: Entry) -> Option<Entry> {
    if rest.is_empty() {
        return node.entry.replace(entry);
    }
    let pos = node.children.iter().position(|e| e.label.first() == rest.first());
    match pos {
        None => {
            node.children.push(Edge {
                label: rest.to_vec(),
                node: Node { entry: Some(entry), children: Vec::new() },
            });
            None
        }
        Some(i) => {
            let common = common_len(&node.children[i].label, rest);
            if common == node.children[i].label.len() {
                insert_rec(&mut node.children[i].node, &rest[common..], entry)
            } else {
                // Split the edge at the divergence point.
                let edge = &mut node.children[i];
                let tail_label = edge.label.split_off(common);
                let old_node = std::mem::take(&mut edge.node);
                edge.node = Node {
                    entry: None,
                    children: vec![Edge { label: tail_label, node: old_node }],
                };
                insert_rec(&mut edge.node, &rest[common..], entry)
            }
        }
    }
}

/// Deepest entry whose key is a prefix of the query, no deeper than
/// `limit` tokens.  Entries below a node sit at `depth + label` or more,
/// so subtrees past the limit are pruned wholesale.
fn best_depth(node: &Node, rest: &[i32], depth: usize, limit: usize) -> Option<usize> {
    let mut best = if node.entry.is_some() && depth >= 1 && depth <= limit {
        Some(depth)
    } else {
        None
    };
    if let Some(edge) = node.children.iter().find(|e| e.label.first() == rest.first()) {
        let l = edge.label.len();
        if l <= rest.len() && edge.label[..] == rest[..l] && depth + l <= limit {
            if let Some(d) = best_depth(&edge.node, &rest[l..], depth + l, limit) {
                best = Some(d);
            }
        }
    }
    best
}

fn entry_at_mut<'a>(node: &'a mut Node, rest: &[i32], depth_left: usize) -> Option<&'a mut Entry> {
    if depth_left == 0 {
        return node.entry.as_mut();
    }
    let i = node.children.iter().position(|e| e.label.first() == rest.first())?;
    let l = node.children[i].label.len();
    if l > depth_left {
        return None;
    }
    entry_at_mut(&mut node.children[i].node, &rest[l..], depth_left - l)
}

fn remove_rec(node: &mut Node, rest: &[i32]) -> Option<Entry> {
    if rest.is_empty() {
        return node.entry.take();
    }
    let i = node.children.iter().position(|e| e.label.first() == rest.first())?;
    let l = node.children[i].label.len();
    if l > rest.len() || node.children[i].label[..] != rest[..l] {
        return None;
    }
    let removed = remove_rec(&mut node.children[i].node, &rest[l..])?;
    // Prune an emptied child; merge a single-child pass-through node back
    // into its edge so the tree stays a proper radix tree.
    let child = &mut node.children[i];
    if child.node.entry.is_none() {
        match child.node.children.len() {
            0 => {
                node.children.swap_remove(i);
            }
            1 => {
                let g = child.node.children.pop().expect("one child");
                child.label.extend_from_slice(&g.label);
                child.node = g.node;
            }
            _ => {}
        }
    }
    Some(removed)
}

fn lru_scan(node: &Node, path: &mut Vec<i32>, best: &mut Option<(u64, Vec<i32>)>) {
    if let Some(e) = &node.entry {
        let older = match best {
            Some((t, _)) => e.last_used < *t,
            None => true,
        };
        if older {
            *best = Some((e.last_used, path.clone()));
        }
    }
    for edge in &node.children {
        let n = path.len();
        path.extend_from_slice(&edge.label);
        lru_scan(&edge.node, path, best);
        path.truncate(n);
    }
}

impl PrefixCache {
    pub fn new(cfg: PrefixConfig, pool: Arc<BlockPool>) -> Arc<PrefixCache> {
        Arc::new(PrefixCache { cfg, pool, inner: Mutex::new(Inner::default()) })
    }

    pub fn config(&self) -> &PrefixConfig {
        &self.cfg
    }

    /// Bind the durability journal: from now on inserts persist their
    /// snapshot (descriptor = cache + key ids + fingerprint) and every
    /// eviction — cap, supersede, pressure shed — journals its removal.
    pub fn bind_journal(&self, store: Arc<KvStore>) {
        self.inner.lock().unwrap().journal = Some(store);
    }

    /// Persist + journal one snapshot; returns its journal id (0 when no
    /// journal is bound or the write failed — serving continues either way).
    fn journal_insert(
        journal: &Option<Arc<KvStore>>,
        fp: &Fingerprint,
        ids: &[i32],
        cache: &KvCache,
    ) -> u64 {
        let Some(store) = journal else { return 0 };
        match cache.persist(store) {
            Ok(mut desc) => {
                if let Json::Obj(map) = &mut desc {
                    map.insert(
                        "ids".to_string(),
                        json::arr(ids.iter().map(|&t| json::n(t as f64)).collect()),
                    );
                    map.insert("fp".to_string(), fp_to_json(fp));
                }
                match store.journal_prefix_put(desc) {
                    Ok(pid) => pid,
                    Err(e) => {
                        eprintln!("prefix-cache: failed to journal snapshot: {e:#}");
                        0
                    }
                }
            }
            Err(e) => {
                eprintln!("prefix-cache: failed to persist snapshot: {e:#}");
                0
            }
        }
    }

    fn journal_remove_pid(journal: &Option<Arc<KvStore>>, pid: u64) {
        if pid == 0 {
            return;
        }
        if let Some(store) = journal {
            if let Err(e) = store.journal_prefix_remove(pid) {
                eprintln!("prefix-cache: failed to journal snapshot removal: {e:#}");
            }
        }
    }

    /// Whether this compression config may use the tree at all.
    /// Attention-fed policies are path-dependent and always bypass.
    pub fn cacheable(&self, cfg: &CompressionConfig) -> bool {
        self.cfg.max_entries > 0 && !cfg.policy.needs_attention()
    }

    fn fingerprint(&self, cfg: &CompressionConfig, seed: u64) -> Option<Fingerprint> {
        if !self.cacheable(cfg) {
            return None;
        }
        Some(Fingerprint {
            policy: cfg.policy,
            sink: cfg.sink,
            lag: cfg.lag,
            ratio_bits: cfg.ratio.to_bits(),
            skip_layers: cfg.skip_layers,
            scorer: cfg.scorer,
            seed: if cfg.policy == PolicyKind::Random { seed } else { 0 },
        })
    }

    /// Attach the deepest stored snapshot whose key is a proper prefix of
    /// `ids`.  Returns the cloned cache (CoW: frozen blocks shared by
    /// refcount) and the matched depth; the caller prefills `ids[depth..]`.
    pub fn lookup(
        &self,
        cfg: &CompressionConfig,
        seed: u64,
        ids: &[i32],
    ) -> Option<(KvCache, usize)> {
        let fp = self.fingerprint(cfg, seed)?;
        let limit = ids.len().checked_sub(1)?;
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let depth = inner.trees.get(&fp).and_then(|root| best_depth(root, ids, 0, limit));
        let Some(depth) = depth else {
            inner.c.misses += 1;
            return None;
        };
        let (cache, bytes) = {
            let entry = inner
                .trees
                .get_mut(&fp)
                .and_then(|root| entry_at_mut(root, ids, depth))
                .expect("entry at matched depth");
            entry.last_used = tick;
            (entry.cache.clone(), entry.bytes)
        };
        inner.c.hits += 1;
        inner.c.reused_bytes += bytes as u64;
        inner.c.reused_tokens += depth as u64;
        Some((cache, depth))
    }

    /// Store (or refresh) the snapshot for exactly `ids`.  The cache is
    /// cloned — frozen blocks shared, loose tail copied — and the clone's
    /// *stable* loose prefix is then frozen into pool blocks, so every
    /// later attach of this snapshot shares those rows CoW instead of
    /// re-copying them (without this, a `PolicyKind::None` snapshot —
    /// which never compacts and therefore never freezes — deep-copies its
    /// entire store into every clone).  Stable means rows no future
    /// scoring window can start below: everything under the layer's
    /// boundary (partition windows start at `boundary.max(sink)`,
    /// monotone), or the whole layer when the driver never compacts it
    /// (no-compression policy, skipped layers).  The caller keeps using
    /// its own cache untouched.  No-ops for uncacheable configs, empty
    /// keys, and single entries that alone bust the byte cap.
    pub fn insert(&self, cfg: &CompressionConfig, seed: u64, ids: &[i32], cache: &KvCache) {
        let Some(fp) = self.fingerprint(cfg, seed) else { return };
        if ids.is_empty() {
            return;
        }
        // Cheap reject before the clone + freeze work: freezing never
        // shrinks a cache's byte cost (block rounding + the duplicated
        // frozen-attn side array only add), so an already-over-cap cache
        // can never become storable.
        if self.cfg.max_bytes > 0 && cache.exact_bytes() > self.cfg.max_bytes {
            return;
        }
        let mut snapshot = cache.clone();
        for layer in 0..snapshot.n_layers {
            let never_compacted =
                cfg.policy == PolicyKind::None || layer < cfg.skip_layers;
            let upto = if never_compacted {
                snapshot.len(layer)
            } else {
                snapshot.layers[layer].boundary
            };
            snapshot.freeze_layer_prefix(layer, upto);
        }
        let bytes = snapshot.exact_bytes();
        if self.cfg.max_bytes > 0 && bytes > self.cfg.max_bytes {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let pid = Self::journal_insert(&inner.journal, &fp, ids, &snapshot);
        let entry = Entry { cache: snapshot, bytes, last_used: inner.tick, pid };
        let replaced = insert_rec(inner.trees.entry(fp).or_default(), ids, entry);
        match replaced {
            Some(old) => {
                Self::journal_remove_pid(&inner.journal, old.pid);
                inner.bytes = inner.bytes - old.bytes + bytes;
            }
            None => {
                inner.entries += 1;
                inner.bytes += bytes;
            }
        }
        inner.c.inserts += 1;
        while inner.entries > self.cfg.max_entries
            || (self.cfg.max_bytes > 0 && inner.bytes > self.cfg.max_bytes)
        {
            if Self::shed_lru_locked(&mut inner).is_none() {
                break;
            }
        }
        self.publish(&inner);
    }

    /// Insert a snapshot rebuilt from the journal at boot.  The key ids
    /// and fingerprint come from the descriptor itself; `pid` is the
    /// existing journal id (no re-journal, no freeze pass — the restored
    /// cache is already block-backed).  Caps still apply: an over-cap
    /// restore sheds LRU entries, journaling their removals.
    pub fn restore(&self, desc: &Json, cache: KvCache, pid: u64) -> Result<()> {
        let fp = fp_from_json(desc.get("fp")?)?;
        let ids_json = desc.get("ids")?.as_arr()?;
        let mut ids = Vec::with_capacity(ids_json.len());
        for j in ids_json {
            ids.push(j.as_i64()? as i32);
        }
        if ids.is_empty() {
            bail!("restored snapshot has an empty key");
        }
        let bytes = cache.exact_bytes();
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let entry = Entry { cache, bytes, last_used: inner.tick, pid };
        let replaced = insert_rec(inner.trees.entry(fp).or_default(), &ids, entry);
        match replaced {
            Some(old) => {
                Self::journal_remove_pid(&inner.journal, old.pid);
                inner.bytes = inner.bytes - old.bytes + bytes;
            }
            None => {
                inner.entries += 1;
                inner.bytes += bytes;
            }
        }
        while inner.entries > self.cfg.max_entries
            || (self.cfg.max_bytes > 0 && inner.bytes > self.cfg.max_bytes)
        {
            if Self::shed_lru_locked(&mut inner).is_none() {
                break;
            }
        }
        self.publish(&inner);
        Ok(())
    }

    /// Evict the least-recently-used snapshot (memory-pressure shedding).
    /// Returns the bytes it freed.
    pub fn shed_lru(&self) -> Option<usize> {
        let mut inner = self.inner.lock().unwrap();
        let freed = Self::shed_lru_locked(&mut inner);
        self.publish(&inner);
        freed
    }

    fn shed_lru_locked(inner: &mut Inner) -> Option<usize> {
        let mut best: Option<(u64, Fingerprint, Vec<i32>)> = None;
        for (fp, root) in &inner.trees {
            let mut path = Vec::new();
            let mut b = None;
            lru_scan(root, &mut path, &mut b);
            if let Some((t, p)) = b {
                let older = match &best {
                    Some((bt, _, _)) => t < *bt,
                    None => true,
                };
                if older {
                    best = Some((t, *fp, p));
                }
            }
        }
        let (_, fp, path) = best?;
        let removed = remove_rec(inner.trees.get_mut(&fp)?, &path)?;
        Self::journal_remove_pid(&inner.journal, removed.pid);
        let empty = inner
            .trees
            .get(&fp)
            .map(|r| r.entry.is_none() && r.children.is_empty())
            .unwrap_or(false);
        if empty {
            inner.trees.remove(&fp);
        }
        inner.entries -= 1;
        inner.bytes -= removed.bytes;
        inner.c.shed += 1;
        Some(removed.bytes)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sum of entry byte costs (the sheddable-class gauge).
    pub fn total_bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    pub fn stats(&self) -> PrefixStats {
        let inner = self.inner.lock().unwrap();
        PrefixStats {
            entries: inner.entries,
            resident_bytes: inner.bytes,
            hits: inner.c.hits,
            misses: inner.c.misses,
            inserts: inner.c.inserts,
            shed: inner.c.shed,
            reused_bytes: inner.c.reused_bytes,
            reused_tokens: inner.c.reused_tokens,
        }
    }

    /// Keep the pool's prefix-sheddable gauge (read by the router's cheap
    /// pre-queue pressure check) in step with the tree on every mutation.
    fn publish(&self, inner: &Inner) {
        self.pool.set_prefix_sheddable(inner.bytes);
    }
}

impl Drop for PrefixCache {
    fn drop(&mut self) {
        self.pool.set_prefix_sheddable(0);
    }
}

impl std::fmt::Debug for PrefixCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("PrefixCache")
            .field("entries", &s.entries)
            .field("resident_bytes", &s.resident_bytes)
            .field("hits", &s.hits)
            .field("misses", &s.misses)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache_with_rows(pool: &Arc<BlockPool>, n: usize) -> KvCache {
        let mut c = KvCache::new_in(Arc::clone(pool), 1, 1, 2);
        for t in 0..n {
            c.append_token(&[t as f32, 0.0], &[0.0, t as f32], t as i32).unwrap();
        }
        c
    }

    fn lag_cfg() -> CompressionConfig {
        CompressionConfig::default()
    }

    fn pc(max_entries: usize, max_bytes: usize) -> (Arc<BlockPool>, Arc<PrefixCache>) {
        let pool = BlockPool::unbounded(4);
        let cache =
            PrefixCache::new(PrefixConfig { max_entries, max_bytes, stride: 8 }, pool.clone());
        (pool, cache)
    }

    #[test]
    fn longest_proper_prefix_wins() {
        let (pool, pc) = pc(16, 0);
        let cfg = lag_cfg();
        pc.insert(&cfg, 0, &[1, 2], &cache_with_rows(&pool, 2));
        pc.insert(&cfg, 0, &[1, 2, 3, 4], &cache_with_rows(&pool, 4));
        pc.insert(&cfg, 0, &[1, 2, 9], &cache_with_rows(&pool, 3));
        // deepest stored prefix of [1,2,3,4,5] is [1,2,3,4]
        let (cache, depth) = pc.lookup(&cfg, 0, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(depth, 4);
        assert_eq!(cache.appended, 4);
        // an exact key never matches itself whole: one suffix token must
        // remain, so [1,2,3,4] falls back to the [1,2] snapshot
        let (_, depth) = pc.lookup(&cfg, 0, &[1, 2, 3, 4]).unwrap();
        assert_eq!(depth, 2);
        // diverging path uses the shared prefix only
        let (_, depth) = pc.lookup(&cfg, 0, &[1, 2, 9, 9]).unwrap();
        assert_eq!(depth, 3);
        assert!(pc.lookup(&cfg, 0, &[7, 7]).is_none(), "disjoint key misses");
        let s = pc.stats();
        assert_eq!(s.hits, 3);
        assert_eq!(s.misses, 1);
        assert_eq!(s.reused_tokens, 4 + 2 + 3);
    }

    #[test]
    fn edge_split_keeps_all_entries_reachable() {
        let (pool, pc) = pc(16, 0);
        let cfg = lag_cfg();
        // insert a long run first, then force a split inside its edge
        pc.insert(&cfg, 0, &[5, 6, 7, 8, 9], &cache_with_rows(&pool, 5));
        pc.insert(&cfg, 0, &[5, 6, 1], &cache_with_rows(&pool, 3));
        pc.insert(&cfg, 0, &[5, 6], &cache_with_rows(&pool, 2));
        assert_eq!(pc.len(), 3);
        let (_, d) = pc.lookup(&cfg, 0, &[5, 6, 7, 8, 9, 9]).unwrap();
        assert_eq!(d, 5);
        let (_, d) = pc.lookup(&cfg, 0, &[5, 6, 1, 1]).unwrap();
        assert_eq!(d, 3);
        let (_, d) = pc.lookup(&cfg, 0, &[5, 6, 2]).unwrap();
        assert_eq!(d, 2, "split point snapshot serves the diverging branch");
    }

    #[test]
    fn lru_caps_and_shed_reconcile_bytes() {
        let (pool, pc) = pc(2, 0);
        let cfg = lag_cfg();
        pc.insert(&cfg, 0, &[1], &cache_with_rows(&pool, 1));
        pc.insert(&cfg, 0, &[2], &cache_with_rows(&pool, 1));
        // refresh [1] so [2] is the LRU victim of the cap
        assert!(pc.lookup(&cfg, 0, &[1, 9]).is_some());
        pc.insert(&cfg, 0, &[3], &cache_with_rows(&pool, 1));
        assert_eq!(pc.len(), 2);
        assert!(pc.lookup(&cfg, 0, &[2, 9]).is_none(), "LRU entry evicted");
        assert!(pc.lookup(&cfg, 0, &[3, 9]).is_some());
        let before = pc.total_bytes();
        let freed = pc.shed_lru().unwrap();
        assert_eq!(pc.total_bytes() + freed, before);
        assert_eq!(pc.len(), 1);
        pc.shed_lru().unwrap();
        assert!(pc.shed_lru().is_none(), "empty tree has nothing to shed");
        assert_eq!(pc.total_bytes(), 0);
        assert_eq!(pool.sheddable_bytes(), 0, "gauge published on every mutation");
    }

    #[test]
    fn byte_cap_evicts_and_oversized_entry_is_skipped() {
        let pool = BlockPool::unbounded(4);
        let one = cache_with_rows(&pool, 2).exact_bytes();
        let pc = PrefixCache::new(
            PrefixConfig { max_entries: 16, max_bytes: 2 * one, stride: 8 },
            pool.clone(),
        );
        let cfg = lag_cfg();
        pc.insert(&cfg, 0, &[1], &cache_with_rows(&pool, 2));
        pc.insert(&cfg, 0, &[2], &cache_with_rows(&pool, 2));
        assert_eq!(pc.len(), 2);
        pc.insert(&cfg, 0, &[3], &cache_with_rows(&pool, 2));
        assert_eq!(pc.len(), 2, "byte cap sheds the LRU entry");
        assert!(pc.total_bytes() <= 2 * one);
        pc.insert(&cfg, 0, &[4], &cache_with_rows(&pool, 20));
        assert!(
            pc.lookup(&cfg, 0, &[4, 9]).is_none(),
            "an entry that alone busts the cap is never stored"
        );
    }

    #[test]
    fn fingerprint_separates_configs_and_h2o_bypasses() {
        let (pool, pc) = pc(16, 0);
        let a = lag_cfg();
        let b = CompressionConfig { lag: 32, ..lag_cfg() };
        pc.insert(&a, 0, &[1, 2, 3], &cache_with_rows(&pool, 3));
        assert!(pc.lookup(&b, 0, &[1, 2, 3, 4]).is_none(), "different lag never matches");
        assert!(pc.lookup(&a, 0, &[1, 2, 3, 4]).is_some());
        // seeded policy: seed is part of the key
        let r = CompressionConfig { policy: PolicyKind::Random, ..lag_cfg() };
        pc.insert(&r, 7, &[1, 2, 3], &cache_with_rows(&pool, 3));
        assert!(pc.lookup(&r, 8, &[1, 2, 3, 4]).is_none(), "other seed never matches");
        assert!(pc.lookup(&r, 7, &[1, 2, 3, 4]).is_some());
        // attention-fed policies bypass entirely (path-dependent statistic)
        let h = CompressionConfig { policy: PolicyKind::H2O, ..lag_cfg() };
        assert!(!pc.cacheable(&h));
        pc.insert(&h, 0, &[9, 9, 9], &cache_with_rows(&pool, 3));
        assert!(pc.lookup(&h, 0, &[9, 9, 9, 9]).is_none());
        let misses_before = pc.stats().misses;
        let _ = pc.lookup(&h, 0, &[9, 9, 9, 9]);
        assert_eq!(pc.stats().misses, misses_before, "bypass is not a miss");
    }

    /// ROADMAP §8 follow-up: a `PolicyKind::None` cache never compacts, so
    /// before tail-freezing its snapshots were all-loose — every attach
    /// deep-copied the whole store.  Insert now freezes the stable loose
    /// prefix into blocks, so attaches share CoW like compressed entries.
    #[test]
    fn none_policy_snapshots_freeze_tails_and_share_cow() {
        let pool = BlockPool::unbounded(4);
        let pc = PrefixCache::new(PrefixConfig::default(), pool.clone());
        let cfg = CompressionConfig { policy: PolicyKind::None, ..CompressionConfig::default() };
        let c = cache_with_rows(&pool, 18); // never compacted: zero frozen blocks
        assert_eq!(c.frozen_blocks(), 0);
        let key: Vec<i32> = (0..18).collect();
        pc.insert(&cfg, 0, &key, &c);
        // the stored snapshot froze 16 of its 18 rows into 4 blocks...
        assert_eq!(pool.stats().resident_blocks, 4);
        let blocks_before = pool.stats().resident_blocks;
        let (attached, depth) = pc.lookup(&cfg, 0, &[key.clone(), vec![99]].concat()).unwrap();
        // ...and an attach shares them by refcount instead of copying
        assert_eq!(depth, 18);
        assert_eq!(attached.frozen_blocks(), 4);
        assert_eq!(pool.stats().resident_blocks, blocks_before, "attach is CoW");
        // reads are unchanged: the attached clone equals the original
        assert_eq!(attached.head_k(0, 0), c.head_k(0, 0));
        assert_eq!(attached.positions(0, 0), c.positions(0, 0));
        // the original cache is untouched (freezing happened on the clone)
        assert_eq!(c.frozen_blocks(), 0);
        // skipped layers freeze fully too (never compacted by the driver)
        let skip = CompressionConfig { skip_layers: 1, ..CompressionConfig::default() };
        let pc2 = PrefixCache::new(PrefixConfig::default(), pool.clone());
        pc2.insert(&skip, 0, &key, &c);
        let (att2, _) = pc2.lookup(&skip, 0, &[key.clone(), vec![7]].concat()).unwrap();
        assert!(att2.frozen_blocks() > 0, "skip-layer snapshot must freeze its tail");
    }

    /// Journal round trip: inserts journal descriptors, supersede and
    /// shed journal removals, and a restored snapshot serves lookups
    /// bit-identically under the same fingerprint.
    #[test]
    fn journaled_snapshots_survive_restart_and_evictions_do_not() {
        use crate::kvstore::{testutil::TempDir, KvStore};
        let dir = TempDir::new("radix-journal");
        let cfg = lag_cfg();
        let key: Vec<i32> = (0..12).collect();
        {
            let kv = Arc::new(KvStore::open(dir.path()).unwrap());
            let (pool, pc) = pc(16, 0);
            pool.bind_store(Arc::clone(&kv));
            pc.bind_journal(Arc::clone(&kv));
            pc.insert(&cfg, 0, &key, &cache_with_rows(&pool, 12));
            assert_eq!(kv.inventory_counts().1, 1, "insert journals the snapshot");
            // refreshing the same key supersedes, never leaks
            pc.insert(&cfg, 0, &key, &cache_with_rows(&pool, 12));
            assert_eq!(kv.inventory_counts().1, 1);
            // a second key, then shed it: its record must go too
            pc.insert(&cfg, 0, &[9, 9], &cache_with_rows(&pool, 2));
            assert_eq!(kv.inventory_counts().1, 2);
            let probe = [key.clone(), vec![55]].concat();
            assert!(pc.lookup(&cfg, 0, &probe).is_some(), "refresh the long key's LRU stamp");
            pc.shed_lru().unwrap(); // sheds [9,9]
            assert_eq!(kv.inventory_counts().1, 1, "shed journaled its removal");
            kv.checkpoint().unwrap();
        }
        let kv = Arc::new(KvStore::open(dir.path()).unwrap());
        let (pool2, pc2) = pc(16, 0);
        pool2.bind_store(Arc::clone(&kv));
        pc2.bind_journal(Arc::clone(&kv));
        let mut handles = std::collections::HashMap::new();
        let boot = kv.boot_prefixes();
        assert_eq!(boot.len(), 1, "only the surviving snapshot replays");
        for (pid, desc) in boot {
            let cache = KvCache::restore(&pool2, &kv, &desc, &mut handles).unwrap();
            pc2.restore(&desc, cache, pid).unwrap();
        }
        assert_eq!(pc2.len(), 1);
        let (attached, depth) = pc2.lookup(&cfg, 0, &[key.clone(), vec![99]].concat()).unwrap();
        assert_eq!(depth, 12);
        assert_eq!(attached.appended, 12);
        // restored snapshot reads back the original payload
        let expect = cache_with_rows(&BlockPool::unbounded(4), 12);
        assert_eq!(attached.head_k(0, 0), expect.head_k(0, 0));
        // shedding the restored entry unwinds the journal completely
        drop(attached);
        pc2.shed_lru().unwrap();
        assert_eq!(kv.inventory_counts(), (0, 0, 0));
    }

    #[test]
    fn snapshots_share_blocks_and_publish_sheddable() {
        let pool = BlockPool::unbounded(4);
        let pc = PrefixCache::new(PrefixConfig::default(), pool.clone());
        let cfg = lag_cfg();
        let mut c = cache_with_rows(&pool, 16);
        // freeze rows [0, 8) so the snapshot has blocks to share
        c.compact_layer(0, 8, 4, &[vec![0, 1]]).unwrap();
        assert!(c.frozen_blocks() > 0);
        let blocks_before = pool.stats().resident_blocks;
        pc.insert(&cfg, 0, &[1, 2, 3, 4], &c);
        assert_eq!(
            pool.stats().resident_blocks,
            blocks_before,
            "a snapshot shares frozen blocks, never copies them"
        );
        assert_eq!(pool.sheddable_bytes(), pc.total_bytes());
        let (attached, depth) = pc.lookup(&cfg, 0, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(depth, 4);
        assert_eq!(pool.stats().resident_blocks, blocks_before, "attach is CoW too");
        assert_eq!(attached.head_k(0, 0), c.head_k(0, 0));
        drop(attached);
        drop(c);
        pc.shed_lru().unwrap();
        assert_eq!(pool.stats().resident_blocks, 0, "all blocks recycled");
    }
}

//! Pool accounting: exact byte ledgers and the typed exhaustion error.

use std::fmt;

/// Point-in-time snapshot of a [`BlockPool`]'s byte ledger.  Every number
/// is exact (maintained transactionally under the pool lock), so serving
/// layers can budget admission on it instead of estimating.
///
/// [`BlockPool`]: super::BlockPool
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolStats {
    /// Payload bytes held in live (referenced) blocks.
    pub block_bytes: usize,
    /// Bytes in the contiguous per-head tail regions registered by caches
    /// via [`LooseGauge`] (rows not yet frozen into blocks).
    ///
    /// [`LooseGauge`]: super::LooseGauge
    pub loose_bytes: usize,
    /// Bytes parked in the free list: recycled block buffers awaiting
    /// reuse.  Not resident data, but still allocated from the OS.
    pub free_bytes: usize,
    /// Highest `resident_bytes()` ever observed.
    pub high_water_bytes: usize,
    /// Count of live blocks (each counted once however many caches share
    /// it — this is true resident memory, not the sum of references).
    pub resident_blocks: usize,
    /// Count of recycled buffers in the free list.
    pub free_blocks: usize,
    /// Payload bytes demoted to the disk tier (`kvstore`): referenced by
    /// live handles but not resident, and not counted against the budget.
    pub spilled_bytes: usize,
    /// Count of live blocks currently on the disk tier.
    pub spilled_blocks: usize,
    /// Cumulative count of block fault-ins (disk → pool).  Monotone:
    /// spill gauges move both ways as blocks demote and return, but every
    /// fault-in is a request-path disk read worth seeing.
    pub faults: u64,
    /// Cumulative payload bytes faulted back in.
    pub fault_bytes: usize,
    /// Encoded bytes held by quantized (encoded-resident) blocks, in
    /// exact `CodecKind::encoded_block_bytes` units — data plus sidecar
    /// plus side arrays.
    pub quant_bytes: usize,
    /// Count of live quantized blocks resident in encoded form.
    pub quant_blocks: usize,
    /// Bytes in the decoded-row cache: fp32 copies of encoded blocks,
    /// counted in full `block_bytes` units, trimmed LRU against the
    /// pool's decode-cache budget.
    pub dq_bytes: usize,
    /// The byte budget, when the pool is budgeted.
    pub budget: Option<usize>,
}

impl PoolStats {
    /// Live data bytes: blocks (fp32 and encoded) plus decoded caches
    /// plus registered loose regions.
    pub fn resident_bytes(&self) -> usize {
        self.block_bytes + self.loose_bytes + self.quant_bytes + self.dq_bytes
    }

    /// Fraction of the pool's total allocation sitting idle in the free
    /// list (0.0 = every allocated byte serves live data).
    pub fn fragmentation(&self) -> f64 {
        let total = self.resident_bytes() + self.free_bytes;
        if total == 0 {
            0.0
        } else {
            self.free_bytes as f64 / total as f64
        }
    }
}

/// Typed allocation failure: the pool's byte budget cannot fit another
/// block.  Carried through `anyhow` by the blanket `std::error::Error`
/// conversion; the serving layer maps admission-time exhaustion to the
/// wire error code `pool-exhausted`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted {
    /// Bytes the failed allocation needed.
    pub needed: usize,
    /// Resident bytes at the time of the failure.
    pub resident: usize,
    /// The pool's configured budget.
    pub budget: usize,
}

impl fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "pool-exhausted: {} more bytes needed with {} resident of a {}-byte budget",
            self.needed, self.resident, self.budget
        )
    }
}

impl std::error::Error for PoolExhausted {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resident_and_fragmentation() {
        let s = PoolStats {
            block_bytes: 600,
            loose_bytes: 200,
            free_bytes: 200,
            high_water_bytes: 1000,
            resident_blocks: 3,
            free_blocks: 1,
            spilled_bytes: 4096,
            spilled_blocks: 2,
            faults: 1,
            fault_bytes: 2048,
            quant_bytes: 0,
            quant_blocks: 0,
            dq_bytes: 0,
            budget: Some(2000),
        };
        assert_eq!(s.resident_bytes(), 800, "spilled bytes are not resident");
        assert!((s.fragmentation() - 0.2).abs() < 1e-12);
        let quant = PoolStats { quant_bytes: 100, dq_bytes: 50, quant_blocks: 1, ..s };
        assert_eq!(quant.resident_bytes(), 950, "encoded and decoded bytes are resident");
        let empty = PoolStats {
            block_bytes: 0,
            loose_bytes: 0,
            free_bytes: 0,
            high_water_bytes: 0,
            resident_blocks: 0,
            free_blocks: 0,
            spilled_bytes: 0,
            spilled_blocks: 0,
            faults: 0,
            fault_bytes: 0,
            quant_bytes: 0,
            quant_blocks: 0,
            dq_bytes: 0,
            budget: None,
        };
        assert_eq!(empty.fragmentation(), 0.0);
    }

    #[test]
    fn exhausted_error_is_typed_and_prefixed() {
        let e = PoolExhausted { needed: 64, resident: 960, budget: 1024 };
        let msg = e.to_string();
        assert!(msg.starts_with("pool-exhausted:"), "stable prefix: {msg}");
        assert!(msg.contains("64") && msg.contains("960") && msg.contains("1024"));
        // converts into anyhow::Error via the std::error::Error blanket impl
        let any: anyhow::Error = e.into();
        assert!(format!("{any:#}").contains("pool-exhausted"));
    }
}

//! Record heap: variable-length byte records over the buffer pool.
//!
//! A record's *head fragment* lives in a slot of a [`PageKind::Slotted`]
//! page; payloads larger than the fragment spill across a chain of
//! [`PageKind::Overflow`] pages linked by the page header's `next`
//! pointer.  Head fragment format:
//!
//! ```text
//! [total_len u32][first_overflow u32][fragment bytes...]
//! ```
//!
//! A [`RecordId`] is `(page, slot)` of the head fragment — stable for the
//! record's lifetime because slot deletion compacts payloads without
//! renumbering slots.  Encoded as `page << 16 | slot` where it crosses a
//! serialization boundary (WAL records, cache descriptors).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::buffer::BufferPool;
use super::page::{PageKind, OVERFLOW_CAP, PAGE_SIZE};

/// Head-fragment prefix: total_len + first_overflow.
const HEAD_PREFIX: usize = 8;
/// Don't start a head fragment in a page with less room than this —
/// a tiny fragment wastes a slot and pushes everything to overflow.
const MIN_HEAD_FRAG: usize = 64;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RecordId {
    pub page: u32,
    pub slot: u16,
}

impl RecordId {
    pub fn to_u64(self) -> u64 {
        (self.page as u64) << 16 | self.slot as u64
    }

    pub fn from_u64(v: u64) -> RecordId {
        RecordId { page: (v >> 16) as u32, slot: (v & 0xffff) as u16 }
    }
}

pub struct RecordHeap {
    pool: BufferPool,
    /// Free bytes per slotted page (insert candidates), rebuilt at open.
    space: BTreeMap<u32, usize>,
}

impl RecordHeap {
    /// Wrap a buffer pool, scanning existing slotted pages to rebuild the
    /// free-space map.
    pub fn open(mut pool: BufferPool) -> Result<RecordHeap> {
        let mut space = BTreeMap::new();
        for id in 0..pool.num_pages() {
            let f = pool.fetch(id)?;
            let (kind, free) = (pool.page(f).kind(), pool.page(f).free_space());
            pool.unpin(f);
            if kind == Some(PageKind::Slotted) {
                space.insert(id, free);
            }
        }
        Ok(RecordHeap { pool, space })
    }

    pub fn num_pages(&self) -> u32 {
        self.pool.num_pages()
    }

    /// Bytes of the page file occupied by in-use pages (total minus the
    /// free list).  The store's disk-tier byte cap is enforced against
    /// this: the file itself never shrinks, but evicting cold inventory
    /// returns pages to the free list, which new writes reuse instead of
    /// growing the file.
    pub fn used_bytes(&self) -> usize {
        let disk = self.pool.disk();
        (disk.num_pages() as usize).saturating_sub(disk.free_pages()) * crate::kvstore::page::PAGE_SIZE
    }

    /// Every live record id (head fragments), for reachability sweeps.
    pub fn live_records(&mut self) -> Result<Vec<RecordId>> {
        let mut out = Vec::new();
        let pages: Vec<u32> = self.space.keys().copied().collect();
        for id in pages {
            let f = self.pool.fetch(id)?;
            for slot in 0..self.pool.page(f).n_slots() {
                if self.pool.page(f).read_slot(slot).is_some() {
                    out.push(RecordId { page: id, slot });
                }
            }
            self.pool.unpin(f);
        }
        Ok(out)
    }

    /// Store a record; returns its id.
    pub fn put(&mut self, data: &[u8]) -> Result<RecordId> {
        if data.is_empty() {
            bail!("empty records are not stored");
        }
        // choose a head page: first slotted page whose free space fits a
        // useful fragment, else a fresh page
        let want = HEAD_PREFIX + data.len().min(MIN_HEAD_FRAG);
        let head_page = self
            .space
            .iter()
            .find(|(_, &free)| free >= want)
            .map(|(&id, _)| id);
        let (head_page, head_frame) = match head_page {
            Some(id) => (id, self.pool.fetch(id)?),
            None => {
                let (id, f) = self.pool.create(PageKind::Slotted)?;
                (id, f)
            }
        };
        let frag_cap = self.pool.page(head_frame).free_space().saturating_sub(HEAD_PREFIX);
        let frag_len = data.len().min(frag_cap);
        // build the overflow chain for the remainder first, so the head
        // fragment can point at its first page
        let first_overflow = self.write_chain(&data[frag_len..])?;
        let mut head = Vec::with_capacity(HEAD_PREFIX + frag_len);
        head.extend_from_slice(&(data.len() as u32).to_le_bytes());
        head.extend_from_slice(&first_overflow.to_le_bytes());
        head.extend_from_slice(&data[..frag_len]);
        let slot = self
            .pool
            .page_mut(head_frame)
            .insert(&head)
            .expect("free_space guaranteed the head fragment fits");
        let free = self.pool.page(head_frame).free_space();
        self.pool.unpin(head_frame);
        self.space.insert(head_page, free);
        Ok(RecordId { page: head_page, slot })
    }

    /// Write `rest` across a chain of overflow pages; returns the first
    /// page id (0 = no overflow; page 0 is always the first slotted page
    /// or WAL-adjacent metadata, never an overflow page).
    fn write_chain(&mut self, rest: &[u8]) -> Result<u32> {
        if rest.is_empty() {
            return Ok(0);
        }
        let mut first = 0u32;
        let mut prev: Option<(u32, usize)> = None;
        for chunk in rest.chunks(OVERFLOW_CAP) {
            let (id, f) = self.pool.create(PageKind::Overflow)?;
            self.pool.page_mut(f).bytes_mut()[PAGE_SIZE - OVERFLOW_CAP..][..chunk.len()]
                .copy_from_slice(chunk);
            if let Some((_, pf)) = prev {
                self.pool.page_mut(pf).set_next(id);
                self.pool.unpin(pf);
            } else {
                first = id;
            }
            prev = Some((id, f));
        }
        if let Some((_, pf)) = prev {
            self.pool.unpin(pf);
        }
        Ok(first)
    }

    /// Copy a record's head fragment out of its page.
    fn read_head(&mut self, rec: RecordId) -> Result<Vec<u8>> {
        let f = self.pool.fetch(rec.page)?;
        let head = self.pool.page(f).read_slot(rec.slot).map(|h| h.to_vec());
        self.pool.unpin(f);
        match head {
            Some(h) if h.len() >= HEAD_PREFIX => Ok(h),
            Some(_) => bail!("corrupt record head at page {} slot {}", rec.page, rec.slot),
            None => bail!("no record at page {} slot {}", rec.page, rec.slot),
        }
    }

    /// Read a whole record back.
    pub fn get(&mut self, rec: RecordId) -> Result<Vec<u8>> {
        let head = self.read_head(rec)?;
        let total = u32::from_le_bytes([head[0], head[1], head[2], head[3]]) as usize;
        let mut next = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(&head[HEAD_PREFIX..]);
        while out.len() < total {
            if next == 0 {
                bail!("truncated overflow chain for record at page {} slot {}", rec.page, rec.slot);
            }
            let f = self.pool.fetch(next)?;
            let kind = self.pool.page(f).kind();
            let following = self.pool.page(f).next();
            if kind != Some(PageKind::Overflow) {
                self.pool.unpin(f);
                bail!("overflow chain hit a non-overflow page {next}");
            }
            let take = (total - out.len()).min(OVERFLOW_CAP);
            out.extend_from_slice(&self.pool.page(f).bytes()[PAGE_SIZE - OVERFLOW_CAP..][..take]);
            self.pool.unpin(f);
            next = following;
        }
        if out.len() != total {
            bail!("record length mismatch: got {} of {total}", out.len());
        }
        Ok(out)
    }

    /// Delete a record, freeing its overflow pages and compacting its
    /// head page.  A fully-emptied head page returns to the free list.
    pub fn delete(&mut self, rec: RecordId) -> Result<()> {
        let head = self.read_head(rec)?;
        let mut next = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
        let f = self.pool.fetch(rec.page)?;
        self.pool.page_mut(f).delete_slot(rec.slot);
        let (live, free) = (self.pool.page(f).live_slots(), self.pool.page(f).free_space());
        self.pool.unpin(f);
        if live == 0 {
            self.space.remove(&rec.page);
            self.pool.free_page(rec.page)?;
        } else {
            self.space.insert(rec.page, free);
        }
        while next != 0 {
            let f = self.pool.fetch(next)?;
            let following = self.pool.page(f).next();
            self.pool.unpin(f);
            self.pool.free_page(next)?;
            next = following;
        }
        Ok(())
    }

    /// Write back every dirty page and sync to stable storage.
    pub fn flush(&mut self) -> Result<()> {
        self.pool.flush_all()
    }
}

#[cfg(test)]
mod tests {
    use super::super::disk::DiskManager;
    use super::super::testutil::TempDir;
    use super::*;

    fn heap(dir: &TempDir) -> RecordHeap {
        let dm = DiskManager::open(&dir.path().join("store.pages")).unwrap();
        RecordHeap::open(BufferPool::new(dm, 8)).unwrap()
    }

    #[test]
    fn small_records_round_trip_and_pack() {
        let dir = TempDir::new("heap");
        let mut h = heap(&dir);
        let a = h.put(b"one").unwrap();
        let b = h.put(b"two-two").unwrap();
        assert_eq!(a.page, b.page, "small records pack into one page");
        assert_eq!(h.get(a).unwrap(), b"one");
        assert_eq!(h.get(b).unwrap(), b"two-two");
        h.delete(a).unwrap();
        assert!(h.get(a).is_err());
        assert_eq!(h.get(b).unwrap(), b"two-two", "neighbors survive delete + compaction");
    }

    #[test]
    fn oversized_record_chains_overflow_pages() {
        let dir = TempDir::new("heap-big");
        let mut h = heap(&dir);
        // ~3 pages of payload: one head fragment + at least two overflow pages
        let big: Vec<u8> = (0..3 * PAGE_SIZE).map(|i| (i * 31 % 251) as u8).collect();
        let rec = h.put(&big).unwrap();
        assert!(h.num_pages() >= 3);
        assert_eq!(h.get(rec).unwrap(), big, "bit-for-bit through the chain");
        let pages_before = h.num_pages();
        h.delete(rec).unwrap();
        // freed overflow pages are reused, not appended
        let rec2 = h.put(&big).unwrap();
        assert_eq!(h.num_pages(), pages_before, "delete returned the chain to the free list");
        assert_eq!(h.get(rec2).unwrap(), big);
    }

    #[test]
    fn records_survive_reopen() {
        let dir = TempDir::new("heap-reopen");
        let big: Vec<u8> = (0..PAGE_SIZE * 2).map(|i| (i % 256) as u8).collect();
        let (a, b) = {
            let mut h = heap(&dir);
            let a = h.put(b"persisted").unwrap();
            let b = h.put(&big).unwrap();
            h.flush().unwrap();
            (a, b)
        };
        let mut h = heap(&dir);
        assert_eq!(h.get(a).unwrap(), b"persisted");
        assert_eq!(h.get(b).unwrap(), big);
        // the rebuilt space map still packs new small records
        let c = h.put(b"more").unwrap();
        assert_eq!(c.page, a.page);
        assert_eq!(
            h.live_records().unwrap().len(),
            3,
            "live_records sees all heads after reopen"
        );
    }

    #[test]
    fn record_id_encoding_round_trips() {
        let r = RecordId { page: 0xabcdef, slot: 0x1234 };
        assert_eq!(RecordId::from_u64(r.to_u64()), r);
    }
}

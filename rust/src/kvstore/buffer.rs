//! Buffer pool: a fixed set of in-memory frames caching store pages.
//!
//! Classic design — page table, pin counts, dirty bits, LRU write-back —
//! sized small (64 frames = 512 KiB) because the store sits under a
//! mutex-guarded facade and every heap operation touches only a handful
//! of pages.  Pins are held for the duration of one heap call, never
//! across calls, so eviction can always find a victim.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::disk::DiskManager;
use super::page::{Page, PageKind};

pub const DEFAULT_FRAMES: usize = 64;

struct Frame {
    page: Page,
    page_id: u32,
    pin: u32,
    dirty: bool,
    tick: u64,
    valid: bool,
}

pub struct BufferPool {
    disk: DiskManager,
    frames: Vec<Frame>,
    /// page_id -> frame index, for every valid frame.
    table: HashMap<u32, usize>,
    clock: u64,
}

impl BufferPool {
    pub fn new(disk: DiskManager, capacity: usize) -> BufferPool {
        assert!(capacity > 0, "buffer pool needs at least one frame");
        let frames = (0..capacity)
            .map(|_| Frame { page: Page::new(), page_id: 0, pin: 0, dirty: false, tick: 0, valid: false })
            .collect();
        BufferPool { disk, frames, table: HashMap::new(), clock: 0 }
    }

    pub fn disk(&self) -> &DiskManager {
        &self.disk
    }

    fn touch(&mut self, frame: usize) {
        self.clock += 1;
        self.frames[frame].tick = self.clock;
    }

    /// Pick a frame for a new resident page: an invalid frame if one
    /// exists, else the least-recently-used unpinned frame (flushing it
    /// first when dirty).
    fn victim(&mut self) -> Result<usize> {
        if let Some(i) = self.frames.iter().position(|f| !f.valid) {
            return Ok(i);
        }
        let mut best: Option<usize> = None;
        for (i, f) in self.frames.iter().enumerate() {
            if f.pin == 0 && best.map_or(true, |b| f.tick < self.frames[b].tick) {
                best = Some(i);
            }
        }
        let Some(i) = best else {
            bail!("buffer pool exhausted: every frame is pinned");
        };
        if self.frames[i].dirty {
            self.disk.write_page(self.frames[i].page_id, &self.frames[i].page)?;
            self.frames[i].dirty = false;
        }
        self.table.remove(&self.frames[i].page_id);
        self.frames[i].valid = false;
        Ok(i)
    }

    /// Load (or find) a page and pin it; returns the frame index.
    pub fn fetch(&mut self, page_id: u32) -> Result<usize> {
        if let Some(&i) = self.table.get(&page_id) {
            self.frames[i].pin += 1;
            self.touch(i);
            return Ok(i);
        }
        let i = self.victim()?;
        self.disk.read_page(page_id, &mut self.frames[i].page)?;
        self.frames[i].page_id = page_id;
        self.frames[i].pin = 1;
        self.frames[i].dirty = false;
        self.frames[i].valid = true;
        self.table.insert(page_id, i);
        self.touch(i);
        Ok(i)
    }

    /// Allocate a fresh page on disk, initialize it in a pinned frame.
    pub fn create(&mut self, kind: PageKind) -> Result<(u32, usize)> {
        let page_id = self.disk.allocate_page()?;
        let i = self.victim()?;
        self.frames[i].page.init(kind, page_id);
        self.frames[i].page_id = page_id;
        self.frames[i].pin = 1;
        self.frames[i].dirty = true;
        self.frames[i].valid = true;
        self.table.insert(page_id, i);
        self.touch(i);
        Ok((page_id, i))
    }

    pub fn page(&self, frame: usize) -> &Page {
        debug_assert!(self.frames[frame].valid);
        &self.frames[frame].page
    }

    /// Mutable access marks the frame dirty.
    pub fn page_mut(&mut self, frame: usize) -> &mut Page {
        debug_assert!(self.frames[frame].valid);
        self.frames[frame].dirty = true;
        &mut self.frames[frame].page
    }

    pub fn unpin(&mut self, frame: usize) {
        debug_assert!(self.frames[frame].pin > 0, "unpin without a pin");
        self.frames[frame].pin = self.frames[frame].pin.saturating_sub(1);
    }

    /// Drop a page from the cache (if resident) and return it to the
    /// disk free list.  The page must not be pinned.
    pub fn free_page(&mut self, page_id: u32) -> Result<()> {
        if let Some(i) = self.table.remove(&page_id) {
            debug_assert_eq!(self.frames[i].pin, 0, "freeing a pinned page");
            self.frames[i].valid = false;
            self.frames[i].dirty = false;
        }
        self.disk.free_page(page_id)
    }

    /// Write every dirty frame back and sync the file.
    pub fn flush_all(&mut self) -> Result<()> {
        for i in 0..self.frames.len() {
            if self.frames[i].valid && self.frames[i].dirty {
                self.disk.write_page(self.frames[i].page_id, &self.frames[i].page)?;
                self.frames[i].dirty = false;
            }
        }
        self.disk.sync()
    }

    pub fn num_pages(&self) -> u32 {
        self.disk.num_pages()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::TempDir;
    use super::*;

    fn pool(dir: &TempDir, frames: usize) -> BufferPool {
        let dm = DiskManager::open(&dir.path().join("store.pages")).unwrap();
        BufferPool::new(dm, frames)
    }

    #[test]
    fn create_fetch_and_write_back() {
        let dir = TempDir::new("buf");
        let mut bp = pool(&dir, 4);
        let (id, f) = bp.create(PageKind::Slotted).unwrap();
        let slot = bp.page_mut(f).insert(b"cached").unwrap();
        bp.unpin(f);
        bp.flush_all().unwrap();
        // fetch through the cache and through a cold pool
        let f2 = bp.fetch(id).unwrap();
        assert_eq!(bp.page(f2).read_slot(slot).unwrap(), b"cached");
        bp.unpin(f2);
        drop(bp);
        let mut cold = pool(&dir, 4);
        let f3 = cold.fetch(id).unwrap();
        assert_eq!(cold.page(f3).read_slot(slot).unwrap(), b"cached");
        cold.unpin(f3);
    }

    #[test]
    fn lru_evicts_unpinned_and_flushes_dirty() {
        let dir = TempDir::new("buf-lru");
        let mut bp = pool(&dir, 2);
        let (a, fa) = bp.create(PageKind::Slotted).unwrap();
        let sa = bp.page_mut(fa).insert(b"aaaa").unwrap();
        bp.unpin(fa);
        let (_b, fb) = bp.create(PageKind::Slotted).unwrap();
        bp.unpin(fb);
        // a third resident page must evict page `a` (the LRU), writing it back
        let (_c, fc) = bp.create(PageKind::Slotted).unwrap();
        bp.unpin(fc);
        let fa2 = bp.fetch(a).unwrap();
        assert_eq!(bp.page(fa2).read_slot(sa).unwrap(), b"aaaa", "dirty eviction wrote back");
        bp.unpin(fa2);
    }

    #[test]
    fn all_pinned_is_a_typed_error() {
        let dir = TempDir::new("buf-pin");
        let mut bp = pool(&dir, 1);
        let (_a, fa) = bp.create(PageKind::Slotted).unwrap();
        assert!(bp.create(PageKind::Slotted).is_err(), "no victim while every frame is pinned");
        bp.unpin(fa);
        assert!(bp.create(PageKind::Slotted).is_ok());
    }
}

//! Raw page I/O: one store file, fixed-size pages, a free-page list.
//!
//! Page `i` lives at byte offset `i * PAGE_SIZE`.  The free list is not
//! persisted separately — it is recovered at open by scanning page
//! headers for [`PageKind::Free`], so the file is always self-describing
//! and a crash can at worst leak a page until the next open.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::page::{Page, PageKind, PAGE_SIZE};

pub struct DiskManager {
    file: File,
    num_pages: u32,
    free: Vec<u32>,
}

impl DiskManager {
    /// Open (creating if missing) the store file and rebuild the free
    /// list from page headers.
    pub fn open(path: &Path) -> Result<DiskManager> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)
            .with_context(|| format!("open page store {}", path.display()))?;
        let len = file.metadata()?.len();
        if len % PAGE_SIZE as u64 != 0 {
            bail!("page store {} is torn: {} bytes is not a page multiple", path.display(), len);
        }
        let num_pages = (len / PAGE_SIZE as u64) as u32;
        let mut dm = DiskManager { file, num_pages, free: Vec::new() };
        let mut page = Page::new();
        for id in 0..num_pages {
            dm.read_page(id, &mut page)?;
            if page.kind() == Some(PageKind::Free) {
                dm.free.push(id);
            }
        }
        Ok(dm)
    }

    pub fn num_pages(&self) -> u32 {
        self.num_pages
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn read_page(&mut self, id: u32, page: &mut Page) -> Result<()> {
        if id >= self.num_pages {
            bail!("read past end of page store: page {id} of {}", self.num_pages);
        }
        self.file.seek(SeekFrom::Start(id as u64 * PAGE_SIZE as u64))?;
        self.file.read_exact(page.bytes_mut())?;
        Ok(())
    }

    pub fn write_page(&mut self, id: u32, page: &Page) -> Result<()> {
        if id >= self.num_pages {
            bail!("write past end of page store: page {id} of {}", self.num_pages);
        }
        self.file.seek(SeekFrom::Start(id as u64 * PAGE_SIZE as u64))?;
        self.file.write_all(page.bytes())?;
        Ok(())
    }

    /// Hand out a page id: pop the free list, else grow the file by one
    /// zeroed page.  The caller initializes and writes the page image.
    pub fn allocate_page(&mut self) -> Result<u32> {
        if let Some(id) = self.free.pop() {
            return Ok(id);
        }
        let id = self.num_pages;
        self.file.seek(SeekFrom::Start(id as u64 * PAGE_SIZE as u64))?;
        self.file.write_all(&[0u8; PAGE_SIZE])?;
        self.num_pages += 1;
        Ok(id)
    }

    /// Return a page to the free list (its header is rewritten so the
    /// next open rediscovers it as free).
    pub fn free_page(&mut self, id: u32) -> Result<()> {
        let mut page = Page::new();
        page.init(PageKind::Free, id);
        self.write_page(id, &page)?;
        self.free.push(id);
        Ok(())
    }

    /// Flush file contents to stable storage (`fdatasync`).
    pub fn sync(&mut self) -> Result<()> {
        self.file.sync_data()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::TempDir;
    use super::*;

    #[test]
    fn allocate_write_read_round_trip() {
        let dir = TempDir::new("disk");
        let path = dir.path().join("store.pages");
        let mut dm = DiskManager::open(&path).unwrap();
        assert_eq!(dm.num_pages(), 0);
        let id = dm.allocate_page().unwrap();
        let mut p = Page::new();
        p.init(PageKind::Slotted, id);
        let slot = p.insert(b"hello pages").unwrap();
        dm.write_page(id, &p).unwrap();
        dm.sync().unwrap();

        let mut back = Page::new();
        dm.read_page(id, &mut back).unwrap();
        assert_eq!(back.kind(), Some(PageKind::Slotted));
        assert_eq!(back.read_slot(slot).unwrap(), b"hello pages");
        assert!(dm.read_page(5, &mut back).is_err(), "reads past the end are typed errors");
    }

    #[test]
    fn free_list_survives_reopen() {
        let dir = TempDir::new("disk-free");
        let path = dir.path().join("store.pages");
        {
            let mut dm = DiskManager::open(&path).unwrap();
            let a = dm.allocate_page().unwrap();
            let b = dm.allocate_page().unwrap();
            let mut p = Page::new();
            p.init(PageKind::Slotted, a);
            dm.write_page(a, &p).unwrap();
            p.init(PageKind::Slotted, b);
            dm.write_page(b, &p).unwrap();
            dm.free_page(a).unwrap();
            dm.sync().unwrap();
        }
        let mut dm = DiskManager::open(&path).unwrap();
        assert_eq!(dm.num_pages(), 2);
        assert_eq!(dm.free_pages(), 1, "free header scan rebuilds the list");
        assert_eq!(dm.allocate_page().unwrap(), 0, "the freed page is reused, not appended");
        assert_eq!(dm.num_pages(), 2);
    }
}

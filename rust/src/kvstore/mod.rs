//! Tiered KV storage: a paged disk store + WAL-journaled inventory.
//!
//! LagKV's frozen blocks are immutable, refcounted, and final by the
//! driver's contract — which makes them perfect cold-tier payloads: a
//! spilled block can be re-read bit-for-bit because nothing can have
//! written through it in the meantime.  This module is the disk half of
//! that tiering:
//!
//! * [`page`] — 8 KiB slotted pages (SNIPPETS' classic layout plus an
//!   overflow `next` pointer, since one frozen block outgrows a page);
//! * [`disk`] — [`DiskManager`]: raw page I/O over one store file with a
//!   header-scan-recovered free-page list;
//! * [`buffer`] — [`BufferPool`]: frame table, pin counts, dirty bits,
//!   LRU write-back;
//! * [`heap`] — [`RecordHeap`]: variable-length records with overflow
//!   chains, addressed by stable [`RecordId`]s;
//! * [`wal`] — the append-only inventory journal (+ checkpoint rewrite).
//!
//! [`KvStore`] is the mutex-guarded facade the serving stack talks to.
//! Block payloads and per-head sidecars are stored as little-endian
//! binary records (JSON cannot round-trip `inf`/`NaN` f32 bits); the
//! journal carries only ids, dims, and descriptor JSON.  Durability
//! contract: appends are flushed to the OS immediately, but only a
//! [`KvStore::checkpoint`] (fsync + journal rewrite) is crash-durable —
//! replay validates every referenced record and drops descriptors whose
//! payloads did not survive, so a torn tail degrades to a smaller
//! inventory, never a corrupt one.

pub mod buffer;
pub mod disk;
pub mod heap;
pub mod page;
pub mod wal;

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::quant::{CodecKind, EncodedKv};
use crate::util::json::Json;

pub use buffer::BufferPool;
pub use disk::DiskManager;
pub use heap::{RecordHeap, RecordId};
pub use wal::{Wal, WalRecord};

/// One block's deserialized payload, bit-identical to what was persisted.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockPayload {
    pub rows: usize,
    pub d: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub pos: Vec<i32>,
    pub attn: Vec<f32>,
}

/// A quantized block's persisted form: the *encoded* payload exactly as
/// the codec produced it at freeze time (data + sidecar), plus the fp32
/// side arrays.  Spill never decodes and fault-in never re-encodes, so
/// the bytes round-trip bit-identically through the disk tier.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantBlockPayload {
    pub rows: usize,
    pub d: usize,
    /// [`CodecKind`] tag (never 0/fp32 — plain blocks use [`BlockPayload`]).
    pub codec: u8,
    pub data: Vec<u8>,
    pub sidecar: Vec<u8>,
    pub pos: Vec<i32>,
    pub attn: Vec<f32>,
}

/// What a checkpoint persisted, and how long it took.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CheckpointSummary {
    pub sessions: usize,
    pub prefixes: usize,
    pub blocks: usize,
    pub pages: usize,
    /// Wall-clock duration of the sweep + fsync + journal rewrite.
    pub elapsed_us: u64,
}

struct BlockMeta {
    rec: RecordId,
    rows: usize,
    d: usize,
    /// Record bytes past the 8-byte header: `kvpool::block_bytes(rows, d)`
    /// for fp32 blocks, the (smaller) encoded form for quantized ones.
    bytes: usize,
    /// [`CodecKind`] tag: 0 = fp32 ([`BlockPayload`] record layout),
    /// nonzero = encoded ([`QuantBlockPayload`] layout).
    codec: u8,
    /// Outstanding claims: at most one live in-memory `Block` handle plus
    /// one per journaled descriptor referencing this block.  At zero the
    /// record is deleted and a `bdel` appended.
    refs: usize,
}

struct StoreInner {
    heap: RecordHeap,
    wal: Wal,
    blocks: HashMap<u64, BlockMeta>,
    sessions: HashMap<String, Json>,
    prefixes: HashMap<u64, Json>,
    /// Sidecar records written but not yet committed into a journaled
    /// descriptor: invisible to checkpoint GC until committed or aborted.
    limbo: HashSet<RecordId>,
    next_block: u64,
    next_prefix: u64,
    /// Disk-tier byte cap (`--store-max-mb`), enforced against the page
    /// file's in-use bytes; `None` = unbounded.
    max_bytes: Option<usize>,
    /// Monotone recency counter for the disk-tier LRU: descriptors are
    /// stamped when journaled, coldest evicted first under the cap.
    lru_clock: u64,
    session_stamp: HashMap<String, u64>,
    prefix_stamp: HashMap<u64, u64>,
}

/// The store facade: one per model variant, shared `Arc` between the
/// block pool (spill/fault), the session store and prefix cache
/// (journaling), and the router (checkpoint, boot restore).
pub struct KvStore {
    dir: PathBuf,
    inner: Mutex<StoreInner>,
}

impl KvStore {
    /// Open (or create) the store under `dir`: replay the journal,
    /// validate every referenced payload, garbage-collect unreferenced
    /// blocks, and compact the journal to the surviving inventory.
    pub fn open(dir: &Path) -> Result<KvStore> {
        KvStore::open_with_cap(dir, None)
    }

    /// [`KvStore::open`] with a disk-tier byte cap (`--store-max-mb`).
    /// When the page file's in-use bytes exceed the cap — at boot or
    /// after any write — the coldest journaled descriptors are evicted
    /// (prefix snapshots before sessions, LRU within each class) until
    /// the store fits or nothing evictable remains.  Eviction releases
    /// the descriptors' block claims, so unshared payloads are deleted
    /// with `bdel` journaled — replay never resurrects them — and an
    /// evicted session simply resumes cold (shed semantics).
    pub fn open_with_cap(dir: &Path, max_bytes: Option<usize>) -> Result<KvStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create store dir {}", dir.display()))?;
        let pages_path = dir.join("store.pages");
        let wal_path = dir.join("wal.log");
        let disk = DiskManager::open(&pages_path)?;
        let mut heap = RecordHeap::open(BufferPool::new(disk, buffer::DEFAULT_FRAMES))?;

        // fold the journal into the final inventory
        let mut blocks: HashMap<u64, BlockMeta> = HashMap::new();
        let mut sessions: HashMap<String, Json> = HashMap::new();
        let mut prefixes: HashMap<u64, Json> = HashMap::new();
        let mut next_block = 1u64;
        let mut next_prefix = 1u64;
        for rec in Wal::replay(&wal_path)? {
            match rec {
                WalRecord::BlockPut { id, rec, rows, d, bytes, codec } => {
                    next_block = next_block.max(id + 1);
                    blocks.insert(
                        id,
                        BlockMeta { rec: RecordId::from_u64(rec), rows, d, bytes, codec, refs: 0 },
                    );
                }
                WalRecord::BlockDel { id } => {
                    blocks.remove(&id);
                }
                WalRecord::SessionPut { id, desc } => {
                    sessions.insert(id, desc);
                }
                WalRecord::SessionDel { id } => {
                    sessions.remove(&id);
                }
                WalRecord::PrefixPut { pid, desc } => {
                    next_prefix = next_prefix.max(pid + 1);
                    prefixes.insert(pid, desc);
                }
                WalRecord::PrefixDel { pid } => {
                    prefixes.remove(&pid);
                }
            }
        }

        // validate descriptors against the page store; count block refs.
        // A descriptor whose payloads did not survive the crash (appended
        // after the last checkpoint, pages never flushed) is dropped.
        let mut block_ok: HashMap<u64, bool> = HashMap::new();
        let mut keep_session: HashMap<String, Json> = HashMap::new();
        let mut keep_prefix: HashMap<u64, Json> = HashMap::new();
        for (id, desc) in sessions {
            if desc_is_valid(&desc, &blocks, &mut heap, &mut block_ok) {
                keep_session.insert(id, desc);
            } else {
                eprintln!("kvstore: dropping session {id:?}: payload missing (torn journal tail)");
            }
        }
        for (pid, desc) in prefixes {
            if desc_is_valid(&desc, &blocks, &mut heap, &mut block_ok) {
                keep_prefix.insert(pid, desc);
            } else {
                eprintln!("kvstore: dropping prefix snapshot {pid}: payload missing");
            }
        }
        for desc in keep_session.values().chain(keep_prefix.values()) {
            for_each_ref(desc, &mut |bid| {
                if let Some(meta) = blocks.get_mut(&bid) {
                    meta.refs += 1;
                }
            });
        }
        // GC blocks nothing references (e.g. spill records of caches that
        // were live at crash time)
        let dead: Vec<u64> =
            blocks.iter().filter(|(_, m)| m.refs == 0).map(|(&id, _)| id).collect();
        for id in &dead {
            let rec = blocks.remove(id).expect("dead id came from the map").rec;
            let _ = heap.delete(rec);
        }

        let wal = Wal::open(&wal_path)?;
        let mut inner = StoreInner {
            heap,
            wal,
            blocks,
            sessions: keep_session,
            prefixes: keep_prefix,
            limbo: HashSet::new(),
            next_block,
            next_prefix,
            max_bytes,
            lru_clock: 0,
            session_stamp: HashMap::new(),
            prefix_stamp: HashMap::new(),
        };
        // seed the LRU stamps for the restored inventory (prefixes colder
        // than sessions, matching the memory tier's shed ordering), then
        // enforce the cap on what survived the restart
        let mut pids: Vec<u64> = inner.prefixes.keys().copied().collect();
        pids.sort_unstable();
        for pid in pids {
            inner.lru_clock += 1;
            let stamp = inner.lru_clock;
            inner.prefix_stamp.insert(pid, stamp);
        }
        let mut sids: Vec<String> = inner.sessions.keys().cloned().collect();
        sids.sort_unstable();
        for sid in sids {
            inner.lru_clock += 1;
            let stamp = inner.lru_clock;
            inner.session_stamp.insert(sid, stamp);
        }
        inner.enforce_cap();
        let store = KvStore { dir: dir.to_path_buf(), inner: Mutex::new(inner) };
        // compact the journal to the surviving inventory (also makes the
        // replayed state durable before anything new is appended)
        store.checkpoint()?;
        Ok(store)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// (sessions, prefixes, blocks) currently journaled.
    pub fn inventory_counts(&self) -> (usize, usize, usize) {
        let inner = self.inner.lock().unwrap();
        (inner.sessions.len(), inner.prefixes.len(), inner.blocks.len())
    }

    // -- blocks ----------------------------------------------------------------

    /// Persist one block payload; returns its store id with one claim (the
    /// caller's live handle).  Appends a `blk` journal record.
    pub fn persist_block(
        &self,
        rows: usize,
        d: usize,
        k: &[f32],
        v: &[f32],
        pos: &[i32],
        attn: &[f32],
    ) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_block;
        inner.next_block += 1;
        let data = encode_block(rows, d, k, v, pos, attn);
        let bytes = data.len() - BLOCK_HEADER;
        // lint: allow(lock-order): `heap.put` is the buffer pool's method, not `SessionStore::put` — the lint's name-level call graph merges them, fabricating a KvStore.inner -> Block.state edge
        let rec = inner.heap.put(&data)?;
        inner.blocks.insert(id, BlockMeta { rec, rows, d, bytes, codec: 0, refs: 1 });
        inner.wal.append(&WalRecord::BlockPut { id, rec: rec.to_u64(), rows, d, bytes, codec: 0 })?;
        inner.enforce_cap();
        Ok(id)
    }

    /// Persist one *encoded* block payload (the spill half of the
    /// quantized tier): the codec's data + sidecar bytes are written
    /// verbatim — never dequantized — so the disk page shrinks by the
    /// codec's factor and a later fault-in is bit-identical.  Appends a
    /// `blk` journal record carrying the codec tag.
    pub fn persist_quant_block(
        &self,
        rows: usize,
        d: usize,
        kind: CodecKind,
        enc: &EncodedKv,
        pos: &[i32],
        attn: &[f32],
    ) -> Result<u64> {
        if kind == CodecKind::Fp32 {
            bail!("fp32 blocks persist through persist_block");
        }
        // lint: allow(panic): lock poisoning is unrecoverable by design across the store
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_block;
        inner.next_block += 1;
        let data = encode_quant_block(rows, d, enc, pos, attn);
        let bytes = data.len() - BLOCK_HEADER;
        // lint: allow(lock-order): `heap.put` is the buffer pool's method, not `SessionStore::put` — the lint's name-level call graph merges them, fabricating a KvStore.inner -> Block.state edge
        let rec = inner.heap.put(&data)?;
        let codec = kind.tag();
        inner.blocks.insert(id, BlockMeta { rec, rows, d, bytes, codec, refs: 1 });
        inner.wal.append(&WalRecord::BlockPut { id, rec: rec.to_u64(), rows, d, bytes, codec })?;
        inner.enforce_cap();
        Ok(id)
    }

    /// Add a claim (a journaled descriptor reference, or a restored live
    /// handle at boot).
    pub fn retain_block(&self, id: u64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(meta) = inner.blocks.get_mut(&id) {
            meta.refs += 1;
        } else {
            debug_assert!(false, "retain of unknown block {id}");
        }
    }

    /// Drop a claim; the last one deletes the payload and journals `bdel`.
    pub fn release_block(&self, id: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.release_block(id);
    }

    /// Read a block payload back (fault-in path).
    pub fn read_block(&self, id: u64) -> Result<BlockPayload> {
        let mut inner = self.inner.lock().unwrap();
        let (rec, rows, d, codec) = match inner.blocks.get(&id) {
            Some(m) => (m.rec, m.rows, m.d, m.codec),
            None => bail!("read of unknown block {id}"),
        };
        if codec != 0 {
            bail!("block {id} is quantized (codec {codec}); read it via read_quant_block");
        }
        let data = inner.heap.get(rec)?;
        let payload = decode_block(&data)?;
        if payload.rows != rows || payload.d != d {
            bail!("block {id} dims changed on disk: {}x{} vs {rows}x{d}", payload.rows, payload.d);
        }
        Ok(payload)
    }

    /// Read an encoded block payload back (quantized fault-in path).
    pub fn read_quant_block(&self, id: u64) -> Result<QuantBlockPayload> {
        // lint: allow(panic): lock poisoning is unrecoverable by design across the store
        let mut inner = self.inner.lock().unwrap();
        let (rec, rows, d, codec) = match inner.blocks.get(&id) {
            Some(m) => (m.rec, m.rows, m.d, m.codec),
            None => bail!("read of unknown block {id}"),
        };
        if codec == 0 {
            bail!("block {id} is fp32; read it via read_block");
        }
        let data = inner.heap.get(rec)?;
        let payload = decode_quant_block(&data, codec)?;
        if payload.rows != rows || payload.d != d {
            bail!("block {id} dims changed on disk: {}x{} vs {rows}x{d}", payload.rows, payload.d);
        }
        Ok(payload)
    }

    /// `(rows, d, payload_bytes)` of a journaled block.
    pub fn block_dims(&self, id: u64) -> Option<(usize, usize, usize)> {
        let inner = self.inner.lock().unwrap();
        inner.blocks.get(&id).map(|m| (m.rows, m.d, m.bytes))
    }

    /// A journaled block's [`CodecKind`] tag (0 = fp32).
    pub fn block_codec(&self, id: u64) -> Option<u8> {
        // lint: allow(panic): lock poisoning is unrecoverable by design across the store
        let inner = self.inner.lock().unwrap();
        inner.blocks.get(&id).map(|m| m.codec)
    }

    /// In-use bytes of the page file (what the `--store-max-mb` cap is
    /// enforced against).
    pub fn used_bytes(&self) -> usize {
        // lint: allow(panic): lock poisoning is unrecoverable by design across the store
        let inner = self.inner.lock().unwrap();
        inner.heap.used_bytes()
    }

    // -- sidecars (opaque byte records referenced from descriptors) ------------

    /// Store descriptor-owned bytes (loose tails, frozen attention).  The
    /// record sits in limbo — protected from checkpoint GC but not yet
    /// owned — until a descriptor referencing it is journaled.
    pub fn put_blob(&self, data: &[u8]) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        // lint: allow(lock-order): `heap.put` is the buffer pool's method, not `SessionStore::put` — the lint's name-level call graph merges them, fabricating a KvStore.inner -> Block.state edge
        let rec = inner.heap.put(data)?;
        inner.limbo.insert(rec);
        Ok(rec.to_u64())
    }

    pub fn read_blob(&self, rec: u64) -> Result<Vec<u8>> {
        let mut inner = self.inner.lock().unwrap();
        inner.heap.get(RecordId::from_u64(rec))
    }

    /// Error-path cleanup: delete limbo blobs a failed persist wrote.
    pub fn abort_blobs(&self, recs: &[u64]) {
        let mut inner = self.inner.lock().unwrap();
        for &r in recs {
            let rec = RecordId::from_u64(r);
            if inner.limbo.remove(&rec) {
                let _ = inner.heap.delete(rec);
            }
        }
    }

    // -- journaled inventory ---------------------------------------------------

    /// Journal a session descriptor (superseding any previous one for the
    /// same id: its claims are released and its sidecars deleted).  The
    /// new descriptor's sidecars leave limbo; its block ids must already
    /// hold claims taken via [`KvStore::retain_block`].
    pub fn journal_session_put(&self, id: &str, desc: Json) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        inner.commit_sidecars(&desc);
        inner.wal.append(&WalRecord::SessionPut { id: id.to_string(), desc: desc.clone() })?;
        if let Some(old) = inner.sessions.insert(id.to_string(), desc) {
            inner.release_desc(&old);
        }
        inner.lru_clock += 1;
        let stamp = inner.lru_clock;
        inner.session_stamp.insert(id.to_string(), stamp);
        inner.enforce_cap();
        Ok(())
    }

    /// Journal removal of a session.  Harmless when the id was never
    /// journaled (the caller need not track that) — returns whether a
    /// descriptor was actually dropped.
    pub fn journal_session_remove(&self, id: &str) -> Result<bool> {
        let mut inner = self.inner.lock().unwrap();
        let Some(old) = inner.sessions.remove(id) else {
            return Ok(false);
        };
        inner.session_stamp.remove(id);
        inner.wal.append(&WalRecord::SessionDel { id: id.to_string() })?;
        inner.release_desc(&old);
        Ok(true)
    }

    /// Journal a prefix snapshot descriptor; returns its journal id.
    pub fn journal_prefix_put(&self, desc: Json) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        let pid = inner.next_prefix;
        inner.next_prefix += 1;
        inner.commit_sidecars(&desc);
        inner.wal.append(&WalRecord::PrefixPut { pid, desc: desc.clone() })?;
        inner.prefixes.insert(pid, desc);
        inner.lru_clock += 1;
        let stamp = inner.lru_clock;
        inner.prefix_stamp.insert(pid, stamp);
        inner.enforce_cap();
        Ok(pid)
    }

    pub fn journal_prefix_remove(&self, pid: u64) -> Result<bool> {
        let mut inner = self.inner.lock().unwrap();
        let Some(old) = inner.prefixes.remove(&pid) else {
            return Ok(false);
        };
        inner.prefix_stamp.remove(&pid);
        inner.wal.append(&WalRecord::PrefixDel { pid })?;
        inner.release_desc(&old);
        Ok(true)
    }

    /// The boot inventory: journaled sessions and prefix snapshots, for
    /// the router to rebuild in-memory state from.
    pub fn boot_sessions(&self) -> Vec<(String, Json)> {
        let inner = self.inner.lock().unwrap();
        inner.sessions.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
    }

    pub fn boot_prefixes(&self) -> Vec<(u64, Json)> {
        let inner = self.inner.lock().unwrap();
        inner.prefixes.iter().map(|(&k, v)| (k, v.clone())).collect()
    }

    /// Make the store crash-durable: sweep unreachable heap records,
    /// flush + fsync every dirty page, then atomically rewrite the
    /// journal to exactly the live inventory.
    pub fn checkpoint(&self) -> Result<CheckpointSummary> {
        // lint: allow(clock): checkpoint duration measures real disk I/O; a fake clock would report 0 and hide fsync stalls
        let t0 = std::time::Instant::now();
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        // reachability sweep over heap records
        let mut reachable: HashSet<RecordId> = inner.limbo.iter().copied().collect();
        for meta in inner.blocks.values() {
            reachable.insert(meta.rec);
        }
        for desc in inner.sessions.values().chain(inner.prefixes.values()) {
            for_each_sidecar(desc, &mut |rec| {
                reachable.insert(RecordId::from_u64(rec));
            });
        }
        for rec in inner.heap.live_records()? {
            if !reachable.contains(&rec) {
                inner.heap.delete(rec)?;
            }
        }
        inner.heap.flush()?;
        // journal rewrite: the page store is durable before the journal
        // claims this inventory
        let mut records = Vec::new();
        let mut ids: Vec<&u64> = inner.blocks.keys().collect();
        ids.sort();
        for id in ids {
            let m = &inner.blocks[id];
            records.push(WalRecord::BlockPut {
                id: *id,
                rec: m.rec.to_u64(),
                rows: m.rows,
                d: m.d,
                bytes: m.bytes,
                codec: m.codec,
            });
        }
        for (id, desc) in &inner.sessions {
            records.push(WalRecord::SessionPut { id: id.clone(), desc: desc.clone() });
        }
        for (&pid, desc) in &inner.prefixes {
            records.push(WalRecord::PrefixPut { pid, desc: desc.clone() });
        }
        inner.wal.checkpoint(&records)?;
        Ok(CheckpointSummary {
            sessions: inner.sessions.len(),
            prefixes: inner.prefixes.len(),
            blocks: inner.blocks.len(),
            pages: inner.heap.num_pages() as usize,
            elapsed_us: t0.elapsed().as_micros() as u64,
        })
    }
}

impl StoreInner {
    fn release_block(&mut self, id: u64) {
        let Some(meta) = self.blocks.get_mut(&id) else {
            debug_assert!(false, "release of unknown block {id}");
            return;
        };
        meta.refs -= 1;
        if meta.refs > 0 {
            return;
        }
        let meta = self.blocks.remove(&id).expect("meta was just read");
        if let Err(e) = self.heap.delete(meta.rec) {
            eprintln!("kvstore: failed to delete block {id}: {e:#}");
        }
        if let Err(e) = self.wal.append(&WalRecord::BlockDel { id }) {
            eprintln!("kvstore: failed to journal bdel {id}: {e:#}");
        }
    }

    /// Release every claim a superseded/removed descriptor held: one per
    /// block reference, plus its sidecar records.
    fn release_desc(&mut self, desc: &Json) {
        let mut blocks = Vec::new();
        let mut sidecars = Vec::new();
        for_each_ref(desc, &mut |bid| blocks.push(bid));
        for_each_sidecar(desc, &mut |rec| sidecars.push(rec));
        for bid in blocks {
            self.release_block(bid);
        }
        for rec in sidecars {
            let rec = RecordId::from_u64(rec);
            self.limbo.remove(&rec);
            if let Err(e) = self.heap.delete(rec) {
                eprintln!("kvstore: failed to delete sidecar: {e:#}");
            }
        }
    }

    /// A descriptor is being journaled: its sidecars are now owned.
    fn commit_sidecars(&mut self, desc: &Json) {
        let mut sidecars = Vec::new();
        for_each_sidecar(desc, &mut |rec| sidecars.push(rec));
        for rec in sidecars {
            self.limbo.remove(&RecordId::from_u64(rec));
        }
    }

    /// Evict cold inventory until the page file's in-use bytes fit the
    /// cap (no-op when unbounded).  Eviction targets *descriptors*, never
    /// block records directly: a spilled block a live handle still claims
    /// keeps its payload (refs stay positive) and only loses the
    /// descriptor's claim, while unshared payloads unwind through
    /// `release_block`, which deletes the record and journals `bdel` —
    /// replay never resurrects an evicted block.  Prefix snapshots go
    /// before sessions (they are pure recompute), LRU within each class;
    /// the single most-recently-stamped descriptor is never evicted (the
    /// cap must not cannibalize the write that triggered it), so like any
    /// LRU the cap is exceeded by at most one working set.  Returns the
    /// number of descriptors evicted.
    fn enforce_cap(&mut self) -> usize {
        let Some(cap) = self.max_bytes else {
            return 0;
        };
        let mut evicted = 0;
        while self.heap.used_bytes() > cap {
            let hottest =
                self.prefix_stamp.values().chain(self.session_stamp.values()).copied().max();
            let pick_prefix =
                coldest(&self.prefix_stamp).filter(|pid| Some(self.prefix_stamp[pid]) != hottest);
            if let Some(pid) = pick_prefix {
                self.prefix_stamp.remove(&pid);
                if let Some(old) = self.prefixes.remove(&pid) {
                    if let Err(e) = self.wal.append(&WalRecord::PrefixDel { pid }) {
                        eprintln!("kvstore: failed to journal evicted prefix {pid}: {e:#}");
                    }
                    self.release_desc(&old);
                    eprintln!("kvstore: store cap: evicted cold prefix snapshot {pid}");
                    evicted += 1;
                }
                continue;
            }
            let pick_session =
                coldest(&self.session_stamp).filter(|sid| Some(self.session_stamp[sid]) != hottest);
            if let Some(sid) = pick_session {
                self.session_stamp.remove(&sid);
                if let Some(old) = self.sessions.remove(&sid) {
                    if let Err(e) = self.wal.append(&WalRecord::SessionDel { id: sid.clone() }) {
                        eprintln!("kvstore: failed to journal evicted session {sid:?}: {e:#}");
                    }
                    self.release_desc(&old);
                    eprintln!("kvstore: store cap: evicted cold session {sid:?}");
                    evicted += 1;
                }
                continue;
            }
            break; // nothing evictable remains; live-handle payloads stay
        }
        evicted
    }
}

/// The least-recently-stamped key in an LRU stamp map.
fn coldest<K: Clone + Eq + std::hash::Hash>(stamps: &HashMap<K, u64>) -> Option<K> {
    stamps.iter().min_by_key(|(_, &t)| t).map(|(k, _)| k.clone())
}

/// Visit every block id (`fb` arrays) in a descriptor's cache tree.
fn for_each_ref(desc: &Json, on_block: &mut dyn FnMut(u64)) {
    walk_heads(desc, &mut |head| {
        if let Some(Ok(fb)) = head.opt("fb").map(|a| a.as_arr()) {
            for id in fb {
                if let Ok(n) = id.as_i64() {
                    on_block(n as u64);
                }
            }
        }
    });
}

/// Visit every sidecar record id (`sc` fields) in a descriptor.
fn for_each_sidecar(desc: &Json, on_sidecar: &mut dyn FnMut(u64)) {
    walk_heads(desc, &mut |head| {
        if let Some(Ok(sc)) = head.opt("sc").map(|s| s.as_i64()) {
            if sc != 0 {
                on_sidecar(sc as u64);
            }
        }
    });
}

fn walk_heads(desc: &Json, f: &mut dyn FnMut(&Json)) {
    let layers = desc
        .opt("cache")
        .and_then(|c| c.opt("layers"))
        .and_then(|l| l.as_arr().ok());
    let Some(layers) = layers else { return };
    for layer in layers {
        let Some(heads) = layer.opt("heads").and_then(|h| h.as_arr().ok()) else { continue };
        for head in heads {
            f(head);
        }
    }
}

/// Can every payload this descriptor references be read back?
fn desc_is_valid(
    desc: &Json,
    blocks: &HashMap<u64, BlockMeta>,
    heap: &mut RecordHeap,
    block_ok: &mut HashMap<u64, bool>,
) -> bool {
    let mut ok = true;
    let mut bids = Vec::new();
    let mut sidecars = Vec::new();
    for_each_ref(desc, &mut |bid| bids.push(bid));
    for_each_sidecar(desc, &mut |rec| sidecars.push(rec));
    for bid in bids {
        let good = *block_ok.entry(bid).or_insert_with(|| match blocks.get(&bid) {
            Some(meta) => heap
                .get(meta.rec)
                .map(|data| data.len() == BLOCK_HEADER + meta.bytes)
                .unwrap_or(false),
            None => false,
        });
        ok &= good;
    }
    for rec in sidecars {
        ok &= heap.get(RecordId::from_u64(rec)).is_ok();
    }
    ok
}

// -- binary block serialization (little-endian) --------------------------------

/// `[rows u32][d u32]` ahead of the payload.
const BLOCK_HEADER: usize = 8;

fn encode_block(rows: usize, d: usize, k: &[f32], v: &[f32], pos: &[i32], attn: &[f32]) -> Vec<u8> {
    debug_assert_eq!(k.len(), rows * d);
    debug_assert_eq!(v.len(), rows * d);
    debug_assert_eq!(pos.len(), rows);
    debug_assert_eq!(attn.len(), rows);
    let mut out = Vec::with_capacity(BLOCK_HEADER + (k.len() + v.len() + attn.len()) * 4 + pos.len() * 4);
    out.extend_from_slice(&(rows as u32).to_le_bytes());
    out.extend_from_slice(&(d as u32).to_le_bytes());
    for x in k {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for p in pos {
        out.extend_from_slice(&p.to_le_bytes());
    }
    for x in attn {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn take_f32s(buf: &[u8], off: &mut usize, n: usize) -> Result<Vec<f32>> {
    let end = *off + n * 4;
    let slice = buf.get(*off..end).ok_or_else(|| anyhow!("short block record"))?;
    let out = slice.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
    *off = end;
    Ok(out)
}

fn decode_block(buf: &[u8]) -> Result<BlockPayload> {
    if buf.len() < BLOCK_HEADER {
        bail!("block record shorter than its header");
    }
    let rows = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    let d = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    let mut off = BLOCK_HEADER;
    let k = take_f32s(buf, &mut off, rows * d)?;
    let v = take_f32s(buf, &mut off, rows * d)?;
    let pos_bytes = buf.get(off..off + rows * 4).ok_or_else(|| anyhow!("short block record"))?;
    let pos: Vec<i32> =
        pos_bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
    off += rows * 4;
    let attn = take_f32s(buf, &mut off, rows)?;
    if off != buf.len() {
        bail!("block record has {} trailing bytes", buf.len() - off);
    }
    Ok(BlockPayload { rows, d, k, v, pos, attn })
}

/// Quantized record layout, sharing the fp32 8-byte dims header so
/// `desc_is_valid`'s `header + bytes` length check covers both:
/// `[rows u32][d u32][dlen u32][slen u32][data][sidecar][pos i32×rows][attn f32×rows]`.
fn encode_quant_block(
    rows: usize,
    d: usize,
    enc: &EncodedKv,
    pos: &[i32],
    attn: &[f32],
) -> Vec<u8> {
    debug_assert_eq!(pos.len(), rows);
    debug_assert_eq!(attn.len(), rows);
    let mut out =
        Vec::with_capacity(BLOCK_HEADER + 8 + enc.data.len() + enc.sidecar.len() + rows * 8);
    out.extend_from_slice(&(rows as u32).to_le_bytes());
    out.extend_from_slice(&(d as u32).to_le_bytes());
    out.extend_from_slice(&(enc.data.len() as u32).to_le_bytes());
    out.extend_from_slice(&(enc.sidecar.len() as u32).to_le_bytes());
    out.extend_from_slice(&enc.data);
    out.extend_from_slice(&enc.sidecar);
    for p in pos {
        out.extend_from_slice(&p.to_le_bytes());
    }
    for x in attn {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn decode_quant_block(buf: &[u8], codec: u8) -> Result<QuantBlockPayload> {
    if buf.len() < BLOCK_HEADER + 8 {
        bail!("quant block record shorter than its header");
    }
    let rows = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    let d = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    let dlen = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize;
    let slen = u32::from_le_bytes([buf[12], buf[13], buf[14], buf[15]]) as usize;
    let mut off = BLOCK_HEADER + 8;
    let data =
        buf.get(off..off + dlen).ok_or_else(|| anyhow!("short quant block record"))?.to_vec();
    off += dlen;
    let sidecar =
        buf.get(off..off + slen).ok_or_else(|| anyhow!("short quant block record"))?.to_vec();
    off += slen;
    let pos_bytes =
        buf.get(off..off + rows * 4).ok_or_else(|| anyhow!("short quant block record"))?;
    let pos: Vec<i32> =
        pos_bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
    off += rows * 4;
    let attn = take_f32s(buf, &mut off, rows)?;
    if off != buf.len() {
        bail!("quant block record has {} trailing bytes", buf.len() - off);
    }
    Ok(QuantBlockPayload { rows, d, codec, data, sidecar, pos, attn })
}

#[cfg(test)]
pub(crate) mod testutil {
    use std::path::{Path, PathBuf};
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Unique per-test directory under the system tempdir, removed on
    /// drop — the hermetic tier leaves zero repo-root artifacts.
    pub struct TempDir {
        path: PathBuf,
    }

    impl TempDir {
        pub fn new(tag: &str) -> TempDir {
            static SEQ: AtomicUsize = AtomicUsize::new(0);
            let n = SEQ.fetch_add(1, Ordering::Relaxed);
            let path = std::env::temp_dir()
                .join(format!("lagkv-{}-{}-{}", tag, std::process::id(), n));
            std::fs::create_dir_all(&path).unwrap();
            TempDir { path }
        }

        pub fn path(&self) -> &Path {
            &self.path
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::TempDir;
    use super::*;
    use crate::util::json;

    fn payload(rows: usize, d: usize, salt: f32) -> (Vec<f32>, Vec<f32>, Vec<i32>, Vec<f32>) {
        let k: Vec<f32> = (0..rows * d).map(|i| i as f32 + salt).collect();
        let v: Vec<f32> = k.iter().map(|x| -x * 0.5).collect();
        let pos: Vec<i32> = (0..rows as i32).collect();
        // deliberately include non-finite bits: binary storage must keep them
        let mut attn = vec![0.25f32; rows];
        attn[0] = f32::INFINITY;
        (k, v, pos, attn)
    }

    fn head_desc(blocks: &[u64], sc: u64) -> Json {
        json::obj(vec![(
            "cache",
            json::obj(vec![(
                "layers",
                json::arr(vec![json::obj(vec![(
                    "heads",
                    json::arr(vec![json::obj(vec![
                        ("fb", json::arr(blocks.iter().map(|&b| json::n(b as f64)).collect())),
                        ("sc", json::n(sc as f64)),
                    ])]),
                )])]),
            )]),
        )])
    }

    #[test]
    fn block_codec_is_bit_exact() {
        let (k, v, pos, attn) = payload(4, 3, 0.125);
        let enc = encode_block(4, 3, &k, &v, &pos, &attn);
        let dec = decode_block(&enc).unwrap();
        assert_eq!(dec.rows, 4);
        assert_eq!(dec.d, 3);
        assert_eq!(dec.k.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                   k.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
        assert_eq!(dec.v, v);
        assert_eq!(dec.pos, pos);
        assert!(dec.attn[0].is_infinite(), "non-finite f32 bits survive");
    }

    #[test]
    fn block_lifecycle_spans_reopen() {
        let dir = TempDir::new("store");
        let (k, v, pos, attn) = payload(4, 2, 1.0);
        let id = {
            let store = KvStore::open(dir.path()).unwrap();
            let id = store.persist_block(4, 2, &k, &v, &pos, &attn).unwrap();
            // a journaled descriptor keeps the block alive across restart
            store.retain_block(id);
            store
                .journal_session_put("s1", head_desc(&[id], 0))
                .unwrap();
            store.release_block(id); // the live handle drops with the process
            store.checkpoint().unwrap();
            id
        };
        let store = KvStore::open(dir.path()).unwrap();
        assert_eq!(store.inventory_counts(), (1, 0, 1));
        let got = store.read_block(id).unwrap();
        assert_eq!(got.k, k);
        assert_eq!(got.v, v);
        assert_eq!(got.pos, pos);
        assert_eq!(store.block_dims(id), Some((4, 2, got.k.len() * 4 + got.v.len() * 4 + 4 * 8)));
        // removing the session releases the last claim: block gone
        assert!(store.journal_session_remove("s1").unwrap());
        assert!(store.read_block(id).is_err());
        assert_eq!(store.inventory_counts(), (0, 0, 0));
    }

    #[test]
    fn unreferenced_blocks_are_gced_at_open() {
        let dir = TempDir::new("store-gc");
        {
            let store = KvStore::open(dir.path()).unwrap();
            let (k, v, pos, attn) = payload(2, 2, 0.0);
            // spilled by a live cache, never journaled into a descriptor:
            // the live handle dies with the process
            store.persist_block(2, 2, &k, &v, &pos, &attn).unwrap();
            store.checkpoint().unwrap();
        }
        let store = KvStore::open(dir.path()).unwrap();
        assert_eq!(store.inventory_counts(), (0, 0, 0), "orphan block was collected");
    }

    #[test]
    fn superseding_a_session_releases_the_old_claims() {
        let dir = TempDir::new("store-supersede");
        let store = KvStore::open(dir.path()).unwrap();
        let (k, v, pos, attn) = payload(2, 2, 0.0);
        let a = store.persist_block(2, 2, &k, &v, &pos, &attn).unwrap();
        store.retain_block(a);
        let sc_a = store.put_blob(b"tail-a").unwrap();
        store.journal_session_put("s", head_desc(&[a], sc_a)).unwrap();
        // turn 2: same block (still claimed) plus a new one and a new tail
        let b = store.persist_block(2, 2, &v, &k, &pos, &attn).unwrap();
        store.retain_block(a);
        store.retain_block(b);
        let sc_b = store.put_blob(b"tail-b").unwrap();
        store.journal_session_put("s", head_desc(&[a, b], sc_b)).unwrap();
        assert!(store.read_blob(sc_a).is_err(), "old sidecar deleted on supersede");
        assert_eq!(store.read_blob(sc_b).unwrap(), b"tail-b");
        let (_, _, blocks) = store.inventory_counts();
        assert_eq!(blocks, 2);
        // drop the live handles, then the session: everything unwinds
        store.release_block(a);
        store.release_block(b);
        store.journal_session_remove("s").unwrap();
        assert_eq!(store.inventory_counts(), (0, 0, 0));
    }

    #[test]
    fn crash_replay_without_checkpoint_keeps_flushed_state() {
        let dir = TempDir::new("store-crash");
        let (k, v, pos, attn) = payload(2, 3, 2.0);
        {
            let store = KvStore::open(dir.path()).unwrap();
            let id = store.persist_block(2, 3, &k, &v, &pos, &attn).unwrap();
            store.retain_block(id);
            store.journal_session_put("crashy", head_desc(&[id], 0)).unwrap();
            // flush pages the way a checkpoint would, but *without* the
            // journal rewrite — then "crash" (drop without cleanup)
            store.checkpoint().unwrap();
            let pid = store.journal_prefix_put(head_desc(&[id], 0));
            // the prefix put retains nothing extra here: invalid on
            // replay only if its payloads are unreadable — they are
            // readable, so it survives; but we did not retain the block
            // for it, which open() tolerates by recounting refs itself
            let _ = pid;
        }
        let store = KvStore::open(dir.path()).unwrap();
        let (sessions, prefixes, blocks) = store.inventory_counts();
        assert_eq!((sessions, blocks), (1, 1));
        assert_eq!(prefixes, 1, "journal tail after the checkpoint replays too");
    }

    #[test]
    fn quant_block_round_trips_encoded_bytes_across_reopen() {
        let dir = TempDir::new("store-quant");
        let enc = EncodedKv { data: vec![1u8, 2, 255, 0, 17, 3, 4, 5], sidecar: vec![9u8; 16] };
        let pos: Vec<i32> = vec![0, 1];
        let attn = vec![0.5f32, f32::INFINITY];
        let id = {
            let store = KvStore::open(dir.path()).unwrap();
            let id = store
                .persist_quant_block(2, 2, CodecKind::Int8Sym, &enc, &pos, &attn)
                .unwrap();
            store.retain_block(id);
            store.journal_session_put("q1", head_desc(&[id], 0)).unwrap();
            store.release_block(id);
            store.checkpoint().unwrap();
            id
        };
        let store = KvStore::open(dir.path()).unwrap();
        assert_eq!(store.block_codec(id), Some(CodecKind::Int8Sym.tag()), "codec survives replay");
        let got = store.read_quant_block(id).unwrap();
        assert_eq!(got.data, enc.data, "encoded bytes are bit-identical");
        assert_eq!(got.sidecar, enc.sidecar);
        assert_eq!(got.pos, pos);
        assert!(got.attn[1].is_infinite());
        assert!(store.read_block(id).is_err(), "the fp32 reader refuses a quant record");
        store.journal_session_remove("q1").unwrap();
        assert_eq!(store.inventory_counts(), (0, 0, 0));
    }

    #[test]
    fn store_cap_evicts_cold_descriptors_lru() {
        let dir = TempDir::new("store-cap");
        // each ~8.3 KiB block spans two 8 KiB pages; a two-page cap fits
        // one block's inventory but not two
        let (k, v, pos, attn) = payload(32, 32, 0.0);
        let store = KvStore::open_with_cap(dir.path(), Some(2 * 8192)).unwrap();
        let a = store.persist_block(32, 32, &k, &v, &pos, &attn).unwrap();
        store.retain_block(a);
        let _pid = store.journal_prefix_put(head_desc(&[a], 0)).unwrap();
        store.release_block(a); // only the prefix claim keeps block a
        let b = store.persist_block(32, 32, &v, &k, &pos, &attn).unwrap();
        store.retain_block(b);
        store.journal_session_put("hot", head_desc(&[b], 0)).unwrap();
        // the cold prefix was evicted to make room: pdel + bdel journaled,
        // its unshared block gone; the freshly stamped session is never
        // self-evicted
        let (sessions, prefixes, _) = store.inventory_counts();
        assert_eq!((sessions, prefixes), (1, 0), "cold prefix evicted before the session");
        assert!(store.read_block(a).is_err(), "evicted prefix released its block");
        assert!(store.read_block(b).is_ok(), "the hot payload survives");
        // replay never resurrects the evicted inventory
        store.release_block(b);
        drop(store);
        let store = KvStore::open_with_cap(dir.path(), Some(2 * 8192)).unwrap();
        let (sessions, prefixes, _) = store.inventory_counts();
        assert_eq!(prefixes, 0, "pdel/bdel kept the eviction durable");
        assert_eq!(sessions, 1, "the survivor is intact after reopen");
    }

    #[test]
    fn checkpoint_sweeps_orphaned_records() {
        let dir = TempDir::new("store-sweep");
        let store = KvStore::open(dir.path()).unwrap();
        let sc = store.put_blob(b"limbo bytes").unwrap();
        store.checkpoint().unwrap();
        assert_eq!(store.read_blob(sc).unwrap(), b"limbo bytes", "limbo survives checkpoint");
        store.abort_blobs(&[sc]);
        assert!(store.read_blob(sc).is_err(), "aborted blob is deleted");
    }
}

//! Write-ahead journal for the store's *inventory*: which blocks,
//! sessions, and prefix snapshots exist, and where their payloads live.
//!
//! One JSON object per line, append-only:
//!
//! ```text
//! {"op":"blk","id":7,"rec":131072,"rows":16,"d":64,"bytes":8320}
//! {"op":"bdel","id":7}
//! {"op":"sput","id":"chat-7","desc":{...}}
//! {"op":"srem","id":"chat-7"}
//! {"op":"pput","pid":3,"desc":{...}}
//! {"op":"pdel","pid":3}
//! ```
//!
//! Payload bytes (f32 KV data, sidecars) never pass through the journal —
//! JSON cannot carry `inf`/`NaN` bit patterns — only record ids into the
//! page store.  Replay folds the lines into the final inventory; a
//! truncated or garbled tail (torn final append) ends replay at the last
//! whole record instead of failing the boot.  A *checkpoint* rewrites the
//! journal to exactly the live inventory (tmp file + fsync + atomic
//! rename), which is also the store's compaction.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// `codec` is the block's [`CodecKind`] tag (0 = fp32).  Serialized
    /// as an optional `"q"` field so journals written before quantization
    /// existed replay unchanged (absent ⇒ 0).
    ///
    /// [`CodecKind`]: crate::quant::CodecKind
    BlockPut { id: u64, rec: u64, rows: usize, d: usize, bytes: usize, codec: u8 },
    BlockDel { id: u64 },
    SessionPut { id: String, desc: Json },
    SessionDel { id: String },
    PrefixPut { pid: u64, desc: Json },
    PrefixDel { pid: u64 },
}

impl WalRecord {
    pub fn to_line(&self) -> String {
        let v = match self {
            WalRecord::BlockPut { id, rec, rows, d, bytes, codec } => {
                let mut fields = vec![
                    ("op", json::s("blk")),
                    ("id", json::n(*id as f64)),
                    ("rec", json::n(*rec as f64)),
                    ("rows", json::n(*rows as f64)),
                    ("d", json::n(*d as f64)),
                    ("bytes", json::n(*bytes as f64)),
                ];
                if *codec != 0 {
                    fields.push(("q", json::n(*codec as f64)));
                }
                json::obj(fields)
            }
            WalRecord::BlockDel { id } => {
                json::obj(vec![("op", json::s("bdel")), ("id", json::n(*id as f64))])
            }
            WalRecord::SessionPut { id, desc } => json::obj(vec![
                ("op", json::s("sput")),
                ("id", json::s(id.clone())),
                ("desc", desc.clone()),
            ]),
            WalRecord::SessionDel { id } => {
                json::obj(vec![("op", json::s("srem")), ("id", json::s(id.clone()))])
            }
            WalRecord::PrefixPut { pid, desc } => json::obj(vec![
                ("op", json::s("pput")),
                ("pid", json::n(*pid as f64)),
                ("desc", desc.clone()),
            ]),
            WalRecord::PrefixDel { pid } => {
                json::obj(vec![("op", json::s("pdel")), ("pid", json::n(*pid as f64))])
            }
        };
        v.to_string()
    }

    pub fn from_line(line: &str) -> Result<WalRecord> {
        let v = Json::parse(line)?;
        let op = v.get("op")?.as_str()?;
        Ok(match op {
            "blk" => WalRecord::BlockPut {
                id: v.get("id")?.as_i64()? as u64,
                rec: v.get("rec")?.as_i64()? as u64,
                rows: v.get("rows")?.as_usize()?,
                d: v.get("d")?.as_usize()?,
                bytes: v.get("bytes")?.as_usize()?,
                codec: match v.opt("q") {
                    Some(q) => q.as_i64()? as u8,
                    None => 0, // pre-quantization journal line
                },
            },
            "bdel" => WalRecord::BlockDel { id: v.get("id")?.as_i64()? as u64 },
            "sput" => WalRecord::SessionPut {
                id: v.get("id")?.as_str()?.to_string(),
                desc: v.get("desc")?.clone(),
            },
            "srem" => WalRecord::SessionDel { id: v.get("id")?.as_str()?.to_string() },
            "pput" => WalRecord::PrefixPut {
                pid: v.get("pid")?.as_i64()? as u64,
                desc: v.get("desc")?.clone(),
            },
            "pdel" => WalRecord::PrefixDel { pid: v.get("pid")?.as_i64()? as u64 },
            other => bail!("unknown WAL op {other:?}"),
        })
    }
}

pub struct Wal {
    path: PathBuf,
    out: BufWriter<File>,
}

impl Wal {
    /// Open the journal for appending (creating it if missing).  Call
    /// [`Wal::replay`] *before* this to read the existing records.
    pub fn open(path: &Path) -> Result<Wal> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("open journal {}", path.display()))?;
        Ok(Wal { path: path.to_path_buf(), out: BufWriter::new(file) })
    }

    /// Fold the journal into its surviving records.  Stops quietly at the
    /// first unparsable line (a torn tail from a crash mid-append).
    pub fn replay(path: &Path) -> Result<Vec<WalRecord>> {
        let mut text = String::new();
        match File::open(path) {
            Ok(mut f) => {
                f.read_to_string(&mut text)
                    .with_context(|| format!("read journal {}", path.display()))?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(e).with_context(|| format!("open journal {}", path.display())),
        }
        let mut out = Vec::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            match WalRecord::from_line(line) {
                Ok(rec) => out.push(rec),
                Err(_) => break, // torn tail: everything before it is intact
            }
        }
        Ok(out)
    }

    /// Append one record.  Flushed to the OS immediately; durable to the
    /// device only at the next [`Wal::checkpoint`] (or OS writeback).
    pub fn append(&mut self, rec: &WalRecord) -> Result<()> {
        let mut line = rec.to_line();
        line.push('\n');
        self.out.write_all(line.as_bytes())?;
        self.out.flush()?;
        Ok(())
    }

    /// Atomically replace the journal with exactly `records`: write a tmp
    /// file, fsync it, rename over the live journal, reopen for append.
    pub fn checkpoint(&mut self, records: &[WalRecord]) -> Result<()> {
        let tmp = self.path.with_extension("tmp");
        {
            let mut f = BufWriter::new(File::create(&tmp)?);
            for rec in records {
                let mut line = rec.to_line();
                line.push('\n');
                f.write_all(line.as_bytes())?;
            }
            f.flush()?;
            f.get_ref().sync_data()?;
        }
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("swap journal {}", self.path.display()))?;
        let file = OpenOptions::new().append(true).open(&self.path)?;
        self.out = BufWriter::new(file);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::TempDir;
    use super::*;

    fn sample() -> Vec<WalRecord> {
        vec![
            WalRecord::BlockPut { id: 1, rec: 65536, rows: 16, d: 8, bytes: 1152, codec: 0 },
            WalRecord::BlockPut { id: 3, rec: 131072, rows: 16, d: 8, bytes: 416, codec: 1 },
            WalRecord::SessionPut {
                id: "chat-7".into(),
                desc: Json::parse(r#"{"pending":3,"turns":2}"#).unwrap(),
            },
            WalRecord::PrefixPut { pid: 9, desc: Json::parse(r#"{"tokens":[1,2,3]}"#).unwrap() },
            WalRecord::BlockDel { id: 1 },
            WalRecord::SessionDel { id: "chat-7".into() },
            WalRecord::PrefixDel { pid: 9 },
        ]
    }

    #[test]
    fn records_round_trip_as_lines() {
        for rec in sample() {
            let line = rec.to_line();
            assert_eq!(WalRecord::from_line(&line).unwrap(), rec, "round trip of {line}");
        }
    }

    #[test]
    fn pre_quantization_blk_lines_parse_as_fp32() {
        // a journal written before the codec field existed has no "q"
        let line = r#"{"op":"blk","id":5,"rec":256,"rows":4,"d":2,"bytes":96}"#;
        assert_eq!(
            WalRecord::from_line(line).unwrap(),
            WalRecord::BlockPut { id: 5, rec: 256, rows: 4, d: 2, bytes: 96, codec: 0 }
        );
        // and fp32 lines written today stay byte-compatible with it
        let rec = WalRecord::BlockPut { id: 5, rec: 256, rows: 4, d: 2, bytes: 96, codec: 0 };
        assert!(!rec.to_line().contains("\"q\""), "fp32 omits the codec field");
    }

    #[test]
    fn append_and_replay() {
        let dir = TempDir::new("wal");
        let path = dir.path().join("wal.log");
        {
            let mut wal = Wal::open(&path).unwrap();
            for rec in sample() {
                wal.append(&rec).unwrap();
            }
        }
        assert_eq!(Wal::replay(&path).unwrap(), sample());
        assert_eq!(Wal::replay(&dir.path().join("missing")).unwrap(), vec![]);
    }

    #[test]
    fn torn_tail_ends_replay_cleanly() {
        let dir = TempDir::new("wal-torn");
        let path = dir.path().join("wal.log");
        {
            let mut wal = Wal::open(&path).unwrap();
            for rec in sample() {
                wal.append(&rec).unwrap();
            }
        }
        // simulate a crash mid-append: chop the file inside the last line
        let text = std::fs::read(&path).unwrap();
        std::fs::write(&path, &text[..text.len() - 4]).unwrap();
        let got = Wal::replay(&path).unwrap();
        assert_eq!(got, sample()[..sample().len() - 1].to_vec());
    }

    #[test]
    fn checkpoint_rewrites_atomically() {
        let dir = TempDir::new("wal-ckpt");
        let path = dir.path().join("wal.log");
        let mut wal = Wal::open(&path).unwrap();
        for rec in sample() {
            wal.append(&rec).unwrap();
        }
        let compacted =
            vec![WalRecord::BlockPut { id: 2, rec: 4, rows: 4, d: 2, bytes: 96, codec: 0 }];
        wal.checkpoint(&compacted).unwrap();
        // post-checkpoint appends land after the compacted inventory
        wal.append(&WalRecord::BlockDel { id: 2 }).unwrap();
        drop(wal);
        let got = Wal::replay(&path).unwrap();
        assert_eq!(got, vec![compacted[0].clone(), WalRecord::BlockDel { id: 2 }]);
        assert!(!dir.path().join("wal.tmp").exists(), "tmp file is consumed by the rename");
    }
}

//! Slotted pages: the fixed-size on-disk unit of the KV store.
//!
//! Layout (little-endian, 8 KiB):
//!
//! ```text
//! ┌─────────────────────────────────────────────┐
//! │ Header (14 bytes)                           │
//! │   kind u16 | page_id u32 | n_slots u16      │
//! │   free_off u16 | next u32                   │
//! ├─────────────────────────────────────────────┤
//! │ Slot directory (grows downward)             │
//! │   [offset u16, len u16] per record          │
//! ├─────────────────────────────────────────────┤
//! │ Free space                                  │
//! ├─────────────────────────────────────────────┤
//! │ Record payloads (grow upward from page end) │
//! └─────────────────────────────────────────────┘
//! ```
//!
//! `next` chains overflow pages: one frozen KV block (16 rows × d=64 ≈
//! 8.3 KiB of payload) does not fit a single page, so a record's head
//! fragment lives in a slotted page and the remainder spills across raw
//! [`PageKind::Overflow`] pages whose whole body past the header is
//! payload.  Deleting a slot compacts the payload region in place; slot
//! indices stay stable (record ids embed them) and dead slots are reused
//! by later inserts.

pub const PAGE_SIZE: usize = 8192;
pub const HEADER_LEN: usize = 14;
pub const SLOT_LEN: usize = 4;
/// Payload capacity of one overflow page (everything past the header).
pub const OVERFLOW_CAP: usize = PAGE_SIZE - HEADER_LEN;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u16)]
pub enum PageKind {
    /// On the free list: contents are garbage.
    Free = 0,
    /// Slotted record page (head fragments).
    Slotted = 1,
    /// Raw continuation payload of an oversized record.
    Overflow = 2,
}

impl PageKind {
    pub fn from_u16(v: u16) -> Option<PageKind> {
        match v {
            0 => Some(PageKind::Free),
            1 => Some(PageKind::Slotted),
            2 => Some(PageKind::Overflow),
            _ => None,
        }
    }
}

/// One in-memory page image.
pub struct Page {
    data: Box<[u8; PAGE_SIZE]>,
}

impl Default for Page {
    fn default() -> Page {
        Page::new()
    }
}

impl Page {
    pub fn new() -> Page {
        Page { data: Box::new([0u8; PAGE_SIZE]) }
    }

    pub fn bytes(&self) -> &[u8] {
        &self.data[..]
    }

    pub fn bytes_mut(&mut self) -> &mut [u8] {
        &mut self.data[..]
    }

    fn u16_at(&self, off: usize) -> u16 {
        u16::from_le_bytes([self.data[off], self.data[off + 1]])
    }

    fn set_u16(&mut self, off: usize, v: u16) {
        self.data[off..off + 2].copy_from_slice(&v.to_le_bytes());
    }

    fn u32_at(&self, off: usize) -> u32 {
        u32::from_le_bytes([self.data[off], self.data[off + 1], self.data[off + 2], self.data[off + 3]])
    }

    fn set_u32(&mut self, off: usize, v: u32) {
        self.data[off..off + 4].copy_from_slice(&v.to_le_bytes());
    }

    // -- header ----------------------------------------------------------------

    /// Reset to an empty page of the given kind.
    pub fn init(&mut self, kind: PageKind, page_id: u32) {
        self.data.fill(0);
        self.set_u16(0, kind as u16);
        self.set_u32(2, page_id);
        self.set_u16(6, 0); // n_slots
        self.set_u16(8, PAGE_SIZE as u16); // free_off (8192 fits u16)
        self.set_u32(10, 0); // next
    }

    pub fn kind(&self) -> Option<PageKind> {
        PageKind::from_u16(self.u16_at(0))
    }

    pub fn page_id(&self) -> u32 {
        self.u32_at(2)
    }

    pub fn n_slots(&self) -> u16 {
        self.u16_at(6)
    }

    fn free_off(&self) -> usize {
        self.u16_at(8) as usize
    }

    pub fn next(&self) -> u32 {
        self.u32_at(10)
    }

    pub fn set_next(&mut self, next: u32) {
        self.set_u32(10, next);
    }

    // -- slot directory --------------------------------------------------------

    fn slot_entry(&self, slot: u16) -> (usize, usize) {
        let base = HEADER_LEN + slot as usize * SLOT_LEN;
        (self.u16_at(base) as usize, self.u16_at(base + 2) as usize)
    }

    fn set_slot_entry(&mut self, slot: u16, off: usize, len: usize) {
        let base = HEADER_LEN + slot as usize * SLOT_LEN;
        self.set_u16(base, off as u16);
        self.set_u16(base + 2, len as u16);
    }

    fn dead_slot(&self) -> Option<u16> {
        (0..self.n_slots()).find(|&i| {
            let (off, _) = self.slot_entry(i);
            off == 0
        })
    }

    /// Count of live (non-deleted) slots.
    pub fn live_slots(&self) -> usize {
        (0..self.n_slots())
            .filter(|&i| {
                let (off, _) = self.slot_entry(i);
                off != 0
            })
            .count()
    }

    /// Bytes a new record payload could occupy right now, accounting for
    /// the slot-directory growth an insert may need.
    pub fn free_space(&self) -> usize {
        let dir_growth = if self.dead_slot().is_some() { 0 } else { SLOT_LEN };
        let dir_end = HEADER_LEN + self.n_slots() as usize * SLOT_LEN + dir_growth;
        self.free_off().saturating_sub(dir_end)
    }

    /// Insert a payload; returns its slot index, or `None` when it does
    /// not fit.  Reuses the lowest dead slot before growing the directory.
    pub fn insert(&mut self, payload: &[u8]) -> Option<u16> {
        if payload.is_empty() || payload.len() > self.free_space() {
            return None;
        }
        let off = self.free_off() - payload.len();
        let slot = match self.dead_slot() {
            Some(s) => s,
            None => {
                let s = self.n_slots();
                self.set_u16(6, s + 1);
                s
            }
        };
        self.data[off..off + payload.len()].copy_from_slice(payload);
        self.set_u16(8, off as u16);
        self.set_slot_entry(slot, off, payload.len());
        Some(slot)
    }

    pub fn read_slot(&self, slot: u16) -> Option<&[u8]> {
        if slot >= self.n_slots() {
            return None;
        }
        let (off, len) = self.slot_entry(slot);
        if off == 0 {
            return None;
        }
        Some(&self.data[off..off + len])
    }

    /// Delete a slot and compact the payload region so `free_space` stays
    /// exact.  Surviving slot indices (and so record ids) are unchanged.
    pub fn delete_slot(&mut self, slot: u16) {
        if slot >= self.n_slots() {
            return;
        }
        let (off, _) = self.slot_entry(slot);
        if off == 0 {
            return;
        }
        self.set_slot_entry(slot, 0, 0);
        self.compact();
    }

    /// Repack live payloads against the end of the page, highest offset
    /// first, so deleted space is reclaimed.  Moves are always toward
    /// higher addresses, which `copy_within` handles in place.
    fn compact(&mut self) {
        let mut live: Vec<(u16, usize, usize)> = (0..self.n_slots())
            .filter_map(|i| {
                let (off, len) = self.slot_entry(i);
                (off != 0).then_some((i, off, len))
            })
            .collect();
        live.sort_by(|a, b| b.1.cmp(&a.1));
        let mut dest = PAGE_SIZE;
        for (slot, off, len) in live {
            dest -= len;
            if dest != off {
                self.data.copy_within(off..off + len, dest);
                self.set_slot_entry(slot, dest, len);
            }
        }
        self.set_u16(8, dest as u16);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_and_header_round_trip() {
        let mut p = Page::new();
        p.init(PageKind::Slotted, 7);
        assert_eq!(p.kind(), Some(PageKind::Slotted));
        assert_eq!(p.page_id(), 7);
        assert_eq!(p.n_slots(), 0);
        assert_eq!(p.next(), 0);
        p.set_next(99);
        assert_eq!(p.next(), 99);
        assert_eq!(p.free_space(), PAGE_SIZE - HEADER_LEN - SLOT_LEN);
    }

    #[test]
    fn insert_read_delete_compacts() {
        let mut p = Page::new();
        p.init(PageKind::Slotted, 1);
        let a = p.insert(b"alpha").unwrap();
        let b = p.insert(b"beta-beta").unwrap();
        let c = p.insert(b"gamma").unwrap();
        assert_eq!((a, b, c), (0, 1, 2));
        assert_eq!(p.read_slot(b).unwrap(), b"beta-beta");
        let before = p.free_space();
        p.delete_slot(b);
        assert_eq!(p.read_slot(b), None);
        assert_eq!(p.free_space(), before + b"beta-beta".len(), "compaction reclaims space");
        // survivors kept their bytes and their slot ids
        assert_eq!(p.read_slot(a).unwrap(), b"alpha");
        assert_eq!(p.read_slot(c).unwrap(), b"gamma");
        // dead slot is reused before the directory grows
        let d = p.insert(b"delta").unwrap();
        assert_eq!(d, b);
        assert_eq!(p.n_slots(), 3);
        assert_eq!(p.live_slots(), 3);
    }

    #[test]
    fn insert_rejects_overflow() {
        let mut p = Page::new();
        p.init(PageKind::Slotted, 1);
        let cap = p.free_space();
        assert!(p.insert(&vec![1u8; cap + 1]).is_none());
        let slot = p.insert(&vec![2u8; cap]).unwrap();
        assert_eq!(p.free_space(), 0);
        assert_eq!(p.read_slot(slot).unwrap().len(), cap);
    }

    #[test]
    fn delete_all_empties_page() {
        let mut p = Page::new();
        p.init(PageKind::Slotted, 1);
        let a = p.insert(b"x").unwrap();
        let b = p.insert(b"y").unwrap();
        p.delete_slot(a);
        p.delete_slot(b);
        assert_eq!(p.live_slots(), 0);
        assert_eq!(p.free_space(), PAGE_SIZE - HEADER_LEN - SLOT_LEN * 2);
    }
}

//! # LagKV — attention-free KV-cache compression inside a Rust serving stack
//!
//! Reproduction of *"LagKV: Lag-Relative Information of the KV Cache Tells
//! Which Tokens Are Important"* (Liang et al., 2025) as a three-layer
//! system:
//!
//! * **L3 (this crate)** — serving coordinator: request router, continuous
//!   batcher, prefill/decode scheduler, and the KV-cache manager in which
//!   LagKV and its baselines live as pluggable eviction policies.  The
//!   public API is streaming- and session-first: requests yield typed
//!   [`coordinator::Event`] streams (cancellable mid-decode), and a
//!   [`coordinator::SessionStore`] carries the compressed cache across
//!   conversation turns so turn N+1 prefills only its new text.  The wire
//!   is the versioned `v1` protocol ([`api`], DESIGN.md §9) — typed
//!   request/response/event shapes plus an ops control plane
//!   (`stats`/`sessions`/`info`/`drain`) — consumed through the blocking
//!   client SDK in [`client`].
//! * **L2 (python/compile, build time only)** — a tiny GQA transformer in
//!   JAX, AOT-lowered to HLO text that the PJRT runtime loads.
//! * **L1 (python/compile/kernels)** — the LagKV scoring Pallas kernel,
//!   lowered into its own HLO artifact and cross-validated against the
//!   pure-Rust scorer in [`compress::scores`].
//!
//! Model execution is abstracted behind [`backend::ExecBackend`]:
//!
//! * the default **CPU reference backend** is pure Rust and hermetic — the
//!   whole stack (generation, continuous batching, recursive compression)
//!   runs under `cargo test` on a clean machine with no artifacts and no
//!   native libraries;
//! * the **XLA backend** (`--features xla`) is the PJRT path over the AOT
//!   HLO artifacts from `make artifacts`; python never runs on the request
//!   path — after `make artifacts` the `lagkv` binary is self-contained.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for measured
//! results.

pub mod api;
pub mod backend;
pub mod client;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod harness;
pub mod kvcache;
pub mod kvpool;
pub mod kvstore;
pub mod metrics;
pub mod quant;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod server;
pub mod sim;
pub mod telemetry;
pub mod tokenizer;
pub mod util;
pub mod workloads;

//! # LagKV — attention-free KV-cache compression inside a Rust serving stack
//!
//! Reproduction of *"LagKV: Lag-Relative Information of the KV Cache Tells
//! Which Tokens Are Important"* (Liang et al., 2025) as a three-layer
//! system:
//!
//! * **L3 (this crate)** — serving coordinator: request router, continuous
//!   batcher, prefill/decode scheduler, and the KV-cache manager in which
//!   LagKV and its baselines live as pluggable eviction policies.
//! * **L2 (python/compile, build time only)** — a tiny GQA transformer in
//!   JAX, AOT-lowered to HLO text that the [`runtime`] loads via PJRT.
//! * **L1 (python/compile/kernels)** — the LagKV scoring Pallas kernel,
//!   lowered into its own HLO artifact and cross-validated against the
//!   pure-Rust scorer in [`compress::scores`].
//!
//! Python never runs on the request path: after `make artifacts` the
//! `lagkv` binary is self-contained.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for measured
//! results.

pub mod config;
pub mod compress;
pub mod coordinator;
pub mod engine;
pub mod harness;
pub mod kvcache;
pub mod metrics;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod tokenizer;
pub mod util;
pub mod workloads;

//! Decode-slot state: one in-flight sequence inside a batch bucket.

use crate::compress::driver::CompressionEvent;
use crate::compress::Scorer;
use crate::config::CompressionConfig;
use crate::engine::ChunkedPrefill;
use crate::kvcache::KvCache;
use crate::tokenizer::EOS;

/// A live sequence occupying a decode slot.
pub struct SeqState {
    pub cache: KvCache,
    pub compression: CompressionConfig,
    pub scorer: Box<dyn Scorer>,
    /// Token to feed at the next decode step.
    pub next_token: i32,
    /// Everything generated so far (greedy), including the token produced
    /// by prefill and possibly a final EOS.
    pub generated: Vec<i32>,
    pub max_new: usize,
    pub done: bool,
    pub compression_events: usize,
    /// Compression events fired by the most recent decode step (replaced
    /// each step; the event-stream emitter drains it).
    pub step_events: Vec<CompressionEvent>,
}

impl SeqState {
    /// Record a newly generated token and update termination state.
    /// `tmax` bounds the absolute position (cache capacity guard).
    pub fn push_generated(&mut self, token: i32, tmax: usize) {
        if self.done {
            return;
        }
        // `next_token` was just consumed by the step; `token` is its output.
        self.next_token = token;
        self.generated.push(token);
        if token == EOS
            || self.generated.len() >= self.max_new
            || self.cache.appended + 1 >= tmax
        {
            self.done = true;
        }
    }

    pub fn generated_without_eos(&self) -> Vec<i32> {
        self.generated.iter().copied().filter(|&t| t != EOS).collect()
    }
}

/// A cold prefill occupying a slot segment-by-segment: the batcher
/// advances `chunked` between decode bursts and promotes the slot to a
/// [`SeqState`] when the last segment lands.
pub struct PrefillJob {
    pub chunked: ChunkedPrefill,
    pub scorer: Box<dyn Scorer>,
    pub compression: CompressionConfig,
    pub max_new: usize,
}

enum Occupant {
    /// Decodes garbage on a zeroed cache; outputs ignored (the
    /// executable's shape is fixed).
    Idle,
    /// A chunked cold prefill owns the slot but contributes nothing to
    /// decode steps yet (boxed: the job carries the whole prefill output).
    Prefilling(Box<PrefillJob>),
    /// A live (or just-finished) decoding sequence.
    Seq(SeqState),
}

/// A batch slot: decoding, prefilling in segments, or idle.
pub struct SlotState {
    occ: Occupant,
}

impl Default for SlotState {
    fn default() -> SlotState {
        SlotState::idle()
    }
}

impl SlotState {
    pub fn idle() -> SlotState {
        SlotState { occ: Occupant::Idle }
    }

    pub fn occupied(
        cache: KvCache,
        compression: CompressionConfig,
        scorer: Box<dyn Scorer>,
        first_token: i32,
        max_new: usize,
    ) -> SlotState {
        SlotState {
            occ: Occupant::Seq(SeqState {
                cache,
                compression,
                scorer,
                next_token: first_token,
                generated: Vec::new(),
                max_new,
                done: false,
                compression_events: 0,
                step_events: Vec::new(),
            }),
        }
    }

    /// Occupy the slot with a chunked cold prefill.
    pub fn prefilling(job: PrefillJob) -> SlotState {
        SlotState { occ: Occupant::Prefilling(Box::new(job)) }
    }

    pub fn active(&self) -> Option<&SeqState> {
        self.seq().filter(|s| !s.done)
    }

    pub fn active_mut(&mut self) -> Option<&mut SeqState> {
        self.seq_mut().filter(|s| !s.done)
    }

    /// The occupying sequence, finished or not (event emission needs to
    /// observe a sequence after its final step marks it done).
    pub fn seq(&self) -> Option<&SeqState> {
        match &self.occ {
            Occupant::Seq(s) => Some(s),
            _ => None,
        }
    }

    pub fn seq_mut(&mut self) -> Option<&mut SeqState> {
        match &mut self.occ {
            Occupant::Seq(s) => Some(s),
            _ => None,
        }
    }

    /// True while a chunked prefill owns the slot.
    pub fn is_prefilling(&self) -> bool {
        matches!(self.occ, Occupant::Prefilling(_))
    }

    pub fn prefill(&self) -> Option<&PrefillJob> {
        match &self.occ {
            Occupant::Prefilling(job) => Some(job),
            _ => None,
        }
    }

    pub fn prefill_mut(&mut self) -> Option<&mut PrefillJob> {
        match &mut self.occ {
            Occupant::Prefilling(job) => Some(job),
            _ => None,
        }
    }

    /// Remove a prefill job from the slot (promotion or abort), leaving
    /// it idle.  None when the slot holds no prefill.
    pub fn take_prefill(&mut self) -> Option<Box<PrefillJob>> {
        match std::mem::replace(&mut self.occ, Occupant::Idle) {
            Occupant::Prefilling(job) => Some(job),
            other => {
                self.occ = other;
                None
            }
        }
    }

    /// Occupied by anything — a sequence or an in-progress prefill.
    pub fn occupied_any(&self) -> bool {
        !matches!(self.occ, Occupant::Idle)
    }

    pub fn finished(&self) -> bool {
        self.seq().map(|s| s.done).unwrap_or(false)
    }

    pub fn take(&mut self) -> Option<SeqState> {
        match std::mem::replace(&mut self.occ, Occupant::Idle) {
            Occupant::Seq(s) => Some(s),
            other => {
                self.occ = other;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::policy::make_policy;
    use crate::config::PolicyKind;

    fn mk_slot(max_new: usize) -> SlotState {
        SlotState::occupied(
            KvCache::new(1, 1, 2),
            CompressionConfig::default(),
            make_policy(PolicyKind::LagKv, 0),
            7,
            max_new,
        )
    }

    #[test]
    fn terminates_on_eos() {
        let mut slot = mk_slot(100);
        slot.active_mut().unwrap().push_generated(9, 512);
        assert!(!slot.finished());
        slot.active_mut().unwrap().push_generated(EOS, 512);
        assert!(slot.finished());
        assert!(slot.active().is_none());
    }

    #[test]
    fn terminates_on_budget() {
        let mut slot = mk_slot(2);
        slot.active_mut().unwrap().push_generated(9, 512);
        slot.active_mut().unwrap().push_generated(9, 512);
        assert!(slot.finished());
    }

    #[test]
    fn eos_stripped_from_text_tokens() {
        let mut slot = mk_slot(5);
        let seq = slot.active_mut().unwrap();
        seq.push_generated(9, 512);
        seq.push_generated(EOS, 512);
        let seq = slot.take().unwrap();
        assert_eq!(seq.generated, vec![9, EOS]);
        assert_eq!(seq.generated_without_eos(), vec![9]);
    }

    #[test]
    fn idle_slot_is_inert() {
        let mut s = SlotState::idle();
        assert!(s.active().is_none());
        assert!(!s.occupied_any());
        assert!(!s.is_prefilling());
        assert!(!s.finished());
        assert!(s.take().is_none());
        assert!(s.take_prefill().is_none());
    }

    #[test]
    fn take_does_not_disturb_other_occupants() {
        // take() must not silently evict a prefill job, and take_prefill()
        // must not evict a sequence.
        let mut s = mk_slot(3);
        assert!(s.take_prefill().is_none());
        assert!(s.occupied_any(), "sequence survives a take_prefill miss");
        assert!(s.take().is_some());
        assert!(!s.occupied_any());
    }
}

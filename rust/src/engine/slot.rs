//! Decode-slot state: one in-flight sequence inside a batch bucket.

use crate::compress::driver::CompressionEvent;
use crate::compress::Scorer;
use crate::config::CompressionConfig;
use crate::kvcache::KvCache;
use crate::tokenizer::EOS;

/// A live sequence occupying a decode slot.
pub struct SeqState {
    pub cache: KvCache,
    pub compression: CompressionConfig,
    pub scorer: Box<dyn Scorer>,
    /// Token to feed at the next decode step.
    pub next_token: i32,
    /// Everything generated so far (greedy), including the token produced
    /// by prefill and possibly a final EOS.
    pub generated: Vec<i32>,
    pub max_new: usize,
    pub done: bool,
    pub compression_events: usize,
    /// Compression events fired by the most recent decode step (replaced
    /// each step; the event-stream emitter drains it).
    pub step_events: Vec<CompressionEvent>,
}

impl SeqState {
    /// Record a newly generated token and update termination state.
    /// `tmax` bounds the absolute position (cache capacity guard).
    pub fn push_generated(&mut self, token: i32, tmax: usize) {
        if self.done {
            return;
        }
        // `next_token` was just consumed by the step; `token` is its output.
        self.next_token = token;
        self.generated.push(token);
        if token == EOS
            || self.generated.len() >= self.max_new
            || self.cache.appended + 1 >= tmax
        {
            self.done = true;
        }
    }

    pub fn generated_without_eos(&self) -> Vec<i32> {
        self.generated.iter().copied().filter(|&t| t != EOS).collect()
    }
}

/// A batch slot: occupied or idle.  Idle slots decode garbage on a zeroed
/// cache; their outputs are ignored (the executable's shape is fixed).
#[derive(Default)]
pub struct SlotState {
    seq: Option<SeqState>,
}

impl SlotState {
    pub fn idle() -> SlotState {
        SlotState { seq: None }
    }

    pub fn occupied(
        cache: KvCache,
        compression: CompressionConfig,
        scorer: Box<dyn Scorer>,
        first_token: i32,
        max_new: usize,
    ) -> SlotState {
        SlotState {
            seq: Some(SeqState {
                cache,
                compression,
                scorer,
                next_token: first_token,
                generated: Vec::new(),
                max_new,
                done: false,
                compression_events: 0,
                step_events: Vec::new(),
            }),
        }
    }

    pub fn active(&self) -> Option<&SeqState> {
        self.seq.as_ref().filter(|s| !s.done)
    }

    pub fn active_mut(&mut self) -> Option<&mut SeqState> {
        self.seq.as_mut().filter(|s| !s.done)
    }

    /// The occupying sequence, finished or not (event emission needs to
    /// observe a sequence after its final step marks it done).
    pub fn seq(&self) -> Option<&SeqState> {
        self.seq.as_ref()
    }

    pub fn seq_mut(&mut self) -> Option<&mut SeqState> {
        self.seq.as_mut()
    }

    pub fn occupied_any(&self) -> bool {
        self.seq.is_some()
    }

    pub fn finished(&self) -> bool {
        self.seq.as_ref().map(|s| s.done).unwrap_or(false)
    }

    pub fn take(&mut self) -> Option<SeqState> {
        self.seq.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::policy::make_policy;
    use crate::config::PolicyKind;

    fn mk_slot(max_new: usize) -> SlotState {
        SlotState::occupied(
            KvCache::new(1, 1, 2),
            CompressionConfig::default(),
            make_policy(PolicyKind::LagKv, 0),
            7,
            max_new,
        )
    }

    #[test]
    fn terminates_on_eos() {
        let mut slot = mk_slot(100);
        slot.active_mut().unwrap().push_generated(9, 512);
        assert!(!slot.finished());
        slot.active_mut().unwrap().push_generated(EOS, 512);
        assert!(slot.finished());
        assert!(slot.active().is_none());
    }

    #[test]
    fn terminates_on_budget() {
        let mut slot = mk_slot(2);
        slot.active_mut().unwrap().push_generated(9, 512);
        slot.active_mut().unwrap().push_generated(9, 512);
        assert!(slot.finished());
    }

    #[test]
    fn eos_stripped_from_text_tokens() {
        let mut slot = mk_slot(5);
        let seq = slot.active_mut().unwrap();
        seq.push_generated(9, 512);
        seq.push_generated(EOS, 512);
        let seq = slot.take().unwrap();
        assert_eq!(seq.generated, vec![9, EOS]);
        assert_eq!(seq.generated_without_eos(), vec![9]);
    }

    #[test]
    fn idle_slot_is_inert() {
        let mut s = SlotState::idle();
        assert!(s.active().is_none());
        assert!(!s.occupied_any());
        assert!(!s.finished());
        assert!(s.take().is_none());
    }
}

//! Model engine: drives the AOT-compiled prefill/decode executables over
//! [`KvCache`]s with recursive compression — the bridge between the
//! coordinator (L3) and the compiled model (L2/L1).
//!
//! Responsibilities:
//! * load manifest + weights, compile executables on first use,
//! * single-sequence [`Engine::generate`] (greedy decoding),
//! * batched [`Engine::step_batch`] for the continuous batcher,
//! * fire the compression driver after prefill and after every appended
//!   token (the paper's "dynamically ... in both prefill and decode"),
//! * optional XLA-backed scoring ([`xla_scorer::XlaScorer`]) that runs the
//!   L1 Pallas kernel instead of the pure-Rust mirror.

pub mod slot;
pub mod xla_scorer;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::compress::{maybe_compress, policy::make_policy, Scorer};
use crate::config::{CompressionConfig, ModelDims, ScorerBackend};
use crate::kvcache::KvCache;
use crate::runtime::literals::argmax as argmax_slice;
use crate::runtime::{lit_f32, lit_i32, lit_i32_scalar, to_vec_f32, Runtime};
use crate::tokenizer::Tokenizer;

pub use slot::SlotState;

/// Result of a single-sequence generation.
#[derive(Debug, Clone)]
pub struct GenOutput {
    pub prompt_tokens: usize,
    pub tokens: Vec<i32>,
    pub text: String,
    /// Final per-layer cache lengths (compression evidence).
    pub cache_lens: Vec<usize>,
    /// Number of partition-compression events fired.
    pub compression_events: usize,
    pub prefill_us: u64,
    pub decode_us: u64,
}

pub struct Engine {
    pub rt: Runtime,
    pub dims: ModelDims,
    pub tokenizer: Tokenizer,
    pub variant: String,
    weights: Vec<xla::Literal>,
    prefill_buckets: Vec<usize>,
    decode_buckets: Vec<usize>,
    score_lags: Vec<usize>,
    pub tmax: usize,
}

impl Engine {
    /// `art_dir` = artifacts/, `variant` = "llama_like" | "qwen_like".
    pub fn load(art_dir: &Path, variant: &str) -> Result<Engine> {
        let rt = Runtime::open(art_dir)?;
        let dims = ModelDims::from_json(rt.manifest.get("model_config")?)?;
        let model_dir: PathBuf = art_dir.join("models").join(variant);
        let digits_per_token = match variant {
            "llama_like" => 3,
            "qwen_like" => 1,
            other => bail!("unknown model variant {other:?}"),
        };
        let tokenizer = Tokenizer::load(&model_dir, digits_per_token)
            .with_context(|| format!("loading tokenizer for {variant}"))?;
        if tokenizer.vocab.size() != dims.vocab_size {
            bail!(
                "vocab size mismatch: tokenizer {} vs model {}",
                tokenizer.vocab.size(),
                dims.vocab_size
            );
        }
        let weights = rt.load_weights(&model_dir)?;
        let prefill_buckets = rt.manifest.get("prefill_buckets")?.as_usize_vec()?;
        let decode_buckets = rt.manifest.get("decode_buckets")?.as_usize_vec()?;
        let score_lags = rt.manifest.get("score_lags")?.as_usize_vec()?;
        let tmax = rt.manifest.get("tmax")?.as_usize()?;
        Ok(Engine {
            rt,
            dims,
            tokenizer,
            variant: variant.to_string(),
            weights,
            prefill_buckets,
            decode_buckets,
            score_lags,
            tmax,
        })
    }

    pub fn decode_buckets(&self) -> &[usize] {
        &self.decode_buckets
    }

    /// Smallest prefill bucket that fits `n` tokens.
    pub fn pick_prefill_bucket(&self, n: usize) -> Result<usize> {
        self.prefill_buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| anyhow!("prompt of {n} tokens exceeds largest prefill bucket"))
    }

    /// Build the per-sequence scorer for a compression config.
    pub fn make_scorer(&self, cfg: &CompressionConfig, seed: u64) -> Box<dyn Scorer> {
        match cfg.scorer {
            ScorerBackend::Rust => make_policy(cfg.policy, seed),
            // Executables are Arc-cached inside the runtime, so the scorer
            // holds its own handles and does not borrow the engine.
            ScorerBackend::Xla => Box::new(xla_scorer::XlaScorer::new(
                self.score_exe_handles(),
                cfg.policy,
                seed,
                self.dims.n_kv_heads,
            )),
        }
    }

    fn score_exe_handles(&self) -> xla_scorer::ScoreExes {
        let mut map = std::collections::HashMap::new();
        for &l in &self.score_lags {
            if let Ok(exe) = self.rt.executable(&format!("lagkv_score_l{l}")) {
                map.insert(l, exe);
            }
        }
        xla_scorer::ScoreExes { by_lag: map }
    }

    /// Run prefill for a prompt; returns (last_logits, populated cache).
    pub fn prefill(&self, ids: &[i32]) -> Result<(Vec<f32>, KvCache)> {
        let bucket = self.pick_prefill_bucket(ids.len())?;
        let mut tokens = vec![0i32; bucket];
        tokens[..ids.len()].copy_from_slice(ids);
        // Literal path: see EXPERIMENTS.md §Perf — the device-resident
        // buffer path (execute_b) segfaults nondeterministically inside
        // this prebuilt xla_extension, so arguments go as literals.
        let mut args = self.weights.clone();
        args.push(lit_i32(&tokens, &[bucket])?);
        args.push(lit_i32_scalar(ids.len() as i32));
        let out = self.rt.execute(&format!("prefill_t{bucket}"), &args)?;
        if out.len() != 4 {
            bail!("prefill returned {} outputs, expected 4", out.len());
        }
        let logits = to_vec_f32(&out[0])?;
        let k = to_vec_f32(&out[1])?;
        let v = to_vec_f32(&out[2])?;
        let attn = to_vec_f32(&out[3])?;
        let mut cache = KvCache::new(self.dims.n_layers, self.dims.n_kv_heads, self.dims.d_head);
        cache.ingest_prefill(&k, &v, &attn, bucket, ids.len())?;
        Ok((logits, cache))
    }

    /// One batched decode step over `slots` (entries may be idle).
    /// Bucket = slots.len() and must be an exported decode bucket.
    pub fn step_batch(&self, slots: &mut [SlotState]) -> Result<()> {
        let b = slots.len();
        if !self.decode_buckets.contains(&b) {
            bail!("no decode executable for batch {b}");
        }
        let (nl, hkv, dh) = (self.dims.n_layers, self.dims.n_kv_heads, self.dims.d_head);
        let tmax = self.tmax;
        let per_slot = hkv * tmax * dh;

        // assemble K/V [nl, B, hkv, tmax, dh] + lens [nl, B] + pos/token [B]
        let mut kbuf = vec![0.0f32; nl * b * per_slot];
        let mut vbuf = vec![0.0f32; nl * b * per_slot];
        let mut lens = vec![0i32; nl * b];
        let mut pos = vec![0i32; b];
        let mut tok = vec![0i32; b];
        for (s, slot) in slots.iter().enumerate() {
            if let Some(seq) = slot.active() {
                for layer in 0..nl {
                    let (lk, lv) = seq.cache.layer_padded(layer, tmax);
                    let dst = (layer * b + s) * per_slot;
                    kbuf[dst..dst + per_slot].copy_from_slice(&lk);
                    vbuf[dst..dst + per_slot].copy_from_slice(&lv);
                    lens[layer * b + s] = seq.cache.len(layer) as i32;
                }
                pos[s] = seq.cache.appended as i32;
                tok[s] = seq.next_token;
            }
        }
        // Literal path (see EXPERIMENTS.md §Perf re: execute_b instability).
        let args: Vec<xla::Literal> = self
            .weights
            .iter()
            .cloned()
            .chain([
                lit_f32(&kbuf, &[nl, b, hkv, tmax, dh])?,
                lit_f32(&vbuf, &[nl, b, hkv, tmax, dh])?,
                lit_i32(&lens, &[nl, b])?,
                lit_i32(&pos, &[b])?,
                lit_i32(&tok, &[b])?,
            ])
            .collect();
        let out = self.rt.execute(&format!("decode_b{b}"), &args)?;
        if out.len() != 6 {
            bail!("decode returned {} outputs, expected 6", out.len());
        }
        let logits = to_vec_f32(&out[0])?; // [B, V]
        let k_new = to_vec_f32(&out[1])?; // [nl, B, hkv, dh]
        let v_new = to_vec_f32(&out[2])?;
        let attn_row = to_vec_f32(&out[5])?; // [nl, B, hkv, tmax]
        let v_size = self.dims.vocab_size;

        for (s, slot) in slots.iter_mut().enumerate() {
            let Some(seq) = slot.active_mut() else { continue };
            // extract this slot's k_new/v_new -> [nl, hkv, dh] flat
            let mut kn = Vec::with_capacity(nl * hkv * dh);
            let mut vn = Vec::with_capacity(nl * hkv * dh);
            for layer in 0..nl {
                let off = ((layer * b) + s) * hkv * dh;
                kn.extend_from_slice(&k_new[off..off + hkv * dh]);
                vn.extend_from_slice(&v_new[off..off + hkv * dh]);
            }
            let position = seq.cache.appended as i32;
            seq.cache.append_token(&kn, &vn, position)?;
            if seq.compression.policy.needs_attention() {
                let mut row = Vec::with_capacity(nl * hkv * tmax);
                for layer in 0..nl {
                    let off = ((layer * b) + s) * hkv * tmax;
                    row.extend_from_slice(&attn_row[off..off + hkv * tmax]);
                }
                seq.cache.accumulate_attention(&row, tmax)?;
            }
            let events =
                maybe_compress(&mut seq.cache, &seq.compression, seq.scorer.as_mut())?;
            seq.compression_events += events.len();

            let next = argmax_slice(&logits[s * v_size..(s + 1) * v_size]) as i32;
            seq.push_generated(next, self.tmax);
        }
        Ok(())
    }

    /// Greedy single-sequence generation with recursive compression.
    pub fn generate(
        &self,
        prompt: &str,
        cfg: &CompressionConfig,
        max_new: usize,
        seed: u64,
    ) -> Result<GenOutput> {
        let ids = self.tokenizer.encode(prompt, true);
        self.generate_ids(&ids, cfg, max_new, seed)
    }

    pub fn generate_ids(
        &self,
        ids: &[i32],
        cfg: &CompressionConfig,
        max_new: usize,
        seed: u64,
    ) -> Result<GenOutput> {
        let t0 = std::time::Instant::now();
        let (logits, cache) = self.prefill(ids)?;
        let prefill_us = t0.elapsed().as_micros() as u64;

        let scorer = self.make_scorer(cfg, seed);
        let first = argmax_slice(&logits) as i32;
        let mut slot = SlotState::occupied(cache, cfg.clone(), scorer, first, max_new);
        // prefill-stage recursive compression
        {
            let seq = slot.active_mut().unwrap();
            let events = maybe_compress(&mut seq.cache, cfg, seq.scorer.as_mut())?;
            seq.compression_events += events.len();
            seq.push_generated(first, self.tmax);
        }

        let t1 = std::time::Instant::now();
        let mut slots = vec![slot];
        while slots[0].active().map(|s| !s.done).unwrap_or(false) {
            self.step_batch(&mut slots)?;
        }
        let decode_us = t1.elapsed().as_micros() as u64;
        let seq = slots[0].take().unwrap();
        let text = self.tokenizer.decode(&seq.generated_without_eos());
        Ok(GenOutput {
            prompt_tokens: ids.len(),
            tokens: seq.generated.clone(),
            text,
            cache_lens: seq.cache.lens(),
            compression_events: seq.compression_events,
            prefill_us,
            decode_us,
        })
    }
}

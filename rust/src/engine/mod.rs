//! Model engine: drives an [`ExecBackend`] over [`KvCache`]s with recursive
//! compression — the bridge between the coordinator (L3) and the model,
//! whatever executes it.
//!
//! Responsibilities:
//! * single-sequence [`Engine::generate`] (greedy decoding),
//! * batched [`Engine::step_batch`] for the continuous batcher,
//! * fire the compression driver after prefill and after every appended
//!   token (the paper's "dynamically ... in both prefill and decode"),
//! * delegate scoring to the backend when it provides an accelerated
//!   scorer (the XLA Pallas kernel), falling back to the pure-Rust
//!   policies otherwise.
//!
//! The engine never names a backend type: all model execution goes through
//! [`crate::backend::ExecBackend`], so the same generation / batching /
//! compression code runs identically on the hermetic CPU reference backend
//! and on the PJRT artifact backend.

pub mod slot;

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::backend::{DecodeBatch, ExecBackend, PrefillOutput};
use crate::compress::driver::CompressionEvent;
use crate::compress::{maybe_compress, policy::make_policy, Scorer};
use crate::config::{CompressionConfig, ModelDims};
use crate::kvcache::KvCache;
use crate::kvpool::{BlockPool, PrefixCache, PrefixConfig};
use crate::quant::QuantSpec;
use crate::telemetry::{Clock, Metric, MonotonicClock, Telemetry};
use crate::tokenizer::Tokenizer;
use crate::util::argmax as argmax_slice;

pub use slot::{PrefillJob, SeqState, SlotState};

/// Result of a single-sequence generation.
#[derive(Debug, Clone)]
pub struct GenOutput {
    pub prompt_tokens: usize,
    /// Prompt tokens served from the engine's prefix cache (0 when the
    /// cache is disabled or missed).
    pub reused_tokens: usize,
    pub tokens: Vec<i32>,
    pub text: String,
    /// Final per-layer cache lengths (compression evidence).
    pub cache_lens: Vec<usize>,
    /// Number of partition-compression events fired.
    pub compression_events: usize,
    pub prefill_us: u64,
    pub decode_us: u64,
}

/// Result of [`Engine::prefill_cached`]: prefill plus the prefill-stage
/// recursive compression, with prefix-cache attribution.
pub struct PrefillOutcome {
    /// Next-token logits of the last prompt token.
    pub logits: Vec<f32>,
    pub cache: KvCache,
    /// Compression events fired during the prefill stage.
    pub events: Vec<CompressionEvent>,
    /// Prompt tokens attached from a radix prefix-cache snapshot instead
    /// of being run through the backend (0 on a cold prefill).
    pub reused_tokens: usize,
}

/// Segment granularity for chunked cold prefill when no prefix cache
/// dictates a snapshot stride: small enough that a decode burst slips in
/// between segments, large enough that the per-segment driver pass
/// amortizes.
pub const DEFAULT_PREFILL_STRIDE: usize = 64;

/// A started prefill: either already complete (warm prefix hit, or a
/// path-dependent policy that must run in one piece) or a cold prefill
/// whose ingest/compression continues in segments.
pub enum PrefillTask {
    Done(PrefillOutcome),
    Chunked(ChunkedPrefill),
}

/// A cold bucketed prefill split into `stride`-token ingest segments.
///
/// The backend compute already happened ([`Engine::begin_prefill`] holds
/// its [`PrefillOutput`]); what remains — per-segment cache ingest, the
/// recursive compression driver, optional prefix-tree snapshots — is
/// advanced one segment per [`ChunkedPrefill::step`] call so the caller
/// can interleave it with other work.  Segment boundaries are
/// trajectory-invisible for order-insensitive policies: the driver fires
/// the same events at the same row thresholds no matter how the ingest is
/// sliced.
pub struct ChunkedPrefill {
    cfg: CompressionConfig,
    seed: u64,
    ids: Vec<i32>,
    bucket: usize,
    out: PrefillOutput,
    cache: KvCache,
    events: Vec<CompressionEvent>,
    stride: usize,
    /// Insert a prefix-tree snapshot at each interior segment boundary
    /// (prefix cache enabled and the config is cacheable).
    insert_snapshots: bool,
}

impl ChunkedPrefill {
    /// Tokens ingested into the cache so far.
    pub fn ingested(&self) -> usize {
        self.cache.appended
    }

    /// Total prompt length.
    pub fn total(&self) -> usize {
        self.ids.len()
    }

    /// True once every segment has been ingested.
    pub fn is_done(&self) -> bool {
        self.cache.appended >= self.ids.len()
    }

    /// Pool bytes the partially-built cache holds right now (admission
    /// accounting: these rows are resident *and* covered by the request's
    /// reservation, so occupancy math must not count them twice).
    pub fn cache_bytes(&self) -> usize {
        self.cache.exact_bytes()
    }

    /// Ingest one more segment and fire any due compression.  Returns
    /// `Ok(true)` when the prefill is complete ([`ChunkedPrefill::finish`]
    /// may then be called), `Ok(false)` when more segments remain.
    pub fn step(&mut self, engine: &Engine, scorer: &mut dyn Scorer) -> Result<bool> {
        let from = self.cache.appended;
        if from >= self.ids.len() {
            return Ok(true);
        }
        let to = (from + self.stride).min(self.ids.len());
        self.cache.ingest_prefill_segment(
            &self.out.k,
            &self.out.v,
            &self.out.attn_sums,
            self.bucket,
            from,
            to,
        )?;
        self.events.extend(engine.timed_compress(&mut self.cache, &self.cfg, scorer)?);
        if to < self.ids.len() {
            if self.insert_snapshots {
                if let Some(prefix) = engine.prefix.as_ref() {
                    prefix.insert(&self.cfg, self.seed, &self.ids[..to], &self.cache);
                }
            }
            Ok(false)
        } else {
            Ok(true)
        }
    }

    /// Consume the finished prefill into a [`PrefillOutcome`], inserting
    /// the compression-final full-prompt snapshot into the prefix tree.
    /// Must only be called after [`ChunkedPrefill::step`] returned true.
    pub fn finish(self, engine: &Engine) -> PrefillOutcome {
        debug_assert!(self.is_done(), "finish() on an unfinished chunked prefill");
        if self.insert_snapshots {
            if let Some(prefix) = engine.prefix.as_ref() {
                prefix.insert(&self.cfg, self.seed, &self.ids, &self.cache);
            }
        }
        PrefillOutcome {
            logits: self.out.logits,
            cache: self.cache,
            events: self.events,
            reused_tokens: 0,
        }
    }
}

pub struct Engine {
    backend: Box<dyn ExecBackend>,
    pub dims: ModelDims,
    pub tokenizer: Tokenizer,
    pub variant: String,
    pub tmax: usize,
    /// The KV block pool every sequence this engine prefills draws from —
    /// one pool per engine, shared with the coordinator's admission path.
    pool: Arc<BlockPool>,
    /// Radix prefix cache over the pool's frozen blocks (None = disabled).
    prefix: Option<Arc<PrefixCache>>,
    /// Block codec map (`--quant`) installed on every cache this engine
    /// creates: freezes encode through it, reads decode transparently.
    quant: Arc<QuantSpec>,
    /// Per-model telemetry hub (None outside a router): compression-pass
    /// latencies feed its histogram registry.
    telemetry: Option<Arc<Telemetry>>,
    /// Time source for compression / prefill / decode timing.  Follows
    /// the telemetry hub's clock once one is attached, so hermetic tests
    /// can pin engine timings with a `FakeClock`.
    clock: Arc<dyn Clock>,
}

impl Engine {
    /// Wrap an already-constructed backend.  The tokenizer must agree with
    /// the backend's vocabulary.
    pub fn new(backend: Box<dyn ExecBackend>, tokenizer: Tokenizer, variant: &str) -> Result<Engine> {
        let dims = backend.dims().clone();
        if tokenizer.vocab.size() != dims.vocab_size {
            bail!(
                "vocab size mismatch: tokenizer {} vs model {}",
                tokenizer.vocab.size(),
                dims.vocab_size
            );
        }
        let tmax = backend.tmax();
        Ok(Engine {
            backend,
            dims,
            tokenizer,
            variant: variant.to_string(),
            tmax,
            pool: BlockPool::unbounded(BlockPool::DEFAULT_ROWS_PER_BLOCK),
            prefix: None,
            quant: Arc::new(QuantSpec::fp32()),
            telemetry: None,
            clock: Arc::new(MonotonicClock::new()),
        })
    }

    /// Install the block codec map (`--quant`).  Applies to caches created
    /// from here on; earlier caches keep the spec they were created with.
    pub fn set_quant(&mut self, quant: Arc<QuantSpec>) {
        self.quant = quant;
    }

    /// The engine's block codec map.
    pub fn quant(&self) -> &Arc<QuantSpec> {
        &self.quant
    }

    /// A fresh cache on the engine's pool with the engine's codec map.
    fn new_cache(&self) -> KvCache {
        let mut cache = KvCache::new_in(
            Arc::clone(&self.pool),
            self.dims.n_layers,
            self.dims.n_kv_heads,
            self.dims.d_head,
        );
        cache.set_quant(Arc::clone(&self.quant));
        cache
    }

    /// Swap in a shared (possibly byte-budgeted) KV block pool.  Called by
    /// the router before any request runs; caches created earlier keep
    /// their original pool.
    pub fn set_pool(&mut self, pool: Arc<BlockPool>) {
        self.pool = pool;
    }

    /// The engine's KV block pool (admission checks, stats, benches).
    pub fn pool(&self) -> &Arc<BlockPool> {
        &self.pool
    }

    /// Attach an already-constructed radix prefix cache (the router builds
    /// one per model so it can read gauges from outside the coordinator
    /// thread).  Must be bound to this engine's pool.
    pub fn set_prefix_cache(&mut self, prefix: Arc<PrefixCache>) {
        self.prefix = Some(prefix);
    }

    /// Construct and attach a prefix cache on this engine's pool
    /// (single-engine callers: benches, tests, `Engine::generate`).
    pub fn enable_prefix_cache(&mut self, cfg: PrefixConfig) -> Arc<PrefixCache> {
        let prefix = PrefixCache::new(cfg, Arc::clone(&self.pool));
        self.prefix = Some(Arc::clone(&prefix));
        prefix
    }

    /// The engine's radix prefix cache, when one is enabled.
    pub fn prefix_cache(&self) -> Option<&Arc<PrefixCache>> {
        self.prefix.as_ref()
    }

    /// Attach the model's telemetry hub (the router builds one per
    /// variant): every compression-driver pass that fires records its
    /// latency into the hub's histogram registry.
    pub fn set_telemetry(&mut self, telemetry: Arc<Telemetry>) {
        self.clock = Arc::clone(telemetry.clock());
        self.telemetry = Some(telemetry);
    }

    /// One compression-driver pass, timed into the `compression` latency
    /// histogram when a hub is attached.  Passes that fire no event are
    /// not recorded — the histogram measures real compaction work, not
    /// the per-token threshold check.
    fn timed_compress(
        &self,
        cache: &mut KvCache,
        cfg: &CompressionConfig,
        scorer: &mut dyn Scorer,
    ) -> Result<Vec<CompressionEvent>> {
        let Some(tel) = &self.telemetry else { return maybe_compress(cache, cfg, scorer) };
        let t0_us = self.clock.now_us();
        let events = maybe_compress(cache, cfg, scorer)?;
        if !events.is_empty() {
            tel.record(Metric::Compression, self.clock.now_us().saturating_sub(t0_us));
        }
        Ok(events)
    }

    /// Hermetic default: the pure-Rust synthetic reference backend.
    pub fn cpu_ref(variant: &str) -> Result<Engine> {
        let (backend, tokenizer) = crate::backend::cpu_ref::CpuRefBackend::load(variant)?;
        Engine::new(Box::new(backend), tokenizer, variant)
    }

    /// PJRT artifact backend: `art_dir` = artifacts/, `variant` =
    /// "llama_like" | "qwen_like".  Requires `--features xla`.
    #[cfg(feature = "xla")]
    pub fn load(art_dir: &Path, variant: &str) -> Result<Engine> {
        use anyhow::Context;
        let backend = crate::backend::xla::XlaBackend::load(art_dir, variant)?;
        let model_dir = art_dir.join("models").join(variant);
        let dpt = crate::backend::digits_per_token(variant)?;
        let tokenizer = Tokenizer::load(&model_dir, dpt)
            .with_context(|| format!("loading tokenizer for {variant}"))?;
        Engine::new(Box::new(backend), tokenizer, variant)
    }

    /// Without the `xla` feature there is no artifact backend; callers get
    /// a clear error instead of a link failure.
    #[cfg(not(feature = "xla"))]
    pub fn load(art_dir: &Path, variant: &str) -> Result<Engine> {
        let _ = (art_dir, variant);
        bail!(
            "this build has no XLA backend (compiled without `--features xla`); \
             use the default cpu backend (`--backend cpu`) or rebuild with the feature"
        )
    }

    /// The execution backend behind this engine.
    pub fn backend(&self) -> &dyn ExecBackend {
        self.backend.as_ref()
    }

    pub fn decode_buckets(&self) -> &[usize] {
        self.backend.decode_buckets()
    }

    /// Smallest prefill bucket that fits `n` tokens.
    pub fn pick_prefill_bucket(&self, n: usize) -> Result<usize> {
        self.backend
            .prefill_buckets()
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| anyhow!("prompt of {n} tokens exceeds largest prefill bucket"))
    }

    /// Largest prompt any prefill bucket can hold.  The serving layer
    /// checks this *before* admission so an oversized prompt is a typed
    /// `bad-params` client error, never a stringly engine failure.
    pub fn max_prompt_tokens(&self) -> usize {
        self.backend.prefill_buckets().iter().copied().max().unwrap_or(0)
    }

    /// Build the per-sequence scorer for a compression config: the
    /// backend's accelerated scorer when it offers one, else the pure-Rust
    /// policy implementation.
    pub fn make_scorer(&self, cfg: &CompressionConfig, seed: u64) -> Box<dyn Scorer> {
        self.backend
            .scorer(cfg, seed)
            .unwrap_or_else(|| make_policy(cfg.policy, seed))
    }

    /// Run prefill for a prompt; returns (last_logits, populated cache).
    pub fn prefill(&self, ids: &[i32]) -> Result<(Vec<f32>, KvCache)> {
        let bucket = self.pick_prefill_bucket(ids.len())?;
        let mut tokens = vec![0i32; bucket];
        tokens[..ids.len()].copy_from_slice(ids);
        let out = self.backend.prefill(&tokens, ids.len())?;
        let mut cache = self.new_cache();
        cache.ingest_prefill(&out.k, &out.v, &out.attn_sums, bucket, ids.len())?;
        Ok((out.logits, cache))
    }

    /// Prefill plus the prefill-stage recursive compression, through the
    /// radix prefix cache when one is enabled:
    ///
    /// 1. **walk** — attach the deepest snapshot whose key is a proper
    ///    prefix of `ids` (CoW: zero deep copies of the shared prefix) and
    ///    run only the unmatched suffix through the packed wide-bucket
    ///    decode path ([`Engine::prefill_onto_batched`] — bit-identical to
    ///    the b=1 trajectory a cold prefill would take, by driver
    ///    order-insensitivity);
    /// 2. **miss** — run the bucketed backend prefill, but ingest the
    ///    output in `stride`-token segments, compressing between segments
    ///    and inserting a snapshot at each boundary so future requests can
    ///    attach at *shared-prefix* depths;
    /// 3. either way, the compression-final full-prompt state is inserted
    ///    back into the tree.
    ///
    /// This is [`Engine::begin_prefill`] driven to completion in place;
    /// the continuous batcher drives the same machinery one segment at a
    /// time, interleaved with decode.  An attention-fed policy (which is
    /// path-dependent and uncacheable) takes a single full-prompt segment
    /// — exactly the classic prefill-then-compress path, byte for byte.
    pub fn prefill_cached(
        &self,
        ids: &[i32],
        cfg: &CompressionConfig,
        scorer: &mut dyn Scorer,
        seed: u64,
    ) -> Result<PrefillOutcome> {
        match self.begin_prefill(ids, cfg, scorer, seed)? {
            PrefillTask::Done(outcome) => Ok(outcome),
            PrefillTask::Chunked(mut chunked) => {
                while !chunked.step(self, scorer)? {}
                Ok(chunked.finish(self))
            }
        }
    }

    /// Start a prefill, splitting the cold path into resumable segments.
    ///
    /// * **warm hit** — the prefix walk + packed suffix decode run to
    ///   completion here (the wide-bucket path made this cheap), returning
    ///   [`PrefillTask::Done`];
    /// * **cold** — the bucketed backend prefill runs here, but the
    ///   segment-by-segment ingest + compression is handed back as a
    ///   [`ChunkedPrefill`] the caller advances with
    ///   [`ChunkedPrefill::step`] — the batcher interleaves those steps
    ///   with in-flight decode so one long cold prompt no longer stalls
    ///   the whole batch.
    ///
    /// Attention-fed policies get a single full-prompt segment: their
    /// scoring is path-dependent, so mid-prompt compression boundaries
    /// would be trajectory-visible.  Everything else is segment-safe by
    /// driver order-insensitivity.
    pub fn begin_prefill(
        &self,
        ids: &[i32],
        cfg: &CompressionConfig,
        scorer: &mut dyn Scorer,
        seed: u64,
    ) -> Result<PrefillTask> {
        let prefix = self.prefix.as_ref().filter(|p| p.cacheable(cfg));

        // Walk: attach the longest stored proper prefix and decode-prefill
        // only the suffix.  The capacity guard runs *before* the lookup —
        // a snapshot's `appended` equals its key depth, so the attached
        // total is always `ids.len()` regardless of the matched depth —
        // which keeps the tree's hit gauges and LRU recency in step with
        // attaches that actually happen.  A backend error mid-suffix still
        // falls back to a cold prefill.
        if let Some(prefix) = prefix {
            if self.suffix_decode_available(cfg) && self.feed_fits(0, ids.len()) {
                if let Some((mut cache, depth)) = prefix.lookup(cfg, seed, ids) {
                    debug_assert_eq!(cache.appended, depth, "snapshot depth != key length");
                    if let Ok((logits, events)) =
                        self.prefill_onto_batched(&mut cache, cfg, scorer, &ids[depth..])
                    {
                        prefix.insert(cfg, seed, ids, &cache);
                        return Ok(PrefillTask::Done(PrefillOutcome {
                            logits,
                            cache,
                            events,
                            reused_tokens: depth,
                        }));
                    }
                }
            }
        }

        // Cold: one bucketed backend prefill, then segmented ingest.
        let bucket = self.pick_prefill_bucket(ids.len())?;
        let mut tokens = vec![0i32; bucket];
        tokens[..ids.len()].copy_from_slice(ids);
        let out = self.backend.prefill(&tokens, ids.len())?;
        let cache = self.new_cache();
        let (stride, insert_snapshots) = if cfg.policy.needs_attention() {
            (ids.len(), false)
        } else if let Some(prefix) = prefix {
            (prefix.config().stride.max(1), true)
        } else {
            (DEFAULT_PREFILL_STRIDE, false)
        };
        Ok(PrefillTask::Chunked(ChunkedPrefill {
            cfg: cfg.clone(),
            seed,
            ids: ids.to_vec(),
            bucket,
            out,
            cache,
            events: Vec::new(),
            stride,
            insert_snapshots,
        }))
    }

    /// One batched decode step over `slots` (entries may be idle).
    /// Bucket = slots.len() and must be an exported decode bucket.
    pub fn step_batch(&self, slots: &mut [SlotState]) -> Result<()> {
        let b = slots.len();
        if !self.backend.decode_buckets().contains(&b) {
            bail!("no decode executable for batch {b}");
        }
        let (nl, hkv, dh) = (self.dims.n_layers, self.dims.n_kv_heads, self.dims.d_head);
        let tmax = self.tmax;
        let per_slot = hkv * tmax * dh;

        // assemble K/V [nl, B, hkv, tmax, dh] + lens [nl, B] + pos/token [B]
        let mut kbuf = vec![0.0f32; nl * b * per_slot];
        let mut vbuf = vec![0.0f32; nl * b * per_slot];
        let mut lens = vec![0i32; nl * b];
        let mut pos = vec![0i32; b];
        let mut tok = vec![0i32; b];
        for (s, slot) in slots.iter().enumerate() {
            if let Some(seq) = slot.active() {
                for layer in 0..nl {
                    let (lk, lv) = seq.cache.layer_padded(layer, tmax);
                    let dst = (layer * b + s) * per_slot;
                    kbuf[dst..dst + per_slot].copy_from_slice(&lk);
                    vbuf[dst..dst + per_slot].copy_from_slice(&lv);
                    lens[layer * b + s] = seq.cache.len(layer) as i32;
                }
                pos[s] = seq.cache.appended as i32;
                tok[s] = seq.next_token;
            }
        }
        let out = self.backend.decode(&DecodeBatch {
            batch: b,
            k: &kbuf,
            v: &vbuf,
            lens: &lens,
            pos: &pos,
            tokens: &tok,
        })?;
        let v_size = self.dims.vocab_size;

        for (s, slot) in slots.iter_mut().enumerate() {
            let Some(seq) = slot.active_mut() else { continue };
            // extract this slot's k_new/v_new -> [nl, hkv, dh] flat
            let mut kn = Vec::with_capacity(nl * hkv * dh);
            let mut vn = Vec::with_capacity(nl * hkv * dh);
            for layer in 0..nl {
                let off = ((layer * b) + s) * hkv * dh;
                kn.extend_from_slice(&out.k_new[off..off + hkv * dh]);
                vn.extend_from_slice(&out.v_new[off..off + hkv * dh]);
            }
            let position = seq.cache.appended as i32;
            seq.cache.append_token(&kn, &vn, position)?;
            if seq.compression.policy.needs_attention() {
                let mut row = Vec::with_capacity(nl * hkv * tmax);
                for layer in 0..nl {
                    let off = ((layer * b) + s) * hkv * tmax;
                    row.extend_from_slice(&out.attn_rows[off..off + hkv * tmax]);
                }
                seq.cache.accumulate_attention(&row, tmax)?;
            }
            let events =
                self.timed_compress(&mut seq.cache, &seq.compression, seq.scorer.as_mut())?;
            seq.compression_events += events.len();
            seq.step_events = events;

            let next = argmax_slice(&out.logits[s * v_size..(s + 1) * v_size]) as i32;
            seq.push_generated(next, self.tmax);
        }
        Ok(())
    }

    /// Unified capacity rule for every decode-path feed (b=1 incremental,
    /// packed wide-bucket, generation steps): `n` tokens on top of
    /// `appended` rows of history fit iff `appended + n < tmax` — one row
    /// stays free so the step *after* the feed can still append.  This is
    /// exactly the closure of the old per-token bail
    /// (`appended + 1 >= tmax` before token `i` ⇔ `appended₀ + n >= tmax`
    /// at `i = n-1`), checked up front so an oversized feed is refused
    /// *before* any partial append mutates the cache.
    pub fn feed_fits(&self, appended: usize, n: usize) -> bool {
        appended + n < self.tmax
    }

    fn check_feed(&self, cache: &KvCache, n: usize) -> Result<()> {
        if !self.feed_fits(cache.appended, n) {
            bail!(
                "session history of {} + feed of {n} tokens exceeds decode capacity {}",
                cache.appended,
                self.tmax
            );
        }
        Ok(())
    }

    /// The widest decode bucket usable for *packed* suffix prefill, if the
    /// backend and policy allow it: the backend's decode must be
    /// KV-oblivious (so sequential tokens of one sequence can share a
    /// call) and the policy must not feed on attention rows (the packed
    /// call's attention surrogate is suppressed via zero lens).
    fn packed_suffix_bucket(&self, cfg: &CompressionConfig) -> Option<usize> {
        if cfg.policy.needs_attention() || !self.backend.decode_is_kv_oblivious() {
            return None;
        }
        self.backend.decode_buckets().iter().copied().max().filter(|&b| b > 1)
    }

    /// Whether suffix/resume prefill can run on this backend at all —
    /// either the classic b=1 bucket or the packed wide-bucket path.
    pub fn suffix_decode_available(&self, cfg: &CompressionConfig) -> bool {
        self.backend.decode_buckets().contains(&1) || self.packed_suffix_bucket(cfg).is_some()
    }

    /// Incremental ("session") prefill: run `ids` through the decode path
    /// on top of an existing cache, appending each token at its absolute
    /// position and firing the recursive compression driver after every
    /// append — exactly the trajectory a concatenated one-shot prefill
    /// would have produced (the driver is order-insensitive).  Returns the
    /// last token's next-token logits plus the compression events fired.
    ///
    /// The padded K/V upload buffers are assembled **once** and patched
    /// per token: each appended row lands at index `len-1` of its layer's
    /// padded image, and only a compression event (which rewrites a
    /// layer's row set) forces a full re-export of that one layer.  The
    /// old shape of this loop re-exported every layer every token — the
    /// O(prompt × layers × tmax) copy storm this rewrite removes.
    pub fn prefill_onto(
        &self,
        cache: &mut KvCache,
        cfg: &CompressionConfig,
        scorer: &mut dyn Scorer,
        ids: &[i32],
    ) -> Result<(Vec<f32>, Vec<crate::compress::driver::CompressionEvent>)> {
        if ids.is_empty() {
            bail!("prefill_onto: empty token stream");
        }
        if !self.backend.decode_buckets().contains(&1) {
            bail!("prefill_onto needs a b=1 decode bucket");
        }
        self.check_feed(cache, ids.len())?;
        let (nl, hkv, dh) = (self.dims.n_layers, self.dims.n_kv_heads, self.dims.d_head);
        let tmax = self.tmax;
        let per_slot = hkv * tmax * dh;
        let mut kbuf = vec![0.0f32; nl * per_slot];
        let mut vbuf = vec![0.0f32; nl * per_slot];
        let mut lens = vec![0i32; nl];
        for layer in 0..nl {
            let dst = layer * per_slot;
            cache.layer_padded_into(
                layer,
                tmax,
                &mut kbuf[dst..dst + per_slot],
                &mut vbuf[dst..dst + per_slot],
            );
            lens[layer] = cache.len(layer) as i32;
        }
        let mut events = Vec::new();
        let mut logits = Vec::new();
        for &tok in ids {
            let pos = cache.appended as i32;
            let out = self.backend.decode(&DecodeBatch {
                batch: 1,
                k: &kbuf,
                v: &vbuf,
                lens: &lens,
                pos: &[pos],
                tokens: &[tok],
            })?;
            cache.append_token(&out.k_new, &out.v_new, pos)?;
            if cfg.policy.needs_attention() {
                cache.accumulate_attention(&out.attn_rows, tmax)?;
            }
            // Patch the one appended row into the reused padded buffers.
            for layer in 0..nl {
                let row = cache.len(layer) - 1;
                debug_assert!(row < tmax, "appended row {row} outside padded capacity {tmax}");
                for h in 0..hkv {
                    let src = (layer * hkv + h) * dh;
                    let dst = layer * per_slot + h * tmax * dh + row * dh;
                    kbuf[dst..dst + dh].copy_from_slice(&out.k_new[src..src + dh]);
                    vbuf[dst..dst + dh].copy_from_slice(&out.v_new[src..src + dh]);
                }
                lens[layer] = cache.len(layer) as i32;
            }
            let step_events = self.timed_compress(cache, cfg, scorer)?;
            for ev in &step_events {
                // Compaction rewrote this layer's row set; re-export it.
                let dst = ev.layer * per_slot;
                cache.layer_padded_into(
                    ev.layer,
                    tmax,
                    &mut kbuf[dst..dst + per_slot],
                    &mut vbuf[dst..dst + per_slot],
                );
                lens[ev.layer] = cache.len(ev.layer) as i32;
            }
            events.extend(step_events);
            logits = out.logits;
        }
        Ok((logits, events))
    }

    /// Wide-bucket ("packed") suffix prefill: pack sequential tokens of
    /// one sequence across the slots of the largest decode bucket, cutting
    /// backend calls by the bucket width.  Falls back to the incremental
    /// b=1 [`Engine::prefill_onto`] when the backend's decode is not
    /// KV-oblivious (real attention) or the policy feeds on attention.
    ///
    /// Trajectory safety: after each decode call the produced rows are
    /// appended **in token order**, firing the recursive compression
    /// driver at exactly the same per-token boundaries as the b=1 path —
    /// so caches, compression events, and logits are bit-identical (the
    /// property suite pins this across every `PolicyKind`).  The packed
    /// K/V buffers are all-zero with zero lens: a KV-oblivious decode
    /// never reads them, and zero lens suppresses the (unused) attention
    /// surrogate rows.
    pub fn prefill_onto_batched(
        &self,
        cache: &mut KvCache,
        cfg: &CompressionConfig,
        scorer: &mut dyn Scorer,
        ids: &[i32],
    ) -> Result<(Vec<f32>, Vec<crate::compress::driver::CompressionEvent>)> {
        let b = match self.packed_suffix_bucket(cfg) {
            Some(b) => b,
            None => return self.prefill_onto(cache, cfg, scorer, ids),
        };
        if ids.is_empty() {
            bail!("prefill_onto_batched: empty token stream");
        }
        self.check_feed(cache, ids.len())?;
        let (nl, hkv, dh) = (self.dims.n_layers, self.dims.n_kv_heads, self.dims.d_head);
        let tmax = self.tmax;
        let per_slot = hkv * tmax * dh;
        // Never read by a KV-oblivious decode; zero lens also skips the
        // attention surrogate, whose rows are dead outputs on this path.
        let kbuf = vec![0.0f32; nl * b * per_slot];
        let vbuf = vec![0.0f32; nl * b * per_slot];
        let lens = vec![0i32; nl * b];
        let v_size = self.dims.vocab_size;
        let mut events = Vec::new();
        let mut logits = Vec::new();
        for chunk in ids.chunks(b) {
            let cb = chunk.len();
            let mut pos = vec![0i32; b];
            let mut tok = vec![0i32; b];
            for (s, &t) in chunk.iter().enumerate() {
                pos[s] = (cache.appended + s) as i32;
                tok[s] = t;
            }
            let out = self.backend.decode(&DecodeBatch {
                batch: b,
                k: &kbuf,
                v: &vbuf,
                lens: &lens,
                pos: &pos,
                tokens: &tok,
            })?;
            for s in 0..cb {
                let mut kn = Vec::with_capacity(nl * hkv * dh);
                let mut vn = Vec::with_capacity(nl * hkv * dh);
                for layer in 0..nl {
                    let off = ((layer * b) + s) * hkv * dh;
                    kn.extend_from_slice(&out.k_new[off..off + hkv * dh]);
                    vn.extend_from_slice(&out.v_new[off..off + hkv * dh]);
                }
                debug_assert_eq!(
                    cache.appended as i32, pos[s],
                    "packed slot position drifted from the cache"
                );
                cache.append_token(&kn, &vn, pos[s])?;
                events.extend(self.timed_compress(cache, cfg, scorer)?);
            }
            logits = out.logits[(cb - 1) * v_size..cb * v_size].to_vec();
        }
        Ok((logits, events))
    }

    /// Run one generation described by a [`GenerateParams`] bundle (the
    /// engine-level analogue of `Router::generate`; sessions and events
    /// need the coordinator).
    ///
    /// [`GenerateParams`]: crate::coordinator::GenerateParams
    pub fn run(&self, params: &crate::coordinator::GenerateParams) -> Result<GenOutput> {
        self.generate(&params.prompt, &params.compression(), params.max_new, params.seed)
    }

    /// Greedy single-sequence generation with recursive compression.
    pub fn generate(
        &self,
        prompt: &str,
        cfg: &CompressionConfig,
        max_new: usize,
        seed: u64,
    ) -> Result<GenOutput> {
        let ids = self.tokenizer.encode(prompt, true);
        self.generate_ids(&ids, cfg, max_new, seed)
    }

    pub fn generate_ids(
        &self,
        ids: &[i32],
        cfg: &CompressionConfig,
        max_new: usize,
        seed: u64,
    ) -> Result<GenOutput> {
        let t0_us = self.clock.now_us();
        let mut scorer = self.make_scorer(cfg, seed);
        // prefill + prefill-stage recursive compression (through the radix
        // prefix cache when the engine has one enabled)
        let outcome = self.prefill_cached(ids, cfg, scorer.as_mut(), seed)?;
        let prefill_us = self.clock.now_us().saturating_sub(t0_us);

        let first = argmax_slice(&outcome.logits) as i32;
        let reused_tokens = outcome.reused_tokens;
        let mut slot = SlotState::occupied(outcome.cache, cfg.clone(), scorer, first, max_new);
        {
            let seq = slot.active_mut().unwrap();
            seq.compression_events += outcome.events.len();
            seq.push_generated(first, self.tmax);
        }

        let t1_us = self.clock.now_us();
        let mut slots = vec![slot];
        while slots[0].active().map(|s| !s.done).unwrap_or(false) {
            self.step_batch(&mut slots)?;
        }
        let decode_us = self.clock.now_us().saturating_sub(t1_us);
        let seq = slots[0].take().unwrap();
        let text = self.tokenizer.decode(&seq.generated_without_eos());
        Ok(GenOutput {
            prompt_tokens: ids.len(),
            reused_tokens,
            tokens: seq.generated.clone(),
            text,
            cache_lens: seq.cache.lens(),
            compression_events: seq.compression_events,
            prefill_us,
            decode_us,
        })
    }
}

//! Model engine: drives an [`ExecBackend`] over [`KvCache`]s with recursive
//! compression — the bridge between the coordinator (L3) and the model,
//! whatever executes it.
//!
//! Responsibilities:
//! * single-sequence [`Engine::generate`] (greedy decoding),
//! * batched [`Engine::step_batch`] for the continuous batcher,
//! * fire the compression driver after prefill and after every appended
//!   token (the paper's "dynamically ... in both prefill and decode"),
//! * delegate scoring to the backend when it provides an accelerated
//!   scorer (the XLA Pallas kernel), falling back to the pure-Rust
//!   policies otherwise.
//!
//! The engine never names a backend type: all model execution goes through
//! [`crate::backend::ExecBackend`], so the same generation / batching /
//! compression code runs identically on the hermetic CPU reference backend
//! and on the PJRT artifact backend.

pub mod slot;

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::backend::{DecodeBatch, ExecBackend};
use crate::compress::driver::CompressionEvent;
use crate::compress::{maybe_compress, policy::make_policy, Scorer};
use crate::config::{CompressionConfig, ModelDims};
use crate::kvcache::KvCache;
use crate::kvpool::{BlockPool, PrefixCache, PrefixConfig};
use crate::tokenizer::Tokenizer;
use crate::util::argmax as argmax_slice;

pub use slot::{SeqState, SlotState};

/// Result of a single-sequence generation.
#[derive(Debug, Clone)]
pub struct GenOutput {
    pub prompt_tokens: usize,
    /// Prompt tokens served from the engine's prefix cache (0 when the
    /// cache is disabled or missed).
    pub reused_tokens: usize,
    pub tokens: Vec<i32>,
    pub text: String,
    /// Final per-layer cache lengths (compression evidence).
    pub cache_lens: Vec<usize>,
    /// Number of partition-compression events fired.
    pub compression_events: usize,
    pub prefill_us: u64,
    pub decode_us: u64,
}

/// Result of [`Engine::prefill_cached`]: prefill plus the prefill-stage
/// recursive compression, with prefix-cache attribution.
pub struct PrefillOutcome {
    /// Next-token logits of the last prompt token.
    pub logits: Vec<f32>,
    pub cache: KvCache,
    /// Compression events fired during the prefill stage.
    pub events: Vec<CompressionEvent>,
    /// Prompt tokens attached from a radix prefix-cache snapshot instead
    /// of being run through the backend (0 on a cold prefill).
    pub reused_tokens: usize,
}

pub struct Engine {
    backend: Box<dyn ExecBackend>,
    pub dims: ModelDims,
    pub tokenizer: Tokenizer,
    pub variant: String,
    pub tmax: usize,
    /// The KV block pool every sequence this engine prefills draws from —
    /// one pool per engine, shared with the coordinator's admission path.
    pool: Arc<BlockPool>,
    /// Radix prefix cache over the pool's frozen blocks (None = disabled).
    prefix: Option<Arc<PrefixCache>>,
}

impl Engine {
    /// Wrap an already-constructed backend.  The tokenizer must agree with
    /// the backend's vocabulary.
    pub fn new(backend: Box<dyn ExecBackend>, tokenizer: Tokenizer, variant: &str) -> Result<Engine> {
        let dims = backend.dims().clone();
        if tokenizer.vocab.size() != dims.vocab_size {
            bail!(
                "vocab size mismatch: tokenizer {} vs model {}",
                tokenizer.vocab.size(),
                dims.vocab_size
            );
        }
        let tmax = backend.tmax();
        Ok(Engine {
            backend,
            dims,
            tokenizer,
            variant: variant.to_string(),
            tmax,
            pool: BlockPool::unbounded(BlockPool::DEFAULT_ROWS_PER_BLOCK),
            prefix: None,
        })
    }

    /// Swap in a shared (possibly byte-budgeted) KV block pool.  Called by
    /// the router before any request runs; caches created earlier keep
    /// their original pool.
    pub fn set_pool(&mut self, pool: Arc<BlockPool>) {
        self.pool = pool;
    }

    /// The engine's KV block pool (admission checks, stats, benches).
    pub fn pool(&self) -> &Arc<BlockPool> {
        &self.pool
    }

    /// Attach an already-constructed radix prefix cache (the router builds
    /// one per model so it can read gauges from outside the coordinator
    /// thread).  Must be bound to this engine's pool.
    pub fn set_prefix_cache(&mut self, prefix: Arc<PrefixCache>) {
        self.prefix = Some(prefix);
    }

    /// Construct and attach a prefix cache on this engine's pool
    /// (single-engine callers: benches, tests, `Engine::generate`).
    pub fn enable_prefix_cache(&mut self, cfg: PrefixConfig) -> Arc<PrefixCache> {
        let prefix = PrefixCache::new(cfg, Arc::clone(&self.pool));
        self.prefix = Some(Arc::clone(&prefix));
        prefix
    }

    /// The engine's radix prefix cache, when one is enabled.
    pub fn prefix_cache(&self) -> Option<&Arc<PrefixCache>> {
        self.prefix.as_ref()
    }

    /// Hermetic default: the pure-Rust synthetic reference backend.
    pub fn cpu_ref(variant: &str) -> Result<Engine> {
        let (backend, tokenizer) = crate::backend::cpu_ref::CpuRefBackend::load(variant)?;
        Engine::new(Box::new(backend), tokenizer, variant)
    }

    /// PJRT artifact backend: `art_dir` = artifacts/, `variant` =
    /// "llama_like" | "qwen_like".  Requires `--features xla`.
    #[cfg(feature = "xla")]
    pub fn load(art_dir: &Path, variant: &str) -> Result<Engine> {
        use anyhow::Context;
        let backend = crate::backend::xla::XlaBackend::load(art_dir, variant)?;
        let model_dir = art_dir.join("models").join(variant);
        let dpt = crate::backend::digits_per_token(variant)?;
        let tokenizer = Tokenizer::load(&model_dir, dpt)
            .with_context(|| format!("loading tokenizer for {variant}"))?;
        Engine::new(Box::new(backend), tokenizer, variant)
    }

    /// Without the `xla` feature there is no artifact backend; callers get
    /// a clear error instead of a link failure.
    #[cfg(not(feature = "xla"))]
    pub fn load(art_dir: &Path, variant: &str) -> Result<Engine> {
        let _ = (art_dir, variant);
        bail!(
            "this build has no XLA backend (compiled without `--features xla`); \
             use the default cpu backend (`--backend cpu`) or rebuild with the feature"
        )
    }

    /// The execution backend behind this engine.
    pub fn backend(&self) -> &dyn ExecBackend {
        self.backend.as_ref()
    }

    pub fn decode_buckets(&self) -> &[usize] {
        self.backend.decode_buckets()
    }

    /// Smallest prefill bucket that fits `n` tokens.
    pub fn pick_prefill_bucket(&self, n: usize) -> Result<usize> {
        self.backend
            .prefill_buckets()
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| anyhow!("prompt of {n} tokens exceeds largest prefill bucket"))
    }

    /// Largest prompt any prefill bucket can hold.  The serving layer
    /// checks this *before* admission so an oversized prompt is a typed
    /// `bad-params` client error, never a stringly engine failure.
    pub fn max_prompt_tokens(&self) -> usize {
        self.backend.prefill_buckets().iter().copied().max().unwrap_or(0)
    }

    /// Build the per-sequence scorer for a compression config: the
    /// backend's accelerated scorer when it offers one, else the pure-Rust
    /// policy implementation.
    pub fn make_scorer(&self, cfg: &CompressionConfig, seed: u64) -> Box<dyn Scorer> {
        self.backend
            .scorer(cfg, seed)
            .unwrap_or_else(|| make_policy(cfg.policy, seed))
    }

    /// Run prefill for a prompt; returns (last_logits, populated cache).
    pub fn prefill(&self, ids: &[i32]) -> Result<(Vec<f32>, KvCache)> {
        let bucket = self.pick_prefill_bucket(ids.len())?;
        let mut tokens = vec![0i32; bucket];
        tokens[..ids.len()].copy_from_slice(ids);
        let out = self.backend.prefill(&tokens, ids.len())?;
        let mut cache = KvCache::new_in(
            Arc::clone(&self.pool),
            self.dims.n_layers,
            self.dims.n_kv_heads,
            self.dims.d_head,
        );
        cache.ingest_prefill(&out.k, &out.v, &out.attn_sums, bucket, ids.len())?;
        Ok((out.logits, cache))
    }

    /// Prefill plus the prefill-stage recursive compression, through the
    /// radix prefix cache when one is enabled:
    ///
    /// 1. **walk** — attach the deepest snapshot whose key is a proper
    ///    prefix of `ids` (CoW: zero deep copies of the shared prefix) and
    ///    run only the unmatched suffix through the b=1 decode path
    ///    ([`Engine::prefill_onto`] — the same trajectory a cold prefill
    ///    would take, by driver order-insensitivity);
    /// 2. **miss** — run the bucketed backend prefill, but ingest the
    ///    output in `stride`-token segments, compressing between segments
    ///    and inserting a snapshot at each boundary so future requests can
    ///    attach at *shared-prefix* depths;
    /// 3. either way, the compression-final full-prompt state is inserted
    ///    back into the tree.
    ///
    /// With the cache disabled (or an attention-fed policy, which is
    /// path-dependent and uncacheable) this is exactly the classic
    /// prefill-then-compress path, byte for byte.
    pub fn prefill_cached(
        &self,
        ids: &[i32],
        cfg: &CompressionConfig,
        scorer: &mut dyn Scorer,
        seed: u64,
    ) -> Result<PrefillOutcome> {
        let prefix = match self.prefix.as_ref().filter(|p| p.cacheable(cfg)) {
            Some(p) => p,
            None => {
                let (logits, mut cache) = self.prefill(ids)?;
                let events = maybe_compress(&mut cache, cfg, scorer)?;
                return Ok(PrefillOutcome { logits, cache, events, reused_tokens: 0 });
            }
        };

        // Walk: attach the longest stored proper prefix and decode-prefill
        // only the suffix.  The capacity guard runs *before* the lookup —
        // a snapshot's `appended` equals its key depth, so the attached
        // total is always `ids.len()` regardless of the matched depth —
        // which keeps the tree's hit gauges and LRU recency in step with
        // attaches that actually happen.  A backend error mid-suffix still
        // falls back to a cold prefill.
        if self.backend.decode_buckets().contains(&1) && ids.len() + 1 < self.tmax {
            if let Some((mut cache, depth)) = prefix.lookup(cfg, seed, ids) {
                debug_assert_eq!(cache.appended, depth, "snapshot depth != key length");
                if let Ok((logits, events)) =
                    self.prefill_onto(&mut cache, cfg, scorer, &ids[depth..])
                {
                    prefix.insert(cfg, seed, ids, &cache);
                    return Ok(PrefillOutcome { logits, cache, events, reused_tokens: depth });
                }
            }
        }

        // Miss: bucketed prefill with segmented ingest + snapshots.
        let bucket = self.pick_prefill_bucket(ids.len())?;
        let mut tokens = vec![0i32; bucket];
        tokens[..ids.len()].copy_from_slice(ids);
        let out = self.backend.prefill(&tokens, ids.len())?;
        let mut cache = KvCache::new_in(
            Arc::clone(&self.pool),
            self.dims.n_layers,
            self.dims.n_kv_heads,
            self.dims.d_head,
        );
        let mut events = Vec::new();
        let stride = prefix.config().stride.max(1);
        loop {
            let from = cache.appended;
            let to = (from + stride).min(ids.len());
            cache.ingest_prefill_segment(&out.k, &out.v, &out.attn_sums, bucket, from, to)?;
            events.extend(maybe_compress(&mut cache, cfg, scorer)?);
            if to < ids.len() {
                prefix.insert(cfg, seed, &ids[..to], &cache);
            } else {
                break;
            }
        }
        prefix.insert(cfg, seed, ids, &cache);
        Ok(PrefillOutcome { logits: out.logits, cache, events, reused_tokens: 0 })
    }

    /// One batched decode step over `slots` (entries may be idle).
    /// Bucket = slots.len() and must be an exported decode bucket.
    pub fn step_batch(&self, slots: &mut [SlotState]) -> Result<()> {
        let b = slots.len();
        if !self.backend.decode_buckets().contains(&b) {
            bail!("no decode executable for batch {b}");
        }
        let (nl, hkv, dh) = (self.dims.n_layers, self.dims.n_kv_heads, self.dims.d_head);
        let tmax = self.tmax;
        let per_slot = hkv * tmax * dh;

        // assemble K/V [nl, B, hkv, tmax, dh] + lens [nl, B] + pos/token [B]
        let mut kbuf = vec![0.0f32; nl * b * per_slot];
        let mut vbuf = vec![0.0f32; nl * b * per_slot];
        let mut lens = vec![0i32; nl * b];
        let mut pos = vec![0i32; b];
        let mut tok = vec![0i32; b];
        for (s, slot) in slots.iter().enumerate() {
            if let Some(seq) = slot.active() {
                for layer in 0..nl {
                    let (lk, lv) = seq.cache.layer_padded(layer, tmax);
                    let dst = (layer * b + s) * per_slot;
                    kbuf[dst..dst + per_slot].copy_from_slice(&lk);
                    vbuf[dst..dst + per_slot].copy_from_slice(&lv);
                    lens[layer * b + s] = seq.cache.len(layer) as i32;
                }
                pos[s] = seq.cache.appended as i32;
                tok[s] = seq.next_token;
            }
        }
        let out = self.backend.decode(&DecodeBatch {
            batch: b,
            k: &kbuf,
            v: &vbuf,
            lens: &lens,
            pos: &pos,
            tokens: &tok,
        })?;
        let v_size = self.dims.vocab_size;

        for (s, slot) in slots.iter_mut().enumerate() {
            let Some(seq) = slot.active_mut() else { continue };
            // extract this slot's k_new/v_new -> [nl, hkv, dh] flat
            let mut kn = Vec::with_capacity(nl * hkv * dh);
            let mut vn = Vec::with_capacity(nl * hkv * dh);
            for layer in 0..nl {
                let off = ((layer * b) + s) * hkv * dh;
                kn.extend_from_slice(&out.k_new[off..off + hkv * dh]);
                vn.extend_from_slice(&out.v_new[off..off + hkv * dh]);
            }
            let position = seq.cache.appended as i32;
            seq.cache.append_token(&kn, &vn, position)?;
            if seq.compression.policy.needs_attention() {
                let mut row = Vec::with_capacity(nl * hkv * tmax);
                for layer in 0..nl {
                    let off = ((layer * b) + s) * hkv * tmax;
                    row.extend_from_slice(&out.attn_rows[off..off + hkv * tmax]);
                }
                seq.cache.accumulate_attention(&row, tmax)?;
            }
            let events =
                maybe_compress(&mut seq.cache, &seq.compression, seq.scorer.as_mut())?;
            seq.compression_events += events.len();
            seq.step_events = events;

            let next = argmax_slice(&out.logits[s * v_size..(s + 1) * v_size]) as i32;
            seq.push_generated(next, self.tmax);
        }
        Ok(())
    }

    /// Incremental ("session") prefill: run `ids` through the decode path
    /// on top of an existing cache, appending each token at its absolute
    /// position and firing the recursive compression driver after every
    /// append — exactly the trajectory a concatenated one-shot prefill
    /// would have produced (the driver is order-insensitive).  Returns the
    /// last token's next-token logits plus the compression events fired.
    pub fn prefill_onto(
        &self,
        cache: &mut KvCache,
        cfg: &CompressionConfig,
        scorer: &mut dyn Scorer,
        ids: &[i32],
    ) -> Result<(Vec<f32>, Vec<crate::compress::driver::CompressionEvent>)> {
        if ids.is_empty() {
            bail!("prefill_onto: empty token stream");
        }
        if !self.backend.decode_buckets().contains(&1) {
            bail!("prefill_onto needs a b=1 decode bucket");
        }
        let (nl, hkv, dh) = (self.dims.n_layers, self.dims.n_kv_heads, self.dims.d_head);
        let tmax = self.tmax;
        let per_slot = hkv * tmax * dh;
        let mut kbuf = vec![0.0f32; nl * per_slot];
        let mut vbuf = vec![0.0f32; nl * per_slot];
        let mut lens = vec![0i32; nl];
        let mut events = Vec::new();
        let mut logits = Vec::new();
        for &tok in ids {
            if cache.appended + 1 >= tmax {
                bail!(
                    "session history of {} tokens exceeds decode capacity {tmax}",
                    cache.appended
                );
            }
            for layer in 0..nl {
                let (lk, lv) = cache.layer_padded(layer, tmax);
                let dst = layer * per_slot;
                kbuf[dst..dst + per_slot].copy_from_slice(&lk);
                vbuf[dst..dst + per_slot].copy_from_slice(&lv);
                lens[layer] = cache.len(layer) as i32;
            }
            let pos = cache.appended as i32;
            let out = self.backend.decode(&DecodeBatch {
                batch: 1,
                k: &kbuf,
                v: &vbuf,
                lens: &lens,
                pos: &[pos],
                tokens: &[tok],
            })?;
            cache.append_token(&out.k_new, &out.v_new, pos)?;
            if cfg.policy.needs_attention() {
                cache.accumulate_attention(&out.attn_rows, tmax)?;
            }
            events.extend(maybe_compress(cache, cfg, scorer)?);
            logits = out.logits;
        }
        Ok((logits, events))
    }

    /// Run one generation described by a [`GenerateParams`] bundle (the
    /// engine-level analogue of `Router::generate`; sessions and events
    /// need the coordinator).
    ///
    /// [`GenerateParams`]: crate::coordinator::GenerateParams
    pub fn run(&self, params: &crate::coordinator::GenerateParams) -> Result<GenOutput> {
        self.generate(&params.prompt, &params.compression(), params.max_new, params.seed)
    }

    /// Greedy single-sequence generation with recursive compression.
    pub fn generate(
        &self,
        prompt: &str,
        cfg: &CompressionConfig,
        max_new: usize,
        seed: u64,
    ) -> Result<GenOutput> {
        let ids = self.tokenizer.encode(prompt, true);
        self.generate_ids(&ids, cfg, max_new, seed)
    }

    pub fn generate_ids(
        &self,
        ids: &[i32],
        cfg: &CompressionConfig,
        max_new: usize,
        seed: u64,
    ) -> Result<GenOutput> {
        let t0 = std::time::Instant::now();
        let mut scorer = self.make_scorer(cfg, seed);
        // prefill + prefill-stage recursive compression (through the radix
        // prefix cache when the engine has one enabled)
        let outcome = self.prefill_cached(ids, cfg, scorer.as_mut(), seed)?;
        let prefill_us = t0.elapsed().as_micros() as u64;

        let first = argmax_slice(&outcome.logits) as i32;
        let reused_tokens = outcome.reused_tokens;
        let mut slot = SlotState::occupied(outcome.cache, cfg.clone(), scorer, first, max_new);
        {
            let seq = slot.active_mut().unwrap();
            seq.compression_events += outcome.events.len();
            seq.push_generated(first, self.tmax);
        }

        let t1 = std::time::Instant::now();
        let mut slots = vec![slot];
        while slots[0].active().map(|s| !s.done).unwrap_or(false) {
            self.step_batch(&mut slots)?;
        }
        let decode_us = t1.elapsed().as_micros() as u64;
        let seq = slots[0].take().unwrap();
        let text = self.tokenizer.decode(&seq.generated_without_eos());
        Ok(GenOutput {
            prompt_tokens: ids.len(),
            reused_tokens,
            tokens: seq.generated.clone(),
            text,
            cache_lens: seq.cache.lens(),
            compression_events: seq.compression_events,
            prefill_us,
            decode_us,
        })
    }
}

//! Versioned wire protocol `v1` — the single source of truth for every
//! byte that crosses the TCP boundary.
//!
//! One JSON object per line, both directions.  A request line is a
//! versioned envelope:
//!
//! ```json
//! {"v": 1, "op": "generate", "prompt": "...", "stream": true}
//! {"v": 1, "op": "cancel", "id": 7}
//! {"v": 1, "op": "stats"}
//! {"v": 1, "op": "sessions", "delete": "chat-42"}
//! {"v": 1, "op": "info"}
//! {"v": 1, "op": "drain"}
//! {"v": 1, "op": "undrain"}
//! {"v": 1, "op": "checkpoint"}
//! {"v": 1, "op": "trace"}
//! ```
//!
//! * **Versioning** — `"v"` names the protocol revision.  Anything other
//!   than `1` is a typed `bad-params` rejection, so a future `v2` can
//!   change shapes without silently corrupting old clients.
//! * **Compat shim** — a line with no `"v"` field is the pre-versioning
//!   dialect: `{"cancel": id}` maps onto `v1/cancel` and any other object
//!   maps onto `v1/generate` with the same field set.  Old clients keep
//!   working verbatim; new fields only exist inside the envelope.
//! * **Unknown fields are a hard error** naming every unrecognized key —
//!   a typo in `stream` or `session_id` must never silently change
//!   behaviour.
//! * **Typed both ways** — every request, response, and event shape here
//!   owns its `to_json`/`from_json` pair and round-trips exactly (pinned
//!   by unit tests here and property tests in rust/tests/properties.rs).
//!   The blocking client SDK ([`crate::client`]) is built entirely on
//!   these types; no caller hand-rolls JSON.
//!
//! Response shapes (server → client) are documented in DESIGN.md §9:
//! one-shot [`crate::coordinator::Response`] lines, NDJSON
//! [`crate::coordinator::Event`] streams, `cancel_ack` lines, and the
//! control-plane payloads ([`StatsResponse`], [`SessionsResponse`],
//! [`InfoResponse`], [`DrainResponse`], [`UndrainResponse`],
//! [`CheckpointResponse`], [`TraceResponse`]).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{PolicyKind, ScorerBackend};
use crate::coordinator::{
    ApiError, CoordStats, Event, GenerateParams, Response, SessionSummary, Timings, Usage,
};
use crate::kvpool::{PoolStats, PrefixStats};
use crate::kvstore::CheckpointSummary;
use crate::telemetry::{HistogramSummary, Span};
use crate::util::json::{arr, n, obj, s, Json};

/// The protocol revision this build speaks.
pub const VERSION: i64 = 1;

/// Envelope fields shared by every v1 request line.
const ENVELOPE_FIELDS: &[&str] = &["v", "op"];

/// `generate` request fields (identical between v1 and the legacy shim).
pub const GENERATE_FIELDS: &[&str] = &[
    "id",
    "model",
    "prompt",
    "policy",
    "sink",
    "lag",
    "ratio",
    "scorer",
    "skip_layers",
    "max_new",
    "seed",
    "stream",
    "session_id",
];

fn bad(message: impl Into<String>) -> ApiError {
    ApiError::BadParams { message: message.into() }
}

fn field_err(e: anyhow::Error, name: &str) -> ApiError {
    bad(format!("field {name:?}: {e:#}"))
}

/// Reject any key outside `known` (with `allow_envelope`, the `v`/`op`
/// envelope fields are also tolerated — the legacy dialect has none).
fn reject_unknown(
    m: &BTreeMap<String, Json>,
    known: &[&str],
    allow_envelope: bool,
) -> Result<(), ApiError> {
    let unknown: Vec<&str> = m
        .keys()
        .map(|k| k.as_str())
        .filter(|k| !known.contains(k) && !(allow_envelope && ENVELOPE_FIELDS.contains(k)))
        .collect();
    if unknown.is_empty() {
        Ok(())
    } else {
        Err(bad(format!("unrecognized fields {unknown:?} (known: {known:?})")))
    }
}

fn opt_string(v: &Json, name: &str) -> Result<Option<String>, ApiError> {
    match v.opt(name) {
        None => Ok(None),
        Some(x) => Ok(Some(x.as_str().map_err(|e| field_err(e, name))?.to_string())),
    }
}

fn envelope(op: &str) -> Vec<(&'static str, Json)> {
    vec![("v", n(VERSION as f64)), ("op", s(op.to_string()))]
}

fn u64_field(v: &Json, name: &str) -> Result<u64> {
    Ok(v.get(name)?.as_i64()? as u64)
}

/// A numeric field that newer revisions added: absent parses as zero so
/// either side of the wire may lag the other by one protocol rev.
fn opt_usize(v: &Json, name: &str) -> Result<usize> {
    match v.opt(name) {
        Some(x) => x.as_usize(),
        None => Ok(0),
    }
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// One parsed client line, any protocol revision (the legacy shim maps the
/// pre-versioning dialect onto these same ops).
#[derive(Debug, Clone, PartialEq)]
pub enum ApiRequest {
    Generate(GenerateRequest),
    Cancel(CancelRequest),
    Stats(StatsRequest),
    Sessions(SessionsRequest),
    Info(InfoRequest),
    Drain(DrainRequest),
    Undrain(UndrainRequest),
    Checkpoint(CheckpointRequest),
    Trace(TraceRequest),
}

impl ApiRequest {
    /// The v1 wire form of this request (always the envelope dialect; the
    /// shim exists for old *clients*, new writers never emit legacy lines).
    pub fn to_json(&self) -> Json {
        match self {
            ApiRequest::Generate(r) => r.to_json(),
            ApiRequest::Cancel(r) => r.to_json(),
            ApiRequest::Stats(r) => r.to_json(),
            ApiRequest::Sessions(r) => r.to_json(),
            ApiRequest::Info(r) => r.to_json(),
            ApiRequest::Drain(r) => r.to_json(),
            ApiRequest::Undrain(r) => r.to_json(),
            ApiRequest::Checkpoint(r) => r.to_json(),
            ApiRequest::Trace(r) => r.to_json(),
        }
    }
}

/// Parse one request line: the v1 envelope, or the legacy bare dialect via
/// the compat shim.  Every failure is a typed `bad-params`.
pub fn parse_line(line: &str) -> Result<ApiRequest, ApiError> {
    let v = Json::parse(line).map_err(|e| bad(format!("invalid JSON: {e:#}")))?;
    let m = v.as_obj().map_err(|_| bad("request must be a JSON object"))?;
    if m.contains_key("v") {
        let ver = v
            .get("v")
            .and_then(|x| x.as_i64())
            .map_err(|e| field_err(e, "v"))?;
        if ver != VERSION {
            return Err(bad(format!(
                "unsupported protocol version {ver} (supported: {VERSION})"
            )));
        }
        let op = v
            .get("op")
            .and_then(|x| x.as_str())
            .map_err(|e| field_err(e, "op"))?;
        match op {
            "generate" => Ok(ApiRequest::Generate(GenerateRequest::from_fields(&v, true)?)),
            "cancel" => Ok(ApiRequest::Cancel(CancelRequest::from_fields(&v)?)),
            "stats" => {
                reject_unknown(m, &[], true)?;
                Ok(ApiRequest::Stats(StatsRequest))
            }
            "sessions" => Ok(ApiRequest::Sessions(SessionsRequest::from_fields(&v)?)),
            "info" => {
                reject_unknown(m, &[], true)?;
                Ok(ApiRequest::Info(InfoRequest))
            }
            "drain" => {
                reject_unknown(m, &[], true)?;
                Ok(ApiRequest::Drain(DrainRequest))
            }
            "undrain" => {
                reject_unknown(m, &[], true)?;
                Ok(ApiRequest::Undrain(UndrainRequest))
            }
            "checkpoint" => {
                reject_unknown(m, &[], true)?;
                Ok(ApiRequest::Checkpoint(CheckpointRequest))
            }
            "trace" => {
                reject_unknown(m, &[], true)?;
                Ok(ApiRequest::Trace(TraceRequest))
            }
            other => Err(bad(format!(
                "unknown op {other:?} \
                 (generate|cancel|stats|sessions|info|drain|undrain|checkpoint|trace)"
            ))),
        }
    } else if m.contains_key("cancel") {
        // Legacy cancel: {"cancel": id}, nothing else.
        let extra: Vec<&str> =
            m.keys().filter(|k| k.as_str() != "cancel").map(|k| k.as_str()).collect();
        if !extra.is_empty() {
            return Err(bad(format!("cancel line has extra fields: {extra:?}")));
        }
        let id = v
            .get("cancel")
            .and_then(|x| x.as_i64())
            .map_err(|e| bad(format!("bad cancel id: {e:#}")))?;
        if id < 0 {
            // Same validation as the v1 cancel op: the shim maps onto
            // identical semantics, never a wrapped huge id.
            return Err(bad("cancel id must be non-negative"));
        }
        Ok(ApiRequest::Cancel(CancelRequest { id: id as u64 }))
    } else {
        // Legacy generate: the bare pre-versioning request line.
        Ok(ApiRequest::Generate(GenerateRequest::from_fields(&v, false)?))
    }
}

/// `{"v":1,"op":"generate", ...}` — a [`GenerateParams`] bundle plus the
/// wire-only knobs (request id, streaming).  Fields at their defaults are
/// omitted on write and filled back in on parse, so the round-trip is
/// exact.
#[derive(Debug, Clone, PartialEq)]
pub struct GenerateRequest {
    /// Client-chosen request id (the server assigns one when absent).
    pub id: Option<u64>,
    /// NDJSON event stream instead of the one-line folded response.
    pub stream: bool,
    pub params: GenerateParams,
}

impl GenerateRequest {
    pub fn new(params: GenerateParams) -> GenerateRequest {
        GenerateRequest { id: None, stream: false, params }
    }

    fn field_pairs(&self) -> Vec<(&'static str, Json)> {
        let p = &self.params;
        let mut pairs: Vec<(&'static str, Json)> = Vec::new();
        if let Some(id) = self.id {
            pairs.push(("id", n(id as f64)));
        }
        pairs.push(("model", s(p.model.clone())));
        pairs.push(("prompt", s(p.prompt.clone())));
        pairs.push(("policy", s(p.policy.name())));
        pairs.push(("sink", n(p.sink as f64)));
        pairs.push(("lag", n(p.lag as f64)));
        pairs.push(("ratio", n(p.ratio)));
        if p.scorer == ScorerBackend::Xla {
            pairs.push(("scorer", s("xla")));
        }
        if let Some(skip) = p.skip_layers {
            pairs.push(("skip_layers", n(skip as f64)));
        }
        pairs.push(("max_new", n(p.max_new as f64)));
        pairs.push(("seed", n(p.seed as f64)));
        if let Some(sid) = &p.session {
            pairs.push(("session_id", s(sid.clone())));
        }
        if self.stream {
            pairs.push(("stream", Json::Bool(true)));
        }
        pairs
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = envelope("generate");
        pairs.extend(self.field_pairs());
        obj(pairs)
    }

    /// The pre-versioning dialect (no envelope) — only for exercising the
    /// compat shim in tests; new writers always emit [`Self::to_json`].
    pub fn to_legacy_json(&self) -> Json {
        obj(self.field_pairs())
    }

    /// Shared field parser for the v1 (`envelope == true`) and legacy
    /// paths.  Absent fields take [`GenerateParams`] defaults; unknown
    /// fields and invalid parameter values are typed `bad-params` errors.
    fn from_fields(v: &Json, envelope: bool) -> Result<GenerateRequest, ApiError> {
        let m = v.as_obj().map_err(|_| bad("request must be a JSON object"))?;
        reject_unknown(m, GENERATE_FIELDS, envelope)?;
        let mut p = GenerateParams::default();
        if let Some(x) = v.opt("model") {
            p.model = x.as_str().map_err(|e| field_err(e, "model"))?.to_string();
        }
        if let Some(x) = v.opt("prompt") {
            p.prompt = x.as_str().map_err(|e| field_err(e, "prompt"))?.to_string();
        }
        if let Some(x) = v.opt("policy") {
            let name = x.as_str().map_err(|e| field_err(e, "policy"))?;
            p.policy = PolicyKind::parse(name).map_err(|e| field_err(e, "policy"))?;
        }
        if let Some(x) = v.opt("sink") {
            p.sink = x.as_usize().map_err(|e| field_err(e, "sink"))?;
        }
        if let Some(x) = v.opt("lag") {
            p.lag = x.as_usize().map_err(|e| field_err(e, "lag"))?;
        }
        if let Some(x) = v.opt("ratio") {
            p.ratio = x.as_f64().map_err(|e| field_err(e, "ratio"))?;
        }
        if let Some(x) = v.opt("scorer") {
            p.scorer = match x.as_str().map_err(|e| field_err(e, "scorer"))? {
                "xla" => ScorerBackend::Xla,
                "rust" => ScorerBackend::Rust,
                other => return Err(bad(format!("unknown scorer {other:?} (rust|xla)"))),
            };
        }
        if let Some(x) = v.opt("skip_layers") {
            p.skip_layers = Some(x.as_usize().map_err(|e| field_err(e, "skip_layers"))?);
        }
        if let Some(x) = v.opt("max_new") {
            p.max_new = x.as_usize().map_err(|e| field_err(e, "max_new"))?;
        }
        if let Some(x) = v.opt("seed") {
            p.seed = x.as_i64().map_err(|e| field_err(e, "seed"))? as u64;
        }
        if let Some(x) = v.opt("session_id") {
            p.session = Some(x.as_str().map_err(|e| field_err(e, "session_id"))?.to_string());
        }
        let stream = match v.opt("stream") {
            Some(x) => x.as_bool().map_err(|e| field_err(e, "stream"))?,
            None => false,
        };
        let id = v
            .opt("id")
            .map(|x| x.as_i64().map_err(|e| field_err(e, "id")))
            .transpose()?
            .map(|i| i as u64);
        p.validate()?;
        Ok(GenerateRequest { id, stream, params: p })
    }
}

/// `{"v":1,"op":"cancel","id":N}` (legacy shim: `{"cancel":N}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CancelRequest {
    pub id: u64,
}

impl CancelRequest {
    pub fn to_json(&self) -> Json {
        let mut pairs = envelope("cancel");
        pairs.push(("id", n(self.id as f64)));
        obj(pairs)
    }

    fn from_fields(v: &Json) -> Result<CancelRequest, ApiError> {
        reject_unknown(v.as_obj().map_err(|_| bad("not an object"))?, &["id"], true)?;
        let id = v
            .get("id")
            .and_then(|x| x.as_i64())
            .map_err(|e| field_err(e, "id"))?;
        if id < 0 {
            return Err(bad("cancel id must be non-negative"));
        }
        Ok(CancelRequest { id: id as u64 })
    }
}

/// `{"v":1,"op":"stats"}` — one snapshot of every model's gauges.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsRequest;

impl StatsRequest {
    pub fn to_json(&self) -> Json {
        obj(envelope("stats"))
    }
}

/// `{"v":1,"op":"sessions"}` — list the session stores; with `"model"`
/// restrict to one model, with `"delete"` drop the named session instead.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SessionsRequest {
    pub model: Option<String>,
    pub delete: Option<String>,
}

impl SessionsRequest {
    pub fn to_json(&self) -> Json {
        let mut pairs = envelope("sessions");
        if let Some(m) = &self.model {
            pairs.push(("model", s(m.clone())));
        }
        if let Some(d) = &self.delete {
            pairs.push(("delete", s(d.clone())));
        }
        obj(pairs)
    }

    fn from_fields(v: &Json) -> Result<SessionsRequest, ApiError> {
        reject_unknown(
            v.as_obj().map_err(|_| bad("not an object"))?,
            &["model", "delete"],
            true,
        )?;
        Ok(SessionsRequest { model: opt_string(v, "model")?, delete: opt_string(v, "delete")? })
    }
}

/// `{"v":1,"op":"info"}` — deployment facts clients self-configure from.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InfoRequest;

impl InfoRequest {
    pub fn to_json(&self) -> Json {
        obj(envelope("info"))
    }
}

/// `{"v":1,"op":"drain"}` — close admission; in-flight work finishes.
/// Reversible with [`UndrainRequest`] (rolling restarts that change their
/// mind reopen admission without a process bounce).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DrainRequest;

impl DrainRequest {
    pub fn to_json(&self) -> Json {
        obj(envelope("drain"))
    }
}

/// `{"v":1,"op":"undrain"}` — reopen admission after a drain.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct UndrainRequest;

impl UndrainRequest {
    pub fn to_json(&self) -> Json {
        obj(envelope("undrain"))
    }
}

/// `{"v":1,"op":"checkpoint"}` — flush every model's disk store: journal
/// the live session/prefix inventory, fsync, and compact the WAL.  A
/// deployment without `--store-dir` answers with an empty model list.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CheckpointRequest;

impl CheckpointRequest {
    pub fn to_json(&self) -> Json {
        obj(envelope("checkpoint"))
    }
}

/// `{"v":1,"op":"trace"}` — recent request spans plus latency histogram
/// summaries, per model.  Serves the telemetry ring's live snapshot; the
/// full history streams to `--trace-dir` NDJSON files (DESIGN.md §12).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceRequest;

impl TraceRequest {
    pub fn to_json(&self) -> Json {
        obj(envelope("trace"))
    }
}

// ---------------------------------------------------------------------------
// Generation responses: one-shot lines and NDJSON event streams
// ---------------------------------------------------------------------------

/// Render one [`Event`] as an NDJSON line body.
pub fn event_to_json(ev: &Event) -> Json {
    match ev {
        Event::Started { id, prompt_tokens, reused_tokens } => obj(vec![
            ("event", s("started")),
            ("id", n(*id as f64)),
            ("prompt_tokens", n(*prompt_tokens as f64)),
            ("reused_tokens", n(*reused_tokens as f64)),
        ]),
        Event::Token { id, token, text_delta } => obj(vec![
            ("event", s("token")),
            ("id", n(*id as f64)),
            ("token", n(*token as f64)),
            ("text_delta", s(text_delta.clone())),
        ]),
        Event::Compression { id, layer_lens, evicted } => obj(vec![
            ("event", s("compression")),
            ("id", n(*id as f64)),
            ("layer_lens", arr(layer_lens.iter().map(|&l| n(l as f64)).collect())),
            ("evicted", n(*evicted as f64)),
        ]),
        Event::Done { id, usage, timings } => obj(vec![
            ("event", s("done")),
            ("id", n(*id as f64)),
            ("prompt_tokens", n(usage.prompt_tokens as f64)),
            ("new_tokens", n(usage.new_tokens as f64)),
            ("reused_tokens", n(usage.reused_tokens as f64)),
            ("cache_lens", arr(usage.cache_lens.iter().map(|&l| n(l as f64)).collect())),
            ("compression_events", n(usage.compression_events as f64)),
            ("queue_us", n(timings.queue_us as f64)),
            ("prefill_us", n(timings.prefill_us as f64)),
            ("decode_us", n(timings.decode_us as f64)),
        ]),
        Event::Error { id, error } => obj(vec![
            ("event", s("error")),
            ("id", n(*id as f64)),
            ("error", error.to_json()),
        ]),
    }
}

/// One NDJSON event line (the exact bytes the server writes).
pub fn event_line(ev: &Event) -> String {
    event_to_json(ev).to_string()
}

/// Parse an NDJSON event line back into the typed [`Event`].
pub fn event_from_json(v: &Json) -> Result<Event> {
    let kind = v.get("event")?.as_str()?;
    let id = v.get("id")?.as_i64()? as u64;
    Ok(match kind {
        "started" => Event::Started {
            id,
            prompt_tokens: v.get("prompt_tokens")?.as_usize()?,
            reused_tokens: v.get("reused_tokens")?.as_usize()?,
        },
        "token" => Event::Token {
            id,
            token: v.get("token")?.as_i64()? as i32,
            text_delta: v.get("text_delta")?.as_str()?.to_string(),
        },
        "compression" => Event::Compression {
            id,
            layer_lens: v.get("layer_lens")?.as_usize_vec()?,
            evicted: v.get("evicted")?.as_usize()?,
        },
        "done" => Event::Done {
            id,
            usage: Usage {
                prompt_tokens: v.get("prompt_tokens")?.as_usize()?,
                new_tokens: v.get("new_tokens")?.as_usize()?,
                reused_tokens: v.get("reused_tokens")?.as_usize()?,
                cache_lens: v.get("cache_lens")?.as_usize_vec()?,
                compression_events: v.get("compression_events")?.as_usize()?,
            },
            timings: Timings {
                queue_us: u64_field(v, "queue_us")?,
                prefill_us: u64_field(v, "prefill_us")?,
                decode_us: u64_field(v, "decode_us")?,
            },
        },
        "error" => Event::Error { id, error: ApiError::from_json(v.get("error")?)? },
        other => anyhow::bail!("unknown event kind {other:?}"),
    })
}

/// Render the one-shot (non-streaming) response line.
pub fn response_to_json(r: &Response) -> Json {
    obj(vec![
        ("id", n(r.id as f64)),
        ("text", s(r.text.clone())),
        ("tokens", arr(r.tokens.iter().map(|&t| n(t as f64)).collect())),
        ("prompt_tokens", n(r.prompt_tokens as f64)),
        ("reused_tokens", n(r.reused_tokens as f64)),
        ("new_tokens", n(r.tokens.len() as f64)),
        ("cache_lens", arr(r.cache_lens.iter().map(|&l| n(l as f64)).collect())),
        ("compression_events", n(r.compression_events as f64)),
        ("queue_us", n(r.queue_us as f64)),
        ("prefill_us", n(r.prefill_us as f64)),
        ("decode_us", n(r.decode_us as f64)),
        ("error", r.error.as_ref().map(|e| e.to_json()).unwrap_or(Json::Null)),
    ])
}

/// One one-shot response line (the exact bytes the server writes).
pub fn response_line(r: &Response) -> String {
    response_to_json(r).to_string()
}

/// Parse a one-shot response line back into the typed [`Response`].
/// (`new_tokens` is derived from `tokens` and accepted but not stored.)
pub fn response_from_json(v: &Json) -> Result<Response> {
    let error = match v.get("error")? {
        Json::Null => None,
        e => Some(ApiError::from_json(e)?),
    };
    let tokens = v
        .get("tokens")?
        .as_arr()?
        .iter()
        .map(|x| Ok(x.as_i64()? as i32))
        .collect::<Result<Vec<i32>>>()?;
    Ok(Response {
        id: v.get("id")?.as_i64()? as u64,
        text: v.get("text")?.as_str()?.to_string(),
        tokens,
        prompt_tokens: v.get("prompt_tokens")?.as_usize()?,
        reused_tokens: v.get("reused_tokens")?.as_usize()?,
        cache_lens: v.get("cache_lens")?.as_usize_vec()?,
        compression_events: v.get("compression_events")?.as_usize()?,
        queue_us: u64_field(v, "queue_us")?,
        prefill_us: u64_field(v, "prefill_us")?,
        decode_us: u64_field(v, "decode_us")?,
        error,
    })
}

/// `{"event":"cancel_ack","id":N,"found":bool}` — the reply to a cancel op
/// (identical between v1 and the legacy dialect; it may arrive interleaved
/// with stream events on the connection that issued the cancel).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CancelAck {
    pub id: u64,
    pub found: bool,
}

impl CancelAck {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("event", s("cancel_ack")),
            ("id", n(self.id as f64)),
            ("found", Json::Bool(self.found)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CancelAck> {
        if v.get("event")?.as_str()? != "cancel_ack" {
            anyhow::bail!("not a cancel_ack line: {v:?}");
        }
        Ok(CancelAck { id: v.get("id")?.as_i64()? as u64, found: v.get("found")?.as_bool()? })
    }
}

// ---------------------------------------------------------------------------
// Control plane: stats / sessions / info / drain responses
// ---------------------------------------------------------------------------

fn pool_stats_to_json(p: &PoolStats) -> Json {
    obj(vec![
        ("block_bytes", n(p.block_bytes as f64)),
        ("loose_bytes", n(p.loose_bytes as f64)),
        ("free_bytes", n(p.free_bytes as f64)),
        ("high_water_bytes", n(p.high_water_bytes as f64)),
        ("resident_blocks", n(p.resident_blocks as f64)),
        ("free_blocks", n(p.free_blocks as f64)),
        ("spilled_bytes", n(p.spilled_bytes as f64)),
        ("spilled_blocks", n(p.spilled_blocks as f64)),
        ("quant_bytes", n(p.quant_bytes as f64)),
        ("quant_blocks", n(p.quant_blocks as f64)),
        ("dq_bytes", n(p.dq_bytes as f64)),
        ("faults", n(p.faults as f64)),
        ("fault_bytes", n(p.fault_bytes as f64)),
        // Derived, for dashboards; ignored on parse.
        ("resident_bytes", n(p.resident_bytes() as f64)),
        ("budget", p.budget.map(|b| n(b as f64)).unwrap_or(Json::Null)),
    ])
}

fn pool_stats_from_json(v: &Json) -> Result<PoolStats> {
    Ok(PoolStats {
        block_bytes: v.get("block_bytes")?.as_usize()?,
        loose_bytes: v.get("loose_bytes")?.as_usize()?,
        free_bytes: v.get("free_bytes")?.as_usize()?,
        high_water_bytes: v.get("high_water_bytes")?.as_usize()?,
        resident_blocks: v.get("resident_blocks")?.as_usize()?,
        free_blocks: v.get("free_blocks")?.as_usize()?,
        spilled_bytes: v.get("spilled_bytes")?.as_usize()?,
        spilled_blocks: v.get("spilled_blocks")?.as_usize()?,
        // Absent on servers that predate quantization: default to zero so
        // a newer ops client can still read their stats.
        quant_bytes: opt_usize(v, "quant_bytes")?,
        quant_blocks: opt_usize(v, "quant_blocks")?,
        dq_bytes: opt_usize(v, "dq_bytes")?,
        faults: u64_field(v, "faults")?,
        fault_bytes: v.get("fault_bytes")?.as_usize()?,
        budget: match v.get("budget")? {
            Json::Null => None,
            b => Some(b.as_usize()?),
        },
    })
}

fn prefix_stats_to_json(p: &PrefixStats) -> Json {
    obj(vec![
        ("entries", n(p.entries as f64)),
        ("resident_bytes", n(p.resident_bytes as f64)),
        ("hits", n(p.hits as f64)),
        ("misses", n(p.misses as f64)),
        ("inserts", n(p.inserts as f64)),
        ("shed", n(p.shed as f64)),
        ("reused_bytes", n(p.reused_bytes as f64)),
        ("reused_tokens", n(p.reused_tokens as f64)),
    ])
}

fn prefix_stats_from_json(v: &Json) -> Result<PrefixStats> {
    Ok(PrefixStats {
        entries: v.get("entries")?.as_usize()?,
        resident_bytes: v.get("resident_bytes")?.as_usize()?,
        hits: u64_field(v, "hits")?,
        misses: u64_field(v, "misses")?,
        inserts: u64_field(v, "inserts")?,
        shed: u64_field(v, "shed")?,
        reused_bytes: u64_field(v, "reused_bytes")?,
        reused_tokens: u64_field(v, "reused_tokens")?,
    })
}

/// Snapshot of one coordinator's liveness counters
/// ([`CoordStats`], atomics flattened for the wire).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CoordCounters {
    pub completed: u64,
    pub cancelled: u64,
    pub failed: u64,
    pub sessions_resumed: u64,
    pub pool_rejected: u64,
    pub sessions_shed: u64,
    pub prefix_shed: u64,
    /// Frozen blocks demoted to the disk tier under admission pressure.
    pub blocks_spilled: u64,
    /// Requests waiting in the admission queue right now.
    pub queued: u64,
}

impl CoordCounters {
    pub fn snapshot(stats: &CoordStats) -> CoordCounters {
        use std::sync::atomic::Ordering::Relaxed;
        CoordCounters {
            completed: stats.completed.load(Relaxed),
            cancelled: stats.cancelled.load(Relaxed),
            failed: stats.failed.load(Relaxed),
            sessions_resumed: stats.sessions_resumed.load(Relaxed),
            pool_rejected: stats.pool_rejected.load(Relaxed),
            sessions_shed: stats.sessions_shed.load(Relaxed),
            prefix_shed: stats.prefix_shed.load(Relaxed),
            blocks_spilled: stats.blocks_spilled.load(Relaxed),
            queued: stats.queued.load(Relaxed),
        }
    }

    fn to_json(self) -> Json {
        obj(vec![
            ("completed", n(self.completed as f64)),
            ("cancelled", n(self.cancelled as f64)),
            ("failed", n(self.failed as f64)),
            ("sessions_resumed", n(self.sessions_resumed as f64)),
            ("pool_rejected", n(self.pool_rejected as f64)),
            ("sessions_shed", n(self.sessions_shed as f64)),
            ("prefix_shed", n(self.prefix_shed as f64)),
            ("blocks_spilled", n(self.blocks_spilled as f64)),
            ("queued", n(self.queued as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<CoordCounters> {
        Ok(CoordCounters {
            completed: u64_field(v, "completed")?,
            cancelled: u64_field(v, "cancelled")?,
            failed: u64_field(v, "failed")?,
            sessions_resumed: u64_field(v, "sessions_resumed")?,
            pool_rejected: u64_field(v, "pool_rejected")?,
            sessions_shed: u64_field(v, "sessions_shed")?,
            prefix_shed: u64_field(v, "prefix_shed")?,
            blocks_spilled: u64_field(v, "blocks_spilled")?,
            queued: u64_field(v, "queued")?,
        })
    }
}

/// Session-store occupancy of one model.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionGauges {
    pub entries: usize,
    pub bytes: usize,
}

/// One model's full gauge set in a [`StatsResponse`].
#[derive(Debug, Clone, PartialEq)]
pub struct ModelStats {
    pub model: String,
    /// The KV block pool's exact byte ledger.
    pub pool: PoolStats,
    /// Radix prefix-cache gauges, when the deployment runs one.
    pub prefix: Option<PrefixStats>,
    pub coord: CoordCounters,
    pub sessions: SessionGauges,
    /// Configured admission-queue capacity (current depth: `coord.queued`).
    pub queue_capacity: usize,
    /// Latency percentiles from the telemetry registry (empty until the
    /// model has served traffic; every entry has `count > 0`).
    pub histograms: Vec<HistogramSummary>,
}

impl ModelStats {
    fn to_json(&self) -> Json {
        obj(vec![
            ("model", s(self.model.clone())),
            ("pool", pool_stats_to_json(&self.pool)),
            ("prefix", self.prefix.as_ref().map(prefix_stats_to_json).unwrap_or(Json::Null)),
            ("coord", self.coord.to_json()),
            (
                "sessions",
                obj(vec![
                    ("entries", n(self.sessions.entries as f64)),
                    ("bytes", n(self.sessions.bytes as f64)),
                ]),
            ),
            ("queue_capacity", n(self.queue_capacity as f64)),
            ("histograms", arr(self.histograms.iter().map(|h| h.to_json()).collect())),
        ])
    }

    fn from_json(v: &Json) -> Result<ModelStats> {
        let sg = v.get("sessions")?;
        Ok(ModelStats {
            model: v.get("model")?.as_str()?.to_string(),
            pool: pool_stats_from_json(v.get("pool")?)?,
            prefix: match v.get("prefix")? {
                Json::Null => None,
                p => Some(prefix_stats_from_json(p)?),
            },
            coord: CoordCounters::from_json(v.get("coord")?)?,
            sessions: SessionGauges {
                entries: sg.get("entries")?.as_usize()?,
                bytes: sg.get("bytes")?.as_usize()?,
            },
            queue_capacity: v.get("queue_capacity")?.as_usize()?,
            histograms: v
                .get("histograms")?
                .as_arr()?
                .iter()
                .map(HistogramSummary::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

/// Reply to `{"v":1,"op":"stats"}`.
#[derive(Debug, Clone, PartialEq)]
pub struct StatsResponse {
    pub draining: bool,
    /// Sorted by model name, one entry per served variant.
    pub models: Vec<ModelStats>,
}

impl StatsResponse {
    pub fn to_json(&self) -> Json {
        let mut pairs = envelope("stats");
        pairs.push(("draining", Json::Bool(self.draining)));
        pairs.push(("models", arr(self.models.iter().map(|m| m.to_json()).collect())));
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<StatsResponse> {
        Ok(StatsResponse {
            draining: v.get("draining")?.as_bool()?,
            models: v
                .get("models")?
                .as_arr()?
                .iter()
                .map(ModelStats::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

fn session_summary_to_json(ss: &SessionSummary) -> Json {
    obj(vec![
        ("id", s(ss.id.clone())),
        ("turns", n(ss.turns as f64)),
        ("rows", n(ss.rows as f64)),
        ("bytes", n(ss.bytes as f64)),
    ])
}

fn session_summary_from_json(v: &Json) -> Result<SessionSummary> {
    Ok(SessionSummary {
        id: v.get("id")?.as_str()?.to_string(),
        turns: v.get("turns")?.as_i64()? as u32,
        rows: v.get("rows")?.as_usize()?,
        bytes: v.get("bytes")?.as_usize()?,
    })
}

/// One model's stored sessions in a [`SessionsResponse`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSessions {
    pub model: String,
    pub sessions: Vec<SessionSummary>,
}

/// Reply to `{"v":1,"op":"sessions"}`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionsResponse {
    pub models: Vec<ModelSessions>,
    /// Entries dropped by this request's `"delete"` (0 without one).
    pub deleted: u64,
}

impl SessionsResponse {
    pub fn to_json(&self) -> Json {
        let mut pairs = envelope("sessions");
        pairs.push(("deleted", n(self.deleted as f64)));
        pairs.push((
            "models",
            arr(self
                .models
                .iter()
                .map(|m| {
                    obj(vec![
                        ("model", s(m.model.clone())),
                        (
                            "sessions",
                            arr(m.sessions.iter().map(session_summary_to_json).collect()),
                        ),
                    ])
                })
                .collect()),
        ));
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<SessionsResponse> {
        let mut models = Vec::new();
        for m in v.get("models")?.as_arr()? {
            models.push(ModelSessions {
                model: m.get("model")?.as_str()?.to_string(),
                sessions: m
                    .get("sessions")?
                    .as_arr()?
                    .iter()
                    .map(session_summary_from_json)
                    .collect::<Result<Vec<_>>>()?,
            });
        }
        Ok(SessionsResponse { models, deleted: u64_field(v, "deleted")? })
    }
}

/// Engine facts for one model, published by its coordinator thread once
/// the engine loads (clients size prompts/batches from these).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelInfo {
    pub model: String,
    /// Ascending prefill token buckets the backend exports.
    pub prefill_buckets: Vec<usize>,
    /// Ascending decode batch buckets.
    pub decode_buckets: Vec<usize>,
    /// Largest prompt any prefill bucket holds (`bad-params` beyond it).
    pub max_prompt_tokens: usize,
    /// Decode capacity: max cache rows per (layer, head).
    pub tmax: usize,
    /// The KV pool's byte budget, when one is configured.
    pub pool_budget_bytes: Option<usize>,
}

impl ModelInfo {
    fn to_json(&self) -> Json {
        obj(vec![
            ("model", s(self.model.clone())),
            (
                "prefill_buckets",
                arr(self.prefill_buckets.iter().map(|&b| n(b as f64)).collect()),
            ),
            (
                "decode_buckets",
                arr(self.decode_buckets.iter().map(|&b| n(b as f64)).collect()),
            ),
            ("max_prompt_tokens", n(self.max_prompt_tokens as f64)),
            ("tmax", n(self.tmax as f64)),
            (
                "pool_budget_bytes",
                self.pool_budget_bytes.map(|b| n(b as f64)).unwrap_or(Json::Null),
            ),
        ])
    }

    fn from_json(v: &Json) -> Result<ModelInfo> {
        Ok(ModelInfo {
            model: v.get("model")?.as_str()?.to_string(),
            prefill_buckets: v.get("prefill_buckets")?.as_usize_vec()?,
            decode_buckets: v.get("decode_buckets")?.as_usize_vec()?,
            max_prompt_tokens: v.get("max_prompt_tokens")?.as_usize()?,
            tmax: v.get("tmax")?.as_usize()?,
            pool_budget_bytes: match v.get("pool_budget_bytes")? {
                Json::Null => None,
                b => Some(b.as_usize()?),
            },
        })
    }
}

/// Reply to `{"v":1,"op":"info"}`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InfoResponse {
    /// Protocol revision the server speaks (this build: 1).
    pub version: i64,
    /// Sorted by model name; a variant whose engine failed to load is
    /// absent (its requests answer `engine-failure`).
    pub models: Vec<ModelInfo>,
    /// Every [`PolicyKind`] name this build accepts.
    pub policies: Vec<String>,
    /// Configured admission-queue depth per model.
    pub queue_depth: usize,
    /// Session-store entry cap per model (0 disables persistence).
    pub session_capacity: usize,
    /// Whether the radix prefix cache is enabled.
    pub prefix_cache: bool,
}

impl InfoResponse {
    pub fn to_json(&self) -> Json {
        let mut pairs = envelope("info");
        pairs.push(("version", n(self.version as f64)));
        pairs.push(("models", arr(self.models.iter().map(|m| m.to_json()).collect())));
        pairs.push(("policies", arr(self.policies.iter().map(|p| s(p.clone())).collect())));
        pairs.push(("queue_depth", n(self.queue_depth as f64)));
        pairs.push(("session_capacity", n(self.session_capacity as f64)));
        pairs.push(("prefix_cache", Json::Bool(self.prefix_cache)));
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<InfoResponse> {
        Ok(InfoResponse {
            version: v.get("version")?.as_i64()?,
            models: v
                .get("models")?
                .as_arr()?
                .iter()
                .map(ModelInfo::from_json)
                .collect::<Result<Vec<_>>>()?,
            policies: v.get("policies")?.as_str_vec()?,
            queue_depth: v.get("queue_depth")?.as_usize()?,
            session_capacity: v.get("session_capacity")?.as_usize()?,
            prefix_cache: v.get("prefix_cache")?.as_bool()?,
        })
    }
}

/// Reply to `{"v":1,"op":"drain"}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DrainResponse {
    /// True after the op; stays true until an `undrain` reopens admission.
    pub draining: bool,
    /// Requests still running or streaming at the time of the reply.
    pub in_flight: usize,
}

impl DrainResponse {
    pub fn to_json(&self) -> Json {
        let mut pairs = envelope("drain");
        pairs.push(("draining", Json::Bool(self.draining)));
        pairs.push(("in_flight", n(self.in_flight as f64)));
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<DrainResponse> {
        Ok(DrainResponse {
            draining: v.get("draining")?.as_bool()?,
            in_flight: v.get("in_flight")?.as_usize()?,
        })
    }
}

/// Reply to `{"v":1,"op":"undrain"}` — the mirror of [`DrainResponse`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UndrainResponse {
    /// Always false after the op (admission is open again).
    pub draining: bool,
    /// Requests still running or streaming at the time of the reply.
    pub in_flight: usize,
}

impl UndrainResponse {
    pub fn to_json(&self) -> Json {
        let mut pairs = envelope("undrain");
        pairs.push(("draining", Json::Bool(self.draining)));
        pairs.push(("in_flight", n(self.in_flight as f64)));
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<UndrainResponse> {
        Ok(UndrainResponse {
            draining: v.get("draining")?.as_bool()?,
            in_flight: v.get("in_flight")?.as_usize()?,
        })
    }
}

/// One model's checkpoint outcome in a [`CheckpointResponse`]: what the
/// store persisted, or why the flush failed (per-model, so one sick disk
/// never hides the healthy variants' results).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelCheckpoint {
    pub model: String,
    pub result: Result<CheckpointSummary, String>,
}

/// Reply to `{"v":1,"op":"checkpoint"}`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CheckpointResponse {
    /// Sorted by model name; a variant without a disk store is absent.
    pub models: Vec<ModelCheckpoint>,
}

impl CheckpointResponse {
    pub fn to_json(&self) -> Json {
        let mut pairs = envelope("checkpoint");
        let models = self
            .models
            .iter()
            .map(|m| {
                let mut p = vec![("model", s(m.model.clone()))];
                match &m.result {
                    Ok(cp) => {
                        p.push(("ok", Json::Bool(true)));
                        p.push(("sessions", n(cp.sessions as f64)));
                        p.push(("prefixes", n(cp.prefixes as f64)));
                        p.push(("blocks", n(cp.blocks as f64)));
                        p.push(("pages", n(cp.pages as f64)));
                        p.push(("elapsed_us", n(cp.elapsed_us as f64)));
                    }
                    Err(e) => {
                        p.push(("ok", Json::Bool(false)));
                        p.push(("error", s(e.clone())));
                    }
                }
                obj(p)
            })
            .collect();
        pairs.push(("models", arr(models)));
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<CheckpointResponse> {
        let mut models = Vec::new();
        for m in v.get("models")?.as_arr()? {
            let model = m.get("model")?.as_str()?.to_string();
            let result = if m.get("ok")?.as_bool()? {
                Ok(CheckpointSummary {
                    sessions: m.get("sessions")?.as_usize()?,
                    prefixes: m.get("prefixes")?.as_usize()?,
                    blocks: m.get("blocks")?.as_usize()?,
                    pages: m.get("pages")?.as_usize()?,
                    elapsed_us: u64_field(m, "elapsed_us")?,
                })
            } else {
                Err(m.get("error")?.as_str()?.to_string())
            };
            models.push(ModelCheckpoint { model, result });
        }
        Ok(CheckpointResponse { models })
    }
}

/// One model's telemetry snapshot in a [`TraceResponse`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ModelTrace {
    pub model: String,
    /// Span events lost to sink backpressure since startup (exact count;
    /// a healthy deployment reads 0).
    pub dropped_events: u64,
    /// Most recent completed request spans, oldest first.
    pub spans: Vec<Span>,
    /// Latency percentiles, one entry per [`crate::telemetry::Metric`]
    /// that has recorded at least one sample.
    pub histograms: Vec<HistogramSummary>,
}

impl ModelTrace {
    fn to_json(&self) -> Json {
        obj(vec![
            ("model", s(self.model.clone())),
            ("dropped_events", n(self.dropped_events as f64)),
            ("spans", arr(self.spans.iter().map(|sp| sp.to_json()).collect())),
            ("histograms", arr(self.histograms.iter().map(|h| h.to_json()).collect())),
        ])
    }

    fn from_json(v: &Json) -> Result<ModelTrace> {
        Ok(ModelTrace {
            model: v.get("model")?.as_str()?.to_string(),
            dropped_events: u64_field(v, "dropped_events")?,
            spans: v
                .get("spans")?
                .as_arr()?
                .iter()
                .map(Span::from_json)
                .collect::<Result<Vec<_>>>()?,
            histograms: v
                .get("histograms")?
                .as_arr()?
                .iter()
                .map(HistogramSummary::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

/// Reply to `{"v":1,"op":"trace"}`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TraceResponse {
    /// Sorted by model name, one entry per served variant.
    pub models: Vec<ModelTrace>,
}

impl TraceResponse {
    pub fn to_json(&self) -> Json {
        let mut pairs = envelope("trace");
        pairs.push(("models", arr(self.models.iter().map(|m| m.to_json()).collect())));
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<TraceResponse> {
        Ok(TraceResponse {
            models: v
                .get("models")?
                .as_arr()?
                .iter()
                .map(ModelTrace::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_gen(line: &str) -> GenerateRequest {
        match parse_line(line).unwrap() {
            ApiRequest::Generate(g) => g,
            other => panic!("expected a generate request, got {other:?}"),
        }
    }

    #[test]
    fn v1_generate_round_trips_and_fills_defaults() {
        let req = GenerateRequest {
            id: Some(7),
            stream: true,
            params: GenerateParams::new("the falcon")
                .model("qwen_like")
                .policy(PolicyKind::H2O)
                .lag(32)
                .session("chat-1"),
        };
        let line = req.to_json().to_string();
        assert!(line.contains("\"v\":1"), "line must carry the envelope: {line}");
        assert!(line.contains("\"op\":\"generate\""));
        let back = parse_gen(&line);
        assert_eq!(back, req);
        // defaults fill in when omitted
        let minimal = parse_gen(r#"{"v":1,"op":"generate","prompt":"hi"}"#);
        assert_eq!(minimal.params.lag, GenerateParams::default().lag);
        assert!(!minimal.stream);
        assert_eq!(minimal.id, None);
    }

    #[test]
    fn legacy_shim_maps_bare_lines_onto_v1_ops() {
        let req = GenerateRequest {
            id: Some(3),
            stream: false,
            params: GenerateParams::new("hello").lag(16).ratio(0.25),
        };
        let legacy = req.to_legacy_json().to_string();
        assert!(!legacy.contains("\"v\""), "legacy dialect has no envelope: {legacy}");
        assert_eq!(parse_gen(&legacy), req, "shim must map onto the same request");
        // and the two dialects parse identically
        assert_eq!(parse_gen(&legacy), parse_gen(&req.to_json().to_string()));
        // legacy cancel
        match parse_line(r#"{"cancel": 12}"#).unwrap() {
            ApiRequest::Cancel(c) => assert_eq!(c.id, 12),
            other => panic!("expected cancel, got {other:?}"),
        }
        assert!(parse_line(r#"{"cancel": 12, "model": "m"}"#).is_err());
        // negative ids are rejected identically by both dialects
        assert_eq!(parse_line(r#"{"cancel": -1}"#).unwrap_err().code(), "bad-params");
        assert_eq!(
            parse_line(r#"{"v":1,"op":"cancel","id":-1}"#).unwrap_err().code(),
            "bad-params"
        );
    }

    #[test]
    fn unknown_fields_and_bad_versions_are_typed_errors() {
        for line in [
            r#"{"v":1,"op":"generate","prompt":"x","strem":true}"#,
            r#"{"prompt":"x","sessionid":"a"}"#,
        ] {
            let err = parse_line(line).unwrap_err();
            assert_eq!(err.code(), "bad-params", "line {line:?}");
        }
        let msg = parse_line(r#"{"prompt":"x","strem":true,"sessionid":"a"}"#)
            .unwrap_err()
            .message();
        assert!(msg.contains("strem"), "must name the typo: {msg}");
        assert!(msg.contains("sessionid"), "must name the typo: {msg}");
        let err = parse_line(r#"{"v":2,"op":"generate","prompt":"x"}"#).unwrap_err();
        assert!(err.message().contains("version"), "got: {}", err.message());
        let err = parse_line(r#"{"v":1,"op":"frobnicate"}"#).unwrap_err();
        assert!(err.message().contains("frobnicate"));
        // invalid params are caught at parse time, v1 and legacy alike
        for line in ["{}", "not json", "[1,2]", r#"{"prompt":"x","ratio":0}"#] {
            assert_eq!(parse_line(line).unwrap_err().code(), "bad-params", "{line:?}");
        }
    }

    #[test]
    fn control_plane_requests_round_trip() {
        for req in [
            ApiRequest::Cancel(CancelRequest { id: 9 }),
            ApiRequest::Stats(StatsRequest),
            ApiRequest::Sessions(SessionsRequest {
                model: Some("llama_like".into()),
                delete: Some("chat-1".into()),
            }),
            ApiRequest::Sessions(SessionsRequest::default()),
            ApiRequest::Info(InfoRequest),
            ApiRequest::Drain(DrainRequest),
            ApiRequest::Undrain(UndrainRequest),
            ApiRequest::Checkpoint(CheckpointRequest),
            ApiRequest::Trace(TraceRequest),
        ] {
            let line = req.to_json().to_string();
            assert_eq!(parse_line(&line).unwrap(), req, "round-trip of {line}");
        }
        assert_eq!(
            parse_line(r#"{"v":1,"op":"stats","extra":1}"#).unwrap_err().code(),
            "bad-params"
        );
        assert_eq!(
            parse_line(r#"{"v":1,"op":"trace","model":"m"}"#).unwrap_err().code(),
            "bad-params"
        );
    }

    #[test]
    fn events_round_trip_through_json() {
        let events = vec![
            Event::Started { id: 7, prompt_tokens: 151, reused_tokens: 12 },
            Event::Token { id: 7, token: 1200, text_delta: " the".into() },
            Event::Compression { id: 7, layer_lens: vec![56, 58], evicted: 12 },
            Event::Done {
                id: 7,
                usage: Usage {
                    prompt_tokens: 151,
                    new_tokens: 2,
                    reused_tokens: 12,
                    cache_lens: vec![83, 83],
                    compression_events: 8,
                },
                timings: Timings { queue_us: 12, prefill_us: 950, decode_us: 310 },
            },
            Event::Error { id: 7, error: ApiError::Cancelled },
            Event::Error {
                id: 8,
                error: ApiError::PoolExhausted { model: "m".into(), detail: "need 64".into() },
            },
        ];
        for ev in &events {
            let line = event_line(ev);
            let back = event_from_json(&Json::parse(&line).unwrap()).unwrap();
            assert_eq!(&back, ev, "round-trip of {line}");
        }
        assert!(event_from_json(&Json::parse(r#"{"event":"nope","id":1}"#).unwrap()).is_err());
    }

    #[test]
    fn responses_round_trip_through_json() {
        let ok = Response {
            id: 3,
            text: "42".into(),
            tokens: vec![9, 2],
            prompt_tokens: 10,
            reused_tokens: 4,
            cache_lens: vec![12, 12],
            compression_events: 1,
            queue_us: 5,
            prefill_us: 6,
            decode_us: 7,
            error: None,
        };
        let back = response_from_json(&Json::parse(&response_line(&ok)).unwrap()).unwrap();
        assert_eq!(back, ok);
        let v = Json::parse(&response_line(&ok)).unwrap();
        assert_eq!(v.get("new_tokens").unwrap().as_usize().unwrap(), 2);

        let err = Response::from_error(4, ApiError::QueueFull { model: "m".into() });
        let v = Json::parse(&response_line(&err)).unwrap();
        let code = v.get("error").unwrap().get("code").unwrap();
        assert_eq!(code.as_str().unwrap(), "queue-full");
        assert_eq!(response_from_json(&v).unwrap(), err);
    }

    #[test]
    fn cancel_ack_round_trips() {
        let ack = CancelAck { id: 12, found: true };
        let v = Json::parse(&ack.to_json().to_string()).unwrap();
        assert_eq!(CancelAck::from_json(&v).unwrap(), ack);
        assert!(CancelAck::from_json(&Json::parse(r#"{"event":"token"}"#).unwrap()).is_err());
    }

    #[test]
    fn control_plane_responses_round_trip() {
        let stats = StatsResponse {
            draining: false,
            models: vec![ModelStats {
                model: "llama_like".into(),
                pool: PoolStats {
                    block_bytes: 3072,
                    loose_bytes: 1024,
                    free_bytes: 512,
                    high_water_bytes: 5120,
                    resident_blocks: 3,
                    free_blocks: 1,
                    spilled_bytes: 2048,
                    spilled_blocks: 2,
                    quant_bytes: 416,
                    quant_blocks: 1,
                    dq_bytes: 1152,
                    faults: 4,
                    fault_bytes: 3072,
                    budget: Some(8192),
                },
                prefix: Some(PrefixStats {
                    entries: 3,
                    resident_bytes: 1024,
                    hits: 5,
                    misses: 2,
                    inserts: 7,
                    shed: 1,
                    reused_bytes: 4096,
                    reused_tokens: 96,
                }),
                coord: CoordCounters { completed: 9, queued: 2, ..Default::default() },
                sessions: SessionGauges { entries: 1, bytes: 2048 },
                queue_capacity: 256,
                histograms: vec![HistogramSummary {
                    metric: crate::telemetry::Metric::Ttft,
                    count: 9,
                    p50_us: 1200,
                    p90_us: 2500,
                    p99_us: 4100,
                }],
            }],
        };
        let v = Json::parse(&stats.to_json().to_string()).unwrap();
        assert_eq!(StatsResponse::from_json(&v).unwrap(), stats);
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "stats");

        let unbudgeted = StatsResponse {
            draining: true,
            models: vec![ModelStats {
                model: "m".into(),
                pool: PoolStats {
                    block_bytes: 0,
                    loose_bytes: 0,
                    free_bytes: 0,
                    high_water_bytes: 0,
                    resident_blocks: 0,
                    free_blocks: 0,
                    spilled_bytes: 0,
                    spilled_blocks: 0,
                    quant_bytes: 0,
                    quant_blocks: 0,
                    dq_bytes: 0,
                    faults: 0,
                    fault_bytes: 0,
                    budget: None,
                },
                prefix: None,
                coord: CoordCounters::default(),
                sessions: SessionGauges::default(),
                queue_capacity: 8,
                histograms: Vec::new(),
            }],
        };
        let v = Json::parse(&unbudgeted.to_json().to_string()).unwrap();
        assert_eq!(StatsResponse::from_json(&v).unwrap(), unbudgeted);

        let sessions = SessionsResponse {
            deleted: 1,
            models: vec![ModelSessions {
                model: "llama_like".into(),
                sessions: vec![SessionSummary {
                    id: "chat-1".into(),
                    turns: 2,
                    rows: 164,
                    bytes: 11808,
                }],
            }],
        };
        let v = Json::parse(&sessions.to_json().to_string()).unwrap();
        assert_eq!(SessionsResponse::from_json(&v).unwrap(), sessions);

        let info = InfoResponse {
            version: VERSION,
            models: vec![ModelInfo {
                model: "llama_like".into(),
                prefill_buckets: vec![128, 256, 512],
                decode_buckets: vec![1, 4],
                max_prompt_tokens: 512,
                tmax: 640,
                pool_budget_bytes: None,
            }],
            policies: PolicyKind::all().iter().map(|p| p.name().to_string()).collect(),
            queue_depth: 256,
            session_capacity: 64,
            prefix_cache: true,
        };
        let v = Json::parse(&info.to_json().to_string()).unwrap();
        assert_eq!(InfoResponse::from_json(&v).unwrap(), info);

        let drain = DrainResponse { draining: true, in_flight: 3 };
        let v = Json::parse(&drain.to_json().to_string()).unwrap();
        assert_eq!(DrainResponse::from_json(&v).unwrap(), drain);

        let undrain = UndrainResponse { draining: false, in_flight: 2 };
        let v = Json::parse(&undrain.to_json().to_string()).unwrap();
        assert_eq!(UndrainResponse::from_json(&v).unwrap(), undrain);

        let checkpoint = CheckpointResponse {
            models: vec![
                ModelCheckpoint {
                    model: "llama_like".into(),
                    result: Ok(CheckpointSummary {
                        sessions: 2,
                        prefixes: 1,
                        blocks: 6,
                        pages: 19,
                        elapsed_us: 740,
                    }),
                },
                ModelCheckpoint {
                    model: "qwen_like".into(),
                    result: Err("disk full".into()),
                },
            ],
        };
        let v = Json::parse(&checkpoint.to_json().to_string()).unwrap();
        assert_eq!(CheckpointResponse::from_json(&v).unwrap(), checkpoint);
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "checkpoint");
        let empty = CheckpointResponse::default();
        let v = Json::parse(&empty.to_json().to_string()).unwrap();
        assert_eq!(CheckpointResponse::from_json(&v).unwrap(), empty);
    }

    #[test]
    fn trace_response_round_trips() {
        use crate::telemetry::{Metric, SpanEvent, SpanEventKind};
        let trace = TraceResponse {
            models: vec![
                ModelTrace {
                    model: "llama_like".into(),
                    dropped_events: 0,
                    spans: vec![Span {
                        id: 7,
                        events: vec![
                            SpanEvent { t_us: 10, kind: SpanEventKind::Queued, value: 0 },
                            SpanEvent { t_us: 25, kind: SpanEventKind::Admitted, value: 0 },
                            SpanEvent {
                                t_us: 60,
                                kind: SpanEventKind::PrefillSegment,
                                value: 64,
                            },
                            SpanEvent { t_us: 90, kind: SpanEventKind::FirstToken, value: 0 },
                            SpanEvent { t_us: 120, kind: SpanEventKind::Done, value: 0 },
                        ],
                    }],
                    histograms: vec![HistogramSummary {
                        metric: Metric::Ttft,
                        count: 1,
                        p50_us: 80,
                        p90_us: 80,
                        p99_us: 80,
                    }],
                },
                ModelTrace {
                    model: "qwen_like".into(),
                    dropped_events: 3,
                    spans: Vec::new(),
                    histograms: Vec::new(),
                },
            ],
        };
        let v = Json::parse(&trace.to_json().to_string()).unwrap();
        assert_eq!(TraceResponse::from_json(&v).unwrap(), trace);
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "trace");
        let empty = TraceResponse::default();
        let v = Json::parse(&empty.to_json().to_string()).unwrap();
        assert_eq!(TraceResponse::from_json(&v).unwrap(), empty);
        // span/histogram payloads reject unknown keys all the way down
        let bad = r#"{"v":1,"op":"trace","models":[{"model":"m","dropped_events":0,
            "spans":[{"id":1,"events":[{"t_us":1,"kind":"queued","value":0,"extra":1}]}],
            "histograms":[]}]}"#;
        assert!(TraceResponse::from_json(&Json::parse(bad).unwrap()).is_err());
    }
}

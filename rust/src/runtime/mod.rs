//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU client (adapted from /opt/xla-example/load_hlo).
//!
//! Key facts encoded here:
//! * The interchange format is **HLO text** — jax >= 0.5 emits protos with
//!   64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//!   parser reassigns ids.
//! * Everything was lowered with `return_tuple=True`, so outputs arrive as
//!   a 1-level tuple which [`Runtime::execute`] decomposes.
//! * Executables are compiled once and cached by entry name; weights are
//!   uploaded once as device-resident [`xla::PjRtBuffer`]s (the serving hot
//!   path never re-transfers them).

pub mod literals;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::read_json;
use crate::util::json::Json;

pub use literals::{lit_f32, lit_i32, lit_i32_scalar, to_vec_f32, to_vec_i32};

/// A compiled-executable cache over the artifact directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
    art_dir: PathBuf,
    pub manifest: Json,
    exes: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over `artifacts/`.
    pub fn open(art_dir: &Path) -> Result<Runtime> {
        let manifest = read_json(&art_dir.join("manifest.json"))
            .context("manifest.json missing — run `make artifacts` first")?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            art_dir: art_dir.to_path_buf(),
            manifest,
            exes: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Entry names available in the manifest.
    pub fn entries(&self) -> Vec<String> {
        self.manifest
            .get("entries")
            .ok()
            .and_then(|e| e.as_obj().ok())
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// Load + compile (cached) an entry by manifest name.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let rel = self
            .manifest
            .get("entries")?
            .get(name)
            .with_context(|| format!("entry {name:?} not in manifest"))?
            .get("file")?
            .as_str()?
            .to_string();
        let path = self.art_dir.join(&rel);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let arc = std::sync::Arc::new(exe);
        self.exes.lock().unwrap().insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Execute an entry with literal inputs; outputs decomposed from the
    /// return tuple, fetched to host.
    pub fn execute(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let out = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("detupling {name}: {e:?}"))
    }

    /// Execute with device-resident buffers (fast path); returns the raw
    /// output tuple buffer WITHOUT host transfer.
    pub fn execute_buffers(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let exe = self.executable(name)?;
        let mut out = exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        if out.is_empty() || out[0].is_empty() {
            bail!("{name}: empty execution result");
        }
        Ok(out.swap_remove(0))
    }

    /// Execute with device-resident buffer args; fetch + decompose the
    /// return tuple to host literals.  Saves re-uploading static args
    /// (weights) on every call — the decode hot path's dominant cost.
    pub fn execute_buffers_detuple(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = self.execute_buffers(name, args)?;
        let lit = bufs[0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("detupling {name}: {e:?}"))
    }

    /// Upload a literal to the device.
    ///
    /// SAFETY CONTRACT: the CPU PJRT client may ZERO-COPY the literal's
    /// host memory into the buffer; the literal MUST outlive every
    /// execution that uses the returned buffer (dropping it first is a
    /// use-after-free that surfaces as content-dependent segfaults).
    /// Callers keep the source literal bound in scope across execute calls.
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("uploading literal: {e:?}"))
    }

    /// Load a model's weights.npz as literals in manifest `param_order`.
    pub fn load_weights(&self, model_dir: &Path) -> Result<Vec<xla::Literal>> {
        let order = self.manifest.get("param_order")?.as_str_vec()?;
        let path = model_dir.join("weights.npz");
        let named = <xla::Literal as xla::FromRawBytes>::read_npz(&path, &())
            .map_err(|e| anyhow!("reading {}: {e:?}", path.display()))?;
        let mut by_name: HashMap<String, xla::Literal> = named
            .into_iter()
            .map(|(mut n, l)| {
                // npz member names carry the ".npy" suffix
                if let Some(s) = n.strip_suffix(".npy") {
                    n = s.to_string();
                }
                (n, l)
            })
            .collect();
        let mut out = Vec::with_capacity(order.len());
        for name in &order {
            let lit = by_name
                .remove(name)
                .with_context(|| format!("weights.npz missing {name:?}"))?;
            // Normalize through vec -> reshape: literals built by the npy
            // reader (create_from_shape_and_untyped_data) carry no layout,
            // and executing with device buffers made from them segfaults
            // inside PJRT.  Rebuilding via vec1().reshape() installs the
            // default major-to-minor layout and round-trips safely.
            let dims: Vec<usize> =
                lit.array_shape().map_err(|e| anyhow!("shape of {name}: {e:?}"))?
                    .dims()
                    .iter()
                    .map(|&d| d as usize)
                    .collect();
            let data = lit.to_vec::<f32>().map_err(|e| anyhow!("read {name}: {e:?}"))?;
            out.push(literals::lit_f32(&data, &dims)?);
        }
        Ok(out)
    }

    /// Upload weights once; reuse for every call.
    pub fn weights_to_device(&self, weights: &[xla::Literal]) -> Result<Vec<xla::PjRtBuffer>> {
        weights.iter().map(|l| self.to_device(l)).collect()
    }

    /// Shape/dtype signature of an entry (from the manifest, for validation).
    pub fn entry_arg_shapes(&self, name: &str) -> Result<Vec<(Vec<usize>, String)>> {
        let args = self
            .manifest
            .get("entries")?
            .get(name)?
            .get("args")?
            .as_arr()?
            .to_vec();
        let mut out = Vec::new();
        for a in &args {
            let pair = a.as_arr()?;
            if pair.len() != 2 {
                bail!("bad arg spec");
            }
            out.push((pair[0].as_usize_vec()?, pair[1].as_str()?.to_string()));
        }
        Ok(out)
    }
}

//! Literal construction/extraction helpers over the `xla` crate.

use anyhow::{anyhow, Result};

/// f32 literal with the given shape.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
}

/// i32 literal with the given shape.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
}

pub fn lit_i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
}

pub fn to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))
}


//! TCP front end: newline-delimited JSON over std::net (the offline image
//! has no tokio; one thread per connection is ample at this scale).
//!
//! Request line:
//! ```json
//! {"id": 1, "model": "llama_like", "prompt": "...", "policy": "lagkv",
//!  "sink": 4, "lag": 64, "ratio": 0.5, "max_new": 72}
//! ```
//! Response line mirrors [`crate::coordinator::Response`].

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{CompressionConfig, PolicyKind, ScorerBackend};
use crate::coordinator::{Request, Response, Router};
use crate::util::json::{arr, n, obj, s, Json};

pub struct Server {
    pub router: Arc<Router>,
    next_id: AtomicU64,
}

impl Server {
    pub fn new(router: Arc<Router>) -> Server {
        Server { router, next_id: AtomicU64::new(1) }
    }

    /// Parse one request line.  Unknown fields are ignored; absent fields
    /// use CompressionConfig defaults.
    pub fn parse_request(&self, line: &str) -> Result<(String, Request)> {
        let v = Json::parse(line)?;
        let model = v
            .opt("model")
            .and_then(|m| m.as_str().ok())
            .unwrap_or("llama_like")
            .to_string();
        let mut comp = CompressionConfig::default();
        if let Some(p) = v.opt("policy") {
            comp.policy = PolicyKind::parse(p.as_str()?)?;
        }
        if let Some(x) = v.opt("sink") {
            comp.sink = x.as_usize()?;
        }
        if let Some(x) = v.opt("lag") {
            comp.lag = x.as_usize()?;
        }
        if let Some(x) = v.opt("ratio") {
            comp.ratio = x.as_f64()?;
        }
        if let Some(x) = v.opt("scorer") {
            comp.scorer = match x.as_str()? {
                "xla" => ScorerBackend::Xla,
                _ => ScorerBackend::Rust,
            };
        }
        if comp.policy == PolicyKind::L2Norm {
            comp.skip_layers = 2;
        }
        comp.validate()?;
        let id = match v.opt("id") {
            Some(x) => x.as_i64()? as u64,
            None => self.next_id.fetch_add(1, Ordering::Relaxed),
        };
        let req = Request {
            id,
            prompt: v.get("prompt")?.as_str()?.to_string(),
            compression: comp,
            max_new: v.opt("max_new").and_then(|x| x.as_usize().ok()).unwrap_or(72),
            seed: v.opt("seed").and_then(|x| x.as_i64().ok()).unwrap_or(0) as u64,
        };
        Ok((model, req))
    }

    pub fn render_response(resp: &Response) -> String {
        obj(vec![
            ("id", n(resp.id as f64)),
            ("text", s(resp.text.clone())),
            ("prompt_tokens", n(resp.prompt_tokens as f64)),
            ("new_tokens", n(resp.tokens.len() as f64)),
            (
                "cache_lens",
                arr(resp.cache_lens.iter().map(|&l| n(l as f64)).collect()),
            ),
            ("compression_events", n(resp.compression_events as f64)),
            ("queue_us", n(resp.queue_us as f64)),
            ("prefill_us", n(resp.prefill_us as f64)),
            ("decode_us", n(resp.decode_us as f64)),
            (
                "error",
                resp.error.clone().map(s).unwrap_or(Json::Null),
            ),
        ])
        .to_string()
    }

    fn handle_conn(&self, stream: TcpStream) -> Result<()> {
        let peer = stream.peer_addr().ok();
        let mut writer = stream.try_clone().context("clone stream")?;
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let reply = match self.parse_request(&line) {
                Ok((model, req)) => match self.router.generate(&model, req) {
                    Ok(resp) => Self::render_response(&resp),
                    Err(e) => obj(vec![("error", s(format!("{e:#}")))]).to_string(),
                },
                Err(e) => obj(vec![("error", s(format!("bad request: {e:#}")))]).to_string(),
            };
            writer.write_all(reply.as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
        }
        let _ = peer;
        Ok(())
    }

    /// Serve until `stop` flips true (checked between accepts).
    pub fn serve(self: Arc<Self>, port: u16, stop: Arc<AtomicBool>) -> Result<()> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding 127.0.0.1:{port}"))?;
        listener.set_nonblocking(true)?;
        eprintln!("lagkv server listening on 127.0.0.1:{port}");
        loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    let me = self.clone();
                    std::thread::spawn(move || {
                        if let Err(e) = me.handle_conn(stream) {
                            eprintln!("connection error: {e:#}");
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

/// Minimal blocking client for the line protocol (used by serve_demo and
/// integration tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(port: u16) -> Result<Client> {
        let stream = TcpStream::connect(("127.0.0.1", port))?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    pub fn call(&mut self, request_json: &str) -> Result<Json> {
        self.writer.write_all(request_json.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::backend::EngineSpec;

    #[test]
    fn parse_request_defaults_and_overrides() {
        let router = Arc::new(Router::start(EngineSpec::cpu(), &[]));
        let srv = Server::new(router);
        let (model, req) = srv
            .parse_request(
                r#"{"prompt": "hello", "policy": "h2o", "lag": 32, "max_new": 5}"#,
            )
            .unwrap();
        assert_eq!(model, "llama_like");
        assert_eq!(req.compression.policy, PolicyKind::H2O);
        assert_eq!(req.compression.lag, 32);
        assert_eq!(req.max_new, 5);
        assert_eq!(req.prompt, "hello");
    }

    #[test]
    fn bad_request_is_error() {
        let router = Arc::new(Router::start(EngineSpec::cpu(), &[]));
        let srv = Server::new(router);
        assert!(srv.parse_request("{}").is_err());
        assert!(srv.parse_request("not json").is_err());
    }

    #[test]
    fn response_renders_as_json() {
        let resp = Response {
            id: 3,
            text: "42".into(),
            tokens: vec![9, 2],
            prompt_tokens: 10,
            cache_lens: vec![12, 12],
            compression_events: 1,
            queue_us: 5,
            prefill_us: 6,
            decode_us: 7,
            error: None,
        };
        let v = Json::parse(&Server::render_response(&resp)).unwrap();
        assert_eq!(v.get("id").unwrap().as_i64().unwrap(), 3);
        assert_eq!(v.get("text").unwrap().as_str().unwrap(), "42");
        assert_eq!(v.get("cache_lens").unwrap().as_usize_vec().unwrap(), vec![12, 12]);
    }
}

//! TCP front end: newline-delimited JSON over std::net (the offline image
//! has no tokio; one thread per connection is ample at this scale).
//!
//! Every line is parsed by [`crate::api::parse_line`] — the versioned `v1`
//! envelope (`{"v":1,"op":...}`) or the legacy bare dialect via the compat
//! shim — so this module owns no wire knowledge of its own: it binds
//! sockets, assigns request ids, tracks live cancel flags, and maps each
//! [`ApiRequest`] onto the router.
//!
//! * `generate` — without `"stream"` the reply is one JSON line (the
//!   folded [`crate::coordinator::Response`]); with `"stream": true` the
//!   reply is NDJSON, one [`crate::coordinator::Event`] per line, and the
//!   connection keeps accepting request lines while the stream runs.
//! * `cancel` — aborts a live request (same or another connection), acked
//!   with `{"event": "cancel_ack", ...}`; the aborted stream terminates
//!   with a `cancelled` error event.
//! * `stats` / `sessions` / `info` — the ops control plane: pool and
//!   prefix-cache gauges, coordinator counters and queue depth, session
//!   listing/deletion, and the engine facts clients self-configure from.
//! * `drain` — closes admission (every later submit is a typed
//!   `draining` rejection) while in-flight work finishes; the operator
//!   then stops the accept loop for a clean shutdown.
//!
//! Full protocol specification: DESIGN.md §9.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::api::{
    self, ApiRequest, CancelAck, CheckpointResponse, CoordCounters, DrainResponse,
    InfoResponse, ModelCheckpoint, ModelSessions, ModelStats, ModelTrace, SessionGauges,
    SessionsRequest, SessionsResponse, StatsResponse, TraceResponse, UndrainResponse,
};
use crate::config::PolicyKind;
use crate::coordinator::{ApiError, GenHandle, Response, Router};
use crate::telemetry::{Clock, MonotonicClock};
use crate::util::json::obj;
use crate::util::locked;

pub struct Server {
    pub router: Arc<Router>,
    next_id: AtomicU64,
    /// Cancel flags of in-flight requests, keyed by request id, so a
    /// cancel op on any connection can abort them.
    live: Mutex<HashMap<u64, Arc<AtomicBool>>>,
    /// Time source for the `info` settle deadline; monotonic in production,
    /// swappable so timeout behaviour stays fake-clock-testable.
    clock: Arc<dyn Clock>,
}

impl Server {
    pub fn new(router: Arc<Router>) -> Server {
        Server {
            router,
            next_id: AtomicU64::new(1),
            live: Mutex::new(HashMap::new()),
            clock: Arc::new(MonotonicClock::new()),
        }
    }

    /// Flip the cancel flag of a live request.  Returns whether the id was
    /// known (an already-finished or never-seen id is `false`).
    pub fn cancel(&self, id: u64) -> bool {
        match locked(&self.live).get(&id) {
            Some(flag) => {
                flag.store(true, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// How many requests are currently in flight (diagnostics / tests /
    /// the `drain` reply).
    pub fn live_requests(&self) -> usize {
        locked(&self.live).len()
    }

    /// Build the `stats` op reply from the router's live gauges.
    pub fn stats_response(&self) -> StatsResponse {
        let mut names = self.router.models();
        names.sort();
        let models = names
            .into_iter()
            .filter_map(|m| {
                // The router's per-model maps are built once at start, so a
                // listed model always resolves today; if a future dynamic
                // registry unloads one mid-snapshot, drop its row rather
                // than panic the control plane.
                let (pool, stats, store) = match (
                    self.router.pool(&m),
                    self.router.stats(&m),
                    self.router.session_store(&m),
                ) {
                    (Some(p), Some(c), Some(s)) => (p, c, s),
                    _ => return None,
                };
                let sessions = {
                    let st = locked(&store);
                    SessionGauges { entries: st.len(), bytes: st.total_bytes() }
                };
                Some(ModelStats {
                    pool: pool.stats(),
                    prefix: self.router.prefix_cache(&m).map(|p| p.stats()),
                    coord: CoordCounters::snapshot(&stats),
                    sessions,
                    queue_capacity: self.router.config().queue_depth,
                    histograms: self
                        .router
                        .telemetry(&m)
                        .map(|t| t.summaries())
                        .unwrap_or_default(),
                    model: m,
                })
            })
            .collect();
        StatsResponse { draining: self.router.is_draining(), models }
    }

    /// Build the `sessions` op reply: list stores (optionally one model),
    /// deleting a named session first when the request asks for it.
    pub fn sessions_response(
        &self,
        req: &SessionsRequest,
    ) -> Result<SessionsResponse, ApiError> {
        let mut names = self.router.models();
        names.sort();
        if let Some(m) = &req.model {
            if !names.contains(m) {
                return Err(ApiError::UnknownModel { model: m.clone(), have: names });
            }
            names = vec![m.clone()];
        }
        let mut deleted = 0u64;
        let mut models = Vec::new();
        for name in names {
            // Same contract as `stats_response`: skip rather than panic if a
            // model's store vanished between listing and lookup.
            let Some(store) = self.router.session_store(&name) else { continue };
            let mut st = locked(&store);
            if let Some(sid) = &req.delete {
                if st.remove(sid) {
                    deleted += 1;
                }
            }
            models.push(ModelSessions { model: name, sessions: st.summaries() });
        }
        Ok(SessionsResponse { models, deleted })
    }

    /// Build the `checkpoint` op reply: flush every variant's disk store.
    /// A deployment without `--store-dir` answers with an empty list.
    pub fn checkpoint_response(&self) -> CheckpointResponse {
        let models = self
            .router
            .checkpoint()
            .into_iter()
            .map(|(model, result)| ModelCheckpoint {
                model,
                result: result.map_err(|e| format!("{e:#}")),
            })
            .collect();
        CheckpointResponse { models }
    }

    /// Build the `trace` op reply: per model, the most recent completed
    /// request spans, the sink's exact drop counter, and latency
    /// percentiles from the histogram registry.
    pub fn trace_response(&self) -> TraceResponse {
        let mut names = self.router.models();
        names.sort();
        let models = names
            .into_iter()
            .map(|m| {
                let tel = self.router.telemetry(&m);
                ModelTrace {
                    dropped_events: tel.as_ref().map(|t| t.dropped_events()).unwrap_or(0),
                    spans: tel.as_ref().map(|t| t.recent_spans()).unwrap_or_default(),
                    histograms: tel.as_ref().map(|t| t.summaries()).unwrap_or_default(),
                    model: m,
                }
            })
            .collect();
        TraceResponse { models }
    }

    /// Build the `info` op reply.  Engines load asynchronously at boot, so
    /// this briefly waits for every variant's load to *settle* — an `info`
    /// fired right after bind (the CI smoke's first call) must see the
    /// full inventory, while a variant whose engine failed publishes a
    /// tombstone and stays absent without stalling the deadline.
    pub fn info_response(&self) -> InfoResponse {
        let mut names = self.router.models();
        names.sort();
        let deadline_us = self.clock.now_us() + 5_000_000;
        while names.iter().any(|m| !self.router.model_settled(m))
            && self.clock.now_us() < deadline_us
        {
            std::thread::sleep(Duration::from_millis(10));
        }
        let models: Vec<api::ModelInfo> =
            names.iter().filter_map(|m| self.router.model_info(m)).collect();
        let cfg = self.router.config();
        InfoResponse {
            version: api::VERSION,
            models,
            policies: PolicyKind::all().iter().map(|p| p.name().to_string()).collect(),
            queue_depth: cfg.queue_depth,
            session_capacity: cfg.sessions.capacity,
            prefix_cache: cfg.prefix_cache.is_some(),
        }
    }

    fn forward_events(&self, id: u64, handle: GenHandle, writer: Arc<Mutex<TcpStream>>) {
        for ev in handle.events.iter() {
            let terminal = ev.is_terminal();
            if write_line(&writer, &api::event_line(&ev)).is_err() {
                // Connection gone: dropping the handle aborts the slot.
                break;
            }
            if terminal {
                break;
            }
        }
        locked(&self.live).remove(&id);
    }

    fn handle_generate(
        self: Arc<Self>,
        gen_req: api::GenerateRequest,
        writer: &Arc<Mutex<TcpStream>>,
    ) -> Result<()> {
        let id = gen_req
            .id
            .unwrap_or_else(|| self.next_id.fetch_add(1, Ordering::Relaxed));
        let streaming = gen_req.stream;
        let model = gen_req.params.model.clone();
        let submitted = match gen_req.params.into_request(id) {
            Ok(request) => {
                // Register under the live-map lock so a duplicate id can
                // never clobber another request's cancel flag (or have its
                // own entry removed by the first finisher).
                let mut live = locked(&self.live);
                if live.contains_key(&id) {
                    Err(ApiError::BadParams {
                        message: format!("request id {id} is already in flight"),
                    })
                } else {
                    self.router.submit(&model, request).map(|handle| {
                        live.insert(id, handle.cancel_flag());
                        handle
                    })
                }
            }
            Err(e) => Err(e),
        };
        match submitted {
            Ok(handle) => {
                if streaming {
                    // Forward events off-thread so this reader keeps
                    // accepting cancel/request lines.
                    let me = self.clone();
                    let w = writer.clone();
                    std::thread::spawn(move || me.forward_events(id, handle, w));
                } else {
                    let resp = handle.wait();
                    locked(&self.live).remove(&id);
                    write_line(writer, &api::response_line(&resp))?;
                }
            }
            Err(e) => {
                let resp = Response::from_error(id, e);
                write_line(writer, &api::response_line(&resp))?;
            }
        }
        Ok(())
    }

    fn handle_conn(self: Arc<Self>, stream: TcpStream) -> Result<()> {
        let writer = Arc::new(Mutex::new(stream.try_clone().context("clone stream")?));
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            match api::parse_line(&line) {
                Ok(ApiRequest::Generate(gen_req)) => {
                    self.clone().handle_generate(gen_req, &writer)?;
                }
                Ok(ApiRequest::Cancel(c)) => {
                    let ack = CancelAck { id: c.id, found: self.cancel(c.id) };
                    write_line(&writer, &ack.to_json().to_string())?;
                }
                Ok(ApiRequest::Stats(_)) => {
                    write_line(&writer, &self.stats_response().to_json().to_string())?;
                }
                Ok(ApiRequest::Sessions(sr)) => match self.sessions_response(&sr) {
                    Ok(resp) => write_line(&writer, &resp.to_json().to_string())?,
                    Err(e) => {
                        write_line(&writer, &obj(vec![("error", e.to_json())]).to_string())?;
                    }
                },
                Ok(ApiRequest::Info(_)) => {
                    write_line(&writer, &self.info_response().to_json().to_string())?;
                }
                Ok(ApiRequest::Drain(_)) => {
                    // Close admission; in-flight slots and queued work run
                    // to completion.  The operator stops the accept loop
                    // (clean shutdown) once live_requests drains to zero —
                    // or reopens admission with `undrain`.
                    self.router.drain();
                    let resp =
                        DrainResponse { draining: true, in_flight: self.live_requests() };
                    write_line(&writer, &resp.to_json().to_string())?;
                }
                Ok(ApiRequest::Undrain(_)) => {
                    // Reopen admission: the rollback half of a rolling
                    // restart.  In-flight work was never affected.
                    self.router.undrain();
                    let resp =
                        UndrainResponse { draining: false, in_flight: self.live_requests() };
                    write_line(&writer, &resp.to_json().to_string())?;
                }
                Ok(ApiRequest::Checkpoint(_)) => {
                    write_line(&writer, &self.checkpoint_response().to_json().to_string())?;
                }
                Ok(ApiRequest::Trace(_)) => {
                    write_line(&writer, &self.trace_response().to_json().to_string())?;
                }
                Err(e) => {
                    write_line(&writer, &obj(vec![("error", e.to_json())]).to_string())?;
                }
            }
        }
        Ok(())
    }

    /// Bind the listen socket; `port == 0` picks an ephemeral port.  The
    /// actual port is returned (CI smoke tests bind ephemerally).
    pub fn bind(port: u16) -> Result<(TcpListener, u16)> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding 127.0.0.1:{port}"))?;
        let actual = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        Ok((listener, actual))
    }

    /// Serve until `stop` flips true (checked between accepts).
    pub fn serve(self: Arc<Self>, port: u16, stop: Arc<AtomicBool>) -> Result<()> {
        let (listener, actual) = Self::bind(port)?;
        let v = api::VERSION;
        eprintln!("lagkv server listening on 127.0.0.1:{actual} (wire protocol v{v})");
        self.serve_listener(listener, stop)
    }

    /// Accept loop over an already-bound (nonblocking) listener.
    pub fn serve_listener(
        self: Arc<Self>,
        listener: TcpListener,
        stop: Arc<AtomicBool>,
    ) -> Result<()> {
        loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    let me = self.clone();
                    std::thread::spawn(move || {
                        if let Err(e) = me.handle_conn(stream) {
                            eprintln!("connection error: {e:#}");
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

fn write_line(writer: &Arc<Mutex<TcpStream>>, line: &str) -> std::io::Result<()> {
    let mut w = locked(writer);
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::backend::EngineSpec;
    use crate::util::json::Json;

    fn server(variants: &[&str]) -> Server {
        let variants: Vec<String> = variants.iter().map(|s| s.to_string()).collect();
        Server::new(Arc::new(Router::start(EngineSpec::cpu(), &variants)))
    }

    #[test]
    fn cancel_of_unknown_id_is_not_found() {
        let srv = server(&[]);
        assert!(!srv.cancel(12));
        assert_eq!(srv.live_requests(), 0);
    }

    #[test]
    fn stats_response_covers_every_model_sorted() {
        let srv = server(&["qwen_like", "llama_like"]);
        let stats = srv.stats_response();
        let names: Vec<&str> = stats.models.iter().map(|m| m.model.as_str()).collect();
        assert_eq!(names, vec!["llama_like", "qwen_like"], "sorted by model");
        assert!(!stats.draining);
        for m in &stats.models {
            assert_eq!(m.queue_capacity, srv.router.config().queue_depth);
            assert_eq!(m.sessions.entries, 0);
            assert!(m.prefix.is_none(), "no prefix cache configured");
        }
        // the reply round-trips through its own wire form
        let v = Json::parse(&stats.to_json().to_string()).unwrap();
        assert_eq!(StatsResponse::from_json(&v).unwrap(), stats);
        srv.router.drain();
        assert!(srv.stats_response().draining);
    }

    #[test]
    fn trace_response_covers_every_model_sorted() {
        let srv = server(&["qwen_like", "llama_like"]);
        let tr = srv.trace_response();
        let names: Vec<&str> = tr.models.iter().map(|m| m.model.as_str()).collect();
        assert_eq!(names, vec!["llama_like", "qwen_like"], "sorted by model");
        for m in &tr.models {
            assert_eq!(m.dropped_events, 0);
            assert!(m.spans.is_empty(), "no traffic yet");
            assert!(m.histograms.is_empty(), "no samples yet");
        }
        let v = Json::parse(&tr.to_json().to_string()).unwrap();
        assert_eq!(TraceResponse::from_json(&v).unwrap(), tr);
    }

    #[test]
    fn checkpoint_without_a_store_is_empty() {
        let srv = server(&["llama_like"]);
        let cp = srv.checkpoint_response();
        assert!(cp.models.is_empty(), "no --store-dir, nothing to flush");
        let v = Json::parse(&cp.to_json().to_string()).unwrap();
        assert_eq!(CheckpointResponse::from_json(&v).unwrap(), cp);
    }

    #[test]
    fn sessions_response_rejects_unknown_model() {
        let srv = server(&["llama_like"]);
        let bad = SessionsRequest { model: Some("nope".into()), delete: None };
        let err = srv.sessions_response(&bad).unwrap_err();
        assert_eq!(err.code(), "unknown-model");
        let ok = srv.sessions_response(&SessionsRequest::default()).unwrap();
        assert_eq!(ok.models.len(), 1);
        assert_eq!(ok.deleted, 0);
        assert!(ok.models[0].sessions.is_empty());
    }

    #[test]
    fn info_response_reports_engine_facts() {
        let srv = server(&["llama_like"]);
        let info = srv.info_response();
        assert_eq!(info.version, api::VERSION);
        assert_eq!(info.models.len(), 1, "the cpu engine must publish its facts");
        let m = &info.models[0];
        assert_eq!(m.model, "llama_like");
        assert!(!m.prefill_buckets.is_empty());
        assert!(m.decode_buckets.contains(&1));
        assert_eq!(m.max_prompt_tokens, *m.prefill_buckets.iter().max().unwrap());
        assert!(info.policies.contains(&"lagkv".to_string()));
        assert!(!info.prefix_cache);
    }
}

//! TCP front end: newline-delimited JSON over std::net (the offline image
//! has no tokio; one thread per connection is ample at this scale).
//!
//! The full wire protocol lives in DESIGN.md; the short version:
//!
//! Request line (all fields except `prompt` optional):
//! ```json
//! {"id": 1, "model": "llama_like", "prompt": "...", "policy": "lagkv",
//!  "sink": 4, "lag": 64, "ratio": 0.5, "max_new": 72,
//!  "stream": true, "session_id": "chat-7"}
//! ```
//!
//! * Without `"stream"` the reply is one JSON line mirroring
//!   [`crate::coordinator::Response`] (errors are structured
//!   `{"code", "message"}` objects, never bare strings).
//! * With `"stream": true` the reply is NDJSON: one line per
//!   [`crate::coordinator::Event`] (`started`, `token`, `compression`,
//!   then a terminal `done` or `error`), and the connection immediately
//!   accepts further request lines while the stream runs.
//! * `{"cancel": ID}` aborts a live request (same or another connection);
//!   the server acks with `{"event": "cancel_ack", "id": ID, "found": ..}`
//!   and the aborted stream terminates with an `error` event of code
//!   `"cancelled"`.
//! * Unknown request fields are a hard `bad-params` error listing the
//!   offending keys — a typo in `stream` or `session_id` must never
//!   silently fall back to one-shot, session-less behaviour.
//! * When the server runs with a KV pool byte budget (`--pool-mb`), a
//!   request that cannot fit even after LRU session shedding is answered
//!   with the typed `pool-exhausted` error (same `{"code", "message"}`
//!   shape) instead of being queued — memory backpressure is explicit on
//!   the wire.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::config::{PolicyKind, ScorerBackend};
use crate::coordinator::{ApiError, Event, GenHandle, GenerateParams, Request, Response, Router};
use crate::util::json::{arr, n, obj, s, Json};

/// Request-line fields the parser accepts; anything else is `bad-params`.
const KNOWN_FIELDS: &[&str] = &[
    "id",
    "model",
    "prompt",
    "policy",
    "sink",
    "lag",
    "ratio",
    "scorer",
    "skip_layers",
    "max_new",
    "seed",
    "stream",
    "session_id",
];

/// One parsed client line.
pub enum ClientLine {
    Generate { model: String, request: Request, stream: bool },
    Cancel { id: u64 },
}

pub struct Server {
    pub router: Arc<Router>,
    next_id: AtomicU64,
    /// Cancel flags of in-flight requests, keyed by request id, so a
    /// `{"cancel": id}` line on any connection can abort them.
    live: Mutex<HashMap<u64, Arc<AtomicBool>>>,
}

impl Server {
    pub fn new(router: Arc<Router>) -> Server {
        Server { router, next_id: AtomicU64::new(1), live: Mutex::new(HashMap::new()) }
    }

    fn bad(message: String) -> ApiError {
        ApiError::BadParams { message }
    }

    /// Parse one client line into a generate request or a cancel command.
    /// Absent fields use [`GenerateParams`] defaults; unknown fields are a
    /// structured `bad-params` error naming every unrecognized key.
    pub fn parse_line(&self, line: &str) -> Result<ClientLine, ApiError> {
        let v = Json::parse(line).map_err(|e| Self::bad(format!("invalid JSON: {e:#}")))?;
        let m = v.as_obj().map_err(|_| Self::bad("request must be a JSON object".into()))?;

        if m.contains_key("cancel") {
            let extra: Vec<&str> =
                m.keys().filter(|k| k.as_str() != "cancel").map(|k| k.as_str()).collect();
            if !extra.is_empty() {
                return Err(Self::bad(format!("cancel line has extra fields: {extra:?}")));
            }
            let id = v
                .get("cancel")
                .and_then(|x| x.as_i64())
                .map_err(|e| Self::bad(format!("bad cancel id: {e:#}")))?;
            return Ok(ClientLine::Cancel { id: id as u64 });
        }

        let unknown: Vec<&str> = m
            .keys()
            .map(|k| k.as_str())
            .filter(|k| !KNOWN_FIELDS.contains(k))
            .collect();
        if !unknown.is_empty() {
            return Err(Self::bad(format!(
                "unrecognized fields {unknown:?} (known: {KNOWN_FIELDS:?})"
            )));
        }

        let mut p = GenerateParams::default();
        let field = |e: anyhow::Error, name: &str| Self::bad(format!("field {name:?}: {e:#}"));
        if let Some(x) = v.opt("model") {
            p.model = x.as_str().map_err(|e| field(e, "model"))?.to_string();
        }
        if let Some(x) = v.opt("prompt") {
            p.prompt = x.as_str().map_err(|e| field(e, "prompt"))?.to_string();
        }
        if let Some(x) = v.opt("policy") {
            let name = x.as_str().map_err(|e| field(e, "policy"))?;
            p.policy = PolicyKind::parse(name).map_err(|e| field(e, "policy"))?;
        }
        if let Some(x) = v.opt("sink") {
            p.sink = x.as_usize().map_err(|e| field(e, "sink"))?;
        }
        if let Some(x) = v.opt("lag") {
            p.lag = x.as_usize().map_err(|e| field(e, "lag"))?;
        }
        if let Some(x) = v.opt("ratio") {
            p.ratio = x.as_f64().map_err(|e| field(e, "ratio"))?;
        }
        if let Some(x) = v.opt("scorer") {
            p.scorer = match x.as_str().map_err(|e| field(e, "scorer"))? {
                "xla" => ScorerBackend::Xla,
                "rust" => ScorerBackend::Rust,
                other => return Err(Self::bad(format!("unknown scorer {other:?} (rust|xla)"))),
            };
        }
        if let Some(x) = v.opt("skip_layers") {
            p.skip_layers = Some(x.as_usize().map_err(|e| field(e, "skip_layers"))?);
        }
        if let Some(x) = v.opt("max_new") {
            p.max_new = x.as_usize().map_err(|e| field(e, "max_new"))?;
        }
        if let Some(x) = v.opt("seed") {
            p.seed = x.as_i64().map_err(|e| field(e, "seed"))? as u64;
        }
        if let Some(x) = v.opt("session_id") {
            p.session = Some(x.as_str().map_err(|e| field(e, "session_id"))?.to_string());
        }
        let stream = match v.opt("stream") {
            Some(x) => x.as_bool().map_err(|e| field(e, "stream"))?,
            None => false,
        };
        let id = match v.opt("id") {
            Some(x) => x.as_i64().map_err(|e| field(e, "id"))? as u64,
            None => self.next_id.fetch_add(1, Ordering::Relaxed),
        };
        let model = p.model.clone();
        let request = p.into_request(id)?;
        Ok(ClientLine::Generate { model, request, stream })
    }

    /// Render one event as an NDJSON line body.
    pub fn render_event(ev: &Event) -> String {
        let j = match ev {
            Event::Started { id, prompt_tokens, reused_tokens } => obj(vec![
                ("event", s("started")),
                ("id", n(*id as f64)),
                ("prompt_tokens", n(*prompt_tokens as f64)),
                ("reused_tokens", n(*reused_tokens as f64)),
            ]),
            Event::Token { id, token, text_delta } => obj(vec![
                ("event", s("token")),
                ("id", n(*id as f64)),
                ("token", n(*token as f64)),
                ("text_delta", s(text_delta.clone())),
            ]),
            Event::Compression { id, layer_lens, evicted } => obj(vec![
                ("event", s("compression")),
                ("id", n(*id as f64)),
                ("layer_lens", arr(layer_lens.iter().map(|&l| n(l as f64)).collect())),
                ("evicted", n(*evicted as f64)),
            ]),
            Event::Done { id, usage, timings } => obj(vec![
                ("event", s("done")),
                ("id", n(*id as f64)),
                ("prompt_tokens", n(usage.prompt_tokens as f64)),
                ("new_tokens", n(usage.new_tokens as f64)),
                ("reused_tokens", n(usage.reused_tokens as f64)),
                ("cache_lens", arr(usage.cache_lens.iter().map(|&l| n(l as f64)).collect())),
                ("compression_events", n(usage.compression_events as f64)),
                ("queue_us", n(timings.queue_us as f64)),
                ("prefill_us", n(timings.prefill_us as f64)),
                ("decode_us", n(timings.decode_us as f64)),
            ]),
            Event::Error { id, error } => obj(vec![
                ("event", s("error")),
                ("id", n(*id as f64)),
                ("error", error.to_json()),
            ]),
        };
        j.to_string()
    }

    /// Render the one-shot response line.
    pub fn render_response(resp: &Response) -> String {
        resp.to_json().to_string()
    }

    /// Flip the cancel flag of a live request.  Returns whether the id was
    /// known (an already-finished or never-seen id is `false`).
    pub fn cancel(&self, id: u64) -> bool {
        match self.live.lock().unwrap().get(&id) {
            Some(flag) => {
                flag.store(true, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// How many requests are currently in flight (diagnostics / tests).
    pub fn live_requests(&self) -> usize {
        self.live.lock().unwrap().len()
    }

    fn forward_events(&self, id: u64, handle: GenHandle, writer: Arc<Mutex<TcpStream>>) {
        for ev in handle.events.iter() {
            let terminal = ev.is_terminal();
            if write_line(&writer, &Self::render_event(&ev)).is_err() {
                // Connection gone: dropping the handle aborts the slot.
                break;
            }
            if terminal {
                break;
            }
        }
        self.live.lock().unwrap().remove(&id);
    }

    fn handle_conn(self: Arc<Self>, stream: TcpStream) -> Result<()> {
        let writer = Arc::new(Mutex::new(stream.try_clone().context("clone stream")?));
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            match self.parse_line(&line) {
                Ok(ClientLine::Cancel { id }) => {
                    let found = self.cancel(id);
                    let ack = obj(vec![
                        ("event", s("cancel_ack")),
                        ("id", n(id as f64)),
                        ("found", Json::Bool(found)),
                    ]);
                    write_line(&writer, &ack.to_string())?;
                }
                Ok(ClientLine::Generate { model, request, stream: streaming }) => {
                    let id = request.id;
                    // Register under the live-map lock so a duplicate id
                    // can never clobber another request's cancel flag (or
                    // have its own entry removed by the first finisher).
                    let submitted = {
                        let mut live = self.live.lock().unwrap();
                        if live.contains_key(&id) {
                            Err(ApiError::BadParams {
                                message: format!("request id {id} is already in flight"),
                            })
                        } else {
                            self.router.submit(&model, request).map(|handle| {
                                live.insert(id, handle.cancel_flag());
                                handle
                            })
                        }
                    };
                    match submitted {
                        Ok(handle) => {
                            if streaming {
                                // Forward events off-thread so this reader
                                // keeps accepting cancel/request lines.
                                let me = self.clone();
                                let w = writer.clone();
                                std::thread::spawn(move || me.forward_events(id, handle, w));
                            } else {
                                let resp = handle.wait();
                                self.live.lock().unwrap().remove(&id);
                                write_line(&writer, &Self::render_response(&resp))?;
                            }
                        }
                        Err(e) => {
                            let resp = Response::from_error(id, e);
                            write_line(&writer, &Self::render_response(&resp))?;
                        }
                    }
                }
                Err(e) => {
                    write_line(&writer, &obj(vec![("error", e.to_json())]).to_string())?;
                }
            }
        }
        Ok(())
    }

    /// Bind the listen socket; `port == 0` picks an ephemeral port.  The
    /// actual port is returned (CI smoke tests bind ephemerally).
    pub fn bind(port: u16) -> Result<(TcpListener, u16)> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding 127.0.0.1:{port}"))?;
        let actual = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        Ok((listener, actual))
    }

    /// Serve until `stop` flips true (checked between accepts).
    pub fn serve(self: Arc<Self>, port: u16, stop: Arc<AtomicBool>) -> Result<()> {
        let (listener, actual) = Self::bind(port)?;
        eprintln!("lagkv server listening on 127.0.0.1:{actual}");
        self.serve_listener(listener, stop)
    }

    /// Accept loop over an already-bound (nonblocking) listener.
    pub fn serve_listener(
        self: Arc<Self>,
        listener: TcpListener,
        stop: Arc<AtomicBool>,
    ) -> Result<()> {
        loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    let me = self.clone();
                    std::thread::spawn(move || {
                        if let Err(e) = me.handle_conn(stream) {
                            eprintln!("connection error: {e:#}");
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

fn write_line(writer: &Arc<Mutex<TcpStream>>, line: &str) -> std::io::Result<()> {
    let mut w = writer.lock().unwrap();
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

/// Minimal blocking client for the line protocol (used by serve_demo,
/// the CI smoke binary, and integration tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(port: u16) -> Result<Client> {
        let stream = TcpStream::connect(("127.0.0.1", port))?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    pub fn send_line(&mut self, json: &str) -> Result<()> {
        self.writer.write_all(json.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    /// Read one JSON line (blocking).
    pub fn read_json(&mut self) -> Result<Json> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
    }

    /// One-shot call: send a request line, read the single response line.
    pub fn call(&mut self, request_json: &str) -> Result<Json> {
        self.send_line(request_json)?;
        self.read_json()
    }

    /// Streaming call: send a request line, collect event lines until the
    /// terminal `done`/`error` (or a top-level parse-error reply).
    pub fn stream(&mut self, request_json: &str) -> Result<Vec<Json>> {
        self.send_line(request_json)?;
        let mut events = Vec::new();
        loop {
            let v = self.read_json()?;
            let kind =
                v.opt("event").and_then(|e| e.as_str().ok()).unwrap_or("").to_string();
            let terminal = kind == "done" || kind == "error" || kind.is_empty();
            events.push(v);
            if terminal {
                return Ok(events);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::backend::EngineSpec;
    use crate::coordinator::{Timings, Usage};

    fn server() -> Server {
        Server::new(Arc::new(Router::start(EngineSpec::cpu(), &[])))
    }

    fn parse_gen(srv: &Server, line: &str) -> (String, Request, bool) {
        match srv.parse_line(line).unwrap() {
            ClientLine::Generate { model, request, stream } => (model, request, stream),
            ClientLine::Cancel { .. } => panic!("expected a generate line"),
        }
    }

    #[test]
    fn parse_request_defaults_and_overrides() {
        let srv = server();
        let (model, req, stream) = parse_gen(
            &srv,
            r#"{"prompt": "hello", "policy": "h2o", "lag": 32, "max_new": 5}"#,
        );
        assert_eq!(model, "llama_like");
        assert_eq!(req.compression.policy, PolicyKind::H2O);
        assert_eq!(req.compression.lag, 32);
        assert_eq!(req.max_new, 5);
        assert_eq!(req.prompt, "hello");
        assert!(req.session.is_none());
        assert!(!stream);
    }

    #[test]
    fn parse_stream_and_session_fields() {
        let srv = server();
        let (_, req, stream) = parse_gen(
            &srv,
            r#"{"prompt": "hi", "stream": true, "session_id": "chat-1"}"#,
        );
        assert!(stream);
        assert_eq!(req.session.as_deref(), Some("chat-1"));
    }

    #[test]
    fn bad_request_is_typed_error() {
        let srv = server();
        for line in ["{}", "not json", "[1,2]", r#"{"prompt": "x", "ratio": 0}"#] {
            let err = srv.parse_line(line).unwrap_err();
            assert_eq!(err.code(), "bad-params", "line {line:?}");
        }
    }

    #[test]
    fn unknown_fields_are_rejected_by_name() {
        let srv = server();
        let err = srv
            .parse_line(r#"{"prompt": "x", "strem": true, "sessionid": "a"}"#)
            .unwrap_err();
        assert_eq!(err.code(), "bad-params");
        let msg = err.message();
        assert!(msg.contains("strem"), "message must name the typo: {msg}");
        assert!(msg.contains("sessionid"), "message must name the typo: {msg}");
    }

    #[test]
    fn cancel_line_parses_and_rejects_extras() {
        let srv = server();
        match srv.parse_line(r#"{"cancel": 12}"#).unwrap() {
            ClientLine::Cancel { id } => assert_eq!(id, 12),
            ClientLine::Generate { .. } => panic!("expected cancel"),
        }
        assert!(srv.parse_line(r#"{"cancel": 12, "model": "m"}"#).is_err());
        // cancelling an unknown id is not found
        assert!(!srv.cancel(12));
    }

    #[test]
    fn response_renders_as_json() {
        let resp = Response {
            id: 3,
            text: "42".into(),
            tokens: vec![9, 2],
            prompt_tokens: 10,
            reused_tokens: 0,
            cache_lens: vec![12, 12],
            compression_events: 1,
            queue_us: 5,
            prefill_us: 6,
            decode_us: 7,
            error: None,
        };
        let v = Json::parse(&Server::render_response(&resp)).unwrap();
        assert_eq!(v.get("id").unwrap().as_i64().unwrap(), 3);
        assert_eq!(v.get("text").unwrap().as_str().unwrap(), "42");
        assert_eq!(v.get("cache_lens").unwrap().as_usize_vec().unwrap(), vec![12, 12]);
        assert_eq!(*v.get("error").unwrap(), Json::Null);
    }

    #[test]
    fn error_response_carries_code_and_message() {
        let resp = Response::from_error(4, ApiError::QueueFull { model: "m".into() });
        let v = Json::parse(&Server::render_response(&resp)).unwrap();
        let e = v.get("error").unwrap();
        assert_eq!(e.get("code").unwrap().as_str().unwrap(), "queue-full");
        assert!(!e.get("message").unwrap().as_str().unwrap().is_empty());
    }

    #[test]
    fn pool_exhausted_renders_typed_wire_error() {
        let resp = Response::from_error(
            5,
            ApiError::PoolExhausted { model: "m".into(), detail: "need 64 bytes".into() },
        );
        let v = Json::parse(&Server::render_response(&resp)).unwrap();
        let e = v.get("error").unwrap();
        assert_eq!(e.get("code").unwrap().as_str().unwrap(), "pool-exhausted");
        assert!(e.get("message").unwrap().as_str().unwrap().contains("need 64 bytes"));
    }

    #[test]
    fn events_render_as_tagged_lines() {
        let done = Event::Done {
            id: 7,
            usage: Usage {
                prompt_tokens: 3,
                new_tokens: 2,
                reused_tokens: 0,
                cache_lens: vec![5],
                compression_events: 1,
            },
            timings: Timings { queue_us: 1, prefill_us: 2, decode_us: 3 },
        };
        let v = Json::parse(&Server::render_event(&done)).unwrap();
        assert_eq!(v.get("event").unwrap().as_str().unwrap(), "done");
        assert_eq!(v.get("new_tokens").unwrap().as_usize().unwrap(), 2);

        let tok = Event::Token { id: 7, token: 1200, text_delta: " the".into() };
        let v = Json::parse(&Server::render_event(&tok)).unwrap();
        assert_eq!(v.get("event").unwrap().as_str().unwrap(), "token");
        assert_eq!(v.get("text_delta").unwrap().as_str().unwrap(), " the");

        let err = Event::Error { id: 7, error: ApiError::Cancelled };
        let v = Json::parse(&Server::render_event(&err)).unwrap();
        assert_eq!(
            v.get("error").unwrap().get("code").unwrap().as_str().unwrap(),
            "cancelled"
        );
    }
}

//! The [`Scorer`] trait and the built-in policy implementations.

use anyhow::Result;

use crate::config::PolicyKind;

use super::scores;

/// Everything a policy may look at when scoring one partition of one head.
pub struct PartitionInput<'a> {
    pub layer: usize,
    pub head: usize,
    /// Current partition K/V, row-major `[l, d]`.
    pub k_cur: &'a [f32],
    pub v_cur: &'a [f32],
    /// Lag reference (the next chunk), row-major `[l, d]`.
    pub k_ref: &'a [f32],
    pub v_ref: &'a [f32],
    /// Accumulated attention mass per current-partition token (H2O).
    pub attn_acc: &'a [f32],
    /// Original absolute positions of the current partition's tokens.
    pub positions: &'a [i32],
    pub l: usize,
    pub d: usize,
}

/// A partition-scoring policy.  Implementations must be deterministic given
/// their construction parameters (the Random policy is seeded).
///
/// NOT `Send`: the XLA-backed scorer holds PJRT handles, which are
/// single-threaded; scorers live and die on their coordinator's thread.
pub trait Scorer {
    fn name(&self) -> &'static str;
    /// Per-token scores, higher = keep.  Length must equal `inp.l`.
    fn score(&mut self, inp: &PartitionInput<'_>) -> Result<Vec<f32>>;
    /// Whether the policy consumes the instrumented attention statistics.
    fn needs_attention(&self) -> bool {
        false
    }
    /// Global-scope policies (the original H2O) pick victims across the
    /// WHOLE evictable region (everything but the sink and the newest lag
    /// window) instead of inside one partition.  The eviction *budget* per
    /// event is identical (L - floor(rL) rows), so cache lengths follow the
    /// same Eq. 10 law and comparisons stay apples-to-apples.
    fn global_scope(&self) -> bool {
        false
    }
}

/// The paper's method, Eqs. 5-9.
pub struct LagKvScorer;

impl Scorer for LagKvScorer {
    fn name(&self) -> &'static str {
        "lagkv"
    }

    fn score(&mut self, inp: &PartitionInput<'_>) -> Result<Vec<f32>> {
        Ok(scores::lagkv_score(inp.k_cur, inp.v_cur, inp.k_ref, inp.v_ref, inp.l, inp.d))
    }
}

/// Appendix A.2 LocalKV: min/max from the local chunk (Eqs. 12-13).
pub struct LocalKvScorer;

impl Scorer for LocalKvScorer {
    fn name(&self) -> &'static str {
        "localkv"
    }

    fn score(&mut self, inp: &PartitionInput<'_>) -> Result<Vec<f32>> {
        Ok(scores::localkv_score(inp.k_cur, inp.v_cur, inp.l, inp.d))
    }
}

/// Appendix A.2 recursive L2-norm: -||K||2 (Eq. 14).  Layer skipping is
/// handled by the driver via `CompressionConfig::skip_layers`.
pub struct L2NormScorer;

impl Scorer for L2NormScorer {
    fn name(&self) -> &'static str {
        "l2norm"
    }

    fn score(&mut self, inp: &PartitionInput<'_>) -> Result<Vec<f32>> {
        Ok(scores::l2norm_score(inp.k_cur, inp.l, inp.d))
    }
}

/// H2O heavy-hitter oracle: the score of a token is its accumulated
/// attention mass (prefill column sums plus every decode step's row), the
/// statistic the original H2O keeps running.  Scope is GLOBAL, matching
/// Zhang et al.: low-mass tokens are evicted from anywhere in the cache
/// (outside the sink and the sliding window), which is precisely what makes
/// long digit strings leak (§3.3) — pre-query attention cannot know the
/// passkey will matter.
pub struct H2oScorer;

impl Scorer for H2oScorer {
    fn name(&self) -> &'static str {
        "h2o"
    }

    fn score(&mut self, inp: &PartitionInput<'_>) -> Result<Vec<f32>> {
        Ok(inp.attn_acc.to_vec())
    }

    fn needs_attention(&self) -> bool {
        true
    }

    fn global_scope(&self) -> bool {
        true
    }
}

/// StreamingLLM-style recency: keep the newest tokens of each partition.
pub struct StreamingScorer;

impl Scorer for StreamingScorer {
    fn name(&self) -> &'static str {
        "streaming"
    }

    fn score(&mut self, inp: &PartitionInput<'_>) -> Result<Vec<f32>> {
        Ok((0..inp.l).map(|i| i as f32).collect())
    }
}

/// StreamingLLM proper (sink + recency window): the score of a token is
/// its absolute position, under GLOBAL scope — the oldest evictable
/// tokens go first, anywhere in the cache, so what survives is exactly
/// the attention sink plus the newest window.  Needs no attention
/// statistics, which makes it the cheap FlashAttention-compatible
/// baseline LagKV must beat (pinned in sim-regression).
pub struct StreamingLlmScorer;

impl Scorer for StreamingLlmScorer {
    fn name(&self) -> &'static str {
        "streamingllm"
    }

    fn score(&mut self, inp: &PartitionInput<'_>) -> Result<Vec<f32>> {
        Ok(inp.positions.iter().map(|&p| p as f32).collect())
    }

    fn global_scope(&self) -> bool {
        true
    }
}

/// Uniform-random retention (sanity floor).  Seeded per (layer, head,
/// partition-start position) so runs are reproducible and heads diverge.
pub struct RandomScorer {
    pub seed: u64,
}

impl Scorer for RandomScorer {
    fn name(&self) -> &'static str {
        "random"
    }

    fn score(&mut self, inp: &PartitionInput<'_>) -> Result<Vec<f32>> {
        let start_pos = inp.positions.first().copied().unwrap_or(0) as u64;
        let mut rng = crate::util::rng::Rng::seed_from(
            self.seed ^ (inp.layer as u64) << 40 ^ (inp.head as u64) << 32 ^ start_pos,
        );
        Ok((0..inp.l).map(|_| rng.f32()).collect())
    }
}

/// Construct the pure-Rust scorer for a policy.  `PolicyKind::None` never
/// reaches the driver (compression disabled upstream) but returns a
/// recency scorer for safety.
pub fn make_policy(kind: PolicyKind, seed: u64) -> Box<dyn Scorer> {
    match kind {
        PolicyKind::LagKv => Box::new(LagKvScorer),
        PolicyKind::LocalKv => Box::new(LocalKvScorer),
        PolicyKind::L2Norm => Box::new(L2NormScorer),
        PolicyKind::H2O => Box::new(H2oScorer),
        PolicyKind::Streaming | PolicyKind::None => Box::new(StreamingScorer),
        PolicyKind::StreamingLlm => Box::new(StreamingLlmScorer),
        PolicyKind::Random => Box::new(RandomScorer { seed }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_input<'a>(
        k: &'a [f32],
        v: &'a [f32],
        attn: &'a [f32],
        pos: &'a [i32],
        l: usize,
        d: usize,
    ) -> PartitionInput<'a> {
        PartitionInput {
            layer: 0,
            head: 0,
            k_cur: k,
            v_cur: v,
            k_ref: k,
            v_ref: v,
            attn_acc: attn,
            positions: pos,
            l,
            d,
        }
    }

    #[test]
    fn all_policies_return_l_scores() {
        let l = 8;
        let d = 4;
        let k: Vec<f32> = (0..l * d).map(|i| (i as f32).sin()).collect();
        let v = k.clone();
        let attn: Vec<f32> = (0..l).map(|i| i as f32 * 0.1).collect();
        let pos: Vec<i32> = (0..l as i32).collect();
        for kind in crate::config::PolicyKind::all() {
            let mut p = make_policy(*kind, 7);
            let s = p.score(&dummy_input(&k, &v, &attn, &pos, l, d)).unwrap();
            assert_eq!(s.len(), l, "{}", p.name());
        }
    }

    #[test]
    fn h2o_scores_are_attention() {
        let l = 4;
        let d = 2;
        let k = vec![0.0; l * d];
        let attn = vec![3.0, 1.0, 2.0, 0.5];
        let pos = vec![0, 1, 2, 3];
        let mut p = make_policy(PolicyKind::H2O, 0);
        assert!(p.needs_attention());
        let s = p.score(&dummy_input(&k, &k, &attn, &pos, l, d)).unwrap();
        assert_eq!(s, attn);
    }

    #[test]
    fn streaming_prefers_recent() {
        let l = 5;
        let d = 1;
        let k = vec![0.0; l];
        let attn = vec![0.0; l];
        let pos = vec![0, 1, 2, 3, 4];
        let mut p = make_policy(PolicyKind::Streaming, 0);
        let s = p.score(&dummy_input(&k, &k, &attn, &pos, l, d)).unwrap();
        assert!(s.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn streamingllm_is_global_recency() {
        let l = 5;
        let d = 1;
        let k = vec![0.0; l];
        let attn = vec![0.0; l];
        // non-contiguous positions (mid-cache, post-eviction): the score
        // must track the token's age, not its slot index
        let pos = vec![3, 7, 8, 20, 21];
        let mut p = make_policy(PolicyKind::StreamingLlm, 0);
        assert!(p.global_scope(), "evicts across the whole cache");
        assert!(!p.needs_attention());
        let s = p.score(&dummy_input(&k, &k, &attn, &pos, l, d)).unwrap();
        assert_eq!(s, vec![3.0, 7.0, 8.0, 20.0, 21.0]);
    }

    #[test]
    fn random_is_deterministic_per_position() {
        let l = 6;
        let d = 1;
        let k = vec![0.0; l];
        let attn = vec![0.0; l];
        let pos = vec![10, 11, 12, 13, 14, 15];
        let mut p1 = make_policy(PolicyKind::Random, 42);
        let mut p2 = make_policy(PolicyKind::Random, 42);
        let a = p1.score(&dummy_input(&k, &k, &attn, &pos, l, d)).unwrap();
        let b = p2.score(&dummy_input(&k, &k, &attn, &pos, l, d)).unwrap();
        assert_eq!(a, b);
        // different start position -> different scores
        let pos2 = vec![20, 21, 22, 23, 24, 25];
        let c = p1.score(&dummy_input(&k, &k, &attn, &pos2, l, d)).unwrap();
        assert_ne!(a, c);
    }
}

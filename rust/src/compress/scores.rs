//! Pure-Rust scoring kernels — numerical mirrors of the L1 Pallas kernels
//! (python/compile/kernels/lagkv_score.py) and the jnp oracles (ref.py).
//!
//! Layouts: every partition is a row-major `[l, d]` slice of one head.
//! Scores are "higher = keep".  Cross-validated three ways:
//!   * golden vectors from the python oracle (rust/tests/golden.rs),
//!   * the AOT-compiled Pallas kernel via PJRT (rust/tests/integration.rs),
//!   * property tests on distribution/outlier invariants (below).

pub const EPS: f32 = 1e-6;

/// Softmax'd channel-std of the lag-normalized tile — one "half" of the
/// LagKV score (Eqs. 5-8) for a single head.
///
/// `cur`/`lag`: `[l, d]` row-major.  Returns `l` scores summing to 1.
pub fn half_score(cur: &[f32], lag: &[f32], l: usize, d: usize) -> Vec<f32> {
    debug_assert_eq!(cur.len(), l * d);
    debug_assert_eq!(lag.len(), l * d);
    // Eqs. 5-6: per-channel min/max over the REFERENCE's sequence axis.
    let mut mn = vec![f32::INFINITY; d];
    let mut mx = vec![f32::NEG_INFINITY; d];
    for row in lag.chunks_exact(d) {
        for (c, &x) in row.iter().enumerate() {
            if x < mn[c] {
                mn[c] = x;
            }
            if x > mx[c] {
                mx[c] = x;
            }
        }
    }
    let mut inv_range = vec![0.0f32; d];
    for c in 0..d {
        inv_range[c] = 1.0 / (mx[c] - mn[c] + EPS);
    }
    // Eq. 7 + Eq. 8 first half: normalize, per-token channel-wise std
    // (population, ddof=0 — matching jnp .std()).
    let mut std = Vec::with_capacity(l);
    for row in cur.chunks_exact(d) {
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for (c, &x) in row.iter().enumerate() {
            let n = ((x - mn[c]) * inv_range[c]) as f64;
            sum += n;
            sum2 += n * n;
        }
        let mean = sum / d as f64;
        let var = (sum2 / d as f64 - mean * mean).max(0.0);
        std.push(var.sqrt() as f32);
    }
    // Eq. 8 second half: softmax along the partition.
    softmax_inplace(&mut std);
    std
}

/// Full LagKV score for one head (Eq. 9: K-half + V-half).
pub fn lagkv_score(
    k_cur: &[f32],
    v_cur: &[f32],
    k_ref: &[f32],
    v_ref: &[f32],
    l: usize,
    d: usize,
) -> Vec<f32> {
    let ks = half_score(k_cur, k_ref, l, d);
    let vs = half_score(v_cur, v_ref, l, d);
    ks.iter().zip(&vs).map(|(a, b)| a + b).collect()
}

/// LocalKV variant (Eqs. 12-13): the chunk is its own reference.
pub fn localkv_score(k_cur: &[f32], v_cur: &[f32], l: usize, d: usize) -> Vec<f32> {
    lagkv_score(k_cur, v_cur, k_cur, v_cur, l, d)
}

/// Recursive L2-norm variant (Eq. 14): score = -||K_i||_2.
pub fn l2norm_score(k_cur: &[f32], l: usize, d: usize) -> Vec<f32> {
    debug_assert_eq!(k_cur.len(), l * d);
    k_cur
        .chunks_exact(d)
        .map(|row| -(row.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt() as f32))
        .collect()
}

/// In-place numerically-stable softmax.
pub fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn half_score_is_distribution() {
        prop::check(100, |g| {
            let l = g.usize(2, 64);
            let d = g.usize(1, 32);
            let (s1, o1) = (g.f32(0.01, 20.0), g.f32(-10.0, 10.0));
            let (s2, o2) = (g.f32(0.01, 20.0), g.f32(-10.0, 10.0));
            let cur = g.vec_normal(l * d, s1, o1);
            let lag = g.vec_normal(l * d, s2, o2);
            let s = half_score(&cur, &lag, l, d);
            let sum: f32 = s.iter().sum();
            if (sum - 1.0).abs() > 1e-4 {
                return Err(format!("softmax sum {sum}"));
            }
            if s.iter().any(|&x| !(x > 0.0) || !x.is_finite()) {
                return Err("non-positive or non-finite score".into());
            }
            Ok(())
        });
    }

    #[test]
    fn constant_reference_is_stable() {
        // max == min in every channel of the reference: EPS guard must hold
        let l = 8;
        let d = 4;
        let cur: Vec<f32> = (0..l * d).map(|i| i as f32 * 0.1).collect();
        let lag = vec![2.5f32; l * d];
        let s = half_score(&cur, &lag, l, d);
        assert!(s.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn outlier_token_wins() {
        // The paper's core mechanism: a token incoherent with the lag
        // reference's min/max band gets the top score.
        let l = 16;
        let d = 8;
        let mut rng = crate::util::rng::Rng::seed_from(2);
        let mut mk = |scale: f32| -> Vec<f32> {
            (0..l * d).map(|_| rng.normal() * scale).collect()
        };
        let mut k_cur = mk(0.1);
        let v_cur = mk(0.1);
        let k_ref = mk(0.1);
        let v_ref = mk(0.1);
        for c in 0..d {
            k_cur[5 * d + c] = 25.0;
        }
        let s = lagkv_score(&k_cur, &v_cur, &k_ref, &v_ref, l, d);
        let argmax = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, 5);
    }

    #[test]
    fn lagkv_sums_to_two() {
        let mut rng = crate::util::rng::Rng::seed_from(3);
        let l = 32;
        let d = 16;
        let xs: Vec<Vec<f32>> =
            (0..4).map(|_| (0..l * d).map(|_| rng.normal()).collect()).collect();
        let s = lagkv_score(&xs[0], &xs[1], &xs[2], &xs[3], l, d);
        let sum: f32 = s.iter().sum();
        assert!((sum - 2.0).abs() < 1e-4, "sum {sum}");
    }

    #[test]
    fn l2norm_prefers_small_keys() {
        let l = 4;
        let d = 2;
        let k = vec![
            1.0, 1.0, // norm ~1.41
            0.1, 0.1, // norm ~0.14  <- highest score
            5.0, 5.0, // norm ~7.07  <- lowest
            2.0, 0.0,
        ];
        let s = l2norm_score(&k, l, d);
        assert!(s[1] > s[0] && s[0] > s[3] && s[3] > s[2]);
    }

    #[test]
    fn localkv_equals_lagkv_with_self_reference() {
        let mut rng = crate::util::rng::Rng::seed_from(4);
        let l = 8;
        let d = 4;
        let k: Vec<f32> = (0..l * d).map(|_| rng.normal()).collect();
        let v: Vec<f32> = (0..l * d).map(|_| rng.normal()).collect();
        assert_eq!(localkv_score(&k, &v, l, d), lagkv_score(&k, &v, &k, &v, l, d));
    }

    #[test]
    fn softmax_stability_extremes() {
        let mut xs = vec![1e30f32, -1e30, 0.0];
        softmax_inplace(&mut xs);
        assert!(xs.iter().all(|x| x.is_finite()));
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }
}

//! Top-k index selection with the cache compactor's layout convention:
//! the k best-scoring indices, returned in **ascending index order** so the
//! surviving rows keep their temporal order (matches ref.topk_indices_ref:
//! stable argsort by descending score, take k, sort).
//!
//! NaN contract: a NaN score sorts BELOW every finite score (and below
//! -inf), so corrupted scores are evicted first and never displace a real
//! candidate — pinned by the tie/NaN property tests.

use std::cmp::Ordering;

/// Descending-score comparator over indices with the NaN contract: any NaN
/// orders after every non-NaN score (including -inf); NaN vs NaN is a tie.
#[inline]
fn desc_cmp(scores: &[f32], a: usize, b: usize) -> Ordering {
    let (sa, sb) = (scores[a], scores[b]);
    match (sa.is_nan(), sb.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater, // a sorts last
        (false, true) => Ordering::Less,
        (false, false) => sb.partial_cmp(&sa).expect("non-NaN scores are comparable"),
    }
}

/// Indices of the `k` largest scores, ties broken toward the EARLIER index
/// (stable), returned ascending.  `k` is clamped to `scores.len()`.
pub fn topk_indices(scores: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    // stable sort by descending score => ties keep ascending index order
    idx.sort_by(|&a, &b| desc_cmp(scores, a, b));
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

/// Selection on an already-allocated scratch vector (hot-path variant used
/// by the driver; avoids per-partition allocation).
pub fn topk_indices_into(scores: &[f32], k: usize, scratch: &mut Vec<usize>, out: &mut Vec<usize>) {
    let k = k.min(scores.len());
    out.clear();
    if k == 0 {
        return;
    }
    scratch.clear();
    scratch.extend(0..scores.len());
    // partial selection: kth-element then sort the prefix
    scratch.select_nth_unstable_by(k - 1, |&a, &b| desc_cmp(scores, a, b).then(a.cmp(&b)));
    out.extend_from_slice(&scratch[..k]);
    out.sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn basic_selection() {
        let s = [0.1, 0.9, 0.5, 0.7];
        assert_eq!(topk_indices(&s, 2), vec![1, 3]);
        assert_eq!(topk_indices(&s, 4), vec![0, 1, 2, 3]);
        assert_eq!(topk_indices(&s, 9), vec![0, 1, 2, 3]);
        assert!(topk_indices(&s, 0).is_empty());
    }

    #[test]
    fn ties_prefer_earlier() {
        let s = [1.0, 1.0, 1.0, 1.0];
        assert_eq!(topk_indices(&s, 2), vec![0, 1]);
    }

    #[test]
    fn nan_scores_sort_below_everything() {
        let s = [0.5, f32::NAN, 0.9, f32::NAN, f32::NEG_INFINITY];
        assert_eq!(topk_indices(&s, 2), vec![0, 2]);
        // -inf still beats NaN; NaNs are only admitted when finite (and
        // -inf) candidates are exhausted, earliest NaN first
        assert_eq!(topk_indices(&s, 3), vec![0, 2, 4]);
        assert_eq!(topk_indices(&s, 4), vec![0, 1, 2, 4]);
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        topk_indices_into(&s, 3, &mut scratch, &mut out);
        assert_eq!(out, vec![0, 2, 4]);
        topk_indices_into(&s, 4, &mut scratch, &mut out);
        assert_eq!(out, vec![0, 1, 2, 4]);
    }

    #[test]
    fn fast_variant_agrees_with_reference() {
        prop::check(200, |g| {
            let n = g.usize(1, 100);
            let k = g.usize(0, n);
            let scores = g.vec_f32(n, -5.0, 5.0);
            let want = topk_indices(&scores, k);
            let mut scratch = Vec::new();
            let mut got = Vec::new();
            topk_indices_into(&scores, k, &mut scratch, &mut got);
            // Both must pick k indices whose score multiset is maximal; with
            // distinct floats they are identical.
            if got != want {
                // tolerate tie permutations: compare score multisets
                let sum_got: f32 = got.iter().map(|&i| scores[i]).sum();
                let sum_want: f32 = want.iter().map(|&i| scores[i]).sum();
                if (sum_got - sum_want).abs() > 1e-5 {
                    return Err(format!("topk mismatch: {got:?} vs {want:?}"));
                }
            }
            if got.windows(2).any(|w| w[0] >= w[1]) {
                return Err("not ascending".into());
            }
            Ok(())
        });
    }
}

//! Eviction policies and the recursive compression driver.
//!
//! A [`Scorer`] maps one partition of one head's cache (plus its lag
//! reference and optional attention statistics) to per-token importance
//! scores; the [`driver`] selects the top `floor(r*L)` per head and
//! compacts the cache.  All policies plug into the *same* driver, which is
//! exactly the paper's framing in Appendix A.2 ("variants from the LagKV
//! framework: only the scoring method changes").
//!
//! Scoring backends:
//! * [`scores`]   — pure-Rust implementations (default hot path, validated
//!                  against the python jnp oracles through golden vectors
//!                  *and* against the AOT Pallas kernel at runtime).
//! * the XLA backend lives in `engine::XlaScorer` (it needs a PJRT client),
//!   selected with `--scorer=xla`.

pub mod driver;
pub mod policy;
pub mod scores;
pub mod topk;

pub use driver::{maybe_compress, CompressionEvent};
pub use policy::{make_policy, PartitionInput, Scorer};
